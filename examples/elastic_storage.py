"""Elastic storage-cluster scenario: checkpoints surviving failures.

Simulates the full fault-tolerance story on a 10-node storage cluster:
save a model checkpoint with 3-way ASURA replication, kill nodes (crash =
no drain), then repair and grow the cluster as THROTTLED LIVE MIGRATIONS
(DESIGN.md sections 8, 10) whose unit of work is a REPLICA SLOT:

  * a node FAILURE becomes a throttled replica repair -- exactly the dead
    node's replica mass re-replicates, a bandwidth-budgeted batch of
    copies per round (the per-round (src, dst) matrices print below),
    while the surviving two replicas of every chunk keep serving reads,
  * growing the cluster drains the minimal per-slot chunk set under an
    ingress budget while reads keep restoring bit-identical state through
    the mixed-version replica read rule -- no atomic table swap, no
    serving gap.

One `TraceLedger` (DESIGN.md section 13) rides the whole scenario:
checkpoint save/restore spans, one `migrate.round` event per drained
round with per-round byte accounting, and the planner's prefilter
counters -- exported as JSONL + Prometheus text at the end.

Run:  PYTHONPATH=src python examples/elastic_storage.py
"""

import numpy as np

from repro.checkpoint import AsuraCheckpointStore, CheckpointManager
from repro.obs import TraceLedger


def cluster_usage(store) -> str:
    used = {nid: node.used_bytes() // 1024 for nid, node in sorted(store.nodes.items())}
    return " ".join(f"n{n}:{k}K" for n, k in used.items())


def main() -> None:
    rng = np.random.default_rng(0)
    state = {
        "layer0/w": rng.standard_normal((2048, 2048)).astype(np.float32),
        "layer1/w": rng.standard_normal((2048, 2048)).astype(np.float32),
        "opt/m": rng.standard_normal((2048, 2048)).astype(np.float32),
    }
    store = AsuraCheckpointStore({i: 1.0 for i in range(10)}, n_replicas=3)
    ledger = TraceLedger()
    mgr = CheckpointManager(store, ledger=ledger)

    mgr.save(step=100, tree=state)
    print("saved 48 MiB checkpoint, 3-way replicated")
    print("usage:", cluster_usage(store))

    # hard-kill two nodes (below replication factor) and restore anyway
    store.fail_node(2)
    store.fail_node(7)
    out = mgr.restore(100, state)
    assert all(np.array_equal(out[k], state[k]) for k in state)
    print("restored bit-identical with nodes 2 and 7 DOWN")

    # repair node 2 as a THROTTLED REPLICA MIGRATION: only its replica
    # mass re-replicates (per-slot plan, every flow sourced at the victim),
    # 6 copies per destination per round, readable the whole time
    clock = {"now": 0.0}
    repair = store.begin_remove_node(
        2, ingress=6, clock=lambda: clock["now"], round_seconds=1.0, ledger=ledger
    )
    plan = repair.live.state.plan
    print(
        f"repairing node 2 live: {plan.n_moves} replica copies to rebuild "
        f"(per-slot plan over {plan.n_scanned} affected chunks), ingress 6/round"
    )
    while not repair.done:
        clock["now"] += 1.0
        for matrix in repair.pump():
            flows = " ".join(
                f"n{s}->n{d}:{c}" for (s, d), c in sorted(matrix.items())
            )
            print(f"  t={clock['now']:>3.0f}s  repair moved {flows}")
        # mid-repair reads fall back to the surviving replicas of the
        # degraded slots -- restores stay bit-identical every round
        out = mgr.restore(100, state)
        assert all(np.array_equal(out[k], state[k]) for k in state)
    print(f"node 2 repaired: {repair.copies_moved} copies (minimal replica mass)")

    # the second victim repairs atomically (the instantaneous variant)
    moved = store.remove_node_and_repair(7)
    print(f"repaired node 7 atomically: {moved} chunk copies re-replicated")
    print("usage:", cluster_usage(store))

    # grow the cluster LIVE: only the new node's share moves (per replica
    # slot), throttled to an ingress budget of 8 copies per round, served
    # throughout
    clock["now"] = 0.0
    migration = store.begin_add_node(
        20,
        capacity=2.0,
        ingress=8,
        clock=lambda: clock["now"],
        round_seconds=1.0,
        ledger=ledger,
    )
    plan = migration.live.state.plan
    print(
        f"added node 20 (cap 2.0) as a live migration: "
        f"{plan.n_moves} replica copies over {plan.n_scanned} chunks to "
        f"move, ingress budget 8/round"
    )
    while not migration.done:
        clock["now"] += 1.0
        for matrix in migration.pump():
            flows = " ".join(
                f"n{s}->n{d}:{c}" for (s, d), c in sorted(matrix.items())
            )
            landed = int(migration.live.state.landed.sum())
            hit = landed / max(1, plan.n_moves)
            print(
                f"  t={clock['now']:>4.0f}s  moved {flows}  "
                f"dual-version hit ratio {hit:.0%} (reads at v+1 owner)"
            )
        # serving under load, mid-migration: restore goes through the
        # dual-version read rule and stays bit-identical every round
        out = mgr.restore(100, state)
        assert all(np.array_equal(out[k], state[k]) for k in state)
    print("migration drained; usage:", cluster_usage(store))

    out = mgr.restore(100, state)
    assert all(np.array_equal(out[k], state[k]) for k in state)
    print("restore still bit-identical after repair + live growth")

    # the whole scenario left a structured trail on the one ledger:
    # save/restore spans, per-round migration events with byte counts,
    # and running counters -- exportable as JSONL or Prometheus text
    rounds = ledger.events(kind="migrate.round")
    moved_bytes = sum(e.get("bytes", 0) for e in rounds)
    print(
        f"telemetry: {len(ledger.events())} events "
        f"({len(rounds)} migration rounds, {moved_bytes // (1 << 20)} MiB moved), "
        f"counters {dict(sorted(ledger.counters.items()))}"
    )
    n = ledger.export_jsonl("elastic_storage_events.jsonl")
    print(f"wrote {n} events to elastic_storage_events.jsonl")
    print(ledger.prometheus_text().rstrip())


if __name__ == "__main__":
    main()
