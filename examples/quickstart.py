"""Quickstart: ASURA in five minutes.

Demonstrates the paper's core API end to end:
  1. build a capacity-weighted cluster (STEP 1),
  2. place data (STEP 2) -- scalar, vectorized, and the Pallas kernel path,
  3. add/remove nodes and observe optimal data movement,
  4. replicate placements and use section-2.D metadata,
  5. route via the paper's comparison baselines through the same engine
     (``Router(algorithm=...)`` -- "asura", "ch", "wrh" or "rs").

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import Cluster, make_cluster
from repro.core.asura import addition_number, remove_numbers
from repro.kernels.ops import asura_place_nodes


def main() -> None:
    # --- STEP 1: nodes -> segments, proportional to capacity (Fig. 3) -----
    cluster = make_cluster([1.5, 0.7, 1.0])  # TB per node, say
    print("segment table:")
    for nid, info in cluster.nodes.items():
        segs = [(s, round(float(cluster.seg_lengths()[s]), 3)) for s in info.segments]
        print(f"  node {nid} (cap {info.capacity}): segments {segs}")

    # --- STEP 2: datum id -> node -----------------------------------------
    ids = np.arange(100_000, dtype=np.uint32)
    owners = cluster.place_nodes(ids)
    frac = np.bincount(owners, minlength=3) / ids.size
    print(f"distribution: {frac.round(4)} (capacity fractions {np.array([1.5,0.7,1.0])/3.2})")

    # Pallas kernel path (interpret mode on CPU, compiled on TPU)
    owners_k = np.asarray(
        asura_place_nodes(ids[:4096], cluster.seg_lengths(), cluster.seg_to_node())
    )
    assert np.array_equal(owners_k, owners[:4096])
    print("pallas kernel matches the oracle on 4096 ids")

    # --- optimal movement on node addition --------------------------------
    before = owners
    cluster.add_node(3, 1.0)
    after = cluster.place_nodes(ids)
    moved = before != after
    print(
        f"added node 3: {100*moved.mean():.2f}% of data moved "
        f"(ideal {100*1.0/4.2:.2f}%), all to node 3: {bool((after[moved]==3).all())}"
    )

    # --- replication + section 2.D metadata --------------------------------
    reps = cluster.place_replicas(ids[:5], 3)
    print(f"3-way replicas for first 5 ids:\n{reps}")
    lengths, node_of = cluster.seg_lengths(), cluster.seg_to_node()
    print(
        f"datum 0: ADDITION NUMBER {addition_number(0, lengths, node_of)}, "
        f"REMOVE NUMBERS {remove_numbers(0, lengths, node_of, 3)}"
    )

    # --- the shared state is just a small table ----------------------------
    blob = cluster.to_json()
    print(f"cluster table serializes to {len(blob)} bytes (memory: "
          f"{cluster.memory_bytes()} bytes for {len(cluster.nodes)} nodes)")
    clone = Cluster.from_json(blob)
    assert np.array_equal(clone.place_nodes(ids[:1000]), after[:1000])
    print("deserialized table places identically — no placement service needed")

    # --- the same interface serves the paper's baselines --------------------
    # Router(algorithm=...) swaps the placement algorithm behind the same
    # engine/artifact machinery: "ch" (consistent hashing), "wrh"
    # (capacity-weighted rendezvous) and "rs" (random slicing) all run on
    # the device-resident kernel paths (DESIGN.md section 9).
    from repro.serve import Router

    caps = {0: 1.5, 1: 0.7, 2: 1.0}
    for algorithm in ("asura", "ch", "wrh", "rs"):
        router = Router(caps, algorithm=algorithm)
        share = np.bincount(router.route(ids[:20_000]), minlength=3) / 20_000
        print(f"  {algorithm:>5} routing shares: {share.round(3)}")


if __name__ == "__main__":
    main()
