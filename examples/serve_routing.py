"""Serving scenario: ASURA request routing across elastic replicas.

Routes a stream of session ids to serving replicas with ASURA; kills a
replica and shows that only its sessions re-route (sticky sessions keep
their KV caches everywhere else); then runs real batched decode for this
replica's share via repro.launch.serve.

Run:  PYTHONPATH=src python examples/serve_routing.py
"""

import numpy as np

from repro.core import make_uniform_cluster
from repro.launch.serve import main as serve_main


def main() -> None:
    routing = make_uniform_cluster(6)
    sessions = np.arange(10_000, dtype=np.uint32)
    before = routing.place_nodes(sessions)
    print("sessions per replica:", np.bincount(before, minlength=6))

    routing.remove_node(3)  # replica 3 dies
    after = routing.place_nodes(sessions)
    moved = before != after
    print(
        f"replica 3 died: {moved.sum()} sessions re-routed "
        f"({(before == 3).sum()} lived there; equal: {moved.sum() == (before==3).sum()})"
    )
    assert (before[moved] == 3).all()

    routing.add_node(6, 1.0)  # warm standby joins
    after2 = routing.place_nodes(sessions)
    moved2 = after != after2
    print(f"standby joined: {moved2.sum()} sessions moved, all to the standby:"
          f" {bool((after2[moved2] == 6).all())}")

    print("\n-- decoding this replica's share with the real model --")
    serve_main(
        [
            "--arch", "smollm-135m", "--reduced",
            "--replicas", "6", "--replica-id", "0",
            "--requests", "32", "--batch", "8", "--decode-len", "4",
        ]
    )


if __name__ == "__main__":
    main()
