"""End-to-end driver: train a reduced smollm-135m for a few hundred steps.

Uses the real launcher (repro.launch.train): ASURA-placed data shards,
AdamW, async ASURA-replicated checkpoints.  On CPU this runs a ~1M-param
reduction; on a TPU fleet drop --reduced for the full config.

Run:  PYTHONPATH=src python examples/train_smollm.py [--steps 200]
"""

import argparse
import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    sys.exit(
        train_main(
            [
                "--arch", "smollm-135m",
                "--reduced",
                "--steps", str(args.steps),
                "--batch", "8",
                "--seq", "128",
                "--ckpt-every", "50",
            ]
        )
    )
