"""Tests for the loop-aware HLO cost analyzer (launch/hlo_cost.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze


def _flops(fn, *specs):
    return analyze(jax.jit(fn).lower(*specs).compile().as_text())


def test_plain_matmul():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    mc = _flops(lambda x, y: x @ y, a, b)
    assert mc.flops == 2 * 64 * 128 * 32


def test_scan_multiplies_by_trip_count():
    def body(x, w):
        return jnp.tanh(x @ w), None

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    for L in (3, 17):
        ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
        mc = _flops(lambda x, ws: jax.lax.scan(body, x, ws)[0], x, ws)
        want = 2 * 32 * 64 * 64 * L
        assert mc.flops == pytest.approx(want, rel=0.01), (L, mc.flops, want)
        assert not mc.trip_unknown


def test_nested_scans():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def outer(x, ws):
        def o(c, _):
            return jax.lax.scan(body, c, ws)[0], None

        return jax.lax.scan(o, x, None, length=5)[0]

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    mc = _flops(outer, x, ws)
    assert mc.flops == pytest.approx(2 * 32 * 64 * 64 * 20, rel=0.01)


def test_xla_cost_analysis_undercounts_scan():
    """The reason hlo_cost.py exists: XLA counts while bodies once."""

    def body(x, w):
        return jnp.tanh(x @ w), None

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((16, 64, 64), jnp.float32)
    compiled = jax.jit(lambda x, ws: jax.lax.scan(body, x, ws)[0]).lower(x, ws).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns one dict per device program
        ca = ca[0]
    xla = ca["flops"]
    ours = analyze(compiled.as_text()).flops
    assert ours >= 10 * xla  # 16 trips counted once by XLA


def test_grad_counts_backward():
    def loss(w, x):
        return jnp.sum(jnp.tanh(x @ w))

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    fwd = _flops(loss, w, x).flops
    bwd = _flops(jax.grad(loss), w, x).flops
    assert bwd >= 2 * fwd  # two matmuls in backward


def test_bytes_positive_and_scale():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    small = _flops(lambda x: x @ x, a)
    b = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    big = _flops(lambda x: x @ x, b)
    assert big.bytes > small.bytes > 0


def test_no_collectives_single_device():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    mc = _flops(lambda x: x @ x, a)
    assert mc.collective_bytes == 0
