"""The sharded bulk-placement layer (DESIGN.md section 11).

Two halves:

  * FORCED-8-DEVICE bit-identity: ``--xla_force_host_platform_device_count``
    must be set before the first jax init, and this test process has long
    since initialized jax on one device -- so the 8-way mesh runs in a
    SUBPROCESS (``repro.launch.placement_mesh --selftest``, the same entry
    CI smokes at 4 devices), which asserts sharded placement / histogram /
    diff / replica-diff / planner results equal the single-device engine
    path for ASURA and all three baselines, R in {1, 3}, odd-sized
    streams.

  * IN-PROCESS semantics on a 1-device mesh (partition + psum plumbing is
    device-count-independent; the subprocess covers >1): pad-lane
    weighting, histogram/matrix exactness, ``engine.sharded()``, the
    planner's ``mesh=`` threading, and the pow2 tail bucketing of the
    streaming planner (ragged chunks share a bucket compile and pad lanes
    can never produce phantom moves).
"""

from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import PlacementEngine, make_uniform_cluster
from repro.launch.placement_mesh import ShardedSweep, make_data_mesh
from repro.migrate import MigrationPlanner

N_NODES = 16
N_IDS = 4_099  # odd: does not divide any mesh


@pytest.fixture(scope="module")
def mesh():
    return make_data_mesh()


@pytest.fixture(scope="module")
def versions():
    """(engine, sweep, ids, v0, v1): a ref-backend engine with two cached
    table versions (one add-node event)."""
    cluster = make_uniform_cluster(N_NODES)
    engine = PlacementEngine(cluster, backend="ref")
    sweep = engine.sharded()
    ids = np.arange(N_IDS, dtype=np.uint32)
    engine.artifact()
    v0 = cluster.version
    cluster.add_node(N_NODES, 1.0)
    return engine, sweep, ids, v0, cluster.version


# ---------------------------------------------------------------------------
# Forced 8 host devices (subprocess: device count locks at first jax init)
# ---------------------------------------------------------------------------


def test_selftest_on_8_forced_host_devices():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(root, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    env.pop("XLA_FLAGS", None)  # the selftest sets the device count itself
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.placement_mesh",
            "--selftest", "--devices", "8", "--ids", "20011",
        ],
        capture_output=True, text=True, env=env, cwd=root, timeout=600,
    )
    assert proc.returncode == 0, f"selftest failed:\n{proc.stderr[-3000:]}"
    assert "OK on 8 devices" in proc.stdout


# ---------------------------------------------------------------------------
# In-process semantics (1-device mesh)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alg", ["asura", "ch", "wrh", "rs"])
def test_sharded_owners_and_histogram_match_engine(alg, mesh):
    cluster = make_uniform_cluster(N_NODES)
    engine = PlacementEngine(cluster, backend="ref", algorithm=alg)
    sweep = ShardedSweep(engine, mesh)
    ids = np.arange(N_IDS, dtype=np.uint32)
    ref = engine.place_nodes(ids)
    assert np.array_equal(sweep.place_nodes(ids), ref)
    hist = sweep.histogram(ids, N_NODES)
    assert hist.sum() == N_IDS  # pad lanes carry weight 0
    assert np.array_equal(hist, np.bincount(ref, minlength=N_NODES))


@pytest.mark.parametrize("n_replicas", [1, 3])
def test_sharded_replica_histogram(n_replicas, versions):
    engine, sweep, ids, _, _ = versions
    nodes = engine.place_replica_nodes(ids, n_replicas)
    hist = sweep.histogram(ids, N_NODES + 1, n_replicas=n_replicas)
    assert hist.sum() == n_replicas * N_IDS
    assert np.array_equal(hist, np.bincount(nodes.ravel(), minlength=N_NODES + 1))


def test_engine_sharded_accessor_caches_default(versions):
    engine, sweep, _, _, _ = versions
    assert engine.sharded() is sweep  # default-mesh sweep is cached
    other = engine.sharded(make_data_mesh())
    assert other is not sweep  # explicit meshes get fresh sweeps


def test_movement_matrix_matches_plan(versions):
    engine, sweep, ids, v0, v1 = versions
    plan = MigrationPlanner(engine).plan(ids, v0, v1)
    n_moved, mat = sweep.movement_matrix(ids, v0, v1, N_NODES + 1)
    assert n_moved == plan.n_moves
    ref = np.zeros((N_NODES + 1, N_NODES + 1), dtype=np.int64)
    np.add.at(ref, (plan.src, plan.dst), 1)
    assert np.array_equal(mat, ref)
    rplan = MigrationPlanner(engine).plan_replicas(ids, v0, v1, 3)
    rn, rmat = sweep.movement_matrix(ids, v0, v1, N_NODES + 1, n_replicas=3)
    assert rn == rplan.n_moves == rmat.sum()


def test_planner_mesh_kwarg_is_bit_identical(versions):
    engine, sweep, ids, v0, v1 = versions
    planner = MigrationPlanner(engine)
    plan = planner.plan(ids, v0, v1)
    for mesh_arg in (sweep, sweep.mesh):
        splan = planner.plan(ids, v0, v1, mesh=mesh_arg)
        for f in ("ids", "src", "dst", "index", "slot", "src_slot"):
            assert np.array_equal(getattr(plan, f), getattr(splan, f))
    rplan = planner.plan_replicas(ids, v0, v1, 3)
    srplan = planner.plan_replicas(ids, v0, v1, 3, mesh=sweep)
    for f in ("ids", "src", "dst", "index", "slot", "src_slot"):
        assert np.array_equal(getattr(rplan, f), getattr(srplan, f))


def test_rejects_non_data_mesh(versions):
    import jax

    engine = versions[0]
    bad = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("model",))
    with pytest.raises(ValueError, match="must be 1-D"):
        ShardedSweep(engine, bad)


# ---------------------------------------------------------------------------
# pow2 tail bucketing of the streaming planner (satellite: no phantom moves)
# ---------------------------------------------------------------------------


def test_pad_pow2_buckets_and_passthrough():
    full = np.arange(1024, dtype=np.uint32)
    padded, n = MigrationPlanner._pad_pow2(full)
    assert padded is full and n == 1024  # pow2 chunks: untouched fast path
    for ragged in (1000, 900, 513):
        padded, n = MigrationPlanner._pad_pow2(
            np.arange(ragged, dtype=np.uint32)
        )
        assert n == ragged
        assert padded.shape[0] == 1024  # same bucket -> same diff compile
        assert not np.any(padded[ragged:])
    padded, _ = MigrationPlanner._pad_pow2(np.arange(6, dtype=np.uint32), 4)
    assert padded.shape[0] == 8  # pow2 already divisible by the mesh


def test_ragged_stream_chunks_produce_no_phantom_moves(versions):
    """Streamed moved-count must equal the assembled plan's n_moves for
    chunkings whose tails are ragged: the pad lanes (zero-filled ids)
    MUST be masked out of ``moved``, not trusted to place identically
    under both table versions."""
    engine, sweep, ids, v0, v1 = versions
    planner = MigrationPlanner(engine)
    want = planner.plan(ids, v0, v1).n_moves
    for chunk, mesh_arg in ((1000, None), (1 << 10, None), (777, sweep)):
        total = 0
        for padded, moved, _, _ in planner.plan_stream(
            planner.chunked(ids, chunk), v0, v1, mesh=mesh_arg
        ):
            m = np.asarray(moved)
            assert m.shape[0] == padded.shape[0]
            total += int(m.sum())
        assert total == want, f"phantom/lost moves at chunk={chunk}"


def test_ragged_replica_stream_no_phantom_moves(versions):
    engine, sweep, ids, v0, v1 = versions
    planner = MigrationPlanner(engine)
    want = planner.plan_replicas(ids, v0, v1, 3).n_moves
    for chunk, mesh_arg in ((1000, None), (777, sweep)):
        total = 0
        for _, moved, _, _, _ in planner.plan_replicas_stream(
            planner.chunked(ids, chunk), v0, v1, 3, mesh=mesh_arg
        ):
            total += int(np.asarray(moved).sum())
        assert total == want, f"phantom/lost replica moves at chunk={chunk}"


def test_device_chunk_tail_pads_on_device(versions):
    """A ragged DEVICE-array chunk must pad on device (no silent host
    round-trip) and still mask its tail."""
    import jax.numpy as jnp

    engine, _, _, v0, v1 = versions
    planner = MigrationPlanner(engine)
    chunk = jnp.arange(900, dtype=jnp.uint32)
    [(padded, moved, _, _)] = list(planner.plan_stream([chunk], v0, v1))
    assert padded.shape[0] == 1024
    assert np.asarray(moved)[900:].sum() == 0
    want = planner.plan(np.arange(900, dtype=np.uint32), v0, v1).n_moves
    assert int(np.asarray(moved).sum()) == want
