"""ISSUE-3 coverage: the device-resident migration subsystem.

  * planner vs the brute-force two-placement NumPy oracle -- bit-identical
    (moved, src, dst) for add, remove and capacity-mix events at top_level
    in {0, 5, 19}, on both device backends,
  * a transfer-guard + np.asarray-tripwire proof that the streaming plan
    sweep performs ZERO host syncs,
  * the device ADDITION-NUMBER prefilter: exact where it reports a value,
    sound (a superset of the true movers) always, and plan-preserving,
  * the throttled mover: budgets never exceeded, full drain, per-round
    movement matrices, simulated-clock pacing,
  * dual-version routing under version flap: add a node, roll back
    mid-migration -- both artifacts served from the engine's LRU with no
    re-upload, and every id routes to a node that actually holds it at
    every round,
  * consumers: live elastic events match the atomic MovePlan, the failure
    detector drives throttled repair, and the checkpoint store restores
    bit-identically at every round of a live rebalance.
"""

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from repro.checkpoint import AsuraCheckpointStore, CheckpointManager
from repro.core import Cluster, PlacementEngine, make_cluster, make_uniform_cluster
from repro.core.asura import DEFAULT_PARAMS, addition_numbers_batch, place_batch
from repro.migrate import (
    MigrationPlan,
    MigrationPlanner,
    MigrationState,
    ThrottledMover,
)
from repro.migrate.mover import _group_ranks
from repro.runtime import ElasticCoordinator, HeartbeatTracker, MigrationDriver

MIXED = [0.3, 1.7, 2.0, 0.9, 1.0, 0.5]


class TableCluster:
    """Duck-typed cluster with direct segment-table control.

    The engine only needs ``version`` / ``params`` / ``seg_lengths()`` /
    ``seg_to_node()``, so oracle tests can pin exact tables (and exact
    top levels) without driving STEP-1 through thousands of node adds.
    """

    def __init__(self, lengths, node_of, params=DEFAULT_PARAMS):
        self.params = params
        self.version = 1
        self._lengths = np.asarray(lengths, dtype=np.float64)
        self._nodes = np.asarray(node_of, dtype=np.int64)

    def seg_lengths(self):
        return self._lengths.copy()

    def seg_to_node(self):
        return self._nodes.copy()

    def mutate(self, lengths, node_of):
        self._lengths = np.asarray(lengths, dtype=np.float64)
        self._nodes = np.asarray(node_of, dtype=np.int64)
        self.version += 1


def _uniform_table(n_segs, node_per_seg=1):
    lengths = np.full(n_segs, 0.9)
    nodes = np.arange(n_segs) // node_per_seg
    return lengths, nodes


# Tables whose entry level is exactly the top we want (see
# tests/test_device_path.py): top 19 needs upper in (2**19, 2**20].
TOP_CASES = {
    0: _uniform_table(2),
    5: _uniform_table(60),
    19: _uniform_table(600_000, node_per_seg=1024),
}


def _mutations(top_level):
    """(name, lengths, node_of) variants of the base table at this top."""
    lengths, nodes = TOP_CASES[top_level]
    # add: a fresh node takes appended segments (and the freed hole if any)
    add_l = np.concatenate([lengths, [0.9, 0.4]])
    add_n = np.concatenate([nodes, [nodes.max() + 1] * 2])
    # remove: zero out one node's segments (correspondences intact)
    rm_l, rm_n = lengths.copy(), nodes.copy()
    victim = nodes[len(nodes) // 2]
    rm_l[nodes == victim] = 0.0
    rm_n[nodes == victim] = -1
    # capacity mix: a heterogeneous re-table (some shrunk, one grown)
    mix_l, mix_n = lengths.copy(), nodes.copy()
    mix_l[:: max(1, len(lengths) // 7)] = 0.31
    mix_l = np.concatenate([mix_l, [0.77]])
    mix_n = np.concatenate([mix_n, [nodes.max() + 2]])
    return [("add", add_l, add_n), ("remove", rm_l, rm_n), ("mix", mix_l, mix_n)]


def _oracle_nodes(ids, lengths, node_of):
    return np.asarray(node_of)[place_batch(ids, lengths)]


# ---------------------------------------------------------------------------
# Planner == brute-force two-placement diff (the NumPy oracle)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("top_level", sorted(TOP_CASES))
def test_diff_matches_bruteforce_oracle(backend, top_level):
    lengths, nodes = TOP_CASES[top_level]
    n_ids = 256 if (backend == "pallas" and top_level == 19) else 1024
    ids = (np.arange(n_ids, dtype=np.uint64) * 2654435761 % (2**32)).astype(
        np.uint32
    )
    for name, new_l, new_n in _mutations(top_level):
        cluster = TableCluster(lengths, nodes)
        eng = PlacementEngine(cluster, backend=backend)
        eng.artifact()
        v_from = cluster.version
        cluster.mutate(new_l, new_n)
        moved, src, dst = eng.diff_nodes_device(ids, v_from, cluster.version)
        want_src = _oracle_nodes(ids, lengths, nodes)
        want_dst = _oracle_nodes(ids, new_l, new_n)
        assert_allclose(np.asarray(src), want_src, atol=0, err_msg=name)
        assert_allclose(np.asarray(dst), want_dst, atol=0, err_msg=name)
        assert_allclose(
            np.asarray(moved), want_src != want_dst, atol=0, err_msg=name
        )


@pytest.mark.parametrize("backend", ["numpy", "ref", "pallas"])
def test_plan_matches_bruteforce_on_real_cluster(backend):
    cluster = make_cluster(MIXED)
    eng = PlacementEngine(cluster, backend=backend)
    ids = np.arange(3000, dtype=np.uint32)
    before = _oracle_nodes(ids, cluster.seg_lengths(), cluster.seg_to_node())
    eng.artifact()
    v_from = cluster.version
    cluster.remove_node(2)
    cluster.add_node(40, 1.1)
    after = _oracle_nodes(ids, cluster.seg_lengths(), cluster.seg_to_node())
    plan = MigrationPlanner(eng).plan(ids, v_from, cluster.version)
    moved = np.nonzero(before != after)[0]
    assert np.array_equal(plan.index, moved)
    assert np.array_equal(plan.ids, ids[moved])
    assert np.array_equal(plan.src, before[moved])
    assert np.array_equal(plan.dst, after[moved])
    assert plan.n_scanned == len(ids)


def test_plan_chunking_is_invisible():
    cluster = make_cluster(MIXED)
    eng = PlacementEngine(cluster, backend="ref")
    ids = np.arange(5000, dtype=np.uint32)
    eng.artifact()
    v_from = cluster.version
    cluster.add_node(7, 0.8)
    planner = MigrationPlanner(eng)
    whole = planner.plan(ids, v_from, cluster.version)
    chunked = planner.plan(ids, v_from, cluster.version, chunk=701)
    assert np.array_equal(whole.ids, chunked.ids)
    assert np.array_equal(whole.src, chunked.src)
    assert np.array_equal(whole.dst, chunked.dst)
    assert np.array_equal(whole.index, chunked.index)


# ---------------------------------------------------------------------------
# Zero host syncs in the streaming sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_plan_stream_zero_host_transfers(backend, monkeypatch):
    """The chunked plan sweep must never touch the host: device-resident id
    chunks in, device (moved, src, dst) out, under a transfer guard with an
    np.asarray tripwire (the CPU-backend guard cannot see device->host
    reads)."""
    cluster = make_cluster(MIXED)
    eng = PlacementEngine(cluster, backend=backend)
    eng.artifact()
    v_from = cluster.version
    cluster.add_node(9, 1.2)
    v_to = cluster.version
    planner = MigrationPlanner(eng)
    chunks = [jnp.arange(s, s + 1024, dtype=jnp.uint32) for s in (0, 1024, 2048)]
    # warm-up: artifact device tables + jit compile
    for _, m, s, d in planner.plan_stream(chunks, v_from, v_to):
        m.block_until_ready()
    uploads = eng.uploads

    real_asarray = np.asarray
    host_reads: list = []

    def tripwire(*args, **kwargs):
        host_reads.append(args)
        return real_asarray(*args, **kwargs)

    monkeypatch.setattr(np, "asarray", tripwire)
    with jax.transfer_guard("disallow"):
        for _, moved, src, dst in planner.plan_stream(chunks, v_from, v_to):
            moved.block_until_ready()
            src.block_until_ready()
            dst.block_until_ready()
    monkeypatch.undo()
    assert isinstance(src, jax.Array) and isinstance(dst, jax.Array)
    assert not host_reads, f"plan sweep touched the host: {len(host_reads)} reads"
    assert eng.uploads == uploads == 2  # one per version, ever


# ---------------------------------------------------------------------------
# Device ADDITION-NUMBER prefilter
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_addition_numbers_device_exact_where_known(backend):
    cluster = make_cluster(MIXED)
    eng = PlacementEngine(cluster, backend=backend)
    ids = np.arange(1500, dtype=np.uint32)
    art = eng.artifact()
    want = addition_numbers_batch(ids, cluster.seg_lengths(), art.node_of)
    got = np.asarray(eng.addition_numbers_device(ids))
    known = got >= 0
    # the level-extended trace resolves the vast majority of lanes exactly
    assert known.mean() > 0.9
    assert np.array_equal(got[known], want[known])


def test_prefilter_is_sound_and_plan_preserving():
    cluster = make_uniform_cluster(8)
    eng = PlacementEngine(cluster, backend="ref")
    ids = np.arange(4000, dtype=np.uint32)
    before = eng.place_nodes(ids)
    v_from = cluster.version
    new_segs = cluster.add_node(50, 1.0)
    after = eng.place_nodes(ids)
    planner = MigrationPlanner(eng)
    full = planner.plan(ids, v_from, cluster.version)
    pre = planner.plan(ids, v_from, cluster.version, max_new_seg=max(new_segs))
    # bit-identical plan through the prefilter
    assert np.array_equal(full.ids, pre.ids)
    assert np.array_equal(full.src, pre.src)
    assert np.array_equal(full.dst, pre.dst)
    assert np.array_equal(full.index, pre.index)
    # and the candidate mask really covered every mover
    moved = before != after
    an = np.asarray(eng.addition_numbers_device(ids, version=v_from))
    cand = (an < 0) | (an <= max(new_segs))
    assert np.all(cand[moved])


# ---------------------------------------------------------------------------
# Throttled mover
# ---------------------------------------------------------------------------


def _toy_plan(n=200, n_nodes=5, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n).astype(np.int64)
    dst = (src + rng.integers(1, n_nodes, n)) % n_nodes
    return MigrationPlan(
        v_from=1,
        v_to=2,
        ids=np.arange(n, dtype=np.uint32),
        src=src,
        dst=dst.astype(np.int64),
        index=np.arange(n, dtype=np.int64),
        n_scanned=n,
    )


def test_group_ranks():
    ranks = _group_ranks(np.array([7, 3, 7, 7, 3]))
    assert ranks.tolist() == [0, 0, 1, 2, 1]
    assert _group_ranks(np.array([], dtype=np.int64)).size == 0


def test_mover_respects_budgets_and_drains():
    plan = _toy_plan(n=300)
    state = MigrationState(plan)
    mover = ThrottledMover(state, egress=7, ingress=11)
    total = 0
    while not mover.done:
        before = state.landed.copy()
        matrix = mover.round()
        rows = np.nonzero(state.landed & ~before)[0]
        egress_used: dict[int, int] = {}
        ingress_used: dict[int, int] = {}
        for r in rows:
            egress_used[int(plan.src[r])] = egress_used.get(int(plan.src[r]), 0) + 1
            ingress_used[int(plan.dst[r])] = ingress_used.get(int(plan.dst[r]), 0) + 1
        assert all(v <= 7 for v in egress_used.values())
        assert all(v <= 11 for v in ingress_used.values())
        assert sum(matrix.values()) == len(rows)
        total += len(rows)
        assert mover.rounds_done < 1000
    assert total == plan.n_moves
    assert sum(mover.movement_matrix().values()) == plan.n_moves


def test_mover_per_node_budget_dict():
    plan = _toy_plan(n=120, n_nodes=3)
    state = MigrationState(plan)
    mover = ThrottledMover(state, egress={0: 1, 1: 5}, ingress=None)
    matrix = mover.round()
    from_0 = sum(c for (s, _), c in matrix.items() if s == 0)
    from_1 = sum(c for (s, _), c in matrix.items() if s == 1)
    from_2 = sum(c for (s, _), c in matrix.items() if s == 2)
    assert from_0 <= 1 and from_1 <= 5
    assert from_2 == int((plan.src == 2).sum())  # unlisted nodes unlimited


def test_mover_clock_pacing():
    plan = _toy_plan(n=50)
    state = MigrationState(plan)
    t = {"now": 0.0}
    mover = ThrottledMover(
        state, egress=2, ingress=2, clock=lambda: t["now"], round_seconds=1.0
    )
    assert mover.pump() == []  # no time elapsed, no rounds due
    t["now"] = 3.5
    assert len(mover.pump()) == 3  # exactly the three whole periods
    t["now"] = 3.9
    assert mover.pump() == []


def test_unthrottled_mover_drains_in_one_round():
    state = MigrationState(_toy_plan(n=64))
    matrices = ThrottledMover(state).run()
    assert len(matrices) == 1 and state.done


def test_mover_pump_unaffected_by_manual_rounds():
    """An eager manual round must not consume a clock-earned period."""
    state = MigrationState(_toy_plan(n=60))
    t = {"now": 0.0}
    mover = ThrottledMover(
        state, egress=1, ingress=None, clock=lambda: t["now"], round_seconds=1.0
    )
    mover.round()  # eager kick-off at t=0
    t["now"] = 1.0
    assert len(mover.pump()) == 1  # the clock's period still runs


# ---------------------------------------------------------------------------
# Dual-version routing under version flap (add -> rollback mid-migration)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "ref"])
def test_migration_window_routing_and_rollback(backend):
    """Every read routes to a node that actually holds the datum, at every
    round, through an add-node migration rolled back at half-drain; both
    table artifacts come from the engine's LRU with no re-upload."""
    cluster = make_uniform_cluster(6)
    eng = PlacementEngine(cluster, backend=backend)
    cluster._engine = eng  # route the coordinator through this backend
    ids = np.arange(3000, dtype=np.uint32)
    coord = ElasticCoordinator(cluster, ids)
    owners_v = eng.place_nodes(ids)
    holdings = dict(zip(ids.tolist(), owners_v.tolist()))

    mig = coord.add_node_live(6, 1.0, egress=20, ingress=None)
    plan = mig.state.plan
    assert plan.n_moves > 60
    uploads = eng.uploads
    assert uploads == 2  # v and v+1, nothing else

    def land_and_check(m):
        before = m.state.landed.copy()
        m.round()
        for r in np.nonzero(m.state.landed & ~before)[0]:
            holdings[int(m.state.plan.ids[r])] = int(m.state.plan.dst[r])
        want = np.array([holdings[int(i)] for i in ids])
        got = m.route(ids)
        assert np.array_equal(got, want)
        got_dev = np.array(m.route_device(jnp.asarray(ids)))
        assert np.array_equal(got_dev, want)

    # drain half, checking the invariant each round
    while mig.state.n_pending > plan.n_moves // 2:
        land_and_check(mig)
    assert not mig.done

    # flap: roll back mid-migration (through the coordinator, which also
    # reverts its owner table AND the membership change itself)
    rev = coord.rollback_live(mig)
    assert 6 not in cluster.nodes  # the added node is gone again
    with pytest.raises(RuntimeError):
        mig.round()
    assert rev.state.plan.n_moves == int(mig.state.landed.sum())
    # budgets swapped roles with the flow direction
    assert rev.mover.ingress == 20 and rev.mover.egress is None
    while not rev.done:
        land_and_check(rev)

    # all data is back at its v owner, served from the same two artifacts
    assert np.array_equal(
        np.array([holdings[int(i)] for i in ids]), owners_v
    )
    assert np.array_equal(rev.route(ids), owners_v)
    assert np.array_equal(coord.owners(), owners_v)  # side state reverted
    assert eng.uploads == uploads  # the flap re-materialized NOTHING

    # the reverted table places bit-identically to v (one new artifact)
    assert np.array_equal(eng.place_nodes(ids), owners_v)
    assert np.array_equal(coord.owners(), owners_v)


def test_coordinator_rejects_overlapping_migrations():
    """Dual-version read rules of overlapping migrations do not compose:
    the coordinator allows one drain at a time (live or atomic)."""
    cluster = make_uniform_cluster(6)
    ids = np.arange(800, dtype=np.uint32)
    coord = ElasticCoordinator(cluster, ids)
    mig = coord.add_node_live(6, 1.0, egress=10)
    for fn in (
        lambda: coord.add_node(7, 1.0),
        lambda: coord.remove_node(0),
        lambda: coord.add_node_live(7, 1.0),
        lambda: coord.remove_node_live(0),
    ):
        with pytest.raises(RuntimeError):
            fn()
    mig.run()
    coord.add_node(7, 1.0)  # drained: events flow again


def test_driver_serializes_double_failure():
    """Two deaths in one window: repairs run one at a time, in death order,
    and both complete."""
    cluster = make_uniform_cluster(6)
    ids = np.arange(900, dtype=np.uint32)
    coord = ElasticCoordinator(cluster, ids)
    t = {"now": 0.0}
    tracker = HeartbeatTracker(timeout=1.0, clock=lambda: t["now"])
    for nid in range(6):
        tracker.beat(nid)
    driver = MigrationDriver(
        tracker,
        lambda node: coord.remove_node_live(
            node, ingress=30, clock=lambda: t["now"], round_seconds=1.0
        ),
    )
    t["now"] = 5.0
    for nid in range(4):  # nodes 0-3 stay alive; 4 and 5 died at t=0
        tracker.beat(nid)
    t["now"] = 5.5
    dead = driver.poll()
    assert set(dead) == {4, 5}
    # only ONE repair is in flight; the other victim is queued
    assert len(driver.active) == 1 and driver.queued == [5]
    for _ in range(400):
        t["now"] += 1.0
        driver.pump()
        assert len(driver.active) <= 1
        if not driver.active and not driver.queued:
            break
    # every repair the cluster could still absorb ran to completion
    assert all(m.done for m in driver.completed)
    assert np.array_equal(coord.owners(), cluster.place_nodes(ids))


def test_rollback_live_rejects_removals_and_reverses():
    cluster = make_uniform_cluster(5)
    ids = np.arange(600, dtype=np.uint32)
    coord = ElasticCoordinator(cluster, ids)
    rm = coord.remove_node_live(2, egress=50)
    with pytest.raises(ValueError):
        coord.rollback_live(rm)  # un-remove is a fresh add event
    rm.run()
    done_add = coord.add_node_live(9, 1.0)
    done_add.run()
    with pytest.raises(ValueError):
        coord.rollback_live(done_add)  # fully drained: that's a remove event
    add = coord.add_node_live(11, 1.0, egress=5)
    add.round()
    assert not add.done  # budget keeps it mid-flight
    with pytest.raises(RuntimeError):
        add.rollback()  # bare rollback would desync the coordinator
    rev = coord.rollback_live(add)
    with pytest.raises(ValueError):
        coord.rollback_live(rev)  # rolling back a rollback: also a fresh add
    rev.run()
    assert np.array_equal(coord.owners(), cluster.place_nodes(ids))


def test_live_plan_equals_atomic_moveplan():
    ids = np.arange(2500, dtype=np.uint32)
    atomic = ElasticCoordinator(make_uniform_cluster(5), ids).add_node(5, 1.0)
    live = ElasticCoordinator(make_uniform_cluster(5), ids).add_node_live(5, 1.0)
    assert live.state.plan.moves_dict() == atomic.moves
    live.run()
    assert live.done


def test_remove_node_live_and_owner_tracking():
    cluster = make_uniform_cluster(6)
    ids = np.arange(2000, dtype=np.uint32)
    coord = ElasticCoordinator(cluster, ids)
    mig = coord.remove_node_live(3, egress=None, ingress=25)
    assert set(np.unique(mig.state.plan.src)) == {3}
    mig.run()
    assert np.array_equal(coord.owners(), cluster.place_nodes(ids))


def test_failure_detector_drives_throttled_repair():
    cluster = make_uniform_cluster(5)
    ids = np.arange(1200, dtype=np.uint32)
    coord = ElasticCoordinator(cluster, ids)
    t = {"now": 0.0}
    tracker = HeartbeatTracker(timeout=2.0, clock=lambda: t["now"])
    for nid in range(5):
        tracker.beat(nid)
    driver = MigrationDriver(
        tracker,
        lambda node: coord.remove_node_live(
            node, ingress=40, clock=lambda: t["now"], round_seconds=1.0
        ),
    )
    t["now"] = 2.0
    for nid in (0, 1, 2, 4):
        tracker.beat(nid)
    t["now"] = 3.5  # node 3 last seen at 0 -> dead; others at 2.0 -> alive
    assert driver.poll() == [3]
    assert len(driver.active) == 1
    mig = driver.active[0]
    while driver.active:
        t["now"] += 1.0
        for matrix in driver.pump():
            for (src, _), _count in matrix.items():
                assert src == 3
    assert driver.completed == [mig] and mig.done
    assert np.array_equal(coord.owners(), cluster.place_nodes(ids))


# ---------------------------------------------------------------------------
# Checkpoint store: live rebalance with read-through
# ---------------------------------------------------------------------------


def test_store_live_add_node_restores_at_every_round():
    store = AsuraCheckpointStore({i: 1.0 for i in range(6)}, n_replicas=2)
    mgr = CheckpointManager(store)
    rng = np.random.default_rng(11)
    tree = {  # ~24 MiB -> ~25 chunks, enough for a multi-round drain
        "w": rng.standard_normal((2048, 2048)).astype(np.float32),
        "m": rng.standard_normal((2048, 1024)).astype(np.float32),
        "b": rng.standard_normal((33,)).astype(np.float32),
    }
    mgr.save(4, tree)
    sm = store.begin_add_node(20, capacity=2.0, egress=None, ingress=3)
    assert store._migration is sm and sm.live.state.plan.n_moves > 0
    rounds = 0
    while not sm.done:
        matrix = sm.round()
        assert sum(c for (_, d), c in matrix.items() if d == 20) <= 3
        out = mgr.restore(4, tree)  # read-through at EVERY round
        assert np.array_equal(out["w"], tree["w"])
        assert np.array_equal(out["m"], tree["m"])
        assert np.array_equal(out["b"], tree["b"])
        rounds += 1
        assert rounds < 1000
    assert rounds > 1  # the budget actually forced multiple rounds
    assert store._migration is None  # detached once drained
    # final copies match what the atomic path would have produced
    keys = np.fromiter(
        {k for n in store.nodes.values() for k in n.blobs}, dtype=np.uint32
    )
    want = store.replicas_for(keys)
    for key, row in zip(keys, want):
        for nid in row:
            assert int(key) in store.nodes[int(nid)].blobs
    out = mgr.restore(4, tree)
    assert np.array_equal(out["w"], tree["w"])


def test_store_overwrite_mid_migration_reads_fresh():
    """A chunk overwritten while its move is still pending must read back
    the NEW blob (writes go through the same window rule as reads), both
    before and after its copy lands."""
    store = AsuraCheckpointStore({i: 1.0 for i in range(6)}, n_replicas=2)
    mgr = CheckpointManager(store)
    rng = np.random.default_rng(3)
    tree = {"w": rng.standard_normal((2048, 2048)).astype(np.float32)}
    mgr.save(1, tree)
    sm = store.begin_add_node(20, capacity=2.0, ingress=2)
    plan = sm.live.state.plan
    assert plan.n_moves > 2
    sm.round()  # leave some rows pending
    assert not sm.done
    tree2 = {"w": rng.standard_normal((2048, 2048)).astype(np.float32)}
    mgr.save(1, tree2)  # overwrite EVERY chunk mid-migration
    out = mgr.restore(1, tree2)
    assert np.array_equal(out["w"], tree2["w"])  # fresh while pending
    sm.run()
    out = mgr.restore(1, tree2)
    assert np.array_equal(out["w"], tree2["w"])  # fresh after landing


def test_prefilter_respects_cluster_params():
    """The host-path AN prefilter must use the cluster's AsuraParams (the
    paper's S=16 family here), not DEFAULT_PARAMS."""
    from repro.core import make_cluster
    from repro.core.asura import AsuraParams

    params = AsuraParams(s_log2=4)
    cluster = make_cluster([1.0] * 8, params=params)
    eng = cluster.engine  # numpy backend -> host prefilter path
    ids = np.arange(4000, dtype=np.uint32)
    eng.artifact()
    v_from = cluster.version
    new_segs = cluster.add_node(50, 1.0)
    planner = MigrationPlanner(eng)
    full = planner.plan(ids, v_from, cluster.version)
    pre = planner.plan(ids, v_from, cluster.version, max_new_seg=max(new_segs))
    assert full.n_moves > 0
    assert np.array_equal(full.ids, pre.ids)
    assert np.array_equal(full.dst, pre.dst)


def test_store_land_never_gcs_past_a_dead_destination():
    """A destination node dying mid-migration must not cost the surviving
    v copies: landing skips the GC until the v+1 set fully holds the chunk,
    so every chunk stays readable through the degraded window."""
    store = AsuraCheckpointStore({i: 1.0 for i in range(6)}, n_replicas=2)
    mgr = CheckpointManager(store)
    rng = np.random.default_rng(5)
    tree = {"w": rng.standard_normal((2048, 2048)).astype(np.float32)}
    mgr.save(9, tree)
    sm = store.begin_add_node(20, capacity=2.0, ingress=2)
    sm.round()
    store.fail_node(20)  # the migration TARGET dies mid-drain
    while not sm.done:
        sm.round()
    out = mgr.restore(9, tree)  # old copies survived; reads fall back
    assert np.array_equal(out["w"], tree["w"])


def test_store_rejects_membership_events_mid_migration():
    store = AsuraCheckpointStore({i: 1.0 for i in range(4)}, n_replicas=2)
    mgr = CheckpointManager(store)
    rng = np.random.default_rng(1)
    mgr.save(1, {"x": rng.standard_normal((2048, 1024)).astype(np.float32)})
    sm = store.begin_add_node(9, 1.0, ingress=1)
    for fn in (
        lambda: store.begin_add_node(10, 1.0),
        lambda: store.add_node(10, 1.0),
        lambda: store.remove_node_and_repair(0),
    ):
        with pytest.raises(RuntimeError):
            fn()
    sm.run()
    assert store.add_node(10, 1.0) >= 0  # drained: events flow again


# ---------------------------------------------------------------------------
# Engine artifact pinning
# ---------------------------------------------------------------------------


def test_artifact_for_evicted_version_raises():
    cluster = make_uniform_cluster(3)
    eng = PlacementEngine(cluster, backend="numpy", cache_versions=2)
    eng.artifact()
    v0 = cluster.version
    for i in range(3):  # push v0 out of the 2-deep LRU
        cluster.add_node(10 + i, 1.0)
        eng.artifact()
    with pytest.raises(KeyError):
        eng.artifact_for(v0)


def test_place_at_matches_historic_placement():
    cluster = make_cluster(MIXED)
    eng = PlacementEngine(cluster, backend="numpy")
    ids = np.arange(1000, dtype=np.uint32)
    v0 = cluster.version
    want = eng.place_nodes(ids)
    cluster.add_node(30, 1.0)
    assert not np.array_equal(eng.place_nodes(ids), want)  # table moved on
    assert np.array_equal(eng.place_nodes_at(ids, v0), want)
