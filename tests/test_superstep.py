"""Scan-fused supersteps + device-resident mover rounds (DESIGN.md 15).

The PR's contract is BIT-IDENTITY under fusion: a superstep of K batches
must reproduce K sequential ``step()`` calls exactly -- chosen nodes,
counters, queue ring, queue histogram, metrics slab -- for every
algorithm, hierarchical mode, the instrumented slab, the migration
window, and on a forced-8-device mesh (subprocess).  Likewise the
mover's ``round_block(k)`` must reproduce k host ``round()`` calls
(matrices, landed bitmap, budgets) including a mid-drain rollback, and
the planner's ``fuse=`` blocks must yield the per-chunk stream
unchanged.  Plus the dispatch-amortization tripwires: one trace per
(config, k) and zero host syncs inside a warm superstep.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core import PlacementEngine, make_uniform_cluster
from repro.core.hierarchy import HierarchicalCluster
from repro.obs import MetricsRegistry
from repro.runtime import ElasticCoordinator
from repro.serve import RequestStreamDriver, Router

K = 3
BLOCKS = 2


def _driver(engine, **kw):
    kw.setdefault("batch", 512)
    kw.setdefault("n_keys", 1 << 12)
    kw.setdefault("n_replicas", 3)
    kw.setdefault("policy", "pow2")
    kw.setdefault("seed", 3)
    return RequestStreamDriver(engine, **kw)


def _drain_pair(d_step, d_super, k=K, blocks=BLOCKS):
    """Run blocks*k steps on one driver, blocks supersteps on the other;
    return (stepped chosen (blocks*k, batch), superstep chosen same)."""
    stepped = np.stack(
        [np.asarray(d_step.step()) for _ in range(blocks * k)]
    )
    supered = np.concatenate(
        [np.asarray(d_super.superstep(k)) for _ in range(blocks)]
    )
    return stepped, supered


def _assert_state_equal(a, b):
    assert np.array_equal(np.asarray(a.counts), np.asarray(b.counts))
    assert np.array_equal(np.asarray(a.queue), np.asarray(b.queue))
    assert np.array_equal(np.asarray(a.qhist), np.asarray(b.qhist))
    assert int(np.asarray(a._step)) == int(np.asarray(b._step))
    assert a.steps_done == b.steps_done


# ---------------------------------------------------------------------------
# Superstep == K steps, every algorithm + hierarchical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alg", ["asura", "ch", "wrh", "rs"])
def test_superstep_matches_k_steps(alg):
    cluster = make_uniform_cluster(10)
    mk = lambda: _driver(PlacementEngine(cluster, backend="ref", algorithm=alg))
    d_step, d_super = mk(), mk()
    stepped, supered = _drain_pair(d_step, d_super)
    assert np.array_equal(stepped, supered)
    _assert_state_equal(d_step, d_super)


def test_superstep_matches_k_steps_hierarchical():
    h = HierarchicalCluster()
    for dom in range(3):
        for n in range(4):
            h.add_node(dom, dom * 4 + n, 1.0)
    mk = lambda: _driver(PlacementEngine(h, backend="ref"))
    d_step, d_super = mk(), mk()
    stepped, supered = _drain_pair(d_step, d_super)
    assert np.array_equal(stepped, supered)
    _assert_state_equal(d_step, d_super)


@pytest.mark.parametrize("policy", ["random", "pow2"])
def test_superstep_counter_feedback_policies(policy):
    """pow2 reads counters fresh between sub-batches INSIDE the scan;
    random never reads them -- both must reproduce the step loop."""
    cluster = make_uniform_cluster(7)
    mk = lambda: _driver(PlacementEngine(cluster, backend="ref"), policy=policy)
    d_step, d_super = mk(), mk()
    stepped, supered = _drain_pair(d_step, d_super)
    assert np.array_equal(stepped, supered)
    _assert_state_equal(d_step, d_super)


def test_superstep_instrumented_slab_parity():
    """With the device metrics plane on, the superstep's once-per-block
    slab contributions (routed counter, kernel stats) plus the scanned
    per-sub-batch served counts must equal the step loop's slab."""
    cluster = make_uniform_cluster(10)
    reg_a, reg_b = MetricsRegistry(), MetricsRegistry()
    d_step = _driver(PlacementEngine(cluster, backend="ref"), metrics=reg_a)
    d_super = _driver(PlacementEngine(cluster, backend="ref"), metrics=reg_b)
    stepped, supered = _drain_pair(d_step, d_super)
    assert np.array_equal(stepped, supered)
    _assert_state_equal(d_step, d_super)
    snap_a, snap_b = reg_a.snapshot(), reg_b.snapshot()
    assert snap_a.keys() == snap_b.keys()
    for name in snap_a:
        assert np.array_equal(snap_a[name], snap_b[name]), name


# ---------------------------------------------------------------------------
# Dispatch tripwires: one trace per (config, k), zero host syncs
# ---------------------------------------------------------------------------


def test_superstep_zero_host_syncs_and_single_trace(monkeypatch):
    cluster = make_uniform_cluster(12)
    eng = PlacementEngine(cluster, backend="ref")
    d = _driver(eng)
    d.superstep(K).block_until_ready()  # warm: upload + scanned compile
    assert d.superstep_traces == 1
    real_asarray = np.asarray
    host_reads: list = []

    def tripwire(*args, **kwargs):
        host_reads.append(args)
        return real_asarray(*args, **kwargs)

    monkeypatch.setattr(np, "asarray", tripwire)
    with jax.transfer_guard("disallow"):
        for _ in range(3):
            chosen = d.superstep(K)
        chosen.block_until_ready()
    monkeypatch.undo()
    assert not host_reads, f"superstep touched the host: {len(host_reads)}"
    assert d.superstep_traces == 1, "repeated supersteps retraced"
    assert d.superstep(K + 1).shape == (K + 1, d.batch)
    assert d.superstep_traces == 2  # a different k is a different program


def test_superstep_rejects_bad_k():
    d = _driver(PlacementEngine(make_uniform_cluster(4), backend="ref"))
    with pytest.raises(ValueError, match="k >= 1"):
        d.superstep(0)


# ---------------------------------------------------------------------------
# Forced 8 host devices (subprocess: device count locks at first jax init)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = """
import numpy as np
from repro.core import PlacementEngine, make_uniform_cluster
from repro.launch.placement_mesh import ShardedSweep, make_data_mesh
from repro.serve import RequestStreamDriver

cluster = make_uniform_cluster(10)
def mk(mesh):
    return RequestStreamDriver(
        PlacementEngine(cluster, backend="ref"), batch=256, n_keys=1 << 12,
        n_replicas=3, policy="pow2", seed=3, mesh=mesh,
    )
single = mk(None)
sharded = mk(make_data_mesh(8))
for _ in range(2):
    a = np.stack([np.asarray(single.step()) for _ in range(3)])
    b = np.asarray(sharded.superstep(3))
    assert a.shape == b.shape == (3, 256), (a.shape, b.shape)
    assert np.array_equal(a, b), "sharded superstep != single-device steps"
assert np.array_equal(single.load_counts(), sharded.load_counts())
print("MESH-SUPERSTEP-OK")
"""


def test_superstep_on_8_forced_host_devices():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(root, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT],
        capture_output=True, text=True, env=env, cwd=root, timeout=600,
    )
    assert proc.returncode == 0, f"mesh superstep failed:\n{proc.stderr[-3000:]}"
    assert "MESH-SUPERSTEP-OK" in proc.stdout


# ---------------------------------------------------------------------------
# Migration window: superstep_migrating == K serve_migrating calls
# ---------------------------------------------------------------------------


def test_superstep_migrating_matches_serve_migrating():
    def window():
        router = Router({i: 1.0 for i in range(8)})
        sessions = np.arange(20_000, dtype=np.uint32)
        mig = router.begin_scale_migration(
            sessions, add=(8, 1.0), n_replicas=3,
            egress={n: 60 for n in range(9)},
        )
        d = router.stream_driver(
            batch=512, n_keys=1 << 12, n_replicas=3, policy="pow2",
            seed=5, n_bins=9,
        )
        return mig, d

    mig_a, d_step = window()
    mig_b, d_super = window()
    for _ in range(2):  # two mid-drain rounds, same pending view each side
        mig_a.round()
        mig_b.round()
        ids_a, chosen_a = zip(
            *[map(np.asarray, d_step.serve_migrating(mig_a)) for _ in range(K)]
        )
        ids_b, chosen_b = map(np.asarray, d_super.superstep_migrating(mig_b, K))
        assert np.array_equal(np.stack(ids_a), ids_b)
        assert np.array_equal(np.stack(chosen_a), chosen_b)
    _assert_state_equal(d_step, d_super)


# ---------------------------------------------------------------------------
# Mover round blocks: round_block(k) == k host rounds, incl. rollback
# ---------------------------------------------------------------------------


def _coord(n_nodes=8, n_ids=20_000):
    cluster = make_uniform_cluster(n_nodes)
    ids = np.arange(n_ids, dtype=np.uint32)
    return ElasticCoordinator(cluster, ids)


def test_mover_round_block_matches_host_rounds():
    ca, cb = _coord(), _coord()
    mig_a = ca.add_node_live(8, 1.0, egress=40)
    mig_b = cb.add_node_live(8, 1.0, egress=40)
    k = 4
    host_mats = [mig_a.round() for _ in range(k)]
    block_mats = mig_b.round_block(k)
    assert host_mats == block_mats
    assert mig_a.mover.rounds_done == mig_b.mover.rounds_done == k
    assert np.array_equal(mig_a.state.landed, mig_b.state.landed)
    # drain the rest via blocks; the final ragged block must not overshoot
    while not mig_b.done:
        mig_b.round_block(3)
    while not mig_a.done:
        mig_a.round()
    assert mig_a.state.n_pending == mig_b.state.n_pending == 0


def test_mover_round_block_mid_drain_rollback():
    """Blocks and host rounds must agree through a rollback: drain part
    of the plan by blocks, roll back via the coordinator, drain the
    reverse by blocks, and land back exactly at v_from (membership and
    owner table both)."""
    coord = _coord()
    members0 = set(coord.cluster.nodes)
    owners0 = coord._owners.copy()
    mig = coord.add_node_live(8, 1.0, egress=40)
    mig.round_block(2)
    assert mig.state.n_pending > 0, "test needs a mid-drain window"
    rev = coord.rollback_live(mig)
    rev.round_block(2)  # reverse drains by blocks too
    if not rev.done:
        rev.run()
    assert rev.state.n_pending == 0
    assert set(coord.cluster.nodes) == members0
    assert np.array_equal(coord._owners, owners0)


# ---------------------------------------------------------------------------
# Planner fuse blocks: fuse>1 yields the per-chunk stream unchanged
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fuse", [2, 4])
def test_planner_fuse_parity(fuse):
    from repro.migrate import MigrationPlanner

    cluster = make_uniform_cluster(12)
    engine = PlacementEngine(cluster, backend="ref")
    engine.artifact()  # cache v0 in the LRU before mutating
    v0 = cluster.version
    cluster.add_node(12, 1.0)
    v1 = cluster.version
    planner = MigrationPlanner(engine)
    ids = np.arange(40_000, dtype=np.uint32)

    def drain(fuse_k):
        out = []
        for got_ids, moved, src, dst in planner.plan_stream(
            planner.chunked(ids, 1 << 13), v0, v1, fuse=fuse_k
        ):
            out.append(tuple(np.asarray(x) for x in (got_ids, moved, src, dst)))
        return out

    a, b = drain(1), drain(fuse)
    assert len(a) == len(b)
    for ca, cb in zip(a, b):
        for xa, xb in zip(ca, cb):
            assert np.array_equal(xa, xb)
