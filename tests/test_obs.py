"""ISSUE-8 coverage: the two-plane telemetry stack (DESIGN.md section 13).

  * ``TraceLedger`` host plane: counters, structured events with an
    injected clock, span timing, the bounded ring, JSONL and
    Prometheus-style export,
  * ``MetricsRegistry`` device plane: append-only layout, disabled
    no-op helpers (build-time: same object back), slab growth across
    registrations, the one-transfer drain with cumulative u64 totals,
  * the instrumented serving stream: snapshot == the host-replayed
    bincount oracle, routed counter == steps * batch, bit-identical
    chosen streams with metrics on/off/disabled, and ZERO host syncs
    per instrumented step (transfer guard + np.asarray tripwire),
  * ``emit_stats`` kernel variants bit-identical to the plain paths,
  * the tripwire back-compat aliases (``engine.uploads``,
    ``step_traces``, ``probe_traces``, ``probe_trace_count``),
  * drain-driver round events (+ bytes), planner prefilter counters,
    checkpoint save/restore spans.
"""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import PlacementEngine, make_uniform_cluster
from repro.kernels.ref import DEPTH_BINS, next_asura, place_replicas_ref
from repro.obs import MetricsRegistry, TraceLedger, get_ledger, set_ledger
from repro.serve import RequestStreamDriver

# ---------------------------------------------------------------------------
# TraceLedger (host plane)
# ---------------------------------------------------------------------------


def test_ledger_counters_and_events():
    t = {"now": 10.0}
    led = TraceLedger(clock=lambda: t["now"])
    assert led.incr("a") == 1
    assert led.incr("a", 5) == 6
    assert led.counter("a") == 6
    assert led.counter("missing") == 0
    led.event("upload", "asura", version=3)
    t["now"] = 12.5
    led.event("upload", "ch", version=1)
    evs = led.events("upload")
    assert [e["ts"] for e in evs] == [10.0, 12.5]
    assert evs[0]["name"] == "asura" and evs[0]["version"] == 3
    assert led.events("nope") == []
    assert led.counters == {"a": 6}


def test_ledger_span_times_with_injected_clock():
    t = {"now": 100.0}
    led = TraceLedger(clock=lambda: t["now"])
    with led.span("work", tag="x"):
        t["now"] = 103.0
    [ev] = led.events("span")
    assert ev["name"] == "work" and ev["dur_s"] == 3.0 and ev["tag"] == "x"


def test_ledger_ring_is_bounded_and_clear():
    led = TraceLedger(clock=lambda: 0.0, capacity=4)
    for i in range(10):
        led.event("e", str(i))
    names = [e["name"] for e in led.events()]
    assert names == ["6", "7", "8", "9"]  # oldest evicted
    led.clear()
    assert led.events() == []


def test_ledger_jsonl_roundtrip(tmp_path):
    led = TraceLedger(clock=lambda: 1.0)
    led.event("upload", "asura", version=2, arr=np.array([1, 2]))
    led.incr("serve.step_traces", 7)
    path = tmp_path / "events.jsonl"
    assert led.export_jsonl(str(path)) == 1
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert lines[0]["kind"] == "upload" and lines[0]["arr"] == [1, 2]
    assert lines[-1] == {
        "kind": "counters",
        "counters": {"serve.step_traces": 7},
    }


def test_ledger_prometheus_text_merges_registry():
    led = TraceLedger(clock=lambda: 0.0)
    led.incr("engine.uploads", 2)
    reg = MetricsRegistry()
    reg.counter("serve.routed")
    reg.histogram("serve.served", 3)
    reg.inc_host("migrate.bytes_moved", 4096)
    txt = led.prometheus_text(reg)
    assert "# TYPE repro_engine_uploads counter" in txt
    assert "repro_engine_uploads 2" in txt
    assert "repro_serve_routed 0" in txt
    assert 'repro_serve_served_bucket{bin="2"} 0' in txt
    assert "repro_migrate_bytes_moved 4096" in txt


def test_global_ledger_swap():
    prev = set_ledger(TraceLedger())
    try:
        get_ledger().incr("x")
        assert get_ledger().counter("x") == 1
        mine = set_ledger(TraceLedger())
        assert mine.counter("x") == 1
        assert get_ledger().counter("x") == 0
    finally:
        set_ledger(prev)


# ---------------------------------------------------------------------------
# MetricsRegistry (device plane)
# ---------------------------------------------------------------------------


def test_registry_layout_append_only_and_idempotent():
    reg = MetricsRegistry()
    assert reg.counter("a") == "a"
    assert reg.histogram("h", 4) == "h"
    assert reg.counter("a") == "a"  # idempotent re-registration
    assert reg.size == 5 and reg.names == ("a", "h")
    with pytest.raises(ValueError):
        reg.histogram("h", 8)  # size mismatch must be loud
    with pytest.raises(ValueError):
        reg.histogram("z", 0)


def test_registry_accumulate_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("c")
    reg.histogram("h", 4)
    slab = reg.slab()
    slab = reg.add(slab, "c", 3)
    slab = reg.add_hist(slab, "h", jnp.array([1, 0, 2, 0], jnp.uint32))
    slab = reg.bucket_add(slab, "h", jnp.array([2, 3, 99]))  # 99 clips to 3
    reg.set_slab(slab)
    snap = reg.snapshot()
    assert snap["c"] == 3
    assert snap["h"].tolist() == [1, 0, 3, 2]
    # drain zeroed the device slab; totals accumulate across snapshots
    slab = reg.add(reg.slab(), "c", 2)
    reg.set_slab(slab)
    assert reg.snapshot()["c"] == 5
    assert reg.totals()["c"] == 5  # no-device-touch read


def test_registry_slab_grows_preserving_live_windows():
    reg = MetricsRegistry()
    reg.counter("a")
    slab = reg.add(reg.slab(), "a", 7)
    reg.set_slab(slab)
    reg.histogram("late", 3)  # registered after traffic
    slab = reg.slab()  # grown, zero-padded
    assert int(slab.shape[0]) == 4
    snap = reg.snapshot()
    assert snap["a"] == 7 and snap["late"].tolist() == [0, 0, 0]


def test_disabled_registry_is_a_build_time_noop():
    reg = MetricsRegistry(enabled=False)
    reg.counter("a")
    reg.histogram("h", 4)
    assert reg.size == 0 and reg.names == ()
    x = jnp.zeros((2,), jnp.uint32)
    assert reg.add(x, "a") is x
    assert reg.add_hist(x, "h", x) is x
    assert reg.bucket_add(x, "h", 0) is x
    assert reg.snapshot() == {}


def test_registry_host_plane():
    reg = MetricsRegistry()
    assert reg.inc_host("planner.prefilter_kept", 10) == 10
    assert reg.inc_host("planner.prefilter_kept", 5) == 15
    assert reg.snapshot()["planner.prefilter_kept"] == 15


# ---------------------------------------------------------------------------
# emit_stats kernel variants: bit-identical placements
# ---------------------------------------------------------------------------


def _asura_tables(n_nodes=12):
    eng = PlacementEngine(make_uniform_cluster(n_nodes), backend="ref")
    art = eng._device_artifact("asura")
    return eng, art


def test_next_asura_emit_depth_bit_identical():
    eng, art = _asura_tables()
    ids = jnp.arange(257, dtype=jnp.uint32)
    counters = jnp.zeros((art.top_level + 1, 257), jnp.uint32)
    k0, f0, c0 = next_asura(ids, counters, art.top_level, eng.params.s_log2)
    k1, f1, c1, depth = next_asura(
        ids, counters, art.top_level, eng.params.s_log2, emit_depth=True
    )
    assert np.array_equal(np.asarray(k0), np.asarray(k1))
    assert np.array_equal(np.asarray(f0), np.asarray(f1))
    assert np.array_equal(np.asarray(c0), np.asarray(c1))
    d = np.asarray(depth)
    assert d.min() >= 1 and d.max() <= art.top_level + 1


def test_place_replicas_emit_stats_bit_identical():
    eng, art = _asura_tables()
    ids = jnp.arange(1001, dtype=jnp.uint32)
    kw = dict(
        top_level=art.top_level,
        s_log2=eng.params.s_log2,
        max_draws=eng.params.max_draws,
        n_replicas=3,
    )
    plain = place_replicas_ref(ids, art.len32_dev, art.node_of_dev, **kw)
    segs, dh = place_replicas_ref(
        ids, art.len32_dev, art.node_of_dev, emit_stats=True, **kw
    )
    assert np.array_equal(np.asarray(plain), np.asarray(segs))
    dh = np.asarray(dh)
    assert dh.shape == (DEPTH_BINS,)
    # every lane needs >= R successful draws (rejections add more)
    assert int(dh.sum()) >= 1001 * 3
    # depth is 1-based and bounded by the ladder height
    assert dh[0] == 0
    assert dh[art.top_level + 2 :].sum() == 0
    # the counter-derived histogram must agree with a per-draw replay of
    # the same lockstep ladder (next_asura emit_depth is the oracle),
    # counting each lane's draws only while it is still seeking -- the
    # shard-invariant semantics the sharded snapshot merge relies on
    n_segs = art.len32_dev.shape[0]
    len32 = np.asarray(art.len32_dev)
    node_of = np.asarray(art.node_of_dev)
    counters = jnp.zeros((art.top_level + 1, 1001), jnp.uint32)
    found = np.zeros(1001, dtype=np.int64)
    lane_nodes = np.full((3, 1001), -1, dtype=np.int64)
    oracle = np.zeros(DEPTH_BINS, dtype=np.int64)
    while (found < 3).any():
        live = found < 3
        k, f, counters, depth = next_asura(
            ids, counters, art.top_level, eng.params.s_log2,
            emit_depth=True, active=jnp.asarray(live),
        )
        oracle += np.bincount(
            np.asarray(depth)[live], minlength=DEPTH_BINS
        )
        k, f = np.asarray(k).astype(np.int64), np.asarray(f)
        k_safe = np.minimum(k, n_segs - 1)
        hit = live & (k < n_segs) & (f < len32[k_safe])
        node_k = node_of[k_safe]
        dup = ((lane_nodes >= 0) & (lane_nodes == node_k[None, :])).any(axis=0)
        take = hit & ~dup
        for r in range(3):
            lane_nodes[r] = np.where(take & (found == r), node_k, lane_nodes[r])
        found = found + take
    assert np.array_equal(oracle, dh.astype(np.int64))


def test_baseline_replicas_emit_stats_bit_identical():
    from repro.kernels.baselines import baseline_replicas_lookup, ch_lookup

    eng = PlacementEngine(
        make_uniform_cluster(10), backend="ref", algorithm="ch"
    )
    art = eng._device_artifact("ch")
    ids = jnp.arange(513, dtype=jnp.uint32)
    plain = baseline_replicas_lookup(
        ch_lookup, ids, art.keys_dev, art.vals_dev, n_replicas=3
    )
    out, reprobes = baseline_replicas_lookup(
        ch_lookup, ids, art.keys_dev, art.vals_dev, n_replicas=3,
        emit_stats=True,
    )
    assert np.array_equal(np.asarray(plain), np.asarray(out))
    # R=3 needs at least 2 extra draws per lane beyond the primary
    assert int(np.asarray(reprobes)[0]) >= 513 * 2


# ---------------------------------------------------------------------------
# The instrumented serving stream
# ---------------------------------------------------------------------------


def _drivers(n_nodes=12, metrics=None, **kw):
    eng = PlacementEngine(make_uniform_cluster(n_nodes), backend="ref")
    kw.setdefault("batch", 1024)
    kw.setdefault("n_keys", 4096)
    kw.setdefault("n_replicas", 3)
    kw.setdefault("policy", "pow2")
    kw.setdefault("seed", 0)
    return RequestStreamDriver(eng, metrics=metrics, **kw)


def test_snapshot_matches_host_replayed_bincount():
    reg = MetricsRegistry()
    d = _drivers(metrics=reg)
    served = np.zeros(d.n_bins, dtype=np.int64)
    steps, batch = 4, 1024
    for _ in range(steps):
        served += np.bincount(np.asarray(d.step()), minlength=d.n_bins)
    snap = reg.snapshot()
    assert snap["serve.routed.asura.pow2"] == steps * batch
    assert np.array_equal(snap["serve.served"].astype(np.int64), served)
    assert snap["asura.nonconverged"] == 0
    depth = snap["asura.ladder_depth"].astype(np.int64)
    # R successful draws per routed request, at least
    assert depth.sum() >= steps * batch * d.n_replicas


def test_instrumented_stream_bit_identical_to_plain():
    plain = _drivers()
    inst = _drivers(metrics=MetricsRegistry())
    disabled = _drivers(metrics=MetricsRegistry(enabled=False))
    for _ in range(3):
        a = np.asarray(plain.step())
        b = np.asarray(inst.step())
        c = np.asarray(disabled.step())
        assert np.array_equal(a, b)
        assert np.array_equal(a, c)
    assert np.array_equal(plain.load_counts(), inst.load_counts())
    assert disabled.step_traces == plain.step_traces


def test_instrumented_step_zero_host_syncs(monkeypatch):
    reg = MetricsRegistry()
    d = _drivers(metrics=reg)
    d.step().block_until_ready()  # warm: upload + compile + slab build
    traces = d.step_traces
    real_asarray = np.asarray
    host_reads: list = []

    def tripwire(*args, **kwargs):
        host_reads.append(args)
        return real_asarray(*args, **kwargs)

    monkeypatch.setattr(np, "asarray", tripwire)
    with jax.transfer_guard("disallow"):
        for _ in range(3):
            chosen = d.step()
        chosen.block_until_ready()
    monkeypatch.undo()
    assert not host_reads, f"instrumented step touched the host: {host_reads}"
    assert d.step_traces == traces, "instrumented steps retraced"
    # the drain is the ONE deliberate transfer, outside the hot loop
    assert reg.snapshot()["serve.routed.asura.pow2"] == 4 * 1024


def test_snapshot_event_rides_the_ledger():
    d = _drivers(metrics=MetricsRegistry())
    d.step()
    snap = d.snapshot()
    [ev] = d.ledger.events("serve.snapshot")
    assert ev["steps"] == snap["steps"] == 1


# ---------------------------------------------------------------------------
# Tripwire aliases + engine events
# ---------------------------------------------------------------------------


def test_engine_upload_alias_and_events():
    eng = PlacementEngine(make_uniform_cluster(8), backend="ref")
    assert eng.uploads == 0
    eng.place_nodes(np.arange(64, dtype=np.uint32))
    assert eng.uploads == 1
    [up] = eng.ledger.events("engine.upload")
    assert up["name"] == "asura" and up["version"] == eng.cluster.version
    spans = [e for e in eng.ledger.events("span")
             if e["name"] == "engine.build_artifact"]
    assert len(spans) == 1 and spans[0]["dur_s"] >= 0.0
    eng.place_nodes(np.arange(64, dtype=np.uint32))
    assert eng.uploads == 1  # cache hit, no re-upload
    assert eng.ledger.counter("engine.lru_hits") >= 1


def test_engine_lru_eviction_events():
    cluster = make_uniform_cluster(6)
    eng = PlacementEngine(cluster, backend="ref", cache_versions=2)
    for nid in (6, 7, 8):
        eng.artifact()
        cluster.add_node(nid, 1.0)
    eng.artifact()
    assert eng.ledger.counter("engine.lru_evictions") == 2
    evicted = [e["version"] for e in eng.ledger.events("engine.lru_evict")]
    assert evicted == sorted(evicted)  # oldest-first


def test_router_probe_trace_alias():
    from repro.serve import ReplicaRouter

    router = ReplicaRouter({i: 1.0 for i in range(5)})
    assert router.probe_traces == 0
    ids = np.arange(100, dtype=np.uint32)
    router.route_replicas_device(ids, 2)
    assert router.probe_traces == 1
    router.route_replicas_device(ids, 2)
    assert router.probe_traces == 1  # cached jit, no retrace
    assert router.ledger.counter("serve.probe_traces") == 1


def test_live_probe_trace_count_alias():
    from repro.migrate.live import probe_trace_count

    prev = set_ledger(TraceLedger())
    try:
        assert probe_trace_count() == 0
        get_ledger().incr("migrate.live.replica_route_traces", 2)
        assert probe_trace_count("replica_route") == 2
    finally:
        set_ledger(prev)


# ---------------------------------------------------------------------------
# Drain-driver round events, planner counters, checkpoint spans
# ---------------------------------------------------------------------------


def _toy_plan(n=60, n_nodes=5, seed=0):
    from repro.migrate import MigrationPlan

    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n).astype(np.int64)
    dst = (src + rng.integers(1, n_nodes, n)) % n_nodes
    return MigrationPlan(
        v_from=1,
        v_to=2,
        ids=np.arange(n, dtype=np.uint32),
        src=src,
        dst=dst.astype(np.int64),
        index=np.arange(n, dtype=np.int64),
        n_scanned=n,
    )


def test_mover_round_events_and_bytes():
    from repro.migrate import MigrationState, ThrottledMover

    led = TraceLedger(clock=lambda: 0.0)
    reg = MetricsRegistry()
    plan = _toy_plan(n=60)
    mover = ThrottledMover(
        MigrationState(plan), egress=7, ingress=11,
        ledger=led, metrics=reg, bytes_per_row=1 << 20,
    )
    matrices = mover.run()
    evs = led.events("migrate.round")
    assert len(evs) == len(matrices)
    assert [e["round"] for e in evs] == list(range(1, len(evs) + 1))
    assert sum(e["moves"] for e in evs) == plan.n_moves
    assert led.counter("migrate.rows_moved") == plan.n_moves
    assert led.counter("migrate.bytes_moved") == plan.n_moves * (1 << 20)
    assert reg.snapshot()["migrate.bytes_moved"] == plan.n_moves * (1 << 20)
    for ev, matrix in zip(evs, matrices):
        assert ev["moves"] == sum(matrix.values())
        assert ev["pairs"] == len(matrix)


def test_mover_without_ledger_emits_nothing():
    from repro.migrate import MigrationState, ThrottledMover

    mover = ThrottledMover(MigrationState(_toy_plan(n=20)))
    assert mover.run()  # field-compatible round dicts, no telemetry


def test_planner_prefilter_counters_and_span():
    from repro.migrate import MigrationPlanner

    cluster = make_uniform_cluster(10)
    eng = PlacementEngine(cluster, backend="ref")
    eng.artifact()
    v0 = cluster.version
    new_segs = cluster.add_node(10, 1.0)
    led = TraceLedger(clock=lambda: 0.0)
    reg = MetricsRegistry()
    planner = MigrationPlanner(eng, ledger=led, metrics=reg)
    ids = np.arange(5000, dtype=np.uint32)
    plan = planner.plan(ids, v0, cluster.version, max_new_seg=max(new_segs))
    scanned = led.counter("planner.prefilter_scanned")
    kept = led.counter("planner.prefilter_kept")
    assert scanned == 5000
    assert plan.n_moves <= kept <= scanned
    snap = reg.snapshot()
    assert snap["planner.prefilter_scanned"] == scanned
    [ev] = [e for e in led.events("span") if e["name"] == "planner.plan"]
    assert ev["n_moves"] == plan.n_moves and ev["n_scanned"] == 5000


def test_checkpoint_save_restore_spans():
    from repro.checkpoint import AsuraCheckpointStore, CheckpointManager

    store = AsuraCheckpointStore({i: 1.0 for i in range(6)}, n_replicas=2)
    led = TraceLedger()
    mgr = CheckpointManager(store, ledger=led)
    tree = {"w": np.arange(1000, dtype=np.float32)}
    mgr.save(3, tree)
    out = mgr.restore(3, tree)
    assert np.array_equal(out["w"], tree["w"])
    names = [e["name"] for e in led.events("span")]
    assert "checkpoint.save" in names and "checkpoint.restore" in names
    save_ev = [e for e in led.events("span")
               if e["name"] == "checkpoint.save"][0]
    assert save_ev["n_bytes"] == 4000 and save_ev["n_chunks"] >= 1
    assert led.counter("checkpoint.bytes_read") == 4000
