"""Unit tests for the core ASURA algorithm (paper sections 2.A-2.D)."""

import numpy as np
import pytest

from repro.core import (
    Cluster,
    make_cluster,
    make_uniform_cluster,
)
from repro.core.asura import (
    DEFAULT_PARAMS,
    AsuraParams,
    _AsuraStream,
    _upper_bound,
    addition_number,
    lengths_to_u32,
    place_batch,
    place_replicas_batch,
    place_replicas_scalar,
    place_scalar,
    placement_trace,
    remove_numbers,
)


class TestStep1SegmentAssignment:
    def test_capacity_to_segments_fig3(self):
        """Paper Fig. 3: 1.5 TB -> full segment + half segment, etc."""
        c = make_cluster([1.5, 0.7, 1.0])
        # Node 0: two segments (1.0-eps, 0.5); node 1: one 0.7; node 2: one ~1.0
        assert len(c.nodes[0].segments) == 2
        assert len(c.nodes[1].segments) == 1
        assert len(c.nodes[2].segments) == 1
        lengths = c.seg_lengths()
        assert abs(sum(lengths[s] for s in c.nodes[0].segments) - 1.5) < 1e-6
        assert abs(lengths[c.nodes[1].segments[0]] - 0.7) < 1e-9

    def test_rule4_lengths_below_one(self):
        c = make_cluster([3.0, 2.5, 0.1])
        assert np.all(c.seg_lengths() < 1.0)

    def test_smallest_free_segment_number_rule(self):
        """Section 2.D: additions take the smallest free number."""
        c = make_cluster([1.0, 1.0, 1.0, 1.0])
        c.remove_node(1)
        freed = 1  # node 1 owned segment 1
        segs = c.add_node(9, 1.0)
        assert segs == [freed]

    def test_existing_correspondence_never_changes(self):
        c = make_cluster([1.0, 2.0, 0.5])
        before = {nid: list(info.segments) for nid, info in c.nodes.items()}
        c.add_node(3, 1.3)
        c.remove_node(0)
        c.add_node(4, 0.4)
        for nid, segs in before.items():
            if nid in c.nodes:
                assert c.nodes[nid].segments == segs

    def test_resize_grow_and_shrink(self):
        c = make_cluster([1.5, 1.0])
        c.resize_node(0, 2.5)
        lengths = c.seg_lengths()
        assert abs(sum(lengths[s] for s in c.nodes[0].segments) - 2.5) < 1e-6
        c.resize_node(0, 0.8)
        lengths = c.seg_lengths()
        assert abs(sum(lengths[s] for s in c.nodes[0].segments) - 0.8) < 1e-6
        assert np.all(lengths[lengths > 0] < 1.0)

    def test_remove_rejects_unknown(self):
        c = make_uniform_cluster(2)
        with pytest.raises(KeyError):
            c.remove_node(99)

    def test_memory_is_order_n(self):
        """Paper Table II: 8N bytes."""
        c = make_uniform_cluster(10_000)
        assert c.memory_bytes() == 8 * 10_000


class TestStep2Placement:
    def test_deterministic(self):
        c = make_uniform_cluster(7)
        assert place_scalar(123, c.seg_lengths()) == place_scalar(123, c.seg_lengths())

    def test_scalar_batch_bit_identical(self):
        c = make_cluster([1.0] * 20 + [0.3, 1.7])
        ids = np.arange(500, dtype=np.uint32)
        batch = place_batch(ids, c.seg_lengths())
        for i in ids[:200]:
            assert place_scalar(int(i), c.seg_lengths()) == batch[i]

    def test_holes_never_selected(self):
        c = make_uniform_cluster(10)
        c.remove_node(4)
        segs = place_batch(np.arange(20_000, dtype=np.uint32), c.seg_lengths())
        assert 4 not in set(segs.tolist())

    def test_uniformity_chi_square(self):
        """Uniform capacities -> counts consistent with multinomial."""
        n_nodes, n_data = 16, 64_000
        c = make_uniform_cluster(n_nodes)
        segs = place_batch(np.arange(n_data, dtype=np.uint32), c.seg_lengths())
        counts = np.bincount(segs, minlength=n_nodes)
        expected = n_data / n_nodes
        chi2 = float(((counts - expected) ** 2 / expected).sum())
        # chi2 with 15 dof: P(chi2 > 37.7) ~ 1e-3
        assert chi2 < 37.7, counts

    def test_capacity_proportionality(self):
        caps = [2.0, 1.0, 0.5, 4.5]
        c = make_cluster(caps)
        nodes = c.place_nodes(np.arange(80_000, dtype=np.uint32))
        frac = np.array([(nodes == k).mean() for k in range(4)])
        want = np.array(caps) / sum(caps)
        assert np.all(np.abs(frac - want) < 0.01), (frac, want)

    def test_upper_bound_tracks_last_occupied(self):
        lengths = np.array([0.9, 0.0, 0.5])
        assert _upper_bound(lengths) == 2.5

    def test_lengths_to_u32_validates(self):
        with pytest.raises(ValueError):
            lengths_to_u32([1.5])
        with pytest.raises(ValueError):
            lengths_to_u32([-0.1])


class TestOptimalMovement:
    """Section 2.A second/third characteristics + mathematical proofs."""

    def test_addition_moves_only_to_new_node(self):
        c = make_uniform_cluster(12)
        ids = np.arange(30_000, dtype=np.uint32)
        before = c.place_nodes(ids)
        c.add_node(12, 1.0)
        after = c.place_nodes(ids)
        moved = before != after
        assert np.all(after[moved] == 12)
        # moved fraction ~ 1/13
        assert abs(moved.mean() - 1 / 13) < 0.01

    def test_removal_moves_only_from_removed_node(self):
        c = make_uniform_cluster(12)
        ids = np.arange(30_000, dtype=np.uint32)
        before = c.place_nodes(ids)
        c.remove_node(5)
        after = c.place_nodes(ids)
        moved = before != after
        assert np.all(before[moved] == 5)
        assert moved.sum() == (before == 5).sum()

    def test_capacity_respected_after_churn(self):
        c = make_cluster([1.0, 2.0, 1.0])
        c.add_node(3, 0.5)
        c.remove_node(0)
        c.add_node(4, 1.5)
        ids = np.arange(60_000, dtype=np.uint32)
        nodes = c.place_nodes(ids)
        caps = {nid: info.capacity for nid, info in c.nodes.items()}
        total = sum(caps.values())
        for nid, cap in caps.items():
            assert abs((nodes == nid).mean() - cap / total) < 0.015


class TestReplication:
    def test_distinct_nodes(self):
        c = make_uniform_cluster(8)
        reps = c.place_replicas(np.arange(2000, dtype=np.uint32), 3)
        for row in reps:
            assert len(set(row.tolist())) == 3

    def test_scalar_batch_identical(self):
        c = make_cluster([1.0, 0.5, 2.0, 1.0, 1.0])
        for datum in range(100):
            s = place_replicas_scalar(datum, c.seg_lengths(), c.seg_to_node(), 3)
            b = place_replicas_batch(
                np.array([datum], dtype=np.uint32),
                c.seg_lengths(),
                c.seg_to_node(),
                3,
            )[0]
            assert list(s) == list(b)

    def test_multi_segment_node_counts_once(self):
        """A node owning several segments must still appear once."""
        c = make_cluster([3.5, 1.0, 1.0, 1.0])
        reps = c.place_replicas(np.arange(3000, dtype=np.uint32), 3)
        for row in reps:
            assert len(set(row.tolist())) == 3

    def test_too_few_nodes_raises(self):
        c = make_uniform_cluster(2)
        with pytest.raises(RuntimeError):
            place_replicas_scalar(1, c.seg_lengths(), c.seg_to_node(), 3)


class TestSection2DMetadata:
    def test_addition_number_detects_next_capture(self):
        """The ADDITION NUMBER names the smallest free segment whose future
        assignment could capture the datum (exactness tested in the
        hypothesis suite against brute force)."""
        c = make_uniform_cluster(6)
        an = addition_number(77, c.seg_lengths(), c.seg_to_node())
        assert an >= 0
        # AN is never an occupied segment's number with a hit: it comes from
        # an unused (non-selecting) number.
        _, numbers, used = placement_trace(77, c.seg_lengths(), c.seg_to_node())
        unused = [v for v, u in zip(numbers[:-1], used[:-1]) if not u]
        if unused:
            assert an == int(min(unused))

    def test_remove_numbers_are_replica_floors(self):
        c = make_uniform_cluster(9)
        segs = place_replicas_scalar(5, c.seg_lengths(), c.seg_to_node(), 3)
        rn = remove_numbers(5, c.seg_lengths(), c.seg_to_node(), 3)
        assert sorted(segs) == rn


class TestRangeExtension:
    """Section 2.B: extending the generator ladder never moves data."""

    def test_placement_invariant_under_extra_levels(self):
        c = make_uniform_cluster(30)
        lengths = c.seg_lengths()
        len32 = lengths_to_u32(lengths)
        n_segs = len(len32)
        top = DEFAULT_PARAMS.level_for(_upper_bound(lengths))

        def place_at(datum, extra):
            st = _AsuraStream(datum, top + extra, DEFAULT_PARAMS)
            while True:
                k, f = st.next()
                if k < n_segs and f < int(len32[k]):
                    return k

        for datum in range(300):
            assert place_at(datum, 0) == place_at(datum, 2) == place_at(datum, 5)

    def test_subsequence_preserved(self):
        """Numbers below the old range keep value and order (section 2.B)."""
        params = DEFAULT_PARAMS
        for datum in range(50):
            base = _AsuraStream(datum, 3, params)
            ext = _AsuraStream(datum, 6, params)
            base_seq = [base.next_value() for _ in range(20)]
            ext_seq = [ext.next_value() for _ in range(200)]
            limit = params.range_at(3)
            sub = [v for v in ext_seq if v < limit]
            m = min(len(sub), len(base_seq))
            assert sub[:m] == base_seq[:m]


class TestSerialization:
    def test_json_roundtrip_places_identically(self):
        c = make_cluster([1.0, 2.5, 0.3])
        c.add_node(7, 1.1)
        c.remove_node(1)
        c2 = Cluster.from_json(c.to_json())
        ids = np.arange(5000, dtype=np.uint32)
        assert np.array_equal(c.place_batch(ids), c2.place_batch(ids))
        assert c2.version == c.version


class TestParams:
    def test_level_for(self):
        p = AsuraParams(s_log2=1)
        assert p.level_for(1.0) == 0
        assert p.level_for(2.0) == 0
        assert p.level_for(2.1) == 1
        assert p.level_for(100.0) == 6
        p16 = AsuraParams(s_log2=4)  # the paper's S=16
        assert p16.level_for(16.0) == 0
        assert p16.level_for(17.0) == 1

    def test_s_log2_bounds(self):
        with pytest.raises(ValueError):
            AsuraParams(s_log2=0)

    def test_paper_s16_config_still_places(self):
        params = AsuraParams(s_log2=4, max_draws=512)
        c = make_uniform_cluster(5, params=params)
        segs = place_batch(np.arange(5000, dtype=np.uint32), c.seg_lengths(), params)
        assert set(np.unique(segs)) <= set(range(5))
        counts = np.bincount(segs, minlength=5)
        assert counts.min() > 800
