"""Fused two-level (domain, node) placement kernel tests (DESIGN.md
section 14):

  * bit identity against the ``HierarchicalCluster`` NumPy oracle for
    R in {1, 2, 3}, ref and pallas backends;
  * a transfer-guard + np.asarray-tripwire proof that the two-level diff
    path runs with ZERO host syncs and exactly one artifact upload per
    version;
  * the exact ``_sync_domain`` resync regression (sub-epsilon churn must
    not drift the top-level capacity off the true domain sum);
  * a churn property test (hypothesis): replica domains stay pairwise
    distinct, a node add moves data only INTO the grown domain (and its
    intra-domain moves land exactly on the new node), a node remove
    sources every move from the shrunk domain, and a domain remove moves
    exactly the rows the domain held.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core import PlacementEngine
from repro.core.hierarchy import HierarchicalCluster


def _mk(domains=4, nodes_per=3, cap=lambda d, i: 1.0):
    h = HierarchicalCluster()
    for d in range(domains):
        for i in range(nodes_per):
            h.add_node(d, 100 + d * nodes_per + i, cap(d, i))
    return h


# ---------------------------------------------------------------------------
# Bit identity vs the NumPy oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("R", [1, 2, 3])
def test_fused_two_level_matches_oracle(backend, R):
    h = _mk(domains=5, nodes_per=4, cap=lambda d, i: 1.0 + 0.25 * i + 0.5 * (d % 2))
    eng = PlacementEngine(h, backend=backend)
    ids = np.arange(20_011, dtype=np.uint32)
    got = eng.place_replica_pairs(ids, R)
    want = h.place_replicas(ids, R)
    assert np.array_equal(got, want), f"{backend} R={R}: kernel != oracle"
    # primary-owner view agrees with the pair view
    assert np.array_equal(eng.place_nodes(ids), want[:, 0, 1])


def test_two_level_identity_survives_churn():
    h = _mk(domains=5, nodes_per=3)
    eng = PlacementEngine(h, backend="ref")
    ids = np.arange(5_003, dtype=np.uint32)
    h.add_node(1, 900, 1.7)
    assert np.array_equal(eng.place_replica_pairs(ids, 3), h.place_replicas(ids, 3))
    h.remove_node(1, 900)
    assert np.array_equal(eng.place_replica_pairs(ids, 3), h.place_replicas(ids, 3))
    h.remove_domain(4)
    assert np.array_equal(eng.place_replica_pairs(ids, 3), h.place_replicas(ids, 3))


def test_flat_only_methods_reject_hierarchical():
    h = _mk()
    eng = PlacementEngine(h, backend="ref")
    with pytest.raises(ValueError, match="HierarchicalCluster"):
        eng.place(np.arange(8, dtype=np.uint32))
    with pytest.raises(ValueError, match="ASURA-only"):
        PlacementEngine(h, backend="ref", algorithm="ch")


# ---------------------------------------------------------------------------
# Zero host syncs on the two-level diff path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_hier_diff_zero_host_transfers(backend, monkeypatch):
    h = _mk(domains=5, nodes_per=4)
    eng = PlacementEngine(h, backend=backend)
    eng.hier_artifact()
    v0 = h.version
    h.add_node(2, 900, 1.0)
    v1 = h.version
    ids = jnp.arange(4096, dtype=jnp.uint32)
    # warm-up: device tables for both versions + jit compile
    for arr in eng.diff_replica_domains_device(ids, v0, v1, 3):
        arr.block_until_ready()
    uploads = eng.uploads

    real_asarray = np.asarray
    host_reads: list = []

    def tripwire(*args, **kwargs):
        host_reads.append(args)
        return real_asarray(*args, **kwargs)

    monkeypatch.setattr(np, "asarray", tripwire)
    with jax.transfer_guard("disallow"):
        out = eng.diff_replica_domains_device(ids, v0, v1, 3)
        for arr in out:
            arr.block_until_ready()
        pairs = eng.place_replica_pairs_device(ids, 3)
        pairs.block_until_ready()
    monkeypatch.undo()
    assert all(isinstance(arr, jax.Array) for arr in out)
    assert isinstance(pairs, jax.Array)
    assert not host_reads, f"two-level diff touched the host: {len(host_reads)}"
    assert eng.uploads == uploads == 2  # one hier artifact per version, ever


# ---------------------------------------------------------------------------
# Exact _sync_domain resync (the float-drift regression)
# ---------------------------------------------------------------------------


def test_sync_domain_exact_after_sub_epsilon_churn():
    """Hundreds of sub-epsilon add/remove cycles must leave the top-level
    domain capacity EXACTLY equal to the member sum -- the old
    tolerance-based resync skipped every step and drifted unbounded."""
    h = _mk(domains=4, nodes_per=2)
    nid = 10_000
    for _ in range(300):
        h.add_node(0, nid, 1e-13)
        h.remove_node(0, nid)
        nid += 1
        assert h._top.nodes[0].capacity == h.domains[0].total_capacity()
    # and with a surviving tiny node the sum still matches bit for bit
    h.add_node(0, nid, 1e-13)
    assert h._top.nodes[0].capacity == h.domains[0].total_capacity()
    # placement over the churned cluster still matches the fused kernel
    eng = PlacementEngine(h, backend="ref")
    ids = np.arange(2_003, dtype=np.uint32)
    assert np.array_equal(eng.place_replica_pairs(ids, 3), h.place_replicas(ids, 3))


# ---------------------------------------------------------------------------
# Two-level churn properties (hypothesis)
# ---------------------------------------------------------------------------


def test_two_level_churn_properties():
    """Property test over add-node / remove-node / remove-domain churn:
    replica domains stay pairwise distinct, the fused diff equals the
    brute-force set diff, and movement is failure-domain-local -- a node
    add pulls data only INTO the grown domain (its intra-domain moves
    land exactly on the new node), a node remove sources every move from
    the shrunk domain, and a domain remove moves per row exactly the
    copies the domain held."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(
        st.tuples(
            st.sampled_from(["add", "remove_node", "remove_domain"]),
            st.floats(0.5, 2.0),
        ),
        min_size=1,
        max_size=3,
    )

    @settings(max_examples=15, deadline=None)
    @given(ops=ops, seed=st.integers(0, 2**16))
    def run(ops, seed):
        rng = np.random.default_rng(seed)
        h = _mk(domains=5, nodes_per=3)
        eng = PlacementEngine(h, backend="ref")
        ids = rng.integers(0, 2**32, 300, dtype=np.uint32)
        R = 3
        next_node = 10_000
        for op, cap in ops:
            before = eng.place_replica_pairs(ids, R)
            v_from = h.version
            domains = sorted(h.domains)
            if op == "remove_domain" and len(domains) > R + 1:
                d = domains[int(cap * 7) % len(domains)]
                h.remove_domain(d)
                kind = "remove_domain"
            elif op == "remove_node" and any(
                len(h.domains[x].nodes) > 1 for x in domains
            ):
                d = next(
                    x
                    for x in domains[int(cap * 5) % len(domains):] + domains
                    if len(h.domains[x].nodes) > 1
                )
                victim = sorted(h.domains[d].nodes)[0]
                h.remove_node(d, victim)
                kind = "remove_node"
            else:
                d = domains[int(cap * 7) % len(domains)]
                h.add_node(d, next_node, float(cap))
                kind = "add"
            after = eng.place_replica_pairs(ids, R)
            # R pairwise-distinct DOMAINS under every membership state
            for row in after:
                assert len(set(row[:, 0].tolist())) == R
            moved, src, dst, src_slot, src_dom, dst_dom = (
                np.asarray(x)
                for x in eng.diff_replica_domains_device(
                    jnp.asarray(ids, dtype=jnp.uint32), v_from, h.version, R
                )
            )
            # the fused diff is the minimal node-set diff
            b_node, a_node = before[:, :, 1], after[:, :, 1]
            minimal = ~(a_node[:, :, None] == b_node[:, None, :]).any(axis=2)
            assert int(moved.sum()) == int(minimal.sum())
            # moved slots' (domain, node) labels match the placements
            assert np.array_equal(dst_dom[moved], after[:, :, 0][moved])
            assert np.array_equal(dst[moved], a_node[moved])
            if kind == "add":
                # all movement lands in the grown domain; intra-domain
                # moves land exactly on the new node
                assert np.all(dst_dom[moved] == d)
                intra = moved & (src_dom == d)
                assert np.all(dst[intra] == next_node)
                next_node += 1
            elif kind == "remove_node":
                # every move vacates the shrunk domain
                assert np.all(src_dom[moved] == d)
            else:  # remove_domain
                assert np.all(src_dom[moved] == d)
                # per row, exactly the copies the domain held moved
                held = (before[:, :, 0] == d).sum(axis=1)
                assert np.array_equal(moved.sum(axis=1), held)

    run()
