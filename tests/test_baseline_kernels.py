"""Baseline device backends (DESIGN.md section 9): oracle bit-identity for
the CH / WRH / RS kernels, the engine's (algorithm, version) LRU keying,
zero-host-sync device paths, and the router/coordinator threading."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    PlacementEngine,
    RandomSlicingTable,
    build_ring,
    ch_place_np,
    make_cluster,
    make_uniform_cluster,
    rs_place_np,
    wrh_place_np,
)
from repro.core.wrh import neg_log2_q16_np
from repro.kernels.baselines import (
    baseline_place_on_table_device,
    ch_table_prep,
    rs_table_prep,
    wrh_table_prep,
)

MIXED = [1.0, 2.5, 0.5, 1.0, 3.0, 0.25, 1.75]


def _scrambled(n: int) -> np.ndarray:
    return (np.arange(n, dtype=np.uint64) * 2654435761 % (2**32)).astype(np.uint32)


# ---------------------------------------------------------------------------
# Kernel bit-identity vs the NumPy oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("batch", [0, 1, 7, 129, 515])
def test_ch_kernel_bit_identical(use_pallas, batch):
    ring, owners = build_ring(range(17), 37)
    ids = _scrambled(batch)
    got = np.asarray(
        baseline_place_on_table_device(
            "ch", ids, *ch_table_prep(ring, owners), use_pallas=use_pallas
        )
    )
    assert got.shape == (batch,)
    assert np.array_equal(got, ch_place_np(ids, ring, owners) if batch else got)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_ch_kernel_exact_lane_multiple_ring_wraps(use_pallas):
    """A 128-entry ring gets no padding, so the explicit idx == n -> 0 wrap
    must fire for ids hashing past the last ring point."""
    ring, owners = build_ring(range(16), 8)  # 16 * 8 = 128 = LANE
    assert ring.shape[0] % 128 == 0
    ids = _scrambled(4096)
    got = np.asarray(
        baseline_place_on_table_device(
            "ch", ids, *ch_table_prep(ring, owners), use_pallas=use_pallas
        )
    )
    assert np.array_equal(got, ch_place_np(ids, ring, owners))


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("batch", [0, 1, 7, 129, 515])
def test_rs_kernel_bit_identical(use_pallas, batch):
    table = RandomSlicingTable({i: c for i, c in enumerate(MIXED)})
    table.rebalance({**table.weights, 99: 2.0})  # splits -> non-trivial table
    starts, owners = table.starts_owners()
    ids = _scrambled(batch)
    got = np.asarray(
        baseline_place_on_table_device(
            "rs", ids, *rs_table_prep(starts, owners), use_pallas=use_pallas
        )
    )
    assert got.shape == (batch,)
    assert np.array_equal(got, rs_place_np(ids, starts, owners) if batch else got)


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("batch", [0, 1, 7, 129, 515])
def test_wrh_kernel_bit_identical_weighted(use_pallas, batch):
    nodes = np.arange(len(MIXED), dtype=np.uint32)
    weights = np.asarray(MIXED, dtype=np.float32)
    ids = _scrambled(batch)
    got = np.asarray(
        baseline_place_on_table_device(
            "wrh", ids, *wrh_table_prep(nodes, weights), use_pallas=use_pallas
        )
    )
    assert got.shape == (batch,)
    assert np.array_equal(got, wrh_place_np(ids, nodes, weights) if batch else got)


def test_wrh_fixed_point_log_accuracy():
    """The Q16 square-and-shift -log2 tracks the float log to ~2**-16."""
    h = _scrambled(4096)
    L = neg_log2_q16_np(h).astype(np.float64) / 2**16
    u = (2 * (h.astype(np.uint64) >> 9) + 1).astype(np.float64) / 2**24
    assert np.all(L > 0)
    assert np.max(np.abs(L - (-np.log2(u)))) < 2**-15


def test_wrh_capacity_weighting():
    nodes = np.arange(4, dtype=np.uint32)
    w = np.asarray([2.0, 1.0, 1.0, 1.0], dtype=np.float32)
    placed = wrh_place_np(np.arange(100_000, dtype=np.uint32), nodes, w)
    frac0 = (placed == 0).mean()
    assert 0.37 < frac0 < 0.43  # 2 / (2+1+1+1)


# ---------------------------------------------------------------------------
# Random slicing table invariants
# ---------------------------------------------------------------------------


def test_rs_table_covers_circle_exactly():
    t = RandomSlicingTable({i: c for i, c in enumerate(MIXED)})
    starts, owners = t.starts_owners()
    assert starts[0] == 0
    assert np.all(np.diff(starts.astype(np.int64)) > 0)
    assert owners.min() >= 0
    lengths = [length for _, length, _ in t._intervals]
    assert sum(lengths) == 2**32


def test_rs_optimal_movement_add_remove():
    ids = _scrambled(50_000)
    t = RandomSlicingTable({i: 1.0 for i in range(20)})
    before = t.place(ids)
    t.rebalance({**t.weights, 20: 1.0})
    after = t.place(ids)
    moved = before != after
    assert np.all(after[moved] == 20)  # moves only TO the new node
    assert abs(moved.mean() - 1 / 21) < 0.005
    before = after
    t.rebalance({n: w for n, w in t.weights.items() if n != 5})
    after = t.place(ids)
    moved = before != after
    assert np.all(before[moved] == 5)  # moves only OFF the removed node
    assert abs(moved.mean() - 1 / 21) < 0.005


def test_rs_rebalance_is_deterministic():
    a = RandomSlicingTable({i: c for i, c in enumerate(MIXED)})
    b = RandomSlicingTable({i: c for i, c in enumerate(MIXED)})
    for table in (a, b):
        table.rebalance({**table.weights, 50: 1.25})
    sa, oa = a.starts_owners()
    sb, ob = b.starts_owners()
    assert np.array_equal(sa, sb) and np.array_equal(oa, ob)


# ---------------------------------------------------------------------------
# Engine dispatch: every backend bit-identical to the numpy oracle path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algorithm", ["ch", "wrh", "rs"])
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_engine_baseline_backend_matches_numpy(algorithm, backend):
    ids = _scrambled(1000)
    host = PlacementEngine(
        make_cluster(MIXED), backend="numpy", algorithm=algorithm
    ).place_nodes(ids)
    dev = PlacementEngine(
        make_cluster(MIXED), backend=backend, algorithm=algorithm
    ).place_nodes(ids)
    assert host.dtype == np.int64
    assert np.array_equal(host, dev)


def test_engine_baseline_pinned_version_accounting():
    """place_nodes_at pins the v table: bit-equal to what place_nodes gave
    while v was current, after the cluster moved on."""
    ids = _scrambled(2000)
    for algorithm in ("ch", "wrh", "rs"):
        cluster = make_cluster(MIXED)
        engine = PlacementEngine(cluster, backend="numpy", algorithm=algorithm)
        before = engine.place_nodes(ids)
        v0 = cluster.version
        cluster.add_node(50, 1.5)
        after = engine.place_nodes(ids)
        assert np.array_equal(engine.place_nodes_at(ids, v0), before)
        assert not np.array_equal(before, after)  # the event moved something


# ---------------------------------------------------------------------------
# (algorithm, version) LRU keying
# ---------------------------------------------------------------------------


def test_engine_lru_keyed_on_algorithm_and_version():
    cluster = make_cluster(MIXED)
    engine = PlacementEngine(cluster, backend="numpy")
    ids = _scrambled(64)
    engine.place_nodes(ids, algorithm="asura")
    engine.place_nodes(ids, algorithm="ch")
    assert engine.uploads == 2  # one artifact per (algorithm, version)
    art_ch = engine.artifact("ch")
    art_asura = engine.artifact("asura")
    assert engine.uploads == 2  # both served from cache
    assert art_ch is not art_asura
    assert art_ch.version == art_asura.version  # same version, no aliasing
    # repeated same-version placements re-materialize nothing
    engine.place_nodes(ids, algorithm="ch")
    engine.place_nodes(ids, algorithm="asura")
    assert engine.uploads == 2


def test_asura_uploads_do_not_evict_baseline_artifact():
    """Churning MORE asura versions than the cache holds must leave the CH
    artifact of the original version untouched (per-algorithm LRUs)."""
    cluster = make_cluster(MIXED)
    engine = PlacementEngine(cluster, backend="numpy", cache_versions=2)
    ids = _scrambled(64)
    v0 = cluster.version
    ch_before = engine.place_nodes(ids, algorithm="ch")
    art0 = engine.artifact("ch")
    for i in range(4):  # 4 new asura versions through a 2-deep LRU
        cluster.add_node(100 + i, 1.0)
        engine.place_nodes(ids, algorithm="asura")
    # the v0 CH artifact is still cached (same object), no rebuild
    uploads = engine.uploads
    assert engine.artifact_for(v0, "ch") is art0
    assert engine.uploads == uploads
    assert np.array_equal(engine.place_nodes_at(ids, v0, algorithm="ch"), ch_before)
    # but asura's own v0 artifact was evicted by the churn
    with pytest.raises(KeyError):
        engine.artifact_for(v0, "asura")


def test_asura_segment_methods_guarded_on_baseline_engine():
    engine = PlacementEngine(make_cluster(MIXED), backend="numpy", algorithm="ch")
    with pytest.raises(ValueError, match="ASURA-only"):
        engine.place([1, 2, 3])
    with pytest.raises(ValueError, match="ASURA-only"):
        engine.place_replicas([1, 2, 3], 2)
    with pytest.raises(ValueError, match="ASURA-only"):
        engine.place_device(jnp.arange(4, dtype=jnp.uint32))


# ---------------------------------------------------------------------------
# Zero host syncs on the baseline device paths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algorithm", ["ch", "wrh", "rs"])
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_baseline_device_path_zero_host_transfers(algorithm, backend, monkeypatch):
    """After warm-up, repeated ``place_nodes_device`` calls with device-
    resident ids must not touch the host: ``jax.transfer_guard('disallow')``
    rejects uploads, an ``np.asarray`` tripwire catches reads."""
    engine = PlacementEngine(make_cluster(MIXED), backend=backend, algorithm=algorithm)
    ids = jnp.arange(4096, dtype=jnp.uint32)
    engine.place_nodes_device(ids).block_until_ready()  # warm + compile
    assert engine.uploads == 1

    real_asarray = np.asarray
    host_reads: list = []

    def tripwire(*args, **kwargs):
        host_reads.append(args)
        return real_asarray(*args, **kwargs)

    monkeypatch.setattr(np, "asarray", tripwire)
    with jax.transfer_guard("disallow"):
        for _ in range(3):
            nodes = engine.place_nodes_device(ids)
            nodes.block_until_ready()
    monkeypatch.undo()
    assert isinstance(nodes, jax.Array)
    assert not host_reads, f"device path touched the host: {len(host_reads)} reads"
    assert engine.uploads == 1


# ---------------------------------------------------------------------------
# Router / coordinator threading
# ---------------------------------------------------------------------------


def test_router_algorithm_threading():
    from repro.serve import Router

    caps = {0: 1.0, 1: 2.0, 2: 1.0}
    ids = _scrambled(3000)
    router = Router(caps, algorithm="ch", virtual_nodes=64)
    ring, owners = build_ring(sorted(caps), 64)
    assert np.array_equal(router.route(ids), ch_place_np(ids, ring, owners))
    # replica fan-out works under a baseline algorithm (the salted
    # rejection re-probe, DESIGN.md section 12): distinct nodes, primary
    # first
    reps = router.route_replicas(ids[:8], 2)
    assert np.array_equal(reps[:, 0], router.route(ids[:8]))
    assert (reps[:, 0] != reps[:, 1]).all()
    # ASURA-only surfaces still raise cleanly under a baseline algorithm
    with pytest.raises(ValueError):
        router.begin_scale_migration(ids[:8], add=(9, 1.0))
    # generic scale planning still works (before/after owner diff)
    plan = router.plan_scale_event(ids, add=(3, 1.0))
    assert plan.n_reprefills > 0
    # ch/wrh blobs rebuild deterministic tables; rs is history-dependent
    assert router.table_blob()
    with pytest.raises(ValueError, match="history-dependent"):
        Router(caps, algorithm="rs").table_blob()


@pytest.mark.parametrize("algorithm", ["wrh", "rs"])
def test_coordinator_baseline_movement_accounting(algorithm):
    from repro.runtime.elastic import ElasticCoordinator

    ids = _scrambled(20_000)
    cluster = make_uniform_cluster(12)
    coord = ElasticCoordinator(cluster, ids, algorithm=algorithm)
    plan = coord.add_node(12, 1.0)
    assert plan.n_moves > 0
    assert all(dst == 12 for _, dst in plan.moves.values())
    assert abs(plan.n_moves / len(ids) - 1 / 13) < 0.01  # ~optimal fraction
    plan = coord.remove_node(3)
    assert all(src == 3 for src, _ in plan.moves.values())
    # owner table tracked the events: a no-change re-place matches it
    assert np.array_equal(coord.owners(), coord.engine.place_nodes(ids, algorithm=algorithm))
    with pytest.raises(ValueError, match="ASURA"):
        coord.add_node_live(99, 1.0)
