"""ISSUE-7 coverage: the batched stream served THROUGH a live migration.

Dual-version serving must keep working under the batched driver: a
generated request stream routed mid-drain via
``LiveMigration.route_replicas_device`` (the cached fused probe) must, at
EVERY batch of every round,

  * match the host ``route_replicas`` rule bit for bit,
  * return pairwise-distinct holder sets (every served set is R live
    copies),
  * serve each slot from the v or v+1 replica set of its id -- never a
    node on neither side of the window,
  * pick the chosen node from the served set,

with stable probe trace counts across batches within a round (the fused
probe caches per routing config, not per call) and zero host syncs after
the per-round pending-view refresh.
"""

import numpy as np
import pytest

import jax

import repro.migrate.live as live
from repro.serve import Router

N_NODES = 8
R = 3
SESSIONS = 20_000


def _window():
    router = Router({i: 1.0 for i in range(N_NODES)})
    sessions = np.arange(SESSIONS, dtype=np.uint32)
    mig = router.begin_scale_migration(
        sessions,
        add=(N_NODES, 1.0),
        n_replicas=R,
        egress={n: 60 for n in range(N_NODES + 1)},
    )
    assert mig.state.plan.n_moves > 120, "plan too small to span rounds"
    driver = router.stream_driver(
        batch=1024, n_keys=1 << 14, n_replicas=R, policy="pow2",
        seed=5, n_bins=N_NODES + 1,
    )
    return router, mig, driver


def test_batched_stream_through_mid_drain_window():
    router, mig, driver = _window()
    engine = router.engine
    v0, v1 = mig.v_from, mig.v_to
    rounds = 0
    while not mig.done and rounds < 6:
        mig.round()
        rounds += 1
        for _ in range(2):  # two batches per round
            ids_dev, chosen_dev = driver.serve_migrating(mig)
            ids = np.asarray(ids_dev)
            chosen = np.asarray(chosen_dev)
            served = np.asarray(mig.route_replicas_device(ids_dev))
            # device rule == host rule, bit for bit
            assert np.array_equal(served, mig.route_replicas(ids))
            # holder sets stay pairwise-distinct mid-drain
            for a in range(R):
                for b in range(a + 1, R):
                    assert (served[:, a] != served[:, b]).all()
            # every served slot is on one side of the version window
            v_set = engine.place_replica_nodes_at(ids, v0, R)
            v1_set = engine.place_replica_nodes_at(ids, v1, R)
            union_hit = (served[:, :, None] == v_set[:, None, :]).any(-1) | (
                served[:, :, None] == v1_set[:, None, :]
            ).any(-1)
            assert union_hit.all(), "served a node on neither side of the window"
            # the selected node comes from the served set
            assert (chosen[:, None] == served).any(axis=1).all()
    assert rounds > 1, "window drained in one round; nothing mid-drain tested"
    if not mig.done:
        mig.run()
    assert driver.load_counts().sum() == driver.steps_done * driver.batch


def test_window_probe_trace_stable_within_round(monkeypatch):
    _router, mig, driver = _window()
    mig.round()
    driver.serve_migrating(mig)  # warm: probe compile + pending-view upload
    traces = live.probe_trace_count()
    real_asarray = np.asarray
    host_reads: list = []

    def tripwire(*args, **kwargs):
        host_reads.append(args)
        return real_asarray(*args, **kwargs)

    monkeypatch.setattr(np, "asarray", tripwire)
    with jax.transfer_guard("disallow"):
        for _ in range(3):
            _ids, chosen = driver.serve_migrating(mig)
        chosen.block_until_ready()
    monkeypatch.undo()
    assert not host_reads, f"mid-round serving touched the host: {len(host_reads)}"
    assert live.probe_trace_count() == traces, "repeated batches retraced the probe"


def test_serve_migrating_requires_matching_replication():
    _router, mig, driver = _window()
    bad = _window()[0].stream_driver(
        batch=256, n_keys=1 << 12, n_replicas=2, n_bins=N_NODES + 1
    )
    with pytest.raises(ValueError, match="R=2"):
        bad.serve_migrating(mig)
    mig.run()
    # a drained window still serves (pending sets empty, all v+1)
    ids_dev, chosen = driver.serve_migrating(mig)
    served = np.asarray(mig.route_replicas_device(ids_dev))
    assert np.array_equal(
        served,
        driver.engine.place_replica_nodes_at(np.asarray(ids_dev), mig.v_to, R),
    )
    assert (np.asarray(chosen)[:, None] == served).any(axis=1).all()
