"""Mathematical correctness of the sequence mixers and MoE dispatch."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import reduced_config
from repro.models.config import MoEConfig
from repro.models.layers import mlp_apply, mlp_init, moe_apply, moe_init
from repro.models.recurrent import (
    _wkv_chunked,
    rglru_apply,
    rglru_init,
    rglru_state_init,
    rwkv6_state_init,
)


class TestRGLRU:
    def test_parallel_scan_matches_sequential(self):
        """associative_scan (train) == step-by-step recurrence (decode)."""
        cfg = reduced_config(get_config("recurrentgemma-9b"))
        rng = jax.random.PRNGKey(0)
        p = rglru_init(rng, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model), jnp.float32)
        full, _ = rglru_apply(cfg, p, x)
        # feed one token at a time through the stateful path
        state = rglru_state_init(cfg, 2)
        outs = []
        for t in range(12):
            o, state = rglru_apply(cfg, p, x[:, t : t + 1], state=state)
            outs.append(o)
        seq = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(full, np.float32), np.asarray(seq, np.float32), atol=2e-2
        )

    def test_state_carries_across_segments(self):
        cfg = reduced_config(get_config("recurrentgemma-9b"))
        p = rglru_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model), jnp.float32)
        full, _ = rglru_apply(cfg, p, x)
        state = rglru_state_init(cfg, 1)
        o1, state = rglru_apply(cfg, p, x[:, :8], state=state)
        o2, _ = rglru_apply(cfg, p, x[:, 8:], state=state)
        both = jnp.concatenate([o1, o2], axis=1)
        np.testing.assert_allclose(
            np.asarray(full, np.float32), np.asarray(both, np.float32), atol=2e-2
        )


class TestWKV:
    def _inputs(self, b=2, s=20, d=64):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        r = jax.random.normal(ks[0], (b, s, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, d), jnp.float32)
        logw = -0.1 * jax.nn.softplus(jax.random.normal(ks[3], (b, s, d)))
        u = 0.3 * jnp.ones((d,), jnp.float32)
        return r, k, v, logw, u

    def test_chunked_matches_naive_recurrence(self):
        """The chunked linear-attention form == the token-by-token WKV."""
        r, k, v, logw, u = self._inputs()
        hd = 32
        out, _ = _wkv_chunked(r, k, v, logw, u, hd)
        b, s, d = r.shape
        h = d // hd
        rr = r.reshape(b, s, h, hd)
        kk = k.reshape(b, s, h, hd)
        vv = v.reshape(b, s, h, hd)
        ww = jnp.exp(logw.reshape(b, s, h, hd))
        uu = u.reshape(h, hd)
        S = jnp.zeros((b, h, hd, hd))
        naive = []
        for t in range(s):
            bonus = jnp.einsum("bhk,bhk->bh", rr[:, t], uu[None] * kk[:, t])
            o = jnp.einsum("bhk,bhkv->bhv", rr[:, t], S) + bonus[..., None] * vv[:, t]
            naive.append(o)
            S = ww[:, t][..., None] * S + jnp.einsum(
                "bhk,bhv->bhkv", kk[:, t], vv[:, t]
            )
        naive = jnp.stack(naive, axis=1).reshape(b, s, d)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(naive), rtol=1e-3, atol=1e-3
        )

    def test_state_carries_across_chunk_boundaries(self):
        r, k, v, logw, u = self._inputs(s=40)
        hd = 32
        full, s_full = _wkv_chunked(r, k, v, logw, u, hd)
        o1, s1 = _wkv_chunked(r[:, :15], k[:, :15], v[:, :15], logw[:, :15], u, hd)
        o2, s2 = _wkv_chunked(
            r[:, 15:], k[:, 15:], v[:, 15:], logw[:, 15:], u, hd, state=s1
        )
        both = jnp.concatenate([o1, o2], axis=1)
        np.testing.assert_allclose(np.asarray(full), np.asarray(both), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), rtol=1e-3, atol=1e-3)


class TestMoE:
    def test_identical_experts_reduce_to_dense_mlp(self):
        """With every expert equal and no drops, MoE(x) == MLP(x)."""
        cfg = reduced_config(get_config("mixtral-8x22b"))
        moe_cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=128, capacity_factor=8.0)
        cfg = dataclasses.replace(cfg, moe=moe_cfg)
        p = moe_init(jax.random.PRNGKey(0), cfg, moe_cfg)
        # overwrite experts with copies of expert 0
        for name in ("w_gate", "w_up", "w_down"):
            p[name] = jnp.broadcast_to(p[name][:1], p[name].shape)
        dense = {
            "w_gate": p["w_gate"][0],
            "w_up": p["w_up"][0],
            "w_down": p["w_down"][0],
        }
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 256, cfg.d_model), jnp.float32)
        y_moe, aux = moe_apply(cfg, p, x, moe_cfg)
        y_dense = mlp_apply(cfg, dense, x)
        np.testing.assert_allclose(
            np.asarray(y_moe), np.asarray(y_dense), rtol=2e-2, atol=2e-2
        )
        assert np.isfinite(float(aux))

    def test_capacity_drops_tokens_gracefully(self):
        cfg = reduced_config(get_config("mixtral-8x22b"))
        moe_cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=128, capacity_factor=0.1)
        cfg = dataclasses.replace(cfg, moe=moe_cfg)
        p = moe_init(jax.random.PRNGKey(0), cfg, moe_cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 256, cfg.d_model), jnp.float32)
        y, _ = moe_apply(cfg, p, x, moe_cfg)
        assert np.all(np.isfinite(np.asarray(y)))
        # with tiny capacity many tokens get zero output, norm well below full
        full_cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=128, capacity_factor=8.0)
        y_full, _ = moe_apply(cfg, p, x, full_cfg)
        assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y_full))

    def test_aux_loss_favours_balance(self):
        """Uniform routing gives the minimal Switch aux loss (~1.0)."""
        cfg = reduced_config(get_config("deepseek-v2-236b"))
        moe_cfg = MoEConfig(
            n_experts=8, top_k=2, d_ff_expert=64, n_shared=0, capacity_factor=2.0
        )
        cfg = dataclasses.replace(cfg, moe=moe_cfg)
        p = moe_init(jax.random.PRNGKey(2), cfg, moe_cfg)
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 512, cfg.d_model), jnp.float32)
        _, aux = moe_apply(cfg, p, x, moe_cfg)
        # with density averaged over the K routing slots, the balanced floor
        # of sum_e density_e * prob_e * E^2/K is E/K (= 4 here); a
        # near-uniform random-init router should sit at it
        floor = moe_cfg.n_experts / moe_cfg.top_k
        assert 0.9 * floor < float(aux) < 2.0 * floor
