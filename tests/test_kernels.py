"""Per-kernel validation: Pallas asura_place vs the pure-jnp/NumPy oracles.

Sweeps batch shapes, cluster sizes/capacity mixes and params, asserting
bit-exact agreement (integer algorithm -- no allclose tolerance needed, but
we use assert_allclose with atol=0 to follow the harness convention).
"""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import make_cluster, make_uniform_cluster
from repro.core.asura import DEFAULT_PARAMS, AsuraParams, place_batch
from repro.kernels.ops import asura_place, asura_place_nodes, table_prep
from repro.kernels.ref import place_ref


CLUSTERS = {
    "uniform_small": [1.0] * 4,
    "uniform_128": [1.0] * 128,
    "mixed": [0.3, 1.7, 2.0, 0.9, 1.0, 0.5],
    "one_node_frac": [0.6],
    "heavy_tail": [4.0] + [0.25] * 20,
}
BATCHES = [1, 7, 128, 1000, 4096]


@pytest.mark.parametrize("name", sorted(CLUSTERS))
@pytest.mark.parametrize("batch", BATCHES)
def test_pallas_matches_numpy(name, batch):
    c = make_cluster(CLUSTERS[name])
    ids = (np.arange(batch, dtype=np.uint64) * 2654435761 % (2**32)).astype(np.uint32)
    want = place_batch(ids, c.seg_lengths())
    got = np.asarray(asura_place(ids, c.seg_lengths(), use_pallas=True))
    assert_allclose(got, want, atol=0)


@pytest.mark.parametrize("name", sorted(CLUSTERS))
def test_ref_matches_numpy(name):
    c = make_cluster(CLUSTERS[name])
    ids = np.arange(2048, dtype=np.uint32)
    want = place_batch(ids, c.seg_lengths())
    got = np.asarray(asura_place(ids, c.seg_lengths(), use_pallas=False))
    assert_allclose(got, want, atol=0)


@pytest.mark.parametrize("rows", [8, 16, 32])
def test_block_shape_sweep(rows):
    c = make_uniform_cluster(32)
    ids = np.arange(rows * 128 * 3 + 5, dtype=np.uint32)  # force padding
    want = place_batch(ids, c.seg_lengths())
    got = np.asarray(
        asura_place(ids, c.seg_lengths(), use_pallas=True, rows_per_block=rows)
    )
    assert_allclose(got, want, atol=0)


def test_id_dtype_acceptance():
    c = make_uniform_cluster(8)
    for dtype in (np.uint32, np.int32, np.int64, np.uint64):
        ids = np.arange(256).astype(dtype)
        got = np.asarray(asura_place(ids, c.seg_lengths()))
        want = place_batch(ids.astype(np.uint32), c.seg_lengths())
        assert_allclose(got, want, atol=0)


def test_paper_s16_params():
    params = AsuraParams(s_log2=4, max_draws=512)
    c = make_uniform_cluster(20, params=params)
    ids = np.arange(4096, dtype=np.uint32)
    want = place_batch(ids, c.seg_lengths(), params)
    got = np.asarray(asura_place(ids, c.seg_lengths(), params))
    assert_allclose(got, want, atol=0)


def test_place_nodes_mapping():
    c = make_cluster([2.0, 1.0, 1.0])
    ids = np.arange(512, dtype=np.uint32)
    segs = np.asarray(asura_place(ids, c.seg_lengths()))
    nodes = np.asarray(asura_place_nodes(ids, c.seg_lengths(), c.seg_to_node()))
    assert_allclose(nodes, c.seg_to_node()[segs], atol=0)


def test_large_cluster_table_pad():
    """Table padding to the 128-lane multiple must not change placement."""
    c = make_uniform_cluster(130)  # 130 segments -> padded to 256
    ids = np.arange(2048, dtype=np.uint32)
    want = place_batch(ids, c.seg_lengths())
    got = np.asarray(asura_place(ids, c.seg_lengths()))
    assert_allclose(got, want, atol=0)
    assert got.max() < 130


def test_after_churn_consistency():
    c = make_uniform_cluster(16)
    c.remove_node(3)
    c.add_node(99, 0.4)
    c.resize_node(5, 2.2)
    ids = np.arange(3000, dtype=np.uint32)
    want = place_batch(ids, c.seg_lengths())
    got = np.asarray(asura_place(ids, c.seg_lengths()))
    assert_allclose(got, want, atol=0)


def test_table_prep_levels():
    c = make_uniform_cluster(100)
    len32, top = table_prep(c.seg_lengths())
    assert len32.shape[0] % 128 == 0
    assert DEFAULT_PARAMS.range_at(top) >= 100
