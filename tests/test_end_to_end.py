"""End-to-end integration: train a tiny model, checkpoint, kill a storage
node, restore, and keep training -- the full fault-tolerance story."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # multi-minute: real training loops

from repro.checkpoint import AsuraCheckpointStore, CheckpointManager
from repro.configs import get_config
from repro.core import make_uniform_cluster
from repro.data import DataPipeline, ShardedDataset
from repro.models import init_params, reduced_config
from repro.train import AdamWConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced_config(get_config("smollm-135m"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _batches(cfg, n, batch=4, seq=64):
    cluster = make_uniform_cluster(2)
    ds = ShardedDataset(n_shards=16, tokens_per_shard=batch * seq * 8, vocab=cfg.vocab)
    pipe = DataPipeline(ds, cluster, 0, batch_per_host=batch, seq_len=seq)
    it = pipe.batches()
    out = []
    for _ in range(n):
        try:
            out.append(next(it))
        except StopIteration:
            it = pipe.batches(epoch=len(out))
            out.append(next(it))
    return out


def test_loss_decreases(tiny):
    cfg, params = tiny
    opt = init_train_state(cfg, params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=5)))
    losses = []
    for tokens in _batches(cfg, 30):
        params, opt, m = step(params, opt, {"tokens": jnp.asarray(tokens)})
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, losses


def test_microbatched_matches_single(tiny):
    """Grad accumulation must match the monolithic step (same math)."""
    cfg, params = tiny
    opt1 = init_train_state(cfg, params)
    opt2 = init_train_state(cfg, params)
    tokens = jnp.asarray(_batches(cfg, 1, batch=8)[0])
    s1 = jax.jit(make_train_step(cfg, AdamWConfig(), n_microbatches=1))
    s2 = jax.jit(make_train_step(cfg, AdamWConfig(), n_microbatches=4))
    p1, _, m1 = s1(params, opt1, {"tokens": tokens})
    p2, _, m2 = s2(params, opt2, {"tokens": tokens})
    # CE means differ slightly (per-microbatch mean of means) but the
    # parameter updates must be close
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-3
        )


def test_train_checkpoint_crash_restore(tiny):
    cfg, params = tiny
    opt = init_train_state(cfg, params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    store = AsuraCheckpointStore({i: 1.0 for i in range(5)}, n_replicas=3)
    mgr = CheckpointManager(store)
    batches = _batches(cfg, 10)
    for i, tokens in enumerate(batches[:5]):
        params, opt, _ = step(params, opt, {"tokens": jnp.asarray(tokens)})
    mgr.save_async(5, {"params": params, "opt": opt})
    mgr.wait()
    # continue training to step 10 (the "lost" progress)
    lost_params = params
    for tokens in batches[5:]:
        lost_params, opt, _ = step(lost_params, opt, {"tokens": jnp.asarray(tokens)})
    # crash: two storage nodes die; restore from step 5 and replay
    store.fail_node(1)
    store.fail_node(3)
    restored = mgr.restore(5, {"params": params, "opt": opt})
    for a, b in zip(jax.tree.leaves(restored["params"]), jax.tree.leaves(params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    opt2 = restored["opt"]
    replayed = restored["params"]
    for tokens in batches[5:]:
        replayed, opt2, _ = step(replayed, opt2, {"tokens": jnp.asarray(tokens)})
    # deterministic replay reaches the same weights
    for a, b in zip(jax.tree.leaves(replayed), jax.tree.leaves(lost_params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-5
        )


def test_sharded_train_step_on_debug_mesh(tiny):
    """jit with explicit shardings on a 1x1 mesh must equal unsharded."""
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.shardings import batch_shardings, param_shardings

    cfg, params = tiny
    mesh = make_debug_mesh(1, 1)
    opt = init_train_state(cfg, params)
    tokens = jnp.asarray(_batches(cfg, 1)[0])
    batch = {"tokens": tokens}
    fn = make_train_step(cfg, AdamWConfig())
    with mesh:
        sharded = jax.jit(
            fn,
            in_shardings=(
                param_shardings(mesh, params),
                {
                    "m": param_shardings(mesh, params),
                    "v": param_shardings(mesh, params),
                    "count": None,
                },
                batch_shardings(mesh, batch),
            ),
        )
        p_s, _, m_s = sharded(params, opt, batch)
    p_u, _, m_u = jax.jit(fn)(params, opt, batch)
    np.testing.assert_allclose(float(m_s["loss"]), float(m_u["loss"]), rtol=1e-5)


def test_train_cli_smoke(capsys):
    """The real launcher end to end: 6 steps, reduced smollm, checkpointing."""
    from repro.launch.train import main as train_main

    rc = train_main(
        ["--arch", "smollm-135m", "--reduced", "--steps", "6", "--batch", "4",
         "--seq", "64", "--ckpt-every", "3", "--lr", "1e-3"]
    )
    out = capsys.readouterr().out
    assert "loss" in out
    assert rc in (0, 1)  # loss direction over 6 steps can be noisy


def test_serve_cli_smoke(capsys):
    from repro.launch.serve import main as serve_main

    rc = serve_main(
        ["--arch", "smollm-135m", "--reduced", "--replicas", "3",
         "--replica-id", "0", "--requests", "8", "--batch", "4",
         "--decode-len", "2", "--cache-len", "8"]
    )
    assert rc == 0
    assert "decoded" in capsys.readouterr().out
