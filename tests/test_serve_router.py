"""Serving-layer session routing tests."""

import numpy as np

from repro.serve import ReplicaRouter


def test_routing_covers_all_replicas():
    router = ReplicaRouter({i: 1.0 for i in range(6)})
    owners = router.route(np.arange(10_000))
    assert set(owners.tolist()) == set(range(6))


def test_capacity_weighted_load():
    router = ReplicaRouter({0: 2.0, 1: 1.0, 2: 1.0})
    owners = router.route(np.arange(40_000))
    frac0 = (owners == 0).mean()
    assert 0.47 < frac0 < 0.53


def test_replica_loss_moves_only_its_sessions():
    sessions = np.arange(8_000)
    router = ReplicaRouter({i: 1.0 for i in range(5)})
    before = router.route(sessions)
    plan = router.plan_scale_event(sessions, remove=2)
    lost = (before == 2).sum()
    assert plan.n_reprefills == lost
    for sid, (src, dst) in plan.moved_sessions.items():
        assert src == 2 and dst != 2


def test_scale_out_steals_minimally():
    sessions = np.arange(8_000)
    router = ReplicaRouter({i: 1.0 for i in range(5)})
    plan = router.plan_scale_event(sessions, add=(9, 1.0))
    for sid, (src, dst) in plan.moved_sessions.items():
        assert dst == 9
    assert plan.n_reprefills < len(sessions) / 4  # ~1/6 expected


def test_frontends_share_only_the_table():
    router = ReplicaRouter({i: 1.0 for i in range(4)})
    blob = router.table_blob()
    assert len(blob) < 4096  # kilobyte-order shared state
    from repro.core import Cluster

    clone = Cluster.from_json(blob)
    ids = np.arange(2_000, dtype=np.uint32)
    assert np.array_equal(clone.place_nodes(ids), router.route(ids))


def test_my_sessions_partition():
    sessions = np.arange(5_000)
    router = ReplicaRouter({i: 1.0 for i in range(4)})
    parts = [router.my_sessions(r, sessions) for r in range(4)]
    merged = np.sort(np.concatenate(parts))
    assert np.array_equal(merged, sessions)


def test_scale_migration_serves_warm_caches_throughout():
    """Live scale-out: every session stays on the replica whose cache is
    warm (v owner until its re-prefill lands, v+1 after), and the final
    routing equals the plain post-event table."""
    sessions = np.arange(6_000, dtype=np.uint32)
    router = ReplicaRouter({i: 1.0 for i in range(5)})
    before = router.route(sessions)
    mig = router.begin_scale_migration(sessions, add=(9, 1.0), ingress=100)
    warm = dict(zip(sessions.tolist(), before.tolist()))
    assert mig.state.plan.n_moves > 0
    while not mig.done:
        pre = mig.state.landed.copy()
        mig.round()
        for r in np.nonzero(mig.state.landed & ~pre)[0]:
            warm[int(mig.state.plan.ids[r])] = int(mig.state.plan.dst[r])
        got = router.route_migrating(sessions, mig)
        assert np.array_equal(got, np.array([warm[int(s)] for s in sessions]))
    assert np.array_equal(router.route_migrating(sessions, mig), router.route(sessions))


def test_scale_migration_remove_only_moves_victims():
    sessions = np.arange(4_000, dtype=np.uint32)
    router = ReplicaRouter({i: 1.0 for i in range(5)})
    mig = router.begin_scale_migration(sessions, remove=2, egress=None)
    assert set(np.unique(mig.state.plan.src)) == {2}
    mig.run()
    assert not (router.route(sessions) == 2).any()
