"""Unit tests for the sharding rules and the dry-run helpers."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import data_axes, make_debug_mesh
from repro.launch.shardings import (
    _fit,
    batch_shardings,
    cache_shardings,
    param_pspec,
    param_shardings,
)
from repro.models import cache_specs, param_specs


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(1, 1)


def _find(tree_sh, *names):
    node = tree_sh
    for n in names:
        node = node[n]
    return node


class TestParamRules:
    def test_all_leaves_get_shardings(self, mesh):
        for arch in ("granite-3-2b", "deepseek-v2-236b", "rwkv6-3b", "recurrentgemma-9b", "whisper-large-v3"):
            cfg = get_config(arch)
            specs = param_specs(cfg)
            sh = param_shardings(mesh, specs)
            assert jax.tree.structure(sh) == jax.tree.structure(specs)

    def test_megatron_pairing(self, mesh):
        """In-proj puts the wide dim on model; out-proj the reverse."""
        cfg = get_config("granite-3-2b")
        sh = param_shardings(mesh, param_specs(cfg))
        blocks = sh["dense_blocks"] if "dense_blocks" in sh else sh["blocks"]
        assert blocks["attn"]["w_q"].spec == P(None, "data", "model")
        assert blocks["attn"]["w_o"].spec == P(None, "model", "data")
        assert blocks["mlp"]["w_gate"].spec == P(None, "data", "model")
        assert blocks["mlp"]["w_down"].spec == P(None, "model", "data")

    def test_embed_vocab_on_model(self, mesh):
        cfg = get_config("deepseek-7b")
        sh = param_shardings(mesh, param_specs(cfg))
        assert sh["embed"].spec == P("model", "data")
        assert sh["lm_head"].spec == P("data", "model")

    def test_moe_expert_parallel_when_divisible(self):
        mesh = make_debug_mesh(1, 1)
        cfg = get_config("deepseek-v2-236b")  # 160 experts
        sh = param_shardings(mesh, param_specs(cfg))
        # 160 % 1 == 0 -> expert axis keeps 'model'
        assert sh["blocks"]["moe"]["w_gate"].spec[-3] == "model"

    def test_moe_fallback_small_expert_count(self):
        """mixtral: 8 experts < model axis 16 -> TP over d_ff instead."""
        # fake a 16-way model axis via spec-level check (no 16 devices here):
        cfg = get_config("mixtral-8x22b")
        specs = param_specs(cfg)
        leaf = specs["blocks"]["moe"]["w_gate"]  # (56, 8, 6144, 16384)

        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")

        p = param_pspec(
            (
                jax.tree_util.DictKey("blocks"),
                jax.tree_util.DictKey("moe"),
                jax.tree_util.DictKey("w_gate"),
            ),
            leaf,
            FakeMesh(),
        )
        assert p == P(None, None, "data", "model")

    def test_norms_replicated(self, mesh):
        cfg = get_config("granite-3-2b")
        sh = param_shardings(mesh, param_specs(cfg))
        assert sh["final_norm"]["scale"].spec == P()


class TestFit:
    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}
        axis_names = ("pod", "data", "model")

    def test_drops_nondividing(self):
        m = self.FakeMesh()
        assert _fit(m, P("data", "model"), (1, 32768)) == P(None, "model")
        assert _fit(m, P("model"), (8,)) == P(None)
        assert _fit(m, P(("pod", "data")), (64,)) == P(("pod", "data"))
        assert _fit(m, P(("pod", "data")), (16,)) == P(None)

    def test_keeps_dividing(self):
        m = self.FakeMesh()
        assert _fit(m, P("data", "model"), (256, 4096)) == P("data", "model")


class TestCacheRules:
    def test_batch_moves_to_seq_for_batch1(self):
        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")

        cfg = get_config("mixtral-8x22b")
        specs = cache_specs(cfg, 1, 4096)  # long_500k clamps to window=4096
        # can't build NamedSharding on a fake mesh; check the pspec directly
        from repro.launch.shardings import cache_pspec

        leaf = specs["blocks"]["k"]  # (56, 1, 4096, 8, 128)
        p = cache_pspec(
            (jax.tree_util.DictKey("blocks"), jax.tree_util.DictKey("k")),
            leaf,
            FakeMesh(),
            cfg,
        )
        # batch 1 unsharded; sequence takes dp AND model (kv=8 cannot take
        # the 16-way model axis -> flash-decode seq sharding)
        assert p == P(None, None, ("data", "model"), None, None)

    def test_batch_sharded_when_divisible(self):
        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")

        from repro.launch.shardings import cache_pspec

        cfg = get_config("deepseek-7b")
        specs = cache_specs(cfg, 128, 32768)
        leaf = specs["dense_blocks"]["k"]  # (30, 128, 32768, 32, 128)
        p = cache_pspec(
            (jax.tree_util.DictKey("dense_blocks"), jax.tree_util.DictKey("k")),
            leaf,
            FakeMesh(),
            cfg,
        )
        # batch over dp; kv heads (32 % 16 == 0) on model
        assert p == P(None, "data", None, "model", None)


class TestCollectiveParser:
    def test_parses_known_ops(self):
        from repro.launch.dryrun import collective_bytes

        hlo = """
  %ag = bf16[256,4096] all-gather(bf16[16,4096] %x), dimensions={0}
  %ar = f32[1024] all-reduce(f32[1024] %y), to_apply=%sum
  %rs = f32[64,8] reduce-scatter(f32[1024,8] %z), dimensions={0}
  %cp = u32[128] collective-permute(u32[128] %w), source_target_pairs={{0,1}}
  %other = f32[2,2] add(f32[2,2] %a, f32[2,2] %b)
"""
        out = collective_bytes(hlo)
        assert out["counts"] == {
            "all-gather": 1,
            "all-reduce": 1,
            "reduce-scatter": 1,
            "collective-permute": 1,
        }
        assert out["bytes"]["all-gather"] == 256 * 4096 * 2
        assert out["bytes"]["all-reduce"] == 1024 * 4
        assert out["total_bytes"] == sum(out["bytes"].values())

    def test_tuple_shapes_ignored_gracefully(self):
        from repro.launch.dryrun import collective_bytes

        hlo = "%t = (f32[8], f32[8]) all-reduce(f32[8] %a, f32[8] %b)"
        out = collective_bytes(hlo)  # tuple output lines don't match the re
        assert out["total_bytes"] >= 0


class TestBatchShardings:
    def test_batch_first_dim(self, mesh):
        import jax.numpy as jnp

        tree = {"tokens": jax.ShapeDtypeStruct((8, 128), jnp.int32)}
        sh = batch_shardings(mesh, tree)
        assert sh["tokens"].spec == P(("data",), None)
