"""The CI perf-regression gate (benchmarks/check_regression.py): clean runs
pass, an injected 2x slowdown fails, new entries warn only, and the
direction policy follows the entry unit."""

import json

import pytest

from benchmarks.check_regression import (
    calibration_ratio,
    check_dirs,
    compare_entries,
    direction,
    main,
)


def _payload(entries: dict) -> dict:
    return {
        "suite": "head_to_head",
        "quick": True,
        "elapsed_s": 1.0,
        "entries": {
            name: {"value": value, "unit": unit}
            for name, (value, unit) in entries.items()
        },
    }


BASE = _payload(
    {
        "h2h_calc_asura_n32": (10.0, "us_per_id"),
        "h2h_calc_ch_n32": (20.0, "us_per_id"),
        "migrate_stream_ids_per_s": (1_000_000, "ids_per_s"),
        "h2h_uniformity_asura_n32_dpn500": (9.5, "maxvar_pct"),
        "h2h_move_add_asura_wrong_dest": (0, "must_be_0_if_optimal"),
    }
)


def test_direction_policy():
    assert direction("us_per_id") == "lower"
    assert direction("us_per_call") == "lower"
    assert direction("bytes") == "lower"
    assert direction("ids_per_s") == "higher"
    assert direction("x_speedup") == "higher"  # the scaling-suite ratios
    assert direction("an_prefilter") == "skip"  # derived-note units skipped
    assert direction("maxvar_pct") == "skip"
    assert direction("must_be_0_if_optimal") == "skip"


def test_clean_run_passes():
    failures, warnings = compare_entries(BASE, BASE)
    assert failures == [] and warnings == []


def test_injected_2x_slowdown_fails():
    fresh = json.loads(json.dumps(BASE))
    fresh["entries"]["h2h_calc_asura_n32"]["value"] = 20.0  # 2x slower
    failures, _ = compare_entries(BASE, fresh)
    assert len(failures) == 1
    assert "h2h_calc_asura_n32" in failures[0]


def test_throughput_halving_fails():
    fresh = json.loads(json.dumps(BASE))
    fresh["entries"]["migrate_stream_ids_per_s"]["value"] = 400_000
    failures, _ = compare_entries(BASE, fresh)
    assert len(failures) == 1
    assert "migrate_stream_ids_per_s" in failures[0]


def test_within_threshold_noise_passes():
    fresh = json.loads(json.dumps(BASE))
    fresh["entries"]["h2h_calc_asura_n32"]["value"] = 12.0  # +20% < +25%
    fresh["entries"]["migrate_stream_ids_per_s"]["value"] = 850_000
    failures, _ = compare_entries(BASE, fresh)
    assert failures == []


def test_quality_metric_swings_are_not_gated():
    fresh = json.loads(json.dumps(BASE))
    fresh["entries"]["h2h_uniformity_asura_n32_dpn500"]["value"] = 50.0
    failures, _ = compare_entries(BASE, fresh)
    assert failures == []


def test_new_and_retired_entries_warn_only():
    fresh = json.loads(json.dumps(BASE))
    fresh["entries"]["h2h_calc_rs_n32"] = {"value": 5.0, "unit": "us_per_id"}
    del fresh["entries"]["h2h_calc_ch_n32"]
    failures, warnings = compare_entries(BASE, fresh)
    assert failures == []
    assert any("new entry" in w for w in warnings)
    assert any("missing from fresh" in w for w in warnings)


CAL_BASE = _payload(
    {
        "h2h_calibration": (100.0, "us_calibration"),
        "h2h_calc_asura_n32": (10.0, "us_per_id"),
        "migrate_stream_ids_per_s": (1_000_000, "ids_per_s"),
        "h2h_memory_ch_n100": (80_000, "bytes"),
        "migrate_stream_sharded_strong_4dev_x_speedup": (2.5, "x_speedup"),
    }
)


def _with(payload, **values):
    out = json.loads(json.dumps(payload))
    for name, value in values.items():
        out["entries"][name]["value"] = value
    return out


def test_calibration_entry_is_never_gated():
    assert direction("us_calibration") == "skip"
    fresh = _with(CAL_BASE, h2h_calibration=900.0)  # 9x, alone not a failure
    failures, _ = compare_entries(CAL_BASE, fresh)
    assert failures == []


def test_calibration_normalizes_slow_runner():
    """A uniformly 2x-slower machine (calibration 2x) is NOT a regression."""
    fresh = _with(
        CAL_BASE,
        h2h_calibration=200.0,
        h2h_calc_asura_n32=20.0,
        migrate_stream_ids_per_s=500_000,
    )
    failures, _ = compare_entries(CAL_BASE, fresh)
    assert failures == []
    # ...but a 4x slowdown on a 2x-slower machine is a real 2x regression
    fresh = _with(CAL_BASE, h2h_calibration=200.0, h2h_calc_asura_n32=40.0)
    failures, _ = compare_entries(CAL_BASE, fresh)
    assert len(failures) == 1 and "h2h_calc_asura_n32" in failures[0]


def test_faster_runner_cannot_mask_regression():
    """Machine got 2x faster but the timing stayed flat -> the code is
    2x slower speed-adjusted, and the gate says so."""
    fresh = _with(CAL_BASE, h2h_calibration=50.0)  # timings unchanged
    failures, _ = compare_entries(CAL_BASE, fresh)
    assert any("h2h_calc_asura_n32" in f for f in failures)


def test_bytes_entries_compare_raw_despite_calibration():
    """Deterministic size entries are machine-independent: a slower runner
    must not excuse a genuinely bigger table."""
    fresh = _with(CAL_BASE, h2h_calibration=200.0, h2h_memory_ch_n100=160_000)
    failures, _ = compare_entries(CAL_BASE, fresh)
    assert any("h2h_memory_ch_n100" in f for f in failures)


def test_speedup_ratios_compare_raw_despite_calibration():
    """Scaling speedups are dimensionless -- machine speed cancels in the
    ratio, so a slower runner must not excuse a lost speedup (and a lost
    speedup IS a regression)."""
    name = "migrate_stream_sharded_strong_4dev_x_speedup"
    fresh = _with(CAL_BASE, **{"h2h_calibration": 200.0, name: 1.1})
    failures, _ = compare_entries(CAL_BASE, fresh)
    assert any(name in f for f in failures)
    # within threshold: fine, regardless of calibration swing
    fresh = _with(CAL_BASE, **{"h2h_calibration": 200.0, name: 2.2})
    failures, _ = compare_entries(CAL_BASE, fresh)
    assert not any(name in f for f in failures)


def test_calibration_ratio_clamped():
    base = CAL_BASE["entries"]
    fresh = _with(CAL_BASE, h2h_calibration=100_000.0)["entries"]
    assert calibration_ratio(base, fresh) == 8.0
    fresh = _with(CAL_BASE, h2h_calibration=0.001)["entries"]
    assert calibration_ratio(base, fresh) == 1 / 8
    assert calibration_ratio(BASE["entries"], BASE["entries"]) == 1.0


def test_custom_threshold():
    fresh = json.loads(json.dumps(BASE))
    fresh["entries"]["h2h_calc_asura_n32"]["value"] = 11.5  # +15%
    assert compare_entries(BASE, fresh, threshold=1.10)[0]
    assert not compare_entries(BASE, fresh, threshold=1.25)[0]


def _write(path, payload):
    path.write_text(json.dumps(payload))


def test_check_dirs_and_main_exit_codes(tmp_path):
    base_dir = tmp_path / "baselines"
    fresh_dir = tmp_path / "fresh"
    base_dir.mkdir()
    fresh_dir.mkdir()
    _write(base_dir / "BENCH_head_to_head.json", BASE)
    _write(fresh_dir / "BENCH_head_to_head.json", BASE)
    failures, warnings = check_dirs(str(base_dir), str(fresh_dir))
    assert failures == []
    assert main(["--baseline-dir", str(base_dir), "--fresh-dir", str(fresh_dir)]) == 0

    slow = json.loads(json.dumps(BASE))
    slow["entries"]["h2h_calc_ch_n32"]["value"] = 41.0  # > 2x
    _write(fresh_dir / "BENCH_head_to_head.json", slow)
    failures, _ = check_dirs(str(base_dir), str(fresh_dir))
    assert len(failures) == 1
    assert main(["--baseline-dir", str(base_dir), "--fresh-dir", str(fresh_dir)]) == 1


def test_missing_fresh_file_warns_not_fails(tmp_path):
    base_dir = tmp_path / "baselines"
    fresh_dir = tmp_path / "fresh"
    base_dir.mkdir()
    fresh_dir.mkdir()
    _write(base_dir / "BENCH_movement.json", BASE)
    failures, warnings = check_dirs(str(base_dir), str(fresh_dir))
    assert failures == []
    assert any("did not emit" in w for w in warnings)


def test_empty_baseline_dir_warns(tmp_path):
    failures, warnings = check_dirs(str(tmp_path), str(tmp_path))
    assert failures == []
    assert any("nothing gated" in w for w in warnings)
