"""Per-architecture smoke tests: reduced config, one forward/train/decode
step on CPU, asserting output shapes and finiteness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # ~4 min: every arch compiles fwd/train/decode

from repro.configs import ARCHS, get_config
from repro.models import (
    SHAPES,
    decode_step,
    init_cache,
    init_params,
    loss_fn,
    make_inputs,
    prefill,
    reduced_config,
)
from repro.models.config import ShapeSpec

SMOKE_TRAIN = ShapeSpec("smoke_train", seq_len=32, global_batch=2, kind="train")
SMOKE_DECODE = ShapeSpec("smoke_decode", seq_len=24, global_batch=2, kind="decode")


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _setup(name, rng):
    cfg = reduced_config(get_config(name))
    params = init_params(cfg, rng)
    return cfg, params


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_smoke(name, rng):
    cfg, params = _setup(name, rng)
    inputs = make_inputs(cfg, SMOKE_TRAIN, rng)

    def loss(p):
        return loss_fn(cfg, p, inputs["batch"])[0]

    val, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val)), f"{name}: loss not finite"
    # a random-init model on 512-way vocab should be near ln(512)
    assert 3.0 < float(val) < 12.0, f"{name}: loss {val} implausible"
    leaves = jax.tree.leaves(grads)
    assert leaves, name
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g))), f"{name}: non-finite grad"
    # at least one grad must be nonzero
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves), name


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_smoke(name, rng):
    cfg, params = _setup(name, rng)
    inputs = make_inputs(cfg, SMOKE_TRAIN, rng)
    logits = prefill(cfg, params, inputs["batch"])
    assert logits.shape == (SMOKE_TRAIN.global_batch, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits))), name


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step_smoke(name, rng):
    cfg, params = _setup(name, rng)
    b = SMOKE_DECODE.global_batch
    cache = init_cache(cfg, b, SMOKE_DECODE.seq_len)
    batch = {
        "tokens": jnp.zeros((b, 1), jnp.int32),
        "positions": jnp.zeros((b, 1), jnp.int32),
    }
    logits, new_cache = decode_step(cfg, params, cache, batch)
    assert logits.shape == (b, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits))), name
    # caches/states must advance: at least one leaf differs
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b_))
        for a, b_ in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache))
    )
    assert changed, f"{name}: decode cache did not change"


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_prefill_prefix(name, rng):
    """Feeding tokens one-by-one through decode must agree with the parallel
    prefill forward on the same prefix (numerics: bf16 tolerance)."""
    cfg, params = _setup(name, rng)
    if cfg.family == "encdec":
        pytest.skip("decode parity needs encoder output plumbing; see below")
    b, s = 2, 8
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab).astype(jnp.int32)
    batch = {"tokens": tokens}
    if cfg.vision_prefix:
        batch["patches"] = jnp.zeros((b, cfg.vision_prefix, cfg.d_model), jnp.bfloat16)
    par = prefill(cfg, params, batch)
    if cfg.vision_prefix:
        pytest.skip("vlm decode path omits the vision prefix (text-only decode)")
    cache = init_cache(cfg, b, 16)
    for t in range(s):
        step_batch = {
            "tokens": tokens[:, t : t + 1],
            "positions": jnp.full((b, 1), t, jnp.int32),
        }
        seq, cache = decode_step(cfg, params, cache, step_batch)
    np.testing.assert_allclose(
        np.asarray(seq, np.float32),
        np.asarray(par, np.float32),
        rtol=0.15,
        atol=0.15,
    )


def test_full_configs_match_spec():
    """The full (non-reduced) configs carry the exact assigned dims."""
    spec = {
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
    }
    for name, (nl, d, h, kv, ff, v) in spec.items():
        cfg = get_config(name)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab) == (
            nl, d, h, kv, ff, v,
        ), name
    assert get_config("deepseek-v2-236b").moe.n_experts == 160
    assert get_config("deepseek-v2-236b").moe.top_k == 6
    assert get_config("deepseek-v2-236b").mla.kv_lora_rank == 512
    assert get_config("mixtral-8x22b").moe.n_experts == 8
    assert get_config("mixtral-8x22b").moe.top_k == 2
    assert get_config("recurrentgemma-9b").block_pattern == ("rec", "rec", "attn")


def test_param_counts_plausible():
    """6ND bookkeeping sanity: param_count within 2x of the nameplate."""
    expect = {
        "granite-3-2b": 2.5e9,
        "command-r-35b": 35e9,
        "deepseek-7b": 7e9,
        "smollm-135m": 135e6,
        "deepseek-v2-236b": 236e9,
        "mixtral-8x22b": 141e9,
        "internvl2-26b": 20e9,
        "recurrentgemma-9b": 9e9,
        "rwkv6-3b": 3e9,
    }
    for name, n in expect.items():
        got = get_config(name).param_count()
        assert 0.5 * n < got < 2.0 * n, f"{name}: {got:.2e} vs nameplate {n:.2e}"


def test_moe_active_params_much_smaller():
    cfg = get_config("deepseek-v2-236b")
    assert cfg.active_param_count() < 0.25 * cfg.param_count()


def test_shape_cells_applicability():
    from repro.configs import all_cells

    cells = list(all_cells())
    assert len(cells) == 40
    skipped = [(a, s.name) for a, s, ok, _ in cells if not ok]
    # exactly the full-attention archs skip long_500k
    assert sorted(skipped) == sorted(
        [
            ("granite-3-2b", "long_500k"),
            ("command-r-35b", "long_500k"),
            ("deepseek-7b", "long_500k"),
            ("smollm-135m", "long_500k"),
            ("whisper-large-v3", "long_500k"),
            ("deepseek-v2-236b", "long_500k"),
            ("internvl2-26b", "long_500k"),
        ]
    )
