"""ISSUE-7 coverage: the batched device-resident serving pipeline.

  * TrafficModel statistics: chi-square goodness-of-fit of the sampled
    ranks against each law's exact pmf at a fixed seed (hand-rolled, no
    scipy: tail bins merged to keep expected counts honest, critical value
    via the Wilson-Hilferty approximation), determinism, and the exact-u32
    threshold quantization,
  * ref-vs-pallas engine backends driving bit-identical streams (ids,
    chosen nodes, counters),
  * zero host syncs per batch step: transfer guard + np.asarray tripwire +
    one table upload + a stable ``step_traces`` trace count,
  * the fused step's accounting: counters == bincount of every chosen
    node, the queue recurrence replayed on the host, ragged external
    batches through the pow2 buckets without phantom counts,
  * power-of-two-choices beating random-of-R under Zipf(1.1) at R=3,
  * the baselines' salted replica fan-out: device == numpy oracle bit for
    bit, pairwise-distinct rows, primary-first, host dispatch,
  * the cached replica probes' trace-count tripwires (router + window).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import PlacementEngine, make_uniform_cluster
from repro.kernels.baselines import (
    REPLICA_MAX_TRIES,
    baseline_place_replicas_np,
)
from repro.serve import RequestStreamDriver, Router, TrafficModel
from repro.serve.stream import select_replica

BASELINES = ("ch", "wrh", "rs")


# ---------------------------------------------------------------------------
# TrafficModel: exact thresholds, determinism, chi-square fit
# ---------------------------------------------------------------------------


def test_uniform_thresholds_are_exact_u32():
    # 2**32 divisible by n_keys: every rank gets exactly 2**32 / n draws
    n = 1 << 8
    tm = TrafficModel(n, law="uniform")
    width = 1 << 24
    expect = np.arange(1, n + 1, dtype=np.uint64) * width - 1
    assert np.array_equal(tm.thresholds.astype(np.uint64), expect)
    # boundary draws map to the right ranks
    ranks = np.asarray(
        TrafficModel.ranks_from_words(
            jnp.asarray([0, width - 1, width, 2**32 - 1], dtype=jnp.uint32),
            tm.thresholds_dev,
        )
    )
    assert list(ranks) == [0, 0, 1, n - 1]


def test_thresholds_monotone_and_total():
    for law in ("uniform", "zipf", "hotset"):
        tm = TrafficModel(1000, law=law)
        thr = tm.thresholds.astype(np.int64)
        assert thr[-1] == 2**32 - 1  # the CDF must cover every u32 draw
        assert (np.diff(thr) >= 0).all()


def test_sample_ranks_deterministic_and_id_bijection():
    tm = TrafficModel(4096, law="zipf", seed=3)
    a = tm.sample_ranks(17, 5000)
    b = tm.sample_ranks(17, 5000)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, tm.sample_ranks(18, 5000))
    # rank -> id is the salted fmix32 bijection, numpy twin == device
    ids_dev = np.asarray(
        TrafficModel.ids_from_ranks(jnp.asarray(a, dtype=jnp.uint32), tm.id_salt)
    )
    assert np.array_equal(ids_dev, tm.rank_to_id_np(a))
    ranks = np.arange(4096, dtype=np.uint32)
    assert len(np.unique(tm.rank_to_id_np(ranks))) == 4096


def _chi_square_crit(df: int, z: float = 3.09) -> float:
    """Upper-tail chi-square critical value (Wilson-Hilferty), z=3.09 is
    the ~0.1% normal quantile -- loose enough to keep a fixed seed stable."""
    h = 2.0 / (9.0 * df)
    return df * (1.0 - h + z * np.sqrt(h)) ** 3


@pytest.mark.parametrize("law", ["uniform", "zipf", "hotset"])
def test_generator_chi_square_fit(law):
    n_keys, n_draws = 512, 1 << 17
    tm = TrafficModel(n_keys, law=law, alpha=1.1, hot_keys=16, seed=0)
    ranks = tm.sample_ranks(5, n_draws)
    obs = np.bincount(ranks, minlength=n_keys).astype(np.float64)
    exp = tm.pmf * n_draws
    # merge the tail into bins with expected count >= 8 (chi-square needs
    # non-starved cells; zipf's tail ranks are individually tiny)
    order = np.argsort(-exp)
    obs_b, exp_b, o_acc, e_acc = [], [], 0.0, 0.0
    for i in order:
        o_acc += obs[i]
        e_acc += exp[i]
        if e_acc >= 8.0:
            obs_b.append(o_acc)
            exp_b.append(e_acc)
            o_acc = e_acc = 0.0
    if e_acc > 0:
        obs_b[-1] += o_acc
        exp_b[-1] += e_acc
    obs_b, exp_b = np.asarray(obs_b), np.asarray(exp_b)
    chi2 = float(((obs_b - exp_b) ** 2 / exp_b).sum())
    crit = _chi_square_crit(len(exp_b) - 1)
    assert chi2 < crit, f"{law}: chi2 {chi2:.1f} >= crit {crit:.1f}"


# ---------------------------------------------------------------------------
# The fused batch step
# ---------------------------------------------------------------------------


def _driver(engine, **kw):
    kw.setdefault("batch", 2048)
    kw.setdefault("n_keys", 1 << 14)
    kw.setdefault("n_replicas", 3)
    kw.setdefault("policy", "pow2")
    kw.setdefault("seed", 0)
    return RequestStreamDriver(engine, **kw)


def test_ref_vs_pallas_streams_bit_identical():
    cluster = make_uniform_cluster(12)
    drivers = [
        _driver(PlacementEngine(cluster, backend=b)) for b in ("ref", "pallas")
    ]
    for _ in range(3):
        a, b = (np.asarray(d.step()) for d in drivers)
        assert np.array_equal(a, b)
    assert np.array_equal(drivers[0].load_counts(), drivers[1].load_counts())


def test_step_zero_host_syncs(monkeypatch):
    cluster = make_uniform_cluster(12)
    eng = PlacementEngine(cluster, backend="ref")
    d = _driver(eng)
    d.step().block_until_ready()  # warm: table upload + fused-step compile
    assert eng.uploads == 1
    traces = d.step_traces
    real_asarray = np.asarray
    host_reads: list = []

    def tripwire(*args, **kwargs):
        host_reads.append(args)
        return real_asarray(*args, **kwargs)

    monkeypatch.setattr(np, "asarray", tripwire)
    with jax.transfer_guard("disallow"):
        for _ in range(3):
            chosen = d.step()
        chosen.block_until_ready()
    monkeypatch.undo()
    assert isinstance(chosen, jax.Array)
    assert not host_reads, f"batch step touched the host: {len(host_reads)} reads"
    assert eng.uploads == 1
    assert d.step_traces == traces, "repeated steps retraced the fused step"


def test_counts_match_chosen_and_queue_recurrence():
    cluster = make_uniform_cluster(10)
    d = _driver(PlacementEngine(cluster, backend="ref"), batch=1024)
    hist_total = np.zeros(d.n_bins, dtype=np.int64)
    q = np.zeros(d.n_bins, dtype=np.int64)
    service = d.service_rate
    for step in range(5):
        chosen = np.asarray(d.step())
        h = np.bincount(chosen, minlength=d.n_bins)
        hist_total += h
        q = np.maximum(q + h - service, 0)
        assert np.array_equal(np.asarray(d.queue), q)
        assert np.array_equal(np.asarray(d.qhist)[step], q)
    assert np.array_equal(d.load_counts(), hist_total)
    # reset rewinds the stream: the replay is bit-identical
    first = np.asarray(d.qhist)[0]
    d.reset()
    d.step()
    assert np.array_equal(np.asarray(d.qhist)[0], first)


def test_route_batch_pow2_buckets_no_phantom_counts():
    cluster = make_uniform_cluster(10)
    d = _driver(PlacementEngine(cluster, backend="ref"))
    ids = np.arange(1000, dtype=np.uint32)
    out = np.asarray(d.route_batch(ids))
    assert out.shape == (1000,)
    assert d.load_counts().sum() == 1000  # pad lanes never counted
    # chosen nodes come from each id's replica set
    sets_ = d.engine.place_replica_nodes(ids, d.n_replicas)
    assert (out[:, None] == sets_).any(axis=1).all()
    traces = d.step_traces
    out2 = np.asarray(d.route_batch(np.arange(700, dtype=np.uint32)))
    assert out2.shape == (700,)
    assert d.load_counts().sum() == 1700
    assert d.step_traces == traces, "same pow2 bucket must share one compile"


def test_pow2_beats_random_under_zipf():
    cluster = make_uniform_cluster(16)
    eng = PlacementEngine(cluster, backend="ref")
    skews = {}
    for policy in ("random", "pow2"):
        d = _driver(eng, batch=4096, law="zipf", alpha=1.1, policy=policy)
        for _ in range(8):
            d.step()
        skews[policy] = d.load_skew()
    assert skews["pow2"] < skews["random"], skews


def test_select_replica_policies():
    owners = jnp.asarray([[3, 1, 2], [5, -1, -1], [-1, -1, -1]], dtype=jnp.int32)
    counts = jnp.asarray([0, 9, 1, 4, 0, 2, 0, 0], dtype=jnp.int32)
    sel = jnp.zeros(3, dtype=jnp.uint32)  # slots i=0, j=1 everywhere
    prim = np.asarray(
        select_replica(owners, sel, counts, policy="primary", n_replicas=3)
    )
    assert list(prim) == [3, 5, 0]  # fully-invalid row clamps to 0
    p2 = np.asarray(
        select_replica(owners, sel, counts, policy="pow2", n_replicas=3)
    )
    # row 0: counts[3]=4 vs counts[1]=9 -> keep 3; row 1: -1 candidate
    # loses to the valid 5; row 2: all invalid -> clamped primary
    assert list(p2) == [3, 5, 0]
    rnd = np.asarray(
        select_replica(owners, sel, counts, policy="random", n_replicas=3)
    )
    assert list(rnd) == [3, 5, 0]


# ---------------------------------------------------------------------------
# Baseline replica fan-out (the salted rejection re-probe)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alg", BASELINES)
@pytest.mark.parametrize("R", [1, 3])
def test_baseline_fanout_device_matches_numpy_oracle(alg, R):
    cluster = make_uniform_cluster(9)
    eng = PlacementEngine(cluster, algorithm=alg, backend="ref")
    ids = np.arange(3000, dtype=np.uint32)
    art = eng.artifact()
    oracle = baseline_place_replicas_np(
        alg, ids, art.keys, art.vals, R, max_tries=REPLICA_MAX_TRIES
    )
    dev = np.asarray(eng.place_replica_nodes_device(ids, R))
    assert np.array_equal(dev, oracle)
    host = eng.place_replica_nodes(ids, R)
    assert np.array_equal(host, oracle)
    # primary-first, pairwise-distinct, converged
    assert np.array_equal(host[:, 0], eng.place_nodes(ids))
    assert (host >= 0).all()
    for r in range(R):
        for s in range(r + 1, R):
            assert (host[:, r] != host[:, s]).all()


def test_baseline_fanout_r_exceeding_nodes_raises():
    cluster = make_uniform_cluster(3)
    eng = PlacementEngine(cluster, algorithm="ch", backend="ref")
    with pytest.raises(ValueError, match="fan-out"):
        eng.place_replica_nodes(np.arange(10, dtype=np.uint32), 4)


# ---------------------------------------------------------------------------
# Cached probes: trace-count tripwires
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alg", ["asura", "ch"])
def test_router_replica_probe_trace_tripwire(alg):
    r = Router({i: 1.0 for i in range(8)}, algorithm=alg)
    ids = np.arange(512, dtype=np.uint32)
    first = np.asarray(r.route_replicas_device(ids, 3))
    assert r.probe_traces == 1
    for _ in range(3):
        out = np.asarray(r.route_replicas_device(ids, 3))
    assert r.probe_traces == 1, "repeated replica routing retraced the probe"
    assert np.array_equal(out, first)
    assert np.array_equal(out, r.route_replicas(ids, 3))
    r.route_replicas_device(ids, 2)
    assert r.probe_traces == 2  # a different R is a different probe


def test_stream_driver_factory_binds_router_algorithm():
    r = Router({i: 1.0 for i in range(6)}, algorithm="wrh")
    d = r.stream_driver(batch=512, n_keys=1 << 12, n_replicas=2, seed=1)
    assert d.algorithm == "wrh"
    chosen = np.asarray(d.step())
    assert chosen.shape == (512,)
    assert d.load_counts().sum() == 512
