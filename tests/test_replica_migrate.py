"""ISSUE-5 coverage: replica-set migration (DESIGN.md section 10).

  * the fused dual-table replica-diff kernel vs an INDEPENDENT scalar
    set-diff oracle -- bit-identical per-slot (moved, src, dst, src_slot)
    for R in {1, 2, 3} at top_level in {0, 5, 19}, ref and pallas, and the
    numpy host path through ``plan_replicas``,
  * a transfer-guard + np.asarray-tripwire proof that the replica
    streaming sweep performs ZERO host syncs,
  * minimal replica mass: an add/remove event moves exactly
    ``|after \\ before|`` replicas per id, with no wrong-direction moves,
  * a churn property test (hypothesis): replica sets stay pairwise
    distinct and planned movement matches the brute-force minimal set
    diff across add/remove/resize sequences,
  * dual-version replica serving: every served set is R pairwise-distinct
    holders at every round, host and device paths agreeing, including
    through a mid-drain rollback (slot re-indexing),
  * consumers: the replica coordinator's owner tracking, the failure
    driver's replica repair, the checkpoint store's per-slot live
    add/repair with bit-identical restores every round,
  * ``remove_numbers_batch`` row-identical to the scalar trace.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import AsuraCheckpointStore, CheckpointManager
from repro.core import Cluster, PlacementEngine, make_uniform_cluster
from repro.core.asura import (
    DEFAULT_PARAMS,
    align_replica_sets,
    place_replicas_batch,
    remove_numbers,
    remove_numbers_batch,
)
from repro.migrate import MigrationPlanner
from repro.runtime import ElasticCoordinator, HeartbeatTracker, MigrationDriver
from repro.serve.router import ReplicaRouter

from test_migrate import TOP_CASES, TableCluster, _mutations


def _oracle_slot_moves(before_row, after_row):
    """Independent scalar oracle: slot -> (src, dst, src_slot) via explicit
    set differences (k-th new after-slot pairs with k-th lost before-slot)."""
    before = [int(x) for x in before_row]
    after = [int(x) for x in after_row]
    lost = [(q, n) for q, n in enumerate(before) if n not in after]
    moves = {}
    k = 0
    for r, n in enumerate(after):
        if n not in before:
            q, src = lost[k]
            k += 1
            moves[r] = (src, n, q)
    assert k == len(lost)  # set differences have equal size
    return moves


def _check_against_oracle(before, after, moved, src, dst, src_slot):
    n, R = before.shape
    for b in range(n):
        moves = _oracle_slot_moves(before[b], after[b])
        for r in range(R):
            assert dst[b, r] == after[b, r]
            if r in moves:
                o_src, o_dst, o_slot = moves[r]
                assert moved[b, r]
                assert src[b, r] == o_src
                assert dst[b, r] == o_dst
                assert src_slot[b, r] == o_slot
            else:
                assert not moved[b, r]
                assert src[b, r] == after[b, r]


# ---------------------------------------------------------------------------
# Replica diff == independent scalar set-diff oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("top_level", sorted(TOP_CASES))
def test_diff_replicas_matches_oracle(backend, top_level):
    lengths, nodes = TOP_CASES[top_level]
    slow = backend == "pallas" and top_level == 19
    n_ids = 128 if slow else 512
    replica_counts = (2,) if slow else (1, 2, 3)
    ids = (np.arange(n_ids, dtype=np.uint64) * 2654435761 % (2**32)).astype(
        np.uint32
    )
    for name, new_l, new_n in _mutations(top_level):
        # R-way replication needs R distinct live nodes under BOTH versions
        live = lambda l, n: len(  # noqa: E731
            set(np.asarray(n)[np.asarray(l) > 0].tolist())
        )
        max_r = min(live(lengths, nodes), live(new_l, new_n))
        for R in replica_counts:
            if R > max_r:
                continue
            cluster = TableCluster(lengths, nodes)
            eng = PlacementEngine(cluster, backend=backend)
            eng.artifact()
            v_from = cluster.version
            cluster.mutate(new_l, new_n)
            moved, src, dst, src_slot = (
                np.asarray(a)
                for a in eng.diff_replicas_device(ids, v_from, cluster.version, R)
            )
            before = np.asarray(nodes)[place_replicas_batch(ids, lengths, nodes, R)]
            after = np.asarray(new_n)[place_replicas_batch(ids, new_l, new_n, R)]
            _check_against_oracle(
                before, after, moved, src, dst, src_slot
            )


@pytest.mark.parametrize("R", [1, 2, 3])
def test_plan_replicas_host_path_matches_oracle(R):
    """The numpy host path (place twice + align) through plan_replicas."""
    cluster = make_uniform_cluster(7)
    eng = PlacementEngine(cluster, backend="numpy")
    ids = np.arange(1200, dtype=np.uint32)
    before = eng.place_replica_nodes(ids, R)
    eng.artifact()
    v_from = cluster.version
    cluster.remove_node(3)
    cluster.add_node(40, 1.3)
    after = eng.place_replica_nodes(ids, R)
    plan = MigrationPlanner(eng).plan_replicas(ids, v_from, cluster.version, R)
    assert plan.n_replicas == R
    # reassemble per-slot rows into dense arrays and compare to the oracle
    moved = np.zeros((len(ids), R), dtype=bool)
    src = np.where(moved, 0, after).astype(np.int64)
    src_slot = np.tile(np.arange(R), (len(ids), 1))
    moved[plan.index, plan.slot] = True
    src[plan.index, plan.slot] = plan.src
    src_slot[plan.index, plan.slot] = plan.src_slot
    dst = after.copy()
    dst[plan.index, plan.slot] = plan.dst
    _check_against_oracle(before, after, moved, src, dst, src_slot)
    # minimal replica mass: exactly the set difference, id by id
    minimal = (~(after[:, :, None] == before[:, None, :]).any(axis=2)).sum()
    assert plan.n_moves == int(minimal)


@pytest.mark.parametrize("backend", ["numpy", "ref"])
def test_plan_replicas_backends_agree_and_chunking_invisible(backend):
    cluster = make_uniform_cluster(6)
    eng = PlacementEngine(cluster, backend=backend)
    ids = np.arange(2000, dtype=np.uint32)
    eng.artifact()
    v_from = cluster.version
    cluster.add_node(9, 0.8)
    planner = MigrationPlanner(eng)
    whole = planner.plan_replicas(ids, v_from, cluster.version, 3)
    chunked = planner.plan_replicas(ids, v_from, cluster.version, 3, chunk=701)
    for field in ("ids", "src", "dst", "index", "slot", "src_slot"):
        assert np.array_equal(getattr(whole, field), getattr(chunked, field))


def test_plan_replicas_prefilter_is_plan_preserving():
    cluster = make_uniform_cluster(8)
    eng = PlacementEngine(cluster, backend="ref")
    ids = np.arange(3000, dtype=np.uint32)
    eng.place_replica_nodes(ids, 3)
    v_from = cluster.version
    new_segs = cluster.add_node(50, 1.0)
    planner = MigrationPlanner(eng)
    full = planner.plan_replicas(ids, v_from, cluster.version, 3)
    pre = planner.plan_replicas(
        ids, v_from, cluster.version, 3, max_new_seg=max(new_segs)
    )
    assert full.n_moves > 0
    for field in ("ids", "src", "dst", "index", "slot", "src_slot"):
        assert np.array_equal(getattr(full, field), getattr(pre, field))


# ---------------------------------------------------------------------------
# Zero host syncs in the replica streaming sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_plan_replicas_stream_zero_host_transfers(backend, monkeypatch):
    cluster = make_uniform_cluster(5)
    eng = PlacementEngine(cluster, backend=backend)
    eng.artifact()
    v_from = cluster.version
    cluster.add_node(9, 1.2)
    v_to = cluster.version
    planner = MigrationPlanner(eng)
    chunks = [jnp.arange(s, s + 512, dtype=jnp.uint32) for s in (0, 512, 1024)]
    for _, m, s, d, ss in planner.plan_replicas_stream(chunks, v_from, v_to, 3):
        m.block_until_ready()  # warm-up: device tables + jit compile
    uploads = eng.uploads

    real_asarray = np.asarray
    host_reads: list = []

    def tripwire(*args, **kwargs):
        host_reads.append(args)
        return real_asarray(*args, **kwargs)

    monkeypatch.setattr(np, "asarray", tripwire)
    with jax.transfer_guard("disallow"):
        for _, moved, src, dst, src_slot in planner.plan_replicas_stream(
            chunks, v_from, v_to, 3
        ):
            moved.block_until_ready()
            src.block_until_ready()
            dst.block_until_ready()
            src_slot.block_until_ready()
    monkeypatch.undo()
    assert isinstance(src, jax.Array) and isinstance(src_slot, jax.Array)
    assert not host_reads, f"replica sweep touched the host: {len(host_reads)}"
    assert eng.uploads == uploads == 2  # one per version, ever


# ---------------------------------------------------------------------------
# Minimal replica mass / direction constraints
# ---------------------------------------------------------------------------


def test_add_remove_move_exactly_the_minimal_replica_mass():
    cluster = make_uniform_cluster(10)
    eng = cluster.engine
    ids = np.arange(4000, dtype=np.uint32)
    R = 3
    planner = MigrationPlanner(eng)

    before = eng.place_replica_nodes(ids, R)
    v0 = cluster.version
    cluster.add_node(10, 1.0)
    plan = planner.plan_replicas(ids, v0, cluster.version, R)
    after = eng.place_replica_nodes(ids, R)
    minimal = int((~(after[:, :, None] == before[:, None, :]).any(axis=2)).sum())
    assert plan.n_moves == minimal > 0
    assert np.all(plan.dst == 10)  # additions pull ONLY toward the new node
    assert plan.n_moves <= len(ids)  # at most one slot per id on a single add

    before = after
    v1 = cluster.version
    cluster.remove_node(4)
    plan = planner.plan_replicas(ids, v1, cluster.version, R)
    after = eng.place_replica_nodes(ids, R)
    minimal = int((~(after[:, :, None] == before[:, None, :]).any(axis=2)).sum())
    assert plan.n_moves == minimal > 0
    assert np.all(plan.src == 4)  # removals push ONLY off the victim
    victims = (before == 4).any(axis=1)
    assert np.array_equal(np.unique(plan.index), np.nonzero(victims)[0])


def test_replica_sets_pairwise_distinct_under_churn():
    """Property test: across an add/remove/resize churn sequence, replica
    sets stay pairwise distinct and every planned movement equals the
    brute-force minimal set diff."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(
        st.tuples(st.sampled_from(["add", "remove", "resize"]),
                  st.floats(0.5, 2.0)),
        min_size=1,
        max_size=4,
    )

    @settings(max_examples=15, deadline=None)
    @given(ops=ops, seed=st.integers(0, 2**16))
    def run(ops, seed):
        rng = np.random.default_rng(seed)
        cluster = make_uniform_cluster(6)
        eng = cluster.engine
        ids = rng.integers(0, 2**32, 300, dtype=np.uint32)
        planner = MigrationPlanner(eng)
        next_node = 100
        R = 3
        for op, cap in ops:
            before = eng.place_replica_nodes(ids, R)
            v_from = cluster.version
            live = list(cluster.nodes)
            if op == "add" or len(live) <= R + 1:
                cluster.add_node(next_node, float(cap))
                next_node += 1
            elif op == "remove":
                cluster.remove_node(live[int(cap * 7) % len(live)])
            else:
                cluster.resize_node(live[int(cap * 5) % len(live)], float(cap))
            after = eng.place_replica_nodes(ids, R)
            # pairwise distinct under every membership state
            for row in after:
                assert len(set(row.tolist())) == R
            plan = planner.plan_replicas(ids, v_from, cluster.version, R)
            minimal = int(
                (~(after[:, :, None] == before[:, None, :]).any(axis=2)).sum()
            )
            assert plan.n_moves == minimal
            # every moved slot's destination really is its v+1 owner
            assert np.array_equal(plan.dst, after[plan.index, plan.slot])
            # and its source really was a v member that vacated
            assert np.array_equal(
                plan.src, before[plan.index, plan.src_slot]
            )

    run()


# ---------------------------------------------------------------------------
# Dual-version replica serving: invariant at every round, incl. rollback
# ---------------------------------------------------------------------------


def _assert_served_sets_valid(served, holdings, ids, R):
    for i, row in zip(ids, served):
        s = set(int(x) for x in row)
        assert len(s) == R  # pairwise distinct
        assert s <= holdings[int(i)], (
            f"id {int(i)}: served {s} not all holders {holdings[int(i)]}"
        )


@pytest.mark.parametrize("backend", ["numpy", "ref"])
def test_replica_window_routing_and_rollback(backend):
    """Every replica read returns R pairwise-distinct nodes that all hold
    the datum, at every round, through an add-node migration rolled back
    at half-drain; host and device read rules agree throughout."""
    R = 3
    cluster = make_uniform_cluster(6)
    eng = PlacementEngine(cluster, backend=backend)
    cluster._engine = eng
    ids = np.arange(1500, dtype=np.uint32)
    coord = ElasticCoordinator(cluster, ids, n_replicas=R)
    sets_v = coord.owners()
    holdings = {int(i): set(map(int, row)) for i, row in zip(ids, sets_v)}

    mig = coord.add_node_live(6, 1.0, egress=25)
    plan = mig.state.plan
    assert plan.n_replicas == R and plan.n_moves > 30
    uploads = eng.uploads

    def land_and_check(m):
        before = m.state.landed.copy()
        m.round()
        p = m.state.plan
        for r in np.nonzero(m.state.landed & ~before)[0]:
            k = int(p.ids[r])
            holdings[k].discard(int(p.src[r]))
            holdings[k].add(int(p.dst[r]))
        served = m.route_replicas(ids)
        _assert_served_sets_valid(served, holdings, ids, R)
        served_dev = np.asarray(m.route_replicas_device(jnp.asarray(ids)))
        assert np.array_equal(served, served_dev)

    while mig.state.n_pending > plan.n_moves // 2:
        land_and_check(mig)
    assert not mig.done

    rev = coord.rollback_live(mig)
    assert 6 not in cluster.nodes
    assert rev.state.plan.n_replicas == R
    # reverse slots are re-indexed into the reverse destination (= v) set
    assert np.array_equal(
        rev.state.plan.slot, mig.state.plan.src_slot[mig.state.landed]
    )
    while not rev.done:
        land_and_check(rev)

    for i in ids:
        assert holdings[int(i)] == set(map(int, sets_v[int(i)]))
    assert np.array_equal(coord.owners(), sets_v)
    assert eng.uploads == uploads  # the flap re-materialized NOTHING


def test_replica_live_plan_equals_atomic():
    ids = np.arange(1800, dtype=np.uint32)
    atomic = ElasticCoordinator(
        make_uniform_cluster(5), ids, n_replicas=2
    )
    a_plan = atomic.add_node(5, 1.0)
    live_coord = ElasticCoordinator(
        make_uniform_cluster(5), ids, n_replicas=2
    )
    live = live_coord.add_node_live(5, 1.0)
    assert live.state.plan.moves_dict() == a_plan.moves
    live.run()
    assert np.array_equal(atomic.owners(), live_coord.owners())
    # the owner table tracks the post-drain truth
    assert np.array_equal(
        live_coord.owners(), live_coord.engine.place_replica_nodes(ids, 2)
    )


def test_replica_coordinator_owner_tracking_through_events():
    cluster = make_uniform_cluster(6)
    ids = np.arange(1000, dtype=np.uint32)
    coord = ElasticCoordinator(cluster, ids, n_replicas=3)
    coord.add_node(7, 1.5)
    assert np.array_equal(coord.owners(), cluster.engine.place_replica_nodes(ids, 3))
    coord.remove_node(2)
    assert np.array_equal(coord.owners(), cluster.engine.place_replica_nodes(ids, 3))
    mig = coord.remove_node_live(3, ingress=50)
    assert np.all(mig.state.plan.src == 3)
    mig.run()
    assert np.array_equal(coord.owners(), cluster.engine.place_replica_nodes(ids, 3))


def test_driver_runs_replica_repairs_to_completion():
    """Failure detector -> throttled replica repair; DrainDriver.run()
    drains every queued repair."""
    cluster = make_uniform_cluster(6)
    ids = np.arange(900, dtype=np.uint32)
    coord = ElasticCoordinator(cluster, ids, n_replicas=2)
    t = {"now": 0.0}
    tracker = HeartbeatTracker(timeout=1.0, clock=lambda: t["now"])
    for nid in range(6):
        tracker.beat(nid)
    driver = MigrationDriver(
        tracker, lambda node: coord.remove_node_live(node, ingress=30)
    )
    t["now"] = 5.0
    for nid in range(4):
        tracker.beat(nid)
    t["now"] = 5.5
    assert set(driver.poll()) == {4, 5}
    assert not driver.done
    driver.run()  # the shared drain loop retires BOTH queued repairs
    assert driver.done and len(driver.completed) == 2
    assert all(m.done for m in driver.completed)
    assert np.array_equal(coord.owners(), cluster.engine.place_replica_nodes(ids, 2))


def test_router_replica_scale_migration():
    router = ReplicaRouter({i: 1.0 for i in range(5)})
    sessions = np.arange(1200, dtype=np.uint32)
    before = router.route_replicas(sessions, 2)
    mig = router.begin_scale_migration(
        sessions, add=(9, 1.0), n_replicas=2, egress=30
    )
    served = router.route_replicas_migrating(sessions, mig)
    # nothing landed yet: every served SET is exactly the v-side holders
    # (slot order follows the v+1 set, so compare as sets)
    assert np.array_equal(np.sort(served, axis=1), np.sort(before, axis=1))
    while not mig.done:
        mig.round()
        served = router.route_replicas_migrating(sessions, mig)
        dev = np.asarray(
            router.route_replicas_migrating_device(jnp.asarray(sessions), mig)
        )
        assert np.array_equal(served, dev)
        for row in served:
            assert len(set(row.tolist())) == 2
    assert np.array_equal(served, router.route_replicas(sessions, 2))


# ---------------------------------------------------------------------------
# Checkpoint store: per-slot live add + live repair
# ---------------------------------------------------------------------------


def test_store_live_repair_restores_at_every_round():
    store = AsuraCheckpointStore({i: 1.0 for i in range(6)}, n_replicas=3)
    mgr = CheckpointManager(store)
    rng = np.random.default_rng(13)
    tree = {"w": rng.standard_normal((2048, 2048)).astype(np.float32)}
    mgr.save(2, tree)
    store.fail_node(1)  # CRASH: no drain possible, sources are gone
    sm = store.begin_remove_node(1, ingress=2)
    plan = sm.live.state.plan
    assert plan.n_moves > 0 and np.all(plan.src == 1)
    rounds = 0
    while not sm.done:
        matrix = sm.round()
        for (_, d), c in matrix.items():
            assert c <= 2  # repair ingress budget per node per round
        out = mgr.restore(2, tree)  # degraded window: replicas fall back
        assert np.array_equal(out["w"], tree["w"])
        rounds += 1
        assert rounds < 500
    assert rounds > 1
    assert store._migration is None
    # repaired copies match the atomic placement exactly
    keys = np.fromiter(
        {k for n in store.nodes.values() for k in n.blobs}, dtype=np.uint32
    )
    for key, row in zip(keys, store.replicas_for(keys)):
        for nid in row:
            assert int(key) in store.nodes[int(nid)].blobs
    assert np.array_equal(mgr.restore(2, tree)["w"], tree["w"])


def test_store_live_add_accounts_every_replica_copy():
    """The per-slot plan accounts each replica copy as its own flow: the
    drained matrices sum to exactly the copies moved."""
    store = AsuraCheckpointStore({i: 1.0 for i in range(5)}, n_replicas=2)
    mgr = CheckpointManager(store)
    rng = np.random.default_rng(2)
    mgr.save(1, {"w": rng.standard_normal((2048, 2048)).astype(np.float32)})
    sm = store.begin_add_node(20, capacity=2.0, ingress=3)
    plan = sm.live.state.plan
    assert plan.n_replicas == 2
    matrices = sm.run()
    assert sum(sum(m.values()) for m in matrices) == plan.n_moves
    assert sm.copies_moved == plan.n_moves  # every row landed one copy
    assert np.all(plan.dst == 20)


def test_remove_numbers_batch_matches_scalar():
    cluster = make_uniform_cluster(9)
    ids = np.arange(120, dtype=np.uint32)
    for R in (1, 2, 3):
        batch = remove_numbers_batch(
            ids, cluster.seg_lengths(), cluster.seg_to_node(), R
        )
        engine_batch = cluster.engine.remove_numbers_batch(ids, R)
        assert np.array_equal(batch, engine_batch)
        for i in ids[:40]:
            want = remove_numbers(
                int(i), cluster.seg_lengths(), cluster.seg_to_node(), R
            )
            assert batch[int(i)].tolist() == want


def test_align_replica_sets_host_vs_device_twin():
    """The two alignment implementations (numpy spec and the jitted jnp
    twin) are bit-identical on random distinct-node sets."""
    from repro.kernels.ops import _align_replica_sets

    rng = np.random.default_rng(0)
    for R in (1, 2, 3):
        rows = []
        for _ in range(400):
            rows.append(
                (
                    rng.choice(12, size=R, replace=False),
                    rng.choice(12, size=R, replace=False),
                )
            )
        before = np.stack([b for b, _ in rows]).astype(np.int64)
        after = np.stack([a for _, a in rows]).astype(np.int64)
        moved, src, src_slot = align_replica_sets(before, after)
        m2, s2, d2, ss2 = (
            np.asarray(x)
            for x in _align_replica_sets(
                jnp.asarray(before, dtype=jnp.int32),
                jnp.asarray(after, dtype=jnp.int32),
                n_replicas=R,
            )
        )
        assert np.array_equal(moved, m2)
        assert np.array_equal(src, s2)
        assert np.array_equal(after, d2)
        assert np.array_equal(src_slot, ss2)
