"""Property-based tests (hypothesis) for ASURA's system invariants.

These are the paper's section 2 theorems checked mechanically over random
cluster histories:

  P1 (addition optimality)   adding a node moves data only onto it.
  P2 (removal optimality)    removing a node moves only its own data.
  P3 (range extension)       extending the generator ladder is a no-op.
  P4 (replication)           R replicas live on R distinct nodes.
  P5 (ADDITION NUMBER)       a datum is affected by a node addition iff the
                             added segment number equals its ADDITION NUMBER
                             (given smallest-free-number assignment order).
  P6 (REMOVE NUMBERS)        a datum leaves a removed node iff one of its
                             REMOVE NUMBERS is a segment of that node.
  P7 (determinism)           placement depends only on (id, table).
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # degrade, don't abort collection
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import make_cluster
from repro.core.asura import (
    DEFAULT_PARAMS,
    _AsuraStream,
    _upper_bound,
    addition_number,
    lengths_to_u32,
    place_batch,
    place_replicas_batch,
    remove_numbers,
)

capacities = st.lists(
    st.floats(min_value=0.2, max_value=3.0, allow_nan=False), min_size=2, max_size=12
)
datum_ids = st.integers(min_value=0, max_value=2**32 - 1)


@settings(max_examples=30, deadline=None)
@given(caps=capacities, new_cap=st.floats(min_value=0.2, max_value=3.0))
def test_p1_addition_moves_only_to_new_node(caps, new_cap):
    c = make_cluster(caps)
    ids = np.arange(2000, dtype=np.uint32)
    before = c.place_nodes(ids)
    new_id = max(c.nodes) + 1
    c.add_node(new_id, new_cap)
    after = c.place_nodes(ids)
    moved = before != after
    assert np.all(after[moved] == new_id)


@settings(max_examples=30, deadline=None)
@given(caps=capacities, victim_idx=st.integers(min_value=0, max_value=11))
def test_p2_removal_moves_only_victims_data(caps, victim_idx):
    c = make_cluster(caps)
    victim = sorted(c.nodes)[victim_idx % len(c.nodes)]
    ids = np.arange(2000, dtype=np.uint32)
    before = c.place_nodes(ids)
    c.remove_node(victim)
    after = c.place_nodes(ids)
    moved = before != after
    assert np.all(before[moved] == victim)
    assert moved.sum() == (before == victim).sum()


@settings(max_examples=20, deadline=None)
@given(caps=capacities, datum=datum_ids, extra=st.integers(min_value=1, max_value=6))
def test_p3_range_extension_noop(caps, datum, extra):
    c = make_cluster(caps)
    lengths = c.seg_lengths()
    len32 = lengths_to_u32(lengths)
    n_segs = len(len32)
    top = DEFAULT_PARAMS.level_for(_upper_bound(lengths))

    def place_at(t):
        stream = _AsuraStream(datum, t, DEFAULT_PARAMS)
        while True:
            k, f = stream.next()
            if k < n_segs and f < int(len32[k]):
                return k

    assert place_at(top) == place_at(top + extra)


@settings(max_examples=20, deadline=None)
@given(caps=st.lists(st.floats(min_value=0.5, max_value=2.0), min_size=4, max_size=10))
def test_p4_replicas_distinct_nodes(caps):
    c = make_cluster(caps)
    reps = c.place_replicas(np.arange(200, dtype=np.uint32), 3)
    for row in reps:
        assert len(set(row.tolist())) == 3


@settings(max_examples=15, deadline=None)
@given(n_nodes=st.integers(min_value=3, max_value=9))
def test_p5_addition_number_exact_full_segments(n_nodes):
    """P5 (paper-literal AN == f rule): with full-length segments, after a
    single-segment addition at the smallest free number f, every datum that
    moved had ADDITION NUMBER == f.

    The paper's == rule is exact only for full-length segment tables: with
    fractional segments a datum's smallest anterior number can fall in an
    occupied segment's *miss region* (frac >= length), masking a mover whose
    capturing number points at a larger free segment.  The framework's
    rebalancer therefore uses the sound AN <= f rule for heterogeneous
    capacity tables (test_p5b below); see DESIGN.md section 7.
    """
    c = make_cluster([1.0] * n_nodes)
    c.remove_node(1)  # frees segment 1
    ids = np.arange(600, dtype=np.uint32)
    lengths, node_of = c.seg_lengths(), c.seg_to_node()
    before = c.place_nodes(ids)
    ans = np.array([addition_number(int(i), lengths, node_of) for i in ids])
    new_id = max(c.nodes) + 1
    new_segs = c.add_node(new_id, 1.0)
    assert new_segs == [1]
    after = c.place_nodes(ids)
    moved = before != after
    assert np.all(np.isin(ans[moved], new_segs))


@settings(max_examples=15, deadline=None)
@given(
    caps=st.lists(st.floats(min_value=0.3, max_value=2.0), min_size=3, max_size=8),
    new_cap=st.floats(min_value=0.3, max_value=0.95),
)
def test_p5b_addition_number_leq_rule_sound(caps, new_cap):
    """P5b (sound rule for fractional segments): every mover has AN <= f.

    floor(smallest unused anterior) <= floor(capturing anterior) == f, so the
    <=-rule check set provably contains all movers for ANY capacity mix."""
    c = make_cluster(caps)
    c.remove_node(1)
    ids = np.arange(600, dtype=np.uint32)
    lengths, node_of = c.seg_lengths(), c.seg_to_node()
    before = c.place_nodes(ids)
    ans = np.array([addition_number(int(i), lengths, node_of) for i in ids])
    new_id = max(c.nodes) + 1
    new_segs = c.add_node(new_id, new_cap)
    assert len(new_segs) == 1
    after = c.place_nodes(ids)
    moved = before != after
    assert np.all(ans[moved] <= new_segs[0])


@settings(max_examples=15, deadline=None)
@given(
    caps=st.lists(st.floats(min_value=0.5, max_value=2.0), min_size=5, max_size=9),
    victim_idx=st.integers(min_value=0, max_value=8),
)
def test_p6_remove_numbers_exact(caps, victim_idx):
    c = make_cluster(caps)
    victim = sorted(c.nodes)[victim_idx % len(c.nodes)]
    ids = np.arange(300, dtype=np.uint32)
    lengths, node_of = c.seg_lengths(), c.seg_to_node()
    reps_before = c.place_replicas(ids, 2)
    rns = [remove_numbers(int(i), lengths, node_of, 2) for i in ids]
    victim_segs = set(c.nodes[victim].segments)
    c.remove_node(victim)
    reps_after = c.place_replicas(ids, 2)
    for i in range(len(ids)):
        lost = victim in set(reps_before[i].tolist())
        flagged = bool(victim_segs & set(rns[i]))
        # REMOVE NUMBERS are exactly the floors of replica-selecting numbers,
        # so the datum had a replica on the victim iff a RN names one of the
        # victim's segments.
        assert lost == flagged
        if not lost:
            assert list(reps_before[i]) == list(reps_after[i])


@settings(max_examples=20, deadline=None)
@given(datum=datum_ids, caps=capacities)
def test_p7_determinism(datum, caps):
    c = make_cluster(caps)
    a = place_batch(np.array([datum], dtype=np.uint32), c.seg_lengths())[0]
    b = place_batch(np.array([datum], dtype=np.uint32), c.seg_lengths())[0]
    assert a == b


@settings(max_examples=10, deadline=None)
@given(caps=capacities)
def test_replica_batch_matches_scalar(caps):
    from repro.core.asura import place_replicas_scalar

    c = make_cluster(caps)
    r = min(2, len(c.nodes))
    ids = np.arange(50, dtype=np.uint32)
    batch = place_replicas_batch(ids, c.seg_lengths(), c.seg_to_node(), r)
    for i in ids:
        scalar = place_replicas_scalar(int(i), c.seg_lengths(), c.seg_to_node(), r)
        assert list(batch[i]) == list(scalar)
