"""Tests for the paper's comparison baselines (sections I, III, IV)."""

import numpy as np

from repro.core import ConsistentHashRing, StrawBucket


class TestConsistentHashing:
    def test_deterministic(self):
        ring = ConsistentHashRing(range(10), virtual_nodes=50)
        ids = np.arange(1000, dtype=np.uint32)
        assert np.array_equal(ring.place(ids), ring.place(ids))

    def test_all_nodes_used(self):
        ring = ConsistentHashRing(range(20), virtual_nodes=100)
        owners = ring.place(np.arange(50_000, dtype=np.uint32))
        assert set(owners.tolist()) == set(range(20))

    def test_removal_moves_only_victims_data(self):
        """CH's own optimal-movement property (paper section I)."""
        nodes = list(range(12))
        ring = ConsistentHashRing(nodes, virtual_nodes=64)
        ids = np.arange(20_000, dtype=np.uint32)
        before = ring.place(ids)
        victim = 5
        ring2 = ConsistentHashRing([n for n in nodes if n != victim], virtual_nodes=64)
        after = ring2.place(ids)
        moved = before != after
        assert np.all(before[moved] == victim)

    def test_more_virtual_nodes_more_uniform(self):
        """Paper Figs. 6-8: uniformity improves with virtual nodes."""
        ids = np.arange(200_000, dtype=np.uint32)

        def maxvar(v):
            ring = ConsistentHashRing(range(10), virtual_nodes=v)
            counts = np.bincount(ring.place(ids), minlength=10)
            return (counts.max() - counts.mean()) / counts.mean()

        assert maxvar(1000) < maxvar(10)

    def test_memory_is_8nv(self):
        ring = ConsistentHashRing(range(100), virtual_nodes=100)
        assert ring.memory_bytes() == 8 * 100 * 100


class TestStrawBucket:
    def test_deterministic(self):
        straw = StrawBucket(range(8))
        ids = np.arange(1000, dtype=np.uint32)
        assert np.array_equal(straw.place(ids), straw.place(ids))

    def test_near_uniform(self):
        straw = StrawBucket(range(10))
        counts = np.bincount(
            straw.place(np.arange(100_000, dtype=np.uint32)), minlength=10
        )
        maxvar = (counts.max() - counts.mean()) / counts.mean()
        assert maxvar < 0.05

    def test_optimal_movement_on_removal(self):
        """Straw's max-hash property: removing a node only moves its data."""
        nodes = list(range(10))
        straw = StrawBucket(nodes)
        ids = np.arange(20_000, dtype=np.uint32)
        before = straw.place(ids)
        victim = 3
        straw2 = StrawBucket([n for n in nodes if n != victim])
        after = straw2.place(ids)
        moved = before != after
        assert np.all(before[moved] == victim)

    def test_optimal_movement_on_addition(self):
        nodes = list(range(10))
        straw = StrawBucket(nodes)
        ids = np.arange(20_000, dtype=np.uint32)
        before = straw.place(ids)
        straw2 = StrawBucket(nodes + [10])
        after = straw2.place(ids)
        moved = before != after
        assert np.all(after[moved] == 10)

    def test_capacity_weighting(self):
        straw = StrawBucket(range(3), weights=[2.0, 1.0, 1.0])
        nodes = straw.place(np.arange(100_000, dtype=np.uint32))
        frac0 = (nodes == 0).mean()
        assert 0.45 < frac0 < 0.55  # 2/(2+1+1)

    def test_replicas_distinct(self):
        straw = StrawBucket(range(6))
        reps = straw.place_replicas(np.arange(500, dtype=np.uint32), 3)
        for row in reps:
            assert len(set(row.tolist())) == 3

    def test_memory_is_8n(self):
        straw = StrawBucket(range(64))
        assert straw.memory_bytes() == 8 * 64
