"""PlacementEngine tests: artifact caching, replica kernels, consumer paths.

Covers the ISSUE acceptance criteria directly:
  * cache invalidation on Cluster.version bump (upload counter),
  * place_replicas_pallas (interpret) bit-identical to place_replicas_scalar
    for R in {1, 2, 3} on mixed-capacity tables,
  * zero table re-uploads across repeated ReplicaRouter.route /
    Cluster.place_nodes calls at a fixed version,
  * the unified exact-integer tail fallback across all backends,
  * the vectorized ADDITION NUMBER trace vs the scalar oracle.
"""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.core import (
    Cluster,
    PlacementEngine,
    make_cluster,
    make_uniform_cluster,
)
from repro.core.asura import (
    AsuraParams,
    addition_number,
    addition_numbers_batch,
    place_batch,
    place_replicas_batch,
    place_replicas_scalar,
)
from repro.kernels.ops import (
    asura_place,
    asura_place_replicas,
    node_table_prep,
    place_replicas_on_table,
    table_prep,
)
from repro.runtime import ElasticCoordinator
from repro.serve import ReplicaRouter

MIXED = [0.3, 1.7, 2.0, 0.9, 1.0, 0.5]


# ---------------------------------------------------------------------------
# Table artifact caching / invalidation
# ---------------------------------------------------------------------------


class TestArtifactCache:
    def test_one_upload_across_repeated_calls(self):
        c = make_cluster(MIXED)
        ids = np.arange(256, dtype=np.uint32)
        for _ in range(5):
            c.place_nodes(ids)
            c.place_batch(ids)
            c.place_replicas(ids[:32], 2)
        assert c.engine.uploads == 1

    def test_version_bump_invalidates(self):
        c = make_cluster(MIXED)
        ids = np.arange(128, dtype=np.uint32)
        c.place_nodes(ids)
        assert c.engine.uploads == 1
        c.add_node(50, 1.0)  # STEP-1 mutation bumps the version
        c.place_nodes(ids)
        assert c.engine.uploads == 2
        c.resize_node(50, 2.5)
        c.place_batch(ids)
        assert c.engine.uploads == 3
        c.remove_node(50)
        c.place_replicas(ids[:16], 2)
        assert c.engine.uploads == 4

    def test_artifact_matches_cluster_tables(self):
        c = make_cluster(MIXED)
        art = c.engine.artifact()
        assert art.version == c.version
        assert art.n_segs == len(c.seg_lengths())
        assert np.array_equal(art.node_of, c.seg_to_node())
        # same object returned while the version holds
        assert c.engine.artifact() is art

    def test_invalidate_forces_rebuild(self):
        c = make_cluster(MIXED)
        c.engine.artifact()
        c.engine.invalidate()
        c.engine.artifact()
        assert c.engine.uploads == 2

    def test_version_flap_hits_cache(self):
        """A router flapping between two live cluster versions (rollback,
        A/B drain) must not re-materialize the table on every alternation:
        the engine keeps the most-recent versions cached."""
        c_new = make_cluster(MIXED)
        c_old = Cluster.from_json(c_new.to_json())  # snapshot at version N
        c_new.add_node(50, 1.0)  # version N+1
        eng = PlacementEngine(c_new)
        ids = np.arange(256, dtype=np.uint32)
        for _ in range(6):  # flap: N+1, N, N+1, N, ...
            eng.cluster = c_new
            want_new = place_batch(ids, c_new.seg_lengths())
            assert_allclose(eng.place(ids), want_new, atol=0)
            eng.cluster = c_old
            want_old = place_batch(ids, c_old.seg_lengths())
            assert_allclose(eng.place(ids), want_old, atol=0)
        assert eng.uploads == 2  # one materialization per distinct version

    def test_cache_evicts_oldest_beyond_capacity(self):
        c = make_cluster(MIXED)
        eng = PlacementEngine(c, cache_versions=2)
        ids = np.arange(64, dtype=np.uint32)
        snapshots = []
        for i in range(3):
            snapshots.append(Cluster.from_json(c.to_json()))
            eng.place(ids)
            c.add_node(100 + i, 1.0)
        eng.place(ids)
        assert eng.uploads == 4
        # oldest snapshot fell out of the 2-deep cache -> one more rebuild
        eng.cluster = snapshots[0]
        eng.place(ids)
        assert eng.uploads == 5

    def test_rejects_unknown_backend(self):
        with pytest.raises(ValueError):
            PlacementEngine(make_cluster(MIXED), backend="tpuv7")


# ---------------------------------------------------------------------------
# Engine placement == established oracles, across backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "ref", "pallas"])
class TestBackendEquivalence:
    def test_place_matches_numpy_batch(self, backend):
        c = make_cluster(MIXED)
        eng = PlacementEngine(c, backend=backend)
        ids = (np.arange(700, dtype=np.uint64) * 2654435761 % (2**32)).astype(
            np.uint32
        )
        want = place_batch(ids, c.seg_lengths())
        assert_allclose(eng.place(ids), want, atol=0)

    def test_place_nodes_matches(self, backend):
        c = make_cluster(MIXED)
        eng = PlacementEngine(c, backend=backend)
        ids = np.arange(512, dtype=np.uint32)
        want = c.seg_to_node()[place_batch(ids, c.seg_lengths())]
        assert_allclose(eng.place_nodes(ids), want, atol=0)

    def test_replicas_match_numpy_batch(self, backend):
        c = make_cluster(MIXED)
        eng = PlacementEngine(c, backend=backend)
        ids = np.arange(300, dtype=np.uint32)
        want = place_replicas_batch(ids, c.seg_lengths(), c.seg_to_node(), 3)
        assert_allclose(eng.place_replicas(ids, 3), want, atol=0)

    def test_forced_tail_unified_across_backends(self, backend):
        """max_draws=0 pushes EVERY lane through the tail fallback; the
        exact-integer spec must agree bit-for-bit on all backends."""
        params = AsuraParams(max_draws=0)
        c = make_cluster(MIXED, params=params)
        eng = PlacementEngine(c, backend=backend)
        ids = np.arange(640, dtype=np.uint32)
        want = place_batch(ids, c.seg_lengths(), params)
        got = eng.place(ids)
        assert_allclose(got, want, atol=0)
        # fallback is total and lands only on occupied segments
        assert (c.seg_lengths()[got] > 0).all()


def test_forced_tail_exact_128bit_scaling():
    """Regression: h * total_mass needs up to 95 bits.  On a 100-node table
    a uint64 product wraps and dumps every fallback lane on segment 0; the
    two-half evaluation must match exact Python big-int arithmetic."""
    from repro.core.asura import lengths_to_u32
    from repro.core.rng import draw_u32_scalar

    params = AsuraParams(max_draws=0)
    c = make_uniform_cluster(100, params=params)
    ids = np.arange(20_000, dtype=np.uint32)
    got = place_batch(ids, c.seg_lengths(), params)
    len32 = lengths_to_u32(c.seg_lengths())
    cum = np.cumsum(len32.astype(np.uint64))
    top = c.engine.artifact().top_level
    for i in (0, 1, 777, 19_999):  # exact big-int oracle, spot-checked
        h = draw_u32_scalar(int(ids[i]), top + 1, 0)
        u = (h * int(cum[-1])) >> 32
        assert got[i] == int(np.searchsorted(cum, np.uint64(u), side="right"))
    # uniform over occupied mass: every segment is reachable, none dominates
    counts = np.bincount(got, minlength=100)
    assert (counts > 0).all()
    assert counts.max() < 3 * counts.mean()


def test_forced_tail_partial_convergence():
    """max_draws=1 leaves a real mixed population of converged and
    tail-resolved lanes; kernel and NumPy paths must still agree."""
    params = AsuraParams(max_draws=1)
    c = make_cluster([0.1, 0.2, 0.05], params=params)  # low hit rate
    ids = np.arange(2048, dtype=np.uint32)
    want = place_batch(ids, c.seg_lengths(), params)
    got = np.asarray(asura_place(ids, c.seg_lengths(), params, use_pallas=True))
    assert_allclose(got, want, atol=0)
    got = np.asarray(asura_place(ids, c.seg_lengths(), params, use_pallas=False))
    assert_allclose(got, want, atol=0)


# ---------------------------------------------------------------------------
# Replica kernel vs the scalar oracle (lane-by-lane)
# ---------------------------------------------------------------------------


class TestReplicaKernel:
    @pytest.mark.parametrize("n_replicas", [1, 2, 3])
    def test_pallas_matches_scalar_lane_by_lane(self, n_replicas):
        c = make_cluster(MIXED)
        ids = (np.arange(64, dtype=np.uint64) * 2654435761 % (2**32)).astype(
            np.uint32
        )
        got = np.asarray(
            asura_place_replicas(
                ids, c.seg_lengths(), c.seg_to_node(), n_replicas, use_pallas=True
            )
        )
        for lane, datum in enumerate(ids):
            want = place_replicas_scalar(
                int(datum), c.seg_lengths(), c.seg_to_node(), n_replicas
            )
            assert got[lane].tolist() == want, (lane, datum)

    def test_replicas_on_distinct_nodes(self):
        c = make_cluster([1.5, 1.0, 0.5, 2.0, 1.0])
        reps = c.place_replicas(np.arange(400, dtype=np.uint32), 3)
        for row in reps:
            assert len(set(row.tolist())) == 3

    def test_primary_column_is_plain_placement(self):
        c = make_cluster(MIXED)
        ids = np.arange(256, dtype=np.uint32)
        reps = c.engine.place_replicas(ids, 3)
        assert_allclose(reps[:, 0], c.engine.place(ids), atol=0)

    def test_on_table_entry_point(self):
        c = make_cluster(MIXED)
        ids = np.arange(128, dtype=np.uint32)
        len32, top = table_prep(c.seg_lengths())
        node_of = node_table_prep(c.seg_to_node())
        got = place_replicas_on_table(ids, len32, node_of, 2, top_level=top)
        want = place_replicas_batch(ids, c.seg_lengths(), c.seg_to_node(), 2)
        assert_allclose(got, want, atol=0)

    def test_nonconvergence_raises(self):
        c = make_cluster([1.0, 1.0])  # only 2 distinct nodes
        with pytest.raises(RuntimeError):
            asura_place_replicas(
                np.arange(8, dtype=np.uint32), c.seg_lengths(), c.seg_to_node(), 3
            )


# ---------------------------------------------------------------------------
# Consumer round-trips through the engine
# ---------------------------------------------------------------------------


class TestConsumers:
    def test_router_zero_reuploads_at_fixed_version(self):
        router = ReplicaRouter({i: 1.0 for i in range(5)})
        ids = np.arange(4000, dtype=np.uint32)
        for _ in range(4):
            router.route(ids)
        assert router.table_uploads == 1

    def test_router_scale_event_uploads_once_per_version(self):
        router = ReplicaRouter({i: 1.0 for i in range(5)})
        ids = np.arange(2000, dtype=np.uint32)
        router.route(ids)
        router.plan_scale_event(ids, add=(9, 1.0))  # one version bump
        router.route(ids)
        router.route(ids)
        assert router.table_uploads == 2

    def test_router_replica_fanout(self):
        router = ReplicaRouter({i: 1.0 for i in range(6)})
        fan = router.route_replicas(np.arange(300), 2)
        assert fan.shape == (300, 2)
        assert (fan[:, 0] != fan[:, 1]).all()
        assert_allclose(fan[:, 0], router.route(np.arange(300)), atol=0)

    def test_coordinator_shares_cluster_engine(self):
        cluster = make_uniform_cluster(6)
        ids = np.arange(800, dtype=np.uint32)
        coord = ElasticCoordinator(cluster, ids)
        assert coord.engine is cluster.engine
        before = cluster.place_nodes(ids)
        plan = coord.add_node(6, 1.0)
        after = cluster.place_nodes(ids)
        moved = np.nonzero(before != after)[0]
        assert set(plan.moves) == {int(ids[i]) for i in moved}
        # init placement + AN trace at v0, then one rebuild for the new node
        assert cluster.engine.uploads == 2

    def test_addition_numbers_batch_matches_scalar(self):
        c = make_cluster(MIXED)
        ids = (np.arange(150, dtype=np.uint64) * 40503 % (2**32)).astype(np.uint32)
        got = addition_numbers_batch(ids, c.seg_lengths(), c.seg_to_node())
        for i, datum in enumerate(ids):
            assert got[i] == addition_number(
                int(datum), c.seg_lengths(), c.seg_to_node()
            ), datum

    def test_addition_numbers_batch_replicated(self):
        c = make_cluster([1.0] * 8)
        ids = np.arange(60, dtype=np.uint32)
        got = addition_numbers_batch(ids, c.seg_lengths(), c.seg_to_node(), 2)
        for i, datum in enumerate(ids):
            assert got[i] == addition_number(
                int(datum), c.seg_lengths(), c.seg_to_node(), 2
            ), datum

    def test_json_round_trip_preserves_placement(self):
        c = make_cluster(MIXED)
        ids = np.arange(500, dtype=np.uint32)
        clone = Cluster.from_json(c.to_json())
        assert_allclose(clone.place_nodes(ids), c.place_nodes(ids), atol=0)
        assert clone.engine.uploads == 1
