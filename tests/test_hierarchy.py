"""Hierarchical (failure-domain-aware) ASURA tests."""

import numpy as np
import pytest

from repro.core.hierarchy import HierarchicalCluster


def _mk(domains=4, nodes_per=3, cap=1.0):
    h = HierarchicalCluster()
    nid = 0
    for d in range(domains):
        for _ in range(nodes_per):
            h.add_node(d, nid, cap)
            nid += 1
    return h


class TestPlacement:
    def test_deterministic(self):
        h = _mk()
        ids = np.arange(500)
        assert np.array_equal(h.place(ids), h.place(ids))

    def test_domain_load_proportional_to_capacity(self):
        h = HierarchicalCluster()
        h.add_node(0, 0, 2.0)
        h.add_node(0, 1, 2.0)  # domain 0: cap 4
        h.add_node(1, 2, 1.0)
        h.add_node(1, 3, 1.0)  # domain 1: cap 2
        placed = h.place(np.arange(60_000))
        frac0 = (placed[:, 0] == 0).mean()
        assert abs(frac0 - 4 / 6) < 0.01

    def test_node_load_within_domain(self):
        h = HierarchicalCluster()
        h.add_node(0, 0, 3.0)
        h.add_node(0, 1, 1.0)
        placed = h.place(np.arange(40_000))
        frac_n0 = (placed[:, 1] == 0).mean()
        assert abs(frac_n0 - 0.75) < 0.01

    def test_node_belongs_to_its_domain(self):
        h = _mk(domains=3, nodes_per=2)
        placed = h.place(np.arange(5_000))
        node_to_dom = {}
        for d, dom in h.domains.items():
            for n in dom.node_ids():
                node_to_dom[n] = d
        for dom_id, node_id in placed:
            assert node_to_dom[node_id] == dom_id


class TestFailureDomains:
    def test_replicas_on_distinct_domains(self):
        h = _mk(domains=5, nodes_per=2)
        reps = h.place_replicas(np.arange(2_000), 3)
        for row in reps:
            assert len(set(row[:, 0].tolist())) == 3  # distinct domains
        # whole-domain loss keeps >= 2 replicas of every datum
        for victim in range(5):
            surviving = (reps[:, :, 0] != victim).sum(axis=1)
            assert surviving.min() >= 2

    def test_too_few_domains_raises(self):
        h = _mk(domains=2, nodes_per=4)
        with pytest.raises(RuntimeError):
            h.place_replicas(np.arange(10), 3)


class TestMovementOptimality:
    def test_node_change_stays_within_domain(self):
        h = _mk(domains=4, nodes_per=3)
        ids = np.arange(20_000)
        before = h.place(ids)
        h.add_node(2, 99, 1.0)  # grow domain 2
        after = h.place(ids)
        moved = ~(before == after).all(axis=1)
        # domain assignment may shift only toward domain 2 (its capacity grew)
        dom_changed = before[:, 0] != after[:, 0]
        assert np.all(after[dom_changed, 0] == 2)
        # data in untouched domains (and not moving to 2) never move
        untouched = (before[:, 0] != 2) & ~dom_changed
        assert not moved[untouched].any()
        # within domain 2, movers go to the new node or came from outside
        inside_movers = moved & (before[:, 0] == 2) & (after[:, 0] == 2)
        assert np.all(after[inside_movers, 1] == 99)

    def test_node_removal_moves_only_its_data(self):
        h = _mk(domains=3, nodes_per=3)
        ids = np.arange(20_000)
        before = h.place(ids)
        victim_node = 4  # lives in domain 1
        h.remove_node(1, victim_node)
        after = h.place(ids)
        moved = ~(before == after).all(axis=1)
        # movers either held the victim node, or shifted domain because
        # domain 1's capacity shrank (level-1 resize) -- and those shifts
        # only move data OUT of domain 1
        for i in np.nonzero(moved)[0]:
            if before[i, 0] == after[i, 0]:
                assert before[i, 1] == victim_node
            else:
                assert before[i, 0] == 1

    def test_domain_removal_moves_only_its_data(self):
        h = _mk(domains=4, nodes_per=2)
        ids = np.arange(15_000)
        before = h.place(ids)
        h.remove_domain(3)
        after = h.place(ids)
        moved = ~(before == after).all(axis=1)
        assert np.all(before[moved, 0] == 3)

    def test_independent_domains_unaffected_by_each_other(self):
        """Salting: node changes in one domain never reshuffle another."""
        h = _mk(domains=3, nodes_per=3)
        ids = np.arange(10_000)
        before = h.place(ids)
        h.add_node(0, 50, 0.5)
        after = h.place(ids)
        other = before[:, 0] != 0
        same_dom = before[other, 0] == after[other, 0]
        # any datum that stayed in its (non-0) domain kept its node
        kept = before[other][same_dom], after[other][same_dom]
        assert np.array_equal(kept[0], kept[1])
