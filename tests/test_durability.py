"""Event-driven durability simulation (runtime/durability.py): the
deterministic trace, the repair wiring through the real MigrationDriver /
ThrottledMover stack, re-failure of repaired nodes, and the headline --
domain-aware placement strictly beats flat R-way under identical
correlated-failure traces at ~equal movement cost."""

import numpy as np
import pytest

from repro.runtime.durability import (
    SECONDS_PER_YEAR,
    DurabilitySimulator,
    FailureEvent,
    compare_policies,
    failure_trace,
    movement_on_node_add,
)

TOPO = {d: {d * 4 + i: 1.0 for i in range(4)} for d in range(6)}
NODE_DOMAIN = {n: d for d, members in TOPO.items() for n in members}


# ---------------------------------------------------------------------------
# The deterministic failure trace
# ---------------------------------------------------------------------------


def test_trace_deterministic_sorted_and_bounded():
    kw = dict(years=5.0, mttf_node_years=3.0, mttf_domain_years=15.0, seed=3)
    t1 = failure_trace(NODE_DOMAIN, **kw)
    t2 = failure_trace(NODE_DOMAIN, **kw)
    assert t1 == t2  # pure function of (topology, rates, seed)
    times = [e.time for e in t1]
    assert times == sorted(times)
    assert all(0.0 <= t < 5.0 * SECONDS_PER_YEAR for t in times)
    kinds = {e.kind for e in t1}
    assert kinds <= {"node", "domain"}
    # the horizon is long enough that both kinds actually occur
    assert "node" in kinds and "domain" in kinds
    # node targets are node ids, domain targets are domain ids
    for e in t1:
        pool = NODE_DOMAIN if e.kind == "node" else set(NODE_DOMAIN.values())
        assert e.target in pool


def test_trace_changes_with_seed():
    kw = dict(years=5.0, mttf_node_years=3.0, mttf_domain_years=15.0)
    assert failure_trace(NODE_DOMAIN, seed=1, **kw) != failure_trace(
        NODE_DOMAIN, seed=2, **kw
    )


# ---------------------------------------------------------------------------
# Single-simulator behavior: repairs restore redundancy, losses are final
# ---------------------------------------------------------------------------


def _sim(owners, **kw):
    return DurabilitySimulator(np.asarray(owners), NODE_DOMAIN, **kw)


def test_single_node_failure_repairs_without_loss():
    # 6 objects spread over distinct nodes, R=2: one node failure never
    # kills an object, and its held rows are re-replicated in full
    owners = np.array([[0, 4], [1, 5], [2, 6], [0, 8], [1, 9], [3, 4]])
    sim = _sim(owners)
    events = [FailureEvent(3600.0, "node", 0)]
    report = sim.run(events, years=1.0)
    assert report.objects_lost == 0
    assert report.loss_incidents == 0
    assert report.repairs_completed == 1
    assert report.rows_repaired == int((owners == 0).sum())
    assert report.bytes_repaired == report.rows_repaired * sim.bytes_per_row
    assert np.all(sim.copy_ok)  # full redundancy restored


def test_simultaneous_loss_of_all_copies_is_final():
    owners = np.array([[0, 1], [2, 3]])
    sim = _sim(owners)
    # both copies of object 0 die in one correlated instant -> lost for
    # good, and the repair of object 1's copies never resurrects it
    events = [
        FailureEvent(3600.0, "node", 0),
        FailureEvent(3600.0, "node", 1),
        FailureEvent(7200.0, "node", 2),
    ]
    report = sim.run(events, years=1.0)
    assert report.objects_lost == 1
    assert report.loss_incidents == 1
    assert bool(sim.lost[0]) and not bool(sim.lost[1])
    assert np.all(sim.copy_ok[1])


def test_staggered_failures_survive_when_repair_lands_between():
    # same two nodes, but the second failure arrives a week later: the
    # repair window is minutes, so object 0 keeps a live copy throughout
    owners = np.array([[0, 1], [2, 3]])
    sim = _sim(owners)
    events = [
        FailureEvent(3600.0, "node", 0),
        FailureEvent(3600.0 + 7 * 86_400.0, "node", 1),
    ]
    report = sim.run(events, years=1.0)
    assert report.objects_lost == 0
    assert report.repairs_completed == 2


def test_repaired_node_refails_and_is_repaired_again():
    """A node's SECOND failure must be re-detected (the detector re-arms on
    recovery) -- the regression that motivated FailureDetector.clear."""
    owners = np.array([[0, 4], [0, 5], [1, 6]])
    sim = _sim(owners)
    events = [
        FailureEvent(3600.0, "node", 0),
        FailureEvent(30 * 86_400.0, "node", 0),  # same node, a month later
    ]
    report = sim.run(events, years=1.0)
    assert report.node_failures == 2
    assert report.repairs_completed == 2
    assert report.objects_lost == 0
    assert np.all(sim.copy_ok)
    # each repair re-replicated node 0's two held rows
    assert report.rows_repaired == 4


def test_domain_event_kills_every_member_node():
    # domain 0 = nodes {0..3}: object 0 lives entirely inside it, object 1
    # keeps a copy on node 4 (domain 1)
    owners = np.array([[0, 1], [2, 4]])
    sim = _sim(owners)
    report = sim.run([FailureEvent(3600.0, "domain", 0)], years=1.0)
    assert report.domain_failures == 1
    assert report.objects_lost == 1  # object 0: domain 0 held all copies
    assert not bool(sim.lost[1])  # object 1 had a copy outside domain 0


def test_serialized_repair_queue_is_tracked():
    owners = np.tile(np.arange(8).reshape(-1, 1), (1, 2)) % 4 + np.array([[0, 4]])
    sim = _sim(owners)
    report = sim.run([FailureEvent(3600.0, "domain", 0)], years=1.0)
    # all 4 member nodes die at once -> one in-flight + queued repairs
    assert report.max_repair_queue == 4
    assert report.repairs_completed == 4


# ---------------------------------------------------------------------------
# The headline comparison (the benchmark's core)
# ---------------------------------------------------------------------------


def test_compare_policies_headline_and_determinism():
    kw = dict(
        n_objects=4_000, n_replicas=3, years=10.0,
        mttf_node_years=3.0, mttf_domain_years=15.0, seed=7,
    )
    reports = compare_policies(TOPO, **kw)
    flat, hier = reports["flat"], reports["hier"]
    # identical traces: both policies saw the same failure schedule
    assert (flat.node_failures, flat.domain_failures) == (
        hier.node_failures, hier.domain_failures,
    )
    assert flat.domain_failures > 0  # correlated outages actually occurred
    # the headline: domain awareness strictly wins on durability ...
    assert hier.objects_lost < flat.objects_lost
    assert hier.loss_incidents < flat.loss_incidents
    assert hier.objects_lost == 0  # R distinct domains, one event each
    # ... at comparable repair traffic (same trace, same object mass)
    assert abs(hier.rows_repaired - flat.rows_repaired) < 0.1 * flat.rows_repaired
    # deterministic replay, end to end
    again = compare_policies(TOPO, **kw)
    assert again["flat"] == flat
    assert again["hier"] == hier


def test_movement_on_node_add_parity():
    moved = movement_on_node_add(TOPO, n_objects=4_000, n_replicas=3)
    # both policies move a small minimal fraction (1 new node among 24+),
    # the two-level policy within ~2x of flat -- domain awareness does not
    # give back ASURA's minimal-movement property
    assert 0.0 < moved["flat"] < 0.25
    assert 0.0 < moved["hier"] < 0.25
    assert moved["hier"] < 2.0 * moved["flat"] + 0.02
