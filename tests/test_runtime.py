"""Integration tests: data pipeline, checkpointing, elastic runtime."""

import numpy as np
import pytest

from repro.checkpoint import AsuraCheckpointStore, CheckpointManager
from repro.core import Cluster, make_uniform_cluster
from repro.data import DataPipeline, ShardedDataset
from repro.runtime import (
    ElasticCoordinator,
    FailureDetector,
    HeartbeatTracker,
    StragglerMitigator,
)


class TestDataPipeline:
    def _mk(self, n_hosts=4, n_shards=64):
        cluster = make_uniform_cluster(n_hosts)
        ds = ShardedDataset(n_shards=n_shards, tokens_per_shard=4096, vocab=1000)
        pipes = [
            DataPipeline(ds, cluster, h, batch_per_host=2, seq_len=128)
            for h in range(n_hosts)
        ]
        return cluster, ds, pipes

    def test_every_shard_owned_exactly_once(self):
        _, _, pipes = self._mk()
        owned = np.concatenate([p.owned_shards for p in pipes])
        assert sorted(owned.tolist()) == list(range(64))

    def test_batches_deterministic(self):
        _, _, pipes = self._mk()
        a = [b.copy() for _, b in zip(range(3), pipes[0].batches())]
        b = [b.copy() for _, b in zip(range(3), pipes[0].batches())]
        for x, y in zip(a, b):
            assert np.array_equal(x, y)

    def test_batch_shape_and_range(self):
        _, _, pipes = self._mk()
        batch = next(iter(pipes[0]))
        assert batch.shape == (2, 128)
        assert batch.min() >= 0 and batch.max() < 1000

    def test_elastic_membership_minimal_movement(self):
        cluster, ds, pipes = self._mk()
        before = {h: set(p.owned_shards.tolist()) for h, p in enumerate(pipes)}
        cluster.add_node(4, 1.0)
        new_pipe = DataPipeline(ds, cluster, 4, batch_per_host=2, seq_len=128)
        gained_total = set(new_pipe.owned_shards.tolist())
        for h, p in enumerate(pipes):
            gained, lost = p.refresh_membership()
            assert gained.size == 0  # existing hosts never gain on addition
            assert set(lost.tolist()) <= gained_total
        owned = set()
        for p in pipes + [new_pipe]:
            owned |= set(p.owned_shards.tolist())
        assert owned == set(range(64))

    def test_epoch_order_varies(self):
        _, _, pipes = self._mk()
        b0 = next(pipes[0].batches(epoch=0))
        b1 = next(pipes[0].batches(epoch=1))
        assert not np.array_equal(b0, b1)


class TestCheckpoint:
    def _tree(self, rng):
        return {
            "w": rng.standard_normal((128, 64)).astype(np.float32),
            "b": rng.standard_normal((7,)).astype(np.float32),
            "nested": {"m": rng.standard_normal((33, 5)).astype(np.float32)},
        }

    def test_roundtrip(self):
        store = AsuraCheckpointStore({i: 1.0 for i in range(6)}, n_replicas=3)
        mgr = CheckpointManager(store)
        tree = self._tree(np.random.default_rng(0))
        mgr.save(10, tree)
        out = mgr.restore(10, tree)
        assert np.array_equal(out["w"], tree["w"])
        assert np.array_equal(out["b"], tree["b"])
        assert np.array_equal(out["nested"]["m"], tree["nested"]["m"])

    def test_survives_node_failures_below_replication(self):
        store = AsuraCheckpointStore({i: 1.0 for i in range(6)}, n_replicas=3)
        mgr = CheckpointManager(store)
        tree = self._tree(np.random.default_rng(1))
        mgr.save(1, tree)
        store.fail_node(0)
        store.fail_node(3)  # 2 < n_replicas failures
        out = mgr.restore(1, tree)
        assert np.array_equal(out["w"], tree["w"])

    def test_repair_moves_only_victims_chunks(self):
        store = AsuraCheckpointStore({i: 1.0 for i in range(8)}, n_replicas=3)
        mgr = CheckpointManager(store)
        tree = self._tree(np.random.default_rng(2))
        mgr.save(5, tree)
        victim_chunks = len(store.nodes[2].blobs)
        moved = store.remove_node_and_repair(2)
        assert moved == victim_chunks  # exactly the victim's copies re-made
        out = mgr.restore(5, tree)
        assert np.array_equal(out["nested"]["m"], tree["nested"]["m"])
        # every chunk is back at full replication
        for nid, node in store.nodes.items():
            assert node.alive

    def test_add_node_rebalances_minimally(self):
        store = AsuraCheckpointStore({i: 1.0 for i in range(4)}, n_replicas=2)
        mgr = CheckpointManager(store)
        tree = self._tree(np.random.default_rng(3))
        mgr.save(7, tree)
        keys = np.fromiter(
            {k for n in store.nodes.values() for k in n.blobs}, dtype=np.uint32
        )
        before = store.replicas_for(keys)
        moved = store.add_node(9, 1.0)
        after = store.replicas_for(keys)
        # exact minimality: copies written == new (key, node) assignments
        want = sum(
            len(set(a.tolist()) - set(b.tolist())) for a, b in zip(after, before)
        )
        assert moved == want
        out = mgr.restore(7, tree)
        assert np.array_equal(out["w"], tree["w"])

    def test_async_save_overlaps(self):
        store = AsuraCheckpointStore({i: 1.0 for i in range(4)}, n_replicas=2)
        mgr = CheckpointManager(store)
        tree = self._tree(np.random.default_rng(4))
        mgr.save_async(3, tree)
        mgr.wait()
        out = mgr.restore(3, tree)
        assert np.array_equal(out["b"], tree["b"])


class TestElasticCoordinator:
    def test_add_plan_matches_bruteforce(self):
        cluster = make_uniform_cluster(6)
        ids = np.arange(3000, dtype=np.uint32)
        coord = ElasticCoordinator(cluster, ids)
        brute_before = cluster.place_nodes(ids)
        plan = coord.add_node(6, 1.0)
        brute_after = cluster.place_nodes(ids)
        moved = np.nonzero(brute_before != brute_after)[0]
        assert set(plan.moves) == {int(ids[i]) for i in moved}
        for datum, (src, dst) in plan.moves.items():
            assert dst == 6
        assert np.array_equal(coord.owners(), brute_after)

    def test_remove_plan_matches_bruteforce(self):
        cluster = make_uniform_cluster(6)
        ids = np.arange(3000, dtype=np.uint32)
        coord = ElasticCoordinator(cluster, ids)
        brute_before = cluster.place_nodes(ids)
        plan = coord.remove_node(2)
        brute_after = cluster.place_nodes(ids)
        moved = np.nonzero(brute_before != brute_after)[0]
        assert set(plan.moves) == {int(ids[i]) for i in moved}
        for datum, (src, dst) in plan.moves.items():
            assert src == 2
        assert np.array_equal(coord.owners(), brute_after)

    def test_heterogeneous_capacity_add(self):
        cluster = Cluster()
        for i, cap in enumerate([0.5, 1.7, 1.0, 2.3]):
            cluster.add_node(i, cap)
        ids = np.arange(2000, dtype=np.uint32)
        coord = ElasticCoordinator(cluster, ids)
        before = cluster.place_nodes(ids)
        plan = coord.add_node(10, 1.4)
        after = cluster.place_nodes(ids)
        moved = np.nonzero(before != after)[0]
        assert set(plan.moves) == {int(ids[i]) for i in moved}

    def test_sequence_of_events(self):
        cluster = make_uniform_cluster(5)
        ids = np.arange(1500, dtype=np.uint32)
        coord = ElasticCoordinator(cluster, ids)
        for event in [("add", 5, 1.0), ("rm", 1, None), ("add", 6, 0.5), ("rm", 5, None)]:
            if event[0] == "add":
                coord.add_node(event[1], event[2])
            else:
                coord.remove_node(event[1])
            assert np.array_equal(coord.owners(), cluster.place_nodes(ids))


class TestFailureDetection:
    def test_heartbeat_timeout(self):
        t = {"now": 0.0}
        tracker = HeartbeatTracker(timeout=5.0, clock=lambda: t["now"])
        tracker.beat(0)
        tracker.beat(1)
        t["now"] = 4.0
        tracker.beat(1)
        t["now"] = 7.0
        assert tracker.dead_nodes() == [0]

    def test_detector_fires_once(self):
        t = {"now": 0.0}
        tracker = HeartbeatTracker(timeout=1.0, clock=lambda: t["now"])
        tracker.beat(0)
        fired = []
        det = FailureDetector(tracker, on_failure=fired.append)
        t["now"] = 3.0
        assert det.poll() == [0]
        assert det.poll() == []
        assert fired == [0]

    def test_end_to_end_failure_recovery(self):
        """Heartbeat loss -> store repair -> restore still works."""
        store = AsuraCheckpointStore({i: 1.0 for i in range(6)}, n_replicas=3)
        mgr = CheckpointManager(store)
        tree = {"w": np.arange(100, dtype=np.float32)}
        mgr.save(1, tree)
        t = {"now": 0.0}
        tracker = HeartbeatTracker(timeout=2.0, clock=lambda: t["now"])
        for nid in store.nodes:
            tracker.beat(nid)
        det = FailureDetector(tracker, on_failure=store.remove_node_and_repair)
        t["now"] = 3.0
        for nid in list(store.nodes):
            if nid != 4:
                tracker.beat(nid)
        t["now"] = 4.0  # node 4 last seen at 0 -> dead; others at 3 -> alive
        assert det.poll() == [4]
        out = mgr.restore(1, tree)
        assert np.array_equal(out["w"], tree["w"])


class TestStraggler:
    def test_backup_dispatch(self):
        t = {"now": 0.0}
        mit = StragglerMitigator(clock=lambda: t["now"], threshold=2.0)
        for sid, host in [(0, 0), (1, 1), (2, 2)]:
            mit.start(sid, host)
        t["now"] = 1.0
        mit.complete(0)
        mit.complete(1)
        t["now"] = 5.0  # shard 2 is now > 2x median (1.0)
        backups = mit.dispatch_backups([0, 1, 2, 3], load={})
        assert backups and backups[0][0] == 2
        assert backups[0][1] != 2

    def test_no_duplicate_backups(self):
        t = {"now": 0.0}
        mit = StragglerMitigator(clock=lambda: t["now"], threshold=2.0)
        mit.start(0, 0)
        mit.start(1, 1)
        t["now"] = 1.0
        mit.complete(0)
        t["now"] = 10.0
        first = mit.dispatch_backups([0, 1], load={})
        second = mit.dispatch_backups([0, 1], load={})
        assert len(first) == 1 and second == []
