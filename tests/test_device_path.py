"""ISSUE-2 coverage: lazy-depth draw ladder + device-resident placement path.

  * lazy ladder vs the scalar oracle / unrolled ladder, lane-by-lane, at
    top_level in {0, 5, 19} (draw-sequence and placement equivalence),
  * forced-tail lanes resolved ON DEVICE bit-identically to
    ``resolve_tail_np`` (reusing the 128-bit tail-scaling regression
    configuration: 100 uniform nodes, where h * total_mass needs 95 bits),
  * non-block-multiple and size-0/size-1 batches through ``place_on_table``
    and the engine device variants,
  * zero host->device transfers between engine ``*_device`` calls
    (transfer-guard + np.asarray tripwire),
  * fused seg->node gather == host gather for placement and replicas.
"""

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from repro.core import Cluster, PlacementEngine, make_cluster, make_uniform_cluster
from repro.core.asura import (
    DEFAULT_PARAMS,
    AsuraParams,
    _AsuraStream,
    _next_asura_batch,
    _next_asura_batch_unrolled,
    _place_batch_u32_unrolled,
    lengths_to_u32,
    place_batch,
    place_batch_u32,
    place_replicas_scalar,
    resolve_tail_np,
    tail_cumsum_halves,
)
from repro.kernels.ops import (
    node_table_prep,
    place_nodes_on_table_device,
    place_on_table,
    place_on_table_device,
    place_replicas_on_table_device,
    table_prep,
    tail_prep,
)

MIXED = [0.3, 1.7, 2.0, 0.9, 1.0, 0.5]

# Half-full uniform tables whose derived entry level is exactly the top we
# want: top 19 needs upper in (2**19, 2**20], i.e. ~600k segments.
TOP_TABLES = {
    0: np.full(2, 0.9),
    5: np.full(60, 0.9),
    19: np.full(600_000, 0.9),
}


def _top_for(lengths) -> int:
    occupied = np.nonzero(lengths > 0)[0]
    upper = occupied[-1] + lengths[occupied[-1]]
    return DEFAULT_PARAMS.level_for(float(upper))


@pytest.mark.parametrize("top_level", sorted(TOP_TABLES))
def test_table_levels_are_as_labelled(top_level):
    assert _top_for(TOP_TABLES[top_level]) == top_level


# ---------------------------------------------------------------------------
# Lazy ladder == scalar oracle == unrolled ladder
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("top_level", [0, 5, 19])
def test_lazy_ladder_draw_sequence_matches_oracle(top_level):
    """The first 40 ASURA numbers of every lane, lane-by-lane vs the scalar
    stream with true per-level counters."""
    ids = (np.arange(16, dtype=np.uint64) * 2654435761 % (2**32)).astype(np.uint32)
    n_draws = 40
    counters = np.zeros((top_level + 1, len(ids)), dtype=np.uint32)
    got = [
        _next_asura_batch(ids, counters, top_level, DEFAULT_PARAMS)
        for _ in range(n_draws)
    ]
    for lane, datum in enumerate(ids):
        stream = _AsuraStream(int(datum), top_level, DEFAULT_PARAMS)
        for d in range(n_draws):
            k, frac = stream.next()
            assert got[d][0][lane] == k, (lane, d)
            assert got[d][1][lane] == frac, (lane, d)
        assert counters[:, lane].tolist() == stream.counters, lane


@pytest.mark.parametrize("top_level", [0, 5, 19])
def test_lazy_ladder_matches_unrolled(top_level):
    ids = np.arange(256, dtype=np.uint32)
    c_lazy = np.zeros((top_level + 1, len(ids)), dtype=np.uint32)
    c_unrl = np.zeros((len(ids), top_level + 1), dtype=np.uint32)  # legacy layout
    for _ in range(10):
        k1, f1 = _next_asura_batch(ids, c_lazy, top_level, DEFAULT_PARAMS)
        k2, f2 = _next_asura_batch_unrolled(ids, c_unrl, top_level, DEFAULT_PARAMS)
        assert_allclose(k1, k2, atol=0)
        assert_allclose(f1, f2, atol=0)
    assert_allclose(c_lazy, c_unrl.T, atol=0)


def _place_scalar_at_top(datum_id, len32, top_level, params=DEFAULT_PARAMS):
    """place_scalar with an explicitly forced entry level."""
    stream = _AsuraStream(int(datum_id), top_level, params)
    n_segs = len(len32)
    while True:
        k, frac = stream.next()
        if k < n_segs and frac < int(len32[k]):
            return k


@pytest.mark.parametrize("top_level", [0, 5, 19])
def test_lazy_placement_lane_by_lane_vs_oracle(top_level):
    lengths = TOP_TABLES[top_level]
    len32 = lengths_to_u32(lengths)
    ids = (np.arange(48, dtype=np.uint64) * 2654435761 % (2**32)).astype(np.uint32)
    got = place_batch_u32(ids, len32, top_level)
    assert (got >= 0).all()  # half-full table: no tail lanes expected
    for lane, datum in enumerate(ids):
        assert got[lane] == _place_scalar_at_top(datum, len32, top_level), lane
    assert_allclose(got, _place_batch_u32_unrolled(ids, len32, top_level), atol=0)


@pytest.mark.parametrize("top_level", [0, 5, 19])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_kernel_ladders_match_numpy(top_level, use_pallas):
    """jnp ref and Pallas (interpret) lazy ladders vs the NumPy batch, at
    the same forced top level, including the on-device tail."""
    lengths = TOP_TABLES[top_level]
    len32 = lengths_to_u32(lengths)
    batch = 1024 if top_level < 19 else 256
    ids = np.arange(batch, dtype=np.uint32)
    want = resolve_tail_np(
        ids, place_batch_u32(ids, len32, top_level), len32, top_level
    )
    len32_dev, _ = table_prep(lengths)
    cum_hi, cum_lo = tail_prep(np.asarray(len32_dev))
    got = place_on_table_device(
        ids,
        len32_dev,
        cum_hi,
        cum_lo,
        top_level=top_level,
        use_pallas=use_pallas,
        rows_per_block=2,
    )
    assert_allclose(np.asarray(got), want, atol=0)


# ---------------------------------------------------------------------------
# On-device tail == resolve_tail_np (the 128-bit regression table)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_forced_tail_on_device_128bit_table(backend):
    """max_draws=0 pushes EVERY lane through the tail; on the 100-node
    uniform table h * total_mass needs up to 95 bits, so a u64-wrapping
    device implementation would dump every lane on segment 0."""
    params = AsuraParams(max_draws=0)
    c = make_uniform_cluster(100, params=params)
    ids = np.arange(20_000, dtype=np.uint32)
    want = place_batch(ids, c.seg_lengths(), params)
    eng = PlacementEngine(c, backend=backend)
    got = np.asarray(eng.place_device(jnp.asarray(ids)))
    assert_allclose(got, want, atol=0)
    # and the fused node-gather variant agrees with the host mapping
    got_nodes = np.asarray(eng.place_nodes_device(jnp.asarray(ids)))
    assert_allclose(got_nodes, c.seg_to_node()[want], atol=0)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_partial_tail_on_device(backend):
    """max_draws=1 leaves a real mixed population of converged and
    tail-resolved lanes."""
    params = AsuraParams(max_draws=1)
    c = make_cluster([0.1, 0.2, 0.05], params=params)
    ids = np.arange(2048, dtype=np.uint32)
    want = place_batch(ids, c.seg_lengths(), params)
    eng = PlacementEngine(c, backend=backend)
    assert_allclose(np.asarray(eng.place_device(ids)), want, atol=0)


def test_tail_cumsum_halves_exact():
    len32 = lengths_to_u32(make_uniform_cluster(100).seg_lengths())
    hi, lo = tail_cumsum_halves(len32)
    cum = np.cumsum(len32.astype(np.uint64))
    assert_allclose(
        hi.astype(np.uint64) * 2**32 + lo.astype(np.uint64), cum, atol=0
    )


# ---------------------------------------------------------------------------
# Batch-shape edges through place_on_table and the engine device variants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("batch", [0, 1, 7, 100, 2049])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_odd_batches_place_on_table(batch, use_pallas):
    c = make_cluster(MIXED)
    len32, top = table_prep(c.seg_lengths())
    ids = np.arange(batch, dtype=np.uint32)
    got = place_on_table(ids, len32, top_level=top, use_pallas=use_pallas)
    assert got.shape == (batch,)
    if batch:
        assert_allclose(got, place_batch(ids, c.seg_lengths()), atol=0)


@pytest.mark.parametrize("batch", [0, 1, 7, 100, 2049])
@pytest.mark.parametrize("backend", ["numpy", "ref", "pallas"])
def test_odd_batches_engine_device(batch, backend):
    c = make_cluster(MIXED)
    eng = PlacementEngine(c, backend=backend)
    ids = np.arange(batch, dtype=np.uint32)
    want_segs = place_batch(ids, c.seg_lengths())
    segs = np.asarray(eng.place_device(ids))
    nodes = np.asarray(eng.place_nodes_device(ids))
    assert segs.shape == (batch,) and nodes.shape == (batch,)
    if batch:
        assert_allclose(segs, want_segs, atol=0)
        assert_allclose(nodes, c.seg_to_node()[want_segs], atol=0)
    reps = np.asarray(eng.place_replica_nodes_device(ids, 2))
    assert reps.shape == (batch, 2)
    if batch:
        want_reps = eng.place_replica_nodes(ids, 2)
        assert_allclose(reps, want_reps, atol=0)


def test_numpy_backend_device_calls_leave_host_path_intact():
    """Device variants on the numpy backend build the device tables lazily
    without a second materialization (uploads stays 1) and host calls keep
    working afterwards."""
    c = make_cluster(MIXED)
    eng = PlacementEngine(c, backend="numpy")
    ids = np.arange(300, dtype=np.uint32)
    host = eng.place(ids)
    dev = np.asarray(eng.place_device(ids))
    assert eng.uploads == 1
    assert_allclose(dev, host, atol=0)
    assert_allclose(eng.place(ids), host, atol=0)


# ---------------------------------------------------------------------------
# Zero host syncs between device calls
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_device_path_zero_host_transfers(backend, monkeypatch):
    """After warm-up, repeated ``place_nodes_device`` /
    ``place_replica_nodes_device`` calls with device-resident ids must not
    touch the host: ``jax.transfer_guard('disallow')`` rejects any
    host->device upload (the old path re-uploaded the host-resolved tail),
    and an ``np.asarray`` tripwire catches device->host reads that the
    CPU-backend guard cannot see.  Results must be jax Arrays."""
    c = make_cluster(MIXED)
    eng = PlacementEngine(c, backend=backend)
    ids = jnp.arange(4096, dtype=jnp.uint32)
    rep_ids = jnp.arange(256, dtype=jnp.uint32)  # sliced OUTSIDE the guard
    # warm-up: artifact build (the one upload) + jit compile
    eng.place_device(ids).block_until_ready()
    eng.place_nodes_device(ids).block_until_ready()
    eng.place_replica_nodes_device(rep_ids, 2).block_until_ready()
    assert eng.uploads == 1

    real_asarray = np.asarray
    host_reads: list = []

    def tripwire(*args, **kwargs):
        host_reads.append(args)
        return real_asarray(*args, **kwargs)

    monkeypatch.setattr(np, "asarray", tripwire)
    with jax.transfer_guard("disallow"):
        for _ in range(3):
            segs = eng.place_device(ids)
            nodes = eng.place_nodes_device(ids)
            reps = eng.place_replica_nodes_device(rep_ids, 2)
            segs.block_until_ready()
            nodes.block_until_ready()
            reps.block_until_ready()
    monkeypatch.undo()
    assert isinstance(nodes, jax.Array) and isinstance(reps, jax.Array)
    assert not host_reads, f"device path touched the host: {len(host_reads)} reads"
    assert eng.uploads == 1


# ---------------------------------------------------------------------------
# Fused seg->node gather == host gather
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_replicas", [1, 2, 3])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_fused_replica_node_gather_matches_scalar(n_replicas, use_pallas):
    c = make_cluster(MIXED)
    ids = (np.arange(64, dtype=np.uint64) * 2654435761 % (2**32)).astype(np.uint32)
    len32, top = table_prep(c.seg_lengths())
    node_of = node_table_prep(c.seg_to_node())
    got = np.asarray(
        place_replicas_on_table_device(
            ids,
            len32,
            node_of,
            n_replicas,
            top_level=top,
            use_pallas=use_pallas,
            emit_nodes=True,
        )
    )
    for lane, datum in enumerate(ids):
        segs = place_replicas_scalar(
            int(datum), c.seg_lengths(), c.seg_to_node(), n_replicas
        )
        want = [int(c.seg_to_node()[s]) for s in segs]
        assert got[lane].tolist() == want, (lane, datum)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_fused_place_node_gather_matches_host(use_pallas):
    c = make_cluster(MIXED)
    ids = np.arange(1000, dtype=np.uint32)
    len32, top = table_prep(c.seg_lengths())
    node_of = node_table_prep(c.seg_to_node())
    cum_hi, cum_lo = tail_prep(np.asarray(len32))
    got = np.asarray(
        place_nodes_on_table_device(
            ids, len32, cum_hi, cum_lo, node_of,
            top_level=top, use_pallas=use_pallas,
        )
    )
    want = c.seg_to_node()[place_batch(ids, c.seg_lengths())]
    assert_allclose(got, want, atol=0)


# ---------------------------------------------------------------------------
# Consumers on the device path
# ---------------------------------------------------------------------------


def test_pipeline_ownership_via_device_path():
    from repro.data.pipeline import DataPipeline, ShardedDataset

    ds = ShardedDataset(n_shards=64, tokens_per_shard=128, vocab=97)
    c_host = make_uniform_cluster(4)
    c_dev = Cluster.from_json(c_host.to_json())
    c_dev._engine = PlacementEngine(c_dev, backend="ref")
    for host in range(4):
        p_host = DataPipeline(
            ds, c_host, host, batch_per_host=2, seq_len=32
        )
        p_dev = DataPipeline(ds, c_dev, host, batch_per_host=2, seq_len=32)
        assert_allclose(p_dev.owned_shards, p_host.owned_shards, atol=0)


def test_checkpoint_add_node_via_device_path():
    from repro.checkpoint.sharded import AsuraCheckpointStore

    def build(backend):
        store = AsuraCheckpointStore({i: 1.0 for i in range(5)}, n_replicas=2)
        if backend != "auto":
            store.engine = store.cluster._engine = PlacementEngine(
                store.cluster, backend=backend
            )
        keys = np.arange(40, dtype=np.uint32)
        store.put_chunks(keys, [bytes([k % 251]) * 8 for k in keys])
        moved = store.add_node(9, 1.0)
        return store, moved

    host_store, host_moved = build("numpy")
    dev_store, dev_moved = build("ref")
    assert dev_moved == host_moved
    for nid, node in host_store.nodes.items():
        assert dev_store.nodes[nid].blobs == node.blobs


# ---------------------------------------------------------------------------
# table_prep canonicalization (satellite: unify on lengths_to_u32)
# ---------------------------------------------------------------------------


def test_table_prep_rejects_out_of_range_lengths():
    with pytest.raises(ValueError):
        table_prep([0.5, 1.0])  # length 1.0 is out of [0, 1)
    with pytest.raises(ValueError):
        table_prep([0.5, -0.1])


def test_table_prep_matches_lengths_to_u32():
    lengths = make_cluster(MIXED).seg_lengths()
    len32, _ = table_prep(lengths)
    want = lengths_to_u32(lengths)
    assert_allclose(np.asarray(len32)[: len(want)], want, atol=0)
    assert (np.asarray(len32)[len(want):] == 0).all()
