"""Paper Fig. 5: distribution-stage calculation time vs node count.

ASURA O(1), Consistent Hashing O(log NV) (VN in {1, 100, 10000}), Straw
Buckets O(N).  The paper times 1e6 scalar calls on a Core2Quad; we report
both the scalar per-call latency (paper-comparable) and the vectorized
per-id throughput (the TPU-relevant metric), at reduced loop counts sized
for this container.  Also reproduces the huge-N scalability check
(section IV.B: "0.73 us at 1e8 nodes" -- we run 1e6 nodes and show the time
is flat in N).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import ConsistentHashRing, StrawBucket, make_uniform_cluster
from repro.core.asura import place_batch, place_scalar

NODE_COUNTS = (1, 10, 100, 400, 800, 1200)
BATCH = 200_000
SCALAR_CALLS = 2_000


def _time(fn, *args) -> float:
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0


def bench_asura(n_nodes: int, batch: int = BATCH):
    cluster = make_uniform_cluster(n_nodes)
    lengths = cluster.seg_lengths()
    ids = np.arange(batch, dtype=np.uint32)
    place_batch(ids[:1000], lengths)  # warm
    dt = _time(place_batch, ids, lengths)
    t0 = time.perf_counter()
    for i in range(SCALAR_CALLS):
        place_scalar(i, lengths)
    scalar_us = (time.perf_counter() - t0) / SCALAR_CALLS * 1e6
    return dt / batch * 1e6, scalar_us


def bench_asura_engine(n_nodes: int, batch: int = BATCH):
    """Engine path: placement against the cached versioned table artifact
    (no per-call table canonicalization / upload)."""
    cluster = make_uniform_cluster(n_nodes)
    engine = cluster.engine
    ids = np.arange(batch, dtype=np.uint32)
    engine.place(ids[:1000])  # warm: builds the artifact (upload #1)
    dt = _time(engine.place, ids)
    assert engine.uploads == 1, "engine must not re-upload at a fixed version"
    return dt / batch * 1e6


def bench_ch(n_nodes: int, virtual_nodes: int, batch: int = BATCH):
    ring = ConsistentHashRing(range(n_nodes), virtual_nodes=virtual_nodes)
    ids = np.arange(batch, dtype=np.uint32)
    ring.place(ids[:1000])
    dt = _time(ring.place, ids)
    return dt / batch * 1e6


def bench_straw(n_nodes: int, batch: int = 20_000):
    straw = StrawBucket(range(n_nodes))
    ids = np.arange(batch, dtype=np.uint32)
    straw.place(ids[:100])
    dt = _time(straw.place, ids)
    return dt / batch * 1e6


def run(csv_print) -> None:
    for n in NODE_COUNTS:
        vec_us, scalar_us = bench_asura(n)
        csv_print(f"fig5_asura_vec_n{n}", vec_us, "us_per_id")
        csv_print(f"fig5_asura_scalar_n{n}", scalar_us, "us_per_call")
        csv_print(f"fig5_asura_engine_n{n}", bench_asura_engine(n), "us_per_id")
        for vn in (1, 100, 10_000):
            if n * vn > 20_000_000:
                continue
            csv_print(f"fig5_ch_vn{vn}_n{n}", bench_ch(n, vn), "us_per_id")
        csv_print(f"fig5_straw_n{n}", bench_straw(n), "us_per_id")
    # huge-N scalability (paper section IV.B)
    for n in (10_000, 1_000_000):
        vec_us, _ = bench_asura(n, batch=50_000)
        csv_print(f"fig5_asura_huge_n{n}", vec_us, "us_per_id")
