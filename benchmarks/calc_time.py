"""Paper Fig. 5: distribution-stage calculation time vs node count.

ASURA O(1), Consistent Hashing O(log NV) (VN in {1, 100, 10000}), Straw
Buckets O(N).  The paper times 1e6 scalar calls on a Core2Quad; we report
the scalar per-call latency (paper-comparable) and the vectorized per-id
throughput (the TPU-relevant metric), at reduced loop counts sized for this
container.  Also reproduces the huge-N scalability check (section IV.B:
"0.73 us at 1e8 nodes" -- we run 1e6 nodes and show the time is flat in N).

The HEADLINE ASURA number (``fig5_asura_vec_n*``) is the engine path --
placement against the cached versioned table artifact, the way every
consumer actually calls it.  ``fig5_asura_uncached_n*`` keeps the old
``place_batch`` number (re-derives the table per call) for comparison; it
understates ASURA vs Consistent Hashing.

Ladder variants (the ISSUE-2 perf_opt acceptance numbers): at a 4096-node
cluster (top_level ~ 11) ``fig5_ladder_lazy_n4096`` vs
``fig5_ladder_unrolled_n4096`` isolates the lazy-depth descend ladder
against the exact pre-PR unrolled arithmetic on the same prebuilt table;
``fig5_ladder_speedup_n4096`` is the ratio (acceptance: >= 2x).

Device variants: ``fig5_asura_device_n*`` times the engine's zero-host-sync
``place_nodes_device`` path (jnp reference kernels off-TPU, Pallas on TPU),
ids resident on device, result blocked on device.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import ConsistentHashRing, PlacementEngine, StrawBucket, make_uniform_cluster
from repro.core.asura import (
    _place_batch_u32_unrolled,
    place_batch,
    place_batch_u32,
    place_scalar,
)

NODE_COUNTS = (1, 10, 100, 400, 800, 1200)
BATCH = 200_000
SCALAR_CALLS = 2_000
LADDER_NODES = 4096
LADDER_BATCH = 100_000  # large enough to amortize per-call setup
HUGE_NODES = (10_000, 1_000_000)

QUICK_NODE_COUNTS = (1, 10, 100)
QUICK_BATCH = 20_000
QUICK_SCALAR_CALLS = 200
QUICK_HUGE_NODES = (10_000,)


def _time(fn, *args) -> float:
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0


def bench_asura_uncached(n_nodes: int, batch: int, scalar_calls: int):
    """Table re-derived per call (the pre-engine number, kept for reference)."""
    cluster = make_uniform_cluster(n_nodes)
    lengths = cluster.seg_lengths()
    ids = np.arange(batch, dtype=np.uint32)
    place_batch(ids[:1000], lengths)  # warm
    dt = _time(place_batch, ids, lengths)
    t0 = time.perf_counter()
    for i in range(scalar_calls):
        place_scalar(i, lengths)
    scalar_us = (time.perf_counter() - t0) / scalar_calls * 1e6
    return dt / batch * 1e6, scalar_us


def bench_asura_engine(n_nodes: int, batch: int):
    """HEADLINE: engine path, placement against the cached versioned table
    artifact (no per-call table canonicalization / upload)."""
    cluster = make_uniform_cluster(n_nodes)
    engine = cluster.engine
    ids = np.arange(batch, dtype=np.uint32)
    engine.place(ids[:1000])  # warm: builds the artifact (upload #1)
    dt = _time(engine.place, ids)
    assert engine.uploads == 1, "engine must not re-upload at a fixed version"
    return dt / batch * 1e6


def bench_asura_device(n_nodes: int, batch: int):
    """Engine device path: ids resident on device, zero host syncs between
    calls (placement + tail + node gather fused on device).  backend="auto"
    so the number tracks the shipped kernels: jnp reference off-TPU, Pallas
    on TPU."""
    import jax.numpy as jnp

    cluster = make_uniform_cluster(n_nodes)
    engine = PlacementEngine(cluster, backend="auto")
    ids = jnp.arange(batch, dtype=jnp.uint32)
    engine.place_nodes_device(ids).block_until_ready()  # warm + compile
    t0 = time.perf_counter()
    engine.place_nodes_device(ids).block_until_ready()
    dt = time.perf_counter() - t0
    assert engine.uploads == 1
    return dt / batch * 1e6


def bench_ladder(n_nodes: int, batch: int, repeats: int = 3):
    """Lazy-depth vs unrolled descend ladder on the same prebuilt table
    (best of ``repeats`` so OS noise cannot fake or hide the speedup)."""
    cluster = make_uniform_cluster(n_nodes)
    art = cluster.engine.artifact()
    ids = np.arange(batch, dtype=np.uint32)
    place_batch_u32(ids[:1000], art.len32, art.top_level)  # warm
    _place_batch_u32_unrolled(ids[:1000], art.len32, art.top_level)
    lazy = min(
        _time(place_batch_u32, ids, art.len32, art.top_level)
        for _ in range(repeats)
    )
    unrolled = min(
        _time(_place_batch_u32_unrolled, ids, art.len32, art.top_level)
        for _ in range(repeats)
    )
    return lazy / batch * 1e6, unrolled / batch * 1e6, art.top_level


def bench_ch(n_nodes: int, virtual_nodes: int, batch: int):
    ring = ConsistentHashRing(range(n_nodes), virtual_nodes=virtual_nodes)
    ids = np.arange(batch, dtype=np.uint32)
    ring.place(ids[:1000])
    dt = _time(ring.place, ids)
    return dt / batch * 1e6


def bench_straw(n_nodes: int, batch: int = 20_000):
    straw = StrawBucket(range(n_nodes))
    ids = np.arange(batch, dtype=np.uint32)
    straw.place(ids[:100])
    dt = _time(straw.place, ids)
    return dt / batch * 1e6


def run(csv_print, quick: bool = False) -> None:
    node_counts = QUICK_NODE_COUNTS if quick else NODE_COUNTS
    batch = QUICK_BATCH if quick else BATCH
    scalar_calls = QUICK_SCALAR_CALLS if quick else SCALAR_CALLS
    for n in node_counts:
        csv_print(f"fig5_asura_vec_n{n}", bench_asura_engine(n, batch), "us_per_id")
        vec_us, scalar_us = bench_asura_uncached(n, batch, scalar_calls)
        csv_print(f"fig5_asura_uncached_n{n}", vec_us, "us_per_id")
        csv_print(f"fig5_asura_scalar_n{n}", scalar_us, "us_per_call")
        csv_print(f"fig5_asura_device_n{n}", bench_asura_device(n, batch), "us_per_id")
        for vn in (1, 100, 10_000):
            if n * vn > 20_000_000 or (quick and vn > 100):
                continue
            csv_print(f"fig5_ch_vn{vn}_n{n}", bench_ch(n, vn, batch), "us_per_id")
        csv_print(f"fig5_straw_n{n}", bench_straw(n), "us_per_id")
    # Lazy-depth ladder vs the pre-PR unrolled ladder (ISSUE-2 acceptance).
    lazy_us, unrolled_us, top = bench_ladder(LADDER_NODES, LADDER_BATCH)
    csv_print(f"fig5_ladder_lazy_n{LADDER_NODES}", lazy_us, "us_per_id")
    csv_print(f"fig5_ladder_unrolled_n{LADDER_NODES}", unrolled_us, "us_per_id")
    csv_print(f"fig5_ladder_top_level_n{LADDER_NODES}", top, "levels")
    csv_print(
        f"fig5_ladder_speedup_n{LADDER_NODES}", unrolled_us / lazy_us, "x_faster"
    )
    # huge-N scalability (paper section IV.B)
    for n in QUICK_HUGE_NODES if quick else HUGE_NODES:
        vec_us = bench_asura_engine(n, batch=min(batch, 50_000))
        csv_print(f"fig5_asura_huge_n{n}", vec_us, "us_per_id")
