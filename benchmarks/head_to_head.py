"""Head-to-head: the paper's full evaluation (sections 6.B-6.D) in one run.

All four algorithms -- ASURA, Consistent Hashing ("ch"), capacity-weighted
Rendezvous Hashing ("wrh") and Random Slicing ("rs") -- run through the SAME
``PlacementEngine`` artifact interface at a COMMON scale, on the device-
resident backends (jnp reference kernels off-TPU, Pallas on TPU), so the
comparison measures the algorithms, not the plumbing.  Paper-figure mapping:

  * ``h2h_calc_<alg>_n<N>``      -- Fig. 5: distribution-stage time per id
    (engine cached-artifact path, batch placement, us/id),
  * ``h2h_uniformity_<alg>_*``   -- Figs. 6-7: max variability (%), uniform
    AND capacity-weighted clusters,
  * ``h2h_move_{add,rm}_<alg>``  -- section 6.D / Table 3: moved fraction
    on one node addition/removal vs the theoretical optimum, plus the
    wrong-direction counters (must be 0 for the optimal-movement
    algorithms),
  * ``h2h_memory_<alg>_n<N>``    -- Table 2: lookup-table bytes at N nodes.

``--quick`` shrinks every population for the CI smoke; the CI perf gate
(``benchmarks/check_regression.py``) compares the timing entries of a fresh
quick run against the committed ``benchmarks/baselines`` snapshots.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import ALGORITHMS, PlacementEngine, make_cluster, make_uniform_cluster
from repro.core.rng import draw_u32_np

NODES = 128
BATCH = 200_000
DATA_PER_NODE = 2_000
MOVE_DATA = 200_000

QUICK_NODES = 32
QUICK_BATCH = 20_000
QUICK_DATA_PER_NODE = 500
QUICK_MOVE_DATA = 20_000

MEMORY_NODES = (100, 1000)


def _engine(cluster, algorithm: str) -> PlacementEngine:
    # backend="ref" keeps the numbers on the shipped device path (jnp
    # kernels) on CPU hosts; on a TPU host "auto" would pick pallas, but a
    # fixed backend keeps CI trajectory points comparable run to run.
    return PlacementEngine(cluster, backend="ref", algorithm=algorithm)


def _ids(n: int, rep: int = 0) -> np.ndarray:
    base = np.arange(n, dtype=np.uint32)
    return draw_u32_np(base, np.uint32(900 + rep), np.zeros_like(base))


def bench_calc(csv_print, n_nodes: int, batch: int, repeats: int = 5) -> None:
    """Fig. 5 at a common scale: one engine per algorithm, cached artifact,
    batch place_nodes timed after a warm call (one upload asserted).

    These entries are the CI-gated ones (check_regression.py), so the
    measurement is built for stability: each repeat times enough back-to-
    back calls to fill ~20 ms (sub-millisecond single calls are all
    dispatch jitter), the entry is the best of ``repeats`` (the least-
    preempted sample), and the gate further normalizes by the suite's
    ``h2h_calibration`` machine-speed entry."""
    ids = _ids(batch)
    for alg in ALGORITHMS:
        cluster = make_uniform_cluster(n_nodes)
        engine = _engine(cluster, alg)
        engine.place_nodes(ids)  # warm at the TIMED shape: artifact + jit
        t0 = time.perf_counter()
        engine.place_nodes(ids)
        once = max(time.perf_counter() - t0, 1e-6)
        inner = max(1, int(0.02 / once))  # ~20 ms of work per repeat
        best = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            for _call in range(inner):
                engine.place_nodes(ids)
            best = min(best, (time.perf_counter() - t0) / inner)
        assert engine.uploads == 1, "cached artifact must not re-upload"
        csv_print(f"h2h_calc_{alg}_n{n_nodes}", best / batch * 1e6, "us_per_id")


def _maxvar(counts: np.ndarray) -> float:
    return float((counts.max() - counts.mean()) / counts.mean())


def calibration_us(repeats: int = 5) -> float:
    """Machine-speed yardstick: best-of-``repeats`` time (us) of a FIXED
    integer workload (fmix32 over 2**21 lanes -- the same op family the
    placement kernels are made of).

    The perf gate divides every timing comparison by the fresh/baseline
    calibration ratio (check_regression.py), so committed baselines stay
    meaningful on a slower/faster runner and transient machine-wide
    slowdowns do not read as algorithmic regressions."""
    from repro.core.rng import fmix32_np

    x = np.arange(1 << 21, dtype=np.uint32)
    fmix32_np(x)  # warm the allocator
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fmix32_np(x)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_uniformity(csv_print, n_nodes: int, data_per_node: int) -> None:
    """Figs. 6-7: max variability, uniform and capacity-weighted clusters."""
    ids = _ids(n_nodes * data_per_node)
    for alg in ALGORITHMS:
        cluster = make_uniform_cluster(n_nodes)
        owners = _engine(cluster, alg).place_nodes(ids)
        counts = np.bincount(owners, minlength=n_nodes)
        csv_print(
            f"h2h_uniformity_{alg}_n{n_nodes}_dpn{data_per_node}",
            100 * _maxvar(counts),
            "maxvar_pct",
        )
    # capacity-weighted: nodes 0..N/2 hold twice the capacity.  CH ignores
    # weights (the paper's unweighted ring); the others must track them.
    caps = [2.0 if i < n_nodes // 2 else 1.0 for i in range(n_nodes)]
    for alg in ("asura", "wrh", "rs"):
        cluster = make_cluster(caps)
        owners = _engine(cluster, alg).place_nodes(ids)
        counts = np.bincount(owners, minlength=n_nodes).astype(np.float64)
        # normalize per-capacity before the variability statistic
        loads = counts / np.asarray(caps)
        csv_print(
            f"h2h_uniformity_weighted_{alg}_n{n_nodes}",
            100 * _maxvar(loads),
            "maxvar_pct_per_cap",
        )


def bench_movement(csv_print, n_nodes: int, n_data: int) -> None:
    """Section 6.D: moved fraction on add/remove vs optimal, through the
    engine's versioned artifacts (place_nodes_at pins the v table)."""
    ids = _ids(n_data)
    for alg in ALGORITHMS:
        cluster = make_uniform_cluster(n_nodes)
        engine = _engine(cluster, alg)
        before = engine.place_nodes(ids)
        v0 = cluster.version
        cluster.add_node(n_nodes, 1.0)
        after = engine.place_nodes(ids)
        assert np.array_equal(engine.place_nodes_at(ids, v0), before)
        moved = before != after
        csv_print(
            f"h2h_move_add_{alg}_pct",
            100 * moved.mean(),
            f"optimal {100 / (n_nodes + 1):.2f}",
        )
        csv_print(
            f"h2h_move_add_{alg}_wrong_dest",
            int((after[moved] != n_nodes).sum()),
            "must_be_0_if_optimal",
        )
        before = after
        cluster.remove_node(7)
        after = engine.place_nodes(ids)
        moved = before != after
        csv_print(
            f"h2h_move_rm_{alg}_pct",
            100 * moved.mean(),
            f"optimal {100 / (n_nodes + 1):.2f}",
        )
        csv_print(
            f"h2h_move_rm_{alg}_wrong_src",
            int((before[moved] != 7).sum()),
            "must_be_0_if_optimal",
        )


def bench_memory(csv_print, node_counts) -> None:
    """Table 2: lookup-state bytes per algorithm at N nodes."""
    for n_nodes in node_counts:
        cluster = make_uniform_cluster(n_nodes)
        for alg in ALGORITHMS:
            engine = _engine(cluster, alg)
            art = engine.artifact(alg)
            n_bytes = (
                cluster.memory_bytes() if alg == "asura" else art.memory_bytes()
            )
            csv_print(f"h2h_memory_{alg}_n{n_nodes}", n_bytes, "bytes")


def bench_scaling(csv_print, quick: bool) -> None:
    """DESIGN.md section 11: the mesh-sharded uniformity sweep's weak and
    strong scaling over 1/2/4(/8) forced host devices (one subprocess per
    device count; results shared with the movement/migrate suites'
    scaling entries via benchmarks/scaling.py's cache)."""
    from .scaling import emit

    emit(csv_print, quick, "h2h_sharded_uniformity", "uniformity")


def run(csv_print, quick: bool = False) -> None:
    n_nodes = QUICK_NODES if quick else NODES
    batch = QUICK_BATCH if quick else BATCH
    dpn = QUICK_DATA_PER_NODE if quick else DATA_PER_NODE
    move_data = QUICK_MOVE_DATA if quick else MOVE_DATA
    csv_print("h2h_calibration", calibration_us(), "us_calibration")
    bench_calc(csv_print, n_nodes, batch)
    bench_uniformity(csv_print, n_nodes, dpn)
    bench_movement(csv_print, n_nodes, move_data)
    bench_memory(csv_print, MEMORY_NODES if not quick else (100,))
    bench_scaling(csv_print, quick)
