"""Durability benchmark: flat vs failure-domain-aware placement under
identical failure traces (DESIGN.md section 14).

The headline the suite exists to defend: at R=3 with correlated rack
failures, DOMAIN-AWARE placement loses orders of magnitude fewer objects
than flat R-way placement at essentially equal movement cost.  Both
policies place the same objects over the same nodes and replay the SAME
seeded failure schedule through the real recovery stack (heartbeat
detection -> serialized ``MigrationDriver`` repairs -> ingress-budgeted
``ThrottledMover`` rounds on a virtual clock), so the loss delta is
attributable to placement alone.

The suite HARD-FAILS (raises) if the domain-aware policy does not lose
strictly fewer objects than flat -- the correctness half of the headline
is CI-gated through the benchmark job itself, not just recorded.  The
movement-parity and throughput entries land in ``BENCH_durability.json``
for the perf gate (``check_regression.py``).
"""

from __future__ import annotations

import time

from repro.runtime.durability import compare_policies, movement_on_node_add

from .head_to_head import calibration_us

# One seeded decade (quick) / double-length run (full).  The rates are
# storage-fleet-plausible: node MTTF a few years, a correlated whole-rack
# outage every ~15 rack-years (shared switch / PDU).
QUICK = dict(
    n_domains=6, nodes_per_domain=4, n_objects=20_000, years=10.0,
    mttf_node_years=3.0, mttf_domain_years=15.0, seed=7,
)
FULL = dict(
    n_domains=12, nodes_per_domain=8, n_objects=200_000, years=20.0,
    mttf_node_years=3.0, mttf_domain_years=15.0, seed=7,
)


def _topology(cfg: dict) -> dict[int, dict[int, float]]:
    per = cfg["nodes_per_domain"]
    return {
        d: {d * per + i: 1.0 for i in range(per)}
        for d in range(cfg["n_domains"])
    }


def run(csv_print, quick: bool = False) -> None:
    csv_print("durability_calibration", calibration_us(), "us_calibration")
    cfg = QUICK if quick else FULL
    topology = _topology(cfg)
    R = 3

    t0 = time.perf_counter()
    reports = compare_policies(
        topology,
        n_objects=cfg["n_objects"],
        n_replicas=R,
        years=cfg["years"],
        mttf_node_years=cfg["mttf_node_years"],
        mttf_domain_years=cfg["mttf_domain_years"],
        seed=cfg["seed"],
    )
    sim_s = time.perf_counter() - t0
    flat, hier = reports["flat"], reports["hier"]

    label = f"R{R}_{cfg['n_domains']}x{cfg['nodes_per_domain']}_{cfg['years']:g}y"
    csv_print("durability_trace_node_failures", flat.node_failures, label)
    csv_print("durability_trace_domain_failures", flat.domain_failures, label)
    csv_print("durability_flat_objects_lost", flat.objects_lost, "objects")
    csv_print("durability_hier_objects_lost", hier.objects_lost, "objects")
    csv_print("durability_flat_loss_incidents", flat.loss_incidents, "events")
    csv_print("durability_hier_loss_incidents", hier.loss_incidents, "events")
    csv_print(
        "durability_flat_loss_ppm",
        round(1e6 * flat.data_loss_probability, 3),
        "ppm_objects",
    )
    csv_print(
        "durability_hier_loss_ppm",
        round(1e6 * hier.data_loss_probability, 3),
        "ppm_objects",
    )
    # loss-reduction factor; with zero hier losses report the flat count
    # (the factor is unbounded -- every flat loss is one hier avoided)
    factor = (
        flat.objects_lost / hier.objects_lost
        if hier.objects_lost
        else float(flat.objects_lost)
    )
    csv_print("durability_loss_reduction_x", round(factor, 1), "x_fewer_lost")

    # equal movement cost, both halves: repair traffic under the trace and
    # reshuffle mass on a planned node add
    csv_print("durability_flat_repair_rows", flat.rows_repaired, "rows")
    csv_print("durability_hier_repair_rows", hier.rows_repaired, "rows")
    parity = (
        100.0 * hier.rows_repaired / flat.rows_repaired
        if flat.rows_repaired
        else 100.0
    )
    csv_print("durability_repair_parity_pct", round(parity, 2), "pct_of_flat")
    moved = movement_on_node_add(
        topology, n_objects=min(cfg["n_objects"], 50_000), n_replicas=R
    )
    csv_print(
        "durability_move_on_add_flat_pct", round(100 * moved["flat"], 3), "pct_rows"
    )
    csv_print(
        "durability_move_on_add_hier_pct", round(100 * moved["hier"], 3), "pct_rows"
    )

    # timed entry for the perf gate: virtual-decade simulation throughput
    total_rows = flat.rows_repaired + hier.rows_repaired
    csv_print(
        "durability_sim_repair_rows_per_s", int(total_rows / max(sim_s, 1e-9)),
        "rows_per_s",
    )

    # the CI-gated headline: domain awareness must strictly win
    if not (hier.objects_lost < flat.objects_lost):
        raise RuntimeError(
            "durability headline violated: domain-aware placement lost "
            f"{hier.objects_lost} objects vs flat {flat.objects_lost} under "
            f"the same trace ({label}, seed {cfg['seed']})"
        )


if __name__ == "__main__":
    run(lambda *a: print(*a, sep=","), quick=True)
