"""Paper section 2.A / Fig. 3 + section III.E: capacity-proportional
(flexible) data distribution.

ASURA encodes capacity as segment length (fully flexible); Straw can weight
straws; CH approximates capacity by virtual-node count (coarse).  We place
400k data on a heterogeneous 4-node cluster and report the L1 gap between
achieved and target fractions for each algorithm."""

from __future__ import annotations

import numpy as np

from repro.core import ConsistentHashRing, StrawBucket, make_cluster
from repro.core.rng import draw_u32_np

CAPS = [0.5, 1.0, 1.5, 3.0]
N_DATA = 400_000


def run(csv_print) -> None:
    ids = np.arange(N_DATA, dtype=np.uint32)
    target = np.array(CAPS) / sum(CAPS)
    # ASURA: segment lengths == capacities
    cluster = make_cluster(CAPS)
    owners = cluster.place_nodes(ids)
    frac = np.bincount(owners, minlength=4) / N_DATA
    csv_print("capacity_asura_l1_gap", float(np.abs(frac - target).sum()), str(frac.round(4)))
    # Straw with weights
    straw = StrawBucket(range(4), weights=CAPS)
    frac = np.bincount(straw.place(ids), minlength=4) / N_DATA
    csv_print("capacity_straw_l1_gap", float(np.abs(frac - target).sum()), str(frac.round(4)))
    # CH: virtual-node counts proportional to capacity (coarse)
    base_vn = 100
    ring_nodes = []
    vns = [max(1, int(round(c * base_vn))) for c in CAPS]
    # build a ring with per-node virtual counts by replicating node ids
    hashes = []
    owners_l = []
    for nid, vn in enumerate(vns):
        h = draw_u32_np(
            np.full(vn, nid, dtype=np.uint32), np.uint32(0), np.arange(vn, dtype=np.uint32)
        )
        hashes.append(h)
        owners_l.append(np.full(vn, nid, dtype=np.uint32))
    ring_h = np.concatenate(hashes)
    ring_o = np.concatenate(owners_l)
    order = np.argsort(ring_h, kind="stable")
    ring_h, ring_o = ring_h[order], ring_o[order]
    from repro.core.rng import fmix32_np

    idx = np.searchsorted(ring_h, fmix32_np(ids), side="left")
    idx = np.where(idx == len(ring_h), 0, idx)
    frac = np.bincount(ring_o[idx], minlength=4) / N_DATA
    csv_print("capacity_ch_l1_gap", float(np.abs(frac - target).sum()), str(frac.round(4)))
