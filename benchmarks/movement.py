"""Paper section 2.A: optimal data movement on node addition/removal.

Measures the moved fraction for ASURA / CH / Straw against the theoretical
optimum (cap_new / cap_total on addition; cap_victim / cap_total on
removal), and verifies the direction constraint (moves only to the new node
/ only off the removed node)."""

from __future__ import annotations

import numpy as np

from repro.core import ConsistentHashRing, StrawBucket, make_uniform_cluster

N_NODES = 50
N_DATA = 200_000


def run(csv_print) -> None:
    ids = np.arange(N_DATA, dtype=np.uint32)
    # ASURA
    cluster = make_uniform_cluster(N_NODES)
    before = cluster.place_nodes(ids)
    cluster.add_node(N_NODES, 1.0)
    after = cluster.place_nodes(ids)
    moved = before != after
    csv_print("move_add_asura_pct", 100 * moved.mean(), f"optimal {100/(N_NODES+1):.2f}")
    csv_print("move_add_asura_wrong_dest", int((after[moved] != N_NODES).sum()), "must_be_0")
    before = after
    cluster.remove_node(7)
    after = cluster.place_nodes(ids)
    moved = before != after
    csv_print("move_rm_asura_pct", 100 * moved.mean(), f"optimal {100/(N_NODES+1):.2f}")
    csv_print("move_rm_asura_wrong_src", int((before[moved] != 7).sum()), "must_be_0")
    # Consistent Hashing
    ring = ConsistentHashRing(range(N_NODES), virtual_nodes=100)
    before = ring.place(ids)
    ring2 = ConsistentHashRing(range(N_NODES + 1), virtual_nodes=100)
    after = ring2.place(ids)
    moved = before != after
    csv_print("move_add_ch_pct", 100 * moved.mean(), f"optimal {100/(N_NODES+1):.2f}")
    csv_print("move_add_ch_wrong_dest", int((after[moved] != N_NODES).sum()), "must_be_0")
    # Straw
    straw = StrawBucket(range(N_NODES))
    before = straw.place(ids)
    straw2 = StrawBucket(range(N_NODES + 1))
    after = straw2.place(ids)
    moved = before != after
    csv_print("move_add_straw_pct", 100 * moved.mean(), f"optimal {100/(N_NODES+1):.2f}")
    csv_print("move_add_straw_wrong_dest", int((after[moved] != N_NODES).sum()), "must_be_0")
