"""Paper section 2.A: optimal data movement on node addition/removal.

Measures the moved fraction for ASURA / CH / Straw against the theoretical
optimum (cap_new / cap_total on addition; cap_victim / cap_total on
removal), and verifies the direction constraint (moves only to the new node
/ only off the removed node).

Also benchmarks the migration subsystem's device streaming planner
(DESIGN.md section 8) at scale: moved fraction vs optimal and planner
throughput (ids/s) for the chunked dual-version diff sweep, with and
without the ADDITION-NUMBER prefilter.

REPLICA movement (DESIGN.md section 10): the paper's characteristic 1
claims minimal movement *even if data are replicated* -- the
``move_*_replica_*`` entries measure the per-slot replica planner on
add/remove events against the brute-force minimal set diff (excess must
be 0) and the direction constraints (no wrong-direction replica moves),
plus replica-planner throughput.  A ``movement_calibration`` entry lets
the CI perf gate normalize the timed entries by machine speed.
``--quick`` shrinks every population for the CI smoke."""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    ConsistentHashRing,
    PlacementEngine,
    StrawBucket,
    make_uniform_cluster,
)
from repro.migrate import MigrationPlanner

from .head_to_head import calibration_us

N_NODES = 50
N_DATA = 200_000

# Streaming-planner scale point (the ISSUE-3 acceptance config).
PLANNER_NODES = 1024
PLANNER_IDS = 10_000_000
PLANNER_CHUNK = 1 << 20

# Replica-movement scale point (ISSUE-5): R-way sets, host planner path.
REPLICA_NODES = 40
REPLICA_IDS = 100_000
N_REPLICAS = 3


def _classic_comparisons(csv_print, n_nodes: int, n_data: int) -> None:
    ids = np.arange(n_data, dtype=np.uint32)
    # ASURA
    cluster = make_uniform_cluster(n_nodes)
    before = cluster.place_nodes(ids)
    cluster.add_node(n_nodes, 1.0)
    after = cluster.place_nodes(ids)
    moved = before != after
    csv_print("move_add_asura_pct", 100 * moved.mean(), f"optimal {100/(n_nodes+1):.2f}")
    csv_print("move_add_asura_wrong_dest", int((after[moved] != n_nodes).sum()), "must_be_0")
    before = after
    cluster.remove_node(7)
    after = cluster.place_nodes(ids)
    moved = before != after
    csv_print("move_rm_asura_pct", 100 * moved.mean(), f"optimal {100/(n_nodes+1):.2f}")
    csv_print("move_rm_asura_wrong_src", int((before[moved] != 7).sum()), "must_be_0")
    # Consistent Hashing
    ring = ConsistentHashRing(range(n_nodes), virtual_nodes=100)
    before = ring.place(ids)
    ring2 = ConsistentHashRing(range(n_nodes + 1), virtual_nodes=100)
    after = ring2.place(ids)
    moved = before != after
    csv_print("move_add_ch_pct", 100 * moved.mean(), f"optimal {100/(n_nodes+1):.2f}")
    csv_print("move_add_ch_wrong_dest", int((after[moved] != n_nodes).sum()), "must_be_0")
    # Straw
    straw = StrawBucket(range(n_nodes))
    before = straw.place(ids)
    straw2 = StrawBucket(range(n_nodes + 1))
    after = straw2.place(ids)
    moved = before != after
    csv_print("move_add_straw_pct", 100 * moved.mean(), f"optimal {100/(n_nodes+1):.2f}")
    csv_print("move_add_straw_wrong_dest", int((after[moved] != n_nodes).sum()), "must_be_0")


def _streaming_planner(csv_print, n_nodes: int, n_ids: int, chunk: int) -> None:
    """Device streaming planner at scale: one add-node event, chunked sweep."""
    ids = np.arange(n_ids, dtype=np.uint32)
    cluster = make_uniform_cluster(n_nodes)
    engine = PlacementEngine(cluster, backend="ref")  # the device path on CPU
    engine.artifact()
    v_from = cluster.version
    new_segs = cluster.add_node(n_nodes, 1.0)
    planner = MigrationPlanner(engine)

    # warm-up: compile the dual-diff at the chunk shape and the tail shape
    warm = [ids[:chunk]]
    if n_ids % chunk:
        warm.append(ids[-(n_ids % chunk):])
    for _, moved, _, _ in planner.plan_stream(warm, v_from, cluster.version):
        moved.block_until_ready()

    t0 = time.perf_counter()
    n_moved = 0
    for _, moved, _, _ in planner.plan_stream(
        planner.chunked(ids, chunk), v_from, cluster.version
    ):
        n_moved += int(np.asarray(moved).sum())
    dt = time.perf_counter() - t0
    csv_print(
        "migrate_stream_moved_pct",
        100 * n_moved / n_ids,
        f"optimal {100/(n_nodes+1):.3f}",
    )
    csv_print("migrate_stream_ids_per_s", int(n_ids / dt), f"{n_nodes}_nodes")

    # Steady state: the first call pays the AN/diff jit compiles at the
    # prefilter's bucket shapes; time the second.
    plan = planner.plan(
        ids, v_from, cluster.version, chunk=chunk, max_new_seg=max(new_segs)
    )
    assert plan.n_moves == n_moved  # the prefilter must not change the plan
    t0 = time.perf_counter()
    planner.plan(ids, v_from, cluster.version, chunk=chunk, max_new_seg=max(new_segs))
    dt = time.perf_counter() - t0
    csv_print("migrate_prefilter_ids_per_s", int(n_ids / dt), "an_prefilter")


def _replica_movement(csv_print, n_nodes: int, n_ids: int, n_replicas: int) -> None:
    """Section-5 replica movement: per-slot plans vs the minimal set diff."""
    ids = np.arange(n_ids, dtype=np.uint32)
    cluster = make_uniform_cluster(n_nodes)
    engine = cluster.engine
    planner = MigrationPlanner(engine)
    mass = n_replicas * n_ids

    before = engine.place_replica_nodes(ids, n_replicas)
    v0 = cluster.version
    cluster.add_node(n_nodes, 1.0)
    t0 = time.perf_counter()
    plan = planner.plan_replicas(ids, v0, cluster.version, n_replicas)
    dt = time.perf_counter() - t0
    after = engine.place_replica_nodes(ids, n_replicas)
    minimal = int((~(after[:, :, None] == before[:, None, :]).any(axis=2)).sum())
    csv_print(
        "move_add_replica_pct",
        100 * plan.n_moves / mass,
        f"R{n_replicas}_optimal {100/(n_nodes+1):.2f}",
    )
    csv_print("move_add_replica_excess", plan.n_moves - minimal, "must_be_0")
    csv_print(
        "move_add_replica_wrong_dest",
        int((plan.dst != n_nodes).sum()),
        "must_be_0",
    )
    csv_print("move_replica_plan_ids_per_s", int(n_ids / dt), "ids_per_s")

    before = after
    victim = 7
    v1 = cluster.version
    cluster.remove_node(victim)
    plan = planner.plan_replicas(ids, v1, cluster.version, n_replicas)
    after = engine.place_replica_nodes(ids, n_replicas)
    minimal = int((~(after[:, :, None] == before[:, None, :]).any(axis=2)).sum())
    csv_print(
        "move_rm_replica_pct",
        100 * plan.n_moves / mass,
        f"R{n_replicas}_optimal {100/(n_nodes+1):.2f}",
    )
    csv_print("move_rm_replica_excess", plan.n_moves - minimal, "must_be_0")
    csv_print(
        "move_rm_replica_wrong_src",
        int((plan.src != victim).sum()),
        "must_be_0",
    )


def _sharded_planner_scaling(csv_print, quick: bool) -> None:
    """DESIGN.md section 11: the mesh-sharded streaming planner's weak and
    strong scaling over 1/2/4(/8) forced host devices (subprocess workers,
    shared with the head_to_head/migrate scaling entries)."""
    from .scaling import emit

    emit(csv_print, quick, "migrate_stream_sharded", "planner")


def run(csv_print, quick: bool = False) -> None:
    csv_print("movement_calibration", calibration_us(), "us_calibration")
    if quick:
        _classic_comparisons(csv_print, n_nodes=20, n_data=20_000)
        _streaming_planner(csv_print, n_nodes=128, n_ids=200_000, chunk=1 << 16)
        _replica_movement(csv_print, n_nodes=16, n_ids=20_000, n_replicas=3)
    else:
        _classic_comparisons(csv_print, N_NODES, N_DATA)
        _streaming_planner(csv_print, PLANNER_NODES, PLANNER_IDS, PLANNER_CHUNK)
        _replica_movement(csv_print, REPLICA_NODES, REPLICA_IDS, N_REPLICAS)
    _sharded_planner_scaling(csv_print, quick)
