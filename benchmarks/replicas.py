"""Replica-placement throughput (paper section 5.A) across implementations.

Measures, for R in {2, 3} over node counts:

  * scalar per-call latency (``place_replicas_scalar`` -- paper-comparable),
  * NumPy batch per-id throughput with per-call table re-derivation (the
    pre-engine path every consumer used),
  * engine per-id throughput (cached versioned table artifact; the table is
    canonicalized once per membership version and reused),
  * the jnp reference path via a prebuilt device table (the kernel-shaped
    code path; the Pallas kernel itself is this exact loop compiled on TPU),

so the engine/kernel speedup is measured, not asserted.  Also prints the
engine's upload counter after the timed loop (must be 1: one table
materialization per cluster version).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import make_uniform_cluster
from repro.core.asura import place_replicas_batch, place_replicas_scalar

NODE_COUNTS = (10, 100, 400)
REPLICAS = (2, 3)
BATCH = 50_000
SCALAR_CALLS = 500
REPEATS = 5


def _time(fn, *args) -> float:
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0


def run(csv_print) -> None:
    for n in NODE_COUNTS:
        cluster = make_uniform_cluster(n)
        lengths = cluster.seg_lengths()
        node_of = cluster.seg_to_node()
        engine = cluster.engine
        ids = np.arange(BATCH, dtype=np.uint32)
        for r in REPLICAS:
            if r > n:
                continue
            # scalar oracle latency
            t0 = time.perf_counter()
            for i in range(SCALAR_CALLS):
                place_replicas_scalar(i, lengths, node_of, r)
            scalar_us = (time.perf_counter() - t0) / SCALAR_CALLS * 1e6
            csv_print(f"replicas_scalar_r{r}_n{n}", scalar_us, "us_per_call")
            # NumPy batch, table re-derived per call (pre-engine behavior)
            place_replicas_batch(ids[:1000], lengths, node_of, r)  # warm
            dt = min(
                _time(place_replicas_batch, ids, lengths, node_of, r)
                for _ in range(REPEATS)
            )
            csv_print(f"replicas_batch_r{r}_n{n}", dt / BATCH * 1e6, "us_per_id")
            # engine: cached table artifact across calls
            engine.place_replicas(ids[:1000], r)  # warm (builds the artifact)
            dt = min(
                _time(engine.place_replicas, ids, r) for _ in range(REPEATS)
            )
            csv_print(f"replicas_engine_r{r}_n{n}", dt / BATCH * 1e6, "us_per_id")
        csv_print(f"replicas_engine_uploads_n{n}", engine.uploads, "table_uploads")
