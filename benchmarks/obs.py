"""Observability overhead benchmark (DESIGN.md section 13).

The device-plane contract is that metrics are FREE on the serving hot
path: the slab accumulation fuses into the batch step (a handful of
in-register scatter-adds), so an instrumented driver must run at the
uninstrumented driver's speed.  This suite measures exactly that:

  * ``obs_base_ids_per_s`` / ``obs_instrumented_ids_per_s`` -- the fused
    zipf+pow2 ASURA step with metrics off and on (gated like the serve
    throughput entries),
  * ``obs_overhead_ratio`` -- instrumented / uninstrumented wall time
    per step, best-of-N interleaved so machine-speed drift cancels.
    The <= 1.05 acceptance ceiling is asserted HERE (absolute -- both
    sides run seconds apart in this process) AND gated lower-better
    against the curated baseline,
  * ``obs_snapshot_us`` -- one ``MetricsRegistry.snapshot()`` drain
    (the single deliberate device->host transfer, informational),

and exports the instrumented run's structured events as
``BENCH_obs_events.jsonl`` next to the BENCH json (CI uploads it as a
workflow artifact: uploads, spans, the serve snapshot, counters).

A ``obs_calibration`` entry (the shared fmix32 yardstick) lets the CI
gate normalize the timed entries by machine speed.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import PlacementEngine, make_uniform_cluster
from repro.obs import MetricsRegistry, TraceLedger
from repro.serve import RequestStreamDriver

from .head_to_head import calibration_us

R = 3
SEED = 11


def _make(engine, metrics, ledger, *, batch, n_keys):
    return RequestStreamDriver(
        engine, batch=batch, n_keys=n_keys, law="zipf", alpha=1.1,
        n_replicas=R, policy="pow2", seed=SEED,
        metrics=metrics, ledger=ledger,
    )


def _time_steps(driver, steps: int) -> float:
    driver.reset()
    t0 = time.perf_counter()
    for _ in range(steps):
        chosen = driver.step()
    chosen.block_until_ready()
    return time.perf_counter() - t0


def run(csv_print, quick: bool = False) -> None:
    csv_print("obs_calibration", calibration_us(), "us_calibration")
    n_nodes = 16 if quick else 64
    n_keys = 1 << 16 if quick else 1 << 20
    batch, steps = (1 << 13, 8) if quick else (1 << 16, 16)
    repeats = 3 if quick else 5

    cluster = make_uniform_cluster(n_nodes)
    engine = PlacementEngine(cluster, backend="ref")
    ledger = TraceLedger()
    registry = MetricsRegistry()
    base = _make(engine, None, None, batch=batch, n_keys=n_keys)
    inst = _make(engine, registry, ledger, batch=batch, n_keys=n_keys)

    # warm both fused steps outside the clock
    for d in (base, inst):
        d.step()
        d.step().block_until_ready()

    # best-of-N, interleaved: one base run then one instrumented run per
    # repeat, so clock drift / thermal state hits both sides equally
    best_base = best_inst = float("inf")
    for _ in range(repeats):
        best_base = min(best_base, _time_steps(base, steps))
        best_inst = min(best_inst, _time_steps(inst, steps))

    csv_print("obs_base_ids_per_s", int(steps * batch / best_base), "ids_per_s")
    csv_print(
        "obs_instrumented_ids_per_s",
        int(steps * batch / best_inst),
        "ids_per_s",
    )
    ratio = round(best_inst / best_base, 4)
    if ratio > 1.05:
        raise RuntimeError(
            f"instrumented fused step is {ratio}x the uninstrumented step "
            "(acceptance ceiling 1.05x) -- the slab accumulation stopped "
            "fusing"
        )
    csv_print("obs_overhead_ratio", ratio, "x_overhead")

    # the ONE deliberate drain transfer (outside the hot loop by contract)
    t0 = time.perf_counter()
    snap = registry.snapshot()
    csv_print(
        "obs_snapshot_us", round(1e6 * (time.perf_counter() - t0), 1), "us"
    )
    served = snap["serve.served"].astype(np.int64)
    routed = int(snap["serve.routed.asura.pow2"])
    if int(served.sum()) != routed:
        raise RuntimeError(
            f"drained served histogram ({int(served.sum())}) does not match "
            f"the routed counter ({routed})"
        )

    # structured-event export: the CI artifact showing the run's telemetry
    inst.snapshot()  # one serve.snapshot event with skew/q_p99
    out_dir = os.environ.get("BENCH_OUT_DIR", ".")
    path = os.path.join(out_dir, "BENCH_obs_events.jsonl")
    # fold the engine's upload/span events into the exported ledger view
    for ev in engine.ledger.events():
        ledger._events.append(ev)
    for name, count in engine.ledger.counters.items():
        ledger.incr(name, count)
    n_events = ledger.export_jsonl(path)
    csv_print("obs_events_exported", n_events, "events")
