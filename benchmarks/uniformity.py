"""Paper Figs. 6-8: maximum variability of the data distribution.

max variability = (max_node_count - mean) / mean, reported in percent, vs
data-per-node, for ASURA and Consistent Hashing at several virtual-node
counts.  The paper sweeps nodes in {100, 1k, 10k}, data/node up to 1e6 with
20 repeats; we reduce to fit the CPU budget while preserving the crossing
the paper highlights: CH's variability floors out at a level set by VN while
ASURA keeps improving ~ 1/sqrt(data/node) (single variability).
"""

from __future__ import annotations

import numpy as np

from repro.core import ConsistentHashRing, make_uniform_cluster
from repro.core.asura import place_batch
from repro.core.rng import draw_u32_np

REPEATS = 3


def max_variability(counts: np.ndarray) -> float:
    return float((counts.max() - counts.mean()) / counts.mean())


def _ids(n: int, rep: int) -> np.ndarray:
    base = np.arange(n, dtype=np.uint32)
    return draw_u32_np(base, np.uint32(500 + rep), np.zeros_like(base))


def bench_asura(n_nodes: int, data_per_node: int) -> float:
    cluster = make_uniform_cluster(n_nodes)
    lengths = cluster.seg_lengths()
    out = []
    for rep in range(REPEATS):
        ids = _ids(n_nodes * data_per_node, rep)
        segs = place_batch(ids, lengths)
        out.append(max_variability(np.bincount(segs, minlength=n_nodes)))
    return float(np.mean(out))


def bench_ch(n_nodes: int, data_per_node: int, virtual_nodes: int) -> float:
    out = []
    for rep in range(REPEATS):
        ring = ConsistentHashRing(range(n_nodes), virtual_nodes=virtual_nodes)
        ids = _ids(n_nodes * data_per_node, rep)
        owners = ring.place(ids)
        out.append(max_variability(np.bincount(owners, minlength=n_nodes)))
    return float(np.mean(out))


def run(csv_print) -> None:
    for n_nodes in (100, 1000):
        for dpn in (1000, 10_000, 100_000):
            if n_nodes * dpn > 2e7:
                continue
            csv_print(
                f"fig67_asura_n{n_nodes}_dpn{dpn}",
                100 * bench_asura(n_nodes, dpn),
                "maxvar_pct",
            )
            for vn in (100, 1000):
                csv_print(
                    f"fig67_ch_vn{vn}_n{n_nodes}_dpn{dpn}",
                    100 * bench_ch(n_nodes, dpn, vn),
                    "maxvar_pct",
                )
    # the paper's best case: 0.32% (ASURA) vs 3.3% (CH) -- high data/node
    csv_print("fig67_asura_best_n100_dpn100k", 100 * bench_asura(100, 100_000), "maxvar_pct")
    csv_print(
        "fig67_ch_best_vn1000_n100_dpn100k",
        100 * bench_ch(100, 100_000, 1000),
        "maxvar_pct",
    )
