"""Weak/strong scaling of the sharded placement/diff sweeps (DESIGN.md 11).

The device count is locked at first jax init, so every mesh size gets its
own SUBPROCESS worker: the parent calls ``measure(quick)`` which launches

    python -m benchmarks.scaling --worker --devices N [--quick]

once per device count (``--xla_force_host_platform_device_count=N`` set in
the worker's env before its first jax import -- the ``launch/dryrun.py``
trick).  One worker measures all three sweep families -- uniformity
histogram (``ShardedSweep.histogram``), single-owner planner stream and
R=3 replica planner stream (``MigrationPlanner.plan*_stream(mesh=...)``)
-- at both a FIXED total population (strong scaling) and a FIXED
per-device population (weak scaling), and prints one JSON line.

Results are cached per process, so the head_to_head / movement / migrate
suites emitting scaling entries in one ``benchmarks.run`` invocation share
a single worker sweep (4 subprocesses quick, not 12).

Forced host devices time-slice the host's real cores: speedups track the
physical core count, not the forced device count (a single-core runner
measures ~1x -- the committed baselines record what the baseline machine
saw, and the perf gate's calibration normalization absorbs machine
differences).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

N_NODES = 128

# strong: fixed total ids; weak: fixed ids PER DEVICE.
STRONG_IDS = 1 << 22
WEAK_IDS_PER_DEV = 1 << 20
CHUNK = 1 << 20
DEVICE_COUNTS = (1, 2, 4, 8)

QUICK_STRONG_IDS = 1 << 19
QUICK_WEAK_IDS_PER_DEV = 1 << 17
QUICK_CHUNK = 1 << 16
QUICK_DEVICE_COUNTS = (1, 2, 4)

N_REPLICAS = 3

METRICS = ("uniformity", "planner", "replica_planner")

_CACHE: dict[bool, dict[int, dict]] = {}


def device_counts(quick: bool) -> tuple[int, ...]:
    return QUICK_DEVICE_COUNTS if quick else DEVICE_COUNTS


def measure(quick: bool) -> dict[int, dict]:
    """{device_count: worker result dict}, one subprocess per count,
    cached for the life of the benchmark process."""
    quick = bool(quick)
    if quick not in _CACHE:
        _CACHE[quick] = {n: _run_worker(n, quick) for n in device_counts(quick)}
    return _CACHE[quick]


def _run_worker(n_devices: int, quick: bool) -> dict:
    cmd = [sys.executable, "-m", "benchmarks.scaling", "--worker",
           "--devices", str(n_devices)]
    if quick:
        cmd.append("--quick")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices} "
        + env.get("XLA_FLAGS", "")
    ).strip()
    proc = subprocess.run(
        cmd, capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"scaling worker ({n_devices} devices) failed:\n{proc.stderr[-2000:]}"
        )
    for line in reversed(proc.stdout.strip().splitlines()):
        if line.startswith("{"):
            return json.loads(line)
    raise RuntimeError(
        f"scaling worker ({n_devices} devices) printed no JSON:\n"
        f"{proc.stdout[-2000:]}"
    )


def emit(csv_print, quick: bool, prefix: str, metric: str) -> None:
    """Emit one sweep family's scaling entries into a suite's BENCH JSON:
    per-device-count throughputs plus the 4-device strong/weak speedup
    ratios the acceptance gate watches (unit ``x_speedup`` -- higher is
    better, compared raw: machine speed cancels in the ratio)."""
    if metric not in METRICS:
        raise ValueError(f"metric must be one of {METRICS}, got {metric!r}")
    res = measure(quick)
    for n, r in sorted(res.items()):
        csv_print(
            f"{prefix}_strong_{n}dev_ids_per_s",
            int(r[f"{metric}_strong_ids_per_s"]),
            "ids_per_s",
        )
        csv_print(
            f"{prefix}_weak_{n}dev_ids_per_s",
            int(r[f"{metric}_weak_ids_per_s"]),
            "ids_per_s",
        )
    base = res[min(res)]
    top = 4 if 4 in res else max(res)
    for kind in ("strong", "weak"):
        ratio = (
            res[top][f"{metric}_{kind}_ids_per_s"]
            / max(base[f"{metric}_{kind}_ids_per_s"], 1e-9)
        )
        csv_print(f"{prefix}_{kind}_{top}dev_x_speedup", ratio, "x_speedup")


# ---------------------------------------------------------------------------
# Worker (runs under --xla_force_host_platform_device_count=N)
# ---------------------------------------------------------------------------


def _best_of(fn, repeats: int = 3) -> float:
    fn()  # warm: compile + artifact upload
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _worker(n_devices: int, quick: bool) -> dict:
    import numpy as np

    from repro.core import PlacementEngine, make_uniform_cluster
    from repro.launch.placement_mesh import ShardedSweep, make_data_mesh
    from repro.migrate import MigrationPlanner

    strong = QUICK_STRONG_IDS if quick else STRONG_IDS
    weak = (QUICK_WEAK_IDS_PER_DEV if quick else WEAK_IDS_PER_DEV) * n_devices
    chunk = QUICK_CHUNK if quick else CHUNK

    cluster = make_uniform_cluster(N_NODES)
    engine = PlacementEngine(cluster, backend="ref")
    mesh = make_data_mesh(n_devices)
    sweep = ShardedSweep(engine, mesh)
    engine.artifact()
    v0 = cluster.version
    cluster.add_node(N_NODES, 1.0)
    v1 = cluster.version
    planner = MigrationPlanner(engine)

    out: dict = {"devices": n_devices, "quick": quick}
    for kind, n_ids in (("strong", strong), ("weak", weak)):
        ids = np.arange(n_ids, dtype=np.uint32)

        out[f"uniformity_{kind}_ids_per_s"] = n_ids / _best_of(
            lambda: sweep.histogram(ids, N_NODES + 1)
        )

        def drain_plan():
            for _, moved, _, _ in planner.plan_stream(
                planner.chunked(ids, chunk), v0, v1, mesh=sweep
            ):
                moved.block_until_ready()

        out[f"planner_{kind}_ids_per_s"] = n_ids / _best_of(drain_plan)

        def drain_replicas():
            for _, moved, _, _, _ in planner.plan_replicas_stream(
                planner.chunked(ids, chunk), v0, v1, N_REPLICAS, mesh=sweep
            ):
                moved.block_until_ready()

        out[f"replica_planner_{kind}_ids_per_s"] = n_ids / _best_of(
            drain_replicas
        )
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if not args.worker:
        # standalone: print the full scaling table (parent mode)
        for n, r in measure(args.quick).items():
            print(json.dumps(r))
        return 0
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + os.environ.get("XLA_FLAGS", "")
    ).strip()
    print(json.dumps(_worker(args.devices, args.quick)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
