"""CI perf-regression gate: fresh BENCH_*.json vs the committed baselines.

The fast CI job reruns the quick benchmark suites and then calls

    python -m benchmarks.check_regression --fresh-dir . \
        --baseline-dir benchmarks/baselines

which compares every entry the fresh run SHARES with a committed baseline
snapshot and fails (exit 1) if any shared timing entry regressed by more
than ``--threshold`` (default 25%).  Policy, driven by the entry's unit so
the gate never misreads a metric's direction:

  * lower-is-better units (``us_per_id``, ``us_per_call``, ``..._s``,
    ``bytes``): regression = fresh > baseline * threshold,
  * higher-is-better units (``ids_per_s``, ``..._per_s``, ``x_faster``,
    ``x_speedup``): regression = fresh < baseline / threshold; the
    dimensionless ratio units are compared raw (machine speed cancels),
  * anything else (quality/count metrics like ``maxvar_pct`` or
    ``must_be_0`` counters) is informational -- correctness is the test
    suite's job, not a noisy perf gate's.

If both payloads carry a machine-speed CALIBRATION entry (unit ending in
``_calibration``, e.g. ``h2h_calibration`` -- a fixed integer workload
timed in the same run), every timing comparison is normalized by the
fresh/baseline calibration ratio: a runner that is 2x slower across the
board is not a regression, and a runner that is 2x faster must not mask
one.  The ratio is clamped to [1/8, 8] so a corrupt calibration cannot
swallow the gate.

New entries (in fresh but not in the baseline) and retired entries (in the
baseline but not fresh) are WARN-only, so adding a benchmark never blocks a
PR; refreshing the committed snapshot is how an intentional perf change
lands.

Only suites with a committed snapshot under ``benchmarks/baselines/`` are
gated at all.  The snapshot set is deliberately curated: the head-to-head
timings are designed for gate stability (fixed shapes, warm jit, best-of-3
-- benchmarks/head_to_head.py), while micro-benchmarks like the fig5
scalar/per-call entries are single-shot and too noisy for a 25% bar; those
suites still upload their JSON as ungated trajectory artifacts.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

DEFAULT_THRESHOLD = 1.25

LOWER_BETTER_UNITS = (
    "us_per_id", "us_per_call", "s", "elapsed_s", "bytes", "x_overhead",
)
HIGHER_BETTER_SUFFIXES = ("_per_s", "x_faster", "x_speedup")

# Units the machine-speed calibration must NOT rescale: deterministic
# byte counts, and dimensionless ratios (e.g. the scaling suite's
# ``x_speedup`` entries and the obs suite's instrumented/uninstrumented
# ``x_overhead`` -- machine speed cancels in the ratio).
RAW_COMPARE_UNITS = ("bytes", "x_faster", "x_speedup", "x_overhead")


def direction(unit: str) -> str:
    """'lower' | 'higher' | 'skip' for a BENCH entry unit string."""
    if unit.endswith("_calibration"):
        return "skip"  # the yardstick itself is never gated
    if unit in LOWER_BETTER_UNITS:
        return "lower"
    if unit.endswith(HIGHER_BETTER_SUFFIXES) or unit == "ids_per_s":
        return "higher"
    return "skip"


def calibration_ratio(base_entries: dict, fresh_entries: dict) -> float:
    """fresh/baseline machine-speed ratio (1.0 when either side lacks the
    calibration entry), clamped to [1/8, 8]."""
    for name, entry in base_entries.items():
        if not str(entry.get("unit", "")).endswith("_calibration"):
            continue
        other = fresh_entries.get(name)
        if other is None:
            continue
        try:
            b, f = float(entry["value"]), float(other["value"])
        except (KeyError, TypeError, ValueError):
            continue
        if b > 0 and f > 0:
            return min(max(f / b, 1 / 8), 8.0)
    return 1.0


def _compare(
    baseline: dict, fresh: dict, *, threshold: float = DEFAULT_THRESHOLD
) -> tuple[list[str], list[str], list[dict]]:
    """Compare two BENCH payloads' ``entries``.

    Returns (failures, warnings, rows) where ``rows`` is one dict per
    entry -- name, fresh/baseline values, speed-adjusted delta and the
    gate verdict -- ready for the markdown run summary.
    """
    failures: list[str] = []
    warnings: list[str] = []
    rows: list[dict] = []
    base_entries = baseline.get("entries", {})
    fresh_entries = fresh.get("entries", {})
    cal = calibration_ratio(base_entries, fresh_entries)
    for name in sorted(set(fresh_entries) - set(base_entries)):
        warnings.append(f"new entry (no baseline, not gated): {name}")
        rows.append(
            {"name": name, "fresh": fresh_entries[name].get("value"),
             "base": None, "unit": str(fresh_entries[name].get("unit", "")),
             "delta": None, "verdict": "new"}
        )
    for name in sorted(set(base_entries) - set(fresh_entries)):
        warnings.append(f"baseline entry missing from fresh run: {name}")
        rows.append(
            {"name": name, "fresh": None,
             "base": base_entries[name].get("value"),
             "unit": str(base_entries[name].get("unit", "")),
             "delta": None, "verdict": "missing"}
        )
    for name in sorted(set(base_entries) & set(fresh_entries)):
        base = base_entries[name]
        new = fresh_entries[name]
        unit = str(base.get("unit", ""))
        sense = direction(unit)
        row = {"name": name, "fresh": new.get("value"),
               "base": base.get("value"), "unit": unit,
               "delta": None, "verdict": "info"}
        rows.append(row)
        if sense == "skip":
            continue
        try:
            b, f = float(base["value"]), float(new["value"])
        except (KeyError, TypeError, ValueError):
            warnings.append(f"unreadable value for {name}; skipped")
            row["verdict"] = "unreadable"
            continue
        if b <= 0:
            warnings.append(f"non-positive baseline for {name}; skipped")
            row["verdict"] = "unreadable"
            continue
        # deterministic units (bytes) and dimensionless ratios are compared
        # raw; timed units are normalized by the machine-speed ratio.
        scale = 1.0 if unit.endswith(RAW_COMPARE_UNITS) else cal
        # signed regression %: positive = worse, whatever the direction
        if sense == "lower":
            regress = f / (b * scale)
        else:
            regress = b / (f * scale)
        row["delta"] = 100.0 * (regress - 1.0)
        row["verdict"] = "ok"
        if sense == "lower" and f > b * threshold * scale:
            row["verdict"] = "FAIL"
            failures.append(
                f"{name}: {f:.4g} vs baseline {b:.4g} "
                f"({f / (b * scale):.2f}x speed-adjusted, limit {threshold:.2f}x)"
            )
        elif sense == "higher" and f < b / (threshold * scale):
            row["verdict"] = "FAIL"
            failures.append(
                f"{name}: {f:.4g} vs baseline {b:.4g} "
                f"({b / (f * scale):.2f}x slower speed-adjusted, "
                f"limit {threshold:.2f}x)"
            )
    return failures, warnings, rows


def compare_entries(
    baseline: dict, fresh: dict, *, threshold: float = DEFAULT_THRESHOLD
) -> tuple[list[str], list[str]]:
    """Compare two BENCH payloads' ``entries`` -> (failures, warnings)."""
    failures, warnings, _ = _compare(baseline, fresh, threshold=threshold)
    return failures, warnings


def _check_dirs(
    baseline_dir: str, fresh_dir: str, *, threshold: float = DEFAULT_THRESHOLD
) -> tuple[list[str], list[str], dict[str, list[dict]]]:
    """Gate every committed BENCH_*.json that the fresh run also produced."""
    failures: list[str] = []
    warnings: list[str] = []
    suite_rows: dict[str, list[dict]] = {}
    base_paths = sorted(glob.glob(os.path.join(baseline_dir, "BENCH_*.json")))
    if not base_paths:
        warnings.append(f"no committed baselines under {baseline_dir}; nothing gated")
    for base_path in base_paths:
        name = os.path.basename(base_path)
        fresh_path = os.path.join(fresh_dir, name)
        if not os.path.exists(fresh_path):
            warnings.append(f"{name}: baseline exists but fresh run did not emit it")
            continue
        with open(base_path) as fh:
            baseline = json.load(fh)
        with open(fresh_path) as fh:
            fresh = json.load(fh)
        fails, warns, rows = _compare(baseline, fresh, threshold=threshold)
        failures += [f"{name}: {m}" for m in fails]
        warnings += [f"{name}: {m}" for m in warns]
        suite_rows[name] = rows
    return failures, warnings, suite_rows


def check_dirs(
    baseline_dir: str, fresh_dir: str, *, threshold: float = DEFAULT_THRESHOLD
) -> tuple[list[str], list[str]]:
    failures, warnings, _ = _check_dirs(baseline_dir, fresh_dir, threshold=threshold)
    return failures, warnings


def _fmt_value(v) -> str:
    if v is None:
        return "--"
    try:
        f = float(v)
    except (TypeError, ValueError):
        return str(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return f"{f:.4g}"


def write_summary(
    path: str,
    suite_rows: dict[str, list[dict]],
    failures: list[str],
    warnings: list[str],
    *,
    warn_only: bool = False,
) -> None:
    """Append a markdown per-entry report to ``path`` (the CI job points
    this at ``$GITHUB_STEP_SUMMARY`` so the gate verdict is on the run
    page, not buried in the log)."""
    if failures:
        verdict = "warn-only (would fail)" if warn_only else "FAILED"
        headline = f"perf gate {verdict}: {len(failures)} regression(s)"
    else:
        headline = f"perf gate clean ({len(warnings)} warnings)"
    lines = ["## Benchmark gate", "", headline, ""]
    for suite, rows in sorted(suite_rows.items()):
        lines += [f"### {suite}", ""]
        lines.append("| entry | value | baseline | delta | verdict |")
        lines.append("| --- | ---: | ---: | ---: | --- |")
        for row in rows:
            delta = "--" if row["delta"] is None else f"{row['delta']:+.1f}%"
            mark = {"FAIL": ":x: FAIL", "ok": ":white_check_mark: ok"}.get(
                row["verdict"], row["verdict"]
            )
            lines.append(
                f"| {row['name']} ({row['unit']}) | {_fmt_value(row['fresh'])} "
                f"| {_fmt_value(row['base'])} | {delta} | {mark} |"
            )
        lines.append("")
    if warnings:
        lines += ["<details><summary>warnings</summary>", ""]
        lines += [f"- {w}" for w in warnings]
        lines += ["", "</details>", ""]
    with open(path, "a") as fh:
        fh.write("\n".join(lines) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--baseline-dir",
        default="benchmarks/baselines",
        help="directory of committed BENCH_*.json snapshots",
    )
    ap.add_argument(
        "--fresh-dir", default=".", help="directory the fresh run wrote to"
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=DEFAULT_THRESHOLD,
        help="allowed slowdown ratio before failing (default 1.25 = +25%%)",
    )
    ap.add_argument(
        "--summary",
        default=None,
        metavar="PATH",
        help="append a markdown per-entry report (value, delta vs baseline, "
        "verdict) to PATH -- CI passes $GITHUB_STEP_SUMMARY",
    )
    ap.add_argument(
        "--warn-only",
        action="store_true",
        help="report regressions but exit 0 (first landing of full-size "
        "baselines on the nightly job)",
    )
    args = ap.parse_args(argv)
    failures, warnings, suite_rows = _check_dirs(
        args.baseline_dir, args.fresh_dir, threshold=args.threshold
    )
    if args.summary:
        write_summary(
            args.summary, suite_rows, failures, warnings, warn_only=args.warn_only
        )
    for w in warnings:
        print(f"WARN  {w}")
    for f in failures:
        print(f"FAIL  {f}")
    if failures:
        print(f"# perf gate: {len(failures)} regression(s) over threshold")
        return 0 if args.warn_only else 1
    print(f"# perf gate: clean ({len(warnings)} warnings)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
