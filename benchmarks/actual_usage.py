"""Paper Table III: "easy evaluation in actual usage".

The paper writes 1,000,000 one-byte data to 100 memcached instances through
modified libmemcached and reports wall time + max variability.  We simulate
the same workload shape: 1M keys are placed and appended to 100 in-memory
node buffers -- same placement math, I/O replaced by a dict append (the
network is not the object of comparison; placement cost and balance are).

Paper: CH(100 VN) 378s / 28.21%, Straw 492s / 0.31%, ASURA 380s / 0.29%.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import ConsistentHashRing, StrawBucket, make_uniform_cluster
from repro.core.asura import place_batch

N_KEYS = 1_000_000
N_NODES = 100


def _simulate(owners: np.ndarray) -> float:
    counts = np.bincount(owners, minlength=N_NODES)
    return float((counts.max() - counts.mean()) / counts.mean())


def run(csv_print) -> None:
    ids = np.arange(N_KEYS, dtype=np.uint32)
    # ASURA
    cluster = make_uniform_cluster(N_NODES)
    lengths = cluster.seg_lengths()
    t0 = time.perf_counter()
    owners = np.asarray(cluster.seg_to_node())[place_batch(ids, lengths)]
    t_asura = time.perf_counter() - t0
    csv_print("table3_asura_time_s", t_asura, f"maxvar {100*_simulate(owners):.2f}% (paper 0.29%)")
    csv_print("table3_asura_maxvar_pct", 100 * _simulate(owners), "paper: 0.29")
    # Consistent Hashing, 100 virtual nodes (the paper's production setting)
    ring = ConsistentHashRing(range(N_NODES), virtual_nodes=100)
    t0 = time.perf_counter()
    owners = ring.place(ids)
    t_ch = time.perf_counter() - t0
    csv_print("table3_ch_time_s", t_ch, f"maxvar {100*_simulate(owners):.2f}% (paper 28.21%)")
    csv_print("table3_ch_maxvar_pct", 100 * _simulate(owners), "paper: 28.21")
    # Straw
    straw = StrawBucket(range(N_NODES))
    t0 = time.perf_counter()
    owners = straw.place(ids)
    t_straw = time.perf_counter() - t0
    csv_print("table3_straw_time_s", t_straw, f"maxvar {100*_simulate(owners):.2f}% (paper 0.31%)")
    csv_print("table3_straw_maxvar_pct", 100 * _simulate(owners), "paper: 0.31")
