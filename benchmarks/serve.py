"""Batched serving-pipeline benchmarks (DESIGN.md section 12).

The paper's serving story measured under traffic: the batched
``RequestStreamDriver`` routes generated request streams (uniform and
Zipf(1.1)) through all four algorithms at R=3 and reports

  * ``serve_<alg>_routed_ids_per_s`` -- steady-state fused-step throughput
    (gated: this is the serving hot path the PR exists for),
  * ``serve_<alg>_<law>_<policy>_skew`` -- per-node served-load max/mean
    under each traffic law x selection policy (informational: the
    power-of-two-choices rows must sit below the random-of-R rows under
    Zipf -- redundancy plus selection flattens what raw placement cannot),
  * ``serve_<alg>_<law>_<policy>_q_p99`` -- p99 queue depth over the
    recorded window at 25% service headroom (informational),
  * ``serve_batched_vs_per_call_ratio`` -- the fused batched step vs a
    per-call ``route_replicas`` loop, per-id.  The >= 10x floor is
    asserted HERE (absolute, ~900x measured) rather than gated against a
    baseline snapshot: the numerator is compute-bound and the denominator
    dispatch-bound, so the ratio does not cancel machine speed and swings
    too much run-to-run for a 1.25x relative gate,
  * ``serve_superstep_ids_per_s`` / ``serve_superstep_vs_step_x_speedup``
    -- the scan-fused superstep (DESIGN.md section 15) at the
    SMALL-BATCH freshness config (batch 32, k 32): counters feed back
    into pow2 selection every 32 requests, yet the superstep routes all
    K sub-batches jointly, so it holds near bulk-batch throughput where
    the per-batch ``step()`` loop is dispatch-bound.  A >= 3x absolute
    floor is asserted here (same reasoning as the per-call ratio: the
    denominator is dispatch-bound, so the ratio swings too much for the
    relative gate, but 3x holds on any machine).

A ``serve_calibration`` entry (the shared fmix32 yardstick) lets the CI
gate normalize the timed entries by machine speed.  ``--quick`` shrinks
the stream for the CI smoke; at full size the ASURA throughput entry
serves 16 x 65536 = 1,048,576 requests per timed run (the baselines run a
shorter stream at the same rate measurement -- wrh is O(nodes) per id and
must not become the nightly long pole).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import PlacementEngine, make_uniform_cluster
from repro.serve import RequestStreamDriver

from .head_to_head import calibration_us

ALGS = ("asura", "ch", "wrh", "rs")
R = 3
SEED = 11


def _drive(engine, *, batch, n_keys, law, policy, steps):
    d = RequestStreamDriver(
        engine, batch=batch, n_keys=n_keys, law=law, alpha=1.1,
        n_replicas=R, policy=policy, seed=SEED,
    )
    for _ in range(steps):
        chosen = d.step()
    chosen.block_until_ready()
    return d


def _throughput_s(driver, steps: int) -> float:
    """Best-of-3 wall time for ``steps`` fused batch steps (warm jit)."""
    best = float("inf")
    for _ in range(3):
        driver.reset()
        t0 = time.perf_counter()
        for _ in range(steps):
            chosen = driver.step()
        chosen.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _per_call_us_per_id(engine, n_calls: int) -> float:
    """The pre-batching serving loop: one ``place_replica_nodes`` host call
    per request (what ``ReplicaRouter.route_replicas`` per-session costs)."""
    ids = np.arange(n_calls, dtype=np.uint32)
    engine.place_replica_nodes(ids[:1], R)  # warm caches outside the clock
    t0 = time.perf_counter()
    for i in range(n_calls):
        engine.place_replica_nodes(ids[i : i + 1], R)
    return 1e6 * (time.perf_counter() - t0) / n_calls


def run(csv_print, quick: bool = False) -> None:
    csv_print("serve_calibration", calibration_us(), "us_calibration")
    n_nodes = 16 if quick else 64
    n_keys = 1 << 16 if quick else 1 << 20
    # the skew/queue grid serves every (alg, law, policy) cell; the O(nodes)
    # per-id baselines (wrh above all) bound the grid size, so it runs a
    # smaller stream than the throughput entries
    grid_batch, grid_steps = (1 << 13, 8) if quick else (1 << 13, 8)
    # throughput streams: ASURA runs the acceptance config (16 x 65536 =
    # 1,048,576 requests per timed run at full size); the baselines run a
    # shorter stream at the same batch shape -- ids_per_s is a rate, so the
    # entries stay comparable without making wrh the nightly long pole
    thr = {
        "asura": ((1 << 13, 8) if quick else (1 << 16, 16)),
        "ch": ((1 << 13, 8) if quick else (1 << 14, 8)),
        "wrh": ((1 << 13, 8) if quick else (1 << 14, 8)),
        "rs": ((1 << 13, 8) if quick else (1 << 14, 8)),
    }

    cluster = make_uniform_cluster(n_nodes)
    engines = {
        alg: PlacementEngine(cluster, algorithm=alg, backend="ref")
        for alg in ALGS
    }

    # load skew + queue depth: traffic law x selection policy, all four
    # algorithms (the pow2 rows must undercut the random rows under zipf)
    for alg in ALGS:
        for law in ("uniform", "zipf"):
            for policy in ("random", "pow2"):
                d = _drive(
                    engines[alg], batch=grid_batch, n_keys=n_keys,
                    law=law, policy=policy, steps=grid_steps,
                )
                csv_print(
                    f"serve_{alg}_{law}_{policy}_skew",
                    round(d.load_skew(), 4),
                    "max_over_mean",
                )
                csv_print(
                    f"serve_{alg}_{law}_{policy}_q_p99",
                    round(d.queue_p99(), 1),
                    "queue_depth",
                )

    # steady-state routed throughput (zipf + pow2: the headline serving
    # config), gated per algorithm
    batched_us_per_id = None
    for alg in ALGS:
        batch, steps = thr[alg]
        d = _drive(
            engines[alg], batch=batch, n_keys=n_keys,
            law="zipf", policy="pow2", steps=2,  # warm the fused step
        )
        dt = _throughput_s(d, steps)
        csv_print(
            f"serve_{alg}_routed_ids_per_s",
            int(steps * batch / dt),
            "ids_per_s",
        )
        if alg == "asura":
            batched_us_per_id = 1e6 * dt / (steps * batch)

    # scan-fused superstep at the small-batch freshness config: pow2
    # selection sees counters fresh every 32 requests in BOTH loops; the
    # superstep amortizes the host dispatch AND routes all K sub-batches
    # through one ladder while_loop (bit-identical -- tested), so only
    # the per-batch loop pays the dispatch-bound small-batch tax
    ss_batch, ss_k, ss_blocks = 32, 32, 4
    d = _drive(
        engines["asura"], batch=ss_batch, n_keys=n_keys,
        law="zipf", policy="pow2", steps=2,
    )
    d.superstep(ss_k)  # warm the scanned jit
    best_step = float("inf")
    best_super = float("inf")
    for _ in range(3):
        d.reset()
        t0 = time.perf_counter()
        for _ in range(ss_blocks * ss_k):
            chosen = d.step()
        chosen.block_until_ready()
        best_step = min(best_step, time.perf_counter() - t0)
        d.reset()
        t0 = time.perf_counter()
        for _ in range(ss_blocks):
            chosen = d.superstep(ss_k)
        chosen.block_until_ready()
        best_super = min(best_super, time.perf_counter() - t0)
    ss_ids = ss_blocks * ss_k * ss_batch
    csv_print(
        "serve_superstep_ids_per_s", int(ss_ids / best_super), "ids_per_s"
    )
    speedup = round(best_step / best_super, 2)
    if speedup < 3.0:
        raise RuntimeError(
            f"superstep only {speedup}x the per-batch step loop (floor 3x)"
        )
    csv_print("serve_superstep_vs_step_x_speedup", speedup, "x_speedup")

    # batched pipeline vs the per-call route_replicas loop (per-id).  The
    # floor is absolute: both sides run in this process seconds apart, so
    # 10x holds on any machine even though the ratio itself is noisy.
    per_call = _per_call_us_per_id(engines["asura"], 100 if quick else 200)
    ratio = round(per_call / batched_us_per_id, 1)
    if ratio < 10.0:
        raise RuntimeError(
            f"batched serving step only {ratio}x the per-call loop (floor 10x)"
        )
    csv_print("serve_batched_vs_per_call_ratio", ratio, "x_vs_per_call")
