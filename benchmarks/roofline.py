"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md section Roofline).

Reads the JSON emitted by ``repro.launch.dryrun --all --out`` and derives,
per (arch x shape) cell on the single-pod 16x16 mesh:

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

plus MODEL_FLOPS = 6*N*D (6*N_active*D for MoE) and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs.  Hardware constants: TPU v5e -- 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI (per the assignment).

NOTE cost_analysis() on the CPU backend reports the per-program totals for
the SPMD-expanded module; we normalize to per-chip by dividing by n_devices
when the dry-run indicates program-level totals (flag ``per_program``).
"""

from __future__ import annotations

import json
from typing import Any

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link

TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}


def roofline_row(cell: dict[str, Any]) -> dict[str, Any]:
    n_dev = cell["n_devices"]
    # hlo_cost.py figures are per-device (the SPMD module is one device's
    # program); collective bytes likewise per device
    flops_per_chip = cell["flops"]
    bytes_per_chip = cell["hlo_bytes"]
    coll_per_chip = cell.get(
        "collective_bytes_per_device", cell["collectives"]["total_bytes"] / n_dev
    )
    t_compute = flops_per_chip / PEAK_FLOPS
    t_memory = bytes_per_chip / HBM_BW
    t_coll = coll_per_chip / LINK_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    tokens = TOKENS.get(cell["shape"], 1)
    n_active = cell.get("active_param_count", cell["param_count"])
    mult = 6 if cell["shape"] == "train_4k" else 2
    model_flops = mult * n_active * tokens  # global
    ratio = model_flops / max(cell["flops"] * n_dev, 1.0)
    bound = max(t_compute, t_memory, t_coll)
    ideal = model_flops / (n_dev * PEAK_FLOPS)
    return {
        "arch": cell["arch"],
        "shape": cell["shape"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops": cell["flops"],
        "useful_ratio": ratio,
        "roofline_fraction": ideal / bound if bound > 0 else 0.0,
        "peak_gb_per_dev": cell["peak_bytes_per_device"] / n_dev / 2**30,
        "hbm_ok": cell["peak_bytes_per_device"] / n_dev <= 16 * 2**30,
    }


def load_table(path: str) -> list[dict[str, Any]]:
    with open(path) as f:
        cells = json.load(f)
    return [roofline_row(c) for c in cells if c.get("status") == "ok"]


def format_table(rows: list[dict[str, Any]]) -> str:
    hdr = (
        f"{'arch':22s} {'shape':12s} {'compute_s':>11s} {'memory_s':>11s} "
        f"{'collect_s':>11s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s} {'GB/dev':>7s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['t_compute_s']:11.3e} "
            f"{r['t_memory_s']:11.3e} {r['t_collective_s']:11.3e} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
            f"{100*r['roofline_fraction']:7.2f} {r['peak_gb_per_dev']:7.2f}"
        )
    return "\n".join(lines)


def run(csv_print, path: str = "dryrun_single_pod.json") -> None:
    import os

    if not os.path.exists(path):
        csv_print("roofline_skipped", 0, f"no {path}; run dryrun --all --out first")
        return
    rows = load_table(path)
    for r in rows:
        csv_print(
            f"roofline_{r['arch']}_{r['shape']}_{r['dominant']}",
            r["roofline_fraction"],
            f"useful={r['useful_ratio']:.3f}",
        )
    print(format_table(rows))
