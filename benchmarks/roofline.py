"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md section Roofline),
plus the PLACEMENT-KERNEL roofline: a bytes-per-id / hashes-per-id model
ceiling for the sharded sweep throughputs (``placement_roofline`` below).

Reads the JSON emitted by ``repro.launch.dryrun --all --out`` and derives,
per (arch x shape) cell on the single-pod 16x16 mesh:

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

plus MODEL_FLOPS = 6*N*D (6*N_active*D for MoE) and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs.  Hardware constants: TPU v5e -- 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI (per the assignment).

NOTE cost_analysis() on the CPU backend reports the per-program totals for
the SPMD-expanded module; we normalize to per-chip by dividing by n_devices
when the dry-run indicates program-level totals (flag ``per_program``).
"""

from __future__ import annotations

import json
from typing import Any

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link

TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}


def roofline_row(cell: dict[str, Any]) -> dict[str, Any]:
    n_dev = cell["n_devices"]
    # hlo_cost.py figures are per-device (the SPMD module is one device's
    # program); collective bytes likewise per device
    flops_per_chip = cell["flops"]
    bytes_per_chip = cell["hlo_bytes"]
    coll_per_chip = cell.get(
        "collective_bytes_per_device", cell["collectives"]["total_bytes"] / n_dev
    )
    t_compute = flops_per_chip / PEAK_FLOPS
    t_memory = bytes_per_chip / HBM_BW
    t_coll = coll_per_chip / LINK_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    tokens = TOKENS.get(cell["shape"], 1)
    n_active = cell.get("active_param_count", cell["param_count"])
    mult = 6 if cell["shape"] == "train_4k" else 2
    model_flops = mult * n_active * tokens  # global
    ratio = model_flops / max(cell["flops"] * n_dev, 1.0)
    bound = max(t_compute, t_memory, t_coll)
    ideal = model_flops / (n_dev * PEAK_FLOPS)
    return {
        "arch": cell["arch"],
        "shape": cell["shape"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops": cell["flops"],
        "useful_ratio": ratio,
        "roofline_fraction": ideal / bound if bound > 0 else 0.0,
        "peak_gb_per_dev": cell["peak_bytes_per_device"] / n_dev / 2**30,
        "hbm_ok": cell["peak_bytes_per_device"] / n_dev <= 16 * 2**30,
    }


def load_table(path: str) -> list[dict[str, Any]]:
    with open(path) as f:
        cells = json.load(f)
    return [roofline_row(c) for c in cells if c.get("status") == "ok"]


def format_table(rows: list[dict[str, Any]]) -> str:
    hdr = (
        f"{'arch':22s} {'shape':12s} {'compute_s':>11s} {'memory_s':>11s} "
        f"{'collect_s':>11s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s} {'GB/dev':>7s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['t_compute_s']:11.3e} "
            f"{r['t_memory_s']:11.3e} {r['t_collective_s']:11.3e} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
            f"{100*r['roofline_fraction']:7.2f} {r['peak_gb_per_dev']:7.2f}"
        )
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Placement-kernel roofline (DESIGN.md section 11)
#
# The scaling entries (benchmarks/scaling.py) need a ceiling that is NOT
# just their own committed baseline, so the sweep throughput is checked
# against a first-order model built from two independently measured
# machine primitives:
#
#   * memory ceiling   -- the sweep streams BYTES_PER_ID per id (4B id
#     read + 4B owner write for placement; + moved/src/dst = 13B for the
#     dual diff; the kilobyte table artifacts live in cache and are free),
#     so ids/s <= stream_bw / bytes_per_id with stream_bw measured by a
#     large-array copy,
#   * compute ceiling  -- one ASURA descent draws a geometric number of
#     u32 hashes with hit rate >= 1/2 (alpha = 2, section 2.C), so
#     E[draws/id] <= alpha/(alpha-1) = 2 fmix-equivalents (4 for the
#     dual-version diff); the fmix32 rate comes from the same
#     ``calibration_us`` workload the perf gate normalizes with.
#
# The SERVING path gets the same treatment (DESIGN.md section 15): the
# scan-fused superstep driver measured against an R-descents-plus-draw
# hash model and a per-request byte model, so the serving hot path's
# distance to the machine ceilings is tracked alongside place/diff.
#
# The achieved fraction is informational (unit skipped by the gate): on
# CPU the jnp while_loop ladder runs well below both ceilings; on TPU the
# Pallas path should approach the memory line.  The straggler-compaction
# schedule in ``place_ref`` (kernels/ref.py) exists because this fraction
# said so: the lockstep draw loop was ~9x off its own hash model on
# half-full tables.
# ---------------------------------------------------------------------------

PLACE_BYTES_PER_ID = 8  # 4B id in + 4B owner out
DIFF_BYTES_PER_ID = 13  # 4B id in + 1B moved + 4B src + 4B dst out
SERVE_BYTES_PER_ID = 16  # 4B id + 4B chosen + 4B counter + 4B queue update
PLACE_HASHES_PER_ID = 2.0  # E[draws] <= alpha/(alpha-1), alpha = 2
DIFF_HASHES_PER_ID = 4.0  # two placement sweeps per id
SERVE_HASHES_PER_ID = 7.0  # R=3 replica descents (2 each) + traffic draw


def _stream_bw_bytes_per_s(repeats: int = 5) -> float:
    """Measured host stream bandwidth: best-of-``repeats`` 64 MiB copy
    (read + write counted)."""
    import time

    import numpy as np

    x = np.arange(1 << 23, dtype=np.float64)  # 64 MiB
    y = np.empty_like(x)
    np.copyto(y, x)  # warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        np.copyto(y, x)
        best = min(best, time.perf_counter() - t0)
    return 2 * x.nbytes / best


def _serving_ids_per_s(quick: bool) -> float:
    """Measured serving hot path: the scan-fused superstep driver
    (DESIGN.md section 15) at the bulk batch shape -- asura R=3, zipf +
    pow2, the headline serving config.  Runs in-process (the serving
    path has no forced-device scaling axis to subprocess over)."""
    import time

    from repro.core import PlacementEngine, make_uniform_cluster
    from repro.serve import RequestStreamDriver

    batch, k, blocks = (1 << 12, 8, 2) if quick else (1 << 13, 16, 4)
    engine = PlacementEngine(make_uniform_cluster(128), backend="ref")
    d = RequestStreamDriver(
        engine, batch=batch, n_keys=1 << 16, law="zipf", alpha=1.1,
        n_replicas=3, policy="pow2", seed=7,
    )
    d.superstep(k)  # warm the scanned jit
    best = float("inf")
    for _ in range(3):
        d.reset()
        t0 = time.perf_counter()
        for _ in range(blocks):
            chosen = d.superstep(k)
        chosen.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return blocks * k * batch / best


def placement_roofline(csv_print, quick: bool) -> None:
    """Placement/diff/serving ids/s vs the bytes-per-id and hashes-per-id
    ceilings; the place/diff points come from the scaling workers (cached
    in ``benchmarks.scaling`` when head_to_head/movement ran in this
    process, spawned fresh otherwise), the serving point from an
    in-process superstep driver."""
    from .head_to_head import calibration_us
    from .scaling import measure

    bw = _stream_bw_bytes_per_s()
    fmix_rate = (1 << 21) / (calibration_us() * 1e-6)  # hashes/s
    res = measure(quick)
    one = res[min(res)]

    for kind, bytes_per_id, hashes_per_id, measured in (
        ("place", PLACE_BYTES_PER_ID, PLACE_HASHES_PER_ID,
         one["uniformity_strong_ids_per_s"]),
        ("diff", DIFF_BYTES_PER_ID, DIFF_HASHES_PER_ID,
         one["planner_strong_ids_per_s"]),
        ("serve", SERVE_BYTES_PER_ID, SERVE_HASHES_PER_ID,
         _serving_ids_per_s(quick)),
    ):
        mem_ceiling = bw / bytes_per_id
        compute_ceiling = fmix_rate / hashes_per_id
        ceiling = min(mem_ceiling, compute_ceiling)
        csv_print(
            f"roofline_{kind}_bytes_per_id", bytes_per_id, "bytes_per_id_model"
        )
        csv_print(
            f"roofline_{kind}_mem_ceiling_ids_per_s",
            int(mem_ceiling),
            f"stream_bw {bw/1e9:.1f}GBps",
        )
        csv_print(
            f"roofline_{kind}_compute_ceiling_ids_per_s",
            int(compute_ceiling),
            f"fmix {fmix_rate/1e6:.0f}M_per_s",
        )
        bound = "memory" if mem_ceiling < compute_ceiling else "compute"
        csv_print(
            f"roofline_{kind}_ceiling_fraction",
            measured / ceiling,
            f"{bound}_bound_model",
        )


def run(csv_print, path: str = "dryrun_single_pod.json", quick: bool = False) -> None:
    import os

    placement_roofline(csv_print, quick)
    if not os.path.exists(path):
        # the dry-run arch table is optional extra context -- the suite is
        # self-contained without it (no placeholder entry: a committed
        # "skipped" row would shadow the measured entries in the gate)
        return
    rows = load_table(path)
    for r in rows:
        csv_print(
            f"roofline_{r['arch']}_{r['shape']}_{r['dominant']}",
            r["roofline_fraction"],
            f"useful={r['useful_ratio']:.3f}",
        )
    print(format_table(rows))
