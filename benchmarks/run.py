"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived`` CSV lines.  Usage:
    PYTHONPATH=src python -m benchmarks.run [--only fig5,table2,...]
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (
    actual_usage,
    calc_time,
    capacity,
    memory,
    movement,
    replicas,
    roofline,
    uniformity,
)

SUITES = {
    "fig5_calc_time": calc_time,
    "table2_memory": memory,
    "fig67_uniformity": uniformity,
    "movement": movement,
    "replicas": replicas,
    "table3_actual_usage": actual_usage,
    "capacity": capacity,
    "roofline": roofline,
}


def csv_print(name: str, value, derived="") -> None:
    print(f"{name},{value},{derived}", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite substrings")
    args = ap.parse_args(argv)
    picks = args.only.split(",") if args.only else None
    for name, mod in SUITES.items():
        if picks and not any(p in name for p in picks):
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            mod.run(csv_print)
        except Exception as e:  # pragma: no cover
            print(f"{name},ERROR,{e}", file=sys.stderr)
            return 1
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
