"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived`` CSV lines AND writes one machine-readable
``BENCH_<suite>.json`` per suite run (the perf trajectory the ROADMAP
tracks; CI uploads them as workflow artifacts so every PR records a perf
point).  Usage:

    PYTHONPATH=src python -m benchmarks.run [--only fig5,table2,...]
        [--quick] [--out-dir DIR]

``--quick`` asks suites that support it for a reduced-size run (the CI
smoke configuration).
"""

from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import time

from . import (
    actual_usage,
    calc_time,
    capacity,
    durability,
    head_to_head,
    memory,
    migrate,
    movement,
    obs,
    replicas,
    roofline,
    serve,
    uniformity,
)

SUITES = {
    "fig5_calc_time": calc_time,
    "table2_memory": memory,
    "fig67_uniformity": uniformity,
    "movement": movement,
    "migrate": migrate,
    "replicas": replicas,
    "head_to_head": head_to_head,
    "serve": serve,
    "obs": obs,
    "table3_actual_usage": actual_usage,
    "capacity": capacity,
    "roofline": roofline,
    "durability": durability,
}


def csv_print(name: str, value, derived="") -> None:
    print(f"{name},{value},{derived}", flush=True)


def _json_path(mod, out_dir: str) -> str:
    short = mod.__name__.rsplit(".", 1)[-1]
    return os.path.join(out_dir, f"BENCH_{short}.json")


def run_suite(name: str, mod, *, quick: bool, out_dir: str) -> None:
    """Run one suite, teeing every entry to CSV stdout and BENCH_*.json."""
    entries: dict[str, dict] = {}

    def record(entry_name: str, value, derived="") -> None:
        csv_print(entry_name, value, derived)
        entries[entry_name] = {"value": value, "unit": str(derived)}

    kwargs = {}
    if "quick" in inspect.signature(mod.run).parameters:
        kwargs["quick"] = quick
    t0 = time.time()
    mod.run(record, **kwargs)
    payload = {
        "suite": name,
        "quick": quick,
        "elapsed_s": round(time.time() - t0, 3),
        "entries": entries,
    }
    path = _json_path(mod, out_dir)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path} ({len(entries)} entries)", flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated suite substrings")
    ap.add_argument(
        "--quick",
        action="store_true",
        help="reduced-size run (CI smoke) for suites that support it",
    )
    ap.add_argument(
        "--out-dir", default=".", help="directory for the BENCH_*.json files"
    )
    args = ap.parse_args(argv)
    os.makedirs(args.out_dir, exist_ok=True)
    picks = args.only.split(",") if args.only else None
    for name, mod in SUITES.items():
        if picks and not any(p in name for p in picks):
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            run_suite(name, mod, quick=args.quick, out_dir=args.out_dir)
        except Exception as e:  # pragma: no cover
            print(f"{name},ERROR,{e}", file=sys.stderr)
            return 1
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
