"""Migration subsystem benchmarks (DESIGN.md sections 8, 10).

Covers the two layers ``movement.py`` does not: the throttled mover's
drain (rounds + rows/s under per-node budgets) and the dual-version
serving window (migration-window routing throughput and the landed
fraction it exposes) -- plus the REPLICA-SET path: a node failure
repaired as a throttled per-slot replica migration and the
mixed-version ``route_replicas`` read rule.  A ``migrate_calibration``
entry lets the CI perf gate normalize the timed entries by machine
speed.  ``--quick`` shrinks populations for the CI smoke.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import make_uniform_cluster
from repro.runtime import ElasticCoordinator

from .head_to_head import calibration_us


def _replica_entries(csv_print, quick: bool) -> None:
    n_nodes = 12 if quick else 48
    n_ids = 30_000 if quick else 400_000
    budget = 100 if quick else 1_500
    R = 3

    cluster = make_uniform_cluster(n_nodes)
    ids = np.arange(n_ids, dtype=np.uint32)
    coord = ElasticCoordinator(cluster, ids, n_replicas=R)

    # node failure -> throttled replica repair (per-slot plan, src = victim)
    t0 = time.perf_counter()
    mig = coord.remove_node_live(1, ingress=budget)
    csv_print(
        "migrate_replica_repair_plan_s",
        round(time.perf_counter() - t0, 4),
        f"R{R}_remove_numbers",
    )
    plan = mig.state.plan
    csv_print(
        "migrate_replica_moved_pct",
        100 * plan.n_moves / (R * n_ids),
        f"optimal {100/n_nodes:.3f}",
    )
    sample = ids[:: max(1, n_ids // 5_000)]
    t0 = time.perf_counter()
    while not mig.done:
        mig.round()
        mig.route_replicas(sample)
    dt = time.perf_counter() - t0
    csv_print(
        "migrate_replica_repair_rows_per_s", int(plan.n_moves / dt), "rows_per_s"
    )
    csv_print("migrate_replica_repair_rounds", mig.mover.rounds_done, f"ingress {budget}")

    # mixed-version replica routing throughput at half-drain
    mig2 = coord.add_node_live(n_nodes + 1, 1.0, egress=budget)
    while not mig2.done and mig2.state.n_pending > mig2.state.plan.n_moves // 2:
        mig2.round()
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        mig2.route_replicas(sample)
    dt = time.perf_counter() - t0
    csv_print(
        "migrate_route_replicas_ids_per_s",
        int(reps * len(sample) / dt),
        "ids_per_s",
    )
    if not mig2.done:
        mig2.run()


def run(csv_print, quick: bool = False) -> None:
    csv_print("migrate_calibration", calibration_us(), "us_calibration")
    n_nodes = 16 if quick else 64
    n_ids = 100_000 if quick else 2_000_000
    budget = 200 if quick else 2_000

    cluster = make_uniform_cluster(n_nodes)
    ids = np.arange(n_ids, dtype=np.uint32)
    coord = ElasticCoordinator(cluster, ids)

    t0 = time.perf_counter()
    mig = coord.add_node_live(n_nodes, 1.0, egress=budget, ingress=None)
    csv_print("migrate_live_plan_s", round(time.perf_counter() - t0, 4), "an_prefilter")
    plan = mig.state.plan
    csv_print(
        "migrate_live_moved_pct",
        100 * plan.n_moves / n_ids,
        f"optimal {100/(n_nodes+1):.3f}",
    )

    # Throttled drain: rounds + mover throughput under the egress budget.
    t0 = time.perf_counter()
    sample = ids[:: max(1, n_ids // 10_000)]
    routed_to_new = 0
    route_calls = 0
    while not mig.done:
        mig.round()
        routed_to_new += int((mig.route(sample) == n_nodes).sum())
        route_calls += len(sample)
    dt = time.perf_counter() - t0
    csv_print("migrate_mover_rounds", mig.mover.rounds_done, f"egress {budget}/round")
    csv_print("migrate_mover_rows_per_s", int(plan.n_moves / dt), "incl_routing")
    csv_print(
        "migrate_window_hit_pct",
        100 * routed_to_new / max(1, route_calls),
        "reads_served_by_v1_owner",
    )

    # Migration-window routing throughput (host rule) at half-drain.
    mig2 = coord.remove_node_live(1, ingress=budget)
    while not mig2.done and mig2.state.n_pending > mig2.state.plan.n_moves // 2:
        mig2.round()
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        mig2.route(sample)
    dt = time.perf_counter() - t0
    csv_print("migrate_route_ids_per_s", int(reps * len(sample) / dt), "dual_version")
    if not mig2.done:
        mig2.run()

    # Device-resident round blocks (DESIGN.md section 15): the throttled
    # drain admitted k rounds per dispatch by the jitted scan over the
    # padded plan view -- zero per-row host sync, bit-identical matrices
    # (tested).  Reported as round/row rates, not a host-vs-device ratio:
    # on a host-only install both paths are CPU-bound and the block's win
    # is structural (one dispatch per k rounds instead of a host loop).
    blk_budget = 20 if quick else 300
    blk_k = 8
    warm = coord.add_node_live(n_nodes + 1, 1.0, egress=blk_budget)
    warm.round_block(blk_k)  # compile outside the clock (shape-shared jit)
    coord.rollback_live(warm).run()
    mig3 = coord.add_node_live(n_nodes + 1, 1.0, egress=blk_budget)
    blk_moves = mig3.state.plan.n_moves
    t0 = time.perf_counter()
    while not mig3.done:
        mig3.round_block(blk_k)
    dt = time.perf_counter() - t0
    csv_print(
        "migrate_mover_block_rows_per_s", int(blk_moves / dt), f"k{blk_k}_blocks"
    )
    csv_print(
        "migrate_mover_block_rounds_per_s",
        int(mig3.mover.rounds_done / dt),
        f"egress {blk_budget}/round",
    )

    _replica_entries(csv_print, quick)

    # DESIGN.md section 11: R=3 replica-planner scaling over forced host
    # devices (subprocess workers, shared with head_to_head/movement).
    from .scaling import emit

    emit(csv_print, quick, "migrate_replica_plan_sharded", "replica_planner")
