"""Migration subsystem benchmarks (DESIGN.md section 8).

Covers the two layers ``movement.py`` does not: the throttled mover's
drain (rounds + rows/s under per-node budgets) and the dual-version
serving window (migration-window routing throughput and the landed
fraction it exposes).  ``--quick`` shrinks populations for the CI smoke.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import make_uniform_cluster
from repro.runtime import ElasticCoordinator


def run(csv_print, quick: bool = False) -> None:
    n_nodes = 16 if quick else 64
    n_ids = 100_000 if quick else 2_000_000
    budget = 200 if quick else 2_000

    cluster = make_uniform_cluster(n_nodes)
    ids = np.arange(n_ids, dtype=np.uint32)
    coord = ElasticCoordinator(cluster, ids)

    t0 = time.perf_counter()
    mig = coord.add_node_live(n_nodes, 1.0, egress=budget, ingress=None)
    csv_print("migrate_live_plan_s", round(time.perf_counter() - t0, 4), "an_prefilter")
    plan = mig.state.plan
    csv_print(
        "migrate_live_moved_pct",
        100 * plan.n_moves / n_ids,
        f"optimal {100/(n_nodes+1):.3f}",
    )

    # Throttled drain: rounds + mover throughput under the egress budget.
    t0 = time.perf_counter()
    sample = ids[:: max(1, n_ids // 10_000)]
    routed_to_new = 0
    route_calls = 0
    while not mig.done:
        mig.round()
        routed_to_new += int((mig.route(sample) == n_nodes).sum())
        route_calls += len(sample)
    dt = time.perf_counter() - t0
    csv_print("migrate_mover_rounds", mig.mover.rounds_done, f"egress {budget}/round")
    csv_print("migrate_mover_rows_per_s", int(plan.n_moves / dt), "incl_routing")
    csv_print(
        "migrate_window_hit_pct",
        100 * routed_to_new / max(1, route_calls),
        "reads_served_by_v1_owner",
    )

    # Migration-window routing throughput (host rule) at half-drain.
    mig2 = coord.remove_node_live(1, ingress=budget)
    while not mig2.done and mig2.state.n_pending > mig2.state.plan.n_moves // 2:
        mig2.round()
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        mig2.route(sample)
    dt = time.perf_counter() - t0
    csv_print("migrate_route_ids_per_s", int(reps * len(sample) / dt), "dual_version")
    if not mig2.done:
        mig2.run()
