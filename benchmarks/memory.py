"""Paper Table II: memory consumption.

Consistent Hashing stores 8NV bytes (node hash + owner per virtual node);
ASURA stores 8N (segment length + owner); Straw stores 8N.  The paper's
example point (10,000 nodes, 100 virtual nodes) gives 7.6 MB vs 78 KB --
reproduced exactly by our accounting."""

from __future__ import annotations

from repro.core import ConsistentHashRing, StrawBucket, make_uniform_cluster


def run(csv_print) -> None:
    n, v = 10_000, 100
    ring = ConsistentHashRing(range(n), virtual_nodes=v)
    cluster = make_uniform_cluster(n)
    straw = StrawBucket(range(n))
    csv_print("table2_ch_bytes_n10000_v100", ring.memory_bytes(), "bytes")
    csv_print("table2_asura_bytes_n10000", cluster.memory_bytes(), "bytes")
    csv_print("table2_straw_bytes_n10000", straw.memory_bytes(), "bytes")
    csv_print("table2_ch_mb", ring.memory_bytes() / 2**20, "MB (paper: 7.6)")
    csv_print("table2_asura_kb", cluster.memory_bytes() / 2**10, "KB (paper: 78)")
    # scaling
    for nn in (100, 1000, 10_000, 100_000):
        csv_print(
            f"table2_asura_bytes_n{nn}",
            make_uniform_cluster(nn).memory_bytes(),
            "bytes",
        )
