"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

Baseline scheme (measured and iterated in EXPERIMENTS.md section Perf):

  * weights:  FSDP over the ``data`` axis x tensor-parallel over ``model``.
    "in" projections (d -> wide) put d on data and the wide dim on model;
    "out" projections (wide -> d) the reverse, so TP matmuls chain without
    resharding (Megatron pairing).
  * embeddings: vocab on model (TP logits + chunked CE), d_model on data.
  * MoE experts: expert axis on model (EP); within-expert dims follow FSDP.
  * batch: sharded over ('pod', 'data').
  * decode caches: batch over dp axes; kv-heads / state width on model when
    divisible, else replicated (MQA kv=1, RWKV H=40 stay unsharded).

Grads inherit param specs; AdamW moments inherit param specs (ZeRO-1: the
optimizer state is already fully sharded because params are FSDP'd).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

from .mesh import data_axes

DATA = "data"
MODEL = "model"


def _spec(ndim: int, *trailing) -> P:
    """PartitionSpec for the trailing dims, None-padded for stacked layers."""
    pad = ndim - len(trailing)
    return P(*([None] * pad + list(trailing)))


_IN = (DATA, MODEL)  # (d_model, wide)
_OUT = (MODEL, DATA)  # (wide, d_model)

# name -> trailing-dims spec, optionally keyed by parent
_RULES: dict[str, Any] = {
    "embed": ("exact", P(MODEL, DATA)),
    "lm_head": ("exact", P(DATA, MODEL)),
    # attention (parent 'attn'/'cross') and rwkv time-mix share names; the
    # parent disambiguates below.
    "w_q": ("trail", _IN),
    "w_qkv": ("trail", _IN),
    "w_o": ("trail", _OUT),
    "w_gate": ("trail", _IN),
    "w_up": ("trail", _IN),
    "w_down": ("trail", _OUT),
    "router": ("trail", (DATA, None)),
    # MLA
    "w_dq": ("trail", (DATA, None)),
    "w_uq": ("trail", (None, MODEL)),
    "w_dkv": ("trail", (DATA, None)),
    "w_kr": ("trail", (DATA, None)),
    "w_uk": ("trail", (None, MODEL)),
    "w_uv": ("trail", (None, MODEL)),
    # RG-LRU
    "w_x": ("trail", _IN),
    "conv_w": ("trail", (None, MODEL)),
    "conv_b": ("trail", (MODEL,)),
    "a_param": ("trail", (MODEL,)),
    "w_rg": ("trail", (MODEL, None)),
    "w_ig": ("trail", (MODEL, None)),
    "w_out": ("trail", _OUT),
    # RWKV6 loras / small tensors -> replicated (handled by default)
    "mix_lora_a": ("trail", (DATA, None)),
    "decay_lora_a": ("trail", (DATA, None)),
}

_PARENT_RULES: dict[tuple[str, str], tuple] = {
    # MoE expert-parallel weights: (E, D, F) / (E, F, D)
    ("moe", "w_gate"): ("trail", (MODEL, DATA, None)),
    ("moe", "w_up"): ("trail", (MODEL, DATA, None)),
    ("moe", "w_down"): ("trail", (MODEL, None, DATA)),
    # attention K/V projections (d, kv*hd): wide dim on model
    ("attn", "w_k"): ("trail", _IN),
    ("attn", "w_v"): ("trail", _IN),
    ("cross", "w_k"): ("trail", _IN),
    ("cross", "w_v"): ("trail", _IN),
    # rwkv time-mix square projections: Megatron pairing.  NOTE (Perf
    # iteration 3, REFUTED): switching these to FSDP-only halves collective
    # bytes (the 40-head reshape can't keep model sharding, forcing fp32
    # activation all-gathers) but the full-width per-device matmuls raise
    # the dominant memory term 2.2x -- net loss; kept as TP.
    ("time", "w_r"): ("trail", _IN),
    ("time", "w_k"): ("trail", _IN),
    ("time", "w_v"): ("trail", _IN),
    ("time", "w_g"): ("trail", _IN),
    ("time", "w_o"): ("trail", _OUT),
    # rwkv channel-mix
    ("channel", "w_k"): ("trail", _IN),
    ("channel", "w_v"): ("trail", _OUT),
    ("channel", "w_r"): ("trail", _IN),
}


def _path_names(path) -> list[str]:
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            names.append(p.name)
    return names


def _axis_size(mesh, axis) -> int:
    if isinstance(axis, (tuple, list)):
        size = 1
        for a in axis:
            size *= mesh.shape[a]
        return size
    return mesh.shape[axis]


def _fit(mesh, spec: P, shape) -> P:
    """Drop mesh axes from dims they do not divide (MQA kv=1, 8-expert MoE,
    batch=1 decode cells, ...)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for extent, axis in zip(shape, dims):
        if axis is None:
            out.append(None)
        elif extent % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def param_pspec(path, leaf, mesh=None) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    shape = tuple(getattr(leaf, "shape", ()))
    nd = len(shape)
    rule = _PARENT_RULES.get((parent, name)) or _RULES.get(name)
    if rule is None:
        return P()  # norms, biases, gates: replicated
    kind, spec = rule
    spec = spec if kind == "exact" else _spec(nd, *spec)
    if mesh is None:
        return spec
    if parent == "moe" and name in ("w_gate", "w_up", "w_down"):
        # EP wants the expert axis on 'model'; with fewer experts than the
        # model axis (mixtral: 8 < 16) fall back to TP over d_ff instead.
        e_dim = nd - 3  # stacked layer dims precede (E, ., .)
        if shape[e_dim] % mesh.shape[MODEL] != 0:
            alt = (None, DATA, MODEL) if name in ("w_gate", "w_up") else (None, MODEL, DATA)
            spec = _spec(nd, *alt)
    return _fit(mesh, spec, shape)


def param_shardings(mesh, params_tree):
    """NamedSharding tree matching a (possibly abstract) param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(path, leaf, mesh)),
        params_tree,
    )


def batch_shardings(mesh, batch_tree):
    dp = data_axes(mesh)

    def one(path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        nd = len(shape)
        spec = _spec(nd, *([dp] + [None] * (nd - 1))) if nd else P()
        return NamedSharding(mesh, _fit(mesh, spec, shape))

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_pspec(path, leaf, mesh, cfg: ModelConfig) -> P:
    """Decode-cache specs: batch over dp; head/width dims on model.

    When the batch dim cannot take the dp axes (long_500k has batch=1), the
    sequence dim of KV-style caches takes them instead, so the 500k-context
    cache and its attention shard across the pod (sequence parallelism).
    Non-dividing extents are dropped by _fit (MQA kv=1, RWKV H=40)."""
    names = _path_names(path)
    name = names[-1] if names else ""
    shape = tuple(getattr(leaf, "shape", ()))
    nd = len(shape)
    dp = data_axes(mesh)
    # Canonical form: a single dp axis is the bare name ('data'), not the
    # 1-tuple ('data',) -- _join_axes still builds real multi-axis tuples.
    if len(dp) == 1:
        dp = dp[0]
    if name == "index" or nd == 0:
        return P()
    batch_ok = nd >= 2 and shape[-_trailing_rank(name)] % _axis_size(mesh, dp) == 0

    def bdim(seq_sharded_ok: bool):
        """(batch_axis, seq_axis): move dp to seq when batch can't shard."""
        if batch_ok:
            return dp, None
        return None, (dp if seq_sharded_ok else None)

    if name in ("k", "v"):  # (L, B, S, kv_heads, hd)
        b_ax, s_ax = bdim(True)
        kv_ok = shape[-2] % mesh.shape[MODEL] == 0
        if not kv_ok:
            # kv heads cannot take the model axis (GQA kv < model size):
            # shard the cache SEQUENCE over model instead -- flash-decode
            # style sequence parallelism; scores psum over model.  Without
            # this the head-sharded new k/v force an fp32 all-gather of the
            # WHOLE cache every step (EXPERIMENTS.md Perf iteration 5).
            s_ax = _join_axes(s_ax, MODEL)
        return _fit(mesh, _spec(nd, b_ax, s_ax, MODEL if kv_ok else None, None), shape)
    if name == "pos":  # (L, B, S) -- must match the k/v seq sharding
        b_ax, s_ax = bdim(True)
        kv_shape = None
        s_ax = _join_axes(s_ax, MODEL) if cfg.n_kv_heads % mesh.shape[MODEL] else s_ax
        return _fit(mesh, _spec(nd, b_ax, s_ax), shape)
    if name == "ckv":  # (L, B, S, kv_lora)
        b_ax, s_ax = bdim(True)
        return _fit(mesh, _spec(nd, b_ax, s_ax, MODEL), shape)
    if name == "krope":  # (L, B, S, rope_dim)
        b_ax, s_ax = bdim(True)
        return _fit(mesh, _spec(nd, b_ax, s_ax, None), shape)
    if name == "h":  # (L, B, W)
        return _fit(mesh, _spec(nd, dp, MODEL), shape)
    if name == "conv":  # (L, B, 3, W)
        return _fit(mesh, _spec(nd, dp, None, MODEL), shape)
    if name == "S":  # (L, B, H, dk, dv)
        return _fit(mesh, _spec(nd, dp, MODEL, None, None), shape)
    if name == "prev":  # (L, B, 1, D)
        return _fit(mesh, _spec(nd, dp, None, None), shape)
    if name == "enc_out":  # (B, S_enc, D)
        return _fit(mesh, _spec(nd, dp, None, None), shape)
    return _fit(mesh, _spec(nd, dp), shape)


_TRAILING = {"k": 4, "v": 4, "pos": 2, "ckv": 3, "krope": 3, "h": 2, "conv": 3,
             "S": 4, "prev": 3, "enc_out": 3}


def _trailing_rank(name: str) -> int:
    """dims after (and including) batch for each cache leaf kind."""
    return _TRAILING.get(name, 1)


def _join_axes(ax, extra):
    """Combine mesh axes on one dim: None+m -> m; ('data',)+m -> ('data', m)."""
    if ax is None:
        return extra
    if isinstance(ax, (tuple, list)):
        return tuple(ax) + (extra,)
    return (ax, extra)


def cache_shardings(mesh, cfg: ModelConfig, cache_tree):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, cache_pspec(path, leaf, mesh, cfg)),
        cache_tree,
    )


def replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def logits_sharding(mesh, batch: int, vocab: int) -> NamedSharding:
    """(B, V) logits: batch over dp if divisible, vocab over model."""
    dp = data_axes(mesh)
    return NamedSharding(mesh, _fit(mesh, P(dp, MODEL), (batch, vocab)))


def activation_constraint_fn(mesh):
    """Constraint hook for repro.models.hooks: shard dim0 (batch) over the
    data axes when divisible; leave other dims to propagation."""
    import jax as _jax

    dp = data_axes(mesh)

    def constrain(x):
        nd = getattr(x, "ndim", 0)
        if nd < 2:
            return x
        spec = _fit(mesh, _spec(nd, *([dp] + [None] * (nd - 1))), x.shape)
        return _jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


def serve_param_shardings(mesh, params_tree):
    """Inference-time weights: TP over 'model' only, NO FSDP.

    FSDP'd weights must be all-gathered on every decode step (the dominant
    decode collective -- EXPERIMENTS.md section Perf iteration on
    recurrentgemma decode); replicating the data-axis dimension trades
    HBM (bf16 weights / model-axis shards fit every assigned arch) for the
    per-token all-gather."""

    def one(path, leaf):
        spec = param_pspec(path, leaf, mesh)
        no_fsdp = P(*[None if ax == DATA else ax for ax in spec])
        return NamedSharding(mesh, _fit(mesh, no_fsdp, tuple(leaf.shape)))

    return jax.tree_util.tree_map_with_path(one, params_tree)
