"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state -- the dry-run sets
XLA_FLAGS for 512 host devices before any jax import; smoke tests and
benchmarks see the single real CPU device.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (v5e), 2 pods for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests on CPU)."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that shard the batch: ('pod', 'data') multi-pod, else ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_pspec(mesh) -> P:
    return P(data_axes(mesh))
