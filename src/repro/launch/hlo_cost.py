"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so a
40-layer ``lax.scan`` under-reports FLOPs/bytes by ~40x (verified in
EXPERIMENTS.md section Dry-run).  This module re-derives the three roofline
inputs directly from the optimized (scheduled) HLO text, expanding the
computation graph:

  * per-computation costs: dot FLOPs (2 * prod(output) * contraction, with
    operand shapes resolved through a per-computation symbol table since
    scheduled HLO drops inline operand types), bytes touched (output +
    operand sizes, skipping pure-plumbing ops), and collective bytes,
  * call sites: fusion/call/conditional/reduce add the callee once; while
    adds (cond + body) x trip count, the trip count recovered from the
    canonical jax loop condition ``compare(iv, constant(N)), direction=LT``
    (falls back to 1 and sets ``trip_unknown``),
  * entry cost = fully expanded cost of the ENTRY computation.

Static analysis of the SPMD module: totals are whole-program; divide by
device count for per-chip roofline terms.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_DEF = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([a-z]\d*[a-z0-9]*)\[([\d,]*)\][^=]*?\s([a-z][a-z0-9\-]*)\("
)
_DEF_TUPLE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(")
_OPERANDS = re.compile(r"\(([^)]*)\)")
_REF = re.compile(r"%([\w.\-]+)")
_CONST = re.compile(r"\bs32\[\]\s+constant\((\d+)\)")
_DOT_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_PARAM_IDX = re.compile(r"parameter\((\d+)\)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_COLLECTIVE = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_PLUMBING = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
             "copy", "after-all", "partition-id", "replica-id"}

# Ops that materialize HBM traffic on TPU.  Elementwise chains (add, mul,
# tanh, convert, select, broadcast, ...) are fused into their producers by
# the TPU compiler, so counting their operand/output bytes would model the
# CPU backend's (unfused) lowering rather than the target hardware; we count
# bytes only at materialization boundaries.  Fusion call-sites count their
# own operands/outputs (the boundary), their callees count FLOPs only.
_BYTES_OPS = {
    "dot", "convolution", "fusion", "reduce", "reduce-window", "scatter",
    "gather", "dynamic-slice", "dynamic-update-slice", "concatenate", "pad",
    "sort", "transpose", "select-and-scatter", "custom-call", "rng",
    "rng-bit-generator", "cholesky", "triangular-solve", "fft",
} | set(_COLLECTIVE)


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    calls: list = dataclasses.field(default_factory=list)  # (kind, names, extra_bytes)
    max_const: int = 1
    # per-parameter HBM charge when this computation is a fusion callee:
    # a param only read through (dynamic-)slice ops is charged the slice
    # output bytes, not the full operand (stacked layer weights!)
    param_full: dict = dataclasses.field(default_factory=dict)   # idx -> bytes
    param_slice: dict = dataclasses.field(default_factory=dict)  # idx -> sliced bytes
    param_direct: set = dataclasses.field(default_factory=set)   # idx used directly
    param_alias: set = dataclasses.field(default_factory=set)    # idx aliased (DUS buffer)
    root_dus_update: float | None = None  # ROOT dynamic-update-slice: update bytes


def _parse(hlo: str) -> tuple[dict[str, CompCost], str]:
    comps: dict[str, CompCost] = {}
    entry = ""
    cur: CompCost | None = None
    symbols: dict[str, tuple[str, int]] = {}
    param_names: dict[str, int] = {}
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m:
                cur = comps.setdefault(m.group(1), CompCost())
                symbols = {}
                param_names = {}
                if stripped.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if stripped == "}":
            cur = None
            continue
        for c in _CONST.findall(line):
            cur.max_const = max(cur.max_const, int(c))
        d = _DEF.match(line)
        if d:
            name, dt, dims, opcode = d.groups()
            out_n = _elems(dims)
            out_bytes = out_n * DTYPE_BYTES.get(dt, 4)
            symbols[name] = (dt, out_n)
            opm = _OPERANDS.search(line[line.index(opcode + "(") :])
            operands = _REF.findall(opm.group(1)) if opm else []
            if opcode == "parameter":
                pidx = _PARAM_IDX.search(line)
                if pidx:
                    idx = int(pidx.group(1))
                    cur.param_full[idx] = out_bytes
                    param_names[name] = idx
            # param usage classification (slice-only / aliased / direct)
            for oi, o in enumerate(operands):
                if o in param_names:
                    idx = param_names[o]
                    if opcode in ("dynamic-slice", "slice"):
                        cur.param_slice[idx] = max(
                            cur.param_slice.get(idx, 0), out_bytes
                        )
                    elif opcode == "dynamic-update-slice" and oi == 0:
                        cur.param_alias.add(idx)  # in-place buffer operand
                    elif opcode not in ("get-tuple-element", "bitcast", "copy"):
                        cur.param_direct.add(idx)
            count_bytes = opcode in _BYTES_OPS and opcode != "fusion"
            if opcode in ("dynamic-slice", "slice", "gather"):
                # reads only the slice: charge output both ways (read+write)
                cur.bytes += 2.0 * out_bytes
                count_bytes = False
            elif opcode == "dynamic-update-slice":
                upd = symbols.get(operands[1]) if len(operands) > 1 else None
                upd_bytes = (
                    upd[1] * DTYPE_BYTES.get(upd[0], 4) if upd else out_bytes
                )
                cur.bytes += 2.0 * upd_bytes
                count_bytes = False
                if line.lstrip().startswith("ROOT"):
                    cur.root_dus_update = upd_bytes
            elif opcode in ("scatter",):
                # in-place update: traffic ~ 2x the update operand, NOT the
                # full buffer (scan output stacking would otherwise charge
                # the whole stacked array per iteration)
                upd = symbols.get(operands[1]) if len(operands) > 1 else None
                upd_bytes = (
                    upd[1] * DTYPE_BYTES.get(upd[0], 4) if upd else out_bytes
                )
                cur.bytes += 2.0 * upd_bytes
                count_bytes = False
            if count_bytes:
                op_bytes = sum(
                    symbols.get(o, ("f32", 0))[1]
                    * DTYPE_BYTES.get(symbols.get(o, ("f32", 0))[0], 4)
                    for o in operands
                )
                cur.bytes += out_bytes + op_bytes
            if opcode == "dot":
                cm = _DOT_CDIMS.search(line)
                lhs = symbols.get(operands[0]) if operands else None
                if cm is not None and lhs is not None:
                    contract = _contract_size(line, operands[0], symbols, hlo_dims.get(operands[0]))
                    cur.flops += 2.0 * out_n * contract
            elif opcode in _COLLECTIVE:
                cur.coll_bytes += out_bytes
                cur.coll_by_kind[opcode] += out_bytes
            _record_calls(cur, line, opcode, out_bytes)
            continue
        t = _DEF_TUPLE.match(line)
        if t:
            # tuple-typed result (e.g. while); record calls, no flops
            opcode = _opcode_of(line)
            _record_calls(cur, line, opcode, 0.0)
    return comps, entry


# per-module map: op name -> dims tuple (filled in analyze's pre-pass)
hlo_dims: dict[str, tuple[int, ...]] = {}

_DIMS_DEF = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*[a-z]\d*[a-z0-9]*\[([\d,]*)\]", re.MULTILINE
)


def _contract_size(line, lhs_name, symbols, lhs_dims):
    cm = _DOT_CDIMS.search(line)
    if cm is None:
        return 0
    if lhs_dims is None:
        return 0
    contract = 1
    for di in cm.group(1).split(","):
        if di.strip().isdigit():
            i = int(di)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return contract


def _opcode_of(line: str) -> str:
    m = re.search(r"\s([a-z][a-z0-9\-]*)\(", line)
    return m.group(1) if m else ""


# opcodes whose callee runs register/VMEM-resident inside the op: the callee
# contributes FLOPs/collectives, but its internal values never touch HBM, so
# bytes count only at the call-site boundary (the op's own operands/output).
_FUSED_CALLERS = {"fusion", "reduce", "reduce-window", "scatter", "sort", "map",
                  "select-and-scatter", "all-reduce", "reduce-scatter"}


def _record_calls(cur: CompCost, line: str, opcode: str, out_bytes: float) -> None:
    if opcode == "while":
        body = _BODY.search(line)
        cond = _COND.search(line)
        names = [m.group(1) for m in (cond, body) if m]
        if names:
            cur.calls.append(("while", names, 0.0))
        return
    kind = "fused" if opcode in _FUSED_CALLERS else "call"
    cm = _CALLS.search(line)
    if cm:
        cur.calls.append((kind, [cm.group(1)], out_bytes))
    bm = _BRANCHES.search(line)
    if bm:
        cur.calls.append(
            ("call", [n.strip().lstrip("%") for n in bm.group(1).split(",")], 0.0)
        )


@dataclasses.dataclass
class ModuleCost:
    flops: float
    bytes: float
    collective_bytes: float
    collective_by_kind: dict
    trip_unknown: bool


def analyze(hlo: str) -> ModuleCost:
    # pre-pass: global name -> dims (names are unique enough per module; dots
    # reference operands defined in the same computation)
    hlo_dims.clear()
    for m in _DIMS_DEF.finditer(hlo):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        hlo_dims[m.group(1)] = dims
    comps, entry = _parse(hlo)
    if not comps:
        return ModuleCost(0.0, 0.0, 0.0, {}, False)
    if not entry:
        entry = next(iter(comps))

    memo: dict[str, tuple] = {}

    def cost_of(name: str, stack=()):
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return (0.0, 0.0, 0.0, {}, False)
        c = comps[name]
        fl, by, co = c.flops, c.bytes, c.coll_bytes
        kinds = defaultdict(float, c.coll_by_kind)
        unknown = False
        for kind, names, extra in c.calls:
            trips = 1
            if kind == "while":
                cond_name = names[0] if len(names) > 1 else None
                trips = comps[cond_name].max_const if cond_name in comps else 1
                if trips <= 1:
                    trips = 1
                    unknown = True
            for sub in names:
                sf, sb, sc, sk, su = cost_of(sub, stack + (name,))
                fl += trips * sf
                if kind == "fused":
                    # fused callee is register/VMEM resident; HBM traffic =
                    # call-site output + per-param charges (full bytes for
                    # directly-read params, slice bytes for sliced params,
                    # zero for in-place-aliased DUS buffers)
                    charge = extra
                    callee = comps.get(sub)
                    if callee is not None:
                        if callee.root_dus_update is not None:
                            # output aliases the buffer: traffic ~ the update
                            charge = 2.0 * callee.root_dus_update
                        for idx, full in callee.param_full.items():
                            if idx in callee.param_alias:
                                continue
                            if idx in callee.param_direct:
                                charge += full
                            elif idx in callee.param_slice:
                                charge += callee.param_slice[idx]
                    by += trips * charge
                else:
                    by += trips * sb
                co += trips * sc
                for k, v in sk.items():
                    kinds[k] += trips * v
                unknown |= su
        memo[name] = (fl, by, co, dict(kinds), unknown)
        return memo[name]

    fl, by, co, kinds, unknown = cost_of(entry)
    return ModuleCost(fl, by, co, kinds, unknown)
