import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import (jax locks the device count
at first init).  For each cell we

  1. build abstract inputs (ShapeDtypeStruct only -- no allocation),
  2. jax.jit the train/prefill/serve step with explicit in/out shardings,
  3. .lower().compile() on the production mesh,
  4. record memory_analysis() (bytes/device -- proves it fits) and
     cost_analysis() (FLOPs / bytes for the roofline), and the collective
     bytes parsed from the compiled HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out FILE]
"""

import argparse
import functools
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.shardings import (
    activation_constraint_fn,
    batch_shardings,
    cache_shardings,
    logits_sharding,
    param_shardings,
    replicated,
    serve_param_shardings,
)
from repro.models import cache_specs, input_specs, param_specs
from repro.models.hooks import activation_sharding
from repro.models.config import ModelConfig, ShapeSpec
from repro.models.layers import COMPUTE_DTYPE
from repro.train import AdamWConfig, make_prefill_step, make_serve_step, make_train_step

# microbatch counts tuned so the activation peak fits HBM (section Perf)
MICROBATCHES: dict[tuple[str, str], int] = {}


def _opt_specs(params_abs):
    return {
        "m": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_abs),
        "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_abs),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def _bf16_params(params_abs):
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, COMPUTE_DTYPE), params_abs
    )


COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
SHAPE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*([a-z0-9]+)\[([\d,]*)\]")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "u1": 1, "s1": 1, "s4": 1, "u4": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective op in the HLO.

    Loop bodies appear once in the text but execute per scan iteration;
    we multiply collectives inside while-loop computations by the trip
    count when it is recoverable from the loop bound (conservative: if not
    recoverable, count once)."""
    totals: dict[str, float] = {}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        sm = SHAPE_RE.match(line)
        if not sm:
            continue
        dt, dims = sm.group(1), sm.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        totals[kind] = totals.get(kind, 0.0) + n * DTYPE_BYTES[dt]
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes": totals, "counts": counts, "total_bytes": sum(totals.values())}


def build_cell(cfg: ModelConfig, spec: ShapeSpec, mesh, *, n_microbatches=1,
               serve_tp_only=False):
    """Returns (fn, in_specs, in_shardings) for one cell."""
    params_abs = param_specs(cfg)
    serve_sh = serve_param_shardings if serve_tp_only else param_shardings
    if spec.kind == "train":
        fn = make_train_step(cfg, AdamWConfig(), n_microbatches=n_microbatches)
        batch = input_specs(cfg, spec)["batch"]
        opt = _opt_specs(params_abs)
        in_specs = (params_abs, opt, batch)
        in_sh = (
            param_shardings(mesh, params_abs),
            {
                "m": param_shardings(mesh, params_abs),
                "v": param_shardings(mesh, params_abs),
                "count": NamedSharding(mesh, P()),
            },
            batch_shardings(mesh, batch),
        )
        out_sh = (in_sh[0], in_sh[1], replicated(mesh, {"loss": 0, "grad_norm": 0, "lr": 0}))
        return fn, in_specs, in_sh, out_sh
    if spec.kind == "prefill":
        fn = make_prefill_step(cfg)
        batch = input_specs(cfg, spec)["batch"]
        pa = _bf16_params(params_abs)
        in_specs = (pa, batch)
        in_sh = (serve_sh(mesh, pa), batch_shardings(mesh, batch))
        out_sh = logits_sharding(mesh, spec.global_batch, cfg.vocab)
        return fn, in_specs, in_sh, out_sh
    # decode
    fn = make_serve_step(cfg)
    ins = input_specs(cfg, spec)
    pa = _bf16_params(params_abs)
    in_specs = (pa, ins["cache"], ins["batch"])
    cache_sh = cache_shardings(mesh, cfg, ins["cache"])
    in_sh = (
        serve_sh(mesh, pa),
        cache_sh,
        batch_shardings(mesh, ins["batch"]),
    )
    out_sh = (logits_sharding(mesh, spec.global_batch, cfg.vocab), cache_sh)
    return fn, in_specs, in_sh, out_sh


def run_cell(arch: str, shape: str, *, multi_pod: bool = False, verbose: bool = True,
             hlo_dir: str | None = None, serve_tp_only: bool = False,
             remat: str | None = None):
    cfg = get_config(arch)
    spec = SHAPES[shape]
    ok, reason = shape_applicable(cfg, spec)
    if not ok:
        return {"arch": arch, "shape": shape, "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    if remat:
        from repro.models import lm as _lm

        _lm.set_remat_policy(remat)
    n_micro = MICROBATCHES.get((arch, shape), 1)
    fn, in_specs, in_sh, out_sh = build_cell(
        cfg, spec, mesh, n_microbatches=n_micro, serve_tp_only=serve_tp_only
    )
    t0 = time.time()
    with mesh, activation_sharding(activation_constraint_fn(mesh)):
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*in_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    if hlo_dir:
        import gzip, os as _os

        _os.makedirs(hlo_dir, exist_ok=True)
        tag = "mp" if multi_pod else "sp"
        with gzip.open(f"{hlo_dir}/{arch}_{shape}_{tag}.hlo.gz", "wt") as f:
            f.write(hlo_text)
    mc = hlo_analyze(hlo_text)  # loop-aware, per-device (SPMD module)
    coll = collective_bytes(hlo_text)
    n_dev = mesh.devices.size
    result = {
        "arch": arch,
        "shape": shape,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # loop-aware per-device analysis (hlo_cost.py); xla_* are XLA's own
        # cost_analysis, which counts while bodies once (see EXPERIMENTS.md)
        "flops": mc.flops,
        "hlo_bytes": mc.bytes,
        "collective_bytes_per_device": mc.collective_bytes,
        "collective_by_kind": dict(mc.collective_by_kind),
        "trip_unknown": mc.trip_unknown,
        "xla_flops": cost.get("flops", 0.0),
        "xla_bytes": cost.get("bytes accessed", 0.0),
        "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes_per_device": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes_per_device": (
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        ),
        "collectives": coll,
        "n_devices": n_dev,
        "n_microbatches": n_micro,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if verbose:
        print(json.dumps(result, indent=2, default=float))
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--hlo-dir", default=None, help="dump compiled HLO text (gz)")
    ap.add_argument("--serve-tp-only", dest="serve_tp_only", action="store_true",
                    default=True,
                    help="serve weights TP-only (no per-token FSDP all-gather); "
                    "confirmed win, default on (EXPERIMENTS.md Perf iteration 5)")
    ap.add_argument("--serve-fsdp", dest="serve_tp_only", action="store_false")
    ap.add_argument("--remat", default=None, choices=["nothing", "dots", "everything"])
    ap.add_argument("--blockwise-threshold", type=int, default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args(argv)
    results = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                print(f"=== {arch} x {shape} (multi_pod={args.multi_pod}) ===", flush=True)
                try:
                    results.append(
                        run_cell(
                            arch, shape, multi_pod=args.multi_pod, hlo_dir=args.hlo_dir,
                            serve_tp_only=args.serve_tp_only, remat=args.remat,
                        )
                    )
                except Exception as e:  # a failure here is a bug in the system
                    results.append(
                        {"arch": arch, "shape": shape, "status": "FAILED", "error": str(e)[:500]}
                    )
                    print(f"FAILED: {e}", file=sys.stderr)
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        if args.microbatches:
            MICROBATCHES[(args.arch, args.shape)] = args.microbatches
        if args.blockwise_threshold:
            from repro.models import layers as _layers

            _layers.set_blockwise_threshold(args.blockwise_threshold)
        results.append(
            run_cell(
                args.arch, args.shape, multi_pod=args.multi_pod, hlo_dir=args.hlo_dir,
                serve_tp_only=args.serve_tp_only, remat=args.remat,
            )
        )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, default=float)
    n_fail = sum(1 for r in results if r["status"] == "FAILED")
    print(f"\n{len(results)} cells: {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
