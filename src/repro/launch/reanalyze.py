"""Offline re-analysis: update dry-run JSON cost fields from dumped HLO.

The dry-run saves compiled HLO under --hlo-dir; this tool re-runs
launch/hlo_cost.analyze on the dumps so analyzer refinements do not require
recompiling 40 cells.

Usage: PYTHONPATH=src python -m repro.launch.reanalyze dryrun_single_pod.json hlo sp
"""

import gzip
import json
import sys

from repro.launch.hlo_cost import analyze


def main(json_path: str, hlo_dir: str, tag: str) -> int:
    with open(json_path) as f:
        cells = json.load(f)
    n = 0
    for cell in cells:
        if cell.get("status") != "ok":
            continue
        path = f"{hlo_dir}/{cell['arch']}_{cell['shape']}_{tag}.hlo.gz"
        try:
            with gzip.open(path, "rt") as f:
                text = f.read()
        except OSError:
            print(f"missing {path}", file=sys.stderr)
            continue
        mc = analyze(text)
        cell["flops"] = mc.flops
        cell["hlo_bytes"] = mc.bytes
        cell["collective_bytes_per_device"] = mc.collective_bytes
        cell["collective_by_kind"] = dict(mc.collective_by_kind)
        cell["trip_unknown"] = mc.trip_unknown
        n += 1
    with open(json_path, "w") as f:
        json.dump(cells, f, indent=2, default=float)
    print(f"reanalyzed {n} cells -> {json_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(*sys.argv[1:4]))
