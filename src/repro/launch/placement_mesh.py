"""Multi-chip scale-out of the placement/diff path (DESIGN.md section 11).

Everything the repo does at cluster scale -- uniformity histograms,
section-6.D movement accounting, migration planning -- is bulk throughput
over millions-to-billions of ids, and the placement/diff kernels are
embarrassingly parallel over ids.  ``ShardedSweep`` is the ``shard_map``
driver that turns one device's sweep into a mesh sweep:

  * the ID STREAM is partitioned over the mesh's single ``data`` axis
    (host-padded to a shard multiple; pad lanes carry weight 0),
  * the TABLE ARTIFACTS (length/cumsum/node tables, baseline lookup
    tables) are replicated -- they are kilobytes, the same "broadcast
    whole into VMEM" budget the Pallas kernels already assume,
  * each shard runs the UNCHANGED zero-host-sync engine kernels (the jnp
    reference bodies behind ``place_nodes_device`` /
    ``place_replica_nodes_device`` / ``diff_nodes_device`` /
    ``diff_replicas_device``), so per-lane results are bit-identical to
    the single-device sweep by construction,
  * the only cross-chip outputs -- per-node histograms, (src, dst)
    movement matrices, moved counts -- are reduced with a SINGLE ``psum``
    per sweep; integer scatter-adds, so the reduction is exact and the
    mesh result equals the single-device result bit for bit.

Per-id owner/diff arrays come back shard-partitioned (``out_specs
P('data')``); the host-facing methods re-assemble and trim the pad.

``check_rep=False`` everywhere: the placement kernels are ``while_loop``
ladders and shard_map has no replication rule for ``while`` -- every
output is either explicitly partitioned or an explicit ``psum``, so
nothing relies on the inferred-replication machinery.

jax is imported lazily (inside functions) so ``main`` can force the host
device count (``--xla_force_host_platform_device_count``, the
``launch/dryrun.py`` trick) BEFORE first jax init:

    PYTHONPATH=src python -m repro.launch.placement_mesh --selftest --devices 8

runs the bit-identity selftest -- sharded placement / histogram / diff /
replica-diff / planner vs the single-device engine path, all four
algorithms, R in {1, 3}, odd-sized id streams -- on 8 forced host
devices.  ``tests/test_sharded_placement.py`` runs the same selftest as a
subprocess; CI runs it at 4 devices in the fast job.
"""

from __future__ import annotations

import numpy as np

DATA_AXIS = "data"


def make_data_mesh(n_devices: int | None = None):
    """1-D placement mesh over the first ``n_devices`` devices (default:
    all).  The placement sweep has no model axis -- ids are the only
    partitioned dimension."""
    import jax

    devs = jax.devices()
    if n_devices is not None:
        if n_devices > len(devs):
            raise ValueError(
                f"asked for {n_devices} devices, only {len(devs)} present "
                "(force more with --xla_force_host_platform_device_count)"
            )
        devs = devs[:n_devices]
    return jax.sharding.Mesh(np.asarray(devs), (DATA_AXIS,))


class ShardedSweep:
    """Mesh-wide bulk placement/diff sweeps bound to one ``PlacementEngine``.

    Construction is cheap (no compile, no upload); the shard_map callables
    are built and jitted lazily per (kind, static-config) and cached, so a
    steady-state sweep re-traces nothing.  All methods accept id streams of
    ANY length -- ids are zero-padded to a shard multiple on the host and
    the pad lanes carry weight 0, so they cannot contribute to any
    histogram, matrix or count (tested), and per-id outputs are trimmed
    back by the host-facing wrappers.
    """

    def __init__(self, engine, mesh=None):
        self.engine = engine
        self.mesh = make_data_mesh() if mesh is None else mesh
        if tuple(self.mesh.axis_names) != (DATA_AXIS,):
            raise ValueError(
                f"placement mesh must be 1-D over ('{DATA_AXIS}',); "
                f"got axes {tuple(self.mesh.axis_names)}"
            )
        self.n_devices = int(self.mesh.devices.size)
        self._fns: dict[tuple, object] = {}

    # -- padding --------------------------------------------------------------

    def _pad(self, datum_ids):
        """(ids_padded, weights, n_valid): host-side zero-pad to a multiple
        of ``n_devices`` so every shard gets an equal slice.  Pad lanes get
        weight 0 -- the single mechanism that keeps them out of every
        reduction (and out of ``moved`` in the diff paths)."""
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        n = ids.shape[0]
        pad = (-n) % self.n_devices
        w = np.ones(n + pad, dtype=np.int32)
        if pad:
            ids = np.concatenate([ids, np.zeros(pad, dtype=np.uint32)])
            w[n:] = 0
        return ids, w, n

    # -- shard_map plumbing ---------------------------------------------------

    def _cached(self, key: tuple, build):
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = build()
        return fn

    def _shard_jit(self, body, n_tables: int, *, n_out: int = 1, reduced: bool):
        """jit(shard_map(body)): ids+weights partitioned, tables replicated,
        outputs either partitioned per-lane arrays or one psum-reduced
        (replicated) array."""
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        in_specs = (P(DATA_AXIS), P(DATA_AXIS)) + (P(),) * n_tables
        one = P() if reduced else P(DATA_AXIS)
        out_specs = one if n_out == 1 else (one,) * n_out
        return jax.jit(
            shard_map(
                body,
                mesh=self.mesh,
                in_specs=in_specs,
                out_specs=out_specs,
                check_rep=False,  # while_loop ladders have no replication rule
            )
        )

    # -- table plumbing (replicated operands) ---------------------------------

    def _asura_tables(self, version: int | None):
        eng = self.engine
        if version is None:
            art = eng._device_artifact("asura")
        else:
            art = eng._device_artifact_for(version, "asura")
        return art, (art.len32_dev, art.cum_hi_dev, art.cum_lo_dev, art.node_of_dev)

    def _alg_tables(self, alg: str):
        """(tables, statics) for the single-version owner sweep."""
        eng = self.engine
        if alg == "asura":
            art, tables = self._asura_tables(None)
            statics = (art.top_level, eng.params.s_log2, eng.params.max_draws)
        else:
            art = eng._device_artifact(alg)
            tables = (art.keys_dev, art.vals_dev)
            statics = ()
        return tables, statics

    @staticmethod
    def _owners_body(alg: str, statics: tuple):
        """Per-shard owners: (ids, *tables) -> int32 node ids -- the same
        jnp kernels the single-device ``place_nodes_device`` runs."""
        if alg == "asura":
            from repro.kernels.ops import _place_fused_ref

            top_level, s_log2, max_draws = statics

            def owners(ids, len32, cum_hi, cum_lo, node_of):
                return _place_fused_ref(
                    ids, len32, cum_hi, cum_lo, node_of,
                    top_level=top_level, s_log2=s_log2, max_draws=max_draws,
                    emit_nodes=True,
                )

            return owners
        from repro.kernels.baselines import ch_lookup, rs_lookup, wrh_lookup

        lookup = {"ch": ch_lookup, "rs": rs_lookup, "wrh": wrh_lookup}[alg]

        def owners(ids, keys, vals):
            return lookup(ids, keys, vals)

        return owners

    # -- per-id sweeps (partitioned outputs) ----------------------------------

    def place_nodes_device(self, datum_ids, algorithm: str | None = None):
        """Mesh-partitioned batch placement -> (padded_batch,) int32 owners,
        shard-sharded device array (pad lanes place id 0 -- callers that
        need the exact stream use ``place_nodes``)."""
        alg = self.engine._resolve_algorithm(algorithm)
        tables, statics = self._alg_tables(alg)
        ids, w, _ = self._pad(datum_ids)
        owners = self._owners_body(alg, statics)

        def build():
            def body(ids_l, w_l, *tabs):
                return owners(ids_l, *tabs)

            return self._shard_jit(body, len(tables), reduced=False)

        fn = self._cached(("owners", alg, statics), build)
        return fn(ids, w, *tables)

    def place_nodes(self, datum_ids, algorithm: str | None = None) -> np.ndarray:
        """Host-facing mesh placement -> int64 owners, bit-identical to
        ``engine.place_nodes`` (one cross-shard gather + pad trim)."""
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        out = self.place_nodes_device(ids, algorithm)
        return np.asarray(out)[: ids.shape[0]].astype(np.int64)

    def diff_nodes_device(self, datum_ids, v_from: int, v_to: int):
        """Mesh-partitioned two-version diff -> (moved, src, dst) shard-
        sharded device arrays, padded length; pad lanes have moved=False
        (weight-masked), so downstream counts/selections see no phantoms."""
        self.engine._require_asura("diff_nodes_device")
        art_a, tabs_a = self._asura_tables(v_from)
        art_b, tabs_b = self._asura_tables(v_to)
        p = self.engine.params
        statics = (art_a.top_level, art_b.top_level, p.s_log2, p.max_draws)
        ids, w, _ = self._pad(datum_ids)

        def build():
            from repro.kernels.ops import _diff_fused_ref

            top_a, top_b, s_log2, max_draws = statics

            def body(ids_l, w_l, la, ha, ca, na, lb, hb, cb, nb):
                moved, src, dst = _diff_fused_ref(
                    ids_l, la, ha, ca, na, lb, hb, cb, nb,
                    top_a=top_a, top_b=top_b,
                    s_log2=s_log2, max_draws=max_draws,
                )
                return moved & (w_l > 0), src, dst

            return self._shard_jit(body, 8, n_out=3, reduced=False)

        fn = self._cached(("diff", statics), build)
        return fn(ids, w, *tabs_a, *tabs_b)

    def diff_replicas_device(self, datum_ids, v_from: int, v_to: int, n_replicas: int):
        """Mesh-partitioned replica-set diff -> (moved, src, dst, src_slot)
        shard-sharded (padded_batch, R) device arrays; pad rows have
        moved all-False (weight-masked)."""
        self.engine._require_asura("diff_replicas_device")
        art_a, _ = self._asura_tables(v_from)
        art_b, _ = self._asura_tables(v_to)
        tabs = (
            art_a.len32_dev, art_a.node_of_dev,
            art_b.len32_dev, art_b.node_of_dev,
        )
        p = self.engine.params
        statics = (
            art_a.top_level, art_b.top_level, p.s_log2, p.max_draws, n_replicas
        )
        ids, w, _ = self._pad(datum_ids)

        def build():
            from repro.kernels.ops import _diff_replicas_fused_ref

            top_a, top_b, s_log2, max_draws, R = statics

            def body(ids_l, w_l, la, na, lb, nb):
                moved, src, dst, src_slot = _diff_replicas_fused_ref(
                    ids_l, la, na, lb, nb,
                    top_a=top_a, top_b=top_b,
                    s_log2=s_log2, max_draws=max_draws, n_replicas=R,
                )
                return moved & (w_l > 0)[:, None], src, dst, src_slot

            return self._shard_jit(body, 4, n_out=4, reduced=False)

        fn = self._cached(("rdiff", statics), build)
        return fn(ids, w, *tabs)

    # -- one-reduction sweeps (psum outputs) ----------------------------------

    def histogram(
        self,
        datum_ids,
        n_bins: int,
        algorithm: str | None = None,
        n_replicas: int | None = None,
    ) -> np.ndarray:
        """Per-node occupancy histogram in ONE mesh sweep -> (n_bins,) int64.

        Each shard places its ids and scatter-adds its weight-masked counts
        locally; the single cross-chip ``psum`` sums the per-shard
        histograms -- exact integer addition, so the result equals
        ``np.bincount(engine.place_nodes(ids), minlength=n_bins)`` bit for
        bit while never materializing the owner array on the host.  With
        ``n_replicas`` the ASURA replica sets are counted instead (each id
        contributes R counts; non-converged -1 slots are excluded).
        """
        import jax
        import jax.numpy as jnp

        alg = self.engine._resolve_algorithm(algorithm)
        ids, w, _ = self._pad(datum_ids)
        if n_replicas is None:
            tables, statics = self._alg_tables(alg)
            owners = self._owners_body(alg, statics)
            key = ("hist", alg, statics, n_bins)

            def build():
                def body(ids_l, w_l, *tabs):
                    nodes = owners(ids_l, *tabs)
                    hist = jnp.zeros((n_bins,), jnp.int32)
                    hist = hist.at[jnp.maximum(nodes, 0)].add(
                        jnp.where(nodes >= 0, w_l, 0)
                    )
                    return jax.lax.psum(hist, DATA_AXIS)

                return self._shard_jit(body, len(tables), reduced=True)

        else:
            if alg != "asura":
                raise ValueError("replica histograms are ASURA-only")
            art, _ = self._asura_tables(None)
            tables = (art.len32_dev, art.node_of_dev)
            p = self.engine.params
            statics = (
                art.top_level, p.s_log2, p.max_draws, n_replicas, n_bins
            )
            key = ("rhist", statics)

            def build():
                from repro.kernels.ops import _place_replicas_fused_ref

                top_level, s_log2, max_draws, R, bins = statics

                def body(ids_l, w_l, len32, node_of):
                    nodes = _place_replicas_fused_ref(
                        ids_l, len32, node_of,
                        top_level=top_level, s_log2=s_log2,
                        max_draws=max_draws, n_replicas=R, emit_nodes=True,
                    )
                    hist = jnp.zeros((bins,), jnp.int32)
                    hist = hist.at[jnp.maximum(nodes, 0)].add(
                        jnp.where(nodes >= 0, w_l[:, None], 0)
                    )
                    return jax.lax.psum(hist, DATA_AXIS)

                return self._shard_jit(body, len(tables), reduced=True)

        fn = self._cached(key, build)
        return np.asarray(fn(ids, w, *tables)).astype(np.int64)

    def movement_matrix(
        self,
        datum_ids,
        v_from: int,
        v_to: int,
        n_bins: int,
        n_replicas: int | None = None,
    ) -> tuple[int, np.ndarray]:
        """(n_moved, (n_bins, n_bins) src->dst matrix) in ONE mesh sweep.

        The section-6.D movement accounting at mesh scale: each shard diffs
        its ids (single-owner, or the per-slot replica alignment with
        ``n_replicas``) and scatter-adds its weight-masked moved rows into
        a local (src, dst) matrix; the single cross-chip ``psum`` sums the
        matrices and ``n_moved`` is the matrix total -- both exact, equal
        to the single-device planner's moved rows bit for bit.
        """
        import jax
        import jax.numpy as jnp

        self.engine._require_asura("movement_matrix")
        art_a, tabs_a = self._asura_tables(v_from)
        art_b, tabs_b = self._asura_tables(v_to)
        p = self.engine.params
        ids, w, _ = self._pad(datum_ids)
        if n_replicas is None:
            tabs = tabs_a + tabs_b
            statics = (
                art_a.top_level, art_b.top_level, p.s_log2, p.max_draws, n_bins
            )
            key = ("mmat", statics)

            def build():
                from repro.kernels.ops import _diff_fused_ref

                top_a, top_b, s_log2, max_draws, bins = statics

                def body(ids_l, w_l, la, ha, ca, na, lb, hb, cb, nb):
                    moved, src, dst = _diff_fused_ref(
                        ids_l, la, ha, ca, na, lb, hb, cb, nb,
                        top_a=top_a, top_b=top_b,
                        s_log2=s_log2, max_draws=max_draws,
                    )
                    add = (moved & (w_l > 0)).astype(jnp.int32)
                    mat = jnp.zeros((bins, bins), jnp.int32)
                    mat = mat.at[jnp.maximum(src, 0), jnp.maximum(dst, 0)].add(add)
                    return jax.lax.psum(mat, DATA_AXIS)

                return self._shard_jit(body, len(tabs), reduced=True)

        else:
            tabs = (
                art_a.len32_dev, art_a.node_of_dev,
                art_b.len32_dev, art_b.node_of_dev,
            )
            statics = (
                art_a.top_level, art_b.top_level,
                p.s_log2, p.max_draws, n_replicas, n_bins,
            )
            key = ("rmmat", statics)

            def build():
                from repro.kernels.ops import _diff_replicas_fused_ref

                top_a, top_b, s_log2, max_draws, R, bins = statics

                def body(ids_l, w_l, la, na, lb, nb):
                    moved, src, dst, _slot = _diff_replicas_fused_ref(
                        ids_l, la, na, lb, nb,
                        top_a=top_a, top_b=top_b,
                        s_log2=s_log2, max_draws=max_draws, n_replicas=R,
                    )
                    add = (moved & (w_l > 0)[:, None]).astype(jnp.int32)
                    mat = jnp.zeros((bins, bins), jnp.int32)
                    mat = mat.at[jnp.maximum(src, 0), jnp.maximum(dst, 0)].add(add)
                    return jax.lax.psum(mat, DATA_AXIS)

                return self._shard_jit(body, len(tabs), reduced=True)

        fn = self._cached(key, build)
        mat = np.asarray(fn(ids, w, *tabs)).astype(np.int64)
        return int(mat.sum()), mat

    # -- serving (DESIGN.md section 12) ---------------------------------------

    def serve_stream(self, **kwargs):
        """A ``RequestStreamDriver`` sharding its request stream over this
        mesh: each shard generates its slice of the global lane range
        (bit-identical words by the counter-based construction), routes and
        selects against the replicated tables + start-of-batch counters,
        and the per-node load histogram merges with ONE exact integer psum
        per batch -- so the sharded stream equals the single-device stream
        bit for bit (selftest-enforced)."""
        from repro.serve import RequestStreamDriver

        return RequestStreamDriver(self.engine, mesh=self, **kwargs)


# ---------------------------------------------------------------------------
# Bit-identity selftest (the forced-host-device smoke; tests + CI call this)
# ---------------------------------------------------------------------------


def selftest(n_devices: int | None = None, n_ids: int = 100_003) -> int:
    """Assert sharded == single-device, all four algorithms, R in {1, 3}.

    ``n_ids`` is deliberately odd (it must not divide the mesh) so the
    pad-lane masking is exercised on every entry point.  Returns the
    device count it ran on.
    """
    from repro.core import PlacementEngine, make_uniform_cluster
    from repro.migrate import MigrationPlanner

    n_nodes = 32
    ids = np.arange(n_ids, dtype=np.uint32)
    mesh = make_data_mesh(n_devices)

    # placement + histogram, all four algorithms
    cluster = make_uniform_cluster(n_nodes)
    for alg in ("asura", "ch", "wrh", "rs"):
        eng = PlacementEngine(cluster, backend="ref", algorithm=alg)
        sw = ShardedSweep(eng, mesh)
        ref = eng.place_nodes(ids)
        got = sw.place_nodes(ids)
        assert np.array_equal(ref, got), f"{alg}: sharded owners differ"
        hist = sw.histogram(ids, n_nodes)
        assert np.array_equal(
            hist, np.bincount(ref, minlength=n_nodes)
        ), f"{alg}: sharded histogram differs"

    engine = PlacementEngine(cluster, backend="ref")
    sweep = ShardedSweep(engine, mesh)

    # replica histograms, R in {1, 3}
    for R in (1, 3):
        nodes = engine.place_replica_nodes(ids, R)
        hist = sweep.histogram(ids, n_nodes, n_replicas=R)
        assert np.array_equal(
            hist, np.bincount(nodes.ravel(), minlength=n_nodes)
        ), f"R={R}: sharded replica histogram differs"

    # version diff + movement matrix + sharded planner, R in {1, 3}
    engine.artifact()
    v0 = cluster.version
    cluster.add_node(n_nodes, 1.0)
    v1 = cluster.version
    planner = MigrationPlanner(engine)
    plan = planner.plan(ids, v0, v1)
    n_moved, mat = sweep.movement_matrix(ids, v0, v1, n_nodes + 1)
    assert n_moved == plan.n_moves, "sharded moved count differs"
    ref_mat = np.zeros((n_nodes + 1, n_nodes + 1), dtype=np.int64)
    np.add.at(ref_mat, (plan.src, plan.dst), 1)
    assert np.array_equal(mat, ref_mat), "sharded movement matrix differs"
    splan = planner.plan(ids, v0, v1, mesh=mesh)
    fields = ("ids", "src", "dst", "index", "slot", "src_slot")
    for field in fields:
        assert np.array_equal(
            getattr(plan, field), getattr(splan, field)
        ), f"sharded plan field {field} differs"
    for R in (1, 3):
        rplan = planner.plan_replicas(ids, v0, v1, R)
        srplan = planner.plan_replicas(ids, v0, v1, R, mesh=mesh)
        for field in fields:
            assert np.array_equal(
                getattr(rplan, field), getattr(srplan, field)
            ), f"R={R}: sharded replica plan field {field} differs"
        rn, _ = sweep.movement_matrix(ids, v0, v1, n_nodes + 1, n_replicas=R)
        assert rn == rplan.n_moves, f"R={R}: sharded replica moved count differs"

    # mesh-sharded serving stream == single-device stream, bit for bit:
    # chosen nodes, load counters and queue state, every batch, all four
    # algorithms, R in {1, 3} (DESIGN.md section 12)
    from repro.serve import RequestStreamDriver

    serve_cluster = make_uniform_cluster(16)
    batch = 256 * int(mesh.devices.size)
    for alg in ("asura", "ch", "wrh", "rs"):
        eng_s = PlacementEngine(serve_cluster, backend="ref", algorithm=alg)
        for R in (1, 3):
            kw = dict(
                batch=batch, n_keys=4096, law="zipf",
                n_replicas=R, policy="pow2", seed=7,
            )
            solo = RequestStreamDriver(eng_s, **kw)
            shard = RequestStreamDriver(eng_s, mesh=mesh, **kw)
            for _step in range(3):
                a = np.asarray(solo.step())
                b = np.asarray(shard.step())
                assert np.array_equal(a, b), (
                    f"{alg} R={R} step {_step}: sharded chosen nodes differ"
                )
                assert np.array_equal(
                    solo.load_counts(), shard.load_counts()
                ), f"{alg} R={R} step {_step}: sharded load counters differ"
                assert np.array_equal(
                    np.asarray(solo.queue), np.asarray(shard.queue)
                ), f"{alg} R={R} step {_step}: sharded queue state differs"

    # metrics slab: the mesh-sharded instrumented stream's psum-merged
    # snapshot equals the single-device snapshot bit for bit (same exact
    # integer reduction contract as the load histogram)
    from repro.obs import MetricsRegistry

    eng_m = PlacementEngine(serve_cluster, backend="ref", algorithm="asura")
    for R in (1, 3):
        kw = dict(
            batch=batch, n_keys=4096, law="zipf",
            n_replicas=R, policy="pow2", seed=7,
        )
        reg_solo, reg_shard = MetricsRegistry(), MetricsRegistry()
        solo = RequestStreamDriver(eng_m, metrics=reg_solo, **kw)
        shard = RequestStreamDriver(eng_m, mesh=mesh, metrics=reg_shard, **kw)
        for _step in range(3):
            solo.step()
            shard.step()
        snap_a, snap_b = reg_solo.snapshot(), reg_shard.snapshot()
        assert set(snap_a) == set(snap_b), "metric name sets differ"
        for name in snap_a:
            assert np.array_equal(snap_a[name], snap_b[name]), (
                f"R={R}: sharded metric {name!r} differs"
            )

    # two-level (domain, node) placement smoke: the fused hierarchy kernel
    # (through the engine, on the forced host devices) must equal the
    # HierarchicalCluster NumPy oracle bit for bit, and the mesh-sharded
    # serving stream on a hierarchical engine must match the single-device
    # stream (DESIGN.md section 14)
    from repro.core import HierarchicalCluster

    hcluster = HierarchicalCluster()
    for d in range(4):
        for i in range(4):
            hcluster.add_node(d, 100 + d * 4 + i, 1.0 + 0.25 * i)
    heng = PlacementEngine(hcluster, backend="ref")
    hids = ids[: min(n_ids, 20_011)]
    for R in (1, 3):
        got = heng.place_replica_pairs(hids, R)
        want = hcluster.place_replicas(hids, R)
        assert np.array_equal(got, want), (
            f"R={R}: two-level kernel differs from the oracle"
        )
    assert np.array_equal(heng.place_nodes(hids), want[:, 0, 1]), (
        "two-level place_nodes differs from the oracle primary"
    )
    for R in (1, 3):
        kw = dict(
            batch=batch, n_keys=4096, law="zipf",
            n_replicas=R, policy="pow2", seed=7,
        )
        solo = RequestStreamDriver(heng, **kw)
        shard = RequestStreamDriver(heng, mesh=mesh, **kw)
        for _step in range(3):
            assert np.array_equal(
                np.asarray(solo.step()), np.asarray(shard.step())
            ), f"hier R={R} step {_step}: sharded chosen nodes differ"
            assert np.array_equal(
                solo.load_counts(), shard.load_counts()
            ), f"hier R={R} step {_step}: sharded load counters differ"

    # scan-fused superstep: a mesh-sharded superstep(k) must equal k
    # single-device step() calls bit for bit -- chosen, counters, queue
    # (DESIGN.md section 15; the per-sub-batch psum stays inside the scan)
    eng_k = PlacementEngine(serve_cluster, backend="ref", algorithm="asura")
    kw = dict(
        batch=batch, n_keys=4096, law="zipf",
        n_replicas=3, policy="pow2", seed=7,
    )
    solo = RequestStreamDriver(eng_k, **kw)
    shard = RequestStreamDriver(eng_k, mesh=mesh, **kw)
    k = 3
    for _block in range(2):
        a = np.stack([np.asarray(solo.step()) for _ in range(k)])
        b = np.asarray(shard.superstep(k))
        assert np.array_equal(a, b), (
            f"block {_block}: sharded superstep chosen nodes differ"
        )
        assert np.array_equal(
            solo.load_counts(), shard.load_counts()
        ), f"block {_block}: superstep load counters differ"
        assert np.array_equal(
            np.asarray(solo.queue), np.asarray(shard.queue)
        ), f"block {_block}: superstep queue state differs"
    return sweep.n_devices


def main(argv=None) -> int:
    import argparse
    import os

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--selftest", action="store_true")
    ap.add_argument(
        "--devices",
        type=int,
        default=None,
        help="force this many host devices (set before first jax init)",
    )
    ap.add_argument("--ids", type=int, default=100_003)
    args = ap.parse_args(argv)
    if args.devices is not None:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        ).strip()
    if not args.selftest:
        print("nothing to do (pass --selftest)")
        return 0
    n_dev = selftest(args.devices, n_ids=args.ids)
    print(f"sharded placement selftest OK on {n_dev} devices")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
