"""Serving driver: batched decode with ASURA request routing.

Requests are routed to serving replicas by ASURA on the request id -- the
same placement function the storage layer uses, so adding/removing replicas
remaps only the minimal set of sessions (sticky sessions move only off dead
replicas).  This process simulates one replica taking its share of a
synthetic request stream and decoding tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
        --replicas 4 --replica-id 0 --requests 64 --decode-len 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core import make_uniform_cluster
from repro.models import init_cache, init_params, reduced_config
from repro.train import make_serve_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--replica-id", type=int, default=0)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--decode-len", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)

    # ASURA request routing via the PlacementEngine: the replica-membership
    # table is canonicalized once and reused for every routing call below.
    routing = make_uniform_cluster(args.replicas)
    engine = routing.engine
    req_ids = np.arange(args.requests, dtype=np.uint32)
    owners = engine.place_nodes(req_ids)
    mine = req_ids[owners == args.replica_id]
    print(
        f"replica {args.replica_id} serves {mine.size}/{args.requests} requests "
        f"(engine backend={engine.backend}, table uploads={engine.uploads})"
    )

    params = init_params(cfg, jax.random.PRNGKey(0))
    serve = jax.jit(make_serve_step(cfg))
    done = 0
    t0 = time.time()
    for start in range(0, mine.size, args.batch):
        ids = mine[start : start + args.batch]
        if ids.size < args.batch:  # pad the tail batch
            ids = np.pad(ids, (0, args.batch - ids.size))
        cache = init_cache(cfg, args.batch, args.cache_len)
        tokens = jnp.asarray(ids % cfg.vocab, jnp.int32)[:, None]
        for t in range(args.decode_len):
            batch = {
                "tokens": tokens,
                "positions": jnp.full((args.batch, 1), t, jnp.int32),
            }
            logits, cache = serve(params, cache, batch)
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        done += int(ids.size)
    dt = time.time() - t0
    print(
        f"decoded {done} requests x {args.decode_len} tokens in {dt:.2f}s "
        f"({done*args.decode_len/max(dt,1e-9):.1f} tok/s)"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
