"""End-to-end training driver (CPU-runnable at reduced scale).

Wires every substrate together: ASURA-placed data pipeline -> sharded model
-> AdamW -> ASURA-replicated async checkpointing -> failure recovery.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --reduced --steps 50 --batch 8 --seq 128

``--reduced`` shrinks the config for CPU; omit it on a real TPU fleet.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsuraCheckpointStore, CheckpointManager
from repro.configs import ARCHS, get_config
from repro.core import make_uniform_cluster
from repro.data import DataPipeline, ShardedDataset
from repro.models import init_params, reduced_config
from repro.train import AdamWConfig, init_train_state, make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    print(f"arch={cfg.name} params~{cfg.param_count():.3g}")

    # data: ASURA-placed shards for this host
    ingest = make_uniform_cluster(args.hosts)
    dataset = ShardedDataset(
        n_shards=max(64, args.hosts * 8),
        tokens_per_shard=args.batch * args.seq * 4,
        vocab=cfg.vocab,
    )
    pipeline = DataPipeline(
        dataset, ingest, args.host_id, batch_per_host=args.batch, seq_len=args.seq
    )
    print(f"host {args.host_id} owns {pipeline.owned_shards.size} shards")

    # checkpoint store: ASURA-replicated
    store = AsuraCheckpointStore({i: 1.0 for i in range(6)}, n_replicas=3)
    mgr = CheckpointManager(store)

    rng = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, rng)
    opt_state = init_train_state(cfg, params)
    step_fn = jax.jit(
        make_train_step(cfg, AdamWConfig(lr=args.lr), n_microbatches=args.microbatches)
    )

    it = pipeline.batches()
    losses = []
    t0 = time.time()
    for step in range(args.steps):
        try:
            tokens = next(it)
        except StopIteration:
            it = pipeline.batches(epoch=step)
            tokens = next(it)
        batch = {"tokens": jnp.asarray(tokens)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16
            )
        if cfg.vision_prefix:
            batch["patches"] = jnp.zeros(
                (args.batch, cfg.vision_prefix, cfg.d_model), jnp.bfloat16
            )
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % 5 == 0 or step == args.steps - 1:
            print(
                f"step {step:4d} loss {losses[-1]:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"({(time.time()-t0)/(step+1):.2f}s/step)"
            )
        if args.ckpt_every and step % args.ckpt_every == 0 and step > 0:
            mgr.save_async(step, {"params": params, "opt": opt_state})
    mgr.wait()
    first = np.mean(losses[:3])
    last = np.mean(losses[-3:])
    print(f"loss {first:.4f} -> {last:.4f} ({'improved' if last < first else 'NOT improved'})")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
