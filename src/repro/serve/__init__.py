from .router import ReplicaRouter, Router

__all__ = ["ReplicaRouter", "Router"]
