from .router import ReplicaRouter

__all__ = ["ReplicaRouter"]
