from .router import ReplicaRouter, Router
from .stream import POLICIES, RequestStreamDriver
from .traffic import LAWS, TrafficModel

__all__ = [
    "LAWS",
    "POLICIES",
    "ReplicaRouter",
    "RequestStreamDriver",
    "Router",
    "TrafficModel",
]
