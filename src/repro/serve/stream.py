"""Batched device-resident serving pipeline (DESIGN.md section 12).

The paper's headline claims (sub-microsecond calc time, <1% load
variability) are about SERVING a placement function under real traffic.
``RequestStreamDriver`` is the batched, stateful driver that replaces
per-call routing on the serving hot path:

  * a device-resident request generator (``serve.traffic``): threefry
    fold-in streams per GLOBAL lane, exact-u32 CDF sampling -- no host RNG
    anywhere in the loop,
  * a fused route+select pass: the batch routes through the replica
    placement (ASURA's section-5.A kernel body, or the baselines' salted
    fan-out), then a replica-selection policy picks one of the R holders
    per request -- ``pow2`` is power-of-two-choices against the on-device
    per-node load counters (arXiv 2312.10360: redundancy level + selection
    policy jointly set the achievable balance),
  * on-device load state: per-node served counters, a queue-depth
    recurrence ``q' = max(q + arrivals - service, 0)`` and a queue-history
    ring for p99 -- scatter-updated in the same jit,

all inside ONE jit per step with zero host syncs (transfer-guard tested).
Every selection in a batch reads the START-of-batch counters and the batch
histogram merges once -- the standard batched approximation of
least-loaded-of-two, and the property that makes the mesh path exact:

``mesh=`` shards the request stream over ``launch/placement_mesh``'s 1-D
``data`` mesh (the PR-6 follow-up): each shard generates ITS slice of the
global lane range (bit-identical words by the counter-based construction),
routes and selects against the replicated kilobyte tables and replicated
start-of-batch counters, and the per-node load histogram merges with ONE
exact integer psum per batch -- so the sharded stream is bit-identical to
the single-device stream (selftest-enforced at 8 forced host devices).

External id batches (``route_batch``) reuse the migration planner's pow2
bucketing so ragged tails share one compile per bucket, and
``serve_migrating`` drives the stream through a live migration window via
the cached fused ``route_replicas_device`` probe -- dual-version serving
keeps working under the batched driver.
"""

from __future__ import annotations

import math

import numpy as np

from repro.migrate.planner import pad_pow2

from .traffic import TrafficModel

POLICIES = ("primary", "random", "pow2")

DEFAULT_BATCH = 1 << 16
DEFAULT_KEYS = 1 << 20
DEFAULT_HIST = 256  # queue-history ring rows (p99 window)


def route_statics(engine, algorithm: str | None = None):
    """(tables, statics) for a replica-routing body under ``algorithm``.

    ``tables`` are the replicated device operands; ``statics`` is a
    hashable key that fully determines the body (the compile-cache key the
    driver, router probe and mesh serving path all share)."""
    alg = engine._resolve_algorithm(algorithm)
    if getattr(engine, "hierarchical", False):
        art = engine.hier_artifact()
        tables = art.tables_dev
        statics = (
            "hier", art.top_level, art.max_top, art.s_pad,
            engine.params.s_log2, engine.params.max_draws,
        )
    elif alg == "asura":
        art = engine._device_artifact("asura")
        tables = (art.len32_dev, art.node_of_dev)
        statics = ("asura", art.top_level, engine.params.s_log2, engine.params.max_draws)
    else:
        art = engine._device_artifact(alg)
        tables = (art.keys_dev, art.vals_dev)
        statics = (alg,)
    return tables, statics


def replica_owners_body(statics: tuple, n_replicas: int, emit_stats: bool = False):
    """Per-shard replica owners: (ids, *tables) -> (batch, R) int32 -- the
    same jnp kernel bodies the single-device engine paths run (the
    ``ShardedSweep._owners_body`` idiom, R-way).

    ``emit_stats=True`` returns ``(owners, stats)`` instead, where
    ``stats`` is the algorithm's uint32 device-plane vector (ASURA:
    ``[ladder_depth_hist..., nonconverged]`` of length ``DEPTH_BINS + 1``;
    baselines: ``[reprobes]``) -- owners are bit-identical either way.

    ``hier`` statics route the fused two-level kernel and emit the NODE
    plane (the request stream balances over node holders; the domains are
    a placement property, not a routing one).  Stats plumbing is flat-path
    only for now."""
    alg = statics[0]
    if alg == "hier":
        if emit_stats:
            raise NotImplementedError(
                "hierarchical serving has no stats plane yet; route with "
                "emit_stats=False"
            )
        from repro.kernels.hierarchy import hier_place_replicas_ref

        _, top_level, max_top, s_pad, s_log2, max_draws = statics

        def owners(ids, *tables):
            out = hier_place_replicas_ref(
                ids, *tables,
                top_level=top_level, max_top=max_top, s_log2=s_log2,
                max_draws=max_draws, s_pad=s_pad, n_replicas=n_replicas,
            )
            return out[1].T  # (batch, R) node plane

        return owners
    if alg == "asura":
        from repro.kernels.ops import _place_replicas_fused_ref

        _, top_level, s_log2, max_draws = statics

        def owners(ids, len32, node_of):
            return _place_replicas_fused_ref(
                ids, len32, node_of,
                top_level=top_level, s_log2=s_log2, max_draws=max_draws,
                n_replicas=n_replicas, emit_nodes=True, emit_stats=emit_stats,
            )

        return owners
    from repro.kernels.baselines import _LOOKUP, baseline_replicas_lookup

    lookup = _LOOKUP[alg]

    def owners(ids, keys, vals):
        return baseline_replicas_lookup(
            lookup, ids, keys, vals, n_replicas=n_replicas,
            emit_stats=emit_stats,
        )

    return owners


def select_replica(owners, sel, counts, *, policy: str, n_replicas: int):
    """Pick one holder per request -> (batch,) int32 chosen nodes.

    ``owners`` is (batch, R) with -1 marking non-converged slots (masked:
    an invalid candidate always loses, and a fully-invalid row falls back
    to a clamped primary).  ``pow2`` draws two DISTINCT slots from the
    selection word and takes the one with the smaller start-of-batch
    counter (strict <, first-slot tie-break); ``random`` takes one slot
    uniformly; ``primary`` (or R == 1) always slot 0.
    """
    import jax.numpy as jnp

    prim = jnp.maximum(owners[:, 0], 0)
    if policy == "primary" or n_replicas == 1:
        return prim
    R = n_replicas
    if policy == "random":
        slot = (sel % jnp.uint32(R)).astype(jnp.int32)
        chosen = jnp.take_along_axis(owners, slot[:, None], axis=1)[:, 0]
        return jnp.where(chosen >= 0, chosen, prim)
    if policy != "pow2":
        raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
    i = (sel % jnp.uint32(R)).astype(jnp.int32)
    off = ((sel >> jnp.uint32(16)) % jnp.uint32(R - 1)).astype(jnp.int32)
    j = (i + 1 + off) % R
    a = jnp.take_along_axis(owners, i[:, None], axis=1)[:, 0]
    b = jnp.take_along_axis(owners, j[:, None], axis=1)[:, 0]
    big = jnp.iinfo(jnp.int32).max
    la = jnp.where(a >= 0, jnp.take(counts, jnp.maximum(a, 0)), big)
    lb = jnp.where(b >= 0, jnp.take(counts, jnp.maximum(b, 0)), big)
    chosen = jnp.where(lb < la, b, a)
    return jnp.where(chosen >= 0, chosen, prim)


class RequestStreamDriver:
    """Stateful batched serving simulator bound to one ``PlacementEngine``.

    Device state (all jax arrays; the host only ever reads them through
    the explicit metric accessors):

      * ``counts`` -- (n_bins,) int32 cumulative served requests per node,
      * ``queue``  -- (n_bins,) int32 current queue depth per node
        (``service_rate`` requests drain per node per step),
      * ``qhist``  -- (max_hist, n_bins) int32 queue-depth ring (p99),
      * ``_step``  -- int32 device scalar (the fold-in stream position).

    ``step()`` runs one fused generate+route+select+count batch and
    returns the chosen nodes (device array; shard-partitioned on a mesh).
    ``step_traces`` counts jit traces of the fused step -- the tripwire
    that repeated steps stop retracing.
    """

    def __init__(
        self,
        engine,
        *,
        batch: int = DEFAULT_BATCH,
        n_keys: int = DEFAULT_KEYS,
        law: str = "zipf",
        alpha: float = 1.1,
        hot_fraction: float = 0.9,
        hot_keys: int = 64,
        n_replicas: int = 3,
        policy: str = "pow2",
        seed: int = 0,
        service_rate: int | None = None,
        max_hist: int = DEFAULT_HIST,
        n_bins: int | None = None,
        mesh=None,
        algorithm: str | None = None,
        metrics=None,
        ledger=None,
    ):
        import jax
        import jax.numpy as jnp

        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
        self.engine = engine
        self.algorithm = engine._resolve_algorithm(algorithm)
        self.batch = int(batch)
        self.n_replicas = int(n_replicas)
        self.policy = policy
        self.max_hist = int(max_hist)
        self.traffic = TrafficModel(
            n_keys, law=law, alpha=alpha,
            hot_fraction=hot_fraction, hot_keys=hot_keys, seed=seed,
        )
        self._sweep = None
        if mesh is not None:
            from repro.launch.placement_mesh import ShardedSweep

            self._sweep = (
                mesh if isinstance(mesh, ShardedSweep) else ShardedSweep(engine, mesh)
            )
            if self.batch % self._sweep.n_devices:
                raise ValueError(
                    f"batch ({self.batch}) must divide the mesh "
                    f"({self._sweep.n_devices} devices)"
                )
        nodes = getattr(engine.cluster, "nodes", None)
        if nodes is None and getattr(engine, "hierarchical", False):
            # two-level cluster: the artifact's node -> domain map is the
            # flat node-id space the load/queue planes index
            nodes = engine.hier_artifact().node_domain
        if n_bins is not None:
            self.n_bins = int(n_bins)
        elif nodes:
            self.n_bins = int(max(nodes)) + 1
        else:  # table-only cluster: size off the seg->node map
            self.n_bins = int(np.max(engine.artifact().node_of)) + 1
        n_active = len(nodes) if nodes else self.n_bins
        if service_rate is None:
            # 25% capacity headroom over the mean arrival rate: uniform
            # traffic keeps queues near zero, skew shows up as real depth.
            service_rate = max(1, math.ceil(1.25 * self.batch / max(1, n_active)))
        self.service_rate = int(service_rate)
        self._service = jnp.full((self.n_bins,), self.service_rate, jnp.int32)
        self._key = jax.random.PRNGKey(seed)
        from repro.obs import TraceLedger

        # Instance-scoped by default so the exact trace-count tripwires
        # never alias across drivers; pass a shared ledger to unify.
        self.ledger = ledger if ledger is not None else TraceLedger()
        self.metrics = metrics
        self._instrumented = metrics is not None and metrics.enabled
        if self._instrumented:
            self._register_metrics()
        self._fns: dict = {}
        self.reset()

    def _register_metrics(self) -> None:
        """Claim this driver's slab windows (append-only; idempotent)."""
        from repro.kernels.ref import DEPTH_BINS

        reg = self.metrics
        self._routed_name = reg.counter(
            f"serve.routed.{self.algorithm}.{self.policy}"
        )
        reg.histogram("serve.served", self.n_bins)
        if self.algorithm == "asura":
            reg.histogram("asura.ladder_depth", DEPTH_BINS)
            reg.counter("asura.nonconverged")
        else:
            reg.counter("baseline.reprobes")

    @property
    def step_traces(self) -> int:
        """Fused-step jit traces (the retrace tripwire) -- a ledger
        counter behind the PR-7 attribute name."""
        return self.ledger.counter("serve.step_traces")

    @property
    def superstep_traces(self) -> int:
        """Scan-fused superstep jit traces (the superstep retrace
        tripwire; one trace per distinct (statics, k))."""
        return self.ledger.counter("serve.superstep_traces")

    def _accumulate(self, delta, hist, stats):
        """Fold one batch's device-plane contributions into a slab delta
        (build-time no-op chain when uninstrumented -- never traced)."""
        from repro.kernels.ref import DEPTH_BINS

        reg = self.metrics
        delta = reg.add_hist(delta, "serve.served", hist)
        if stats is not None:
            if self.algorithm == "asura":
                delta = reg.add_hist(delta, "asura.ladder_depth", stats[:DEPTH_BINS])
                delta = reg.add(delta, "asura.nonconverged", stats[DEPTH_BINS])
            else:
                delta = reg.add(delta, "baseline.reprobes", stats[0])
        return delta

    # -- state ----------------------------------------------------------------

    def reset(self) -> None:
        """Zero the load/queue state and rewind the request stream."""
        import jax.numpy as jnp

        self.counts = jnp.zeros((self.n_bins,), jnp.int32)
        self.queue = jnp.zeros((self.n_bins,), jnp.int32)
        self.qhist = jnp.zeros((self.max_hist, self.n_bins), jnp.int32)
        self._step = jnp.zeros((), jnp.int32)
        self.steps_done = 0

    def _cached(self, key: tuple, build):
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = build()
        return fn

    # -- the fused step -------------------------------------------------------

    def _batch_body(self, statics: tuple):
        """The traced ONE-BATCH body ``step()`` and ``superstep()`` share:
        generate -> route -> select -> count, signature

            body(key, step_idx, counts, queue, qhist, *rest)
              -> (counts, queue, qhist, [slab,] step_idx + 1, chosen)

        where ``rest = [slab,] service, thresholds, *tables``.  Both
        drivers trace EXACTLY this function (step jits it directly, the
        superstep scans it), which is what makes ``superstep(k)``
        bit-identical to K sequential ``step()`` calls by construction.

        With a live ``MetricsRegistry`` the body also threads the u32
        metrics slab: routed/served/kernel-stats accumulate into a zeros
        DELTA slab in-register, and under a mesh the delta rides the
        batch's single exact integer psum alongside the per-node histogram
        (DESIGN.md section 13) -- still zero host syncs per batch.
        """
        import jax
        import jax.numpy as jnp

        batch, R = self.batch, self.n_replicas
        policy, n_bins, max_hist = self.policy, self.n_bins, self.max_hist
        id_salt = self.traffic.id_salt
        instrumented = self._instrumented
        owners_fn = replica_owners_body(statics, R, emit_stats=instrumented)
        sweep = self._sweep
        driver = self

        def body(key, step_idx, counts, queue, qhist, *rest):
            if instrumented:
                slab, service, thresholds, *tables = rest
            else:
                service, thresholds, *tables = rest
            if sweep is None:
                lanes = jnp.arange(batch, dtype=jnp.uint32)
            else:
                from repro.launch.placement_mesh import DATA_AXIS

                local = batch // sweep.n_devices
                first = jax.lax.axis_index(DATA_AXIS).astype(jnp.uint32) * local
                lanes = first + jnp.arange(local, dtype=jnp.uint32)
            ids, sel = TrafficModel.draw(key, step_idx, lanes, thresholds, id_salt)
            if instrumented:
                owners, stats = owners_fn(ids, *tables)
            else:
                owners = owners_fn(ids, *tables)
            chosen = select_replica(
                owners, sel, counts, policy=policy, n_replicas=R
            )
            hist = jnp.zeros((n_bins,), jnp.int32).at[chosen].add(1)
            if instrumented:
                delta = jnp.zeros_like(slab)
                delta = driver.metrics.add(
                    delta, driver._routed_name, lanes.shape[0]
                )
                delta = driver._accumulate(delta, hist, stats)
            if sweep is not None:
                from repro.launch.placement_mesh import DATA_AXIS

                if instrumented:
                    # the slab delta rides the step's ONE exact psum
                    merged = jax.lax.psum(
                        jnp.concatenate([hist, delta.astype(jnp.int32)]),
                        DATA_AXIS,
                    )
                    hist = merged[:n_bins]
                    delta = merged[n_bins:].astype(jnp.uint32)
                else:
                    hist = jax.lax.psum(hist, DATA_AXIS)
            counts = counts + hist
            queue = jnp.maximum(queue + hist - service, 0)
            qhist = jax.lax.dynamic_update_slice(
                qhist, queue[None], (step_idx % max_hist, jnp.int32(0))
            )
            if instrumented:
                return counts, queue, qhist, slab + delta, step_idx + 1, chosen
            return counts, queue, qhist, step_idx + 1, chosen

        return body

    def _spec_counts(self, statics: tuple) -> tuple[int, int]:
        """(n_in, n_rep_out) for the mesh shard_map wrap of a batch body."""
        # flat routing carries 2 table operands; the two-level path carries
        # the 8-array stacked hierarchy artifact (kernels/hierarchy.py)
        n_tables = (8 if statics[0] == "hier" else 2) + len(self._fixed_operands())
        n_in = (6 if self._instrumented else 5) + n_tables
        n_rep_out = 4 if self._instrumented else 3
        return n_in, n_rep_out

    def _step_fn(self, statics: tuple):
        """One-jit batch step: the shared batch body, jitted (shard_mapped
        on a mesh), plus the per-TRACE retrace tripwire."""
        import jax

        body = self._batch_body(statics)
        sweep = self._sweep
        driver = self

        def stepped(*args):
            driver.ledger.incr("serve.step_traces")  # fires per TRACE only
            return body(*args)

        if sweep is None:
            return jax.jit(stepped)
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.launch.placement_mesh import DATA_AXIS

        n_in, n_rep_out = self._spec_counts(statics)
        return jax.jit(
            shard_map(
                stepped,
                mesh=sweep.mesh,
                # everything replicated: lanes derive from axis_index, so
                # there is no partitioned INPUT at all -- only the chosen
                # lanes come back shard-partitioned.
                in_specs=(P(),) * n_in,
                out_specs=(P(),) * (n_rep_out + 1) + (P(DATA_AXIS),),
                check_rep=False,  # while_loop ladders have no replication rule
            )
        )

    def _superstep_fn(self, statics: tuple, k: int):
        """K fused batches in ONE jit, restructured around what actually
        needs to be sequential:

          1. generate ALL K sub-batches in one vectorized draw (every
             threefry word is a pure function of (key, step, lane)),
          2. route the joint (k*batch,) id block through ONE ladder
             while_loop -- amortizing the loop's per-iteration dispatch
             overhead k-fold instead of paying it per sub-batch,
          3. ``lax.scan`` only the counter-COUPLED tail (pow2 select,
             count, queue ring) with (counts, queue, qhist, [slab,]
             step_idx) as the carry.

        This is still bit-identical to K sequential ``step()`` calls:
        generation is counter-based (stateless), the routing loops are
        per-lane pure (a lane's result and its emitted stats never depend
        on which other lanes share the batch -- the same partition
        invariance the sharded stream's psum merge already relies on,
        selftest-enforced), and the selection scan reads counters fresh
        as of the previous sub-batch exactly as ``step()`` does.  The
        once-per-batch slab contributions (routed counter, kernel stats)
        fold in once per SUPERSTEP with the same u32 modular sum.  On a
        mesh the per-sub-batch exact psum stays INSIDE the scan (K+1
        psums fused into one dispatch), so sharded supersteps remain
        bit-identical to single-device.  ``chosen`` comes back stacked
        (k, batch).
        """
        import jax
        import jax.numpy as jnp

        batch, R = self.batch, self.n_replicas
        policy, n_bins, max_hist = self.policy, self.n_bins, self.max_hist
        id_salt = self.traffic.id_salt
        instrumented = self._instrumented
        owners_fn = replica_owners_body(statics, R, emit_stats=instrumented)
        sweep = self._sweep
        driver = self

        def super_body(key, step_idx, counts, queue, qhist, *rest):
            driver.ledger.incr("serve.superstep_traces")  # per TRACE only
            if instrumented:
                slab, service, thresholds, *tables = rest
            else:
                service, thresholds, *tables = rest
            if sweep is None:
                local = batch
                lanes = jnp.arange(batch, dtype=jnp.uint32)
            else:
                from repro.launch.placement_mesh import DATA_AXIS

                local = batch // sweep.n_devices
                first = jax.lax.axis_index(DATA_AXIS).astype(jnp.uint32) * local
                lanes = first + jnp.arange(local, dtype=jnp.uint32)

            # stage 1+2: all K sub-batches drawn and routed jointly
            steps = step_idx + jnp.arange(k, dtype=step_idx.dtype)
            ids, sel = jax.vmap(
                lambda s: TrafficModel.draw(key, s, lanes, thresholds, id_salt)
            )(steps)  # (k, local) each
            if instrumented:
                owners, stats = owners_fn(ids.reshape(k * local), *tables)
            else:
                owners = owners_fn(ids.reshape(k * local), *tables)
            owners = owners.reshape(k, local, R)

            # stage 3: the counter-coupled tail, scanned
            def sub(carry, xs):
                if instrumented:
                    counts, queue, qhist, slab, si = carry
                else:
                    counts, queue, qhist, si = carry
                owners_i, sel_i = xs
                chosen = select_replica(
                    owners_i, sel_i, counts, policy=policy, n_replicas=R
                )
                hist = jnp.zeros((n_bins,), jnp.int32).at[chosen].add(1)
                if instrumented:
                    delta = jnp.zeros_like(slab)
                    delta = driver._accumulate(delta, hist, None)
                if sweep is not None:
                    from repro.launch.placement_mesh import DATA_AXIS

                    if instrumented:
                        merged = jax.lax.psum(
                            jnp.concatenate([hist, delta.astype(jnp.int32)]),
                            DATA_AXIS,
                        )
                        hist = merged[:n_bins]
                        delta = merged[n_bins:].astype(jnp.uint32)
                    else:
                        hist = jax.lax.psum(hist, DATA_AXIS)
                counts = counts + hist
                queue = jnp.maximum(queue + hist - service, 0)
                qhist = jax.lax.dynamic_update_slice(
                    qhist, queue[None], (si % max_hist, jnp.int32(0))
                )
                if instrumented:
                    return (counts, queue, qhist, slab + delta, si + 1), chosen
                return (counts, queue, qhist, si + 1), chosen

            if instrumented:
                carry0 = (counts, queue, qhist, slab, step_idx)
            else:
                carry0 = (counts, queue, qhist, step_idx)
            carry, chosen = jax.lax.scan(sub, carry0, (owners, sel), length=k)
            if instrumented:
                # once-per-superstep slab contributions: the routed counter
                # and the joint route's kernel stats (their per-sub-batch
                # sums are the same u32 total -- partition invariance)
                counts, queue, qhist, slab, si = carry
                delta = jnp.zeros_like(slab)
                delta = driver.metrics.add(
                    delta, driver._routed_name, k * local
                )
                delta = driver._accumulate(delta, jnp.zeros((n_bins,), jnp.int32), stats)
                if sweep is not None:
                    from repro.launch.placement_mesh import DATA_AXIS

                    delta = jax.lax.psum(
                        delta.astype(jnp.int32), DATA_AXIS
                    ).astype(jnp.uint32)
                carry = (counts, queue, qhist, slab + delta, si)
            return (*carry, chosen)

        if sweep is None:
            return jax.jit(super_body)
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.launch.placement_mesh import DATA_AXIS

        n_in, n_rep_out = self._spec_counts(statics)
        return jax.jit(
            shard_map(
                super_body,
                mesh=sweep.mesh,
                in_specs=(P(),) * n_in,
                # stacked chosen is (k, local): partitioned on the LANE
                # axis, replicated over the scan axis.
                out_specs=(P(),) * (n_rep_out + 1) + (P(None, DATA_AXIS),),
                check_rep=False,
            )
        )

    def _fixed_operands(self):
        return (self._service, self.traffic.thresholds_dev)

    def step(self):
        """Serve one generated batch -> (batch,) int32 chosen nodes (device
        array; shard-partitioned over the mesh when sharded).  Zero host
        syncs: state stays on device, the stream position is a device
        scalar."""
        tables, statics = route_statics(self.engine, self.algorithm)
        fn = self._cached(("step", statics), lambda: self._step_fn(statics))
        if self._instrumented:
            (self.counts, self.queue, self.qhist, slab, self._step,
             chosen) = fn(
                self._key, self._step, self.counts, self.queue, self.qhist,
                self.metrics.slab(), *self._fixed_operands(), *tables,
            )
            self.metrics.set_slab(slab)
        else:
            self.counts, self.queue, self.qhist, self._step, chosen = fn(
                self._key, self._step, self.counts, self.queue, self.qhist,
                *self._fixed_operands(), *tables,
            )
        self.steps_done += 1
        return chosen

    def superstep(self, k: int):
        """Serve K generated batches in ONE host dispatch -> (k, batch)
        int32 chosen nodes (device array; lane-partitioned over the mesh
        when sharded).

        Bit-identical to K sequential ``step()`` calls -- same counters,
        queue ring, metrics slab and chosen nodes: generation and routing
        are per-lane pure, so the superstep draws and routes all K
        sub-batches JOINTLY (one ladder while_loop instead of K) and scans
        only the counter-coupled select/count tail (``_superstep_fn``).
        Amortizes both the host dispatch and the routing loop's
        per-iteration overhead ~k-fold; at most one slab transfer per
        superstep when instrumented.  Pick k so ``k * batch`` trails the
        metric-read cadence (README "Throughput tuning")."""
        k = int(k)
        if k < 1:
            raise ValueError(f"superstep needs k >= 1, got {k}")
        tables, statics = route_statics(self.engine, self.algorithm)
        fn = self._cached(
            ("superstep", statics, k), lambda: self._superstep_fn(statics, k)
        )
        if self._instrumented:
            (self.counts, self.queue, self.qhist, slab, self._step,
             chosen) = fn(
                self._key, self._step, self.counts, self.queue, self.qhist,
                self.metrics.slab(), *self._fixed_operands(), *tables,
            )
            self.metrics.set_slab(slab)
        else:
            self.counts, self.queue, self.qhist, self._step, chosen = fn(
                self._key, self._step, self.counts, self.queue, self.qhist,
                *self._fixed_operands(), *tables,
            )
        self.steps_done += k
        return chosen

    # -- external batches (pow2 bucketing -- ragged tails share compiles) -----

    def _route_batch_fn(self, statics: tuple):
        import jax
        import jax.numpy as jnp

        R, policy = self.n_replicas, self.policy
        n_bins, max_hist = self.n_bins, self.max_hist
        instrumented = self._instrumented
        # External batches carry pad lanes, whose kernel stats would be
        # phantom work -- only the valid-masked routed/served metrics
        # accumulate here, so the body routes without emit_stats.
        owners_fn = replica_owners_body(statics, R)
        driver = self

        @jax.jit
        def body(ids, n_valid, key, step_idx, counts, queue, qhist, *rest):
            driver.ledger.incr("serve.step_traces")
            if instrumented:
                slab, service, *tables = rest
            else:
                service, *tables = rest
            lanes = jnp.arange(ids.shape[0], dtype=jnp.uint32)
            valid = lanes < n_valid.astype(jnp.uint32)
            sel = TrafficModel.lane_words(key, step_idx, lanes, 1)[:, 0]
            owners = owners_fn(ids.astype(jnp.uint32), *tables)
            chosen = select_replica(
                owners, sel, counts, policy=policy, n_replicas=R
            )
            hist = jnp.zeros((n_bins,), jnp.int32).at[chosen].add(
                valid.astype(jnp.int32)  # pad lanes never count
            )
            counts = counts + hist
            queue = jnp.maximum(queue + hist - service, 0)
            qhist = jax.lax.dynamic_update_slice(
                qhist, queue[None], (step_idx % max_hist, jnp.int32(0))
            )
            if instrumented:
                delta = jnp.zeros_like(slab)
                delta = driver.metrics.add(delta, driver._routed_name, n_valid)
                delta = driver._accumulate(delta, hist, None)
                return counts, queue, qhist, slab + delta, step_idx + 1, chosen
            return counts, queue, qhist, step_idx + 1, chosen

        return body

    def route_batch(self, datum_ids):
        """Serve one EXTERNAL id batch through the fused select+count pass
        -> (len(ids),) int32 chosen nodes (device array).

        Ids are pow2-bucketed (``migrate.planner.pad_pow2``) with the valid
        count traced, so ragged tails share one compile per bucket and pad
        lanes never touch a counter.  Single-device (the generated stream
        is the mesh path)."""
        import jax.numpy as jnp

        from repro.kernels.ops import _head

        if self._sweep is not None:
            raise ValueError(
                "route_batch serves host-fed batches single-device; "
                "mesh-sharded serving goes through step()"
            )
        ids = jnp.asarray(datum_ids)
        n = int(ids.shape[0])
        padded, n_valid = pad_pow2(ids)
        tables, statics = route_statics(self.engine, self.algorithm)
        fn = self._cached(("route_batch", statics), lambda: self._route_batch_fn(statics))
        if self._instrumented:
            (self.counts, self.queue, self.qhist, slab, self._step,
             chosen) = fn(
                padded, jnp.uint32(n_valid), self._key, self._step,
                self.counts, self.queue, self.qhist, self.metrics.slab(),
                self._service, *tables,
            )
            self.metrics.set_slab(slab)
        else:
            self.counts, self.queue, self.qhist, self._step, chosen = fn(
                padded, jnp.uint32(n_valid), self._key, self._step,
                self.counts, self.queue, self.qhist, self._service, *tables,
            )
        self.steps_done += 1
        return _head(chosen, n)

    # -- serving through a live migration window ------------------------------

    def _gen_fn(self):
        import jax
        import jax.numpy as jnp

        batch, id_salt = self.batch, self.traffic.id_salt

        @jax.jit
        def gen(key, step_idx, thresholds):
            lanes = jnp.arange(batch, dtype=jnp.uint32)
            return TrafficModel.draw(key, step_idx, lanes, thresholds, id_salt)

        return gen

    def _mig_select_fn(self):
        import jax
        import jax.numpy as jnp

        policy, R = self.policy, self.n_replicas
        n_bins, max_hist = self.n_bins, self.max_hist
        instrumented = self._instrumented
        driver = self

        @jax.jit
        def select(owners, sel, step_idx, counts, queue, qhist, *rest):
            if instrumented:
                slab, service = rest
            else:
                (service,) = rest
            chosen = select_replica(
                owners, sel, counts, policy=policy, n_replicas=R
            )
            hist = jnp.zeros((n_bins,), jnp.int32).at[chosen].add(1)
            counts = counts + hist
            queue = jnp.maximum(queue + hist - service, 0)
            qhist = jax.lax.dynamic_update_slice(
                qhist, queue[None], (step_idx % max_hist, jnp.int32(0))
            )
            if instrumented:
                delta = jnp.zeros_like(slab)
                delta = driver.metrics.add(
                    delta, driver._routed_name, owners.shape[0]
                )
                delta = driver._accumulate(delta, hist, None)
                return counts, queue, qhist, slab + delta, step_idx + 1, chosen
            return counts, queue, qhist, step_idx + 1, chosen

        return select

    def serve_migrating(self, migration):
        """Serve one generated batch THROUGH a live migration window ->
        (datum_ids, chosen) device arrays.

        Routing goes through the window's dual-version replica read rule
        (``LiveMigration.route_replicas_device`` -- the cached fused
        probe), so every request lands on a node that physically holds its
        datum mid-drain.  Three jitted dispatches (generate, route,
        select+count), zero host syncs after the per-round pending-view
        refresh.  Single-device, like the window itself."""
        if self._sweep is not None:
            raise ValueError(
                "migration windows are single-device (the pending views "
                "refresh per round); build the driver without mesh="
            )
        if migration.n_replicas != self.n_replicas:
            raise ValueError(
                f"driver serves R={self.n_replicas} but the migration plan "
                f"is R={migration.n_replicas}"
            )
        gen = self._cached(("gen",), self._gen_fn)
        ids, sel = gen(self._key, self._step, self.traffic.thresholds_dev)
        owners = migration.route_replicas_device(ids)
        select = self._cached(("mig_select",), self._mig_select_fn)
        if self._instrumented:
            (self.counts, self.queue, self.qhist, slab, self._step,
             chosen) = select(
                owners, sel, self._step, self.counts, self.queue, self.qhist,
                self.metrics.slab(), self._service,
            )
            self.metrics.set_slab(slab)
        else:
            self.counts, self.queue, self.qhist, self._step, chosen = select(
                owners, sel, self._step, self.counts, self.queue, self.qhist,
                self._service,
            )
        self.steps_done += 1
        return ids, chosen

    def _mig_superstep_fn(self, statics: tuple, k: int):
        """K migration-window batches in ONE jit: generate, the fused
        dual-version replica read rule (the ``migrate.live``
        ``_fused_replica_route`` body, inlined) and select+count, scanned
        with the serving state as the carry -- the superstep twin of
        ``serve_migrating``'s three dispatches."""
        import jax
        import jax.numpy as jnp

        from repro.kernels.ops import _place_replicas_fused_ref

        top_level, s_log2, max_draws, R = statics
        batch, id_salt = self.batch, self.traffic.id_salt
        policy, n_bins, max_hist = self.policy, self.n_bins, self.max_hist
        instrumented = self._instrumented
        driver = self

        @jax.jit
        def super_body(key, step_idx, counts, queue, qhist, *rest):
            driver.ledger.incr("serve.superstep_traces")  # per TRACE only
            if instrumented:
                (slab, service, thresholds, len32, node_of,
                 ids_pad, src_pad, pcounts) = rest
                carry0 = (counts, queue, qhist, slab, step_idx)
            else:
                (service, thresholds, len32, node_of,
                 ids_pad, src_pad, pcounts) = rest
                carry0 = (counts, queue, qhist, step_idx)

            def route(u):
                dst = _place_replicas_fused_ref(
                    u, len32, node_of,
                    top_level=top_level, s_log2=s_log2, max_draws=max_draws,
                    n_replicas=R, emit_nodes=True,
                )

                def per_slot(sorted_pad, src_vals, n):
                    pos = jnp.searchsorted(sorted_pad, u, side="left")
                    pos_c = jnp.minimum(pos, sorted_pad.shape[0] - 1)
                    hit = (pos < n) & (sorted_pad[pos_c] == u)
                    return hit, src_vals[pos_c]

                hit, src = jax.vmap(per_slot)(ids_pad, src_pad, pcounts)
                return jnp.where(hit.T, src.T, dst)

            def sub(carry, _):
                if instrumented:
                    c, q, qh, sl, si = carry
                else:
                    c, q, qh, si = carry
                lanes = jnp.arange(batch, dtype=jnp.uint32)
                ids, sel = TrafficModel.draw(key, si, lanes, thresholds, id_salt)
                owners = route(ids.astype(jnp.uint32))
                chosen = select_replica(
                    owners, sel, c, policy=policy, n_replicas=R
                )
                hist = jnp.zeros((n_bins,), jnp.int32).at[chosen].add(1)
                c = c + hist
                q = jnp.maximum(q + hist - service, 0)
                qh = jax.lax.dynamic_update_slice(
                    qh, q[None], (si % max_hist, jnp.int32(0))
                )
                if instrumented:
                    delta = jnp.zeros_like(sl)
                    delta = driver.metrics.add(
                        delta, driver._routed_name, owners.shape[0]
                    )
                    delta = driver._accumulate(delta, hist, None)
                    return (c, q, qh, sl + delta, si + 1), (ids, chosen)
                return (c, q, qh, si + 1), (ids, chosen)

            carry, (ids, chosen) = jax.lax.scan(sub, carry0, None, length=k)
            return (*carry, ids, chosen)

        return super_body

    def superstep_migrating(self, migration, k: int):
        """Serve K generated batches THROUGH a live migration window in
        ONE host dispatch -> (datum_ids, chosen), each (k, batch).

        Bit-identical to K sequential ``serve_migrating`` calls against
        the same pending view: the whole dual-version read rule runs
        inside the scan, counters stay fresh between sub-batches, and the
        pending snapshot is the one at call time (refresh per round, as
        with ``serve_migrating``).  Single-device, like the window."""
        if self._sweep is not None:
            raise ValueError(
                "migration windows are single-device (the pending views "
                "refresh per round); build the driver without mesh="
            )
        if migration.n_replicas != self.n_replicas:
            raise ValueError(
                f"driver serves R={self.n_replicas} but the migration plan "
                f"is R={migration.n_replicas}"
            )
        k = int(k)
        if k < 1:
            raise ValueError(f"superstep needs k >= 1, got {k}")
        migration._check_live()
        art = migration.engine._device_artifact_for(migration.v_to, "asura")
        params = migration.engine.params
        statics = (
            art.top_level, params.s_log2, params.max_draws, self.n_replicas
        )
        ids_pad, src_pad, pcounts = migration.state.pending_replicas_device()
        fn = self._cached(
            ("mig_superstep", statics, k),
            lambda: self._mig_superstep_fn(statics, k),
        )
        operands = (
            self._service, self.traffic.thresholds_dev,
            art.len32_dev, art.node_of_dev, ids_pad, src_pad, pcounts,
        )
        if self._instrumented:
            (self.counts, self.queue, self.qhist, slab, self._step,
             ids, chosen) = fn(
                self._key, self._step, self.counts, self.queue, self.qhist,
                self.metrics.slab(), *operands,
            )
            self.metrics.set_slab(slab)
        else:
            (self.counts, self.queue, self.qhist, self._step,
             ids, chosen) = fn(
                self._key, self._step, self.counts, self.queue, self.qhist,
                *operands,
            )
        self.steps_done += k
        return ids, chosen

    # -- host-facing metrics (each accessor is ONE deliberate sync) -----------

    def _active_bins(self) -> np.ndarray:
        nodes = getattr(self.engine.cluster, "nodes", None)
        if nodes:
            return np.asarray(sorted(int(n) for n in nodes), dtype=np.int64)
        return np.arange(self.n_bins, dtype=np.int64)

    def load_counts(self) -> np.ndarray:
        return np.asarray(self.counts)

    def load_skew(self) -> float:
        """max/mean served load over the live nodes (1.0 = perfectly
        even; the paper's uniformity story, measured under traffic)."""
        c = self.load_counts()[self._active_bins()].astype(np.float64)
        mean = c.mean()
        return float(c.max() / mean) if mean > 0 else 0.0

    def queue_p99(self) -> float:
        """p99 queue depth over (recorded step, live node) samples."""
        rows = min(self.steps_done, self.max_hist)
        if rows == 0:
            return 0.0
        q = np.asarray(self.qhist)[:rows][:, self._active_bins()]
        return float(np.percentile(q, 99))

    def snapshot(self) -> dict:
        snap = {
            "counts": self.load_counts(),
            "queue": np.asarray(self.queue),
            "steps": self.steps_done,
            "skew": self.load_skew(),
            "q_p99": self.queue_p99(),
        }
        self.ledger.event(
            "serve.snapshot", self.algorithm,
            steps=self.steps_done, skew=snap["skew"], q_p99=snap["q_p99"],
        )
        return snap
