"""ASURA session routing across serving replicas.

Sessions (request streams with KV caches) are sticky: a session's cache
lives on one replica, so re-routing a session is expensive (cache refill =
a full prefill). ASURA gives exactly the right trade:

  * any frontend computes the owner locally from the O(N) table — no
    routing service, no consistent-hashing ring to sync,
  * replica loss re-routes ONLY its sessions (everyone else's caches stay
    hot) — the paper's removal-optimality theorem,
  * capacity-weighted replicas (heterogeneous hardware generations) get
    proportional load via segment lengths,
  * scale-out steals the minimal set of sessions from existing replicas.

``plan_scale_event`` returns the exact session moves so the serving layer
can schedule cache re-prefill for just those sessions.

Routing goes through the cluster's ``PlacementEngine``: the segment table is
canonicalized (and, on accelerator backends, uploaded) once per membership
version, so the per-request hot path is pure placement -- no table prep.

``Router(algorithm=...)`` swaps the placement algorithm under the SAME
interface: ``"asura"`` (default), ``"ch"``, ``"wrh"`` or ``"rs"`` route
through the engine's baseline device backends (DESIGN.md section 9), so the
paper's head-to-head comparison runs on the serving path too -- including
R-way replica fan-out (the baselines use the salted rejection re-probe,
DESIGN.md section 12).  Live scale migrations remain ASURA-only (they ride
on its dual-version table artifacts) and raise a clear error otherwise.

The replica hot path is a CACHED fused probe: ``route_replicas_device``
compiles once per ``(algorithm statics, n_replicas, table shapes)`` and
every later batch is a single dispatch (``probe_traces`` is the tests'
retrace tripwire).  ``stream_driver()`` hands the same engine to the
batched serving pipeline (``serve.stream``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import Cluster, PlacementEngine
from repro.core.engine import DEFAULT_VIRTUAL_NODES


@dataclasses.dataclass
class ScalePlan:
    moved_sessions: dict[int, tuple[int, int]]  # session -> (src, dst)

    @property
    def n_reprefills(self) -> int:
        return len(self.moved_sessions)


class ReplicaRouter:
    def __init__(
        self,
        replica_capacities: dict[int, float],
        *,
        algorithm: str = "asura",
        virtual_nodes: int = DEFAULT_VIRTUAL_NODES,
        ledger=None,
    ):
        from repro.obs import TraceLedger

        self.hierarchical = any(
            isinstance(v, dict) for v in replica_capacities.values()
        )
        if self.hierarchical:
            # {domain: {replica: capacity}} -> failure-domain-aware routing
            # (two-level ASURA; replica sets span R distinct domains).
            if algorithm != "asura":
                raise ValueError(
                    "hierarchical routing is ASURA-only (two-level segment "
                    f"tables); got algorithm={algorithm!r}"
                )
            from repro.core.hierarchy import HierarchicalCluster

            self.cluster = HierarchicalCluster()
            for did, members in replica_capacities.items():
                for rid, cap in members.items():
                    self.cluster.add_node(did, rid, cap)
        else:
            self.cluster = Cluster()
            for rid, cap in replica_capacities.items():
                self.cluster.add_node(rid, cap)
        self.algorithm = algorithm
        if algorithm == "asura":
            self.engine = self.cluster.engine
        else:
            # dedicated engine whose DEFAULT algorithm is the baseline, so
            # every route call dispatches to the baseline device backend.
            self.engine = PlacementEngine(
                self.cluster, algorithm=algorithm, virtual_nodes=virtual_nodes
            )
        self._scale_migration = None  # at most one live window at a time
        self._probe_cache: dict = {}  # (statics, R, table shapes) -> jitted probe
        # instance-scoped unless a shared ledger is injected -- the exact
        # probe-trace tripwire counts must never alias across routers
        self.ledger = ledger if ledger is not None else TraceLedger()

    @property
    def probe_traces(self) -> int:
        """Replica-probe jit traces (retrace tripwire) -- a ledger counter
        behind the PR-7 attribute name."""
        return self.ledger.counter("serve.probe_traces")

    def route(self, session_ids) -> np.ndarray:
        """session ids -> replica ids (vectorized, table-local)."""
        return self.engine.place_nodes(np.asarray(session_ids, dtype=np.uint32))

    def route_device(self, session_ids):
        """session ids -> replica ids as a DEVICE array, zero host syncs.

        The request hot path for device-chained frontends: pass
        device-resident session ids and the placement, tail resolution and
        replica-id gather all stay on device (the routing result feeds
        device-side batching/dispatch without a round trip)."""
        return self.engine.place_nodes_device(session_ids)

    def route_replicas(self, session_ids, n_replicas: int) -> np.ndarray:
        """(sessions, R) replica ids on distinct replicas, primary first --
        for read fan-out / warm-standby session caches (section 5.A; the
        baselines fan out via the salted rejection re-probe).  Hierarchical
        routers return the replica ids of pairwise-DISTINCT domains (use
        ``route_replica_pairs`` for the (domain, replica) view)."""
        out = self.engine.place_replica_nodes(
            np.asarray(session_ids, dtype=np.uint32), n_replicas
        )
        return out[:, :, 1] if self.hierarchical else out

    def route_replica_pairs(self, session_ids, n_replicas: int) -> np.ndarray:
        """(sessions, R, 2) ``(domain, replica)`` pairs, hierarchical
        routers only: every session's R cache holders live in R distinct
        failure domains, so a whole-domain outage re-prefills at most one
        warm copy per session."""
        if not self.hierarchical:
            raise ValueError(
                "route_replica_pairs needs a hierarchical router (pass "
                "{domain: {replica: capacity}} capacities)"
            )
        return self.engine.place_replica_pairs(
            np.asarray(session_ids, dtype=np.uint32), n_replicas
        )

    def _replica_probe(self, n_replicas: int):
        """The cached fused replica probe + its table operands.

        One jit per ``(algorithm statics, n_replicas, table shapes)``:
        membership changes (new table shapes) or a different R compile a
        new probe; steady-state serving always hits the cache.  The trace
        counter increments inside the traced body, so it ticks per TRACE,
        not per call -- the tripwire tests pin it across repeated batches.
        """
        from .stream import replica_owners_body, route_statics

        tables, statics = route_statics(self.engine, self.algorithm)
        key = (statics, n_replicas, tuple(t.shape for t in tables))
        fn = self._probe_cache.get(key)
        if fn is None:
            import jax

            owners_fn = replica_owners_body(statics, n_replicas)
            router = self

            @jax.jit
            def probe(ids, *tabs):
                router.ledger.incr("serve.probe_traces")  # per TRACE only
                return owners_fn(ids, *tabs)

            fn = self._probe_cache[key] = probe
        return fn, tables

    def route_replicas_device(self, session_ids, n_replicas: int):
        """Device-resident ``route_replicas`` (fused node gather; -1 marks
        the practically-impossible non-converged entries).  One cached-jit
        dispatch per call -- the serving hot path."""
        import jax.numpy as jnp

        fn, tables = self._replica_probe(n_replicas)
        return fn(jnp.asarray(session_ids), *tables)

    def stream_driver(self, **kwargs):
        """A batched ``RequestStreamDriver`` bound to this router's engine
        and algorithm (DESIGN.md section 12) -- the serving-at-scale entry
        point: device-resident traffic generation, fused route+select,
        on-device load counters."""
        from .stream import RequestStreamDriver

        return RequestStreamDriver(self.engine, algorithm=self.algorithm, **kwargs)

    @property
    def table_uploads(self) -> int:
        """Table materializations so far (1 per membership version used)."""
        return self.engine.uploads

    def my_sessions(self, replica_id: int, session_ids) -> np.ndarray:
        ids = np.asarray(session_ids, dtype=np.uint32)
        return ids[self.route(ids) == replica_id]

    def plan_scale_event(self, session_ids, *, add=None, remove=None) -> ScalePlan:
        """Apply a membership change; return the minimal session moves.

        Hierarchical routers take ``add=(domain, replica, capacity)`` /
        ``remove=(domain, replica)``; flat routers the 2-/1-tuple forms."""
        ids = np.asarray(session_ids, dtype=np.uint32)
        before = self.route(ids)
        if remove is not None:
            if self.hierarchical:
                self.cluster.remove_node(*remove)
            else:
                self.cluster.remove_node(remove)
        if add is not None:
            self.cluster.add_node(*add)
        after = self.route(ids)
        moved = np.nonzero(before != after)[0]
        return ScalePlan(
            {int(ids[i]): (int(before[i]), int(after[i])) for i in moved}
        )

    # -- migration-window serving (DESIGN.md section 8) ----------------------

    def begin_scale_migration(
        self,
        session_ids,
        *,
        add=None,
        remove=None,
        n_replicas: int = 1,
        egress=None,
        ingress=None,
        clock=None,
        round_seconds: float = 1.0,
    ):
        """Apply a membership change as a LIVE migration.

        Instead of an instantaneous table swap, the minimal session moves
        (session cache re-prefills) drain under per-replica ingress/egress
        budgets while ``route_migrating`` keeps every request on a replica
        whose cache is actually warm: the v owner until the session's
        re-prefill lands, the v+1 owner after.  The add-node case uses the
        ADDITION-NUMBER device prefilter, so only AN-candidates pay the
        dual-version diff.  With ``n_replicas > 1`` the plan is the
        per-slot REPLICA plan (DESIGN.md section 10) -- warm-standby
        session caches (section 5.A fan-out) migrate replica by replica,
        and ``route_replicas_migrating`` serves the mixed-version sets.
        Returns a ``LiveMigration``.
        """
        from repro.migrate import LiveMigration, MigrationPlanner

        if self.algorithm != "asura":
            raise ValueError(
                "live scale migrations ride on ASURA's dual-version table "
                f"artifacts; this router routes via {self.algorithm!r} -- "
                "use plan_scale_event for the instantaneous-swap plan"
            )
        if self.hierarchical:
            raise NotImplementedError(
                "live scale-migration windows are flat-router only for "
                "now; hierarchical routers plan instantaneous swaps via "
                "plan_scale_event (the engine's diff_replica_domains_device "
                "gives the per-slot moves for external drivers)"
            )
        live = self._scale_migration
        if live is not None and not (live.done or live.aborted):
            # overlapping windows' read rules do not compose (section 8.3)
            raise RuntimeError(
                "a scale migration is already in flight; drain it first"
            )
        ids = np.asarray(session_ids, dtype=np.uint32)
        self.engine.artifact()  # pin the v table in the LRU before mutating
        v_from = self.cluster.version
        max_new_seg = None
        if remove is not None:
            self.cluster.remove_node(remove)
        if add is not None:
            rid, cap = add
            new_segs = self.cluster.add_node(rid, cap)
            if remove is None:
                max_new_seg = max(new_segs)
        planner = MigrationPlanner(self.engine)
        if n_replicas > 1:
            plan = planner.plan_replicas(
                ids,
                v_from,
                self.cluster.version,
                n_replicas,
                max_new_seg=max_new_seg,
            )
        else:
            plan = planner.plan(
                ids, v_from, self.cluster.version, max_new_seg=max_new_seg
            )
        self._scale_migration = LiveMigration.from_plan(
            self.engine,
            plan,
            egress=egress,
            ingress=ingress,
            clock=clock,
            round_seconds=round_seconds,
        )
        return self._scale_migration

    def route_migrating(self, session_ids, migration) -> np.ndarray:
        """Migration-window routing: each session goes to the replica that
        holds its warm cache right now (v owner while its re-prefill is
        pending, v+1 owner once landed)."""
        return migration.route(np.asarray(session_ids, dtype=np.uint32))

    def route_migrating_device(self, session_ids, migration):
        """Device-resident migration-window routing (zero host syncs after
        the per-round pending-set refresh)."""
        return migration.route_device(session_ids)

    def route_replicas_migrating(self, session_ids, migration) -> np.ndarray:
        """Migration-window REPLICA routing: (sessions, R) replica sets,
        each slot independently on whichever side of the version window
        holds its warm cache (pending -> v-side source, landed -> v+1
        owner).  Sets stay pairwise-distinct every round."""
        return migration.route_replicas(np.asarray(session_ids, dtype=np.uint32))

    def route_replicas_migrating_device(self, session_ids, migration):
        """Device-resident ``route_replicas_migrating`` (zero host syncs
        after the per-round per-slot pending refresh)."""
        return migration.route_replicas_device(session_ids)

    def table_blob(self) -> str:
        """The only state frontends need to share (kilobytes).

        Valid for "asura" (the blob IS the placement state), "ch" and
        "wrh" (their tables derive deterministically from the blob's
        membership).  Random slicing is HISTORY-dependent -- its interval
        table lives in the engine shadow, not the cluster blob -- so a
        frontend rebuilt from the blob would route differently; sharing it
        would silently split ownership, so this raises instead.
        """
        if self.algorithm == "rs":
            raise ValueError(
                "random slicing's interval table is history-dependent and "
                "not captured by the cluster blob; rs frontends must share "
                "the router (or replay the same membership sequence), not "
                "table_blob()"
            )
        return self.cluster.to_json()


# the name the quickstart / head-to-head docs use
Router = ReplicaRouter
