"""Device-resident request-stream generator (DESIGN.md section 12).

What millions of users do to a storage cluster is not a uniform id sweep:
a few hot keys dominate (Zipf), or a small working set takes most of the
traffic (hot-set).  ``TrafficModel`` turns a traffic law into a sampler
that runs entirely inside the serving driver's fused jit:

  * the law's CDF is computed ONCE on the host in float64 and quantized to
    exact u32 thresholds (the repo's exact-u32 idiom: ``thresholds[i]`` is
    the largest raw draw that maps to rank <= i, and ``thresholds[-1]`` is
    2**32 - 1 exactly), so sampling is one integer ``searchsorted`` per
    request -- backend-independent, bit-identical on ref and Pallas
    engines,
  * per-request randomness is COUNTER-BASED threefry: the batch key is
    ``fold_in(root_key, step)`` and each lane folds in its GLOBAL lane
    index, so a mesh shard generating lanes [k*S, (k+1)*S) draws exactly
    the words the single-device batch draws at those lanes -- sharded
    generation is bit-identical by construction, and there is no host RNG
    (or sequential state) anywhere in the loop,
  * sampled RANKS map to datum ids through ``fmix32`` (bijective on u32),
    so distinct ranks give distinct, well-scattered ids and the hot keys
    are not the numerically-small ids that every placement test already
    uses.

Laws: ``uniform`` over ``n_keys``; ``zipf`` with p(r) proportional to
1/(r+1)**alpha; ``hotset`` sending ``hot_fraction`` of the traffic to the
first ``hot_keys`` ranks uniformly (the rest uniform over everything).
"""

from __future__ import annotations

import numpy as np

from repro.core.rng import fmix32_np

LAWS = ("uniform", "zipf", "hotset")

_TWO32 = float(2**32)


class TrafficModel:
    """One traffic law over ``n_keys`` ranked keys, ready for device use.

    Host-side construction only (float64 CDF + u32 quantization); the
    device sampler is the static ``draw`` method, composed into the
    serving driver's fused step jit with ``thresholds_dev`` passed as a
    replicated operand.
    """

    def __init__(
        self,
        n_keys: int,
        *,
        law: str = "zipf",
        alpha: float = 1.1,
        hot_fraction: float = 0.9,
        hot_keys: int = 64,
        seed: int = 0,
    ):
        if law not in LAWS:
            raise ValueError(f"law must be one of {LAWS}, got {law!r}")
        if n_keys < 1:
            raise ValueError("n_keys must be >= 1")
        self.n_keys = int(n_keys)
        self.law = law
        self.alpha = float(alpha)
        self.hot_fraction = float(hot_fraction)
        self.hot_keys = min(int(hot_keys), self.n_keys)
        # rank -> id bijection salt: any fixed u32; derived from the seed so
        # two models with different seeds serve disjoint-looking key sets.
        self.id_salt = int(
            fmix32_np(np.asarray([seed ^ 0x7261666B], dtype=np.uint32))[0]
        )
        self._pmf = self._build_pmf()
        cum = np.cumsum(self._pmf)
        cum[-1] = 1.0  # kill float64 cumsum drift before quantizing
        thr = np.round(cum * _TWO32).astype(np.uint64) - 1
        self._thresholds = np.minimum(thr, np.uint64(2**32 - 1)).astype(np.uint32)
        self._thresholds_dev = None

    def _build_pmf(self) -> np.ndarray:
        n = self.n_keys
        if self.law == "uniform":
            p = np.full(n, 1.0 / n, dtype=np.float64)
        elif self.law == "zipf":
            p = 1.0 / np.power(np.arange(1, n + 1, dtype=np.float64), self.alpha)
            p /= p.sum()
        else:  # hotset
            k, h = self.hot_keys, self.hot_fraction
            p = np.full(n, (1.0 - h) / n, dtype=np.float64)
            p[:k] += h / k
            p /= p.sum()
        return p

    @property
    def pmf(self) -> np.ndarray:
        """Target probability per rank (float64, sums to 1) -- the
        chi-square tests' expected frequencies."""
        return self._pmf

    @property
    def thresholds(self) -> np.ndarray:
        """Inclusive u32 upper bounds per rank: ``searchsorted(thresholds,
        u, 'left')`` maps a raw u32 draw to its rank."""
        return self._thresholds

    @property
    def thresholds_dev(self):
        """Device copy of ``thresholds`` (built lazily, uploaded once)."""
        if self._thresholds_dev is None:
            import jax.numpy as jnp

            self._thresholds_dev = jnp.asarray(self._thresholds)
        return self._thresholds_dev

    # -- device sampler (pure jnp; composed into the driver's fused jit) ------

    @staticmethod
    def lane_words(root_key, step_idx, lanes, n_words: int = 2):
        """(len(lanes), n_words) u32 threefry words for GLOBAL lane indices.

        ``fold_in(fold_in(root_key, step), lane)`` per lane: every word is
        a pure function of (root_key, step, global lane), which is the
        whole sharding story -- a shard holding a slice of the global lane
        range reproduces the single-device words exactly.
        """
        import jax
        import jax.numpy as jnp

        batch_key = jax.random.fold_in(root_key, step_idx)

        def one(lane):
            return jax.random.bits(
                jax.random.fold_in(batch_key, lane), (n_words,), jnp.uint32
            )

        return jax.vmap(one)(lanes)

    @staticmethod
    def ranks_from_words(words, thresholds):
        """u32 draws -> ranks via the exact-u32 CDF (one searchsorted)."""
        import jax.numpy as jnp

        ranks = jnp.searchsorted(thresholds, words, side="left")
        return jnp.minimum(ranks, thresholds.shape[0] - 1).astype(jnp.uint32)

    @staticmethod
    def ids_from_ranks(ranks, id_salt: int):
        """Bijective rank -> datum-id map (fmix32 of the salted rank)."""
        import jax.numpy as jnp

        from repro.kernels.ref import fmix32

        return fmix32(ranks.astype(jnp.uint32) + jnp.uint32(id_salt))

    @staticmethod
    def draw(root_key, step_idx, lanes, thresholds, id_salt: int):
        """One fused generator step -> (datum_ids, selection_words).

        Word 0 of each lane samples the rank (then id); word 1 is handed to
        the replica-selection policy untouched.
        """
        words = TrafficModel.lane_words(root_key, step_idx, lanes, 2)
        ranks = TrafficModel.ranks_from_words(words[:, 0], thresholds)
        return TrafficModel.ids_from_ranks(ranks, id_salt), words[:, 1]

    # -- host-facing helpers (tests, examples) --------------------------------

    def sample_ranks(self, seed: int, n: int, batch: int = 1 << 14) -> np.ndarray:
        """Draw ``n`` ranks at a fixed seed (host-facing; the statistical
        tests' entry point -- same per-lane stream the driver consumes)."""
        import jax
        import jax.numpy as jnp

        key = jax.random.PRNGKey(seed)
        out = []
        step = 0
        remaining = n
        while remaining > 0:
            take = min(batch, remaining)
            lanes = jnp.arange(take, dtype=jnp.uint32)
            words = self.lane_words(key, jnp.int32(step), lanes, 1)
            out.append(np.asarray(self.ranks_from_words(words[:, 0], self.thresholds_dev)))
            step += 1
            remaining -= take
        return np.concatenate(out)

    def rank_to_id_np(self, ranks) -> np.ndarray:
        """NumPy twin of ``ids_from_ranks`` (bit-identical)."""
        r = np.asarray(ranks, dtype=np.uint32)
        with np.errstate(over="ignore"):
            return fmix32_np(r + np.uint32(self.id_salt))
