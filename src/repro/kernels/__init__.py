"""Pallas TPU kernels: batched ASURA placement (asura_place) with jit
wrapper (ops) and pure-jnp oracle (ref)."""

from .ops import asura_place, asura_place_nodes, table_prep

__all__ = ["asura_place", "asura_place_nodes", "table_prep"]
