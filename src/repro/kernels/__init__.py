"""Pallas TPU kernels: batched ASURA placement and replication
(asura_place) with jit wrappers (ops) and pure-jnp oracles (ref)."""

from .ops import (
    asura_place,
    asura_place_nodes,
    asura_place_replicas,
    node_table_prep,
    place_nodes_on_table_device,
    place_on_table,
    place_on_table_device,
    place_replicas_on_table,
    place_replicas_on_table_device,
    table_prep,
    tail_prep,
)

__all__ = [
    "asura_place",
    "asura_place_nodes",
    "asura_place_replicas",
    "node_table_prep",
    "place_nodes_on_table_device",
    "place_on_table",
    "place_on_table_device",
    "place_replicas_on_table",
    "place_replicas_on_table_device",
    "table_prep",
    "tail_prep",
]
