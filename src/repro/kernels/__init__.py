"""Pallas TPU kernels: batched ASURA placement and replication
(asura_place) with jit wrappers (ops) and pure-jnp oracles (ref), plus the
baseline lookup kernels (baselines: ch/wrh/rs, DESIGN.md section 9)."""

from .baselines import (
    baseline_place_on_table_device,
    ch_place_pallas,
    rs_place_pallas,
    wrh_place_pallas,
)
from .ops import (
    asura_place,
    asura_place_nodes,
    asura_place_replicas,
    diff_nodes_on_tables_device,
    diff_replicas_on_tables_device,
    node_table_prep,
    place_nodes_on_table_device,
    place_on_table,
    place_on_table_device,
    place_replicas_on_table,
    place_replicas_on_table_device,
    table_prep,
    tail_prep,
)

__all__ = [
    "asura_place",
    "baseline_place_on_table_device",
    "ch_place_pallas",
    "rs_place_pallas",
    "wrh_place_pallas",
    "asura_place_nodes",
    "asura_place_replicas",
    "diff_nodes_on_tables_device",
    "diff_replicas_on_tables_device",
    "node_table_prep",
    "place_nodes_on_table_device",
    "place_on_table",
    "place_on_table_device",
    "place_replicas_on_table",
    "place_replicas_on_table_device",
    "table_prep",
    "tail_prep",
]
