"""Pallas TPU kernel: batched ASURA placement (the paper's hot spot).

The paper optimizes per-datum placement latency on a CPU (0.6 us/call); the
TPU-native re-think (DESIGN.md section 3) is *throughput*: place a whole
vector of datum ids per call for data-pipeline sharding, checkpoint-shard
routing and request routing.  The kernel is pure uint32 VPU work:

  * the id vector is tiled into (ROWS, 128) VMEM blocks (lane-aligned),
  * the O(N) segment table (ASURA's memory advantage over Consistent
    Hashing's O(NV) ring, paper Table II) is broadcast whole into VMEM --
    40 KB for 10k segments, far under the ~16 MB VMEM budget,
  * each grid step runs the bounded masked draw loop entirely on-chip:
    counter-based hashing (no PRNG state), MSB descend test, shift-based
    floor/fraction, one dynamic VMEM gather per draw for the hit test,
  * the descend ladder is LAZY-DEPTH (DESIGN.md section 3.4): a
    ``lax.while_loop`` over the scalar level that exits once no lane is
    still consulting -- expected 2 consulted levels per draw, independent
    of ``top_level``, instead of the historical fully-unrolled ladder that
    hashed every level on every draw.

Trip count: Appendix B bounds expected draws by ~4 (hole fraction <= 1/2),
and the while_loop exits as soon as every lane has placed, so the typical
block does 4-6 iterations; max_draws caps the tail at p < 2**-53 per lane.

``place_fused_pallas`` is the fully device-resident variant: the
non-converged tail is resolved on-chip (section 3.2 spec against the
precomputed u64-cumsum halves) and the seg->node gather can be fused, so
engine device paths chain into further device work with zero host syncs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import next_asura, resolve_tail_dev

LANE = 128
DEFAULT_ROWS = 16  # (16, 128) = 2048 ids per grid step


def _next_asura_tile(ids, counters, top_level: int, s_log2: int):
    """One ASURA number per lane of a (rows, LANE) tile: (k, frac32, ctrs).

    The lazy-depth descend ladder shared by the placement and replication
    kernels -- a ``lax.while_loop`` over the scalar level that exits as soon
    as no lane is still consulting (expected 2 iterations instead of
    ``top_level + 1``); counter-based draws, MSB descend test, shift-based
    floor/fraction (the exact-u32 formulation, DESIGN.md sections 3, 3.4).
    Shared verbatim with the jnp reference (``ref.next_asura`` is
    shape-polymorphic), so the two paths cannot drift."""
    return next_asura(ids, counters, top_level, s_log2)


def _place_kernel(
    ids_ref,
    table_ref,
    out_ref,
    *,
    top_level: int,
    s_log2: int,
    max_draws: int,
    n_segs: int,
):
    ids = ids_ref[...]  # (rows, LANE) uint32
    table = table_ref[...]  # (n_pad,) uint32
    shape = ids.shape

    def next_asura(counters):
        return _next_asura_tile(ids, counters, top_level, s_log2)

    def cond(state):
        i, _, _, done = state
        return (i < max_draws) & ~jnp.all(done)

    def body(state):
        i, counters, result, done = state
        k, f, counters = next_asura(counters)
        k_safe = jnp.minimum(k, n_segs - 1)
        lens = jnp.take(table, k_safe.reshape(-1), axis=0).reshape(shape)
        hit = (~done) & (k < n_segs) & (f < lens)
        result = jnp.where(hit, k, result)
        return i + 1, counters, result, done | hit

    counters0 = jnp.zeros((top_level + 1,) + shape, dtype=jnp.uint32)
    result0 = jnp.full(shape, -1, dtype=jnp.int32)
    done0 = jnp.zeros(shape, dtype=bool)
    _, _, result, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), counters0, result0, done0)
    )
    out_ref[...] = result


def _place_total_tile(
    ids,
    table,
    cum_hi,
    cum_lo,
    node_of,
    *,
    top_level: int,
    s_log2: int,
    max_draws: int,
    n_segs: int,
    emit_nodes: bool,
):
    """Total placement of one (rows, LANE) tile against one in-VMEM table.

    The shared body of ``_place_fused_kernel`` and ``_diff_kernel``: bounded
    masked draw loop, on-chip section 3.2 tail resolution, optional fused
    seg->node gather.  Pure traced jnp so it can run twice (once per table
    version) inside a single kernel invocation."""
    shape = ids.shape

    def cond(state):
        i, _, _, done = state
        return (i < max_draws) & ~jnp.all(done)

    def body(state):
        i, counters, result, done = state
        k, f, counters = _next_asura_tile(ids, counters, top_level, s_log2)
        k_safe = jnp.minimum(k, n_segs - 1)
        lens = jnp.take(table, k_safe.reshape(-1), axis=0).reshape(shape)
        hit = (~done) & (k < n_segs) & (f < lens)
        result = jnp.where(hit, k, result)
        return i + 1, counters, result, done | hit

    counters0 = jnp.zeros((top_level + 1,) + shape, dtype=jnp.uint32)
    result0 = jnp.full(shape, -1, dtype=jnp.int32)
    done0 = jnp.zeros(shape, dtype=bool)
    _, _, result, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), counters0, result0, done0)
    )
    result = resolve_tail_dev(ids, result, cum_hi, cum_lo, top_level)
    if emit_nodes:
        result = jnp.take(node_of, result.reshape(-1), axis=0).reshape(shape)
    return result


def _place_fused_kernel(
    ids_ref,
    table_ref,
    cum_hi_ref,
    cum_lo_ref,
    node_ref,
    out_ref,
    *,
    top_level: int,
    s_log2: int,
    max_draws: int,
    n_segs: int,
    emit_nodes: bool,
):
    """Fully device-resident placement: bounded draw loop + on-chip tail
    resolution (the exact section 3.2 spec via ``resolve_tail_dev``, against
    the precomputed u64-cumsum halves held in VMEM) + optionally the fused
    seg->node gather, so the kernel's output is final -- no host fix-up, no
    second device pass.  ``emit_nodes=False`` writes (total, >= 0) segment
    numbers; ``emit_nodes=True`` writes node ids."""
    out_ref[...] = _place_total_tile(
        ids_ref[...],
        table_ref[...],
        cum_hi_ref[...],
        cum_lo_ref[...],
        node_ref[...],
        top_level=top_level,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs=n_segs,
        emit_nodes=emit_nodes,
    )


def _diff_kernel(
    ids_ref,
    table_a_ref,
    cum_hi_a_ref,
    cum_lo_a_ref,
    node_a_ref,
    table_b_ref,
    cum_hi_b_ref,
    cum_lo_b_ref,
    node_b_ref,
    out_ref,
    *,
    top_a: int,
    top_b: int,
    s_log2: int,
    max_draws: int,
    n_segs_a: int,
    n_segs_b: int,
):
    """Version-diff kernel (DESIGN.md section 8): place every id under TWO
    table versions in one kernel pass.

    Both tables (lengths, u64-cumsum halves, seg->node maps) sit in VMEM
    side by side; each (rows, LANE) id tile runs the full bounded draw loop
    + tail + node gather against table A, then -- with fresh counters, the
    ASURA stream restarts per table -- against table B.  Output row 0 is the
    node under A (v), row 1 the node under B (v+1): the migration planner's
    ``(src, dst)`` with ``moved = src != dst`` derived outside.  One id
    upload, one kernel launch, zero host syncs."""
    ids = ids_ref[...]  # (rows, LANE) uint32
    src = _place_total_tile(
        ids,
        table_a_ref[...],
        cum_hi_a_ref[...],
        cum_lo_a_ref[...],
        node_a_ref[...],
        top_level=top_a,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs=n_segs_a,
        emit_nodes=True,
    )
    dst = _place_total_tile(
        ids,
        table_b_ref[...],
        cum_hi_b_ref[...],
        cum_lo_b_ref[...],
        node_b_ref[...],
        top_level=top_b,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs=n_segs_b,
        emit_nodes=True,
    )
    out_ref[...] = jnp.stack([src, dst])


def _place_replicas_tile(
    ids,
    table,
    node_of,
    *,
    top_level: int,
    s_log2: int,
    max_draws: int,
    n_segs: int,
    n_replicas: int,
):
    """Section 5.A replication of one (rows, LANE) tile against one table.

    The shared body of ``_place_replicas_kernel`` and
    ``_diff_replicas_kernel``: the bounded masked draw loop with per-lane
    ``(found, segs[R], nodes[R])`` state -- ``nodes`` carries the node of
    each already-picked replica in-register so the distinct-node dup test is
    R compares instead of R extra VMEM gathers; the seg->node table is
    gathered once per draw (alongside the length gather) to resolve the
    candidate's node.  Draw order and hit tests are bit-identical to
    ``place_replicas_scalar``; -1 marks non-converged entries.  Pure traced
    jnp so it can run twice (once per table version) inside a single kernel
    invocation; returns ``(segs, nodes)``, each (R, rows, LANE) int32.
    """
    shape = ids.shape
    R = n_replicas

    def next_asura(counters):
        return _next_asura_tile(ids, counters, top_level, s_log2)

    def cond(state):
        i, _, _, _, found = state
        return (i < max_draws * max(1, R)) & ~jnp.all(found >= R)

    def body(state):
        i, counters, segs, nodes, found = state
        k, f, counters = next_asura(counters)
        k_safe = jnp.minimum(k, n_segs - 1)
        flat = k_safe.reshape(-1)
        lens = jnp.take(table, flat, axis=0).reshape(shape)
        node_k = jnp.take(node_of, flat, axis=0).reshape(shape)
        hit = (found < R) & (k < n_segs) & (f < lens)
        dup = jnp.zeros(shape, dtype=bool)
        for r in range(R):
            dup |= (nodes[r] >= 0) & (nodes[r] == node_k)
        take = hit & ~dup
        segs = jnp.stack(
            [jnp.where(take & (found == r), k, segs[r]) for r in range(R)]
        )
        nodes = jnp.stack(
            [jnp.where(take & (found == r), node_k, nodes[r]) for r in range(R)]
        )
        return i + 1, counters, segs, nodes, found + take.astype(jnp.int32)

    counters0 = jnp.zeros((top_level + 1,) + shape, dtype=jnp.uint32)
    segs0 = jnp.full((R,) + shape, -1, dtype=jnp.int32)
    nodes0 = jnp.full((R,) + shape, -1, dtype=jnp.int32)
    found0 = jnp.zeros(shape, dtype=jnp.int32)
    _, _, segs, nodes, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), counters0, segs0, nodes0, found0)
    )
    return segs, nodes


def _place_replicas_kernel(
    ids_ref,
    table_ref,
    node_ref,
    out_ref,
    *,
    top_level: int,
    s_log2: int,
    max_draws: int,
    n_segs: int,
    n_replicas: int,
    emit_nodes: bool = False,
):
    """Section 5.A replication: first R hits on distinct nodes, per lane.

    The draw loop lives in ``_place_replicas_tile`` (shared with the
    replica-diff kernel).  ``emit_nodes=True`` writes the in-register
    ``nodes`` state instead of ``segs`` -- the fused seg->node gather for
    the device-resident path (node ids are already resolved per pick, so
    fusion costs nothing); ops.py raises on -1 entries on the host path.
    """
    segs, nodes = _place_replicas_tile(
        ids_ref[...],
        table_ref[...],
        node_ref[...],
        top_level=top_level,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs=n_segs,
        n_replicas=n_replicas,
    )
    out_ref[...] = nodes if emit_nodes else segs


def _diff_replicas_kernel(
    ids_ref,
    table_a_ref,
    node_a_ref,
    table_b_ref,
    node_b_ref,
    out_ref,
    *,
    top_a: int,
    top_b: int,
    s_log2: int,
    max_draws: int,
    n_segs_a: int,
    n_segs_b: int,
    n_replicas: int,
):
    """Replica-set version-diff kernel (DESIGN.md section 10): place every
    id's FULL R-replica set under two table versions in one kernel pass.

    Both tables (lengths + seg->node maps; replication needs no tail
    tables, non-convergence is a -1 marker) sit in VMEM side by side; each
    (rows, LANE) id tile runs the full bounded replica draw loop against
    table A, then -- with fresh counters, the ASURA stream restarts per
    table -- against table B.  Output index 0 is the replica-node set under
    A (v), index 1 under B (v+1), each (R, rows, LANE): the replica planner
    derives the per-slot ``(moved, src, dst, src_slot)`` alignment outside.
    One id upload, one kernel launch, zero host syncs.
    """
    ids = ids_ref[...]  # (rows, LANE) uint32
    _, src = _place_replicas_tile(
        ids,
        table_a_ref[...],
        node_a_ref[...],
        top_level=top_a,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs=n_segs_a,
        n_replicas=n_replicas,
    )
    _, dst = _place_replicas_tile(
        ids,
        table_b_ref[...],
        node_b_ref[...],
        top_level=top_b,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs=n_segs_b,
        n_replicas=n_replicas,
    )
    out_ref[...] = jnp.stack([src, dst])


@functools.partial(
    jax.jit,
    static_argnames=(
        "top_level",
        "s_log2",
        "max_draws",
        "n_replicas",
        "rows_per_block",
        "interpret",
        "emit_nodes",
    ),
)
def place_replicas_pallas(
    ids: jax.Array,
    len32: jax.Array,
    node_of: jax.Array,
    *,
    top_level: int,
    s_log2: int = 1,
    max_draws: int = 128,
    n_replicas: int = 1,
    rows_per_block: int = DEFAULT_ROWS,
    interpret: bool = True,
    emit_nodes: bool = False,
) -> jax.Array:
    """Batched replica placement via pl.pallas_call -> (total, R) int32.

    ids must be (m * rows_per_block * 128,) uint32 and len32 / node_of
    128-padded (ops.py pads; node padding is -1).  Non-converged entries are
    -1 (the ops.py host wrapper raises on them after unpadding; the device
    path documents them).  ``emit_nodes=True`` returns node ids directly
    (the fused in-kernel seg->node gather) instead of segment numbers.
    """
    n_segs = int(len32.shape[0])
    total = ids.shape[0]
    block = rows_per_block * LANE
    assert total % block == 0, "ops.py must pad ids to a block multiple"
    assert n_segs % LANE == 0, "ops.py must pad the table to a lane multiple"
    assert node_of.shape[0] == n_segs, "node table must match the length table"
    ids2 = ids.reshape(total // LANE, LANE)
    grid = (total // block,)
    kernel = functools.partial(
        _place_replicas_kernel,
        top_level=top_level,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs=n_segs,
        n_replicas=n_replicas,
        emit_nodes=emit_nodes,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
            pl.BlockSpec((n_segs,), lambda i: (0,)),  # whole table per block
            pl.BlockSpec((n_segs,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n_replicas, rows_per_block, LANE), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (n_replicas, total // LANE, LANE), jnp.int32
        ),
        interpret=interpret,
    )(ids2, len32, node_of.astype(jnp.int32))
    return out.reshape(n_replicas, total).T


@functools.partial(
    jax.jit,
    static_argnames=("top_level", "s_log2", "max_draws", "rows_per_block", "interpret"),
)
def place_pallas(
    ids: jax.Array,
    len32: jax.Array,
    *,
    top_level: int,
    s_log2: int = 1,
    max_draws: int = 128,
    rows_per_block: int = DEFAULT_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Batched placement via pl.pallas_call.

    ids must be (m * rows_per_block * 128,) uint32 (pre-padded by ops.py);
    len32 must be 128-padded.  Returns int32 segment numbers (-1 for the
    p < 2**-53 non-converged tail; ops.py resolves those).
    """
    n_segs = int(len32.shape[0])
    total = ids.shape[0]
    block = rows_per_block * LANE
    assert total % block == 0, "ops.py must pad ids to a block multiple"
    assert n_segs % LANE == 0, "ops.py must pad the table to a lane multiple"
    ids2 = ids.reshape(total // LANE, LANE)
    grid = (total // block,)
    kernel = functools.partial(
        _place_kernel,
        top_level=top_level,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs=n_segs,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
            pl.BlockSpec((n_segs,), lambda i: (0,)),  # whole table per block
        ],
        out_specs=pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(ids2.shape, jnp.int32),
        interpret=interpret,
    )(ids2, len32)
    return out.reshape(total)


@functools.partial(
    jax.jit,
    static_argnames=(
        "top_level",
        "s_log2",
        "max_draws",
        "rows_per_block",
        "interpret",
        "emit_nodes",
    ),
)
def place_fused_pallas(
    ids: jax.Array,
    len32: jax.Array,
    cum_hi: jax.Array,
    cum_lo: jax.Array,
    node_of: jax.Array,
    *,
    top_level: int,
    s_log2: int = 1,
    max_draws: int = 128,
    rows_per_block: int = DEFAULT_ROWS,
    interpret: bool = True,
    emit_nodes: bool = False,
) -> jax.Array:
    """Device-resident batched placement -> (total,) int32, no host fix-up.

    Like ``place_pallas`` but total: the p < 2**-53 non-converged tail is
    resolved on-chip against the precomputed u64-cumsum halves
    (``resolve_tail_dev``, bit-identical to ``resolve_tail_np``), and with
    ``emit_nodes=True`` the seg->node gather is fused so the output is node
    ids.  All five operands live in VMEM per block; the result never touches
    the host.
    """
    n_segs = int(len32.shape[0])
    total = ids.shape[0]
    block = rows_per_block * LANE
    assert total % block == 0, "ops.py must pad ids to a block multiple"
    assert n_segs % LANE == 0, "ops.py must pad the table to a lane multiple"
    assert cum_hi.shape[0] == n_segs and cum_lo.shape[0] == n_segs
    assert node_of.shape[0] == n_segs
    ids2 = ids.reshape(total // LANE, LANE)
    grid = (total // block,)
    kernel = functools.partial(
        _place_fused_kernel,
        top_level=top_level,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs=n_segs,
        emit_nodes=emit_nodes,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
            pl.BlockSpec((n_segs,), lambda i: (0,)),  # whole table per block
            pl.BlockSpec((n_segs,), lambda i: (0,)),
            pl.BlockSpec((n_segs,), lambda i: (0,)),
            pl.BlockSpec((n_segs,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(ids2.shape, jnp.int32),
        interpret=interpret,
    )(ids2, len32, cum_hi, cum_lo, node_of.astype(jnp.int32))
    return out.reshape(total)


@functools.partial(
    jax.jit,
    static_argnames=(
        "top_a",
        "top_b",
        "s_log2",
        "max_draws",
        "rows_per_block",
        "interpret",
    ),
)
def diff_nodes_pallas(
    ids: jax.Array,
    len32_a: jax.Array,
    cum_hi_a: jax.Array,
    cum_lo_a: jax.Array,
    node_a: jax.Array,
    len32_b: jax.Array,
    cum_hi_b: jax.Array,
    cum_lo_b: jax.Array,
    node_b: jax.Array,
    *,
    top_a: int,
    top_b: int,
    s_log2: int = 1,
    max_draws: int = 128,
    rows_per_block: int = DEFAULT_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Dual-version placement via pl.pallas_call -> (2, total) int32 nodes.

    Row 0 is each id's owner under table A (version v), row 1 under table B
    (version v+1) -- the migration planner derives ``(moved, src, dst)``
    from this.  Both tables must be lane-padded (ops.py pads); ids must be
    a block multiple.  One kernel pass over the ids, both tables resident
    in VMEM, zero host syncs.
    """
    n_segs_a = int(len32_a.shape[0])
    n_segs_b = int(len32_b.shape[0])
    total = ids.shape[0]
    block = rows_per_block * LANE
    assert total % block == 0, "ops.py must pad ids to a block multiple"
    assert n_segs_a % LANE == 0 and n_segs_b % LANE == 0
    assert cum_hi_a.shape[0] == n_segs_a and cum_lo_a.shape[0] == n_segs_a
    assert cum_hi_b.shape[0] == n_segs_b and cum_lo_b.shape[0] == n_segs_b
    assert node_a.shape[0] == n_segs_a and node_b.shape[0] == n_segs_b
    ids2 = ids.reshape(total // LANE, LANE)
    grid = (total // block,)
    kernel = functools.partial(
        _diff_kernel,
        top_a=top_a,
        top_b=top_b,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs_a=n_segs_a,
        n_segs_b=n_segs_b,
    )
    spec_a = pl.BlockSpec((n_segs_a,), lambda i: (0,))
    spec_b = pl.BlockSpec((n_segs_b,), lambda i: (0,))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
            spec_a,  # whole A table per block
            spec_a,
            spec_a,
            spec_a,
            spec_b,  # whole B table per block
            spec_b,
            spec_b,
            spec_b,
        ],
        out_specs=pl.BlockSpec((2, rows_per_block, LANE), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((2, total // LANE, LANE), jnp.int32),
        interpret=interpret,
    )(
        ids2,
        len32_a,
        cum_hi_a,
        cum_lo_a,
        node_a.astype(jnp.int32),
        len32_b,
        cum_hi_b,
        cum_lo_b,
        node_b.astype(jnp.int32),
    )
    return out.reshape(2, total)


@functools.partial(
    jax.jit,
    static_argnames=(
        "top_a",
        "top_b",
        "s_log2",
        "max_draws",
        "n_replicas",
        "rows_per_block",
        "interpret",
    ),
)
def diff_replicas_pallas(
    ids: jax.Array,
    len32_a: jax.Array,
    node_a: jax.Array,
    len32_b: jax.Array,
    node_b: jax.Array,
    *,
    top_a: int,
    top_b: int,
    s_log2: int = 1,
    max_draws: int = 128,
    n_replicas: int = 1,
    rows_per_block: int = DEFAULT_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Dual-version replica placement via pl.pallas_call -> (2, total, R).

    Index 0 is each id's R-replica node set under table A (version v),
    index 1 under table B (version v+1) -- the replica planner derives the
    per-slot ``(moved, src, dst, src_slot)`` alignment from this
    (``ops._align_replica_sets``).  Both tables must be lane-padded (ops.py
    pads); ids must be a block multiple.  One kernel pass over the ids,
    both tables resident in VMEM, zero host syncs.
    """
    n_segs_a = int(len32_a.shape[0])
    n_segs_b = int(len32_b.shape[0])
    total = ids.shape[0]
    block = rows_per_block * LANE
    assert total % block == 0, "ops.py must pad ids to a block multiple"
    assert n_segs_a % LANE == 0 and n_segs_b % LANE == 0
    assert node_a.shape[0] == n_segs_a and node_b.shape[0] == n_segs_b
    ids2 = ids.reshape(total // LANE, LANE)
    grid = (total // block,)
    kernel = functools.partial(
        _diff_replicas_kernel,
        top_a=top_a,
        top_b=top_b,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs_a=n_segs_a,
        n_segs_b=n_segs_b,
        n_replicas=n_replicas,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
            pl.BlockSpec((n_segs_a,), lambda i: (0,)),  # whole A table per block
            pl.BlockSpec((n_segs_a,), lambda i: (0,)),
            pl.BlockSpec((n_segs_b,), lambda i: (0,)),  # whole B table per block
            pl.BlockSpec((n_segs_b,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec(
            (2, n_replicas, rows_per_block, LANE), lambda i: (0, 0, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (2, n_replicas, total // LANE, LANE), jnp.int32
        ),
        interpret=interpret,
    )(
        ids2,
        len32_a,
        node_a.astype(jnp.int32),
        len32_b,
        node_b.astype(jnp.int32),
    )
    return out.reshape(2, n_replicas, total).transpose(0, 2, 1)
