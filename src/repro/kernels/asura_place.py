"""Pallas TPU kernel: batched ASURA placement (the paper's hot spot).

The paper optimizes per-datum placement latency on a CPU (0.6 us/call); the
TPU-native re-think (DESIGN.md section 3) is *throughput*: place a whole
vector of datum ids per call for data-pipeline sharding, checkpoint-shard
routing and request routing.  The kernel is pure uint32 VPU work:

  * the id vector is tiled into (ROWS, 128) VMEM blocks (lane-aligned),
  * the O(N) segment table (ASURA's memory advantage over Consistent
    Hashing's O(NV) ring, paper Table II) is broadcast whole into VMEM --
    40 KB for 10k segments, far under the ~16 MB VMEM budget,
  * each grid step runs the bounded masked draw loop entirely on-chip:
    counter-based hashing (no PRNG state), MSB descend test, shift-based
    floor/fraction, one dynamic VMEM gather per draw for the hit test.

Trip count: Appendix B bounds expected draws by ~4 (hole fraction <= 1/2),
and the while_loop exits as soon as every lane has placed, so the typical
block does 4-6 iterations; max_draws caps the tail at p < 2**-53 per lane.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import draw_u32

LANE = 128
DEFAULT_ROWS = 16  # (16, 128) = 2048 ids per grid step


def _next_asura_tile(ids, counters, top_level: int, s_log2: int):
    """One ASURA number per lane of a (rows, LANE) tile: (k, frac32, ctrs).

    The unrolled descend ladder shared by the placement and replication
    kernels -- counter-based draws, MSB descend test, shift-based
    floor/fraction (the exact-u32 formulation, DESIGN.md section 3)."""
    shape = ids.shape
    consult = jnp.ones(shape, dtype=bool)
    out_k = jnp.zeros(shape, dtype=jnp.int32)
    out_f = jnp.zeros(shape, dtype=jnp.uint32)
    rows = []
    for level in range(top_level, -1, -1):
        h = draw_u32(ids, level, counters[top_level - level])
        rows.append(counters[top_level - level] + consult.astype(jnp.uint32))
        descend = consult & (level > 0) & ((h & jnp.uint32(0x80000000)) == 0)
        emit = consult & ~descend
        k = (h >> jnp.uint32(32 - s_log2 - level)).astype(jnp.int32)
        f = h << jnp.uint32(s_log2 + level)
        out_k = jnp.where(emit, k, out_k)
        out_f = jnp.where(emit, f, out_f)
        consult = descend
    return out_k, out_f, jnp.stack(rows)


def _place_kernel(
    ids_ref,
    table_ref,
    out_ref,
    *,
    top_level: int,
    s_log2: int,
    max_draws: int,
    n_segs: int,
):
    ids = ids_ref[...]  # (rows, LANE) uint32
    table = table_ref[...]  # (n_pad,) uint32
    shape = ids.shape

    def next_asura(counters):
        return _next_asura_tile(ids, counters, top_level, s_log2)

    def cond(state):
        i, _, _, done = state
        return (i < max_draws) & ~jnp.all(done)

    def body(state):
        i, counters, result, done = state
        k, f, counters = next_asura(counters)
        k_safe = jnp.minimum(k, n_segs - 1)
        lens = jnp.take(table, k_safe.reshape(-1), axis=0).reshape(shape)
        hit = (~done) & (k < n_segs) & (f < lens)
        result = jnp.where(hit, k, result)
        return i + 1, counters, result, done | hit

    counters0 = jnp.zeros((top_level + 1,) + shape, dtype=jnp.uint32)
    result0 = jnp.full(shape, -1, dtype=jnp.int32)
    done0 = jnp.zeros(shape, dtype=bool)
    _, _, result, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), counters0, result0, done0)
    )
    out_ref[...] = result


def _place_replicas_kernel(
    ids_ref,
    table_ref,
    node_ref,
    out_ref,
    *,
    top_level: int,
    s_log2: int,
    max_draws: int,
    n_segs: int,
    n_replicas: int,
):
    """Section 5.A replication: first R hits on distinct nodes, per lane.

    Same bounded masked draw loop as ``_place_kernel``, with per-lane
    ``(found, segs[R], nodes[R])`` state: ``nodes`` carries the node of each
    already-picked replica in-register so the distinct-node dup test is R
    compares instead of R extra VMEM gathers; the seg->node table is gathered
    once per draw (alongside the length gather) to resolve the candidate's
    node.  Draw order and hit tests are bit-identical to
    ``place_replicas_scalar``; -1 marks non-converged entries (ops.py raises).
    """
    ids = ids_ref[...]  # (rows, LANE) uint32
    table = table_ref[...]  # (n_pad,) uint32
    node_of = node_ref[...]  # (n_pad,) int32, -1 on holes/padding
    shape = ids.shape
    R = n_replicas

    def next_asura(counters):
        return _next_asura_tile(ids, counters, top_level, s_log2)

    def cond(state):
        i, _, _, _, found = state
        return (i < max_draws * max(1, R)) & ~jnp.all(found >= R)

    def body(state):
        i, counters, segs, nodes, found = state
        k, f, counters = next_asura(counters)
        k_safe = jnp.minimum(k, n_segs - 1)
        flat = k_safe.reshape(-1)
        lens = jnp.take(table, flat, axis=0).reshape(shape)
        node_k = jnp.take(node_of, flat, axis=0).reshape(shape)
        hit = (found < R) & (k < n_segs) & (f < lens)
        dup = jnp.zeros(shape, dtype=bool)
        for r in range(R):
            dup |= (nodes[r] >= 0) & (nodes[r] == node_k)
        take = hit & ~dup
        segs = jnp.stack(
            [jnp.where(take & (found == r), k, segs[r]) for r in range(R)]
        )
        nodes = jnp.stack(
            [jnp.where(take & (found == r), node_k, nodes[r]) for r in range(R)]
        )
        return i + 1, counters, segs, nodes, found + take.astype(jnp.int32)

    counters0 = jnp.zeros((top_level + 1,) + shape, dtype=jnp.uint32)
    segs0 = jnp.full((R,) + shape, -1, dtype=jnp.int32)
    nodes0 = jnp.full((R,) + shape, -1, dtype=jnp.int32)
    found0 = jnp.zeros(shape, dtype=jnp.int32)
    _, _, segs, _, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), counters0, segs0, nodes0, found0)
    )
    out_ref[...] = segs


@functools.partial(
    jax.jit,
    static_argnames=(
        "top_level",
        "s_log2",
        "max_draws",
        "n_replicas",
        "rows_per_block",
        "interpret",
    ),
)
def place_replicas_pallas(
    ids: jax.Array,
    len32: jax.Array,
    node_of: jax.Array,
    *,
    top_level: int,
    s_log2: int = 1,
    max_draws: int = 128,
    n_replicas: int = 1,
    rows_per_block: int = DEFAULT_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Batched replica placement via pl.pallas_call -> (total, R) int32 segs.

    ids must be (m * rows_per_block * 128,) uint32 and len32 / node_of
    128-padded (ops.py pads; node padding is -1).  Non-converged entries are
    -1 (the ops.py wrapper raises on them after unpadding).
    """
    n_segs = int(len32.shape[0])
    total = ids.shape[0]
    block = rows_per_block * LANE
    assert total % block == 0, "ops.py must pad ids to a block multiple"
    assert n_segs % LANE == 0, "ops.py must pad the table to a lane multiple"
    assert node_of.shape[0] == n_segs, "node table must match the length table"
    ids2 = ids.reshape(total // LANE, LANE)
    grid = (total // block,)
    kernel = functools.partial(
        _place_replicas_kernel,
        top_level=top_level,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs=n_segs,
        n_replicas=n_replicas,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
            pl.BlockSpec((n_segs,), lambda i: (0,)),  # whole table per block
            pl.BlockSpec((n_segs,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n_replicas, rows_per_block, LANE), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (n_replicas, total // LANE, LANE), jnp.int32
        ),
        interpret=interpret,
    )(ids2, len32, node_of.astype(jnp.int32))
    return out.reshape(n_replicas, total).T


@functools.partial(
    jax.jit,
    static_argnames=("top_level", "s_log2", "max_draws", "rows_per_block", "interpret"),
)
def place_pallas(
    ids: jax.Array,
    len32: jax.Array,
    *,
    top_level: int,
    s_log2: int = 1,
    max_draws: int = 128,
    rows_per_block: int = DEFAULT_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Batched placement via pl.pallas_call.

    ids must be (m * rows_per_block * 128,) uint32 (pre-padded by ops.py);
    len32 must be 128-padded.  Returns int32 segment numbers (-1 for the
    p < 2**-53 non-converged tail; ops.py resolves those).
    """
    n_segs = int(len32.shape[0])
    total = ids.shape[0]
    block = rows_per_block * LANE
    assert total % block == 0, "ops.py must pad ids to a block multiple"
    assert n_segs % LANE == 0, "ops.py must pad the table to a lane multiple"
    ids2 = ids.reshape(total // LANE, LANE)
    grid = (total // block,)
    kernel = functools.partial(
        _place_kernel,
        top_level=top_level,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs=n_segs,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
            pl.BlockSpec((n_segs,), lambda i: (0,)),  # whole table per block
        ],
        out_specs=pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(ids2.shape, jnp.int32),
        interpret=interpret,
    )(ids2, len32)
    return out.reshape(total)
