"""Jitted public wrappers for batched ASURA placement and replication.

``asura_place`` pads the id vector / segment table, dispatches to the Pallas
kernel (interpret mode on CPU, compiled on TPU), resolves the p < 2**-53
non-converged tail with the exact-integer uniform draw over occupied mass
(``repro.core.asura.resolve_tail_np`` -- the single tail spec shared with the
NumPy batch path; DESIGN.md section 3.2), and unpads.  ``asura_place_nodes``
additionally maps segments -> node ids; ``asura_place_replicas`` runs the
section 5.A distinct-node replica kernel.

The ``*_on_table`` variants take a prebuilt device-resident table (lane-padded
u32 lengths + int32 seg->node map + static top level) so the PlacementEngine
can issue many placement calls against one host->device upload.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.asura import (
    DEFAULT_PARAMS,
    AsuraParams,
    _upper_bound,
    resolve_tail_np,
)

from .asura_place import (
    DEFAULT_ROWS,
    LANE,
    place_pallas,
    place_replicas_pallas,
)
from .ref import place_ref, place_replicas_ref


def _pad_to(x: jax.Array, multiple: int, fill) -> jax.Array:
    pad = (-x.shape[0]) % multiple
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), fill, dtype=x.dtype)])


def _lane_pad_np(x: np.ndarray, fill) -> np.ndarray:
    pad = (-x.shape[0]) % LANE
    if pad == 0:
        return x
    return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])


def table_prep(seg_lengths, params: AsuraParams = DEFAULT_PARAMS):
    """Host-side: canonical u32 table (lane-padded) + static top level."""
    lengths = np.asarray(seg_lengths, dtype=np.float64)
    top_level = params.level_for(_upper_bound(lengths))
    len32 = np.minimum(np.round(lengths * 2.0**32), 2.0**32 - 1).astype(np.uint32)
    return jnp.asarray(_lane_pad_np(len32, np.uint32(0))), top_level


def node_table_prep(seg_to_node) -> jax.Array:
    """Host-side: int32 seg->node map, lane-padded with -1 (hole marker)."""
    node_of = np.asarray(seg_to_node, dtype=np.int32)
    return jnp.asarray(_lane_pad_np(node_of, np.int32(-1)))


def place_on_table(
    datum_ids,
    len32: jax.Array,
    *,
    top_level: int,
    params: AsuraParams = DEFAULT_PARAMS,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> np.ndarray:
    """Placement against a prebuilt (lane-padded) device table -> int64 segs.

    The tail (-1 lanes, p < 2**-53) is resolved on the host with the exact
    integer spec, so this path agrees bit-for-bit with the NumPy
    ``place_batch`` including the fallback.  This is a host-facing API (one
    device->host transfer per call, which every engine consumer needs
    anyway); pipelines that keep results on device should call
    ``place_pallas`` directly and treat -1 as the (practically impossible)
    non-converged marker.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    ids = jnp.asarray(datum_ids).astype(jnp.uint32)
    n = ids.shape[0]
    if use_pallas:
        block = rows_per_block * LANE
        padded = _pad_to(ids, block, 0)
        result = place_pallas(
            padded,
            len32,
            top_level=top_level,
            s_log2=params.s_log2,
            max_draws=params.max_draws,
            rows_per_block=rows_per_block,
            interpret=interpret,
        )[:n]
    else:
        result = place_ref(
            ids,
            len32,
            top_level=top_level,
            s_log2=params.s_log2,
            max_draws=params.max_draws,
        )
    return resolve_tail_np(
        np.asarray(ids), np.asarray(result).astype(np.int64), np.asarray(len32), top_level
    )


def place_replicas_on_table(
    datum_ids,
    len32: jax.Array,
    node_of: jax.Array,
    n_replicas: int,
    *,
    top_level: int,
    params: AsuraParams = DEFAULT_PARAMS,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> np.ndarray:
    """Replica placement against a prebuilt table -> (batch, R) int64 segs.

    Raises on non-convergence (more replicas requested than distinct nodes
    can supply within the bounded loop), matching the NumPy batch path.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    ids = jnp.asarray(datum_ids).astype(jnp.uint32)
    n = ids.shape[0]
    if use_pallas:
        block = rows_per_block * LANE
        padded = _pad_to(ids, block, 0)
        result = place_replicas_pallas(
            padded,
            len32,
            node_of,
            top_level=top_level,
            s_log2=params.s_log2,
            max_draws=params.max_draws,
            n_replicas=n_replicas,
            rows_per_block=rows_per_block,
            interpret=interpret,
        )[:n]
    else:
        result = place_replicas_ref(
            ids,
            len32,
            node_of,
            top_level=top_level,
            s_log2=params.s_log2,
            max_draws=params.max_draws,
            n_replicas=n_replicas,
        )
    out = np.asarray(result).astype(np.int64)
    if (out < 0).any():
        raise RuntimeError("replication did not converge; too few distinct nodes?")
    return out


def asura_place(
    datum_ids,
    seg_lengths,
    params: AsuraParams = DEFAULT_PARAMS,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> jax.Array:
    """Place a batch of datum ids -> int32 segment numbers.

    use_pallas=False routes through the pure-jnp reference (place_ref) --
    the path the distributed pipeline uses on CPU hosts; the Pallas path is
    the TPU fast path (validated bit-identical in tests/test_kernels.py).
    """
    len32, top_level = table_prep(seg_lengths, params)
    segs = place_on_table(
        datum_ids,
        len32,
        top_level=top_level,
        params=params,
        use_pallas=use_pallas,
        interpret=interpret,
        rows_per_block=rows_per_block,
    )
    return jnp.asarray(segs.astype(np.int32))


def asura_place_nodes(
    datum_ids,
    seg_lengths,
    seg_to_node,
    params: AsuraParams = DEFAULT_PARAMS,
    **kwargs,
) -> jax.Array:
    segs = asura_place(datum_ids, seg_lengths, params, **kwargs)
    return jnp.asarray(np.asarray(seg_to_node, dtype=np.int32))[segs]


def asura_place_replicas(
    datum_ids,
    seg_lengths,
    seg_to_node,
    n_replicas: int,
    params: AsuraParams = DEFAULT_PARAMS,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> jax.Array:
    """Replica placement -> (batch, R) int32 segment numbers, primary first."""
    len32, top_level = table_prep(seg_lengths, params)
    node_of = node_table_prep(seg_to_node)
    segs = place_replicas_on_table(
        datum_ids,
        len32,
        node_of,
        n_replicas,
        top_level=top_level,
        params=params,
        use_pallas=use_pallas,
        interpret=interpret,
        rows_per_block=rows_per_block,
    )
    return jnp.asarray(segs.astype(np.int32))
