"""Jitted public wrappers for batched ASURA placement and replication.

Two tiers of entry points (DESIGN.md sections 3.2-3.4, 6):

  * ``*_on_table_device`` -- the fully device-resident path: placement,
    the p < 2**-53 non-converged tail (resolved on device against the
    precomputed u64-cumsum halves, bit-identical to
    ``repro.core.asura.resolve_tail_np``) and, for the ``nodes`` variants,
    the fused seg->node gather all run on device and return device arrays
    with ZERO host syncs -- the path the ``PlacementEngine`` device
    variants and device-chained consumers (router, data pipeline,
    checkpoint store) use.
  * ``place_on_table`` / ``place_replicas_on_table`` -- host-facing: the
    same device computation plus exactly ONE device->host transfer of the
    final result (no jnp->np->jnp ping-pong; historically the tail was
    resolved on the host and the fixed-up result re-uploaded).

``asura_place*`` are the table-deriving conveniences: they canonicalize the
segment table (via ``core.asura.lengths_to_u32``, which validates lengths
in [0, 1) exactly like the NumPy path) and dispatch to the kernels --
Pallas (interpret mode on CPU, compiled on TPU) or the jnp reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.asura import (
    DEFAULT_PARAMS,
    AsuraParams,
    _upper_bound,
    lengths_to_u32,
    tail_cumsum_halves,
)

from .asura_place import (
    DEFAULT_ROWS,
    LANE,
    diff_nodes_pallas,
    diff_replicas_pallas,
    place_fused_pallas,
    place_pallas,
    place_replicas_pallas,
)
from .hierarchy import hier_place_replicas_pallas, hier_place_replicas_ref
from .ref import (
    addition_numbers_ref,
    place_ref,
    place_replicas_ref,
    resolve_tail_dev,
)

__all__ = [
    "table_prep",
    "node_table_prep",
    "tail_prep",
    "place_on_table",
    "place_on_table_device",
    "place_nodes_on_table_device",
    "place_replicas_on_table",
    "place_replicas_on_table_device",
    "diff_nodes_on_tables_device",
    "diff_replicas_on_tables_device",
    "hier_place_replicas_on_tables",
    "hier_place_replicas_on_tables_device",
    "hier_diff_replicas_on_tables_device",
    "addition_numbers_on_table_device",
    "asura_place",
    "asura_place_nodes",
    "asura_place_replicas",
]


@functools.partial(jax.jit, static_argnames=("multiple",))
def _pad_ids(x: jax.Array, multiple: int) -> jax.Array:
    """Zero-pad ids to a block multiple ON DEVICE (jitted so the pad
    constant is baked at compile time -- no per-call host->device scalar)."""
    pad = (-x.shape[0]) % multiple
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.zeros((pad,), dtype=x.dtype)])


def _lane_pad_np(x: np.ndarray, fill) -> np.ndarray:
    pad = (-x.shape[0]) % LANE
    if pad == 0:
        return x
    return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])


def table_prep(seg_lengths, params: AsuraParams = DEFAULT_PARAMS):
    """Host-side: canonical u32 table (lane-padded) + static top level.

    Uses ``core.asura.lengths_to_u32`` -- the single canonicalization spec
    -- so out-of-range lengths raise here exactly as on the NumPy path
    instead of silently wrapping on device.
    """
    lengths = np.asarray(seg_lengths, dtype=np.float64)
    top_level = params.level_for(_upper_bound(lengths))
    len32 = lengths_to_u32(lengths)
    return jnp.asarray(_lane_pad_np(len32, np.uint32(0))), top_level


def node_table_prep(seg_to_node) -> jax.Array:
    """Host-side: int32 seg->node map, lane-padded with -1 (hole marker)."""
    node_of = np.asarray(seg_to_node, dtype=np.int32)
    return jnp.asarray(_lane_pad_np(node_of, np.int32(-1)))


def tail_prep(len32) -> tuple[jax.Array, jax.Array]:
    """Host-side: u64 length-cumsum as two lane-padded u32 halves on device.

    The device-resident tail tables (DESIGN.md section 3.2): computed once
    per table version from the (already lane-padded) u32 length table;
    padding entries carry cumsum == total mass and can never win the tail
    draw.  One upload alongside the length/node tables.
    """
    cum_hi, cum_lo = tail_cumsum_halves(np.asarray(len32, dtype=np.uint32))
    return jnp.asarray(cum_hi), jnp.asarray(cum_lo)


@functools.partial(
    jax.jit,
    static_argnames=("top_level", "s_log2", "max_draws", "emit_nodes", "emit_stats"),
)
def _place_fused_ref(
    ids: jax.Array,
    len32: jax.Array,
    cum_hi: jax.Array,
    cum_lo: jax.Array,
    node_of: jax.Array,
    *,
    top_level: int,
    s_log2: int,
    max_draws: int,
    emit_nodes: bool,
    emit_stats: bool = False,
):
    """jnp-reference analogue of ``place_fused_pallas``: total, on-device.

    ``emit_stats=True`` returns ``(out, tail_count)`` where ``tail_count``
    is the uint32 number of lanes that fell through the bounded draw loop
    into the 95-bit tail resolution (obs device plane; p < 2**-53 per lane,
    so a nonzero count is itself a signal).  Outputs are bit-identical
    either way."""
    segs = place_ref(
        ids, len32, top_level=top_level, s_log2=s_log2, max_draws=max_draws
    )
    tail_count = jnp.sum((segs < 0).astype(jnp.uint32)) if emit_stats else None
    segs = resolve_tail_dev(ids, segs, cum_hi, cum_lo, top_level)
    if emit_nodes:
        segs = jnp.take(node_of, segs, axis=0)
    if emit_stats:
        return segs, tail_count
    return segs


@functools.partial(jax.jit, static_argnames=("n",))
def _head(x: jax.Array, n: int) -> jax.Array:
    """x[:n] ON DEVICE (jitted: an eager slice materializes its start
    indices as host scalars, which a transfer guard rightly rejects)."""
    return x[:n]


@functools.partial(
    jax.jit,
    static_argnames=(
        "top_level", "s_log2", "max_draws", "n_replicas", "emit_nodes",
        "emit_stats",
    ),
)
def _place_replicas_fused_ref(
    ids: jax.Array,
    len32: jax.Array,
    node_of: jax.Array,
    *,
    top_level: int,
    s_log2: int,
    max_draws: int,
    n_replicas: int,
    emit_nodes: bool,
    emit_stats: bool = False,
):
    """jnp-reference replica placement with the optional fused node gather
    (one jit so no eager scalar ops escape to the host between calls).

    ``emit_stats=True`` returns ``(out, stats)`` where ``stats`` is the
    (DEPTH_BINS + 1,) uint32 vector ``[ladder_depth_hist..., nonconverged]``
    the obs device plane accumulates into its slab -- placements stay
    bit-identical (tested)."""
    if emit_stats:
        segs, depth_hist = place_replicas_ref(
            ids,
            len32,
            node_of,
            top_level=top_level,
            s_log2=s_log2,
            max_draws=max_draws,
            n_replicas=n_replicas,
            emit_stats=True,
        )
        nonconv = jnp.sum((segs < 0).astype(jnp.uint32))
        stats = jnp.concatenate([depth_hist, nonconv[None]])
    else:
        segs = place_replicas_ref(
            ids,
            len32,
            node_of,
            top_level=top_level,
            s_log2=s_log2,
            max_draws=max_draws,
            n_replicas=n_replicas,
        )
    if emit_nodes:
        segs = jnp.where(segs >= 0, jnp.take(node_of, jnp.maximum(segs, 0)), -1)
    if emit_stats:
        return segs, stats
    return segs


def _default_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def place_on_table_device(
    datum_ids,
    len32: jax.Array,
    cum_hi: jax.Array,
    cum_lo: jax.Array,
    node_of: jax.Array | None = None,
    *,
    top_level: int,
    params: AsuraParams = DEFAULT_PARAMS,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
    emit_nodes: bool = False,
) -> jax.Array:
    """Fully device-resident placement -> (batch,) int32 device array.

    Total (the tail is resolved on device, bit-identical to the host spec)
    and sync-free: inputs already on device stay there, the output is a
    device array, and nothing round-trips through the host.  With
    ``emit_nodes=True`` the seg->node gather is fused and the result is
    node ids (``node_of`` required).
    """
    interpret = _default_interpret(interpret)
    ids = jnp.asarray(datum_ids).astype(jnp.uint32)
    n = ids.shape[0]
    if emit_nodes and node_of is None:
        raise ValueError("emit_nodes=True requires the node table")
    if node_of is None:
        node_of = jnp.full(len32.shape, -1, dtype=jnp.int32)
    if n == 0:
        return jnp.zeros((0,), dtype=jnp.int32)
    if use_pallas:
        block = rows_per_block * LANE
        padded = _pad_ids(ids, block)
        out = place_fused_pallas(
            padded,
            len32,
            cum_hi,
            cum_lo,
            node_of,
            top_level=top_level,
            s_log2=params.s_log2,
            max_draws=params.max_draws,
            rows_per_block=rows_per_block,
            interpret=interpret,
            emit_nodes=emit_nodes,
        )
        return _head(out, n)
    return _place_fused_ref(
        ids,
        len32,
        cum_hi,
        cum_lo,
        node_of,
        top_level=top_level,
        s_log2=params.s_log2,
        max_draws=params.max_draws,
        emit_nodes=emit_nodes,
    )


@functools.partial(
    jax.jit, static_argnames=("top_a", "top_b", "s_log2", "max_draws")
)
def _diff_fused_ref(
    ids: jax.Array,
    len32_a: jax.Array,
    cum_hi_a: jax.Array,
    cum_lo_a: jax.Array,
    node_a: jax.Array,
    len32_b: jax.Array,
    cum_hi_b: jax.Array,
    cum_lo_b: jax.Array,
    node_b: jax.Array,
    *,
    top_a: int,
    top_b: int,
    s_log2: int,
    max_draws: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """jnp-reference version diff: both placements + the compare in ONE jit
    (no eager scalar ops escape to the host between the two sweeps)."""
    src = _place_fused_ref(
        ids, len32_a, cum_hi_a, cum_lo_a, node_a,
        top_level=top_a, s_log2=s_log2, max_draws=max_draws, emit_nodes=True,
    )
    dst = _place_fused_ref(
        ids, len32_b, cum_hi_b, cum_lo_b, node_b,
        top_level=top_b, s_log2=s_log2, max_draws=max_draws, emit_nodes=True,
    )
    return src != dst, src, dst


@jax.jit
def _neq(src: jax.Array, dst: jax.Array) -> jax.Array:
    """src != dst ON DEVICE (jitted so no eager dispatch can stage through
    host scalars under a transfer guard)."""
    return src != dst


@functools.partial(jax.jit, static_argnames=("n",))
def _split_diff(out: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """(2, padded) kernel output -> (src[:n], dst[:n]) ON DEVICE (an eager
    row index would materialize its start index as a host scalar)."""
    return out[0, :n], out[1, :n]


def diff_nodes_on_tables_device(
    datum_ids,
    len32_a: jax.Array,
    cum_hi_a: jax.Array,
    cum_lo_a: jax.Array,
    node_a: jax.Array,
    len32_b: jax.Array,
    cum_hi_b: jax.Array,
    cum_lo_b: jax.Array,
    node_b: jax.Array,
    *,
    top_a: int,
    top_b: int,
    params: AsuraParams = DEFAULT_PARAMS,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Version diff against two prebuilt tables -> (moved, src, dst).

    Places every id under table A (version v) and table B (version v+1) in
    one device pass and emits the migration planner's triple: ``moved``
    (bool, owner changed), ``src`` / ``dst`` (int32 node ids under v / v+1).
    All three are DEVICE arrays and nothing round-trips through the host --
    the planner's ``plan_stream`` chains chunks of this with zero syncs
    (DESIGN.md section 8).
    """
    interpret = _default_interpret(interpret)
    ids = jnp.asarray(datum_ids).astype(jnp.uint32)
    n = ids.shape[0]
    if n == 0:
        empty = jnp.zeros((0,), dtype=jnp.int32)
        return jnp.zeros((0,), dtype=bool), empty, empty
    if use_pallas:
        block = rows_per_block * LANE
        padded = _pad_ids(ids, block)
        out = diff_nodes_pallas(
            padded,
            len32_a, cum_hi_a, cum_lo_a, node_a,
            len32_b, cum_hi_b, cum_lo_b, node_b,
            top_a=top_a,
            top_b=top_b,
            s_log2=params.s_log2,
            max_draws=params.max_draws,
            rows_per_block=rows_per_block,
            interpret=interpret,
        )
        src, dst = _split_diff(out, n)
        return _neq(src, dst), src, dst
    return _diff_fused_ref(
        ids,
        len32_a, cum_hi_a, cum_lo_a, node_a,
        len32_b, cum_hi_b, cum_lo_b, node_b,
        top_a=top_a,
        top_b=top_b,
        s_log2=params.s_log2,
        max_draws=params.max_draws,
    )


@functools.partial(jax.jit, static_argnames=("n_replicas",))
def _align_replica_sets(
    before: jax.Array, after: jax.Array, *, n_replicas: int
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-slot minimal alignment of two (batch, R) replica-node sets.

    The jitted device twin of ``core.asura.align_replica_sets`` (same exact
    integer formulation, bit-identical -- tested): slots index the AFTER
    set; ``moved[b, r]`` iff ``after[b, r]`` is not in ``before[b, :]``
    (exactly the section-5 minimal replica mass), ``src`` is the
    rank-matched vacated node for moved slots (``after[b, r]`` itself
    otherwise), ``src_slot`` its before-set position (rollback re-indexing).
    Returns ``(moved, src, dst, src_slot)``, all (batch, R); ``dst`` is
    ``after`` cast to int32.
    """
    before = before.astype(jnp.int32)
    after = after.astype(jnp.int32)
    new = ~jnp.any(after[:, :, None] == before[:, None, :], axis=2)
    lost = ~jnp.any(before[:, :, None] == after[:, None, :], axis=2)
    new_i = new.astype(jnp.int32)
    lost_i = lost.astype(jnp.int32)
    rank_new = jnp.cumsum(new_i, axis=1) - new_i
    rank_lost = jnp.cumsum(lost_i, axis=1) - lost_i
    match = lost[:, None, :] & (rank_lost[:, None, :] == rank_new[:, :, None])
    picked_src = jnp.sum(jnp.where(match, before[:, None, :], 0), axis=2)
    slots = jnp.arange(n_replicas, dtype=jnp.int32)
    picked_slot = jnp.sum(jnp.where(match, slots[None, None, :], 0), axis=2)
    src = jnp.where(new, picked_src, after)
    src_slot = jnp.where(new, picked_slot, slots[None, :])
    return new, src, after, src_slot


@functools.partial(
    jax.jit,
    static_argnames=("top_a", "top_b", "s_log2", "max_draws", "n_replicas"),
)
def _diff_replicas_fused_ref(
    ids: jax.Array,
    len32_a: jax.Array,
    node_a: jax.Array,
    len32_b: jax.Array,
    node_b: jax.Array,
    *,
    top_a: int,
    top_b: int,
    s_log2: int,
    max_draws: int,
    n_replicas: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """jnp-reference replica-set version diff: both R-replica placements +
    the per-slot alignment in ONE jit (no eager scalar ops escape to the
    host between the two sweeps)."""
    before = _place_replicas_fused_ref(
        ids, len32_a, node_a,
        top_level=top_a, s_log2=s_log2, max_draws=max_draws,
        n_replicas=n_replicas, emit_nodes=True,
    )
    after = _place_replicas_fused_ref(
        ids, len32_b, node_b,
        top_level=top_b, s_log2=s_log2, max_draws=max_draws,
        n_replicas=n_replicas, emit_nodes=True,
    )
    return _align_replica_sets(before, after, n_replicas=n_replicas)


@functools.partial(jax.jit, static_argnames=("n",))
def _split_diff_sets(out: jax.Array, n: int) -> tuple[jax.Array, jax.Array]:
    """(2, padded, R) kernel output -> (before[:n], after[:n]) ON DEVICE."""
    return out[0, :n], out[1, :n]


def diff_replicas_on_tables_device(
    datum_ids,
    len32_a: jax.Array,
    node_a: jax.Array,
    len32_b: jax.Array,
    node_b: jax.Array,
    *,
    top_a: int,
    top_b: int,
    n_replicas: int,
    params: AsuraParams = DEFAULT_PARAMS,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Replica-set version diff against two prebuilt tables
    -> ``(moved, src, dst, src_slot)``, each a (batch, R) DEVICE array.

    Places every id's FULL R-replica set under table A (version v) and
    table B (version v+1) in one device pass (``diff_replicas_pallas`` /
    the fused jnp reference) and aligns the two sets per slot
    (``_align_replica_sets``): ``moved[b, r]`` iff slot r's owner actually
    changed, ``src`` the vacated v-side node for moved slots, ``dst`` the
    v+1 set, ``src_slot`` the before-set position for rollback.  Nothing
    round-trips through the host -- the replica planner's
    ``plan_replicas_stream`` chains chunks of this with zero syncs
    (DESIGN.md section 10).
    """
    interpret = _default_interpret(interpret)
    ids = jnp.asarray(datum_ids).astype(jnp.uint32)
    n = ids.shape[0]
    if n == 0:
        empty = jnp.zeros((0, n_replicas), dtype=jnp.int32)
        return jnp.zeros((0, n_replicas), dtype=bool), empty, empty, empty
    if use_pallas:
        block = rows_per_block * LANE
        padded = _pad_ids(ids, block)
        sets = diff_replicas_pallas(
            padded,
            len32_a,
            node_a,
            len32_b,
            node_b,
            top_a=top_a,
            top_b=top_b,
            s_log2=params.s_log2,
            max_draws=params.max_draws,
            n_replicas=n_replicas,
            rows_per_block=rows_per_block,
            interpret=interpret,
        )
        before, after = _split_diff_sets(sets, n)
        return _align_replica_sets(before, after, n_replicas=n_replicas)
    return _diff_replicas_fused_ref(
        ids,
        len32_a,
        node_a,
        len32_b,
        node_b,
        top_a=top_a,
        top_b=top_b,
        s_log2=params.s_log2,
        max_draws=params.max_draws,
        n_replicas=n_replicas,
    )


def addition_numbers_on_table_device(
    datum_ids,
    len32: jax.Array,
    node_of: jax.Array,
    *,
    top_level: int,
    n_replicas: int = 1,
    extra_levels: int | None = None,
    params: AsuraParams = DEFAULT_PARAMS,
) -> jax.Array:
    """Device-resident ADDITION NUMBERs against a prebuilt table.

    Runs the trace ``extra_levels`` generator levels ABOVE the entry level
    (default: up to 4, capped by the 2**31 segment-space bound).  Extension
    is how the scalar oracle handles the common "placed on the first draw,
    no anterior number" case, and it is exact here too: by the section 2.B
    invariance the extended stream only INSERTS numbers, every inserted
    number is a miss (its value exceeds every segment), and numbers emitted
    at level l lie in the disjoint range [2**(s+l-1), 2**(s+l)), so the
    minimum unused anterior is unchanged when the unextended trace has one
    and equals the minimally-extended scalar result when it does not.

    -1 marks the remaining lanes (needs more extension than the static
    budget, or non-convergence) -- checking on device would force a sync,
    so callers treat -1 as "candidate", which keeps the prefilter sound.
    Both engine backends route through the jitted jnp reference
    (``addition_numbers_ref``); the trace is metadata work off the
    placement hot path, so it has no Pallas variant.
    """
    if extra_levels is None:
        extra_levels = max(0, min(4, 31 - params.s_log2 - top_level))
    ids = jnp.asarray(datum_ids).astype(jnp.uint32)
    if ids.shape[0] == 0:
        return jnp.zeros((0,), dtype=jnp.int32)
    return addition_numbers_ref(
        ids,
        len32,
        node_of,
        top_level=top_level + extra_levels,
        s_log2=params.s_log2,
        max_draws=params.max_draws,
        n_replicas=n_replicas,
    )


def place_nodes_on_table_device(
    datum_ids,
    len32: jax.Array,
    cum_hi: jax.Array,
    cum_lo: jax.Array,
    node_of: jax.Array,
    **kwargs,
) -> jax.Array:
    """Device-resident placement straight to node ids (fused gather)."""
    return place_on_table_device(
        datum_ids, len32, cum_hi, cum_lo, node_of, emit_nodes=True, **kwargs
    )


def place_on_table(
    datum_ids,
    len32: jax.Array,
    *,
    top_level: int,
    cum_hi: jax.Array | None = None,
    cum_lo: jax.Array | None = None,
    params: AsuraParams = DEFAULT_PARAMS,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> np.ndarray:
    """Placement against a prebuilt (lane-padded) device table -> int64 segs.

    Host-facing: runs the device-resident path (including the on-device
    tail, bit-identical to the NumPy ``place_batch`` fallback) and pays
    exactly one device->host transfer for the final result.  Callers that
    chain into further device work should use ``place_on_table_device``
    instead.  ``cum_hi``/``cum_lo`` are the precomputed tail tables
    (``tail_prep``); if omitted they are derived here (one extra table
    read), which only table-per-call conveniences do.
    """
    if cum_hi is None or cum_lo is None:
        cum_hi, cum_lo = tail_prep(np.asarray(len32))
    segs = place_on_table_device(
        datum_ids,
        len32,
        cum_hi,
        cum_lo,
        top_level=top_level,
        params=params,
        use_pallas=use_pallas,
        interpret=interpret,
        rows_per_block=rows_per_block,
    )
    return np.asarray(segs).astype(np.int64)


def place_replicas_on_table_device(
    datum_ids,
    len32: jax.Array,
    node_of: jax.Array,
    n_replicas: int,
    *,
    top_level: int,
    params: AsuraParams = DEFAULT_PARAMS,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
    emit_nodes: bool = False,
) -> jax.Array:
    """Device-resident replica placement -> (batch, R) int32 device array.

    ``emit_nodes=True`` returns node ids via the fused in-kernel gather
    (primary first).  Non-converged entries stay -1 -- checking would force
    a device->host sync, so the device path documents the marker instead of
    raising; the host wrapper ``place_replicas_on_table`` raises.
    """
    interpret = _default_interpret(interpret)
    ids = jnp.asarray(datum_ids).astype(jnp.uint32)
    n = ids.shape[0]
    if n == 0:
        return jnp.zeros((0, n_replicas), dtype=jnp.int32)
    if use_pallas:
        block = rows_per_block * LANE
        padded = _pad_ids(ids, block)
        out = place_replicas_pallas(
            padded,
            len32,
            node_of,
            top_level=top_level,
            s_log2=params.s_log2,
            max_draws=params.max_draws,
            n_replicas=n_replicas,
            rows_per_block=rows_per_block,
            interpret=interpret,
            emit_nodes=emit_nodes,
        )
        return _head(out, n)
    return _place_replicas_fused_ref(
        ids,
        len32,
        node_of,
        top_level=top_level,
        s_log2=params.s_log2,
        max_draws=params.max_draws,
        n_replicas=n_replicas,
        emit_nodes=emit_nodes,
    )


def place_replicas_on_table(
    datum_ids,
    len32: jax.Array,
    node_of: jax.Array,
    n_replicas: int,
    *,
    top_level: int,
    params: AsuraParams = DEFAULT_PARAMS,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> np.ndarray:
    """Replica placement against a prebuilt table -> (batch, R) int64 segs.

    Raises on non-convergence (more replicas requested than distinct nodes
    can supply within the bounded loop), matching the NumPy batch path.
    """
    result = place_replicas_on_table_device(
        datum_ids,
        len32,
        node_of,
        n_replicas,
        top_level=top_level,
        params=params,
        use_pallas=use_pallas,
        interpret=interpret,
        rows_per_block=rows_per_block,
    )
    out = np.asarray(result).astype(np.int64)
    if (out < 0).any():
        raise RuntimeError("replication did not converge; too few distinct nodes?")
    return out


@functools.partial(jax.jit, static_argnames=("n",))
def _hier_head(out: jax.Array, n: int) -> jax.Array:
    """(2, R, padded) kernel output -> (2, R, n) ON DEVICE."""
    return out[:, :, :n]


def hier_place_replicas_on_tables_device(
    datum_ids,
    tables,
    *,
    top_level: int,
    max_top: int,
    s_pad: int,
    n_replicas: int,
    params: AsuraParams = DEFAULT_PARAMS,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> jax.Array:
    """Fused two-level replication -> (2, R, batch) int32 DEVICE array.

    ``tables`` is the 8-tuple of prebuilt device operands (top length +
    domain-slot tables, stacked per-domain length/node/cumsum tables,
    per-domain top levels and domain ids -- the hierarchical artifact's
    device view).  Plane 0 holds domain ids, plane 1 node ids; -1 marks
    level-1 non-convergence (too few distinct domains).  Zero host syncs.
    """
    interpret = _default_interpret(interpret)
    ids = jnp.asarray(datum_ids).astype(jnp.uint32)
    n = ids.shape[0]
    if n == 0:
        return jnp.zeros((2, n_replicas, 0), dtype=jnp.int32)
    kw = dict(
        top_level=top_level,
        max_top=max_top,
        s_log2=params.s_log2,
        max_draws=params.max_draws,
        s_pad=s_pad,
        n_replicas=n_replicas,
    )
    if use_pallas:
        block = rows_per_block * LANE
        padded = _pad_ids(ids, block)
        out = hier_place_replicas_pallas(
            padded, *tables, rows_per_block=rows_per_block, interpret=interpret, **kw
        )
        return _hier_head(out, n)
    return hier_place_replicas_ref(ids, *tables, **kw)


def hier_place_replicas_on_tables(datum_ids, tables, **kwargs) -> np.ndarray:
    """Host wrapper -> (batch, R, 2) int64 [domain, node] pairs.

    Raises on level-1 non-convergence, matching the oracle's
    ``place_replicas_u32`` behaviour (more replicas than distinct domains).
    """
    out = np.asarray(hier_place_replicas_on_tables_device(datum_ids, tables, **kwargs))
    if (out[0] < 0).any():
        raise RuntimeError(
            "hierarchical replication did not converge; too few distinct domains?"
        )
    return out.transpose(2, 1, 0).astype(np.int64)


@functools.partial(jax.jit, static_argnames=("n_replicas",))
def _hier_align(before: jax.Array, after: jax.Array, *, n_replicas: int):
    """Align two (2, R, batch) two-level placements on their NODE plane.

    Node ids are globally unique across domains (the hierarchical engine
    validates this), so the flat rank-matched alignment applies unchanged;
    the domain planes ride along: ``src_dom[b, r]`` is the vacated node's
    domain under v (gathered at ``src_slot``), ``dst_dom`` the v+1 set's
    domains.  Returns ``(moved, src, dst, src_slot, src_dom, dst_dom)``.
    """
    b_dom, b_node = before[0].T, before[1].T
    a_dom, a_node = after[0].T, after[1].T
    moved, src, dst, src_slot = _align_replica_sets(
        b_node, a_node, n_replicas=n_replicas
    )
    src_dom = jnp.take_along_axis(b_dom.astype(jnp.int32), src_slot, axis=1)
    dst_dom = a_dom.astype(jnp.int32)
    src_dom = jnp.where(moved, src_dom, dst_dom)
    return moved, src, dst, src_slot, src_dom, dst_dom


def hier_diff_replicas_on_tables_device(
    datum_ids,
    tables_a,
    tables_b,
    *,
    statics_a: tuple,
    statics_b: tuple,
    n_replicas: int,
    params: AsuraParams = DEFAULT_PARAMS,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
):
    """Two-level replica-set version diff, both levels under both versions.

    ``statics_*`` are ``(top_level, max_top, s_pad)`` per version (the two
    artifacts' static shape keys).  Places every id's full (domain, node)
    R-set under v and v+1 with the fused two-level pass each, then aligns
    on the node plane -- ``(moved, src, dst, src_slot, src_dom, dst_dom)``,
    all (batch, R) device arrays, zero host syncs.
    """
    ids = jnp.asarray(datum_ids).astype(jnp.uint32)
    if ids.shape[0] == 0:
        empty = jnp.zeros((0, n_replicas), dtype=jnp.int32)
        return (
            jnp.zeros((0, n_replicas), dtype=bool),
            empty, empty, empty, empty, empty,
        )
    kw = dict(
        n_replicas=n_replicas,
        params=params,
        use_pallas=use_pallas,
        interpret=interpret,
        rows_per_block=rows_per_block,
    )
    top_a, max_a, pad_a = statics_a
    top_b, max_b, pad_b = statics_b
    before = hier_place_replicas_on_tables_device(
        ids, tables_a, top_level=top_a, max_top=max_a, s_pad=pad_a, **kw
    )
    after = hier_place_replicas_on_tables_device(
        ids, tables_b, top_level=top_b, max_top=max_b, s_pad=pad_b, **kw
    )
    return _hier_align(before, after, n_replicas=n_replicas)


def asura_place(
    datum_ids,
    seg_lengths,
    params: AsuraParams = DEFAULT_PARAMS,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> jax.Array:
    """Place a batch of datum ids -> int32 segment numbers (device array).

    use_pallas=False routes through the pure-jnp reference (place_ref) --
    the path the distributed pipeline uses on CPU hosts; the Pallas path is
    the TPU fast path (validated bit-identical in tests/test_kernels.py).
    The result is total (on-device tail) and stays on device -- no host
    round trip, no result re-upload.
    """
    len32, top_level = table_prep(seg_lengths, params)
    cum_hi, cum_lo = tail_prep(len32)
    return place_on_table_device(
        datum_ids,
        len32,
        cum_hi,
        cum_lo,
        top_level=top_level,
        params=params,
        use_pallas=use_pallas,
        interpret=interpret,
        rows_per_block=rows_per_block,
    )


def asura_place_nodes(
    datum_ids,
    seg_lengths,
    seg_to_node,
    params: AsuraParams = DEFAULT_PARAMS,
    **kwargs,
) -> jax.Array:
    """Batch placement straight to node ids (fused gather, device array)."""
    len32, top_level = table_prep(seg_lengths, params)
    cum_hi, cum_lo = tail_prep(len32)
    node_of = node_table_prep(seg_to_node)
    return place_nodes_on_table_device(
        datum_ids,
        len32,
        cum_hi,
        cum_lo,
        node_of,
        top_level=top_level,
        params=params,
        **kwargs,
    )


def asura_place_replicas(
    datum_ids,
    seg_lengths,
    seg_to_node,
    n_replicas: int,
    params: AsuraParams = DEFAULT_PARAMS,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> jax.Array:
    """Replica placement -> (batch, R) int32 segment numbers, primary first."""
    len32, top_level = table_prep(seg_lengths, params)
    node_of = node_table_prep(seg_to_node)
    segs = place_replicas_on_table(
        datum_ids,
        len32,
        node_of,
        n_replicas,
        top_level=top_level,
        params=params,
        use_pallas=use_pallas,
        interpret=interpret,
        rows_per_block=rows_per_block,
    )
    return jnp.asarray(segs.astype(np.int32))
