"""Jitted public wrapper for batched ASURA placement.

``asura_place`` pads the id vector / segment table, dispatches to the Pallas
kernel (interpret mode on CPU, compiled on TPU), resolves the p < 2**-53
non-converged tail with a uniform draw over occupied mass (totality without
sacrificing uniformity), and unpads.  ``asura_place_nodes`` additionally maps
segments -> node ids.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.asura import DEFAULT_PARAMS, AsuraParams, _upper_bound

from .asura_place import DEFAULT_ROWS, LANE, place_pallas
from .ref import draw_u32, place_ref


def _pad_to(x: jax.Array, multiple: int, fill) -> jax.Array:
    pad = (-x.shape[0]) % multiple
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), fill, dtype=x.dtype)])


def _resolve_tail(ids, result, len32):
    """Uniform-over-occupied-mass fallback for non-converged lanes."""
    mass = jnp.cumsum(len32.astype(jnp.float32) * jnp.float32(2.0**-32))
    u = (
        draw_u32(ids, 40, jnp.zeros_like(ids)).astype(jnp.float32)
        * jnp.float32(2.0**-32)
        * mass[-1]
    )
    fallback = jnp.searchsorted(mass, u, side="right").astype(jnp.int32)
    return jnp.where(result < 0, fallback, result)


def table_prep(seg_lengths, params: AsuraParams = DEFAULT_PARAMS):
    """Host-side: canonical u32 table (lane-padded) + static top level."""
    lengths = np.asarray(seg_lengths, dtype=np.float64)
    top_level = params.level_for(_upper_bound(lengths))
    len32 = np.minimum(np.round(lengths * 2.0**32), 2.0**32 - 1).astype(np.uint32)
    pad = (-len32.shape[0]) % LANE
    if pad:
        len32 = np.concatenate([len32, np.zeros(pad, dtype=np.uint32)])
    return jnp.asarray(len32), top_level


def asura_place(
    datum_ids,
    seg_lengths,
    params: AsuraParams = DEFAULT_PARAMS,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> jax.Array:
    """Place a batch of datum ids -> int32 segment numbers.

    use_pallas=False routes through the pure-jnp reference (place_ref) --
    the path the distributed pipeline uses on CPU hosts; the Pallas path is
    the TPU fast path (validated bit-identical in tests/test_kernels.py).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    ids = jnp.asarray(datum_ids).astype(jnp.uint32)
    n = ids.shape[0]
    len32, top_level = table_prep(seg_lengths, params)
    if use_pallas:
        block = rows_per_block * LANE
        padded = _pad_to(ids, block, 0)
        result = place_pallas(
            padded,
            len32,
            top_level=top_level,
            s_log2=params.s_log2,
            max_draws=params.max_draws,
            rows_per_block=rows_per_block,
            interpret=interpret,
        )[:n]
    else:
        result = place_ref(
            ids,
            len32,
            top_level=top_level,
            s_log2=params.s_log2,
            max_draws=params.max_draws,
        )
    return _resolve_tail(ids, result, len32)


def asura_place_nodes(
    datum_ids,
    seg_lengths,
    seg_to_node,
    params: AsuraParams = DEFAULT_PARAMS,
    **kwargs,
) -> jax.Array:
    segs = asura_place(datum_ids, seg_lengths, params, **kwargs)
    return jnp.asarray(np.asarray(seg_to_node, dtype=np.int32))[segs]
