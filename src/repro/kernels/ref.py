"""Pure-jnp oracle for the batched ASURA placement kernel.

Bit-identical to ``repro.core.asura.place_batch`` (NumPy) and to the Pallas
kernel in ``asura_place.py`` -- all three use the exact integer formulation
(uint32 draws, MSB descend test, shift-based floor/fraction).  Tested against
both in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

GOLDEN = 0x9E3779B9
KMULT = 0x85EBCA77
MSB = jnp.uint32(0x80000000)


def fmix32(h: jax.Array) -> jax.Array:
    """MurmurHash3 finalizer on uint32 lanes."""
    h = h.astype(jnp.uint32)
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def draw_u32(ids: jax.Array, level: int, counters: jax.Array) -> jax.Array:
    """k-th raw draw of the level-``level`` generator (counter-based)."""
    lvl_term = jnp.uint32((GOLDEN * (level + 1)) & 0xFFFFFFFF)
    seed = fmix32(ids.astype(jnp.uint32) + lvl_term)
    return fmix32(seed ^ (counters.astype(jnp.uint32) * jnp.uint32(KMULT)))


def next_asura(ids, counters, top_level: int, s_log2: int):
    """One ASURA number per lane as (k:int32, frac32:uint32, new_counters).

    counters: (top_level + 1, batch) uint32; row r is the counter of level
    ``top_level - r`` (row 0 = top).
    """
    batch = ids.shape[0]
    consult = jnp.ones((batch,), dtype=bool)
    out_k = jnp.zeros((batch,), dtype=jnp.int32)
    out_f = jnp.zeros((batch,), dtype=jnp.uint32)
    rows = []
    for level in range(top_level, -1, -1):
        row = top_level - level
        h = draw_u32(ids, level, counters[row])
        rows.append(counters[row] + consult.astype(jnp.uint32))
        descend = consult & (level > 0) & ((h & MSB) == 0)
        emit = consult & ~descend
        k = (h >> jnp.uint32(32 - s_log2 - level)).astype(jnp.int32)
        f = h << jnp.uint32(s_log2 + level)
        out_k = jnp.where(emit, k, out_k)
        out_f = jnp.where(emit, f, out_f)
        consult = descend
    return out_k, out_f, jnp.stack(rows)


@functools.partial(jax.jit, static_argnames=("top_level", "s_log2", "max_draws"))
def place_ref(
    ids: jax.Array,
    len32: jax.Array,
    *,
    top_level: int,
    s_log2: int = 1,
    max_draws: int = 128,
) -> jax.Array:
    """Batched STEP 2 -> int32 segment numbers (-1 if not converged).

    ids: (batch,) uint32 datum ids.
    len32: (n_segs,) uint32 canonical segment lengths (round(len * 2**32)).
    """
    ids = ids.astype(jnp.uint32)
    n_segs = len32.shape[0]
    batch = ids.shape[0]

    def cond(state):
        i, _, _, done = state
        return (i < max_draws) & ~jnp.all(done)

    def body(state):
        i, counters, result, done = state
        k, f, counters = next_asura(ids, counters, top_level, s_log2)
        k_safe = jnp.minimum(k, n_segs - 1)
        hit = (~done) & (k < n_segs) & (f < len32[k_safe])
        result = jnp.where(hit, k, result)
        return i + 1, counters, result, done | hit

    counters0 = jnp.zeros((top_level + 1, batch), dtype=jnp.uint32)
    result0 = jnp.full((batch,), -1, dtype=jnp.int32)
    done0 = jnp.zeros((batch,), dtype=bool)
    _, _, result, _ = jax.lax.while_loop(cond, body, (0, counters0, result0, done0))
    return result


@functools.partial(
    jax.jit, static_argnames=("top_level", "s_log2", "max_draws", "n_replicas")
)
def place_replicas_ref(
    ids: jax.Array,
    len32: jax.Array,
    node_of: jax.Array,
    *,
    top_level: int,
    s_log2: int = 1,
    max_draws: int = 128,
    n_replicas: int = 1,
) -> jax.Array:
    """Batched section 5.A replication -> (batch, R) int32 segment numbers.

    First column is the primary; the R draws hit distinct *nodes* (checked
    against the nodes of already-picked replicas, carried in-register so the
    dup test costs no extra table gather).  -1 marks lanes that did not
    converge (the wrapper raises).  Bit-identical to
    ``repro.core.asura.place_replicas_scalar`` lane-by-lane (tested).
    """
    ids = ids.astype(jnp.uint32)
    n_segs = len32.shape[0]
    batch = ids.shape[0]
    R = n_replicas

    def cond(state):
        i, _, _, _, found = state
        return (i < max_draws * max(1, R)) & ~jnp.all(found >= R)

    def body(state):
        i, counters, segs, nodes, found = state
        k, f, counters = next_asura(ids, counters, top_level, s_log2)
        k_safe = jnp.minimum(k, n_segs - 1)
        hit = (found < R) & (k < n_segs) & (f < len32[k_safe])
        node_k = node_of[k_safe]
        dup = jnp.zeros((batch,), dtype=bool)
        for r in range(R):
            dup |= (nodes[r] >= 0) & (nodes[r] == node_k)
        take = hit & ~dup
        segs = jnp.stack(
            [jnp.where(take & (found == r), k, segs[r]) for r in range(R)]
        )
        nodes = jnp.stack(
            [jnp.where(take & (found == r), node_k, nodes[r]) for r in range(R)]
        )
        return i + 1, counters, segs, nodes, found + take.astype(jnp.int32)

    counters0 = jnp.zeros((top_level + 1, batch), dtype=jnp.uint32)
    segs0 = jnp.full((R, batch), -1, dtype=jnp.int32)
    nodes0 = jnp.full((R, batch), -1, dtype=jnp.int32)
    found0 = jnp.zeros((batch,), dtype=jnp.int32)
    _, _, segs, _, _ = jax.lax.while_loop(
        cond, body, (0, counters0, segs0, nodes0, found0)
    )
    return segs.T
