"""Pure-jnp oracle for the batched ASURA placement kernel.

Bit-identical to ``repro.core.asura.place_batch`` (NumPy) and to the Pallas
kernel in ``asura_place.py`` -- all three use the exact integer formulation
(uint32 draws, MSB descend test, shift-based floor/fraction).  Tested against
both in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

GOLDEN = 0x9E3779B9
KMULT = 0x85EBCA77
# Ladder-depth histogram width (obs device plane): a draw's depth is
# ``top_level - exit_level + 1`` in [1, top_level + 1], and the shift
# construction bounds top_level <= 32 - s_log2, so 34 bins (clipped)
# cover every reachable depth at any parameterization.
DEPTH_BINS = 34
# NOTE: no module-level jnp constants here -- this module's helpers run
# inside Pallas kernels, which reject captured device arrays.


def fmix32(h: jax.Array) -> jax.Array:
    """MurmurHash3 finalizer on uint32 lanes."""
    h = h.astype(jnp.uint32)
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def draw_u32(ids: jax.Array, level, counters: jax.Array) -> jax.Array:
    """k-th raw draw of the level-``level`` generator (counter-based).

    ``level`` may be a static int or a traced scalar (the lazy-depth ladder
    walks levels inside a ``lax.while_loop``); uint32 wrap-around
    multiplication matches the static ``(GOLDEN * (level+1)) & 0xFFFFFFFF``.
    """
    lvl = jnp.asarray(level).astype(jnp.uint32)
    lvl_term = jnp.uint32(GOLDEN) * (lvl + jnp.uint32(1))
    seed = fmix32(ids.astype(jnp.uint32) + lvl_term)
    return fmix32(seed ^ (counters.astype(jnp.uint32) * jnp.uint32(KMULT)))


def next_asura(
    ids,
    counters,
    top_level: int,
    s_log2: int,
    emit_depth: bool = False,
    active=None,
):
    """One ASURA number per lane as (k:int32, frac32:uint32, new_counters).

    counters: (top_level + 1, ...) uint32; row r is the counter of level
    ``top_level - r`` (row 0 = top).  ``ids`` may be any shape (1-D batch
    here, (rows, 128) tiles in the Pallas kernels); counters carry one
    leading level axis over it.

    Lazy-depth ladder (DESIGN.md section 3.4): every lane starts at
    ``top_level`` and lanes descend in lockstep one level per iteration, so
    all still-consulting lanes sit at the SAME level and the ladder is a
    ``lax.while_loop`` over a scalar level that exits as soon as no lane is
    still consulting -- expected 2 iterations (the descend test is a coin
    flip), not ``top_level + 1``.  Counter rows are read/updated through
    dynamic indexing at the one consulted level, so the loop-carried state
    is the counter array plus O(1) scalars instead of one rebuilt counter
    tensor per unrolled level.  Draw order and counter ticks are
    bit-identical to the unrolled ladder and the scalar oracle (tested).

    ``emit_depth=True`` additionally returns the per-lane consulted depth
    (``top_level - exit_level + 1``, int32) as a fourth output -- the obs
    device plane's ladder-depth histogram source.  The k/frac/counter
    stream is bit-identical either way (the extra ``where`` only feeds
    the depth output; tested in tests/test_obs.py).

    ``active`` (optional bool mask over ``ids``) gates the counter TICK
    only: inactive lanes still draw (their k/f outputs are garbage the
    caller ignores) but leave their counters frozen.  The replica loop
    uses this to keep satisfied lanes' lockstep dead draws out of the
    derived depth histogram -- the gate rides the existing one-row
    counter update, so it costs O(batch) per consulted level instead of
    an O(levels x batch) select per draw.  Active lanes' streams are
    unaffected (lanes never read each other's counters).
    """
    shape = ids.shape
    # NOTE: constants below are created inside the traced function (not
    # module-level jnp arrays) so this helper can run inside Pallas kernels.

    def cond(state):
        consult = state[1]
        return jnp.any(consult)

    def body(state):
        if emit_depth:
            level, consult, out_k, out_f, out_d, ctrs = state
        else:
            level, consult, out_k, out_f, ctrs = state
        row = top_level - level
        ctr = jax.lax.dynamic_index_in_dim(ctrs, row, 0, keepdims=False)
        h = draw_u32(ids, level, ctr)
        tick = consult if active is None else consult & active
        ctrs = jax.lax.dynamic_update_index_in_dim(
            ctrs, ctr + tick.astype(jnp.uint32), row, 0
        )
        descend = consult & (level > 0) & ((h & jnp.uint32(0x80000000)) == 0)
        emit = consult & ~descend
        lvl = level.astype(jnp.uint32)
        k = (h >> (jnp.uint32(32 - s_log2) - lvl)).astype(jnp.int32)
        f = h << (jnp.uint32(s_log2) + lvl)
        out_k = jnp.where(emit, k, out_k)
        out_f = jnp.where(emit, f, out_f)
        if emit_depth:
            out_d = jnp.where(emit, jnp.int32(top_level) - level + 1, out_d)
            return level - 1, descend, out_k, out_f, out_d, ctrs
        return level - 1, descend, out_k, out_f, ctrs

    state = (
        jnp.int32(top_level),
        jnp.ones(shape, dtype=bool),
        jnp.zeros(shape, dtype=jnp.int32),
        jnp.zeros(shape, dtype=jnp.uint32),
        *((jnp.zeros(shape, dtype=jnp.int32),) if emit_depth else ()),
        counters,
    )
    out = jax.lax.while_loop(cond, body, state)
    if emit_depth:
        _, _, out_k, out_f, out_d, counters = out
        return out_k, out_f, counters, out_d
    _, _, out_k, out_f, counters = out
    return out_k, out_f, counters


def mul32_wide(a: jax.Array, b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Full 32x32 -> 64 bit product as (hi, lo) uint32 pairs.

    TPUs have no native u64, so the 64-bit product is assembled from 16-bit
    limbs; ``t`` (the carry column) fits uint32 by construction.
    """
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    m16 = jnp.uint32(0xFFFF)
    a_lo, a_hi = a & m16, a >> jnp.uint32(16)
    b_lo, b_hi = b & m16, b >> jnp.uint32(16)
    ll = a_lo * b_lo
    lh = a_lo * b_hi
    hl = a_hi * b_lo
    hh = a_hi * b_hi
    t = (ll >> jnp.uint32(16)) + (lh & m16) + (hl & m16)
    lo = (t << jnp.uint32(16)) | (ll & m16)
    hi = hh + (lh >> jnp.uint32(16)) + (hl >> jnp.uint32(16)) + (t >> jnp.uint32(16))
    return hi, lo


def resolve_tail_dev(
    ids: jax.Array,
    segs: jax.Array,
    cum_hi: jax.Array,
    cum_lo: jax.Array,
    top_level: int,
) -> jax.Array:
    """Device-resident non-converged-tail fallback (DESIGN.md section 3.2).

    Bit-identical to ``repro.core.asura.resolve_tail_np``: lanes with
    ``segs < 0`` get one raw draw h at level ``top_level + 1`` (counter 0),
    scaled by the exact total occupied mass T via
    ``u = h*(T>>32) + ((h*(T&0xFFFFFFFF))>>32)`` (the 95-bit product split
    through ``mul32_wide``), then mapped to the segment whose inclusive u64
    cumsum first exceeds u -- a branchless per-lane binary search over the
    (cum_hi, cum_lo) halves, so no u64 and no host round trip.  Trailing
    zero-length padding (cumsum == T > u) never wins.  The whole fallback is
    gated behind ``lax.cond`` on any lane missing, so the p < 2**-53 common
    case pays one reduction only.  Runs in plain jit and inside Pallas
    kernels (all constants are trace-time).
    """
    n_pad = cum_hi.shape[0]
    shape = ids.shape
    miss = segs < 0

    def tail(_):
        h = draw_u32(ids, top_level + 1, jnp.zeros(shape, dtype=jnp.uint32))
        t_hi = cum_hi[n_pad - 1]
        t_lo = cum_lo[n_pad - 1]
        p1_hi, p1_lo = mul32_wide(h, t_hi)
        p2_hi, _ = mul32_wide(h, t_lo)
        u_lo = p1_lo + p2_hi
        u_hi = p1_hi + (u_lo < p1_lo).astype(jnp.uint32)
        # searchsorted(cum, u, side="right"): first index with cum[idx] > u.
        lo = jnp.zeros(shape, dtype=jnp.int32)
        hi = jnp.full(shape, n_pad, dtype=jnp.int32)
        for _step in range(max(1, int(n_pad).bit_length())):
            active = lo < hi
            mid = jnp.minimum((lo + hi) >> 1, n_pad - 1)
            c_hi = jnp.take(cum_hi, mid.reshape(-1), axis=0).reshape(shape)
            c_lo = jnp.take(cum_lo, mid.reshape(-1), axis=0).reshape(shape)
            le = (c_hi < u_hi) | ((c_hi == u_hi) & (c_lo <= u_lo))  # cum<=u
            lo = jnp.where(active & le, mid + 1, lo)
            hi = jnp.where(active & ~le, mid, hi)
        return lo

    tail_seg = jax.lax.cond(
        jnp.any(miss), tail, lambda _: jnp.zeros(shape, dtype=jnp.int32), None
    )
    return jnp.where(miss, tail_seg, segs)


# Full-width draws before the bulk place loop compacts its stragglers
# (below).  After p draws a lane survives with probability ~(1-fill)^p,
# so 4 leaves ~6% of lanes at the half-full tables every post-add
# version has -- inside the batch/8 straggler block with 2x margin.
_PREFIX_DRAWS = 4


@functools.partial(jax.jit, static_argnames=("top_level", "s_log2", "max_draws"))
def place_ref(
    ids: jax.Array,
    len32: jax.Array,
    *,
    top_level: int,
    s_log2: int = 1,
    max_draws: int = 128,
) -> jax.Array:
    """Batched STEP 2 -> int32 segment numbers (-1 if not converged).

    ids: (batch,) uint32 datum ids.
    len32: (n_segs,) uint32 canonical segment lengths (round(len * 2**32)).

    Draw-loop schedule: a lockstep while_loop pays every draw over the
    FULL batch even though per-lane draw counts are geometric (E[draws]
    = 1/fill); on a half-full table (every post-add/remove version) the
    all-lanes-converged exit trails the typical lane by ~10 draws, so
    the naive loop does ~9x the useful hash work.  After
    ``_PREFIX_DRAWS`` full-width draws the surviving lanes are compacted
    (cumsum scatter) into a ``batch/8`` straggler block that finishes
    narrow; a guard falls back to the full-width loop if the stragglers
    ever overflow the block (pathologically sparse tables).  Per-lane
    draw sequences are pure functions of the lane's id, so compaction
    changes nothing a lane computes -- results are bit-identical to the
    uncompacted loop (tested against the scalar oracle).
    """
    ids = ids.astype(jnp.uint32)
    n_segs = len32.shape[0]
    batch = ids.shape[0]

    def cond(state):
        i, _, _, done = state
        return (i < max_draws) & ~jnp.all(done)

    def mk_body(lane_ids):
        def body(state):
            i, counters, result, done = state
            k, f, counters = next_asura(lane_ids, counters, top_level, s_log2)
            k_safe = jnp.minimum(k, n_segs - 1)
            hit = (~done) & (k < n_segs) & (f < len32[k_safe])
            result = jnp.where(hit, k, result)
            return i + 1, counters, result, done | hit

        return body

    body = mk_body(ids)
    state = (
        0,
        jnp.zeros((top_level + 1, batch), dtype=jnp.uint32),
        jnp.full((batch,), -1, dtype=jnp.int32),
        jnp.zeros((batch,), dtype=bool),
    )
    w = batch >> 3
    if w < 64 or max_draws <= _PREFIX_DRAWS:
        # small batches: compaction overhead beats the tail waste
        _, _, result, _ = jax.lax.while_loop(cond, body, state)
        return result

    def prefix_cond(state):
        i, _, _, done = state
        return (i < _PREFIX_DRAWS) & ~jnp.all(done)

    state = jax.lax.while_loop(prefix_cond, body, state)
    n_live = jnp.sum((~state[3]).astype(jnp.int32))

    def narrow(state):
        i, counters, result, done = state
        live = ~done
        pos = jnp.cumsum(live.astype(jnp.int32)) - 1
        slot = jnp.where(live, pos, w)  # dead lanes -> OOB, dropped
        idx = (
            jnp.zeros((w,), dtype=jnp.int32)
            .at[slot]
            .set(jnp.arange(batch, dtype=jnp.int32), mode="drop")
        )
        # unused slots hold lane 0: duplicates recompute lane 0's exact
        # draw sequence, so the write-back scatter is value-unique
        sub = (i, counters[:, idx], result[idx], done[idx])
        _, _, sub_result, _ = jax.lax.while_loop(cond, mk_body(ids[idx]), sub)
        return result.at[idx].set(sub_result)

    def full(state):
        _, _, result, _ = jax.lax.while_loop(cond, body, state)
        return result

    return jax.lax.cond(n_live <= w, narrow, full, state)


@functools.partial(
    jax.jit, static_argnames=("top_level", "s_log2", "max_draws", "n_replicas")
)
def addition_numbers_ref(
    ids: jax.Array,
    len32: jax.Array,
    node_of: jax.Array,
    *,
    top_level: int,
    s_log2: int = 1,
    max_draws: int = 128,
    n_replicas: int = 1,
) -> jax.Array:
    """Device-resident section 2.D ADDITION NUMBER -> (batch,) int32.

    The migration planner's prefilter variant of
    ``repro.core.asura.addition_numbers_batch``: every lane runs the bounded
    replica trace on device, tracking the minimum *unused* anterior ASURA
    number as an exact ``(k, frac32)`` lexicographic pair (no u64 needed, so
    it runs on TPUs).  Where the NumPy batch falls back to the exact scalar
    oracle (non-convergence, or the rare range-extension case where every
    anterior number was used), this returns ``-1`` -- checking would force a
    host sync.  ``-1`` means "unknown: treat as a candidate", which keeps
    the AN <= f prefilter sound (DESIGN.md sections 7, 8); lanes with a
    definite result are bit-identical to the NumPy batch (tested).
    """
    ids = ids.astype(jnp.uint32)
    n_segs = len32.shape[0]
    batch = ids.shape[0]
    R = n_replicas
    NO_K = jnp.int32(0x7FFFFFFF)  # above any reachable k (k < 2**(s+top))

    def cond(state):
        i, _, _, found, _, _ = state
        return (i < max_draws * max(1, R)) & ~jnp.all(found >= R)

    def body(state):
        i, counters, nodes, found, min_k, min_f = state
        k, f, counters = next_asura(ids, counters, top_level, s_log2)
        k_safe = jnp.minimum(k, n_segs - 1)
        hit = (k < n_segs) & (f < len32[k_safe])
        node_k = node_of[k_safe]
        dup = jnp.zeros((batch,), dtype=bool)
        for r in range(R):
            dup |= (nodes[r] >= 0) & (nodes[r] == node_k)
        active = found < R
        used = active & hit & ~dup
        unused = active & ~used
        better = unused & ((k < min_k) | ((k == min_k) & (f < min_f)))
        min_k = jnp.where(better, k, min_k)
        min_f = jnp.where(better, f, min_f)
        nodes = jnp.stack(
            [jnp.where(used & (found == r), node_k, nodes[r]) for r in range(R)]
        )
        return i + 1, counters, nodes, found + used.astype(jnp.int32), min_k, min_f

    counters0 = jnp.zeros((top_level + 1, batch), dtype=jnp.uint32)
    nodes0 = jnp.full((R, batch), -1, dtype=jnp.int32)
    found0 = jnp.zeros((batch,), dtype=jnp.int32)
    min_k0 = jnp.full((batch,), NO_K, dtype=jnp.int32)
    min_f0 = jnp.zeros((batch,), dtype=jnp.uint32)
    _, _, _, found, min_k, _ = jax.lax.while_loop(
        cond, body, (0, counters0, nodes0, found0, min_k0, min_f0)
    )
    return jnp.where((found >= R) & (min_k != NO_K), min_k, jnp.int32(-1))


@functools.partial(
    jax.jit,
    static_argnames=("top_level", "s_log2", "max_draws", "n_replicas", "emit_stats"),
)
def place_replicas_ref(
    ids: jax.Array,
    len32: jax.Array,
    node_of: jax.Array,
    *,
    top_level: int,
    s_log2: int = 1,
    max_draws: int = 128,
    n_replicas: int = 1,
    emit_stats: bool = False,
):
    """Batched section 5.A replication -> (batch, R) int32 segment numbers.

    First column is the primary; the R draws hit distinct *nodes* (checked
    against the nodes of already-picked replicas, carried in-register so the
    dup test costs no extra table gather).  -1 marks lanes that did not
    converge (the wrapper raises).  Bit-identical to
    ``repro.core.asura.place_replicas_scalar`` lane-by-lane (tested).

    ``emit_stats=True`` returns ``(segs, depth_hist)`` where ``depth_hist``
    is the (DEPTH_BINS,) uint32 consulted-ladder-depth histogram over every
    draw each lane issued while still seeking replicas -- the obs device
    plane's view of how much ladder work the batch cost.  It is DERIVED
    from the final draw counters rather than accumulated per draw: row
    ``r`` of the counter array ticks once for every draw that consulted
    level ``top_level - r``, i.e. every draw of depth >= r + 1, so the
    histogram is the first difference of the per-row counter sums -- one
    reduction after the loop plus a lane-liveness gate folded into the
    existing one-row counter tick (the <= 1.05x overhead ceiling rules
    out both an in-loop scatter and a full-array counter select).
    Satisfied lanes' counters freeze so the histogram is a function of
    each lane's id alone -- summing per-shard histograms of any partition
    of a batch is bit-identical to the unsharded histogram (the sharded
    snapshot merge relies on this).  The placement stream is bit-identical
    either way (frozen lanes are inert: ``take`` requires ``found < R``).
    """
    ids = ids.astype(jnp.uint32)
    n_segs = len32.shape[0]
    batch = ids.shape[0]
    R = n_replicas

    def cond(state):
        i, found = state[0], state[4]
        return (i < max_draws * max(1, R)) & ~jnp.all(found >= R)

    def body(state):
        i, counters, segs, nodes, found = state
        # With stats on, satisfied lanes stop ticking their counters: the
        # lockstep dead draws they keep issuing depend on the slowest lane
        # IN THIS BATCH, so counting them would make the derived histogram
        # depend on how a stream is sharded.  A frozen lane is inert for
        # placement either way (``take`` requires ``found < R``), so the
        # segment stream is bit-identical with or without stats.
        k, f, counters = next_asura(
            ids,
            counters,
            top_level,
            s_log2,
            active=(found < R) if emit_stats else None,
        )
        k_safe = jnp.minimum(k, n_segs - 1)
        hit = (found < R) & (k < n_segs) & (f < len32[k_safe])
        node_k = node_of[k_safe]
        dup = jnp.zeros((batch,), dtype=bool)
        for r in range(R):
            dup |= (nodes[r] >= 0) & (nodes[r] == node_k)
        take = hit & ~dup
        segs = jnp.stack(
            [jnp.where(take & (found == r), k, segs[r]) for r in range(R)]
        )
        nodes = jnp.stack(
            [jnp.where(take & (found == r), node_k, nodes[r]) for r in range(R)]
        )
        found = found + take.astype(jnp.int32)
        return i + 1, counters, segs, nodes, found

    counters0 = jnp.zeros((top_level + 1, batch), dtype=jnp.uint32)
    segs0 = jnp.full((R, batch), -1, dtype=jnp.int32)
    nodes0 = jnp.full((R, batch), -1, dtype=jnp.int32)
    found0 = jnp.zeros((batch,), dtype=jnp.int32)
    _, counters, segs, _, _ = jax.lax.while_loop(
        cond, body, (0, counters0, segs0, nodes0, found0)
    )
    if emit_stats:
        # cnt[r] = draws of depth >= r + 1; hist[d] = cnt[d-1] - cnt[d]
        cnt = jnp.sum(counters, axis=1, dtype=jnp.uint32)
        cnt = jnp.concatenate([cnt, jnp.zeros((1,), dtype=jnp.uint32)])
        dh = jnp.zeros((DEPTH_BINS,), dtype=jnp.uint32)
        dh = dh.at[1 : top_level + 2].set(cnt[:-1] - cnt[1:])
        return segs.T, dh
    return segs.T
