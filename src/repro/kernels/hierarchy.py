"""Fused two-level (failure-domain-aware) ASURA placement kernels.

``core.hierarchy.HierarchicalCluster`` places a datum in two ASURA steps:
the section-5.A distinct-replica draw over the DOMAIN cluster (racks /
zones, capacity = the domain's node sum), then a salted per-domain draw
over that domain's own node cluster.  The host oracle runs the second step
domain-by-domain; the kernels here run BOTH levels for a whole id batch in
one zero-host-sync pass, bit-identical to the oracle (tested for R in
{1, 2, 3}, ref and Pallas).

The device layout (built by the engine, DESIGN.md section 14):

  * the top level is an ordinary segment table whose "node ids" are DENSE
    DOMAIN SLOTS (0..D-1), so the section-5.A tile body is reused verbatim
    -- distinct slots are distinct domains,
  * the D per-domain tables are stacked into flat ``(D * s_pad,)`` arrays
    (lengths zero-padded, seg->node padded -1, u64-cumsum halves carried
    at the domain total through the padding), gathered at
    ``slot * s_pad + k`` -- ragged domains, one VMEM operand each,
  * per-domain top levels ride as a ``(D,)`` vector: ``next_asura_vartop``
    is the per-LANE descend ladder -- the scalar level descends in
    lockstep from ``max_top`` and a lane joins when the level reaches ITS
    domain's top, which reproduces that lane's solo stream exactly
    (draws are a function of (id, level, counter[level]) only),
  * the salted second-level id is ``fmix32(id ^ domain_id * GOLDEN)``,
    matching ``HierarchicalCluster._salt`` (uint32 wrap-around), and the
    non-converged tail resolves per lane against the owning domain's
    cumsum row (``resolve_tail_vartop``).

Outputs are ``(2, R, batch)``: plane 0 the domain ids, plane 1 the node
ids; -1 marks lanes whose level-1 replica draw did not converge (too few
distinct domains -- the host wrapper raises, matching the oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .asura_place import DEFAULT_ROWS, LANE, _place_replicas_tile
from .ref import GOLDEN, draw_u32, fmix32, mul32_wide


def next_asura_vartop(ids, counters, lane_top, max_top: int, s_log2: int):
    """One ASURA number per lane with a PER-LANE top level.

    ``counters``: (max_top + 1, ...) uint32, row L = the counter of level
    L (levels index rows directly -- unlike ``next_asura``'s top-relative
    rows -- so lanes with different tops share one array).  ``lane_top``:
    int32 per-lane start level, <= ``max_top`` (static).

    The scalar level descends in lockstep from ``max_top``; a lane
    consults only once the level has reached its own top and it has not
    yet emitted.  Per lane this is bit-identical to ``next_asura`` run at
    that lane's top: each draw is a function of (id, level, counter[level])
    alone, and the sequence of consulted levels from ``lane_top`` down is
    unchanged by the extra idle iterations above it.
    """
    shape = ids.shape

    def cond(state):
        level, emitted = state[0], state[1]
        return (level >= 0) & ~jnp.all(emitted)

    def body(state):
        level, emitted, out_k, out_f, ctrs = state
        consult = ~emitted & (level <= lane_top)
        ctr = jax.lax.dynamic_index_in_dim(ctrs, level, 0, keepdims=False)
        h = draw_u32(ids, level, ctr)
        ctrs = jax.lax.dynamic_update_index_in_dim(
            ctrs, ctr + consult.astype(jnp.uint32), level, 0
        )
        descend = consult & (level > 0) & ((h & jnp.uint32(0x80000000)) == 0)
        emit = consult & ~descend
        lvl = level.astype(jnp.uint32)
        k = (h >> (jnp.uint32(32 - s_log2) - lvl)).astype(jnp.int32)
        f = h << (jnp.uint32(s_log2) + lvl)
        out_k = jnp.where(emit, k, out_k)
        out_f = jnp.where(emit, f, out_f)
        return level - 1, emitted | emit, out_k, out_f, ctrs

    state = (
        jnp.int32(max_top),
        jnp.zeros(shape, dtype=bool),
        jnp.zeros(shape, dtype=jnp.int32),
        jnp.zeros(shape, dtype=jnp.uint32),
        counters,
    )
    _, _, out_k, out_f, counters = jax.lax.while_loop(cond, body, state)
    return out_k, out_f, counters


def resolve_tail_vartop(ids, segs, cum_hi, cum_lo, lane_top, dom_slot, s_pad: int):
    """Per-lane section 3.2 tail against STACKED per-domain cumsum rows.

    ``cum_hi`` / ``cum_lo``: flat (D * s_pad,) inclusive u64-cumsum halves,
    each domain's row padded at its own total mass, so the branchless
    binary search stays within ``dom_slot``'s row and is bit-identical to
    ``resolve_tail_np`` on that domain's unpadded table.  The raw draw is
    at ``lane_top + 1`` (the owning domain's top), counter 0.
    """
    shape = ids.shape
    miss = segs < 0
    base = dom_slot * s_pad

    def tail(_):
        h = draw_u32(ids, lane_top + 1, jnp.zeros(shape, dtype=jnp.uint32))
        last = (base + (s_pad - 1)).reshape(-1)
        t_hi = jnp.take(cum_hi, last, axis=0).reshape(shape)
        t_lo = jnp.take(cum_lo, last, axis=0).reshape(shape)
        p1_hi, p1_lo = mul32_wide(h, t_hi)
        p2_hi, _ = mul32_wide(h, t_lo)
        u_lo = p1_lo + p2_hi
        u_hi = p1_hi + (u_lo < p1_lo).astype(jnp.uint32)
        # searchsorted(cum, u, side="right") within the domain's row.
        lo = jnp.zeros(shape, dtype=jnp.int32)
        hi = jnp.full(shape, s_pad, dtype=jnp.int32)
        for _step in range(max(1, int(s_pad).bit_length())):
            active = lo < hi
            mid = jnp.minimum((lo + hi) >> 1, s_pad - 1)
            idx = (base + mid).reshape(-1)
            c_hi = jnp.take(cum_hi, idx, axis=0).reshape(shape)
            c_lo = jnp.take(cum_lo, idx, axis=0).reshape(shape)
            le = (c_hi < u_hi) | ((c_hi == u_hi) & (c_lo <= u_lo))  # cum<=u
            lo = jnp.where(active & le, mid + 1, lo)
            hi = jnp.where(active & ~le, mid, hi)
        return lo

    tail_seg = jax.lax.cond(
        jnp.any(miss), tail, lambda _: jnp.zeros(shape, dtype=jnp.int32), None
    )
    return jnp.where(miss, tail_seg, segs)


def _place_vartop(
    ids,
    len32_flat,
    cum_hi,
    cum_lo,
    lane_top,
    dom_slot,
    *,
    max_top: int,
    s_log2: int,
    s_pad: int,
    max_draws: int,
):
    """Total single placement of every lane in ITS OWN domain's table.

    The ``place_ref`` loop with the vartop ladder and stacked-table
    gathers: padded (zero-length) slots never hit, so the miss set is
    exactly the oracle's ``k >= n_segs_d | frac >= len32[k]``; the tail
    then resolves per lane.  Returns per-domain segment indices.
    """
    shape = ids.shape
    base = dom_slot * s_pad

    def cond(state):
        i, _, _, done = state
        return (i < max_draws) & ~jnp.all(done)

    def body(state):
        i, counters, result, done = state
        k, f, counters = next_asura_vartop(ids, counters, lane_top, max_top, s_log2)
        k_safe = jnp.minimum(k, s_pad - 1)
        lens = jnp.take(len32_flat, (base + k_safe).reshape(-1), axis=0).reshape(shape)
        hit = (~done) & (k < s_pad) & (f < lens)
        result = jnp.where(hit, k, result)
        return i + 1, counters, result, done | hit

    counters0 = jnp.zeros((max_top + 1,) + shape, dtype=jnp.uint32)
    result0 = jnp.full(shape, -1, dtype=jnp.int32)
    done0 = jnp.zeros(shape, dtype=bool)
    _, _, result, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), counters0, result0, done0)
    )
    return resolve_tail_vartop(ids, result, cum_hi, cum_lo, lane_top, dom_slot, s_pad)


def _hier_replicas_tile(
    ids,
    top_len32,
    top_slot_of,
    dom_len32,
    dom_node,
    dom_cum_hi,
    dom_cum_lo,
    dom_top,
    dom_ids,
    *,
    top_level: int,
    max_top: int,
    s_log2: int,
    max_draws: int,
    n_segs_top: int,
    s_pad: int,
    n_replicas: int,
):
    """Both levels for one tile -> (domains, nodes), each (R, ...) int32.

    Level 1 is the untouched section-5.A replica tile against the domain
    table (distinct "nodes" = distinct domain slots); level 2 runs one
    salted vartop placement per replica slot -- fresh counters per slot,
    exactly one ``place_nodes`` stream per (id, domain) like the oracle.
    """
    shape = ids.shape
    ids = ids.astype(jnp.uint32)
    _, slots = _place_replicas_tile(
        ids,
        top_len32,
        top_slot_of,
        top_level=top_level,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs=n_segs_top,
        n_replicas=n_replicas,
    )
    out_dom, out_node = [], []
    for r in range(n_replicas):
        slot = slots[r]
        valid = slot >= 0
        slot_safe = jnp.maximum(slot, 0)
        flat = slot_safe.reshape(-1)
        did = jnp.take(dom_ids, flat, axis=0).reshape(shape)
        lane_top = jnp.take(dom_top, flat, axis=0).reshape(shape)
        salted = fmix32(ids ^ (did.astype(jnp.uint32) * jnp.uint32(GOLDEN)))
        seg = _place_vartop(
            salted,
            dom_len32,
            dom_cum_hi,
            dom_cum_lo,
            lane_top,
            slot_safe,
            max_top=max_top,
            s_log2=s_log2,
            s_pad=s_pad,
            max_draws=max_draws,
        )
        node = jnp.take(
            dom_node, (slot_safe * s_pad + seg).reshape(-1), axis=0
        ).reshape(shape)
        out_dom.append(jnp.where(valid, did, jnp.int32(-1)))
        out_node.append(jnp.where(valid, node, jnp.int32(-1)))
    return jnp.stack(out_dom), jnp.stack(out_node)


_HIER_STATICS = (
    "top_level",
    "max_top",
    "s_log2",
    "max_draws",
    "s_pad",
    "n_replicas",
)


@functools.partial(jax.jit, static_argnames=_HIER_STATICS)
def hier_place_replicas_ref(
    ids,
    top_len32,
    top_slot_of,
    dom_len32,
    dom_node,
    dom_cum_hi,
    dom_cum_lo,
    dom_top,
    dom_ids,
    *,
    top_level: int,
    max_top: int,
    s_log2: int,
    max_draws: int,
    s_pad: int,
    n_replicas: int,
):
    """jnp twin of the fused two-level kernel -> (2, R, batch) int32.

    Plane 0 = domain ids, plane 1 = node ids; -1 marks non-converged
    level-1 lanes (the engine's host wrapper raises on them).
    """
    doms, nodes = _hier_replicas_tile(
        ids.astype(jnp.uint32),
        top_len32,
        top_slot_of.astype(jnp.int32),
        dom_len32,
        dom_node.astype(jnp.int32),
        dom_cum_hi,
        dom_cum_lo,
        dom_top.astype(jnp.int32),
        dom_ids.astype(jnp.int32),
        top_level=top_level,
        max_top=max_top,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs_top=int(top_len32.shape[0]),
        s_pad=s_pad,
        n_replicas=n_replicas,
    )
    return jnp.stack([doms, nodes])


def _hier_replicas_kernel(
    ids_ref,
    top_len_ref,
    top_slot_ref,
    dom_len_ref,
    dom_node_ref,
    dom_ch_ref,
    dom_cl_ref,
    dom_top_ref,
    dom_ids_ref,
    out_ref,
    *,
    top_level: int,
    max_top: int,
    s_log2: int,
    max_draws: int,
    n_segs_top: int,
    s_pad: int,
    n_replicas: int,
):
    doms, nodes = _hier_replicas_tile(
        ids_ref[...],
        top_len_ref[...],
        top_slot_ref[...],
        dom_len_ref[...],
        dom_node_ref[...],
        dom_ch_ref[...],
        dom_cl_ref[...],
        dom_top_ref[...],
        dom_ids_ref[...],
        top_level=top_level,
        max_top=max_top,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs_top=n_segs_top,
        s_pad=s_pad,
        n_replicas=n_replicas,
    )
    out_ref[...] = jnp.stack([doms, nodes])


@functools.partial(
    jax.jit, static_argnames=_HIER_STATICS + ("rows_per_block", "interpret")
)
def hier_place_replicas_pallas(
    ids,
    top_len32,
    top_slot_of,
    dom_len32,
    dom_node,
    dom_cum_hi,
    dom_cum_lo,
    dom_top,
    dom_ids,
    *,
    top_level: int,
    max_top: int,
    s_log2: int,
    max_draws: int,
    s_pad: int,
    n_replicas: int,
    rows_per_block: int = DEFAULT_ROWS,
    interpret: bool = True,
):
    """Fused two-level replication via pl.pallas_call -> (2, R, total).

    ids must be block-padded; all tables lane-padded (the engine pads).
    Both levels' tables sit whole in VMEM per grid step -- the top table
    plus D stacked domain rows are still kilobytes.
    """
    n_segs_top = int(top_len32.shape[0])
    d_flat = int(dom_len32.shape[0])
    d_pad = int(dom_top.shape[0])
    total = ids.shape[0]
    block = rows_per_block * LANE
    assert total % block == 0, "the engine must pad ids to a block multiple"
    assert n_segs_top % LANE == 0, "top table must be lane-padded"
    assert d_flat % LANE == 0 and d_flat % s_pad == 0, "stacked tables must be lane-padded"
    assert d_pad % LANE == 0, "domain vectors must be lane-padded"
    ids2 = ids.reshape(total // LANE, LANE)
    grid = (total // block,)
    kernel = functools.partial(
        _hier_replicas_kernel,
        top_level=top_level,
        max_top=max_top,
        s_log2=s_log2,
        max_draws=max_draws,
        n_segs_top=n_segs_top,
        s_pad=s_pad,
        n_replicas=n_replicas,
    )
    whole = lambda n: pl.BlockSpec((n,), lambda i: (0,))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
            whole(n_segs_top),
            whole(n_segs_top),
            whole(d_flat),
            whole(d_flat),
            whole(d_flat),
            whole(d_flat),
            whole(d_pad),
            whole(d_pad),
        ],
        out_specs=pl.BlockSpec(
            (2, n_replicas, rows_per_block, LANE), lambda i: (0, 0, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (2, n_replicas, total // LANE, LANE), jnp.int32
        ),
        interpret=interpret,
    )(
        ids2,
        top_len32,
        top_slot_of.astype(jnp.int32),
        dom_len32,
        dom_node.astype(jnp.int32),
        dom_cum_hi,
        dom_cum_lo,
        dom_top.astype(jnp.int32),
        dom_ids.astype(jnp.int32),
    )
    return out.reshape(2, n_replicas, total)


__all__ = [
    "next_asura_vartop",
    "resolve_tail_vartop",
    "hier_place_replicas_ref",
    "hier_place_replicas_pallas",
]
