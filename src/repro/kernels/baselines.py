"""Device-resident baseline placement kernels (DESIGN.md section 9).

The paper's evaluation (sections 6.B-6.D) is a head-to-head of ASURA
against Consistent Hashing, Rendezvous/Straw-weighted hashing and Random
Slicing.  PRs 1-3 made ASURA fully device-resident; these kernels do the
same for the baselines so the comparison runs at a common scale through the
same ``PlacementEngine`` artifact interface:

  * ``ch``  -- virtual-node ring lookup: ``fmix32(id)`` then a branchless
    binary search (side='left') over the sorted u32 ring, wrap to the first
    point; O(log NV) per id, the ring broadcast whole into VMEM,
  * ``wrh`` -- weighted rendezvous: per-node keyed hash (salt precomputed
    at table prep), fixed-point Q16 ``-log2(u)`` (pure u32 square-and-
    shift, see ``repro.core.wrh``), one IEEE f32 multiply by the
    precomputed capacity reciprocal, running argmin over the node table;
    O(N) per id -- the unscalability the paper's Fig. 5 shows,
  * ``rs``  -- random slicing: ``fmix32(id)`` then a branchless binary
    search (side='right' - 1) over the u32 interval starts; O(log I).

Every algorithm has a jnp ``*_lookup`` twin (shape-polymorphic, shared
VERBATIM by the jitted reference path and the Pallas kernel bodies, the
``next_asura`` pattern) and is bit-identical to its NumPy oracle
(``ch_place_np`` / ``wrh_place_np`` / ``rs_place_np``) -- integer compares
and searches only; WRH's single float op is a lone IEEE division, exact on
every backend.  ``baseline_place_on_table_device`` is the engine's entry
point: zero host syncs, device arrays in and out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.wrh import Q16

from .asura_place import DEFAULT_ROWS, LANE
from .ref import draw_u32, fmix32

__all__ = [
    "ch_table_prep",
    "rs_table_prep",
    "wrh_table_prep",
    "ch_lookup",
    "rs_lookup",
    "wrh_lookup",
    "neg_log2_q16",
    "ch_place_pallas",
    "rs_place_pallas",
    "wrh_place_pallas",
    "baseline_place_on_table_device",
    "baseline_replicas_lookup",
    "baseline_place_replicas_np",
    "baseline_place_replicas_on_table_device",
    "REPLICA_FANOUT_LEVEL",
    "REPLICA_MAX_TRIES",
]

# R-way fan-out rejection stream: the r-th re-probe hashes the datum id
# through the shared counter-based generator at a reserved level far above
# any ASURA ladder level, so fan-out draws can never alias placement draws.
REPLICA_FANOUT_LEVEL = 0x52455031  # "REP1"
REPLICA_MAX_TRIES = 64  # collision odds ~ (R/N)**tries: negligible at 64


# ---------------------------------------------------------------------------
# Host-side table prep (lane padding, one upload per artifact)
# ---------------------------------------------------------------------------


def _lane_pad(x: np.ndarray, fill) -> np.ndarray:
    pad = (-x.shape[0]) % LANE
    if pad == 0:
        return x
    return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])


def ch_table_prep(ring_hashes: np.ndarray, ring_owners: np.ndarray):
    """Lane-padded device ring.  Hash padding is 0xFFFFFFFF and owner
    padding is the FIRST ring owner, so a datum hashing past every real
    point lands on a pad and resolves to the wrap target -- the same owner
    the oracle's explicit ``idx == n -> 0`` wrap picks."""
    hashes = np.asarray(ring_hashes, dtype=np.uint32)
    owners = np.asarray(ring_owners).astype(np.int32)
    return (
        jnp.asarray(_lane_pad(hashes, np.uint32(0xFFFFFFFF))),
        jnp.asarray(_lane_pad(owners, np.int32(owners[0]))),
    )


def rs_table_prep(starts32: np.ndarray, owners: np.ndarray):
    """Lane-padded device interval table.  Start padding is 0xFFFFFFFF and
    owner padding the LAST real owner: the 'right'-side search maps a hash
    at/above the last pad start to the final interval's owner, exactly as
    the unpadded oracle does."""
    starts = np.asarray(starts32, dtype=np.uint32)
    owners = np.asarray(owners).astype(np.int32)
    return (
        jnp.asarray(_lane_pad(starts, np.uint32(0xFFFFFFFF))),
        jnp.asarray(_lane_pad(owners, np.int32(owners[-1]))),
    )


def wrh_table_prep(node_ids: np.ndarray, weights: np.ndarray):
    """Lane-padded device salt/reciprocal tables.

    The per-id loop over the node table is WRH's whole cost (O(N) hashes
    per id), so everything per-NODE is hoisted here, once per artifact:

      * ``salts[j] = GOLDEN * (node_id + 1) mod 2**32`` -- the level term
        of the keyed draw, so the loop hashes ``fmix32(fmix32(id + salt))``
        instead of re-deriving the salt per (id, node) pair,
      * ``inv_w[j] = float32(1) / weight`` -- the straw key becomes one f32
        MULTIPLY per (id, node) instead of a division (same single-op IEEE
        rounding contract; the NumPy oracle multiplies by the identical
        precomputed reciprocal, so bit-identity is preserved).

    Reciprocal padding is 0.0, which the lookup masks out (a zero-capacity
    straw can never win); ``wrh_lookup`` recovers the winning node id from
    its salt via the odd-constant inverse."""
    nodes = np.asarray(node_ids, dtype=np.uint32)
    w = np.asarray(weights, dtype=np.float32)
    from .ref import GOLDEN

    with np.errstate(over="ignore", divide="ignore"):  # u32 wrap by design
        salts = np.uint32(GOLDEN) * (nodes + np.uint32(1))
        inv_w = np.where(
            w > 0.0, np.float32(1.0) / w, np.float32(0.0)
        ).astype(np.float32)
    return (
        jnp.asarray(_lane_pad(salts, np.uint32(0))),
        jnp.asarray(_lane_pad(inv_w, np.float32(0.0))),
    )


# ---------------------------------------------------------------------------
# Shape-polymorphic jnp lookups (run in plain jit AND inside Pallas kernels)
# ---------------------------------------------------------------------------


def _bsearch(keys: jax.Array, h: jax.Array, *, side_left: bool) -> jax.Array:
    """Branchless u32 binary search over an in-VMEM sorted table.

    side_left=True  -> first index with keys[idx] >= h  (searchsorted 'left')
    side_left=False -> first index with keys[idx] >  h  (searchsorted 'right')

    Fixed trip count (bit_length of the padded table size), per-lane active
    masks -- the ``resolve_tail_dev`` pattern, no host round trips.
    """
    n_pad = keys.shape[0]
    shape = h.shape
    lo = jnp.zeros(shape, dtype=jnp.int32)
    hi = jnp.full(shape, n_pad, dtype=jnp.int32)
    for _step in range(max(1, int(n_pad).bit_length())):
        active = lo < hi
        mid = jnp.minimum((lo + hi) >> 1, n_pad - 1)
        k = jnp.take(keys, mid.reshape(-1), axis=0).reshape(shape)
        below = (k < h) if side_left else (k <= h)
        lo = jnp.where(active & below, mid + 1, lo)
        hi = jnp.where(active & ~below, mid, hi)
    return lo


def ch_lookup(ids: jax.Array, ring: jax.Array, owners: jax.Array) -> jax.Array:
    """Consistent-hashing distribution stage on one tile/batch -> int32."""
    h = fmix32(ids.astype(jnp.uint32))
    idx = _bsearch(ring, h, side_left=True)
    idx = jnp.where(idx == ring.shape[0], 0, idx)  # wrap (exact-multiple pad)
    return jnp.take(owners, idx.reshape(-1), axis=0).reshape(ids.shape)


def rs_lookup(ids: jax.Array, starts: jax.Array, owners: jax.Array) -> jax.Array:
    """Random-slicing lookup on one tile/batch -> int32 owners."""
    h = fmix32(ids.astype(jnp.uint32))
    idx = _bsearch(starts, h, side_left=False) - 1  # starts[0] == 0 -> idx >= 0
    return jnp.take(owners, idx.reshape(-1), axis=0).reshape(ids.shape)


def neg_log2_q16(h: jax.Array) -> jax.Array:
    """jnp twin of ``repro.core.wrh.neg_log2_q16_np`` (bit-identical).

    Pure u32 shifts/multiplies (the squaring through 16-bit limbs), so it
    runs unchanged inside Pallas kernels.
    """
    h = h.astype(jnp.uint32)
    v = ((h >> jnp.uint32(9)) << jnp.uint32(1)) | jnp.uint32(1)
    x = v
    e = jnp.zeros(v.shape, dtype=jnp.uint32)
    for s in (16, 8, 4, 2, 1):
        big = x >= (jnp.uint32(1) << jnp.uint32(s))
        e = e + jnp.where(big, jnp.uint32(s), jnp.uint32(0))
        x = jnp.where(big, x >> jnp.uint32(s), x)
    m = v << (jnp.uint32(23) - e)
    frac = jnp.zeros(v.shape, dtype=jnp.uint32)
    m16 = jnp.uint32(0xFFFF)
    for i in range(1, Q16 + 1):
        a_lo, a_hi = m & m16, m >> jnp.uint32(16)
        ll = a_lo * a_lo
        lh = a_lo * a_hi
        t = (ll >> jnp.uint32(16)) + (lh & m16) + (lh & m16)
        lo = (t << jnp.uint32(16)) | (ll & m16)
        hi = (
            a_hi * a_hi
            + (lh >> jnp.uint32(16))
            + (lh >> jnp.uint32(16))
            + (t >> jnp.uint32(16))
        )
        m = (hi << jnp.uint32(9)) | (lo >> jnp.uint32(23))
        ge = m >= (jnp.uint32(1) << jnp.uint32(24))
        frac = frac | jnp.where(ge, jnp.uint32(1) << jnp.uint32(Q16 - i), jnp.uint32(0))
        m = jnp.where(ge, m >> jnp.uint32(1), m)
    return (
        ((jnp.uint32(24) - e).astype(jnp.int32) << jnp.int32(Q16))
        - frac.astype(jnp.int32)
    )


def wrh_lookup(
    ids: jax.Array, salts: jax.Array, inv_w: jax.Array
) -> jax.Array:
    """Weighted-rendezvous winner on one tile/batch -> int32 node ids.

    Running argmin of ``neg_log2_q16(hash(id, node)) * (1/weight)`` over
    the prepped salt/reciprocal tables (``wrh_table_prep``): the per-node
    salt and capacity reciprocal are precomputed, so each loop iteration
    is two fmix rounds, the Q16 log and ONE f32 multiply -- the hoist that
    closes WRH's serving fan-out gap.  Strict ``<`` keeps the FIRST
    minimal node, matching the NumPy oracle's ``argmin``; zero-reciprocal
    (padding) entries never win.  The winner's node id is recovered from
    its salt by the odd-constant modular inverse (``salt = GOLDEN *
    (nid + 1)`` is a bijection on u32; best-salt 0 recovers the -1
    sentinel exactly).
    """
    from .ref import GOLDEN

    shape = ids.shape
    n_pad = salts.shape[0]
    ids_u32 = ids.astype(jnp.uint32)

    def body(j, state):
        best_key, best_salt = state
        salt = jax.lax.dynamic_index_in_dim(salts, j, 0, keepdims=False)
        iw = jax.lax.dynamic_index_in_dim(inv_w, j, 0, keepdims=False)
        h = fmix32(fmix32(ids_u32 + salt))  # draw_u32 with hoisted level term
        key = neg_log2_q16(h).astype(jnp.float32) * iw  # one IEEE f32 mul
        valid = iw > jnp.float32(0.0)
        better = valid & (key < best_key)
        best_key = jnp.where(better, key, best_key)
        best_salt = jnp.where(better, salt, best_salt)
        return best_key, best_salt

    best_key0 = jnp.full(shape, jnp.inf, dtype=jnp.float32)
    best_salt0 = jnp.zeros(shape, dtype=jnp.uint32)
    _, best_salt = jax.lax.fori_loop(0, n_pad, body, (best_key0, best_salt0))
    inv = jnp.uint32(pow(GOLDEN, -1, 1 << 32))
    return (best_salt * inv - jnp.uint32(1)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Pallas kernels: one (rows, LANE) id tile per grid step, tables whole in VMEM
# ---------------------------------------------------------------------------


def _ch_kernel(ids_ref, ring_ref, owners_ref, out_ref):
    out_ref[...] = ch_lookup(ids_ref[...], ring_ref[...], owners_ref[...])


def _rs_kernel(ids_ref, starts_ref, owners_ref, out_ref):
    out_ref[...] = rs_lookup(ids_ref[...], starts_ref[...], owners_ref[...])


def _wrh_kernel(ids_ref, salts_ref, inv_ref, out_ref):
    out_ref[...] = wrh_lookup(ids_ref[...], salts_ref[...], inv_ref[...])


def _tiled_pallas_call(kernel, ids, tables, *, rows_per_block, interpret):
    """Shared launch shape: (rows, LANE) id tiles, each table broadcast
    whole per block (the segment-table pattern -- baseline tables are the
    same kilobyte order as ASURA's, far under the VMEM budget)."""
    from jax.experimental import pallas as pl

    total = ids.shape[0]
    block = rows_per_block * LANE
    assert total % block == 0, "wrapper must pad ids to a block multiple"
    for t in tables:
        assert t.shape[0] % LANE == 0, "tables must be lane-padded"
    ids2 = ids.reshape(total // LANE, LANE)
    out = pl.pallas_call(
        kernel,
        grid=(total // block,),
        in_specs=[pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0))]
        + [pl.BlockSpec((t.shape[0],), lambda i: (0,)) for t in tables],
        out_specs=pl.BlockSpec((rows_per_block, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(ids2.shape, jnp.int32),
        interpret=interpret,
    )(ids2, *tables)
    return out.reshape(total)


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def ch_place_pallas(
    ids: jax.Array,
    ring: jax.Array,
    owners: jax.Array,
    *,
    rows_per_block: int = DEFAULT_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Batched CH ring lookup via pl.pallas_call -> (total,) int32 owners."""
    return _tiled_pallas_call(
        _ch_kernel, ids, (ring, owners.astype(jnp.int32)),
        rows_per_block=rows_per_block, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def rs_place_pallas(
    ids: jax.Array,
    starts: jax.Array,
    owners: jax.Array,
    *,
    rows_per_block: int = DEFAULT_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Batched random-slicing lookup via pl.pallas_call -> int32 owners."""
    return _tiled_pallas_call(
        _rs_kernel, ids, (starts, owners.astype(jnp.int32)),
        rows_per_block=rows_per_block, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def wrh_place_pallas(
    ids: jax.Array,
    salts: jax.Array,
    inv_w: jax.Array,
    *,
    rows_per_block: int = DEFAULT_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Batched weighted-rendezvous argmin via pl.pallas_call -> int32."""
    return _tiled_pallas_call(
        _wrh_kernel, ids, (salts, inv_w),
        rows_per_block=rows_per_block, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# jitted jnp reference wrappers (the non-Pallas device path)
# ---------------------------------------------------------------------------


@jax.jit
def _ch_ref(ids, ring, owners):
    return ch_lookup(ids, ring, owners)


@jax.jit
def _rs_ref(ids, starts, owners):
    return rs_lookup(ids, starts, owners)


@jax.jit
def _wrh_ref(ids, salts, inv_w):
    return wrh_lookup(ids, salts, inv_w)


_REF = {"ch": _ch_ref, "rs": _rs_ref, "wrh": _wrh_ref}
_PALLAS = {"ch": ch_place_pallas, "rs": rs_place_pallas, "wrh": wrh_place_pallas}
_LOOKUP = {"ch": ch_lookup, "rs": rs_lookup, "wrh": wrh_lookup}


# ---------------------------------------------------------------------------
# R-way replica fan-out (serving read fan-out for the baselines)
# ---------------------------------------------------------------------------
#
# The baselines have no segment semantics, so ASURA's section-5.A distinct-
# node replica draw does not apply.  The standard construction is a salted
# rejection re-probe: slot 0 is the primary lookup; each further slot
# re-places a fresh counter-based hash of the id (``draw_u32`` at the
# reserved fan-out level) and accepts the first candidate distinct from the
# already-accepted set.  Same bounded-tries / -1 sentinel contract as the
# ASURA replica kernel, and the jnp body is bit-identical to the NumPy
# oracle (integer lookups on both sides).


def baseline_replicas_lookup(
    lookup,
    ids: jax.Array,
    keys: jax.Array,
    vals: jax.Array,
    *,
    n_replicas: int,
    max_tries: int = REPLICA_MAX_TRIES,
    emit_stats: bool = False,
):
    """Shape-polymorphic jnp R-way fan-out -> (*ids.shape, R) int32 nodes.

    ``lookup`` is one of the ``*_lookup`` bodies; slots that fail to find a
    distinct node within ``max_tries`` stay -1 (only possible when
    R > live nodes, or with astronomically bad luck).

    ``emit_stats=True`` returns ``(nodes, stats)`` where ``stats`` is a
    (1,) uint32 vector holding the total salted re-probe attempts the
    batch issued (draws on lanes whose R-set was still incomplete) -- the
    obs device plane's rejection-cost metric.  Nodes are bit-identical
    either way.

    The rejection loop is an EARLY-EXIT ``while_loop``: once every lane
    has its R distinct nodes (almost always after R-1 tries, collision
    odds ~ (R/N)**k) the loop stops, instead of sweeping all ``max_tries``
    full-table lookups -- the fix for WRH's O(N)-per-lookup fan-out being
    ~32x slower than ASURA's.  Bit-identical to the fixed-trip loop (and
    to the NumPy oracle's host early-break): skipped iterations change no
    state and issue zero probes by definition."""
    shape = ids.shape
    u = ids.astype(jnp.uint32)
    prim = lookup(ids, keys, vals)
    if n_replicas == 1:
        out = prim[..., None]
        if emit_stats:
            return out, jnp.zeros((1,), dtype=jnp.uint32)
        return out
    slots = jnp.full((n_replicas,) + shape, -1, dtype=jnp.int32)
    slots = slots.at[0].set(prim)
    found = jnp.ones(shape, dtype=jnp.int32)
    row = jnp.arange(n_replicas, dtype=jnp.int32).reshape(
        (n_replicas,) + (1,) * len(shape)
    )

    def cond(state):
        k = state[0]
        found = state[2]
        return (k <= max_tries) & jnp.any(found < n_replicas)

    def body(state):
        if emit_stats:
            k, slots, found, nprobe = state
            nprobe = nprobe + jnp.sum((found < n_replicas).astype(jnp.uint32))
        else:
            k, slots, found = state
        ctr = jnp.broadcast_to(k.astype(jnp.uint32), shape)
        h = draw_u32(u, REPLICA_FANOUT_LEVEL, ctr)
        cand = lookup(h, keys, vals)
        dup = jnp.any(slots == cand[None], axis=0)
        take = (~dup) & (found < n_replicas)
        put = take[None] & (row == found[None])
        slots = jnp.where(put, cand[None], slots)
        found = found + take.astype(jnp.int32)
        if emit_stats:
            return k + 1, slots, found, nprobe
        return k + 1, slots, found

    k0 = jnp.int32(1)
    if emit_stats:
        _, slots, _, nprobe = jax.lax.while_loop(
            cond, body, (k0, slots, found, jnp.uint32(0))
        )
        return jnp.moveaxis(slots, 0, -1), nprobe[None]
    _, slots, _ = jax.lax.while_loop(cond, body, (k0, slots, found))
    return jnp.moveaxis(slots, 0, -1)


def baseline_place_replicas_np(
    algorithm: str,
    datum_ids,
    keys: np.ndarray,
    vals: np.ndarray,
    n_replicas: int,
    *,
    max_tries: int = REPLICA_MAX_TRIES,
) -> np.ndarray:
    """NumPy oracle of ``baseline_replicas_lookup`` -> (batch, R) int64."""
    from repro.core.consistent_hashing import ch_place_np
    from repro.core.random_slicing import rs_place_np
    from repro.core.rng import draw_u32_np
    from repro.core.wrh import wrh_place_np

    place = {"ch": ch_place_np, "rs": rs_place_np, "wrh": wrh_place_np}[algorithm]
    ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
    n = ids.shape[0]
    slots = np.full((n_replicas, n), -1, dtype=np.int64)
    slots[0] = place(ids, keys, vals)
    found = np.ones(n, dtype=np.int64)
    for k in range(1, max_tries + 1):
        if (found >= n_replicas).all():
            break
        h = draw_u32_np(ids, REPLICA_FANOUT_LEVEL, np.full(n, k, dtype=np.uint32))
        cand = place(h, keys, vals)
        dup = (slots == cand[None]).any(axis=0)
        take = (~dup) & (found < n_replicas)
        slots[found[take], np.nonzero(take)[0]] = cand[take]
        found[take] += 1
    return slots.T


@functools.partial(jax.jit, static_argnames=("algorithm", "n_replicas", "max_tries"))
def _baseline_replicas_ref(ids, keys, vals, *, algorithm, n_replicas, max_tries):
    return baseline_replicas_lookup(
        _LOOKUP[algorithm], ids, keys, vals,
        n_replicas=n_replicas, max_tries=max_tries,
    )


def baseline_place_replicas_on_table_device(
    algorithm: str,
    datum_ids,
    table_a: jax.Array,
    table_b: jax.Array,
    *,
    n_replicas: int,
    max_tries: int = REPLICA_MAX_TRIES,
    use_pallas: bool = False,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> jax.Array:
    """Device-resident baseline fan-out -> (batch, R) int32, zero host syncs.

    Runs the jitted jnp body on every backend (the ``ShardedSweep`` idiom:
    the fan-out is a rejection loop around the shape-polymorphic lookups,
    bit-identical to the Pallas lookups by construction), so the
    ``use_pallas``/``interpret``/``rows_per_block`` knobs are accepted for
    interface parity with ``baseline_place_on_table_device`` and ignored.
    """
    del use_pallas, interpret, rows_per_block
    ids = jnp.asarray(datum_ids).astype(jnp.uint32)
    if ids.shape[0] == 0:
        return jnp.zeros((0, n_replicas), dtype=jnp.int32)
    return _baseline_replicas_ref(
        ids, table_a, table_b,
        algorithm=algorithm, n_replicas=n_replicas, max_tries=max_tries,
    )


def baseline_place_on_table_device(
    algorithm: str,
    datum_ids,
    table_a: jax.Array,
    table_b: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
    rows_per_block: int = DEFAULT_ROWS,
) -> jax.Array:
    """Device-resident baseline placement -> (batch,) int32 node ids.

    ``(table_a, table_b)`` are the algorithm's two prepped device tables:
    (ring, owners) for ``ch``, (starts, owners) for ``rs``, (node_ids,
    weights) for ``wrh``.  Sync-free like ``place_on_table_device``: device
    ids stay on device, the output is a device array.
    """
    from .ops import _pad_ids, _default_interpret, _head

    interpret = _default_interpret(interpret)
    ids = jnp.asarray(datum_ids).astype(jnp.uint32)
    n = ids.shape[0]
    if n == 0:
        return jnp.zeros((0,), dtype=jnp.int32)
    if use_pallas:
        block = rows_per_block * LANE
        padded = _pad_ids(ids, block)
        out = _PALLAS[algorithm](
            padded, table_a, table_b,
            rows_per_block=rows_per_block, interpret=interpret,
        )
        return _head(out, n)
    return _REF[algorithm](ids, table_a, table_b)
