"""Model zoo: configs, layers, and the functional model API."""

from .api import (
    cache_specs,
    decode_step,
    forward,
    init_cache,
    init_params,
    input_specs,
    loss_fn,
    make_inputs,
    param_specs,
    prefill,
    reduced_config,
)
from .config import SHAPES, MLAConfig, ModelConfig, MoEConfig, ShapeSpec, shape_applicable

__all__ = [
    "SHAPES",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "ShapeSpec",
    "cache_specs",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "input_specs",
    "loss_fn",
    "make_inputs",
    "param_specs",
    "prefill",
    "reduced_config",
    "shape_applicable",
]
