"""Public model API: input specs, reduced (smoke) configs, spec helpers."""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .config import ModelConfig, MLAConfig, MoEConfig, ShapeSpec
from .layers import COMPUTE_DTYPE
from . import lm

init_params = lm.init_params
param_specs = lm.param_specs
loss_fn = lm.loss_fn
prefill = lm.prefill
decode_step = lm.decode_step
init_cache = lm.init_cache
forward = lm.forward


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    # batch/max_len must stay static (cache sizes are shape parameters)
    return jax.eval_shape(lambda: lm.init_cache(cfg, batch, max_len))


def input_specs(cfg: ModelConfig, spec: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    No device allocation -- exactly what ``.lower()`` needs.  decode cells
    include the KV/state cache at the cell's seq_len (windowed archs clamp
    the cache to the window internally)."""
    b, s = spec.global_batch, spec.seq_len
    i32 = jnp.int32
    if spec.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), COMPUTE_DTYPE
            )
        if cfg.vision_prefix:
            batch["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_prefix, cfg.d_model), COMPUTE_DTYPE
            )
        return {"batch": batch}
    # decode: one new token against a cache of size seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "positions": jax.ShapeDtypeStruct((b, 1), i32),
    }
    return {"batch": batch, "cache": cache_specs(cfg, b, s)}


def make_inputs(cfg: ModelConfig, spec: ShapeSpec, rng) -> dict:
    """Concrete (small-scale) inputs matching input_specs -- smoke tests."""
    specs = input_specs(cfg, spec)

    def materialize(sd: jax.ShapeDtypeStruct):
        if jnp.issubdtype(sd.dtype, jnp.integer):
            return jax.random.randint(rng, sd.shape, 0, max(cfg.vocab - 1, 2)).astype(sd.dtype)
        return jnp.zeros(sd.shape, sd.dtype)

    out = jax.tree.map(materialize, specs)
    if spec.kind == "decode":
        out["cache"] = lm.init_cache(cfg, spec.global_batch, spec.seq_len)
        out["batch"]["positions"] = jnp.full(
            (spec.global_batch, 1), spec.seq_len - 1, jnp.int32
        )
    return out


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Same family/feature set, tiny dims -- one CPU forward/train step."""
    changes: dict = dict(
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        rwkv_head_dim=32,
    )
    if cfg.family == "rglru":
        changes["n_layers"] = len(cfg.block_pattern) + 1  # pattern + tail
        changes["lru_width"] = 128
        changes["window"] = 16
    elif cfg.family == "encdec":
        changes["n_layers"] = 2
        changes["n_enc_layers"] = 2
        changes["enc_seq"] = 16
    else:
        changes["n_layers"] = 2
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(
            n_experts=8,
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=128,
            n_shared=min(cfg.moe.n_shared, 1),
            d_ff_shared=128,
        )
        changes["n_dense_layers"] = min(cfg.n_dense_layers, 1)
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(
            q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16,
            v_head_dim=32,
        )
    if cfg.attn_kind == "swa":
        changes["window"] = 16
    if cfg.vision_prefix:
        changes["vision_prefix"] = 8
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **changes)
