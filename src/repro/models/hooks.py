"""Activation-sharding hook.

Models call ``constrain(x)`` on the residual stream (after embedding, after
every block, on decode steps).  By default it is the identity; the launcher
registers a ``with_sharding_constraint`` under its mesh so GSPMD keeps the
batch dim of loop carries sharded over the data axes instead of replicating
them inside ``lax.scan`` bodies (observed: without the constraint the SPMD
partitioner replicates the (B, S, D) carry and every attention tensor in the
layer loop -- EXPERIMENTS.md section Perf, iteration 1).

The hook keeps ``repro.models`` free of any dependency on mesh/layout code.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax

_CONSTRAIN: Optional[Callable[[jax.Array], jax.Array]] = None


def set_constraint(fn: Optional[Callable[[jax.Array], jax.Array]]) -> None:
    global _CONSTRAIN
    _CONSTRAIN = fn


def constrain(x: jax.Array) -> jax.Array:
    if _CONSTRAIN is None:
        return x
    return _CONSTRAIN(x)


class activation_sharding:
    """Context manager: register a constraint function."""

    def __init__(self, fn: Callable[[jax.Array], jax.Array]):
        self.fn = fn

    def __enter__(self):
        set_constraint(self.fn)
        return self

    def __exit__(self, *exc):
        set_constraint(None)
        return False
