"""Model assembly: init / train forward / decode for every assigned family.

Uniform functional API (used by train/serve steps, the dry-run and tests):

    params     = init_params(cfg, rng)
    loss, aux  = loss_fn(cfg, params, batch)            # train_4k
    logits     = prefill(cfg, params, batch)            # prefill_32k
    cache      = init_cache(cfg, batch, max_len)
    logits, c  = decode_step(cfg, params, cache, batch) # decode_32k/long_500k

Layers are stacked (leading layer axis) and driven by jax.lax.scan with
per-layer remat -- HLO size and compile memory stay bounded for 40-60-layer
models, and the dry-run's 512-device lowering stays fast.  Cross-entropy is
computed in sequence chunks so the (B, S, V) logits tensor is never
materialized (V up to 256k).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .hooks import constrain
from .layers import (
    COMPUTE_DTYPE,
    _init,
    attention_apply,
    attention_cache_init,
    attention_init,
    mla_apply,
    mla_cache_init,
    mla_init,
    mlp_apply,
    mlp_init,
    moe_apply,
    moe_init,
    norm_apply,
    norm_init,
)
from .recurrent import (
    rglru_apply,
    rglru_init,
    rglru_state_init,
    rwkv6_channelmix_apply,
    rwkv6_channelmix_init,
    rwkv6_state_init,
    rwkv6_timemix_apply,
    rwkv6_timemix_init,
)

CE_CHUNK = 256

_REMAT_POLICIES = {
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    "everything": jax.checkpoint_policies.everything_saveable,
}
_remat_policy_name = "nothing"


def set_remat_policy(name: str) -> None:
    "Perf knob (EXPERIMENTS.md section Perf): which residuals remat saves."
    assert name in _REMAT_POLICIES, name
    global _remat_policy_name
    _remat_policy_name = name


def _remat_policy():
    return _REMAT_POLICIES[_remat_policy_name]


# ---------------------------------------------------------------------------
# Block init/apply per family
# ---------------------------------------------------------------------------


def _lm_block_init(rng, cfg: ModelConfig, *, use_moe: bool):
    keys = jax.random.split(rng, 4)
    p = {"norm1": norm_init(cfg, cfg.d_model), "norm2": norm_init(cfg, cfg.d_model)}
    if cfg.mla is not None:
        p["mla"] = mla_init(keys[0], cfg, cfg.mla)
    else:
        p["attn"] = attention_init(keys[0], cfg)
    if use_moe:
        p["moe"] = moe_init(keys[1], cfg, cfg.moe)
    else:
        p["mlp"] = mlp_init(keys[1], cfg, cfg.d_model, cfg.d_ff)
    return p


def _lm_block_apply(cfg: ModelConfig, p, x, positions, cache=None):
    window = cfg.window if cfg.attn_kind == "swa" else 0
    h = norm_apply(cfg, p["norm1"], x)
    if cfg.mla is not None:
        attn_out, new_cache = mla_apply(cfg, p["mla"], h, positions=positions, cache=cache)
    else:
        attn_out, new_cache = attention_apply(
            cfg, p["attn"], h, positions=positions, causal=True, window=window, cache=cache
        )
    x = x + attn_out
    h = norm_apply(cfg, p["norm2"], x)
    aux = jnp.float32(0.0)
    if "moe" in p:
        mlp_out, aux = moe_apply(cfg, p["moe"], h, cfg.moe)
    else:
        mlp_out = mlp_apply(cfg, p["mlp"], h)
    return x + mlp_out, aux, new_cache


def _rglru_block_init(rng, cfg: ModelConfig, kind: str):
    keys = jax.random.split(rng, 3)
    p = {"norm1": norm_init(cfg, cfg.d_model), "norm2": norm_init(cfg, cfg.d_model)}
    if kind == "rec":
        p["rec"] = rglru_init(keys[0], cfg)
    else:
        p["attn"] = attention_init(keys[0], cfg)
    p["mlp"] = mlp_init(keys[1], cfg, cfg.d_model, cfg.d_ff)
    return p


def _rglru_block_apply(cfg: ModelConfig, p, x, positions, kind: str, state=None):
    h = norm_apply(cfg, p["norm1"], x)
    if kind == "rec":
        mix_out, new_state = rglru_apply(cfg, p["rec"], h, state=state)
    else:
        mix_out, new_state = attention_apply(
            cfg, p["attn"], h, positions=positions, causal=True,
            window=cfg.window, cache=state,
        )
    x = x + mix_out
    h = norm_apply(cfg, p["norm2"], x)
    return x + mlp_apply(cfg, p["mlp"], h), new_state


def _rwkv_block_init(rng, cfg: ModelConfig):
    keys = jax.random.split(rng, 2)
    return {
        "norm1": norm_init(cfg, cfg.d_model),
        "norm2": norm_init(cfg, cfg.d_model),
        "time": rwkv6_timemix_init(keys[0], cfg),
        "channel": rwkv6_channelmix_init(keys[1], cfg),
    }


def _rwkv_block_apply(cfg: ModelConfig, p, x, state=None):
    tstate = None if state is None else state["time"]
    cstate = None if state is None else state["channel"]
    h, new_t = rwkv6_timemix_apply(cfg, p["time"], norm_apply(cfg, p["norm1"], x), state=tstate)
    x = x + h
    h, new_c = rwkv6_channelmix_apply(
        cfg, p["channel"], norm_apply(cfg, p["norm2"], x), state=cstate
    )
    return x + h, {"time": new_t, "channel": new_c}


# ---------------------------------------------------------------------------
# Parameter init (stacked layers)
# ---------------------------------------------------------------------------


def _stack_init(init_fn, rng, n: int):
    return jax.vmap(init_fn)(jax.random.split(rng, n))


def init_params(cfg: ModelConfig, rng) -> dict:
    keys = jax.random.split(rng, 8)
    d = cfg.d_model
    params: dict = {
        "embed": _init(keys[0], (cfg.vocab, d)),
        "final_norm": norm_init(cfg, d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _init(keys[1], (d, cfg.vocab))
    if cfg.family == "lm":
        n_moe = cfg.n_layers - cfg.n_dense_layers if cfg.moe else 0
        n_dense = cfg.n_layers - n_moe
        if n_dense:
            params["dense_blocks"] = _stack_init(
                lambda r: _lm_block_init(r, cfg, use_moe=False), keys[2], n_dense
            )
        if n_moe:
            params["blocks"] = _stack_init(
                lambda r: _lm_block_init(r, cfg, use_moe=True), keys[3], n_moe
            )
    elif cfg.family == "rglru":
        pat = cfg.block_pattern
        n_super, n_tail = divmod(cfg.n_layers, len(pat))
        params["super_blocks"] = _stack_init(
            lambda r: {
                f"l{i}": _rglru_block_init(k, cfg, kind)
                for i, (kind, k) in enumerate(zip(pat, jax.random.split(r, len(pat))))
            },
            keys[2],
            n_super,
        )
        if n_tail:
            params["tail_blocks"] = _stack_init(
                lambda r: _rglru_block_init(r, cfg, pat[0]), keys[3], n_tail
            )
    elif cfg.family == "rwkv6":
        params["blocks"] = _stack_init(lambda r: _rwkv_block_init(r, cfg), keys[2], cfg.n_layers)
    elif cfg.family == "encdec":
        params["enc_blocks"] = _stack_init(
            lambda r: {
                "norm1": norm_init(cfg, d),
                "norm2": norm_init(cfg, d),
                "attn": attention_init(jax.random.split(r)[0], cfg),
                "mlp": mlp_init(jax.random.split(r)[1], cfg, d, cfg.d_ff),
            },
            keys[2],
            cfg.n_enc_layers,
        )
        params["enc_final_norm"] = norm_init(cfg, d)
        params["blocks"] = _stack_init(
            lambda r: {
                "norm1": norm_init(cfg, d),
                "norm2": norm_init(cfg, d),
                "norm3": norm_init(cfg, d),
                "attn": attention_init(jax.random.split(r, 3)[0], cfg),
                "cross": attention_init(jax.random.split(r, 3)[1], cfg),
                "mlp": mlp_init(jax.random.split(r, 3)[2], cfg, d, cfg.d_ff),
            },
            keys[3],
            cfg.n_layers,
        )
    else:
        raise ValueError(cfg.family)
    return params


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0)
    )


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _sinusoidal(positions, d):
    half = d // 2
    freqs = np.exp(-np.log(10_000.0) * np.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _scan_blocks(cfg, stacked, x, positions, apply_fn):
    """remat + scan over stacked layer params; accumulates aux loss."""

    block = jax.checkpoint(apply_fn, policy=_remat_policy(), static_argnums=())

    def f(carry, layer_p):
        h, aux = carry
        h, aux_l = block(layer_p, h, positions)
        return (constrain(h), aux + aux_l), None

    (x, aux), _ = jax.lax.scan(f, (constrain(x), jnp.float32(0.0)), stacked)
    return x, aux


def forward(cfg: ModelConfig, params, batch: dict):
    """Full-sequence forward -> final hidden states (B, S, D) and aux loss.

    batch: {"tokens": (B,S) int32} plus family extras:
      encdec: {"frames": (B, enc_seq, D)}   (stub audio frontend output)
      vlm:    {"patches": (B, vision_prefix, D)} (stub vision tower output)
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    dt = COMPUTE_DTYPE
    x = constrain(jnp.take(params["embed"], tokens, axis=0).astype(dt))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    n_prefix = 0
    if cfg.vision_prefix and "patches" in batch:
        prefix = batch["patches"].astype(dt)
        n_prefix = prefix.shape[1]
        x = jnp.concatenate([prefix, x], axis=1)
        positions = jnp.broadcast_to(
            jnp.arange(s + n_prefix, dtype=jnp.int32), (b, s + n_prefix)
        )
    aux = jnp.float32(0.0)
    if cfg.family == "lm":
        if "dense_blocks" in params:
            x, a = _scan_blocks(
                cfg, params["dense_blocks"], x, positions,
                lambda p, h, pos: _lm_block_apply(cfg, p, h, pos)[:2],
            )
            aux += a
        if "blocks" in params:
            x, a = _scan_blocks(
                cfg, params["blocks"], x, positions,
                lambda p, h, pos: _lm_block_apply(cfg, p, h, pos)[:2],
            )
            aux += a
    elif cfg.family == "rglru":
        pat = cfg.block_pattern

        def super_apply(p, h, pos):
            for i, kind in enumerate(pat):
                h, _ = _rglru_block_apply(cfg, p[f"l{i}"], h, pos, kind)
            return h, jnp.float32(0.0)

        x, _ = _scan_blocks(cfg, params["super_blocks"], x, positions, super_apply)
        if "tail_blocks" in params:
            x, _ = _scan_blocks(
                cfg, params["tail_blocks"], x, positions,
                lambda p, h, pos: (_rglru_block_apply(cfg, p, h, pos, pat[0])[0], jnp.float32(0.0)),
            )
    elif cfg.family == "rwkv6":
        x, _ = _scan_blocks(
            cfg, params["blocks"], x, positions,
            lambda p, h, pos: (_rwkv_block_apply(cfg, p, h)[0], jnp.float32(0.0)),
        )
    elif cfg.family == "encdec":
        enc = _encode(cfg, params, batch["frames"])
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc.shape[1], dtype=jnp.int32), enc.shape[:2]
        )
        x = x + _sinusoidal(positions, cfg.d_model).astype(dt)

        def dec_apply(p, h, pos):
            h1 = norm_apply(cfg, p["norm1"], h)
            a_out, _ = attention_apply(cfg, p["attn"], h1, positions=pos, causal=True)
            h = h + a_out
            h2 = norm_apply(cfg, p["norm2"], h)
            kv = _cross_kv(cfg, p["cross"], enc)
            c_out, _ = attention_apply(
                cfg, p["cross"], h2, positions=pos, kv_override=kv + (enc_pos,)
            )
            h = h + c_out
            h3 = norm_apply(cfg, p["norm3"], h)
            return h + mlp_apply(cfg, p["mlp"], h3), jnp.float32(0.0)

        x, _ = _scan_blocks(cfg, params["blocks"], x, positions, dec_apply)
    x = norm_apply(cfg, params["final_norm"], x)
    if n_prefix:
        x = x[:, n_prefix:]
    return x, aux


def _cross_kv(cfg, p, enc):
    dt = enc.dtype
    b, se, _ = enc.shape
    hd = cfg.head_dim_
    k = (enc @ p["w_k"].astype(dt)).reshape(b, se, cfg.n_kv_heads, hd)
    v = (enc @ p["w_v"].astype(dt)).reshape(b, se, cfg.n_kv_heads, hd)
    return k, v


def _encode(cfg: ModelConfig, params, frames):
    dt = COMPUTE_DTYPE
    x = frames.astype(dt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = x + _sinusoidal(positions, cfg.d_model).astype(dt)

    def enc_apply(p, h, pos):
        h1 = norm_apply(cfg, p["norm1"], h)
        a_out, _ = attention_apply(cfg, p["attn"], h1, positions=pos, causal=False)
        h = h + a_out
        h2 = norm_apply(cfg, p["norm2"], h)
        return h + mlp_apply(cfg, p["mlp"], h2), jnp.float32(0.0)

    x, _ = _scan_blocks(cfg, params["enc_blocks"], x, positions, enc_apply)
    return norm_apply(cfg, params["enc_final_norm"], x)


# ---------------------------------------------------------------------------
# Loss (chunked cross-entropy) and prefill
# ---------------------------------------------------------------------------


def _lm_head(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def chunked_ce(cfg, params, hidden, targets, mask):
    """Mean next-token CE without materializing (B, S, V)."""
    head = _lm_head(cfg, params).astype(COMPUTE_DTYPE)
    b, s, d = hidden.shape
    n = -(-s // CE_CHUNK)
    pad = n * CE_CHUNK - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = hidden.reshape(b, n, CE_CHUNK, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, n, CE_CHUNK).transpose(1, 0, 2)
    mc = mask.reshape(b, n, CE_CHUNK).transpose(1, 0, 2)

    def step(acc, inp):
        h, t, m = inp
        logits = (h @ head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return (acc[0] + nll.sum(), acc[1] + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(step, policy=_remat_policy()),
        (jnp.float32(0.0), jnp.float32(0.0)),
        (hc, tc, mc),
    )
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(cfg: ModelConfig, params, batch: dict):
    hidden, aux = forward(cfg, params, batch)
    tokens = batch["tokens"]
    targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.concatenate(
        [
            jnp.ones(tokens[:, 1:].shape, jnp.float32),
            jnp.zeros(tokens[:, :1].shape, jnp.float32),
        ],
        axis=1,
    )
    ce = chunked_ce(cfg, params, hidden, targets, mask)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


def prefill(cfg: ModelConfig, params, batch: dict):
    """Full-prompt forward returning last-position logits (B, V)."""
    hidden, _ = forward(cfg, params, batch)
    head = _lm_head(cfg, params).astype(COMPUTE_DTYPE)
    return (hidden[:, -1] @ head).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Decode (cache init + single-token step)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family == "lm":
        n_moe = cfg.n_layers - cfg.n_dense_layers if cfg.moe else 0
        n_dense = cfg.n_layers - n_moe
        window = cfg.window if cfg.attn_kind == "swa" else 0

        def one(_):
            if cfg.mla is not None:
                return mla_cache_init(cfg, batch, max_len)
            return attention_cache_init(cfg, batch, max_len, window)

        cache = {}
        if n_dense:
            cache["dense_blocks"] = jax.vmap(one)(jnp.arange(n_dense))
        if n_moe:
            cache["blocks"] = jax.vmap(one)(jnp.arange(n_moe))
        return cache
    if cfg.family == "rglru":
        pat = cfg.block_pattern
        n_super, n_tail = divmod(cfg.n_layers, len(pat))

        def one_super(_):
            return {
                f"l{i}": (
                    rglru_state_init(cfg, batch)
                    if kind == "rec"
                    else attention_cache_init(cfg, batch, max_len, cfg.window)
                )
                for i, kind in enumerate(pat)
            }

        cache = {"super_blocks": jax.vmap(one_super)(jnp.arange(n_super))}
        if n_tail:
            cache["tail_blocks"] = jax.vmap(lambda _: rglru_state_init(cfg, batch))(
                jnp.arange(n_tail)
            )
        return cache
    if cfg.family == "rwkv6":
        return {
            "blocks": jax.vmap(lambda _: rwkv6_state_init(cfg, batch))(
                jnp.arange(cfg.n_layers)
            )
        }
    if cfg.family == "encdec":
        # cross-attention K/V are recomputed from cached encoder output
        return {
            "blocks": jax.vmap(
                lambda _: attention_cache_init(cfg, batch, max_len, 0)
            )(jnp.arange(cfg.n_layers)),
            "enc_out": jnp.zeros((batch, cfg.enc_seq, cfg.d_model), COMPUTE_DTYPE),
        }
    raise ValueError(cfg.family)


def _scan_decode(stacked_params, stacked_cache, x, step_fn):
    def f(h, inp):
        layer_p, layer_c = inp
        h, new_c = step_fn(layer_p, layer_c, h)
        return constrain(h), new_c

    return jax.lax.scan(f, constrain(x), (stacked_params, stacked_cache))


def decode_step(cfg: ModelConfig, params, cache, batch: dict):
    """One-token step.  batch: {"tokens": (B,1), "positions": (B,1)}.
    Returns (logits (B, V) fp32, new_cache)."""
    tokens, positions = batch["tokens"], batch["positions"]
    dt = COMPUTE_DTYPE
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    new_cache = {}
    if cfg.family == "lm":
        def step(p, c, h):
            h, _, nc = _lm_block_apply(cfg, p, h, positions, cache=c)
            return h, nc

        if "dense_blocks" in params:
            x, nc = _scan_decode(params["dense_blocks"], cache["dense_blocks"], x, step)
            new_cache["dense_blocks"] = nc
        if "blocks" in params:
            x, nc = _scan_decode(params["blocks"], cache["blocks"], x, step)
            new_cache["blocks"] = nc
    elif cfg.family == "rglru":
        pat = cfg.block_pattern

        def super_step(p, c, h):
            new_c = {}
            for i, kind in enumerate(pat):
                h, new_c[f"l{i}"] = _rglru_block_apply(
                    cfg, p[f"l{i}"], h, positions, kind, state=c[f"l{i}"]
                )
            return h, new_c

        x, nc = _scan_decode(params["super_blocks"], cache["super_blocks"], x, super_step)
        new_cache["super_blocks"] = nc
        if "tail_blocks" in params:
            def tail_step(p, c, h):
                return _rglru_block_apply(cfg, p, h, positions, pat[0], state=c)

            x, nc = _scan_decode(params["tail_blocks"], cache["tail_blocks"], x, tail_step)
            new_cache["tail_blocks"] = nc
    elif cfg.family == "rwkv6":
        def step(p, c, h):
            return _rwkv_block_apply(cfg, p, h, state=c)

        x, nc = _scan_decode(params["blocks"], cache["blocks"], x, step)
        new_cache["blocks"] = nc
    elif cfg.family == "encdec":
        enc = cache["enc_out"].astype(dt)
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc.shape[1], dtype=jnp.int32), enc.shape[:2]
        )
        x = x + _sinusoidal(positions, cfg.d_model).astype(dt)

        def step(p, c, h):
            h1 = norm_apply(cfg, p["norm1"], h)
            a_out, nc = attention_apply(
                cfg, p["attn"], h1, positions=positions, causal=True, cache=c
            )
            h = h + a_out
            h2 = norm_apply(cfg, p["norm2"], h)
            kv = _cross_kv(cfg, p["cross"], enc)
            c_out, _ = attention_apply(
                cfg, p["cross"], h2, positions=positions, kv_override=kv + (enc_pos,)
            )
            h = h + c_out
            h3 = norm_apply(cfg, p["norm3"], h)
            return h + mlp_apply(cfg, p["mlp"], h3), nc

        x, nc = _scan_decode(params["blocks"], cache["blocks"], x, step)
        new_cache = {"blocks": nc, "enc_out": cache["enc_out"]}
    x = norm_apply(cfg, params["final_norm"], x)
    head = _lm_head(cfg, params).astype(dt)
    logits = (x[:, -1] @ head).astype(jnp.float32)
    return logits, new_cache
