"""Model/shape configuration for the assigned architecture pool.

One ``ModelConfig`` covers every family (dense GQA, enc-dec, MLA+MoE,
SWA+MoE, VLM, RG-LRU hybrid, RWKV6) via feature fields; ``family`` selects
the forward implementation.  ``ShapeSpec`` enumerates the assigned input
shapes; decode shapes lower ``serve_step`` (single token + KV cache), not
``train_step``.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

Family = Literal["lm", "encdec", "rglru", "rwkv6"]
AttnKind = Literal["full", "swa", "local"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention dims."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    attn_kind: AttnKind = "full"
    window: int = 0  # swa / local attention window
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    n_dense_layers: int = 0  # leading dense layers before MoE layers
    # hybrid (recurrentgemma): block pattern, e.g. ("rec", "rec", "attn")
    block_pattern: tuple[str, ...] = ()
    lru_width: int = 0
    # rwkv6
    rwkv_head_dim: int = 64
    # enc-dec
    n_enc_layers: int = 0
    enc_seq: int = 0  # stub frontend sequence length (audio frames / patches)
    # vlm: number of prefix patch embeddings from the (stub) vision tower
    vision_prefix: int = 0
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # long_500k applicability: True iff memory/compute are sub-quadratic in
    # context (SSM / hybrid-local / sliding-window); see DESIGN.md section 4.
    subquadratic: bool = False

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        total += self._block_params()
        if self.family == "encdec":
            total += self.enc_seq * d  # encoder positional table
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        total += self._block_params(active_only=True)
        return total

    def _block_params(self, active_only: bool = False) -> int:
        d = self.d_model
        hd = self.head_dim_
        n_moe_layers = max(self.n_layers - self.n_dense_layers, 0) if self.moe else 0
        n_dense = self.n_layers - n_moe_layers
        total = 0
        # attention / mixer params per layer
        if self.family == "rwkv6":
            per_mix = 4 * d * d + 6 * d * 32 * 2  # r,k,v,o + lora decay/mix
        elif self.mla is not None:
            m = self.mla
            per_mix = (
                d * m.q_lora_rank
                + m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                + d * (m.kv_lora_rank + m.qk_rope_dim)
                + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        else:
            per_mix = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.family == "rglru":
            # mixer params vary by block type; approximate with the mean
            n_rec = sum(1 for b in self.block_pattern if b == "rec")
            n_att = len(self.block_pattern) - n_rec
            w = self.lru_width or d
            per_rec = 2 * d * w + w * d + 4 * w  # in-proj x2, out-proj, gates
            per_mix = (per_rec * n_rec + per_mix * n_att) / max(
                len(self.block_pattern), 1
            )
        mlp_mult = 3 if self.act in ("swiglu", "geglu") else 2
        per_dense_mlp = mlp_mult * d * self.d_ff
        total += self.n_layers * per_mix + n_dense * per_dense_mlp
        if self.moe:
            e_all = self.moe.top_k if active_only else self.moe.n_experts
            per_moe = (
                e_all * mlp_mult * d * self.moe.d_ff_expert
                + self.moe.n_shared * mlp_mult * d * self.moe.d_ff_shared
                + d * self.moe.n_experts  # router
            )
            total += n_moe_layers * per_moe
        if self.family == "encdec":
            # encoder blocks + decoder cross-attention
            total += self.n_enc_layers * (per_mix + per_dense_mlp)
            total += self.n_layers * per_mix  # cross-attn per decoder layer
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, spec: ShapeSpec) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs; reason recorded when skipped."""
    if spec.name == "long_500k" and not cfg.subquadratic:
        return False, (
            "full-attention arch: 500k context needs sub-quadratic attention "
            "(DESIGN.md section 4)"
        )
    return True, ""
