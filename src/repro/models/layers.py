"""Shared neural building blocks (pure-JAX, no flax).

Conventions:
  * params are pytrees of fp32 arrays (master copy); ``apply`` functions cast
    to the compute dtype (bf16 by default) at the edges and keep
    norms/softmax in fp32,
  * all sequence mixers support three modes: train/prefill over a full
    sequence (optionally blockwise for long context) and single-token decode
    against a cache,
  * weights are stored (d_in, d_out) so the TP sharding rules in
    launch/shardings.py can pattern-match on path names.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import MLAConfig, ModelConfig, MoEConfig

COMPUTE_DTYPE = jnp.bfloat16


def _init(rng, shape, scale=0.02):
    return scale * jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)


def rmsnorm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(x.dtype)


def norm_init(cfg: ModelConfig, d: int):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def norm_apply(cfg: ModelConfig, p, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dim = x.shape[-1]
    freqs = rope_freqs(dim, theta)  # (dim/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S,1,dim/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (swiglu / geglu / gelu)
# ---------------------------------------------------------------------------


def mlp_init(rng, cfg: ModelConfig, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(rng, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {
            "w_gate": _init(k1, (d_model, d_ff)),
            "w_up": _init(k2, (d_model, d_ff)),
            "w_down": _init(k3, (d_ff, d_model)),
        }
    return {"w_up": _init(k1, (d_model, d_ff)), "w_down": _init(k2, (d_ff, d_model))}


def mlp_apply(cfg: ModelConfig, p, x):
    dt = x.dtype
    if cfg.act in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = act(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    else:
        h = jax.nn.gelu(x @ p["w_up"].astype(dt))
    return h @ p["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# Dense attention (GQA; full / sliding-window / local), train + decode
# ---------------------------------------------------------------------------

BLOCKWISE_THRESHOLD = 8_192  # above this, use the kv-chunked online-softmax path
KV_CHUNK = 1_024


def set_blockwise_threshold(n: int) -> None:
    "Perf knob: sequence length above which attention goes kv-chunked."
    global BLOCKWISE_THRESHOLD
    BLOCKWISE_THRESHOLD = n


def attention_init(rng, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {
        "w_q": _init(k1, (d, cfg.n_heads * hd)),
        "w_k": _init(k2, (d, cfg.n_kv_heads * hd)),
        "w_v": _init(k3, (d, cfg.n_kv_heads * hd)),
        "w_o": _init(k4, (cfg.n_heads * hd, d)),
    }


def _mask_bias(q_pos, k_pos, *, causal: bool, window: int):
    """(..., Sq, Sk) additive mask in fp32."""
    ok = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        ok &= q_pos[..., :, None] >= k_pos[..., None, :]
    if window > 0:
        ok &= q_pos[..., :, None] - k_pos[..., None, :] < window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def _sdpa(q, k, v, bias):
    """q:(B,Sq,H,D) k,v:(B,Sk,Hkv,D) bias:(B,Sq,Sk) -> (B,Sq,H,D)."""
    h, hkv = q.shape[2], k.shape[2]
    group = h // hkv
    scale = 1.0 / np.sqrt(q.shape[-1])
    qg = q.reshape(q.shape[0], q.shape[1], hkv, group, q.shape[3])
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    scores = scores + bias[:, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(q.shape)


def _sdpa_blockwise(q, k, v, q_pos, k_pos, *, causal: bool, window: int):
    """kv-chunked online-softmax attention: O(Sq * chunk) live memory.

    Scans kv chunks, maintaining (m, l, acc) running max / normalizer /
    weighted accumulator per query -- the flash-attention recurrence in pure
    jnp (a Pallas kernel would fuse this on real TPUs; the lowered scan keeps
    peak activation memory bounded for the 32k/500k shape cells).
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    group = h // hkv
    sk = k.shape[1]
    n_chunks = -(-sk // KV_CHUNK)
    pad = n_chunks * KV_CHUNK - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2**30)
    kc = k.reshape(b, n_chunks, KV_CHUNK, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, KV_CHUNK, hkv, d).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(b, n_chunks, KV_CHUNK).transpose(1, 0, 2)
    qg = q.reshape(b, sq, hkv, group, d)
    scale = 1.0 / np.sqrt(d)

    def step(carry, chunk):
        m, l, acc = carry
        kb, vb, pb = chunk
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb).astype(jnp.float32) * scale
        bias = _mask_bias(q_pos, pb, causal=causal, window=window)
        scores = scores + bias[:, None, None]
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(q.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, group, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, group, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


def attention_apply(
    cfg: ModelConfig,
    p,
    x,
    *,
    positions,
    causal: bool = True,
    window: int = 0,
    cache: Optional[dict] = None,
    kv_override: Optional[tuple] = None,
    n_kv_heads: Optional[int] = None,
):
    """GQA attention.  cache (decode): {"k","v","pos","index"} ring/linear
    buffer updated functionally.  kv_override: (k, v, k_pos) for
    cross-attention (encoder outputs).  Returns (out, new_cache)."""
    dt = x.dtype
    b, s, d = x.shape
    hd = cfg.head_dim_
    n_kv = n_kv_heads if n_kv_heads is not None else cfg.n_kv_heads
    q = (x @ p["w_q"].astype(dt)).reshape(b, s, -1, hd)
    if kv_override is None:
        k = (x @ p["w_k"].astype(dt)).reshape(b, s, n_kv, hd)
        v = (x @ p["w_v"].astype(dt)).reshape(b, s, n_kv, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v, k_positions = kv_override
    new_cache = None
    if cache is not None and kv_override is None:
        # single-token (or short) decode append into a ring buffer
        idx = cache["index"]
        size = cache["k"].shape[1]
        slot = jax.lax.rem(idx + jnp.arange(s), size)
        ck = cache["k"].at[:, slot].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[:, slot].set(v.astype(cache["v"].dtype))
        cpos = cache["pos"].at[:, slot].set(positions.astype(cache["pos"].dtype))
        new_cache = {"k": ck, "v": cv, "pos": cpos, "index": idx + s}
        k, v, k_positions = ck.astype(dt), cv.astype(dt), cpos
        bias = _mask_bias(positions, k_positions, causal=True, window=window)
        out = _sdpa(q, k, v, bias)
    elif kv_override is not None:
        bias = _mask_bias(positions, k_positions, causal=False, window=0)
        out = _sdpa(q, k, v, bias)
    else:
        if s > BLOCKWISE_THRESHOLD:
            out = _sdpa_blockwise(
                q, k, v, positions, positions, causal=causal, window=window
            )
        else:
            bias = _mask_bias(positions, positions, causal=causal, window=window)
            out = _sdpa(q, k, v, bias)
    return out.reshape(b, s, -1) @ p["w_o"].astype(dt), new_cache


def attention_cache_init(cfg: ModelConfig, batch: int, max_len: int, window: int = 0):
    """Ring-buffer cache; windowed attention only keeps ``window`` slots."""
    size = min(max_len, window) if window > 0 else max_len
    hd = cfg.head_dim_
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, hd), COMPUTE_DTYPE),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, hd), COMPUTE_DTYPE),
        # empty slots sit in the "future" so the causal mask excludes them
        "pos": jnp.full((batch, size), 2**30, jnp.int32),
        "index": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MoE (grouped dense dispatch, Mesh-TF style; EP-shardable einsums)
# ---------------------------------------------------------------------------

MOE_GROUP = 256  # tokens per dispatch group


def moe_init(rng, cfg: ModelConfig, moe: MoEConfig):
    d = cfg.d_model
    keys = jax.random.split(rng, 5)
    mult = 3 if cfg.act in ("swiglu", "geglu") else 2
    p = {
        "router": _init(keys[0], (d, moe.n_experts), scale=0.01),
        "w_gate": _init(keys[1], (moe.n_experts, d, moe.d_ff_expert)),
        "w_up": _init(keys[2], (moe.n_experts, d, moe.d_ff_expert)),
        "w_down": _init(keys[3], (moe.n_experts, moe.d_ff_expert, d)),
    }
    if mult == 2:
        del p["w_up"]
    if moe.n_shared:
        p["shared"] = mlp_init(keys[4], cfg, d, moe.d_ff_shared * moe.n_shared)
    return p


def moe_apply(cfg: ModelConfig, p, x, moe: MoEConfig):
    """Grouped dense dispatch: tokens -> (expert, capacity) slots via one-hot
    einsums (collective-clean under GSPMD; the expert axis shards over the
    mesh 'model' axis for EP).  Returns (y, aux_loss)."""
    dt = x.dtype
    b, s, d = x.shape
    n_tok = b * s
    g = max(n_tok // MOE_GROUP, 1)
    xt = x.reshape(g, -1, d)  # (G, Sg, D)
    sg = xt.shape[1]
    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)  # (G,Sg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, moe.top_k)  # (G,Sg,K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )  # renormalize
    cap = int(max(sg * moe.top_k / moe.n_experts * moe.capacity_factor, 4))
    onehot = jax.nn.one_hot(idx, moe.n_experts, dtype=jnp.float32)  # (G,Sg,K,E)
    pos = (jnp.cumsum(onehot.reshape(g, sg * moe.top_k, moe.n_experts), axis=1) - 1.0)
    pos = pos.reshape(g, sg, moe.top_k, moe.n_experts) * onehot
    keep = (pos < cap) & (onehot > 0)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32) * keep[
        ..., None
    ]
    # dispatch: (G,Sg,K,E,C) x (G,Sg,D) -> (G,E,C,D)
    dispatch = pos_oh  # (G,Sg,K,E,C)
    xe = jnp.einsum("gskec,gsd->gecd", dispatch.astype(dt), xt)
    act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
    if "w_up" in p:
        h = act(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dt)))
        h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dt))
    else:
        h = act(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dt)))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    combine = (dispatch * gate_vals[..., None, None]).astype(dt)  # (G,Sg,K,E,C)
    y = jnp.einsum("gskec,gecd->gsd", combine, ye)
    # Switch-style load-balancing auxiliary loss
    density = onehot.mean(axis=(1, 2))  # (G,E) fraction routed
    density_probs = probs.mean(axis=1)  # (G,E)
    aux = (density * density_probs).sum(-1).mean() * (moe.n_experts**2 / moe.top_k)
    if moe.n_shared:
        y = y + mlp_apply(cfg, p["shared"], xt)
    return y.reshape(b, s, d), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention) with compressed KV cache
# ---------------------------------------------------------------------------


def mla_init(rng, cfg: ModelConfig, mla: MLAConfig):
    d, h = cfg.d_model, cfg.n_heads
    keys = jax.random.split(rng, 8)
    qk = mla.qk_nope_dim + mla.qk_rope_dim
    return {
        "w_dq": _init(keys[0], (d, mla.q_lora_rank)),
        "q_norm": jnp.ones((mla.q_lora_rank,), jnp.float32),
        "w_uq": _init(keys[1], (mla.q_lora_rank, h * qk)),
        "w_dkv": _init(keys[2], (d, mla.kv_lora_rank)),
        "kv_norm": jnp.ones((mla.kv_lora_rank,), jnp.float32),
        "w_kr": _init(keys[3], (d, mla.qk_rope_dim)),
        "w_uk": _init(keys[4], (mla.kv_lora_rank, h * mla.qk_nope_dim)),
        "w_uv": _init(keys[5], (mla.kv_lora_rank, h * mla.v_head_dim)),
        "w_o": _init(keys[6], (h * mla.v_head_dim, d)),
    }


def mla_apply(cfg: ModelConfig, p, x, *, positions, cache=None):
    """Returns (out, new_cache); cache = compressed (c_kv, k_rope) -- the
    paper-faithful memory win (kv_lora + rope dims per token, not 2*H*hd)."""
    mla = cfg.mla
    dt = x.dtype
    b, s, d = x.shape
    h = cfg.n_heads
    cq = rmsnorm(x @ p["w_dq"].astype(dt), p["q_norm"])
    q = (cq @ p["w_uq"].astype(dt)).reshape(b, s, h, -1)
    q_nope, q_rope = jnp.split(q, [mla.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = rmsnorm(x @ p["w_dkv"].astype(dt), p["kv_norm"])  # (b,s,kv_lora)
    krope = apply_rope(
        (x @ p["w_kr"].astype(dt)).reshape(b, s, 1, mla.qk_rope_dim),
        positions,
        cfg.rope_theta,
    )
    new_cache = None
    if cache is not None:
        idx = cache["index"]
        size = cache["ckv"].shape[1]
        slot = jax.lax.rem(idx + jnp.arange(s), size)
        cckv = cache["ckv"].at[:, slot].set(ckv.astype(cache["ckv"].dtype))
        ckr = cache["krope"].at[:, slot].set(krope[:, :, 0].astype(cache["krope"].dtype))
        cpos = cache["pos"].at[:, slot].set(positions.astype(jnp.int32))
        new_cache = {"ckv": cckv, "krope": ckr, "pos": cpos, "index": idx + s}
        ckv_all, krope_all, k_pos = cckv.astype(dt), ckr.astype(dt), cpos
    else:
        ckv_all, krope_all, k_pos = ckv, krope[:, :, 0], positions
    k_nope = (ckv_all @ p["w_uk"].astype(dt)).reshape(b, -1, h, mla.qk_nope_dim)
    v = (ckv_all @ p["w_uv"].astype(dt)).reshape(b, -1, h, mla.v_head_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope_all[:, :, None], k_nope.shape[:3] + (mla.qk_rope_dim,))],
        axis=-1,
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    bias = _mask_bias(positions, k_pos, causal=True, window=0)
    if s > BLOCKWISE_THRESHOLD and cache is None:
        out = _sdpa_blockwise(q_full, k, v_pad(v, k), positions, k_pos, causal=True, window=0)
        out = out[..., : mla.v_head_dim]
    else:
        out = _sdpa_mixed(q_full, k, v, bias)
    return out.reshape(b, s, -1) @ p["w_o"].astype(dt), new_cache


def v_pad(v, k):
    """Pad v head_dim up to k's head_dim so the blockwise kernel (which
    assumes equal q/k/v dims) can be reused; caller slices back."""
    pad = k.shape[-1] - v.shape[-1]
    if pad <= 0:
        return v
    return jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))


def _sdpa_mixed(q, k, v, bias):
    """MHA attention where v head dim differs from qk head dim."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    scores = scores + bias[:, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int):
    mla = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, mla.kv_lora_rank), COMPUTE_DTYPE),
        "krope": jnp.zeros((batch, max_len, mla.qk_rope_dim), COMPUTE_DTYPE),
        # empty slots sit in the "future" so the causal mask excludes them
        "pos": jnp.full((batch, max_len), 2**30, jnp.int32),
        "index": jnp.zeros((), jnp.int32),
    }
