"""Recurrent sequence mixers: RG-LRU (RecurrentGemma/Griffin) and RWKV6.

Both are linear recurrences, so the train path avoids token-by-token scans:

  * RG-LRU: elementwise h_t = a_t * h_{t-1} + b_t -> jax.lax.associative_scan
    (log-depth, TPU-friendly).
  * RWKV6: matrix-state S_t = diag(w_t) S_{t-1} + k_t v_t^T -> chunked linear
    attention (scan over chunks of CHUNK tokens, einsums within a chunk),
    the standard O(T/C) formulation with log-space cumulative decays.

Decode paths carry constant-size state: (B, width) for RG-LRU, the conv1d
tail, and (B, H, dk, dv) for RWKV6 -- this is why these archs run the
long_500k cell (DESIGN.md section 4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _init, rmsnorm

RWKV_CHUNK = 128
LRU_C = 8.0  # Griffin's fixed recurrence-sharpness constant
CONV_WIDTH = 4


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block: in-proj -> conv1d -> RG-LRU -> gate)
# ---------------------------------------------------------------------------


def rglru_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_width or d
    keys = jax.random.split(rng, 7)
    # a_param initialised so a = sigmoid(a_param) in [0.9, 0.999]-ish
    a_init = jnp.log(jnp.expm1(-(jnp.log(jnp.linspace(0.9, 0.999, w)))))
    return {
        "w_x": _init(keys[0], (d, w)),
        "w_gate": _init(keys[1], (d, w)),
        "conv_w": _init(keys[2], (CONV_WIDTH, w)),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "w_rg": _init(keys[3], (w, w)),  # recurrence gate
        "w_ig": _init(keys[4], (w, w)),  # input gate
        "a_param": -a_init.astype(jnp.float32),
        "w_out": _init(keys[5], (w, d)),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv1d, width CONV_WIDTH.  state: (B, W-1, C) tail of
    the previous tokens (decode).  Returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(width)
    ) + b.astype(x.dtype)
    new_state = xp[:, -(width - 1) :]
    return y, new_state


def rglru_apply(cfg: ModelConfig, p, x, *, state=None):
    """x: (B,S,D).  state (decode): {"h": (B,W), "conv": (B,3,W)}.
    Returns (out, new_state)."""
    dt = x.dtype
    xb = x @ p["w_x"].astype(dt)  # (B,S,W)
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt))
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)
    r = jax.nn.sigmoid((xc @ p["w_rg"].astype(dt)).astype(jnp.float32))
    i = jax.nn.sigmoid((xc @ p["w_ig"].astype(dt)).astype(jnp.float32))
    log_a = -LRU_C * r * jax.nn.softplus(p["a_param"])  # (B,S,W) fp32, <= 0
    a = jnp.exp(log_a)
    gated_x = i * xc.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    if state is None:
        # associative scan over the linear recurrence h_t = a_t h_{t-1} + b_t
        def combine(l, r_):
            (al, bl), (ar, br) = l, r_
            return al * ar, br + ar * bl

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_h = h[:, -1]
    else:
        h_prev = state["h"].astype(jnp.float32)  # (B,W)

        def step(hc, ab):
            at, bt = ab
            hn = at * hc + bt
            return hn, hn

        new_h, hs = jax.lax.scan(
            step, h_prev, (a.transpose(1, 0, 2), b.transpose(1, 0, 2))
        )
        h = hs.transpose(1, 0, 2)
    out = (h.astype(dt) * gate) @ p["w_out"].astype(dt)
    new_state = {"h": new_h.astype(jnp.float32), "conv": new_conv.astype(jnp.float32)}
    return out, new_state


def rglru_state_init(cfg: ModelConfig, batch: int):
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, w), jnp.float32),
    }


# ---------------------------------------------------------------------------
# RWKV6 (Finch) time-mix + channel-mix
# ---------------------------------------------------------------------------

RWKV_LORA = 32


def rwkv6_timemix_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    keys = jax.random.split(rng, 12)
    return {
        "mix_base": 0.5 * jnp.ones((5, d), jnp.float32),  # r,k,v,w,g shift mixes
        "mix_lora_a": _init(keys[0], (d, RWKV_LORA * 5)),
        "mix_lora_b": _init(keys[1], (5, RWKV_LORA, d)),
        "w_r": _init(keys[2], (d, d)),
        "w_k": _init(keys[3], (d, d)),
        "w_v": _init(keys[4], (d, d)),
        "w_g": _init(keys[5], (d, d)),
        "w_o": _init(keys[6], (d, d)),
        "decay_base": -6.0 * jnp.ones((d,), jnp.float32),
        "decay_lora_a": _init(keys[7], (d, 64)),
        "decay_lora_b": _init(keys[8], (64, d)),
        "bonus_u": _init(keys[9], (d,), scale=0.5),
        "ln_scale": jnp.ones((d,), jnp.float32),
    }


def _token_shift(x, prev):
    """prev: (B,1,D) last token of the previous segment (or zeros)."""
    return jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)


def _wkv_chunked(r, k, v, logw, u, head_dim: int, state=None):
    """Chunked WKV: r,k,v (B,S,D); logw (B,S,D) per-channel log-decay (<0);
    u (D,) bonus.  state: (B,H,dk,dv) carried matrix state.
    Returns (out (B,S,D), new_state)."""
    b, s, d = r.shape
    h = d // head_dim
    n = -(-s // RWKV_CHUNK)
    pad = n * RWKV_CHUNK - s
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0)))  # pad decay 0 => w=1

    def hsplit(x_):
        return x_.reshape(b, n, RWKV_CHUNK, h, head_dim).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, wc = hsplit(r), hsplit(k), hsplit(v), hsplit(logw)
    # (n, B, H, C, dk/dv) fp32 math
    rc, kc, vc = rc.astype(jnp.float32), kc.astype(jnp.float32), vc.astype(jnp.float32)
    wc = wc.astype(jnp.float32)
    uu = u.reshape(h, head_dim).astype(jnp.float32)
    s0 = (
        jnp.zeros((b, h, head_dim, head_dim), jnp.float32)
        if state is None
        else state.astype(jnp.float32)
    )

    def chunk_step(S, inp):
        rb, kb, vb, wb = inp  # (B,H,C,dk) etc.
        csum = jnp.cumsum(wb, axis=2)  # inclusive cumulative log decay
        p_incl = csum  # decay from chunk start through token i (inclusive)
        p_excl = csum - wb  # decay through token i-1
        # inter-chunk: r_i (decayed-from-state) @ S
        r_dec = rb * jnp.exp(p_excl)
        out = jnp.einsum("bhck,bhkv->bhcv", r_dec, S)
        # intra-chunk, per-channel decay:
        # scores_{ij} = sum_k r_ik k_jk exp(p_excl_i[k] - p_incl_j[k])  (j < i)
        #             = <r_i * exp(p_excl_i), k_j * exp(-p_incl_j)>
        ri = rb * jnp.exp(p_excl)  # p_excl <= 0: bounded
        # -p_incl >= 0 is unbounded for strong decays; clip at 30 -- pairs
        # beyond that have true weight exp(p_excl_i - p_incl_j) ~ 0 anyway
        # (production kernels renormalize per row; fine at smoke/dry scale).
        kj = kb * jnp.exp(jnp.clip(-p_incl, None, 30.0))
        scores = jnp.einsum("bhck,bhjk->bhcj", ri, kj)
        c = rb.shape[2]
        tri = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)
        out = out + jnp.einsum("bhcj,bhjv->bhcv", scores * tri, vb)
        # current-token bonus term: (r_i . (u * k_i)) v_i
        bonus = jnp.einsum("bhck,bhck->bhc", rb, uu[None, :, None, :] * kb)
        out = out + bonus[..., None] * vb
        # state update: S' = diag(exp(csum_C)) S + sum_j exp(csum_C - p_incl_j) k_j v_j^T
        total = csum[:, :, -1:, :]  # (B,H,1,dk)
        S_new = jnp.exp(total[:, :, 0, :, None]) * S + jnp.einsum(
            "bhjk,bhjv->bhkv", kb * jnp.exp(total - p_incl), vb
        )
        return S_new, out

    S_final, outs = jax.lax.scan(chunk_step, s0, (rc, kc, vc, wc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, n * RWKV_CHUNK, d)[:, :s]
    return out, S_final


def rwkv6_timemix_apply(cfg: ModelConfig, p, x, *, state=None):
    """state (decode): {"S": (B,H,dk,dv), "prev": (B,1,D)}."""
    dt = x.dtype
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    prev = (
        jnp.zeros((b, 1, d), dt) if state is None else state["prev"].astype(dt)
    )
    xs = _token_shift(x, prev)
    # data-dependent shift mixes (5 lora heads: r,k,v,w,g)
    delta = xs - x
    lora = jnp.tanh(x @ p["mix_lora_a"].astype(dt)).reshape(b, s, 5, RWKV_LORA)
    mixes = p["mix_base"].astype(dt)[None, None] + jnp.einsum(
        "bslr,lrd->bsld", lora, p["mix_lora_b"].astype(dt)
    )
    xr, xk, xv, xw, xg = [
        x + delta * mixes[:, :, i] for i in range(5)
    ]
    r = xr @ p["w_r"].astype(dt)
    k = xk @ p["w_k"].astype(dt)
    v = xv @ p["w_v"].astype(dt)
    g = jax.nn.silu(xg @ p["w_g"].astype(dt))
    decay_in = jnp.tanh(xw @ p["decay_lora_a"].astype(dt)) @ p["decay_lora_b"].astype(dt)
    logw = -jnp.exp(
        (p["decay_base"].astype(jnp.float32) + decay_in.astype(jnp.float32))
    )  # (B,S,D) < 0
    prev_S = None if state is None else state["S"]
    wkv, new_S = _wkv_chunked(r, k, v, logw, p["bonus_u"], hd, prev_S)
    # per-head groupnorm, then the learned output scale
    wkv = wkv.reshape(b, s, d // hd, hd)
    wkv = rmsnorm(wkv, jnp.ones((hd,), jnp.float32)).reshape(b, s, d)
    wkv = wkv.astype(dt) * p["ln_scale"].astype(dt)
    out = (wkv * g) @ p["w_o"].astype(dt)
    new_state = {"S": new_S, "prev": x[:, -1:].astype(jnp.float32)}
    return out, new_state


def rwkv6_channelmix_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    keys = jax.random.split(rng, 3)
    return {
        "mix_k": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_r": 0.5 * jnp.ones((d,), jnp.float32),
        "w_k": _init(keys[0], (d, cfg.d_ff)),
        "w_v": _init(keys[1], (cfg.d_ff, d)),
        "w_r": _init(keys[2], (d, d)),
    }


def rwkv6_channelmix_apply(cfg: ModelConfig, p, x, *, state=None):
    """state (decode): {"prev": (B,1,D)}."""
    dt = x.dtype
    prev = (
        jnp.zeros((x.shape[0], 1, x.shape[2]), dt)
        if state is None
        else state["prev"].astype(dt)
    )
    xs = _token_shift(x, prev)
    xk = x + (xs - x) * p["mix_k"].astype(dt)
    xr = x + (xs - x) * p["mix_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"].astype(dt)))
    rr = jax.nn.sigmoid(xr @ p["w_r"].astype(dt))
    out = rr * (kk @ p["w_v"].astype(dt))
    return out, {"prev": x[:, -1:].astype(jnp.float32)}


def rwkv6_state_init(cfg: ModelConfig, batch: int):
    d, hd = cfg.d_model, cfg.rwkv_head_dim
    return {
        "time": {
            "S": jnp.zeros((batch, d // hd, hd, hd), jnp.float32),
            "prev": jnp.zeros((batch, 1, d), jnp.float32),
        },
        "channel": {"prev": jnp.zeros((batch, 1, d), jnp.float32)},
    }
