"""Deterministic data pipeline with ASURA shard placement.

The training corpus is split into fixed-size shards (the paper's "data");
each shard id is placed onto an ingest host by ASURA, so

  * placement is computed locally on every host from the O(N) segment table
    (no placement service / manifest to distribute -- the paper's
    algorithm-management argument vs. table management, section "intro"),
  * hosts receive shards uniformly in proportion to their ingest capacity,
  * elastic events (host joins/leaves) move only the provably-minimal set of
    shards (paper section 2.A; re-verified here in tests/test_runtime.py).

Shard payloads are synthesized deterministically from the shard id (token
streams), so any host can (re)materialize any shard it owns -- which is also
how straggler backup tasks work (runtime/straggler.py).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core import Cluster
from repro.core.rng import draw_u32_np


def synthetic_shard(shard_id: int, *, tokens_per_shard: int, vocab: int) -> np.ndarray:
    """Deterministic, LEARNABLE token stream for a shard id.

    Counting sequences (t_{j+1} = t_j + 1 mod p) with a per-shard phase and
    ~6% hash noise: a model that learns the successor bigram drives CE well
    below ln(vocab), which end-to-end training tests rely on; the noise keeps
    the task non-degenerate.  O(1) state: any position is recomputable."""
    n = tokens_per_shard
    period = min(97, vocab)
    pos = np.arange(n, dtype=np.uint32)
    ids = np.full(n, shard_id, dtype=np.uint32)
    phase = draw_u32_np(ids[:1], np.uint32(6), np.zeros(1, np.uint32))[0]
    base = (phase + pos) % np.uint32(period)
    noise_draw = draw_u32_np(ids, np.uint32(7), pos)
    noisy = noise_draw % np.uint32(vocab)
    use_noise = (noise_draw >> np.uint32(16)) % np.uint32(16) == 0
    return np.where(use_noise, noisy, base).astype(np.int32)


@dataclasses.dataclass
class ShardedDataset:
    n_shards: int
    tokens_per_shard: int
    vocab: int

    def shard(self, shard_id: int) -> np.ndarray:
        if not 0 <= shard_id < self.n_shards:
            raise IndexError(shard_id)
        return synthetic_shard(
            shard_id, tokens_per_shard=self.tokens_per_shard, vocab=self.vocab
        )


class DataPipeline:
    """Per-host view: iterate (batch, seq) token batches from owned shards."""

    def __init__(
        self,
        dataset: ShardedDataset,
        cluster: Cluster,
        host_id: int,
        *,
        batch_per_host: int,
        seq_len: int,
        seed: int = 0,
    ):
        self.dataset = dataset
        self.cluster = cluster
        # Every pipeline on a host shares the cluster's PlacementEngine, so
        # ownership recomputes (initial + refresh_membership after elastic
        # events) reuse one cached table artifact per membership version.
        self.engine = cluster.engine
        self.host_id = host_id
        self.batch_per_host = batch_per_host
        self.seq_len = seq_len
        self.seed = seed
        self._owned = self._compute_owned()

    def _compute_owned(self) -> np.ndarray:
        shard_ids = np.arange(self.dataset.n_shards, dtype=np.uint32)
        if self.engine.backend != "numpy":
            # Device path: placement, tail and node gather stay on device;
            # the only host sync is the final ownership mask (one bool
            # vector), instead of transferring every owner id.
            owners = self.engine.place_nodes_device(shard_ids)
            return shard_ids[np.asarray(owners == self.host_id)]
        owners = self.engine.place_nodes(shard_ids)
        return shard_ids[owners == self.host_id]

    def refresh_membership(self) -> tuple[np.ndarray, np.ndarray]:
        """Recompute ownership after an elastic event.  Returns
        (gained_shards, lost_shards) -- provably minimal under ASURA."""
        new = self._compute_owned()
        gained = np.setdiff1d(new, self._owned)
        lost = np.setdiff1d(self._owned, new)
        self._owned = new
        return gained, lost

    @property
    def owned_shards(self) -> np.ndarray:
        return self._owned

    def __iter__(self) -> Iterator[np.ndarray]:
        return self.batches()

    def batches(self, epoch: int = 0) -> Iterator[np.ndarray]:
        """Yield (batch_per_host, seq_len) int32 batches.

        Shard visit order is a deterministic per-epoch permutation derived
        from the counter-based hash, so restarts resume identically."""
        if self._owned.size == 0:
            return
        order_keys = draw_u32_np(
            self._owned, np.uint32(100 + epoch), np.zeros_like(self._owned)
        )
        order = self._owned[np.argsort(order_keys, kind="stable")]
        need = self.batch_per_host * self.seq_len
        buf = np.empty(0, dtype=np.int32)
        for sid in order:
            buf = np.concatenate([buf, self.dataset.shard(int(sid))])
            while buf.size >= need:
                batch, buf = buf[:need], buf[need:]
                yield batch.reshape(self.batch_per_host, self.seq_len)
