from .pipeline import DataPipeline, ShardedDataset, synthetic_shard

__all__ = ["DataPipeline", "ShardedDataset", "synthetic_shard"]
