"""STEP 1 of ASURA: node <-> segment assignment (paper sections 2.A, 2.D).

Rules reproduced faithfully:

  1. a node gets segments in proportion to its capacity (one unit of
     capacity = one full-length segment; the fractional remainder becomes a
     shorter segment, as in the paper's Fig. 3 where 1.5 TB -> segment of
     length 1.0 + segment of length 0.5),
  2. existing node <-> segment correspondences never change,
  3. segments start at integer points; the segment number is the start,
  4. segment length is < 1.0 (we use 1.0 - eps for "full" segments so rule 4
     holds exactly),
  5. additions take the smallest free segment number first (section 2.D --
     this ordering is what makes the ADDITION NUMBER scheme exact).

The table is the *only* state ASURA shares cluster-wide: O(N) floats +
node ids, the paper's kilobyte-order memory claim (Table II).
"""

from __future__ import annotations

import dataclasses
import heapq
import json
from typing import Iterable

import numpy as np

from .asura import DEFAULT_PARAMS, AsuraParams, place_scalar

FULL_SEGMENT = (2.0**32 - 1.0) / 2.0**32  # rule 4: strictly under 1.0 (exact in u32)


@dataclasses.dataclass
class NodeInfo:
    node_id: int
    capacity: float
    segments: list[int] = dataclasses.field(default_factory=list)


class Cluster:
    """Mutable segment-table cluster state with ASURA placement methods."""

    def __init__(self, params: AsuraParams = DEFAULT_PARAMS):
        self.params = params
        self.nodes: dict[int, NodeInfo] = {}
        self._seg_lengths: list[float] = []
        self._seg_to_node: list[int] = []
        self._free_segments: list[int] = []  # min-heap of freed numbers
        self._version = 0
        self._engine = None  # lazy PlacementEngine (one table artifact)

    # -- table views -------------------------------------------------------

    @property
    def version(self) -> int:
        return self._version

    @property
    def engine(self):
        """The cluster's PlacementEngine (created on first placement).

        All batched STEP-2 entry points below route through it, so repeated
        placements at one version share a single cached table artifact
        (one host->device upload on accelerator backends)."""
        if self._engine is None:
            from .engine import PlacementEngine  # lazy: avoids import cycle

            self._engine = PlacementEngine(self)
        return self._engine

    def seg_lengths(self) -> np.ndarray:
        return np.asarray(self._seg_lengths, dtype=np.float64)

    def seg_to_node(self) -> np.ndarray:
        return np.asarray(self._seg_to_node, dtype=np.int64)

    def total_capacity(self) -> float:
        return float(sum(n.capacity for n in self.nodes.values()))

    def node_ids(self) -> list[int]:
        return sorted(self.nodes)

    def memory_bytes(self) -> int:
        """Paper Table II accounting: 8 bytes per segment entry."""
        return 8 * len(self._seg_lengths)

    # -- STEP 1 mutations ----------------------------------------------------

    def _alloc_segment(self) -> int:
        if self._free_segments:
            return heapq.heappop(self._free_segments)
        self._seg_lengths.append(0.0)
        self._seg_to_node.append(-1)
        return len(self._seg_lengths) - 1

    def add_node(self, node_id: int, capacity: float) -> list[int]:
        """Assign smallest-free-numbered segments totalling ``capacity``."""
        if node_id in self.nodes:
            raise ValueError(f"node {node_id} already present")
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        info = NodeInfo(node_id=node_id, capacity=float(capacity))
        remaining = float(capacity)
        while remaining > 1e-12:
            length = FULL_SEGMENT if remaining >= 1.0 else remaining
            seg = self._alloc_segment()
            self._seg_lengths[seg] = length
            self._seg_to_node[seg] = node_id
            info.segments.append(seg)
            remaining -= 1.0 if remaining >= 1.0 else remaining
        self.nodes[node_id] = info
        self._version += 1
        return info.segments

    def remove_node(self, node_id: int) -> list[int]:
        """Free the node's segments; numbers become reusable (rule 2 keeps
        every *other* node's correspondence intact)."""
        info = self.nodes.pop(node_id, None)
        if info is None:
            raise KeyError(f"node {node_id} not in cluster")
        for seg in info.segments:
            self._seg_lengths[seg] = 0.0
            self._seg_to_node[seg] = -1
            heapq.heappush(self._free_segments, seg)
        self._version += 1
        return info.segments

    def resize_node(self, node_id: int, new_capacity: float) -> None:
        """Grow/shrink a node's capacity with minimal segment churn."""
        info = self.nodes[node_id]
        if new_capacity <= 0:
            raise ValueError("capacity must be positive")
        # Exact no-op test: a sub-epsilon delta must still update the
        # recorded capacity (the 1e-12 guards below keep the segment churn
        # minimal -- a < 2**-32 length gap is invisible to the u32 table --
        # but skipping the bookkeeping lets repeated tiny resizes accumulate
        # unbounded drift between `capacity` and the true target).
        if new_capacity == info.capacity:
            return
        # Rebuild only this node's fractional tail; full segments are kept.
        lengths = [self._seg_lengths[s] for s in info.segments]
        target = float(new_capacity)
        # Shrink: trim from the last (fractional first) segments.
        while sum(lengths) > target + 1e-12:
            excess = sum(lengths) - target
            if lengths[-1] <= excess + 1e-12:
                seg = info.segments.pop()
                lengths.pop()
                self._seg_lengths[seg] = 0.0
                self._seg_to_node[seg] = -1
                heapq.heappush(self._free_segments, seg)
            else:
                lengths[-1] -= excess
                self._seg_lengths[info.segments[-1]] = lengths[-1]
        # Grow: top up the fractional segment then add new ones.
        if lengths and lengths[-1] < FULL_SEGMENT and sum(lengths) < target - 1e-12:
            add = min(FULL_SEGMENT - lengths[-1], target - sum(lengths))
            lengths[-1] += add
            self._seg_lengths[info.segments[-1]] = lengths[-1]
        while sum(lengths) < target - 1e-12:
            rem = target - sum(lengths)
            length = FULL_SEGMENT if rem >= 1.0 else rem
            seg = self._alloc_segment()
            self._seg_lengths[seg] = length
            self._seg_to_node[seg] = node_id
            info.segments.append(seg)
            lengths.append(length)
        info.capacity = float(new_capacity)
        self._version += 1

    # -- STEP 2 placement ----------------------------------------------------

    def place(self, datum_id: int) -> int:
        """Segment number for one datum (scalar oracle path)."""
        return place_scalar(datum_id, self.seg_lengths(), self.params)

    def place_node(self, datum_id: int) -> int:
        return self._seg_to_node[self.place(datum_id)]

    def place_batch(self, datum_ids) -> np.ndarray:
        return self.engine.place(datum_ids)

    def place_nodes(self, datum_ids) -> np.ndarray:
        return self.engine.place_nodes(datum_ids)

    def place_replicas(self, datum_ids, n_replicas: int) -> np.ndarray:
        """(batch, R) node ids, primary first."""
        return self.engine.place_replica_nodes(datum_ids, n_replicas)

    # -- serialization (the small shared table) -----------------------------

    def to_json(self) -> str:
        return json.dumps(
            {
                "version": self._version,
                "seg_lengths": self._seg_lengths,
                "seg_to_node": self._seg_to_node,
                "free": sorted(self._free_segments),
                "nodes": {
                    str(nid): {"capacity": info.capacity, "segments": info.segments}
                    for nid, info in self.nodes.items()
                },
                "params": dataclasses.asdict(self.params),
            }
        )

    @classmethod
    def from_json(cls, blob: str) -> "Cluster":
        data = json.loads(blob)
        cluster = cls(params=AsuraParams(**data["params"]))
        cluster._version = data["version"]
        cluster._seg_lengths = [float(x) for x in data["seg_lengths"]]
        cluster._seg_to_node = [int(x) for x in data["seg_to_node"]]
        cluster._free_segments = list(data["free"])
        heapq.heapify(cluster._free_segments)
        for nid, info in data["nodes"].items():
            cluster.nodes[int(nid)] = NodeInfo(
                node_id=int(nid),
                capacity=float(info["capacity"]),
                segments=[int(s) for s in info["segments"]],
            )
        return cluster


def make_cluster(capacities: Iterable[float], params: AsuraParams = DEFAULT_PARAMS) -> Cluster:
    """Cluster with nodes 0..N-1 of the given capacities."""
    cluster = Cluster(params=params)
    for i, cap in enumerate(capacities):
        cluster.add_node(i, cap)
    return cluster


def make_uniform_cluster(n_nodes: int, params: AsuraParams = DEFAULT_PARAMS) -> Cluster:
    return make_cluster([1.0] * n_nodes, params=params)
