"""Weighted Rendezvous Hashing baseline in the exact-u32 formulation.

Rendezvous (highest-random-weight) hashing assigns each datum to the node
with the best keyed hash of the (datum, node) pair; weighting by capacity
uses the exponential-race form: node ``i`` wins iff it minimizes

    key_i = -log2(u_i) / w_i,      u_i = hash(datum, node_i) mapped to (0, 1),

which selects node ``i`` with probability w_i / sum(w) (the max of
``u**(1/w)`` rule, CRUSH "straw" / Sage & Weil).  ``core.straw.StrawBucket``
already implements this rule on the host with float64 ``np.log`` -- a
transcendental whose last-bit rounding is libm-specific, so a device kernel
could never be BIT-IDENTICAL to it.  This module is the device-exact
re-formulation the ``PlacementEngine`` baseline backend uses:

  * ``-log2(u)`` is computed by the classic integer square-and-shift
    algorithm in Q16 fixed point -- pure u32 shifts/multiplies (via the same
    16-bit-limb trick the tail resolver uses), bit-identical on NumPy, jnp
    and inside Pallas kernels,
  * the only float ops are ONE IEEE float32 reciprocal per NODE (computed
    at table-prep time, shared by every id) and ONE float32 multiply per
    (id, node) pair -- each a single correctly-rounded op, immune to FMA
    re-association, so host and device agree bit-for-bit,
  * argmin ties break to the lowest node index on every path.

The mantissa keeps 23 bits of the raw draw (u = (2*(h >> 9) + 1) * 2**-24,
exactly representable in float32 and never 0 or 1), which leaves the
selection probabilities within 2**-16 of exact -- far below the sampling
noise of any uniformity figure -- while making cross-backend equality a
bit-for-bit assertion instead of a tolerance.
"""

from __future__ import annotations

import numpy as np

from .rng import draw_u32_np

Q16 = 16  # fractional bits of the fixed-point -log2


def neg_log2_q16_np(h: np.ndarray) -> np.ndarray:
    """-log2(u) in Q16 for u = (2*(h >> 9) + 1) / 2**24 -> int32, > 0.

    Integer square-and-shift log: normalize the 24-bit odd mantissa
    ``v = 2*(h >> 9) + 1`` to ``m in [2**23, 2**24)``, then square 16 times,
    shifting out one fraction bit per overflow.  Every step is exact u32
    arithmetic, so NumPy, jnp and Pallas agree bit-for-bit.
    """
    h = np.asarray(h, dtype=np.uint32)
    v = ((h >> np.uint32(9)) << np.uint32(1)) | np.uint32(1)  # odd, [1, 2**24)
    # e = floor(log2 v) via binary integer search (no float bitcasts).
    x = v.copy()
    e = np.zeros(v.shape, dtype=np.uint32)
    for s in (16, 8, 4, 2, 1):
        big = x >= np.uint32(1) << np.uint32(s)
        e += np.where(big, np.uint32(s), np.uint32(0))
        x = np.where(big, x >> np.uint32(s), x)
    m = v << (np.uint32(23) - e)  # mantissa in [2**23, 2**24)
    frac = np.zeros(v.shape, dtype=np.uint32)
    with np.errstate(over="ignore"):  # the limb products wrap by design
        for i in range(1, Q16 + 1):
            # m*m needs 48 bits: assemble from 16-bit limbs, keep bits 47..23.
            m16 = np.uint32(0xFFFF)
            a_lo, a_hi = m & m16, m >> np.uint32(16)
            ll = a_lo * a_lo
            lh = a_lo * a_hi
            t = (ll >> np.uint32(16)) + (lh & m16) + (lh & m16)
            lo = (t << np.uint32(16)) | (ll & m16)
            hi = (
                a_hi * a_hi
                + (lh >> np.uint32(16))
                + (lh >> np.uint32(16))
                + (t >> np.uint32(16))
            )
            m = (hi << np.uint32(9)) | (lo >> np.uint32(23))
            ge = m >= np.uint32(1) << np.uint32(24)
            frac |= np.where(ge, np.uint32(1) << np.uint32(Q16 - i), np.uint32(0))
            m = np.where(ge, m >> np.uint32(1), m)
    # -log2(u) = 24 - log2(v);  log2(v) ~= e + frac * 2**-16 (truncated).
    return (
        ((np.uint32(24) - e).astype(np.int32) << np.int32(Q16)) - frac.astype(np.int32)
    )


def wrh_hash_np(datum_ids: np.ndarray, node_ids: np.ndarray) -> np.ndarray:
    """(batch, n) raw pair hashes -- the same keyed draw StrawBucket uses."""
    ids = np.asarray(datum_ids, dtype=np.uint32).reshape(-1)
    nodes = np.asarray(node_ids, dtype=np.uint32)
    return draw_u32_np(
        ids[:, None], nodes[None, :], np.zeros((1, nodes.shape[0]), dtype=np.uint32)
    )


def wrh_place_np(
    datum_ids: np.ndarray, node_ids: np.ndarray, weights: np.ndarray
) -> np.ndarray:
    """NumPy oracle: index into ``node_ids`` of each datum's winner.

    ``weights`` are float32 capacities (> 0).  Returns int64 node ids.
    Bit-identical to the jnp twin and the Pallas kernel in
    ``repro.kernels.baselines`` (tested).
    """
    nodes = np.asarray(node_ids, dtype=np.uint32)
    w = np.asarray(weights, dtype=np.float32)
    ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
    if ids.shape[0] == 0:
        return np.zeros(0, dtype=np.int64)
    h = wrh_hash_np(ids, nodes)
    # One f32 reciprocal per NODE, one f32 multiply per (id, node) -- the
    # same precomputed-reciprocal key the device tables bake in
    # (``kernels.baselines.wrh_table_prep``), so the two paths stay
    # bit-identical; both are single correctly-rounded IEEE ops.
    with np.errstate(divide="ignore"):
        inv_w = np.where(w > 0.0, np.float32(1.0) / w, np.float32(0.0))
    key = neg_log2_q16_np(h).astype(np.float32) * inv_w[None, :].astype(np.float32)
    return nodes[np.argmin(key, axis=1)].astype(np.int64)  # first-min tie-break
