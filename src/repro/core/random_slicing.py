"""Random Slicing baseline (Miranda et al. 2014), as framed by the paper's
related work: the unit interval [0, 1) is partitioned into contiguous
intervals, each owned by one node, and a datum is stored on the owner of
the interval its hash falls into.

Membership changes re-slice minimally: capacity shares are recomputed and
ONLY the surplus mass of over-quota nodes is cut off (splitting their
intervals) and handed to under-quota nodes, so data moves exactly from
givers to takers -- the optimal-movement property ASURA is compared
against.  Lookup is a binary search over the interval starts, O(log I) for
I intervals; memory is O(I) and I grows by at most O(N) per membership
event.

The table is canonicalized exactly like the ASURA segment table: interval
boundaries are maintained as EXACT integers on the u32 circle (total mass
2**32, shares by largest-remainder rounding), so

  * ``starts32`` (sorted uint32, first entry 0) + ``owners`` (int32) is the
    whole lookup state,
  * the lookup is ``owners[searchsorted(starts32, fmix32(id), 'right') - 1]``
    -- the branchless binary-search kernel in ``repro.kernels.baselines``
    is bit-identical to the NumPy oracle below,
  * no float boundary can drift between host and device.
"""

from __future__ import annotations

import numpy as np

from .rng import fmix32_np

_MASS = 1 << 32  # total mass of the u32 circle


def _quotas(weights: dict[int, float]) -> dict[int, int]:
    """Largest-remainder shares of the 2**32 circle, summing exactly.

    Deterministic: remainders tie-break by node id, so every replica of the
    table computes the same slicing.
    """
    total = float(sum(weights.values()))
    if total <= 0:
        raise ValueError("total capacity must be positive")
    floors: dict[int, int] = {}
    rema: list[tuple[float, int]] = []
    for nid in sorted(weights):
        exact = weights[nid] * _MASS / total
        f = int(exact)
        floors[nid] = f
        rema.append((-(exact - f), nid))
    short = _MASS - sum(floors.values())
    for _, nid in sorted(rema)[:short]:
        floors[nid] += 1
    return floors


class RandomSlicingTable:
    """Mutable interval table mirroring a cluster's membership.

    ``rebalance`` moves the table from its current slicing to the quota of a
    new weight map in one minimal step -- additions, removals and resizes
    are all the same operation, so the engine can sync the table to any
    cluster version with one call.
    """

    def __init__(self, weights: dict[int, float] | None = None):
        # intervals: (start, length, owner) with exact int starts/lengths,
        # sorted by start, covering [0, 2**32) exactly once.
        self._intervals: list[tuple[int, int, int]] = []
        self.weights: dict[int, float] = {}
        if weights:
            self.rebalance(weights)

    # -- slicing -------------------------------------------------------------

    def _assigned(self) -> dict[int, int]:
        mass: dict[int, int] = {nid: 0 for nid in self.weights}
        for _, length, owner in self._intervals:
            mass[owner] = mass.get(owner, 0) + length
        return mass

    def rebalance(self, weights: dict[int, float]) -> None:
        """Re-slice to the new weight map with minimal movement.

        Over-quota nodes (including departed ones, quota 0) free exactly
        their surplus, cut from the tail of each of their intervals in
        start order (splitting an interval when the cut lands inside it);
        the freed pieces are handed to under-quota nodes in node-id order.
        Mass moves only giver -> taker, so the moved fraction equals the
        quota delta -- optimal.
        """
        for nid, w in weights.items():
            if w <= 0:
                raise ValueError(f"node {nid} capacity must be positive")
        quotas = _quotas(weights)
        assigned = self._assigned()
        if not self._intervals:
            free = [(0, _MASS)]  # initial build: the whole circle is free
        else:
            free = []
            kept: list[tuple[int, int, int]] = []
            for start, length, owner in self._intervals:
                surplus = assigned.get(owner, 0) - quotas.get(owner, 0)
                give = min(max(surplus, 0), length)
                if give:
                    # cut from the tail of this interval
                    if give < length:
                        kept.append((start, length - give, owner))
                    free.append((start + length - give, give))
                    assigned[owner] -= give
                else:
                    kept.append((start, length, owner))
            self._intervals = kept
        # hand the freed pieces to under-quota nodes, node-id order.
        free.reverse()  # pop() serves pieces in ascending-start order
        for nid in sorted(quotas):
            need = quotas[nid] - assigned.get(nid, 0)
            while need > 0:
                start, length = free.pop()
                take = min(length, need)
                self._intervals.append((start, take, nid))
                if take < length:
                    free.append((start + take, length - take))
                need -= take
        assert not free, "re-slice must cover the circle exactly"
        self._intervals.sort()
        self.weights = dict(weights)

    # -- canonical lookup state ---------------------------------------------

    def n_intervals(self) -> int:
        return len(self._intervals)

    def memory_bytes(self) -> int:
        """Table-II-style accounting: 8 bytes per interval (start + owner)."""
        return 8 * len(self._intervals)

    def starts_owners(self) -> tuple[np.ndarray, np.ndarray]:
        """(starts32 uint32 sorted with starts32[0] == 0, owners int32)."""
        starts = np.asarray([s for s, _, _ in self._intervals], dtype=np.uint64)
        owners = np.asarray([o for _, _, o in self._intervals], dtype=np.int32)
        return starts.astype(np.uint32), owners

    def place(self, datum_ids) -> np.ndarray:
        starts32, owners = self.starts_owners()
        return rs_place_np(datum_ids, starts32, owners)


def rs_place_np(datum_ids, starts32: np.ndarray, owners: np.ndarray) -> np.ndarray:
    """NumPy oracle: hash each id onto the circle, map to its interval owner.

    ``searchsorted(..., 'right') - 1`` finds the last interval starting at
    or before the hash; ``starts32[0] == 0`` guarantees the index is valid.
    Bit-identical to the jnp twin / Pallas kernel (tested).
    """
    ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
    if ids.shape[0] == 0:
        return np.zeros(0, dtype=np.int64)
    h = fmix32_np(ids)
    idx = np.searchsorted(starts32, h, side="right") - 1
    return owners[idx].astype(np.int64)
