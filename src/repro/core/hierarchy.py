"""Hierarchical ASURA: failure-domain-aware placement (beyond the paper).

The paper notes ASURA "can be applied to general one-dimensional lines or
even multidimensional space" but leaves it out of scope.  Production storage
needs replica separation across failure domains (racks / pods / zones) --
the feature CRUSH's hierarchy provides.  We compose ASURA with itself:

  level 1: a cluster of DOMAINS, each domain's capacity = sum of its nodes'
           capacities -> the first R distinct-domain hits pick the replica
           domains (paper section 5.A semantics, applied to domains),
  level 2: within each chosen domain, an independent ASURA cluster over its
           nodes places the datum (the datum id is salted with the domain id
           so placements are independent across domains).

Inherited properties (tested in tests/test_hierarchy.py):
  * replicas land on R distinct domains -- a whole-domain failure loses at
    most one replica of any datum;
  * load is proportional to domain capacity, and to node capacity within a
    domain;
  * movement optimality composes: adding/removing a NODE moves only data
    within its domain (level-2 theorem); adding/removing a DOMAIN moves
    only the data it wins/held (level-1 theorem).  Cross-domain placements
    elsewhere never change.
"""

from __future__ import annotations

import numpy as np

from .asura import DEFAULT_PARAMS, AsuraParams
from .cluster import Cluster
from .rng import fmix32_np


class HierarchicalCluster:
    """Two-level ASURA: domains (racks/pods) -> nodes."""

    def __init__(self, params: AsuraParams = DEFAULT_PARAMS):
        self.params = params
        self.domains: dict[int, Cluster] = {}
        self._top = Cluster(params=params)

    # -- membership ----------------------------------------------------------

    def add_domain(self, domain_id: int) -> None:
        if domain_id in self.domains:
            raise ValueError(f"domain {domain_id} exists")
        self.domains[domain_id] = Cluster(params=self.params)

    def add_node(self, domain_id: int, node_id: int, capacity: float) -> None:
        if domain_id not in self.domains:
            self.add_domain(domain_id)
        dom = self.domains[domain_id]
        had = dom.total_capacity()
        dom.add_node(node_id, capacity)
        self._sync_domain(domain_id, had)

    def remove_node(self, domain_id: int, node_id: int) -> None:
        dom = self.domains[domain_id]
        had = dom.total_capacity()
        dom.remove_node(node_id)
        self._sync_domain(domain_id, had)

    def remove_domain(self, domain_id: int) -> None:
        del self.domains[domain_id]
        self._top.remove_node(domain_id)

    def _sync_domain(self, domain_id: int, had: float) -> None:
        """Keep the top-level capacity equal to the domain's node sum."""
        now = self.domains[domain_id].total_capacity()
        if had == 0 and now > 0:
            self._top.add_node(domain_id, now)
        elif now == 0:
            self._top.remove_node(domain_id)
        elif abs(now - had) > 1e-12:
            self._top.resize_node(domain_id, now)

    # -- placement -----------------------------------------------------------

    def _salt(self, ids: np.ndarray, domain_id: int) -> np.ndarray:
        with np.errstate(over="ignore"):
            return fmix32_np(
                ids.astype(np.uint32) ^ np.uint32((domain_id * 0x9E3779B9) & 0xFFFFFFFF)
            )

    def place(self, datum_ids) -> np.ndarray:
        """(batch,) -> (domain_id, node_id) pairs, shape (batch, 2)."""
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        dom_of = self._top.place_nodes(ids)
        out = np.empty((ids.size, 2), dtype=np.int64)
        out[:, 0] = dom_of
        for d in np.unique(dom_of):
            rows = dom_of == d
            salted = self._salt(ids[rows], int(d))
            out[rows, 1] = self.domains[int(d)].place_nodes(salted)
        return out

    def place_replicas(self, datum_ids, n_replicas: int) -> np.ndarray:
        """(batch, R, 2): R replicas on R DISTINCT domains, primary first."""
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        dom_reps = self._top.place_replicas(ids, n_replicas)  # (batch, R)
        out = np.empty((ids.size, n_replicas, 2), dtype=np.int64)
        out[:, :, 0] = dom_reps
        for d in np.unique(dom_reps):
            dom = self.domains[int(d)]
            mask = dom_reps == d  # (batch, R) positions using this domain
            rows = np.nonzero(mask.any(axis=1))[0]
            salted = self._salt(ids[rows], int(d))
            nodes = dom.place_nodes(salted)
            for r in range(n_replicas):
                sel = mask[rows, r]
                out[rows[sel], r, 1] = nodes[sel]
        return out

    def total_capacity(self) -> float:
        return self._top.total_capacity()
