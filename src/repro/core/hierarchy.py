"""Hierarchical ASURA: failure-domain-aware placement (beyond the paper).

The paper notes ASURA "can be applied to general one-dimensional lines or
even multidimensional space" but leaves it out of scope.  Production storage
needs replica separation across failure domains (racks / pods / zones) --
the feature CRUSH's hierarchy provides.  We compose ASURA with itself:

  level 1: a cluster of DOMAINS, each domain's capacity = sum of its nodes'
           capacities -> the first R distinct-domain hits pick the replica
           domains (paper section 5.A semantics, applied to domains),
  level 2: within each chosen domain, an independent ASURA cluster over its
           nodes places the datum (the datum id is salted with the domain id
           so placements are independent across domains).

Inherited properties (tested in tests/test_hierarchy.py):
  * replicas land on R distinct domains -- a whole-domain failure loses at
    most one replica of any datum;
  * load is proportional to domain capacity, and to node capacity within a
    domain;
  * movement optimality composes: adding/removing a NODE moves only data
    within its domain (level-2 theorem); adding/removing a DOMAIN moves
    only the data it wins/held (level-1 theorem).  Cross-domain placements
    elsewhere never change.
"""

from __future__ import annotations

import numpy as np

from .asura import DEFAULT_PARAMS, AsuraParams
from .cluster import Cluster
from .rng import fmix32_np


class HierarchicalCluster:
    """Two-level ASURA: domains (racks/pods) -> nodes.

    Carries a monotonic ``version`` (bumped by every membership mutation)
    and a lazy ``engine`` exactly like ``Cluster``, so the hierarchical
    ``PlacementEngine`` mode can key its versioned two-level artifacts off
    this cluster (DESIGN.md section 14).
    """

    is_hierarchical = True

    def __init__(self, params: AsuraParams = DEFAULT_PARAMS):
        self.params = params
        self.domains: dict[int, Cluster] = {}
        self._top = Cluster(params=params)
        self._version = 0
        self._engine = None  # lazy hierarchical PlacementEngine

    @property
    def version(self) -> int:
        return self._version

    @property
    def engine(self):
        """The cluster's hierarchical PlacementEngine (created on first
        placement) -- the fused two-level kernel path, bit-identical to
        the host oracle below."""
        if self._engine is None:
            from .engine import PlacementEngine  # lazy: avoids import cycle

            self._engine = PlacementEngine(self)
        return self._engine

    # -- membership ----------------------------------------------------------

    def add_domain(self, domain_id: int) -> None:
        if domain_id in self.domains:
            raise ValueError(f"domain {domain_id} exists")
        self.domains[domain_id] = Cluster(params=self.params)
        self._version += 1

    def add_node(self, domain_id: int, node_id: int, capacity: float) -> None:
        if domain_id not in self.domains:
            self.add_domain(domain_id)
        dom = self.domains[domain_id]
        dom.add_node(node_id, capacity)
        self._sync_domain(domain_id)
        self._version += 1

    def remove_node(self, domain_id: int, node_id: int) -> None:
        dom = self.domains[domain_id]
        dom.remove_node(node_id)
        self._sync_domain(domain_id)
        self._version += 1

    def remove_domain(self, domain_id: int) -> None:
        del self.domains[domain_id]
        self._top.remove_node(domain_id)
        self._version += 1

    def _sync_domain(self, domain_id: int) -> None:
        """Keep the top-level capacity EXACTLY equal to the domain's node sum.

        Compares against the top cluster's recorded capacity (not a caller
        snapshot): the historical ``abs(now - had) > 1e-12`` tolerance let
        repeated sub-epsilon churn accumulate unbounded drift between
        ``_top`` and the true sum (each step under the tolerance, the total
        not) -- regression-tested in tests/test_hier_kernel.py.
        """
        now = self.domains[domain_id].total_capacity()
        info = self._top.nodes.get(domain_id)
        if info is None:
            if now > 0:
                self._top.add_node(domain_id, now)
        elif now == 0:
            self._top.remove_node(domain_id)
        elif now != info.capacity:
            self._top.resize_node(domain_id, now)

    def node_domains(self) -> dict[int, int]:
        """node_id -> domain_id over every node in the hierarchy.

        The engine's hierarchical mode requires node ids to be GLOBALLY
        unique across domains (so replica diffs, movers and the serving
        path keep a flat node-id space); this is the validation view.
        """
        out: dict[int, int] = {}
        for did, dom in self.domains.items():
            for nid in dom.nodes:
                if nid in out:
                    raise ValueError(
                        f"node id {nid} appears in domains {out[nid]} and "
                        f"{did}; hierarchical placement requires globally "
                        "unique node ids"
                    )
                out[nid] = did
        return out

    # -- placement -----------------------------------------------------------

    def _salt(self, ids: np.ndarray, domain_id: int) -> np.ndarray:
        with np.errstate(over="ignore"):
            return fmix32_np(
                ids.astype(np.uint32) ^ np.uint32((domain_id * 0x9E3779B9) & 0xFFFFFFFF)
            )

    def place(self, datum_ids) -> np.ndarray:
        """(batch,) -> (domain_id, node_id) pairs, shape (batch, 2)."""
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        dom_of = self._top.place_nodes(ids)
        out = np.empty((ids.size, 2), dtype=np.int64)
        out[:, 0] = dom_of
        for d in np.unique(dom_of):
            rows = dom_of == d
            salted = self._salt(ids[rows], int(d))
            out[rows, 1] = self.domains[int(d)].place_nodes(salted)
        return out

    def place_replicas(self, datum_ids, n_replicas: int) -> np.ndarray:
        """(batch, R, 2): R replicas on R DISTINCT domains, primary first."""
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        dom_reps = self._top.place_replicas(ids, n_replicas)  # (batch, R)
        out = np.empty((ids.size, n_replicas, 2), dtype=np.int64)
        out[:, :, 0] = dom_reps
        for d in np.unique(dom_reps):
            dom = self.domains[int(d)]
            mask = dom_reps == d  # (batch, R) positions using this domain
            rows = np.nonzero(mask.any(axis=1))[0]
            salted = self._salt(ids[rows], int(d))
            nodes = dom.place_nodes(salted)
            for r in range(n_replicas):
                sel = mask[rows, r]
                out[rows[sel], r, 1] = nodes[sel]
        return out

    def total_capacity(self) -> float:
        return self._top.total_capacity()
