"""Straw Buckets baseline (Weil et al., CRUSH 2006), as evaluated in the paper.

Each node draws a hash ("straw length") per datum; the node with the maximum
straw stores the datum (paper Fig. 2).  Distribution-stage cost is O(N) per
datum -- the property that makes it unscalable in the paper's Fig. 5.
Capacity weighting multiplies straws by CRUSH-style per-node factors so
selection probability tracks capacity (section III.E "in limited case").
Replication takes the R largest straws (section V.A).

This float64 ``np.log`` formulation is host-only; the ``PlacementEngine``
"wrh" backend uses the device-exact re-formulation in ``core/wrh.py``
(fixed-point -log2, bit-identical across NumPy/jnp/Pallas -- DESIGN.md
section 9), which implements the same weighted-rendezvous selection rule.
"""

from __future__ import annotations

import numpy as np

from .rng import draw_u32_np


class StrawBucket:
    def __init__(self, node_ids, weights=None):
        self.node_ids = np.asarray(list(node_ids), dtype=np.uint32)
        n = self.node_ids.shape[0]
        if n == 0:
            raise ValueError("need at least one node")
        if weights is None:
            self.scale = np.ones(n)
        else:
            w = np.asarray(weights, dtype=np.float64)
            # CRUSH straw scaling: straw_i = hash ** (1 / w_i) on (0, 1);
            # equivalently compare log(u) / w_i.
            self.scale = w

    def memory_bytes(self) -> int:
        """O(N): node id + weight per node."""
        return 8 * self.node_ids.shape[0]

    def _straws(self, datum_ids) -> np.ndarray:
        ids = np.asarray(datum_ids, dtype=np.uint32).reshape(-1)
        # hash(datum, node) per pair -- depends ONLY on the pair, so straws
        # are stable under membership changes (the optimal-movement property).
        h = draw_u32_np(
            ids[:, None],
            self.node_ids[None, :],
            np.zeros((1, self.node_ids.shape[0]), dtype=np.uint32),
        ).astype(np.float64)
        u = (h + 1.0) * 2.0**-32  # (0, 1]
        return np.log(u) / self.scale[None, :]  # max == capacity-weighted max straw

    def place(self, datum_ids) -> np.ndarray:
        straws = self._straws(datum_ids)
        return self.node_ids[np.argmax(straws, axis=1)]

    def place_replicas(self, datum_ids, n_replicas: int) -> np.ndarray:
        straws = self._straws(datum_ids)
        order = np.argsort(-straws, axis=1)[:, :n_replicas]
        return self.node_ids[order]
