"""ASURA placement algorithm (Ishikawa 2013), faithful reproduction.

Implements the paper's STEP 2 (data-storing node determination) on top of a
segment table built by STEP 1 (``cluster.py``):

  * ``AsuraParams``        -- the doubling generator-family ladder of
                              section 2.C (alpha = 2, S = 2**s_log2).
  * ``place_scalar``       -- exact per-datum oracle with true per-level draw
                              counters and an unbounded retry loop (the
                              paper's while(1)).
  * ``place_batch``        -- vectorized NumPy placement for benchmark-scale
                              id batches (bounded masked loop; bit-identical
                              to the oracle; tested lane-by-lane).
  * ``place_replicas_*``   -- replication: first R draws hitting *distinct
                              nodes* (section 5.A).
  * ``addition_number``,
    ``remove_numbers``     -- the section 2.D metadata accelerating node
                              addition / removal change detection.

Exact integer formulation (the TPU adaptation, DESIGN.md section 3):
restricting to the paper's own evaluation choice alpha = 2 with S a power of
two makes every test a pure uint32 operation on the raw draw ``h``:

    value   = h * 2**(s+l-32)            on [0, 2**(s+l))
    descend = value < 2**(s+l-1)    <=>  h < 2**31         (MSB clear)
    k       = floor(value)           =   h >> (32 - s - l)
    frac32  = (value - k) * 2**32    =   (h << (s + l)) mod 2**32
    hit     = frac32 < len32[k]          (len32 = round(length * 2**32))

No float round-off can reorder a boundary, so the scalar oracle, the NumPy
batch path, the jnp reference and the Pallas kernel agree bit-for-bit.

The ASURA random number sequence (section 2.C): generators at level l emit
uniform values on [0, S * 2**l).  ``next`` starts at the narrowest level L
covering all segments and descends while the value falls inside the
next-narrower range, consuming one counter tick per consulted level.  The
subsequence of emitted values below S * 2**l is, by construction, exactly
the sequence the level-l configuration would emit -- the range-extension
invariance the paper proves in section 2.B (property-tested).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from .rng import GOLDEN, KMULT, draw_u32_np, draw_u32_scalar, fmix32_np

U32 = np.uint32
_2_32 = 2.0**32


def lengths_to_u32(seg_lengths: Sequence[float]) -> np.ndarray:
    """Canonical integer segment lengths: round(length * 2**32), < 2**32."""
    lengths = np.asarray(seg_lengths, dtype=np.float64)
    if np.any(lengths < 0) or np.any(lengths >= 1.0):
        raise ValueError("segment lengths must lie in [0, 1)")
    return np.minimum(np.round(lengths * _2_32), _2_32 - 1).astype(np.uint32)


@dataclasses.dataclass(frozen=True)
class AsuraParams:
    """Generator-family parameters (paper section 2.C / Appendix B).

    s_log2: log2 of the DEFAULT_MAXIMUM_RANDOM_NUMBER in the Appendix-A
        pseudocode (the level-0 range).  The paper's evaluation used 16
        (s_log2=4); we default to 2**1 = 2 so the raw-draw hit rate stays
        >= ~1/4 even for a single half-full node (Appendix B's expectation
        depends only on h/n once n >> S).
    max_draws: trip count of the bounded batched loop.  Appendix B bounds
        expected draws per placement by (S*a**x/(n-h)) * a/(a-1) <= 4 for
        hole fraction <= 1/2, so 128 draws miss with p < 2**-53 per lane.
    """

    s_log2: int = 1
    max_draws: int = 128

    def __post_init__(self):
        if not (1 <= self.s_log2 <= 16):
            raise ValueError("s_log2 must be in [1, 16]")

    @property
    def s_initial(self) -> float:
        return float(2**self.s_log2)

    def level_for(self, upper: float) -> int:
        """Smallest level L with 2**(s+L) >= upper (Appendix B eq. (1))."""
        level = max(0, int(math.ceil(math.log2(max(upper, 1.0)))) - self.s_log2)
        if self.s_log2 + level > 31:
            raise ValueError("segment space exceeds 2**31; unsupported")
        return level

    def range_at(self, level: int) -> float:
        return float(2 ** (self.s_log2 + level))


DEFAULT_PARAMS = AsuraParams()


def _upper_bound(seg_lengths: np.ndarray) -> float:
    """n of Appendix B: max occupied segment number + its length."""
    occupied = np.nonzero(seg_lengths > 0)[0]
    if occupied.size == 0:
        raise ValueError("segment table has no occupied segments")
    last = int(occupied[-1])
    return last + float(seg_lengths[last])


def tail_cumsum_halves(len32: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """The u64 inclusive length-cumsum as two u32 halves (hi, lo).

    This is the device-side representation of the section 3.2 tail spec:
    ``cum = cumsum(len32)`` needs up to 63 bits (n_segs < 2**31), which TPUs
    do not carry natively, so the table artifact stores ``cum >> 32`` and
    ``cum & 0xFFFFFFFF`` separately and the kernels compare 64-bit values
    through the halves.  Computed on the host once per table version.
    """
    cum = np.cumsum(np.asarray(len32, dtype=np.uint32).astype(np.uint64))
    return (
        (cum >> np.uint64(32)).astype(np.uint32),
        (cum & np.uint64(0xFFFFFFFF)).astype(np.uint32),
    )


def resolve_tail_np(
    datum_ids: np.ndarray,
    result: np.ndarray,
    len32: np.ndarray,
    top_level: int,
) -> np.ndarray:
    """Exact-integer fallback for non-converged lanes (DESIGN.md section 3.2).

    Lanes still at -1 after the bounded draw loop (p < 2**-53 per lane) get a
    uniform draw over the occupied u32 mass: one raw draw h at level
    ``top_level + 1`` (counter 0) is scaled by the exact total mass T,

        u = (h * T) >> 32,    u in [0, T),    T = sum(len32),

    and mapped to the segment whose inclusive u64 cumsum first exceeds u.
    The product h * T needs up to 95 bits (T < 2**63 since n_segs < 2**31),
    so it is evaluated exactly through 32-bit halves of T:

        u = h * (T >> 32) + ((h * (T & 0xFFFFFFFF)) >> 32)

    where both terms fit uint64.  Pure integer arithmetic, so every
    implementation (NumPy batch, jnp reference, Pallas wrapper) resolves the
    tail bit-identically.  Trailing zero-length padding in ``len32`` never
    wins (its cumsum equals the total).
    """
    result = np.asarray(result)
    miss = result < 0
    if not miss.any():
        return result
    len32 = np.asarray(len32, dtype=np.uint32)
    cum = np.cumsum(len32.astype(np.uint64))
    total = cum[-1]
    ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
    h = draw_u32_np(
        ids[miss], np.uint32(top_level + 1), np.zeros(int(miss.sum()), np.uint32)
    ).astype(np.uint64)
    hi, lo = total >> np.uint64(32), total & np.uint64(0xFFFFFFFF)
    u = h * hi + ((h * lo) >> np.uint64(32))
    result = result.copy()
    result[miss] = np.searchsorted(cum, u, side="right")
    return result


# ---------------------------------------------------------------------------
# Scalar oracle
# ---------------------------------------------------------------------------


class _AsuraStream:
    """Per-datum ASURA random number stream with true per-level counters."""

    def __init__(self, datum_id: int, top_level: int, params: AsuraParams):
        self.datum_id = int(datum_id) & 0xFFFFFFFF
        self.top_level = top_level
        self.params = params
        self.counters = [0] * (top_level + 1)

    def next(self) -> tuple[int, int]:
        """One ASURA random number as (k, frac32); value = k + frac32/2**32."""
        level = self.top_level
        s = self.params.s_log2
        while True:
            h = draw_u32_scalar(self.datum_id, level, self.counters[level])
            self.counters[level] += 1
            if level > 0 and h < 2**31:
                level -= 1  # value in next-narrower range: consult it instead
                continue
            k = h >> (32 - s - level)
            frac32 = (h << (s + level)) & 0xFFFFFFFF
            return k, frac32

    def next_value(self) -> float:
        k, frac32 = self.next()
        return k + frac32 / _2_32


def place_scalar(
    datum_id: int,
    seg_lengths: Sequence[float],
    params: AsuraParams = DEFAULT_PARAMS,
) -> int:
    """Paper STEP 2: the segment number storing ``datum_id``.

    seg_lengths[k] is the length (0 <= len < 1) of segment k, 0.0 for holes.
    Deterministic in ``datum_id``.
    """
    lengths = np.asarray(seg_lengths, dtype=np.float64)
    len32 = lengths_to_u32(lengths)
    n_segs = len(len32)
    stream = _AsuraStream(datum_id, params.level_for(_upper_bound(lengths)), params)
    while True:
        k, frac32 = stream.next()
        if k < n_segs and frac32 < int(len32[k]):
            return k


def place_replicas_scalar(
    datum_id: int,
    seg_lengths: Sequence[float],
    seg_to_node: Sequence[int],
    n_replicas: int,
    params: AsuraParams = DEFAULT_PARAMS,
) -> list[int]:
    """First ``n_replicas`` hits on distinct *nodes* (section 5.A).

    Returns the list of segment numbers, primary first.
    """
    lengths = np.asarray(seg_lengths, dtype=np.float64)
    len32 = lengths_to_u32(lengths)
    node_of = np.asarray(seg_to_node)
    n_segs = len(len32)
    stream = _AsuraStream(datum_id, params.level_for(_upper_bound(lengths)), params)
    segs: list[int] = []
    nodes_seen: set[int] = set()
    guard = 0
    while len(segs) < n_replicas:
        guard += 1
        if guard > 1_000_000:
            raise RuntimeError("replication needs more distinct nodes than exist")
        k, frac32 = stream.next()
        if k >= n_segs or frac32 >= int(len32[k]):
            continue
        node = int(node_of[k])
        if node in nodes_seen:
            continue
        nodes_seen.add(node)
        segs.append(k)
    return segs


# ---------------------------------------------------------------------------
# Section 2.D metadata: ADDITION NUMBER and REMOVE NUMBERS
# ---------------------------------------------------------------------------


def placement_trace(
    datum_id: int,
    seg_lengths: Sequence[float],
    seg_to_node: Sequence[int],
    n_replicas: int = 1,
    params: AsuraParams = DEFAULT_PARAMS,
    extra_levels: int = 0,
) -> tuple[list[int], list[float], list[bool]]:
    """Replica segments plus the full anterior ASURA-number trace.

    Returns (replica_segments, numbers, used) where ``numbers`` is every
    ASURA random number generated up to and including the finally selected
    one (at top level = level_for(n) + extra_levels, i.e. optionally with the
    range extended for the ADDITION-NUMBER search) and ``used[i]`` marks the
    numbers that selected a replica.
    """
    lengths = np.asarray(seg_lengths, dtype=np.float64)
    len32 = lengths_to_u32(lengths)
    node_of = np.asarray(seg_to_node)
    n_segs = len(len32)
    top = params.level_for(_upper_bound(lengths)) + extra_levels
    stream = _AsuraStream(datum_id, top, params)
    numbers: list[float] = []
    used: list[bool] = []
    segs: list[int] = []
    nodes_seen: set[int] = set()
    guard = 0
    while len(segs) < n_replicas:
        guard += 1
        if guard > 1_000_000:
            raise RuntimeError("trace did not converge")
        k, frac32 = stream.next()
        numbers.append(k + frac32 / _2_32)
        hit = k < n_segs and frac32 < int(len32[k]) and int(node_of[k]) not in nodes_seen
        used.append(bool(hit))
        if hit:
            nodes_seen.add(int(node_of[k]))
            segs.append(k)
    return segs, numbers, used


def addition_number(
    datum_id: int,
    seg_lengths: Sequence[float],
    seg_to_node: Sequence[int],
    n_replicas: int = 1,
    params: AsuraParams = DEFAULT_PARAMS,
) -> int:
    """Section 2.D ADDITION NUMBER.

    floor of the smallest ASURA number anterior to the finally selected one
    that did not select a replica.  If every anterior number was used, the
    range is extended (extra levels) until an unused anterior number exists;
    extension only inserts numbers, never reorders existing ones, so the
    trace stays consistent (section 2.B).
    """
    extra = 0
    while True:
        _, numbers, used = placement_trace(
            datum_id, seg_lengths, seg_to_node, n_replicas, params, extra_levels=extra
        )
        unused = [v for v, u in zip(numbers[:-1], used[:-1]) if not u]
        if unused:
            return int(min(unused))
        extra += 1
        if extra > 32:
            raise RuntimeError("could not find an unused anterior number")


def remove_numbers(
    datum_id: int,
    seg_lengths: Sequence[float],
    seg_to_node: Sequence[int],
    n_replicas: int = 1,
    params: AsuraParams = DEFAULT_PARAMS,
) -> list[int]:
    """Section 2.D REMOVE NUMBERS: floors of the replica-selecting numbers."""
    _, numbers, used = placement_trace(
        datum_id, seg_lengths, seg_to_node, n_replicas, params
    )
    return sorted(int(v) for v, u in zip(numbers, used) if u)


# ---------------------------------------------------------------------------
# Vectorized NumPy batch placement
# ---------------------------------------------------------------------------


def _lvl_term(level: int) -> np.uint32:
    # computed in python ints: scalar uint32 multiplies warn on overflow
    return np.uint32((GOLDEN * (level + 1)) & 0xFFFFFFFF)


def _next_asura_batch(
    ids: np.ndarray,
    counters: np.ndarray,
    top_level: int,
    params: AsuraParams,
) -> tuple[np.ndarray, np.ndarray]:
    """One ASURA number per lane as (k, frac32); advances per-level counters.

    counters: (top_level + 1, batch) uint32, mutated in place; row l holds
    the level-l counters (contiguous, so per-level reads/ticks are cheap).

    Lazy-depth ladder (DESIGN.md section 3.4): the descend test is a coin
    flip per level, so the expected consulted depth is < 2 regardless of
    ``top_level``.  The top level is consulted by EVERY lane on every draw
    and is evaluated on the full batch with no index arrays; each deeper
    level hashes only the (geometrically shrinking) subset of lanes still
    consulting, and the loop exits as soon as no lane is.  Per-draw hash
    work is therefore O(expected depth) ~ 2 level-batches, not
    O(top_level).  Counters tick exactly one per consulted level per lane
    -- bit-identical to the unrolled ladder and to the scalar oracle
    (tested lane-by-lane).
    """
    s = params.s_log2
    kmult = np.uint32(KMULT)
    # -- top level: full batch, no indexing --------------------------------
    h = fmix32_np(fmix32_np(ids + _lvl_term(top_level)) ^ (counters[top_level] * kmult))
    counters[top_level] += np.uint32(1)
    # Emit values computed for ALL lanes; descending lanes get theirs
    # overwritten by the store at their (unique) emitting level below.
    out_k = (h >> np.uint32(32 - s - top_level)).astype(np.int64)
    out_frac = (h << np.uint32(s + top_level)).astype(np.uint32)
    if top_level == 0:
        return out_k, out_frac
    descend = h < np.uint32(2**31)
    active = np.nonzero(descend)[0]  # absolute lane index of each live row
    sub_ids = ids[descend]
    # -- deeper levels: compacted subsets ----------------------------------
    for level in range(top_level - 1, -1, -1):
        if active.size == 0:
            break
        ctr = counters[level]
        h = fmix32_np(fmix32_np(sub_ids + _lvl_term(level)) ^ (ctr[active] * kmult))
        ctr[active] += np.uint32(1)
        if level > 0:
            descend = h < np.uint32(2**31)
            emit = ~descend
        else:
            descend = np.zeros(h.shape, dtype=bool)
            emit = np.ones(h.shape, dtype=bool)
        em = active[emit]
        he = h[emit]
        out_k[em] = (he >> np.uint32(32 - s - level)).astype(np.int64)
        out_frac[em] = (he << np.uint32(s + level)).astype(np.uint32)
        active = active[descend]
        sub_ids = sub_ids[descend]
    return out_k, out_frac


def _next_asura_batch_unrolled(
    ids: np.ndarray,
    counters: np.ndarray,
    top_level: int,
    params: AsuraParams,
) -> tuple[np.ndarray, np.ndarray]:
    """Pre-lazy-ladder reference: hash EVERY level for EVERY lane per draw.

    Kept (a) as the regression oracle for the lazy ladder and (b) so
    ``benchmarks/calc_time.py`` can measure the ladder speedup against the
    exact pre-optimization arithmetic.  Bit-identical to
    ``_next_asura_batch`` by construction.  counters: the LEGACY
    (batch, top_level + 1) layout, mutated in place.
    """
    batch = ids.shape[0]
    s = params.s_log2
    consult = np.ones(batch, dtype=bool)
    out_k = np.zeros(batch, dtype=np.int64)
    out_frac = np.zeros(batch, dtype=np.uint32)
    for level in range(top_level, -1, -1):
        h = draw_u32_np(ids, level, counters[:, level])
        counters[:, level] += consult.astype(np.uint32)
        descend = consult & (level > 0) & (h < np.uint32(2**31))
        emit = consult & ~descend
        k = (h >> np.uint32(32 - s - level)).astype(np.int64)
        frac = (h << np.uint32(s + level)).astype(np.uint32)
        out_k = np.where(emit, k, out_k)
        out_frac = np.where(emit, frac, out_frac)
        consult = descend
    return out_k, out_frac


def place_batch_u32(
    datum_ids: np.ndarray,
    len32: np.ndarray,
    top_level: int,
    params: AsuraParams = DEFAULT_PARAMS,
) -> np.ndarray:
    """Bounded-loop STEP 2 on a prebuilt u32 table; -1 marks non-converged.

    The table-artifact entry point: ``PlacementEngine`` calls this with its
    cached canonical table so repeated placements never re-derive ``len32``
    or the top level.  Callers resolve the -1 tail via ``resolve_tail_np``.

    Placed lanes are compacted out between draws (lanes are independent, so
    dropping a finished row changes nothing for the others): with expected
    ~4 draws per lane the draw loop touches roughly ``4 * batch`` lanes
    total instead of ``max_draws * batch``.
    """
    ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
    len32 = np.asarray(len32, dtype=np.uint32)
    n_segs = len(len32)
    batch = ids.shape[0]
    result = np.full(batch, -1, dtype=np.int64)
    alive = np.arange(batch)  # original lane index of each live row
    live_ids = ids
    counters = np.zeros((top_level + 1, batch), dtype=np.uint32)
    for _ in range(params.max_draws):
        if alive.size == 0:
            break
        k, frac = _next_asura_batch(live_ids, counters, top_level, params)
        k_safe = np.minimum(k, n_segs - 1)
        hit = (k < n_segs) & (frac < len32[k_safe])
        result[alive[hit]] = k[hit]
        keep = ~hit
        alive = alive[keep]
        live_ids = live_ids[keep]
        counters = counters[:, keep]
    return result


def _place_batch_u32_unrolled(
    datum_ids: np.ndarray,
    len32: np.ndarray,
    top_level: int,
    params: AsuraParams = DEFAULT_PARAMS,
) -> np.ndarray:
    """The pre-PR bounded loop (unrolled ladder, no lane compaction).

    Benchmark baseline only -- see ``_next_asura_batch_unrolled``.
    """
    ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
    len32 = np.asarray(len32, dtype=np.uint32)
    n_segs = len(len32)
    batch = ids.shape[0]
    counters = np.zeros((batch, top_level + 1), dtype=np.uint32)
    result = np.full(batch, -1, dtype=np.int64)
    done = np.zeros(batch, dtype=bool)
    for _ in range(params.max_draws):
        k, frac = _next_asura_batch_unrolled(ids, counters, top_level, params)
        k_safe = np.minimum(k, n_segs - 1)
        hit = (~done) & (k < n_segs) & (frac < len32[k_safe])
        result = np.where(hit, k, result)
        done |= hit
        if done.all():
            break
    return result


def place_batch(
    datum_ids: np.ndarray,
    seg_lengths: Sequence[float],
    params: AsuraParams = DEFAULT_PARAMS,
) -> np.ndarray:
    """Vectorized STEP 2 for a batch of datum ids -> segment numbers.

    Bit-identical to ``place_scalar`` lane-by-lane (tested).  Lanes that fail
    to hit within ``params.max_draws`` draws (probability < 2**-53 per lane
    for hole fractions <= 1/2) fall back to the exact-integer uniform draw
    over the occupied mass (``resolve_tail_np``) -- total and uniform but
    outside the movement-optimality guarantee; see DESIGN.md section 3.2.
    """
    ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
    lengths = np.asarray(seg_lengths, dtype=np.float64)
    len32 = lengths_to_u32(lengths)
    top = params.level_for(_upper_bound(lengths))
    result = place_batch_u32(ids, len32, top, params)
    return resolve_tail_np(ids, result, len32, top)


def place_nodes_batch(
    datum_ids: np.ndarray,
    seg_lengths: Sequence[float],
    seg_to_node: Sequence[int],
    params: AsuraParams = DEFAULT_PARAMS,
) -> np.ndarray:
    """Batch placement straight to node ids."""
    segs = place_batch(datum_ids, seg_lengths, params)
    return np.asarray(seg_to_node)[segs]


def place_replicas_u32(
    datum_ids: np.ndarray,
    len32: np.ndarray,
    node_of: np.ndarray,
    n_replicas: int,
    top_level: int,
    params: AsuraParams = DEFAULT_PARAMS,
) -> np.ndarray:
    """Replica placement on a prebuilt u32 table -> (batch, R) segments."""
    ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
    len32 = np.asarray(len32, dtype=np.uint32)
    node_of = np.asarray(node_of)
    n_segs = len(len32)
    batch = ids.shape[0]
    counters = np.zeros((top_level + 1, batch), dtype=np.uint32)
    result = np.full((batch, n_replicas), -1, dtype=np.int64)
    found = np.zeros(batch, dtype=np.int64)
    for _ in range(params.max_draws * max(1, n_replicas)):
        k, frac = _next_asura_batch(ids, counters, top_level, params)
        k_safe = np.minimum(k, n_segs - 1)
        hit = (k < n_segs) & (frac < len32[k_safe]) & (found < n_replicas)
        node_k = node_of[k_safe]
        dup = np.zeros(batch, dtype=bool)
        for r in range(n_replicas):
            prev = result[:, r]
            dup |= (prev >= 0) & (node_of[np.maximum(prev, 0)] == node_k)
        hit &= ~dup
        rows = np.nonzero(hit)[0]
        result[rows, found[rows]] = k[rows]
        found[rows] += 1
        if (found >= n_replicas).all():
            break
    if not (found >= n_replicas).all():
        raise RuntimeError("replication did not converge; too few distinct nodes?")
    return result


def place_replicas_batch(
    datum_ids: np.ndarray,
    seg_lengths: Sequence[float],
    seg_to_node: Sequence[int],
    n_replicas: int,
    params: AsuraParams = DEFAULT_PARAMS,
) -> np.ndarray:
    """(batch, n_replicas) segment numbers; first column is the primary.

    Vectorized analogue of ``place_replicas_scalar`` (bit-identical; tested).
    """
    lengths = np.asarray(seg_lengths, dtype=np.float64)
    len32 = lengths_to_u32(lengths)
    top = params.level_for(_upper_bound(lengths))
    return place_replicas_u32(
        datum_ids, len32, np.asarray(seg_to_node), n_replicas, top, params
    )


def addition_numbers_batch(
    datum_ids: np.ndarray,
    seg_lengths: Sequence[float],
    seg_to_node: Sequence[int],
    n_replicas: int = 1,
    params: AsuraParams = DEFAULT_PARAMS,
) -> np.ndarray:
    """Vectorized section 2.D ADDITION NUMBER for a batch of datum ids.

    Runs the replica trace for every lane at once, tracking the minimum
    *unused* anterior ASURA number as an exact (k << 32 | frac32) uint64 key
    (value ordering is identical to the float ordering of the scalar trace,
    without float64 round-off).  Lanes whose trace needs the rare
    range-extension path (every anterior number used) or does not converge in
    the bounded loop fall back to the exact scalar ``addition_number``.
    Matches ``addition_number`` lane-by-lane (tested).
    """
    ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
    lengths = np.asarray(seg_lengths, dtype=np.float64)
    len32 = lengths_to_u32(lengths)
    node_of = np.asarray(seg_to_node)
    n_segs = len(len32)
    top = params.level_for(_upper_bound(lengths))
    batch = ids.shape[0]
    counters = np.zeros((top + 1, batch), dtype=np.uint32)
    found = np.zeros(batch, dtype=np.int64)
    picked_nodes = np.full((batch, n_replicas), -1, dtype=np.int64)
    no_min = np.uint64(0xFFFFFFFFFFFFFFFF)
    min_unused = np.full(batch, no_min, dtype=np.uint64)
    for _ in range(params.max_draws * max(1, n_replicas)):
        active = found < n_replicas
        if not active.any():
            break
        k, frac = _next_asura_batch(ids, counters, top, params)
        k_safe = np.minimum(k, n_segs - 1)
        hit = (k < n_segs) & (frac < len32[k_safe])
        node_k = node_of[k_safe]
        dup = np.any((picked_nodes >= 0) & (picked_nodes == node_k[:, None]), axis=1)
        used = active & hit & ~dup
        key = (k.astype(np.uint64) << np.uint64(32)) | frac.astype(np.uint64)
        unused = active & ~used
        min_unused = np.where(unused, np.minimum(min_unused, key), min_unused)
        rows = np.nonzero(used)[0]
        picked_nodes[rows, found[rows]] = node_k[rows]
        found[rows] += 1
    an = (min_unused >> np.uint64(32)).astype(np.int64)
    needs_scalar = (found < n_replicas) | (min_unused == no_min)
    for i in np.nonzero(needs_scalar)[0]:
        an[i] = addition_number(int(ids[i]), lengths, node_of, n_replicas, params)
    return an


def remove_numbers_batch(
    datum_ids: np.ndarray,
    seg_lengths: Sequence[float],
    seg_to_node: Sequence[int],
    n_replicas: int = 1,
    params: AsuraParams = DEFAULT_PARAMS,
) -> np.ndarray:
    """Vectorized section 2.D REMOVE NUMBERS -> (batch, R) sorted segments.

    A datum's remove numbers are the floors of its replica-SELECTING ASURA
    numbers, and the floor of a selecting number IS the selected segment --
    so the batch is one vectorized replica placement plus a row sort,
    replacing the per-id scalar trace (``remove_numbers``).  Row-identical
    to the scalar (tested).
    """
    segs = place_replicas_batch(
        datum_ids, seg_lengths, seg_to_node, n_replicas, params
    )
    return np.sort(segs, axis=1)


def align_replica_sets(
    before: np.ndarray, after: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-slot minimal alignment of two replica-node sets (the host spec).

    ``before`` / ``after`` are (batch, R) replica-node sets (each row
    pairwise-distinct, primary first) of the same ids under versions v and
    v+1.  Slots index the AFTER set.  Returns ``(moved, src, src_slot)``:

      * ``moved[b, r]``    -- slot r's owner actually changed, i.e.
        ``after[b, r]`` is not a member of ``before[b, :]`` (so exactly
        ``|after \\ before|`` slots move -- the section-5 minimal replica
        mass; common nodes that merely changed position move nothing),
      * ``src[b, r]``      -- where slot r's bytes live under v: for a moved
        slot the rank-matched VACATED node (the k-th new after-slot pairs
        with the k-th lost before-slot, both in slot order -- the set
        differences have equal size, so the match is total), else
        ``after[b, r]`` itself (it holds the datum throughout),
      * ``src_slot[b, r]`` -- the BEFORE-set position of ``src`` for moved
        slots (rollback re-indexes the reverse plan with it), else r.

    Pure exact integer ops, formulated identically to the jitted device
    twin (``kernels.ops._align_replica_sets``) so the two are bit-identical.
    """
    before = np.asarray(before)
    after = np.asarray(after)
    n_replicas = after.shape[1]
    new = ~(after[:, :, None] == before[:, None, :]).any(axis=2)
    lost = ~(before[:, :, None] == after[:, None, :]).any(axis=2)
    new_i = new.astype(np.int64)
    lost_i = lost.astype(np.int64)
    rank_new = np.cumsum(new_i, axis=1) - new_i
    rank_lost = np.cumsum(lost_i, axis=1) - lost_i
    match = lost[:, None, :] & (rank_lost[:, None, :] == rank_new[:, :, None])
    picked_src = np.where(match, before[:, None, :], 0).sum(axis=2)
    slots = np.arange(n_replicas, dtype=np.int64)
    picked_slot = np.where(match, slots[None, None, :], 0).sum(axis=2)
    src = np.where(new, picked_src, after)
    src_slot = np.where(new, picked_slot, slots[None, :])
    return new, src, src_slot
