"""Consistent Hashing baseline (Karger et al. 1997), as evaluated in the paper.

Faithful to the paper's section IV setup: each node gets V virtual-node hash
numbers placed on a 32-bit ring; the initial stage sorts them (O(NV log NV));
the distribution stage hashes the datum id and binary-searches the ring
(O(log NV)).  Memory is O(NV) -- 8 bytes per virtual node (Table II).

The same counter-based generator used by ASURA produces the hashes, matching
the paper's "same pseudorandom number generator for a fair quantitative
evaluation" premise.
"""

from __future__ import annotations

import numpy as np

from .rng import draw_u32_np, fmix32_np


def build_ring(node_ids, virtual_nodes: int) -> tuple[np.ndarray, np.ndarray]:
    """Initial stage as bare arrays: (sorted ring hashes u32, owners u32).

    The canonical lookup state the ``PlacementEngine`` baseline backend
    caches per cluster version (the ring analogue of the segment table).
    """
    nodes = np.asarray(list(node_ids), dtype=np.uint32)
    if nodes.shape[0] == 0:
        raise ValueError("need at least one node")
    ids = np.repeat(nodes, int(virtual_nodes))
    vidx = np.tile(np.arange(int(virtual_nodes), dtype=np.uint32), nodes.shape[0])
    hashes = draw_u32_np(ids, np.uint32(0), vidx)
    order = np.argsort(hashes, kind="stable")
    return hashes[order], ids[order]


def ch_place_np(datum_ids, ring_hashes: np.ndarray, ring_owners: np.ndarray) -> np.ndarray:
    """NumPy oracle for the distribution stage: first ring point clockwise.

    Bit-identical to the jnp twin / Pallas binary-search kernel in
    ``repro.kernels.baselines`` (tested).
    """
    ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
    if ids.shape[0] == 0:
        return np.zeros(0, dtype=np.int64)
    h = fmix32_np(ids)
    idx = np.searchsorted(ring_hashes, h, side="left")
    idx = np.where(idx == ring_hashes.shape[0], 0, idx)  # wrap
    return ring_owners[idx].astype(np.int64)


class ConsistentHashRing:
    def __init__(self, node_ids, virtual_nodes: int = 100):
        self.virtual_nodes = int(virtual_nodes)
        self.node_ids = np.asarray(list(node_ids), dtype=np.uint32)
        # initial stage: NV hash numbers, sorted once.
        self.ring_hashes, self.ring_owners = build_ring(
            self.node_ids, self.virtual_nodes
        )

    def memory_bytes(self) -> int:
        """Table II accounting: 8NV bytes (4-byte hash + 4-byte owner)."""
        return 8 * self.ring_hashes.shape[0]

    def place(self, datum_ids) -> np.ndarray:
        """Distribution stage: datum hash -> first ring point clockwise."""
        return ch_place_np(datum_ids, self.ring_hashes, self.ring_owners)
