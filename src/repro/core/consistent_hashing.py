"""Consistent Hashing baseline (Karger et al. 1997), as evaluated in the paper.

Faithful to the paper's section IV setup: each node gets V virtual-node hash
numbers placed on a 32-bit ring; the initial stage sorts them (O(NV log NV));
the distribution stage hashes the datum id and binary-searches the ring
(O(log NV)).  Memory is O(NV) -- 8 bytes per virtual node (Table II).

The same counter-based generator used by ASURA produces the hashes, matching
the paper's "same pseudorandom number generator for a fair quantitative
evaluation" premise.
"""

from __future__ import annotations

import numpy as np

from .rng import draw_u32_np, fmix32_np


class ConsistentHashRing:
    def __init__(self, node_ids, virtual_nodes: int = 100):
        self.virtual_nodes = int(virtual_nodes)
        self.node_ids = np.asarray(list(node_ids), dtype=np.uint32)
        n = self.node_ids.shape[0]
        if n == 0:
            raise ValueError("need at least one node")
        # initial stage: NV hash numbers, sorted once.
        ids = np.repeat(self.node_ids, self.virtual_nodes)
        vidx = np.tile(np.arange(self.virtual_nodes, dtype=np.uint32), n)
        hashes = draw_u32_np(ids, np.uint32(0), vidx)
        order = np.argsort(hashes, kind="stable")
        self.ring_hashes = hashes[order]
        self.ring_owners = ids[order]

    def memory_bytes(self) -> int:
        """Table II accounting: 8NV bytes (4-byte hash + 4-byte owner)."""
        return 8 * self.ring_hashes.shape[0]

    def place(self, datum_ids) -> np.ndarray:
        """Distribution stage: datum hash -> first ring point clockwise."""
        h = fmix32_np(np.asarray(datum_ids, dtype=np.uint32))
        idx = np.searchsorted(self.ring_hashes, h, side="left")
        idx = np.where(idx == self.ring_hashes.shape[0], 0, idx)  # wrap
        return self.ring_owners[idx]
