"""Counter-based pseudorandom draws shared by every ASURA implementation.

The paper's reference implementation seeds a (SIMD-oriented) Mersenne Twister
per datum.  A stateful sequential PRNG is hostile to batched TPU execution, so
we use a *counter-based* construction instead (DESIGN.md section 3): the k-th
draw of the level-``l`` generator for datum ``id`` is

    u(id, l, k) = fmix32(fmix32(id + GOLDEN * (l + 1)) ^ (k * KMULT)) / 2**32

which preserves the three properties the paper requires of its generator
family (section 2.C):

  1. same seed (datum id)      -> same sequence,
  2. different seed            -> superficially independent sequence,
  3. draws are near-uniform on [0, 1).

``fmix32`` is the MurmurHash3 32-bit finalizer, a well-studied bijective
mixer.  Every draw is independently computable -- no sequential state -- so a
batch of a million ids maps onto the TPU VPU as pure element-wise integer ops.

All implementations (scalar oracle, vectorized NumPy, jnp reference, Pallas
kernel) use *bit-identical* arithmetic so they can be cross-checked exactly.
"""

from __future__ import annotations

import numpy as np

M32 = np.uint32(0xFFFFFFFF)
GOLDEN = 0x9E3779B9  # 2**32 / golden ratio
KMULT = 0x85EBCA77   # odd multiplier decorrelating the counter stream

_INV_2_32 = float(2.0**-32)


def fmix32_scalar(h: int) -> int:
    """MurmurHash3 finalizer on a Python int (masked to 32 bits)."""
    h &= 0xFFFFFFFF
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & 0xFFFFFFFF
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & 0xFFFFFFFF
    h ^= h >> 16
    return h


def draw_u32_scalar(datum_id: int, level: int, counter: int) -> int:
    """The k-th raw 32-bit draw of the level-``level`` generator."""
    seed = fmix32_scalar((datum_id + GOLDEN * (level + 1)) & 0xFFFFFFFF)
    return fmix32_scalar(seed ^ ((counter * KMULT) & 0xFFFFFFFF))


def draw_u01_scalar(datum_id: int, level: int, counter: int) -> float:
    """Uniform draw on [0, 1) -- scalar oracle path."""
    return draw_u32_scalar(datum_id, level, counter) * _INV_2_32


def fmix32_np(h: np.ndarray) -> np.ndarray:
    """Vectorized MurmurHash3 finalizer (uint32 in, uint32 out)."""
    h = h.astype(np.uint32)
    h ^= h >> np.uint32(16)
    h *= np.uint32(0x85EBCA6B)
    h ^= h >> np.uint32(13)
    h *= np.uint32(0xC2B2AE35)
    h ^= h >> np.uint32(16)
    return h


def draw_u32_np(datum_ids: np.ndarray, level, counters) -> np.ndarray:
    """Vectorized raw draws; broadcasts over ids/levels/counters."""
    ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
    lvl = np.atleast_1d(np.asarray(level, dtype=np.uint32))
    ctr = np.atleast_1d(np.asarray(counters, dtype=np.uint32))
    with np.errstate(over="ignore"):  # uint32 wrap-around is intended
        seed = fmix32_np(ids + np.uint32(GOLDEN) * (lvl + np.uint32(1)))
        out = fmix32_np(seed ^ (ctr * np.uint32(KMULT)))
    return out


def draw_u01_np(datum_ids: np.ndarray, level, counters) -> np.ndarray:
    return draw_u32_np(datum_ids, level, counters).astype(np.float64) * _INV_2_32


def hash_str_to_u32(s: str) -> int:
    """Stable string -> uint32 for node / datum ids given as strings."""
    h = 0x811C9DC5  # FNV-1a
    for b in s.encode("utf-8"):
        h = ((h ^ b) * 0x01000193) & 0xFFFFFFFF
    return fmix32_scalar(h)
