"""Core ASURA algorithm (the paper's contribution) and comparison baselines."""

from .asura import (
    DEFAULT_PARAMS,
    AsuraParams,
    addition_number,
    addition_numbers_batch,
    align_replica_sets,
    place_batch,
    place_nodes_batch,
    place_replicas_batch,
    place_replicas_scalar,
    place_scalar,
    placement_trace,
    remove_numbers,
    remove_numbers_batch,
    resolve_tail_np,
    tail_cumsum_halves,
)
from .cluster import Cluster, NodeInfo, make_cluster, make_uniform_cluster
from .engine import (
    ALGORITHMS,
    BaselineArtifact,
    HierArtifact,
    PlacementEngine,
    TableArtifact,
)
from .hierarchy import HierarchicalCluster
from .consistent_hashing import ConsistentHashRing, build_ring, ch_place_np
from .random_slicing import RandomSlicingTable, rs_place_np
from .straw import StrawBucket
from .wrh import wrh_place_np

__all__ = [
    "ALGORITHMS",
    "AsuraParams",
    "BaselineArtifact",
    "DEFAULT_PARAMS",
    "Cluster",
    "NodeInfo",
    "ConsistentHashRing",
    "HierArtifact",
    "HierarchicalCluster",
    "PlacementEngine",
    "RandomSlicingTable",
    "StrawBucket",
    "TableArtifact",
    "build_ring",
    "ch_place_np",
    "rs_place_np",
    "wrh_place_np",
    "addition_number",
    "addition_numbers_batch",
    "align_replica_sets",
    "make_cluster",
    "make_uniform_cluster",
    "place_batch",
    "place_nodes_batch",
    "place_replicas_batch",
    "place_replicas_scalar",
    "place_scalar",
    "placement_trace",
    "remove_numbers",
    "remove_numbers_batch",
    "resolve_tail_np",
    "tail_cumsum_halves",
]
