"""PlacementEngine: one versioned, device-resident table artifact per cluster.

Every placement consumer (router, elastic coordinator, data pipeline,
checkpoint store, serving driver) used to re-derive, re-pad and re-upload the
STEP-1 segment table on every call.  The engine owns a cached
``TableArtifact`` keyed by ``Cluster.version``:

  * ``len32``    -- canonical u32 lengths (round(length * 2**32)),
  * ``node_of``  -- int32 seg->node map (-1 on holes),
  * ``top_level``-- the static generator-ladder entry level,
  * device copies, lane-padded for the Pallas kernels,

so a STEP-1 mutation produces exactly ONE table materialization (one
host->device upload on accelerator backends) no matter how many placement
calls follow -- the ``uploads`` counter asserts this in tests.  STEP 2 then
dispatches to one of three bit-identical backends:

  * ``numpy``  -- vectorized NumPy (the CPU-host default; no device round
                  trip for table or ids),
  * ``ref``    -- jitted pure-jnp reference,
  * ``pallas`` -- the Pallas kernel family (the TPU default), including the
                  section 5.A replica-placement kernel.

The non-converged tail (p < 2**-53 per lane) is resolved by the single
exact-integer spec ``resolve_tail_np`` on every backend (DESIGN.md section
3.2), so results are bit-for-bit independent of the backend choice.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from .asura import (
    DEFAULT_PARAMS,
    AsuraParams,
    _upper_bound,
    lengths_to_u32,
    place_batch_u32,
    place_replicas_u32,
    resolve_tail_np,
)

BACKENDS = ("auto", "numpy", "ref", "pallas")


@dataclasses.dataclass(frozen=True)
class TableArtifact:
    """Immutable snapshot of one cluster version's placement table.

    ``len32`` / ``node_of`` are the host (unpadded) canonical arrays --
    ``node_of`` is int64 so per-call seg->node gathers never widen-copy the
    table; ``len32_dev`` / ``node_of_dev`` are the lane-padded device copies
    (None on the numpy backend, which never touches a device).
    """

    version: int
    n_segs: int
    top_level: int
    len32: np.ndarray
    node_of: np.ndarray
    len32_dev: Any = None
    node_of_dev: Any = None


class PlacementEngine:
    """Cached STEP-2 dispatcher bound to one mutable ``Cluster``.

    The engine is deliberately duck-typed on the cluster: anything exposing
    ``version``, ``params``, ``seg_lengths()`` and ``seg_to_node()`` works.
    """

    def __init__(
        self,
        cluster,
        *,
        backend: str = "auto",
        interpret: bool | None = None,
        rows_per_block: int | None = None,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        self.cluster = cluster
        self.params: AsuraParams = getattr(cluster, "params", DEFAULT_PARAMS)
        self._backend = backend
        self._interpret = interpret
        self._rows_per_block = rows_per_block
        self._artifact: TableArtifact | None = None
        self.uploads = 0  # table materializations (one per cluster version used)

    # -- artifact lifecycle --------------------------------------------------

    @property
    def backend(self) -> str:
        if self._backend == "auto":
            # Lazy: only decide (and import jax) when placement is requested.
            import jax

            self._backend = "pallas" if jax.default_backend() == "tpu" else "numpy"
        return self._backend

    def artifact(self) -> TableArtifact:
        """The current version's table, rebuilding (and re-uploading) only
        when ``cluster.version`` has moved past the cached artifact."""
        version = self.cluster.version
        if self._artifact is not None and self._artifact.version == version:
            return self._artifact
        lengths = np.asarray(self.cluster.seg_lengths(), dtype=np.float64)
        len32 = lengths_to_u32(lengths)
        node_of = np.asarray(self.cluster.seg_to_node(), dtype=np.int64)
        top_level = self.params.level_for(_upper_bound(lengths))
        len32_dev = node_of_dev = None
        if self.backend != "numpy":
            from repro.kernels.ops import node_table_prep, table_prep

            len32_dev, _ = table_prep(lengths, self.params)
            node_of_dev = node_table_prep(node_of)
        self._artifact = TableArtifact(
            version=version,
            n_segs=len(len32),
            top_level=top_level,
            len32=len32,
            node_of=node_of,
            len32_dev=len32_dev,
            node_of_dev=node_of_dev,
        )
        self.uploads += 1
        return self._artifact

    def invalidate(self) -> None:
        """Drop the cached artifact (next placement rebuilds it)."""
        self._artifact = None

    # -- STEP 2 dispatch -----------------------------------------------------

    def _kernel_kwargs(self) -> dict:
        kw: dict = {
            "params": self.params,
            "use_pallas": self.backend == "pallas",
            "interpret": self._interpret,
        }
        if self._rows_per_block is not None:
            kw["rows_per_block"] = self._rows_per_block
        return kw

    def place(self, datum_ids) -> np.ndarray:
        """Batch placement -> int64 segment numbers (tail-resolved, total)."""
        art = self.artifact()
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        if self.backend == "numpy":
            segs = place_batch_u32(ids, art.len32, art.top_level, self.params)
            return resolve_tail_np(ids, segs, art.len32, art.top_level)
        from repro.kernels.ops import place_on_table

        return place_on_table(
            ids, art.len32_dev, top_level=art.top_level, **self._kernel_kwargs()
        )

    def place_nodes(self, datum_ids) -> np.ndarray:
        """Batch placement -> int64 node ids."""
        art = self.artifact()
        return art.node_of[self.place(datum_ids)]

    def place_replicas(self, datum_ids, n_replicas: int) -> np.ndarray:
        """(batch, R) segment numbers on R distinct nodes, primary first."""
        art = self.artifact()
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        if self.backend == "numpy":
            return place_replicas_u32(
                ids, art.len32, art.node_of, n_replicas, art.top_level, self.params
            )
        from repro.kernels.ops import place_replicas_on_table

        return place_replicas_on_table(
            ids,
            art.len32_dev,
            art.node_of_dev,
            n_replicas,
            top_level=art.top_level,
            **self._kernel_kwargs(),
        )

    def place_replica_nodes(self, datum_ids, n_replicas: int) -> np.ndarray:
        """(batch, R) node ids, primary first."""
        art = self.artifact()
        return art.node_of[self.place_replicas(datum_ids, n_replicas)]
