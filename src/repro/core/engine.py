"""PlacementEngine: versioned, device-resident table artifacts per cluster.

Every placement consumer (router, elastic coordinator, data pipeline,
checkpoint store, serving driver) used to re-derive, re-pad and re-upload the
STEP-1 segment table on every call.  The engine owns a small LRU cache of
``TableArtifact`` snapshots keyed by ``Cluster.version``:

  * ``len32``    -- canonical u32 lengths (round(length * 2**32)),
  * ``node_of``  -- int32 seg->node map (-1 on holes),
  * ``top_level``-- the static generator-ladder entry level,
  * device copies, lane-padded for the kernels, including the u64
    length-cumsum as two u32 halves (the device-resident tail tables,
    DESIGN.md section 3.2),

so a STEP-1 mutation produces exactly ONE table materialization (one
host->device upload on accelerator backends) no matter how many placement
calls follow -- the ``uploads`` counter asserts this in tests.  The cache
holds the ``CACHE_VERSIONS`` most-recent versions, so a router flapping
between two live versions (rollback, A/B drain) re-materializes nothing.

STEP 2 dispatches to one of three bit-identical backends:

  * ``numpy``  -- vectorized NumPy (the CPU-host default; no device round
                  trip for table or ids),
  * ``ref``    -- jitted pure-jnp reference,
  * ``pallas`` -- the Pallas kernel family (the TPU default), including the
                  section 5.A replica-placement kernel.

Host-facing methods (``place`` / ``place_nodes`` / ``place_replicas``)
return NumPy arrays with exactly one device->host transfer on accelerator
backends.  The ``*_device`` variants return device arrays with ZERO host
syncs -- placement, the non-converged tail and the seg->node gather all run
on device -- for consumers that chain into further device work.

The non-converged tail (p < 2**-53 per lane) follows the single
exact-integer spec (``resolve_tail_np`` on the host, ``resolve_tail_dev``
on device -- bit-identical; DESIGN.md section 3.2), so results are
bit-for-bit independent of the backend choice.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any

import numpy as np

from .asura import (
    DEFAULT_PARAMS,
    AsuraParams,
    _upper_bound,
    lengths_to_u32,
    place_batch_u32,
    place_replicas_u32,
    resolve_tail_np,
)

BACKENDS = ("auto", "numpy", "ref", "pallas")

CACHE_VERSIONS = 4  # most-recent table versions kept materialized


@dataclasses.dataclass(frozen=True)
class TableArtifact:
    """Immutable snapshot of one cluster version's placement table.

    ``len32`` / ``node_of`` are the host (unpadded) canonical arrays --
    ``node_of`` is int64 so per-call seg->node gathers never widen-copy the
    table; ``len32_dev`` / ``node_of_dev`` / ``cum_hi_dev`` / ``cum_lo_dev``
    are the lane-padded device copies (None until a device path needs them;
    the numpy backend never builds them unless a ``*_device`` variant is
    called).
    """

    version: int
    n_segs: int
    top_level: int
    len32: np.ndarray
    node_of: np.ndarray
    len32_dev: Any = None
    node_of_dev: Any = None
    cum_hi_dev: Any = None
    cum_lo_dev: Any = None

    @property
    def has_device_tables(self) -> bool:
        return self.len32_dev is not None


class PlacementEngine:
    """Cached STEP-2 dispatcher bound to one mutable ``Cluster``.

    The engine is deliberately duck-typed on the cluster: anything exposing
    ``version``, ``params``, ``seg_lengths()`` and ``seg_to_node()`` works.
    """

    def __init__(
        self,
        cluster,
        *,
        backend: str = "auto",
        interpret: bool | None = None,
        rows_per_block: int | None = None,
        cache_versions: int = CACHE_VERSIONS,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        if cache_versions < 1:
            raise ValueError("cache_versions must be >= 1")
        self.cluster = cluster
        self.params: AsuraParams = getattr(cluster, "params", DEFAULT_PARAMS)
        self._backend = backend
        self._interpret = interpret
        self._rows_per_block = rows_per_block
        self._cache_versions = cache_versions
        # version -> TableArtifact, most-recently-used last.
        self._artifacts: OrderedDict[int, TableArtifact] = OrderedDict()
        self.uploads = 0  # table materializations (one per cluster version used)

    # -- artifact lifecycle --------------------------------------------------

    @property
    def backend(self) -> str:
        if self._backend == "auto":
            # Lazy: only decide (and import jax) when placement is requested.
            import jax

            self._backend = "pallas" if jax.default_backend() == "tpu" else "numpy"
        return self._backend

    def _build_device_tables(self, art: TableArtifact) -> TableArtifact:
        """Fill the lane-padded device copies (one host->device upload)."""
        import jax.numpy as jnp

        from repro.kernels.ops import _lane_pad_np, node_table_prep, tail_prep

        len32_pad = _lane_pad_np(art.len32, np.uint32(0))
        cum_hi, cum_lo = tail_prep(len32_pad)
        return dataclasses.replace(
            art,
            len32_dev=jnp.asarray(len32_pad),
            node_of_dev=node_table_prep(art.node_of),
            cum_hi_dev=cum_hi,
            cum_lo_dev=cum_lo,
        )

    def artifact(self) -> TableArtifact:
        """The current version's table, rebuilding (and re-uploading) only
        when ``cluster.version`` is not among the cached artifacts."""
        version = self.cluster.version
        art = self._artifacts.get(version)
        if art is not None:
            self._artifacts.move_to_end(version)
            return art
        lengths = np.asarray(self.cluster.seg_lengths(), dtype=np.float64)
        len32 = lengths_to_u32(lengths)
        node_of = np.asarray(self.cluster.seg_to_node(), dtype=np.int64)
        top_level = self.params.level_for(_upper_bound(lengths))
        art = TableArtifact(
            version=version,
            n_segs=len(len32),
            top_level=top_level,
            len32=len32,
            node_of=node_of,
        )
        if self.backend != "numpy":
            art = self._build_device_tables(art)
        self._artifacts[version] = art
        while len(self._artifacts) > self._cache_versions:
            self._artifacts.popitem(last=False)
        self.uploads += 1
        return art

    def _device_artifact(self) -> TableArtifact:
        """Like ``artifact()`` but guaranteed to carry device tables.

        On the numpy backend the device tables are built lazily on the
        first ``*_device`` call (part of the same version's one
        materialization -- the ``uploads`` counter does not tick again).
        """
        art = self.artifact()
        if not art.has_device_tables:
            art = self._build_device_tables(art)
            self._artifacts[art.version] = art
        return art

    def artifact_for(self, version: int) -> TableArtifact:
        """The table artifact of a SPECIFIC version (migration dual-serving).

        The current version is built on demand; any other version must
        still be in the LRU (a consumer that placed at that version keeps
        it cached -- the flap/rollback pattern).  An evicted version cannot
        be rebuilt (the cluster has moved on), so this raises ``KeyError``
        rather than silently re-deriving the wrong table.
        """
        if version == self.cluster.version:
            return self.artifact()
        art = self._artifacts.get(version)
        if art is None:
            raise KeyError(
                f"table version {version} not cached (LRU holds "
                f"{list(self._artifacts)}); place at that version before "
                "mutating, or raise cache_versions"
            )
        self._artifacts.move_to_end(version)
        return art

    def _device_artifact_for(self, version: int) -> TableArtifact:
        """``artifact_for`` with device tables (same materialization)."""
        art = self.artifact_for(version)
        if not art.has_device_tables:
            art = self._build_device_tables(art)
            self._artifacts[art.version] = art
        return art

    def invalidate(self) -> None:
        """Drop every cached artifact (next placement rebuilds)."""
        self._artifacts.clear()

    # -- STEP 2 dispatch -----------------------------------------------------

    def _kernel_kwargs(self) -> dict:
        kw: dict = {
            "params": self.params,
            "use_pallas": self.backend == "pallas",
            "interpret": self._interpret,
        }
        if self._rows_per_block is not None:
            kw["rows_per_block"] = self._rows_per_block
        return kw

    def place(self, datum_ids) -> np.ndarray:
        """Batch placement -> int64 segment numbers (tail-resolved, total)."""
        art = self.artifact()
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        if self.backend == "numpy":
            segs = place_batch_u32(ids, art.len32, art.top_level, self.params)
            return resolve_tail_np(ids, segs, art.len32, art.top_level)
        return np.asarray(self.place_device(ids)).astype(np.int64)

    def place_nodes(self, datum_ids) -> np.ndarray:
        """Batch placement -> int64 node ids."""
        art = self.artifact()
        if self.backend == "numpy":
            return art.node_of[self.place(datum_ids)]
        return np.asarray(self.place_nodes_device(datum_ids)).astype(np.int64)

    def place_replicas(self, datum_ids, n_replicas: int) -> np.ndarray:
        """(batch, R) segment numbers on R distinct nodes, primary first."""
        art = self.artifact()
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        if self.backend == "numpy":
            return place_replicas_u32(
                ids, art.len32, art.node_of, n_replicas, art.top_level, self.params
            )
        from repro.kernels.ops import place_replicas_on_table

        art = self._device_artifact()
        return place_replicas_on_table(
            ids,
            art.len32_dev,
            art.node_of_dev,
            n_replicas,
            top_level=art.top_level,
            **self._kernel_kwargs(),
        )

    def place_replica_nodes(self, datum_ids, n_replicas: int) -> np.ndarray:
        """(batch, R) node ids, primary first."""
        art = self.artifact()
        return art.node_of[self.place_replicas(datum_ids, n_replicas)]

    # -- version-pinned placement (migration dual-version serving) -----------

    def place_at(self, datum_ids, version: int) -> np.ndarray:
        """Batch placement under a SPECIFIC cached table version -> int64
        segments (tail-resolved, total).  Same results ``place`` gave while
        that version was current -- the dual-version read rule's building
        block (DESIGN.md section 8)."""
        art = self.artifact_for(version)
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        if self.backend == "numpy":
            segs = place_batch_u32(ids, art.len32, art.top_level, self.params)
            return resolve_tail_np(ids, segs, art.len32, art.top_level)
        return np.asarray(self.place_device_at(ids, version)).astype(np.int64)

    def place_nodes_at(self, datum_ids, version: int) -> np.ndarray:
        """Batch placement under a specific version -> int64 node ids."""
        art = self.artifact_for(version)
        return art.node_of[self.place_at(datum_ids, version)]

    # -- device-resident variants (zero host syncs) --------------------------

    def place_device(self, datum_ids):
        """Batch placement -> (batch,) int32 DEVICE array, total, sync-free.

        Pass device-resident ids to keep the whole chain on device; NumPy
        ids are uploaded once.  On the numpy backend this routes through
        the jnp reference kernels (the device tables are built lazily).
        """
        from repro.kernels.ops import place_on_table_device

        art = self._device_artifact()
        return place_on_table_device(
            datum_ids,
            art.len32_dev,
            art.cum_hi_dev,
            art.cum_lo_dev,
            art.node_of_dev,  # cached: avoids a per-call dummy node table
            top_level=art.top_level,
            **self._device_kwargs(),
        )

    def place_nodes_device(self, datum_ids):
        """Batch placement -> (batch,) int32 node ids on device (fused
        seg->node gather, on-device tail, zero host syncs)."""
        from repro.kernels.ops import place_nodes_on_table_device

        art = self._device_artifact()
        return place_nodes_on_table_device(
            datum_ids,
            art.len32_dev,
            art.cum_hi_dev,
            art.cum_lo_dev,
            art.node_of_dev,
            top_level=art.top_level,
            **self._device_kwargs(),
        )

    def place_replica_nodes_device(self, datum_ids, n_replicas: int):
        """(batch, R) int32 node ids on device, primary first, zero host
        syncs.  Non-converged entries stay -1 (checking would force a
        sync); the host variant raises instead."""
        from repro.kernels.ops import place_replicas_on_table_device

        art = self._device_artifact()
        return place_replicas_on_table_device(
            datum_ids,
            art.len32_dev,
            art.node_of_dev,
            n_replicas,
            top_level=art.top_level,
            emit_nodes=True,
            **self._device_kwargs(),
        )

    def place_device_at(self, datum_ids, version: int):
        """``place_device`` under a specific cached version (zero syncs)."""
        from repro.kernels.ops import place_on_table_device

        art = self._device_artifact_for(version)
        return place_on_table_device(
            datum_ids,
            art.len32_dev,
            art.cum_hi_dev,
            art.cum_lo_dev,
            art.node_of_dev,
            top_level=art.top_level,
            **self._device_kwargs(),
        )

    def place_nodes_device_at(self, datum_ids, version: int):
        """``place_nodes_device`` under a specific cached version."""
        from repro.kernels.ops import place_nodes_on_table_device

        art = self._device_artifact_for(version)
        return place_nodes_on_table_device(
            datum_ids,
            art.len32_dev,
            art.cum_hi_dev,
            art.cum_lo_dev,
            art.node_of_dev,
            top_level=art.top_level,
            **self._device_kwargs(),
        )

    # -- migration planner primitives ----------------------------------------

    def diff_nodes_device(self, datum_ids, v_from: int, v_to: int):
        """Two-version placement diff -> (moved, src, dst) DEVICE arrays.

        Places every id under the ``v_from`` and ``v_to`` table artifacts
        (both must be in the LRU -- they are, during a migration window) in
        one device pass: ``src``/``dst`` are int32 node ids under the two
        versions and ``moved = src != dst``.  Zero host syncs -- the
        streaming planner chains chunks of this in fixed device memory
        (DESIGN.md section 8).
        """
        from repro.kernels.ops import diff_nodes_on_tables_device

        art_a = self._device_artifact_for(v_from)
        art_b = self._device_artifact_for(v_to)
        return diff_nodes_on_tables_device(
            datum_ids,
            art_a.len32_dev,
            art_a.cum_hi_dev,
            art_a.cum_lo_dev,
            art_a.node_of_dev,
            art_b.len32_dev,
            art_b.cum_hi_dev,
            art_b.cum_lo_dev,
            art_b.node_of_dev,
            top_a=art_a.top_level,
            top_b=art_b.top_level,
            **self._device_kwargs(),
        )

    def addition_numbers_device(
        self, datum_ids, version: int | None = None, n_replicas: int = 1
    ):
        """Device-resident section 2.D ADDITION NUMBERs -> int32 device array.

        The planner's add-node prefilter: computed against the (cached)
        ``version`` table (default: current).  -1 means "unknown, treat as
        candidate" -- the exact-fallback lanes the NumPy batch resolves via
        the scalar oracle would force a host sync here (see
        ``addition_numbers_ref``)."""
        from repro.kernels.ops import addition_numbers_on_table_device

        if version is None:
            version = self.cluster.version
        art = self._device_artifact_for(version)
        return addition_numbers_on_table_device(
            datum_ids,
            art.len32_dev,
            art.node_of_dev,
            top_level=art.top_level,
            n_replicas=n_replicas,
            params=self.params,
        )

    def _device_kwargs(self) -> dict:
        kw = self._kernel_kwargs()
        # numpy backend device calls run on the jnp reference kernels.
        kw["use_pallas"] = self.backend == "pallas"
        return kw
