"""PlacementEngine: versioned, device-resident table artifacts per cluster.

Every placement consumer (router, elastic coordinator, data pipeline,
checkpoint store, serving driver) used to re-derive, re-pad and re-upload the
STEP-1 segment table on every call.  The engine owns a small LRU cache of
``TableArtifact`` snapshots keyed by ``Cluster.version``:

  * ``len32``    -- canonical u32 lengths (round(length * 2**32)),
  * ``node_of``  -- int32 seg->node map (-1 on holes),
  * ``top_level``-- the static generator-ladder entry level,
  * device copies, lane-padded for the kernels, including the u64
    length-cumsum as two u32 halves (the device-resident tail tables,
    DESIGN.md section 3.2),

so a STEP-1 mutation produces exactly ONE table materialization (one
host->device upload on accelerator backends) no matter how many placement
calls follow -- the ``uploads`` counter asserts this in tests.  The cache
holds the ``CACHE_VERSIONS`` most-recent versions, so a router flapping
between two live versions (rollback, A/B drain) re-materializes nothing.

STEP 2 dispatches to one of three bit-identical backends:

  * ``numpy``  -- vectorized NumPy (the CPU-host default; no device round
                  trip for table or ids),
  * ``ref``    -- jitted pure-jnp reference,
  * ``pallas`` -- the Pallas kernel family (the TPU default), including the
                  section 5.A replica-placement kernel.

Host-facing methods (``place`` / ``place_nodes`` / ``place_replicas``)
return NumPy arrays with exactly one device->host transfer on accelerator
backends.  The ``*_device`` variants return device arrays with ZERO host
syncs -- placement, the non-converged tail and the seg->node gather all run
on device -- for consumers that chain into further device work.

The non-converged tail (p < 2**-53 per lane) follows the single
exact-integer spec (``resolve_tail_np`` on the host, ``resolve_tail_dev``
on device -- bit-identical; DESIGN.md section 3.2), so results are
bit-for-bit independent of the backend choice.

The engine also serves the paper's COMPARISON BASELINES as first-class
device backends (DESIGN.md section 9): ``algorithm`` selects ``"asura"``
(default), ``"ch"`` (consistent hashing, virtual-node ring), ``"wrh"``
(capacity-weighted rendezvous hashing) or ``"rs"`` (random slicing).  Each
baseline gets a ``BaselineArtifact`` -- its canonical lookup table,
materialized and uploaded once per cluster version, cached in a PER-
ALGORITHM LRU keyed on ``(algorithm, version)`` so an ASURA upload can
never evict or alias a same-version baseline artifact -- and the generic
``place_nodes`` / ``place_nodes_device`` / ``*_at`` entry points dispatch
on the algorithm (per-call override via ``algorithm=``).  Baseline device
paths are bit-identical to their NumPy oracles, like ASURA's.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any

import numpy as np

from .asura import (
    DEFAULT_PARAMS,
    AsuraParams,
    _upper_bound,
    lengths_to_u32,
    place_batch_u32,
    place_replicas_u32,
    resolve_tail_np,
)
from .consistent_hashing import build_ring, ch_place_np
from .random_slicing import RandomSlicingTable, rs_place_np
from .wrh import wrh_place_np

BACKENDS = ("auto", "numpy", "ref", "pallas")

ALGORITHMS = ("asura", "ch", "wrh", "rs")

CACHE_VERSIONS = 4  # most-recent table versions kept materialized per algorithm

DEFAULT_VIRTUAL_NODES = 100  # the paper's CH evaluation default

_BASELINE_ORACLE = {"ch": ch_place_np, "rs": rs_place_np, "wrh": wrh_place_np}


@dataclasses.dataclass(frozen=True)
class TableArtifact:
    """Immutable snapshot of one cluster version's placement table.

    ``len32`` / ``node_of`` are the host (unpadded) canonical arrays --
    ``node_of`` is int64 so per-call seg->node gathers never widen-copy the
    table; ``len32_dev`` / ``node_of_dev`` / ``cum_hi_dev`` / ``cum_lo_dev``
    are the lane-padded device copies (None until a device path needs them;
    the numpy backend never builds them unless a ``*_device`` variant is
    called).
    """

    version: int
    n_segs: int
    top_level: int
    len32: np.ndarray
    node_of: np.ndarray
    len32_dev: Any = None
    node_of_dev: Any = None
    cum_hi_dev: Any = None
    cum_lo_dev: Any = None

    @property
    def has_device_tables(self) -> bool:
        return self.len32_dev is not None


@dataclasses.dataclass(frozen=True)
class BaselineArtifact:
    """Immutable snapshot of one baseline algorithm's lookup table at one
    cluster version (DESIGN.md section 9).

    ``keys`` / ``vals`` are the host canonical arrays, with algorithm-
    specific meaning:

      * ``ch``  -- keys = sorted u32 ring hashes, vals = int32 owners,
      * ``rs``  -- keys = u32 interval starts (first 0), vals = int32 owners,
      * ``wrh`` -- keys = u32 node ids, vals = float32 capacity weights.

    ``keys_dev`` / ``vals_dev`` are the lane-padded device copies (None
    until a device path needs them, exactly like ``TableArtifact``).
    """

    algorithm: str
    version: int
    n_entries: int
    keys: np.ndarray
    vals: np.ndarray
    keys_dev: Any = None
    vals_dev: Any = None

    @property
    def has_device_tables(self) -> bool:
        return self.keys_dev is not None

    def memory_bytes(self) -> int:
        """Table-II accounting: 8 bytes per lookup entry (key + value)."""
        return 8 * self.n_entries


@dataclasses.dataclass(frozen=True)
class HierArtifact:
    """Immutable snapshot of one HIERARCHICAL cluster version (section 14).

    The device view of both levels: the domain-level segment table (node
    ids re-mapped to dense domain SLOTS so the section-5.A tile's
    distinct-node test is a distinct-domain test), the D per-domain tables
    stacked into flat ``(D * s_pad,)`` arrays (lengths zero-padded, node
    map -1-padded, u64-cumsum halves carried at each domain's total), and
    the per-domain top levels + domain ids as lane-padded vectors.
    ``tables_dev`` is the 8-tuple in the kernel's operand order.  Node ids
    are validated globally unique at build time (``node_domain`` is the
    host-side node -> domain accounting view).
    """

    version: int
    n_domains: int
    top_level: int
    max_top: int
    s_pad: int
    domain_ids: np.ndarray
    node_domain: dict
    tables_dev: tuple

    @property
    def statics(self) -> tuple:
        return (self.top_level, self.max_top, self.s_pad)

    @property
    def has_device_tables(self) -> bool:
        return True


class PlacementEngine:
    """Cached STEP-2 dispatcher bound to one mutable ``Cluster``.

    The engine is deliberately duck-typed on the cluster: anything exposing
    ``version``, ``params``, ``seg_lengths()`` and ``seg_to_node()`` works.
    A ``HierarchicalCluster`` (``is_hierarchical``) switches the engine into
    the domain-aware mode: two-level artifacts behind the same versioned
    LRU, ``place_replica_nodes[_device]`` emitting (domain, node) sets with
    pairwise-distinct domains, and ``diff_replicas_*`` diffing both levels
    (DESIGN.md section 14).  Flat segment-semantics methods raise a
    directed error in this mode.
    """

    def __init__(
        self,
        cluster,
        *,
        backend: str = "auto",
        algorithm: str = "asura",
        virtual_nodes: int = DEFAULT_VIRTUAL_NODES,
        interpret: bool | None = None,
        rows_per_block: int | None = None,
        cache_versions: int = CACHE_VERSIONS,
        ledger=None,
        metrics=None,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        if algorithm not in ALGORITHMS:
            raise ValueError(
                f"algorithm must be one of {ALGORITHMS}, got {algorithm!r}"
            )
        if cache_versions < 1:
            raise ValueError("cache_versions must be >= 1")
        self.cluster = cluster
        self.params: AsuraParams = getattr(cluster, "params", DEFAULT_PARAMS)
        self.hierarchical = bool(getattr(cluster, "is_hierarchical", False))
        if self.hierarchical and algorithm != "asura":
            raise ValueError(
                "hierarchical placement is ASURA-only (two-level segment "
                f"tables); got algorithm={algorithm!r}"
            )
        self.algorithm = algorithm
        self._virtual_nodes = int(virtual_nodes)
        self._backend = backend
        self._interpret = interpret
        self._rows_per_block = rows_per_block
        self._cache_versions = cache_versions
        # algorithm -> (version -> artifact, most-recently-used last).  One
        # LRU per algorithm: placements under one algorithm can never evict
        # (or alias) another algorithm's artifact of the same version.
        self._artifacts: dict[str, OrderedDict[int, Any]] = {}
        # shadow interval table mirroring cluster membership for "rs" --
        # random slicing is HISTORY-dependent (incremental re-slicing), so
        # the engine carries the table forward version to version instead of
        # re-deriving it from a membership snapshot.
        self._rs_shadow: RandomSlicingTable | None = None
        self._default_sweep = None  # lazily-built all-device ShardedSweep
        from repro.obs import TraceLedger

        # host-plane telemetry: artifact uploads / LRU hits / evictions land
        # here as counters + structured events (instance-scoped unless a
        # shared ledger is injected -- the exact upload tripwire counts in
        # the tests must never alias across engines).  ``metrics`` is the
        # optional device-plane registry consumers (planner, movers) share.
        self.ledger = ledger if ledger is not None else TraceLedger()
        self.metrics = metrics

    @property
    def uploads(self) -> int:
        """Table materializations (one per (algorithm, version)) -- a
        ledger counter behind the original attribute name."""
        return self.ledger.counter("engine.uploads")

    # -- artifact lifecycle --------------------------------------------------

    @property
    def backend(self) -> str:
        if self._backend == "auto":
            # Lazy: only decide (and import jax) when placement is requested.
            import jax

            self._backend = "pallas" if jax.default_backend() == "tpu" else "numpy"
        return self._backend

    def _build_device_tables(self, art: TableArtifact) -> TableArtifact:
        """Fill the lane-padded device copies (one host->device upload)."""
        import jax.numpy as jnp

        from repro.kernels.ops import _lane_pad_np, node_table_prep, tail_prep

        len32_pad = _lane_pad_np(art.len32, np.uint32(0))
        cum_hi, cum_lo = tail_prep(len32_pad)
        return dataclasses.replace(
            art,
            len32_dev=jnp.asarray(len32_pad),
            node_of_dev=node_table_prep(art.node_of),
            cum_hi_dev=cum_hi,
            cum_lo_dev=cum_lo,
        )

    def _resolve_algorithm(self, algorithm: str | None) -> str:
        alg = self.algorithm if algorithm is None else algorithm
        if alg not in ALGORITHMS:
            raise ValueError(f"algorithm must be one of {ALGORITHMS}, got {alg!r}")
        return alg

    def _cache(self, algorithm: str) -> OrderedDict[int, Any]:
        return self._artifacts.setdefault(algorithm, OrderedDict())

    def _store(self, algorithm: str, art) -> None:
        cache = self._cache(algorithm)
        cache[art.version] = art
        while len(cache) > self._cache_versions:
            evicted_version, _ = cache.popitem(last=False)
            self.ledger.incr("engine.lru_evictions")
            self.ledger.event(
                "engine.lru_evict", algorithm, version=evicted_version
            )

    def artifact(self, algorithm: str | None = None):
        """The current version's lookup table under ``algorithm`` (default:
        the engine's own), rebuilding (and re-uploading) only when
        ``(algorithm, cluster.version)`` is not among the cached artifacts."""
        alg = self._resolve_algorithm(algorithm)
        version = self.cluster.version
        cache = self._cache(alg)
        art = cache.get(version)
        if art is not None:
            cache.move_to_end(version)
            self.ledger.incr("engine.lru_hits")
            return art
        with self.ledger.span("engine.build_artifact", algorithm=alg,
                              version=version):
            if alg == "asura":
                art = self._build_asura_artifact(version)
            else:
                art = self._build_baseline_artifact(alg, version)
        self._store(alg, art)
        self.ledger.incr("engine.uploads")
        self.ledger.event(
            "engine.upload", alg, version=version,
            n_segs=getattr(art, "n_segs", None)
        )
        return art

    def _build_asura_artifact(self, version: int) -> TableArtifact:
        lengths = np.asarray(self.cluster.seg_lengths(), dtype=np.float64)
        len32 = lengths_to_u32(lengths)
        node_of = np.asarray(self.cluster.seg_to_node(), dtype=np.int64)
        top_level = self.params.level_for(_upper_bound(lengths))
        art = TableArtifact(
            version=version,
            n_segs=len(len32),
            top_level=top_level,
            len32=len32,
            node_of=node_of,
        )
        if self.backend != "numpy":
            art = self._build_device_tables(art)
        return art

    def _node_weights(self) -> dict[int, float]:
        nodes = getattr(self.cluster, "nodes", None)
        if nodes is None:
            raise TypeError(
                "baseline algorithms need a cluster exposing `.nodes` "
                "(node_id -> NodeInfo); this cluster is table-only"
            )
        return {int(nid): float(info.capacity) for nid, info in nodes.items()}

    def _build_baseline_artifact(self, alg: str, version: int) -> BaselineArtifact:
        weights = self._node_weights()
        node_ids = sorted(weights)
        if alg == "ch":
            # the paper's CH setup: V virtual nodes per node, unweighted.
            keys, vals = build_ring(node_ids, self._virtual_nodes)
            vals = vals.astype(np.int32)
        elif alg == "wrh":
            keys = np.asarray(node_ids, dtype=np.uint32)
            vals = np.asarray([weights[n] for n in node_ids], dtype=np.float32)
        else:  # rs
            if self._rs_shadow is None:
                self._rs_shadow = RandomSlicingTable()
            self._rs_shadow.rebalance(weights)
            keys, vals = self._rs_shadow.starts_owners()
        art = BaselineArtifact(
            algorithm=alg,
            version=version,
            n_entries=int(keys.shape[0]),
            keys=keys,
            vals=vals,
        )
        if self.backend != "numpy":
            art = self._build_baseline_device_tables(art)
        return art

    def _build_baseline_device_tables(self, art: BaselineArtifact) -> BaselineArtifact:
        """Fill the lane-padded device copies (one host->device upload)."""
        from repro.kernels.baselines import (
            ch_table_prep,
            rs_table_prep,
            wrh_table_prep,
        )

        prep = {"ch": ch_table_prep, "rs": rs_table_prep, "wrh": wrh_table_prep}
        keys_dev, vals_dev = prep[art.algorithm](art.keys, art.vals)
        return dataclasses.replace(art, keys_dev=keys_dev, vals_dev=vals_dev)

    def _with_device_tables(self, alg: str, art):
        """Ensure ``art`` carries device tables (same materialization --
        the ``uploads`` counter does not tick again)."""
        if not art.has_device_tables:
            if alg == "asura":
                art = self._build_device_tables(art)
            else:
                art = self._build_baseline_device_tables(art)
            self._cache(alg)[art.version] = art
        return art

    def _device_artifact(self, algorithm: str | None = None):
        """Like ``artifact()`` but guaranteed to carry device tables.

        On the numpy backend the device tables are built lazily on the
        first ``*_device`` call (part of the same version's one
        materialization -- the ``uploads`` counter does not tick again).
        """
        alg = self._resolve_algorithm(algorithm)
        return self._with_device_tables(alg, self.artifact(alg))

    def artifact_for(self, version: int, algorithm: str | None = None):
        """The table artifact of a SPECIFIC version (migration dual-serving,
        baseline movement accounting).

        The current version is built on demand; any other version must
        still be in the LRU (a consumer that placed at that version keeps
        it cached -- the flap/rollback pattern).  An evicted version cannot
        be rebuilt (the cluster has moved on), so this raises ``KeyError``
        rather than silently re-deriving the wrong table.
        """
        alg = self._resolve_algorithm(algorithm)
        if version == self.cluster.version:
            return self.artifact(alg)
        cache = self._cache(alg)
        art = cache.get(version)
        if art is None:
            raise KeyError(
                f"{alg} table version {version} not cached (LRU holds "
                f"{list(cache)}); place at that version before "
                "mutating, or raise cache_versions"
            )
        cache.move_to_end(version)
        return art

    def _device_artifact_for(self, version: int, algorithm: str | None = None):
        """``artifact_for`` with device tables (same materialization)."""
        alg = self._resolve_algorithm(algorithm)
        return self._with_device_tables(alg, self.artifact_for(version, alg))

    def invalidate(self) -> None:
        """Drop every cached artifact, all algorithms (next placement
        rebuilds)."""
        self._artifacts.clear()

    # -- hierarchical artifacts (DESIGN.md section 14) ------------------------

    def _require_hier(self, method: str) -> None:
        if not self.hierarchical:
            raise ValueError(
                f"{method} needs a HierarchicalCluster-bound engine; this "
                "engine's cluster is flat"
            )

    def _build_hier_artifact(self, version: int) -> HierArtifact:
        import jax.numpy as jnp

        from repro.kernels.asura_place import LANE
        from repro.kernels.ops import _lane_pad_np

        from .asura import tail_cumsum_halves

        h = self.cluster
        top = h._top
        lengths = np.asarray(top.seg_lengths(), dtype=np.float64)
        top_len32 = lengths_to_u32(lengths)
        top_level = self.params.level_for(_upper_bound(lengths))
        node_domain = h.node_domains()  # validates global node-id uniqueness
        domain_ids = np.asarray(sorted(int(d) for d in top.nodes), dtype=np.int64)
        slot_of = {int(d): i for i, d in enumerate(domain_ids)}
        top_slot = np.asarray(
            [slot_of[int(d)] if d >= 0 else -1 for d in top.seg_to_node()],
            dtype=np.int32,
        )
        dom_lens, dom_nodes, dom_tops = [], [], []
        for d in domain_ids:
            dom = h.domains[int(d)]
            dl = np.asarray(dom.seg_lengths(), dtype=np.float64)
            dom_tops.append(self.params.level_for(_upper_bound(dl)))
            dom_lens.append(lengths_to_u32(dl))
            dom_nodes.append(np.asarray(dom.seg_to_node(), dtype=np.int32))
        s_pad = -(-max(len(row) for row in dom_lens) // LANE) * LANE
        D = len(domain_ids)
        len_flat = np.zeros(D * s_pad, dtype=np.uint32)
        node_flat = np.full(D * s_pad, -1, dtype=np.int32)
        cum_hi = np.zeros(D * s_pad, dtype=np.uint32)
        cum_lo = np.zeros(D * s_pad, dtype=np.uint32)
        for i, (row, nodes) in enumerate(zip(dom_lens, dom_nodes)):
            base = i * s_pad
            len_flat[base : base + len(row)] = row
            node_flat[base : base + len(nodes)] = nodes
            hi, lo = tail_cumsum_halves(
                np.concatenate([row, np.zeros(s_pad - len(row), dtype=np.uint32)])
            )
            cum_hi[base : base + s_pad] = hi
            cum_lo[base : base + s_pad] = lo
        tables_dev = (
            jnp.asarray(_lane_pad_np(top_len32, np.uint32(0))),
            jnp.asarray(_lane_pad_np(top_slot, np.int32(-1))),
            jnp.asarray(len_flat),
            jnp.asarray(node_flat),
            jnp.asarray(cum_hi),
            jnp.asarray(cum_lo),
            jnp.asarray(_lane_pad_np(np.asarray(dom_tops, dtype=np.int32), np.int32(0))),
            jnp.asarray(_lane_pad_np(domain_ids.astype(np.int32), np.int32(0))),
        )
        return HierArtifact(
            version=version,
            n_domains=D,
            top_level=top_level,
            max_top=int(max(dom_tops)),
            s_pad=s_pad,
            domain_ids=domain_ids,
            node_domain=node_domain,
            tables_dev=tables_dev,
        )

    def hier_artifact(self) -> HierArtifact:
        """The current version's two-level artifact (same versioned LRU,
        upload ledger and eviction events as the flat artifacts)."""
        self._require_hier("hier_artifact")
        version = self.cluster.version
        cache = self._cache("hier")
        art = cache.get(version)
        if art is not None:
            cache.move_to_end(version)
            self.ledger.incr("engine.lru_hits")
            return art
        with self.ledger.span(
            "engine.build_artifact", algorithm="hier", version=version
        ):
            art = self._build_hier_artifact(version)
        self._store("hier", art)
        self.ledger.incr("engine.uploads")
        self.ledger.event(
            "engine.upload", "hier", version=version, n_segs=art.n_domains
        )
        return art

    def hier_artifact_for(self, version: int) -> HierArtifact:
        """A SPECIFIC version's two-level artifact (must be in the LRU --
        the same pin-before-mutating contract as ``artifact_for``)."""
        self._require_hier("hier_artifact_for")
        if version == self.cluster.version:
            return self.hier_artifact()
        cache = self._cache("hier")
        art = cache.get(version)
        if art is None:
            raise KeyError(
                f"hier table version {version} not cached (LRU holds "
                f"{list(cache)}); place at that version before mutating, "
                "or raise cache_versions"
            )
        cache.move_to_end(version)
        return art

    def _hier_place_kwargs(self, art: HierArtifact, n_replicas: int) -> dict:
        return dict(
            top_level=art.top_level,
            max_top=art.max_top,
            s_pad=art.s_pad,
            n_replicas=n_replicas,
            **self._device_kwargs(),
        )

    def place_replica_pairs_device(
        self, datum_ids, n_replicas: int, version: int | None = None
    ):
        """Fused two-level replication -> (2, R, batch) int32 DEVICE array
        (plane 0 domains, plane 1 nodes), zero host syncs; -1 marks
        level-1 non-convergence (too few distinct domains).  ``version``
        pins a cached table version (default: current)."""
        from repro.kernels.ops import hier_place_replicas_on_tables_device

        self._require_hier("place_replica_pairs_device")
        art = (
            self.hier_artifact()
            if version is None
            else self.hier_artifact_for(version)
        )
        return hier_place_replicas_on_tables_device(
            datum_ids, art.tables_dev, **self._hier_place_kwargs(art, n_replicas)
        )

    def place_replica_pairs(
        self, datum_ids, n_replicas: int, version: int | None = None
    ) -> np.ndarray:
        """Host-facing fused two-level replication -> (batch, R, 2) int64
        ``(domain_id, node_id)`` pairs with pairwise-DISTINCT domains,
        primary first -- bit-identical to the ``HierarchicalCluster``
        oracle.  Raises if the distinct-domain draw did not converge."""
        from repro.kernels.ops import hier_place_replicas_on_tables

        self._require_hier("place_replica_pairs")
        art = (
            self.hier_artifact()
            if version is None
            else self.hier_artifact_for(version)
        )
        return hier_place_replicas_on_tables(
            datum_ids, art.tables_dev, **self._hier_place_kwargs(art, n_replicas)
        )

    def diff_replica_domains_device(
        self, datum_ids, v_from: int, v_to: int, n_replicas: int
    ):
        """Two-level replica diff with the domain planes attached ->
        ``(moved, src, dst, src_slot, src_dom, dst_dom)`` device arrays.

        Both LEVELS of both VERSIONS are placed by the fused kernel; the
        alignment runs on the node plane (node ids are globally unique)
        and the domains ride along -- the intra-domain movement proofs and
        the durability simulator's bytes accounting read them directly.
        """
        from repro.kernels.ops import hier_diff_replicas_on_tables_device

        self._require_hier("diff_replica_domains_device")
        art_a = self.hier_artifact_for(v_from)
        art_b = self.hier_artifact_for(v_to)
        return hier_diff_replicas_on_tables_device(
            datum_ids,
            art_a.tables_dev,
            art_b.tables_dev,
            statics_a=art_a.statics,
            statics_b=art_b.statics,
            n_replicas=n_replicas,
            **self._device_kwargs(),
        )

    # -- STEP 2 dispatch -----------------------------------------------------

    def _kernel_kwargs(self) -> dict:
        kw: dict = {
            "params": self.params,
            "use_pallas": self.backend == "pallas",
            "interpret": self._interpret,
        }
        if self._rows_per_block is not None:
            kw["rows_per_block"] = self._rows_per_block
        return kw

    def _baseline_kwargs(self) -> dict:
        kw = self._kernel_kwargs()
        del kw["params"]  # baseline lookups have no generator ladder
        return kw

    def _require_asura(self, method: str) -> None:
        if self.algorithm != "asura":
            raise ValueError(
                f"{method} is segment-table semantics, ASURA-only; this "
                f"engine's algorithm is {self.algorithm!r} -- use "
                "place_nodes/place_nodes_device (they dispatch per "
                "algorithm)"
            )
        if self.hierarchical:
            raise ValueError(
                f"{method} is flat-table semantics; this engine is bound to "
                "a HierarchicalCluster -- use place_nodes / "
                "place_replica_nodes / place_replica_pairs[_device] / "
                "diff_replica{s,_domains}_device (the two-level paths)"
            )

    def place(self, datum_ids) -> np.ndarray:
        """Batch placement -> int64 segment numbers (tail-resolved, total)."""
        self._require_asura("place")
        art = self.artifact("asura")
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        if self.backend == "numpy":
            segs = place_batch_u32(ids, art.len32, art.top_level, self.params)
            return resolve_tail_np(ids, segs, art.len32, art.top_level)
        return np.asarray(self.place_device(ids)).astype(np.int64)

    def place_nodes(self, datum_ids, algorithm: str | None = None) -> np.ndarray:
        """Batch placement -> int64 node ids (dispatches on ``algorithm``)."""
        alg = self._resolve_algorithm(algorithm)
        if self.hierarchical:
            return self.place_replica_nodes(datum_ids, 1)[:, 0, 1]
        art = self.artifact(alg)
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        if alg != "asura":
            if self.backend == "numpy":
                return _BASELINE_ORACLE[alg](ids, art.keys, art.vals)
            return np.asarray(
                self.place_nodes_device(ids, algorithm=alg)
            ).astype(np.int64)
        if self.backend == "numpy":
            segs = place_batch_u32(ids, art.len32, art.top_level, self.params)
            segs = resolve_tail_np(ids, segs, art.len32, art.top_level)
            return art.node_of[segs]
        return np.asarray(
            self.place_nodes_device(ids, algorithm="asura")
        ).astype(np.int64)

    def place_replicas(self, datum_ids, n_replicas: int) -> np.ndarray:
        """(batch, R) segment numbers on R distinct nodes, primary first."""
        self._require_asura("place_replicas")
        art = self.artifact()
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        if self.backend == "numpy":
            return place_replicas_u32(
                ids, art.len32, art.node_of, n_replicas, art.top_level, self.params
            )
        from repro.kernels.ops import place_replicas_on_table

        art = self._device_artifact()
        return place_replicas_on_table(
            ids,
            art.len32_dev,
            art.node_of_dev,
            n_replicas,
            top_level=art.top_level,
            **self._kernel_kwargs(),
        )

    def place_replica_nodes(
        self, datum_ids, n_replicas: int, algorithm: str | None = None
    ) -> np.ndarray:
        """(batch, R) node ids, primary first (dispatches on ``algorithm``:
        ASURA's section-5.A distinct-node draw, or the baselines' salted
        rejection fan-out -- DESIGN.md section 12).

        HIERARCHICAL engines return (batch, R, 2) ``(domain, node)`` pairs
        instead (section-5.A applied to the DOMAIN cluster, then the salted
        per-domain node draw): the replica domains are pairwise distinct,
        so a whole-domain failure holds at most one replica of any datum.
        """
        alg = self._resolve_algorithm(algorithm)
        if self.hierarchical:
            return self.place_replica_pairs(datum_ids, n_replicas)
        if alg != "asura":
            from repro.kernels.baselines import baseline_place_replicas_np

            art = self.artifact(alg)
            ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
            if self.backend == "numpy":
                out = baseline_place_replicas_np(
                    alg, ids, art.keys, art.vals, n_replicas
                )
            else:
                out = np.asarray(
                    self.place_replica_nodes_device(ids, n_replicas, algorithm=alg)
                ).astype(np.int64)
            if n_replicas > 1 and (out < 0).any():
                raise ValueError(
                    f"{alg} replica fan-out found no {n_replicas} distinct "
                    "nodes within the try budget (R exceeds live nodes?)"
                )
            return out
        art = self.artifact("asura")
        return art.node_of[self.place_replicas(datum_ids, n_replicas)]

    def remove_numbers_batch(
        self, datum_ids, n_replicas: int, version: int | None = None
    ) -> np.ndarray:
        """Vectorized section 2.D REMOVE NUMBERS -> (batch, R) sorted segs.

        A datum's remove numbers are the floors of its replica-selecting
        ASURA numbers = the segment numbers of its R replicas, so the batch
        is one replica placement against the cached artifact plus a row
        sort -- no per-id scalar trace, and on accelerator backends the
        sweep runs on device.  Row-identical to the scalar
        ``core.asura.remove_numbers`` (tested)."""
        segs = self.place_replicas_at(
            datum_ids, self.cluster.version if version is None else version,
            n_replicas,
        )
        return np.sort(np.asarray(segs, dtype=np.int64), axis=1)

    # -- version-pinned placement (migration dual-version serving) -----------

    def place_at(self, datum_ids, version: int) -> np.ndarray:
        """Batch placement under a SPECIFIC cached table version -> int64
        segments (tail-resolved, total).  Same results ``place`` gave while
        that version was current -- the dual-version read rule's building
        block (DESIGN.md section 8)."""
        self._require_asura("place_at")
        art = self.artifact_for(version)
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        if self.backend == "numpy":
            segs = place_batch_u32(ids, art.len32, art.top_level, self.params)
            return resolve_tail_np(ids, segs, art.len32, art.top_level)
        return np.asarray(self.place_device_at(ids, version)).astype(np.int64)

    def place_nodes_at(
        self, datum_ids, version: int, algorithm: str | None = None
    ) -> np.ndarray:
        """Batch placement under a specific cached version -> int64 node ids
        (dispatches on ``algorithm`` -- the baselines' movement-accounting
        building block: diff owners across two cached versions)."""
        alg = self._resolve_algorithm(algorithm)
        if self.hierarchical:
            return self.place_replica_pairs(datum_ids, 1, version)[:, 0, 1]
        art = self.artifact_for(version, alg)
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        if alg != "asura":
            if self.backend == "numpy":
                return _BASELINE_ORACLE[alg](ids, art.keys, art.vals)
            return np.asarray(
                self.place_nodes_device_at(ids, version, algorithm=alg)
            ).astype(np.int64)
        if self.backend == "numpy":
            segs = place_batch_u32(ids, art.len32, art.top_level, self.params)
            segs = resolve_tail_np(ids, segs, art.len32, art.top_level)
            return art.node_of[segs]
        return np.asarray(
            self.place_nodes_device_at(ids, version, algorithm="asura")
        ).astype(np.int64)

    def place_replicas_at(self, datum_ids, version: int, n_replicas: int) -> np.ndarray:
        """(batch, R) segment numbers under a SPECIFIC cached version --
        the replica twin of ``place_at`` (dual-version replica serving and
        the vectorized REMOVE-NUMBER sweep build on it)."""
        self._require_asura("place_replicas_at")
        art = self.artifact_for(version)
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        if self.backend == "numpy":
            return place_replicas_u32(
                ids, art.len32, art.node_of, n_replicas, art.top_level, self.params
            )
        from repro.kernels.ops import place_replicas_on_table

        art = self._device_artifact_for(version)
        return place_replicas_on_table(
            ids,
            art.len32_dev,
            art.node_of_dev,
            n_replicas,
            top_level=art.top_level,
            **self._kernel_kwargs(),
        )

    def place_replica_nodes_at(
        self, datum_ids, version: int, n_replicas: int
    ) -> np.ndarray:
        """(batch, R) node ids under a specific cached version, primary
        first -- the migration window's replica read rule places the v+1
        sets through this (DESIGN.md section 10).  Hierarchical engines
        return (batch, R, 2) pairs, as in ``place_replica_nodes``."""
        if self.hierarchical:
            return self.place_replica_pairs(datum_ids, n_replicas, version)
        self._require_asura("place_replica_nodes_at")
        art = self.artifact_for(version)
        return art.node_of[self.place_replicas_at(datum_ids, version, n_replicas)]

    # -- device-resident variants (zero host syncs) --------------------------

    def place_device(self, datum_ids):
        """Batch placement -> (batch,) int32 DEVICE array, total, sync-free.

        Pass device-resident ids to keep the whole chain on device; NumPy
        ids are uploaded once.  On the numpy backend this routes through
        the jnp reference kernels (the device tables are built lazily).
        """
        from repro.kernels.ops import place_on_table_device

        self._require_asura("place_device")
        art = self._device_artifact("asura")
        return place_on_table_device(
            datum_ids,
            art.len32_dev,
            art.cum_hi_dev,
            art.cum_lo_dev,
            art.node_of_dev,  # cached: avoids a per-call dummy node table
            top_level=art.top_level,
            **self._device_kwargs(),
        )

    def place_nodes_device(self, datum_ids, algorithm: str | None = None):
        """Batch placement -> (batch,) int32 node ids on device, zero host
        syncs (dispatches on ``algorithm``: ASURA's fused seg->node gather
        with the on-device tail, or a baseline's lookup kernel)."""
        from repro.kernels.ops import place_nodes_on_table_device

        alg = self._resolve_algorithm(algorithm)
        if self.hierarchical:
            return self.place_replica_pairs_device(datum_ids, 1)[1, 0, :]
        art = self._device_artifact(alg)
        if alg != "asura":
            from repro.kernels.baselines import baseline_place_on_table_device

            return baseline_place_on_table_device(
                alg,
                datum_ids,
                art.keys_dev,
                art.vals_dev,
                **self._baseline_device_kwargs(),
            )
        return place_nodes_on_table_device(
            datum_ids,
            art.len32_dev,
            art.cum_hi_dev,
            art.cum_lo_dev,
            art.node_of_dev,
            top_level=art.top_level,
            **self._device_kwargs(),
        )

    def place_replica_nodes_device(
        self, datum_ids, n_replicas: int, algorithm: str | None = None
    ):
        """(batch, R) int32 node ids on device, primary first, zero host
        syncs (dispatches on ``algorithm``).  Non-converged entries stay -1
        (checking would force a sync); the host variant raises instead.
        Hierarchical engines return the (2, R, batch) pair planes of
        ``place_replica_pairs_device``."""
        from repro.kernels.ops import place_replicas_on_table_device

        alg = self._resolve_algorithm(algorithm)
        if self.hierarchical:
            return self.place_replica_pairs_device(datum_ids, n_replicas)
        if alg != "asura":
            from repro.kernels.baselines import (
                baseline_place_replicas_on_table_device,
            )

            art = self._device_artifact(alg)
            return baseline_place_replicas_on_table_device(
                alg,
                datum_ids,
                art.keys_dev,
                art.vals_dev,
                n_replicas=n_replicas,
                **self._baseline_device_kwargs(),
            )
        art = self._device_artifact("asura")
        return place_replicas_on_table_device(
            datum_ids,
            art.len32_dev,
            art.node_of_dev,
            n_replicas,
            top_level=art.top_level,
            emit_nodes=True,
            **self._device_kwargs(),
        )

    def place_device_at(self, datum_ids, version: int):
        """``place_device`` under a specific cached version (zero syncs)."""
        from repro.kernels.ops import place_on_table_device

        self._require_asura("place_device_at")
        art = self._device_artifact_for(version, "asura")
        return place_on_table_device(
            datum_ids,
            art.len32_dev,
            art.cum_hi_dev,
            art.cum_lo_dev,
            art.node_of_dev,
            top_level=art.top_level,
            **self._device_kwargs(),
        )

    def place_nodes_device_at(
        self, datum_ids, version: int, algorithm: str | None = None
    ):
        """``place_nodes_device`` under a specific cached version."""
        from repro.kernels.ops import place_nodes_on_table_device

        alg = self._resolve_algorithm(algorithm)
        if self.hierarchical:
            return self.place_replica_pairs_device(datum_ids, 1, version)[1, 0, :]
        art = self._device_artifact_for(version, alg)
        if alg != "asura":
            from repro.kernels.baselines import baseline_place_on_table_device

            return baseline_place_on_table_device(
                alg,
                datum_ids,
                art.keys_dev,
                art.vals_dev,
                **self._baseline_device_kwargs(),
            )
        return place_nodes_on_table_device(
            datum_ids,
            art.len32_dev,
            art.cum_hi_dev,
            art.cum_lo_dev,
            art.node_of_dev,
            top_level=art.top_level,
            **self._device_kwargs(),
        )

    def place_replica_nodes_device_at(
        self, datum_ids, version: int, n_replicas: int
    ):
        """``place_replica_nodes_device`` under a specific cached version
        (zero host syncs; -1 marks non-converged entries)."""
        from repro.kernels.ops import place_replicas_on_table_device

        if self.hierarchical:
            return self.place_replica_pairs_device(datum_ids, n_replicas, version)
        self._require_asura("place_replica_nodes_device_at")
        art = self._device_artifact_for(version, "asura")
        return place_replicas_on_table_device(
            datum_ids,
            art.len32_dev,
            art.node_of_dev,
            n_replicas,
            top_level=art.top_level,
            emit_nodes=True,
            **self._device_kwargs(),
        )

    # -- migration planner primitives ----------------------------------------

    def diff_nodes_device(self, datum_ids, v_from: int, v_to: int):
        """Two-version placement diff -> (moved, src, dst) DEVICE arrays.

        Places every id under the ``v_from`` and ``v_to`` table artifacts
        (both must be in the LRU -- they are, during a migration window) in
        one device pass: ``src``/``dst`` are int32 node ids under the two
        versions and ``moved = src != dst``.  Zero host syncs -- the
        streaming planner chains chunks of this in fixed device memory
        (DESIGN.md section 8).
        """
        from repro.kernels.ops import diff_nodes_on_tables_device

        self._require_asura("diff_nodes_device")
        art_a = self._device_artifact_for(v_from, "asura")
        art_b = self._device_artifact_for(v_to, "asura")
        return diff_nodes_on_tables_device(
            datum_ids,
            art_a.len32_dev,
            art_a.cum_hi_dev,
            art_a.cum_lo_dev,
            art_a.node_of_dev,
            art_b.len32_dev,
            art_b.cum_hi_dev,
            art_b.cum_lo_dev,
            art_b.node_of_dev,
            top_a=art_a.top_level,
            top_b=art_b.top_level,
            **self._device_kwargs(),
        )

    def diff_replicas_device(
        self, datum_ids, v_from: int, v_to: int, n_replicas: int
    ):
        """Two-version REPLICA-SET diff -> ``(moved, src, dst, src_slot)``
        DEVICE arrays, each (batch, R), zero host syncs.

        Places every id's full R-replica set under the ``v_from`` and
        ``v_to`` table artifacts (both must be in the LRU) in one device
        pass -- the fused dual-table replica kernel -- and aligns the two
        sets per slot: ``moved[b, r]`` iff slot r's owner actually changed
        (``dst[b, r]`` not in the v set: the section-5 minimal replica
        mass), ``src`` the vacated v-side node for moved slots (the common
        owner otherwise), ``src_slot`` its v-set position (rollback
        re-indexing).  DESIGN.md section 10.

        Hierarchical engines diff the NODE planes of the fused two-level
        placement under both versions (same 4-tuple contract, node ids are
        globally unique); ``diff_replica_domains_device`` adds the domain
        planes.
        """
        from repro.kernels.ops import diff_replicas_on_tables_device

        if self.hierarchical:
            return self.diff_replica_domains_device(
                datum_ids, v_from, v_to, n_replicas
            )[:4]
        self._require_asura("diff_replicas_device")
        art_a = self._device_artifact_for(v_from, "asura")
        art_b = self._device_artifact_for(v_to, "asura")
        return diff_replicas_on_tables_device(
            datum_ids,
            art_a.len32_dev,
            art_a.node_of_dev,
            art_b.len32_dev,
            art_b.node_of_dev,
            top_a=art_a.top_level,
            top_b=art_b.top_level,
            n_replicas=n_replicas,
            **self._device_kwargs(),
        )

    def diff_replicas_at(
        self, datum_ids, v_from: int, v_to: int, n_replicas: int
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Host-facing ``diff_replicas_device``: the same per-slot
        ``(moved, src, dst, src_slot)`` as NumPy arrays (int64 nodes).

        On the numpy backend both replica sweeps run on the vectorized host
        path and the alignment uses the single host spec
        (``core.asura.align_replica_sets``) -- bit-identical to the device
        twin; on accelerator backends this is the device path plus one
        final transfer.
        """
        from .asura import align_replica_sets

        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        if self.hierarchical:
            # Two-level diffs always run the fused kernels (jnp reference
            # twins on the numpy backend) -- one code path, both backends.
            moved, src, dst, src_slot = self.diff_replicas_device(
                ids, v_from, v_to, n_replicas
            )
            return (
                np.asarray(moved),
                np.asarray(src).astype(np.int64),
                np.asarray(dst).astype(np.int64),
                np.asarray(src_slot),
            )
        if self.backend == "numpy":
            before = self.place_replica_nodes_at(ids, v_from, n_replicas)
            after = self.place_replica_nodes_at(ids, v_to, n_replicas)
            moved, src, src_slot = align_replica_sets(before, after)
            return moved, src, after, src_slot
        moved, src, dst, src_slot = self.diff_replicas_device(
            ids, v_from, v_to, n_replicas
        )
        return (
            np.asarray(moved),
            np.asarray(src).astype(np.int64),
            np.asarray(dst).astype(np.int64),
            np.asarray(src_slot),
        )

    def addition_numbers_device(
        self, datum_ids, version: int | None = None, n_replicas: int = 1
    ):
        """Device-resident section 2.D ADDITION NUMBERs -> int32 device array.

        The planner's add-node prefilter: computed against the (cached)
        ``version`` table (default: current).  -1 means "unknown, treat as
        candidate" -- the exact-fallback lanes the NumPy batch resolves via
        the scalar oracle would force a host sync here (see
        ``addition_numbers_ref``)."""
        from repro.kernels.ops import addition_numbers_on_table_device

        self._require_asura("addition_numbers_device")
        if version is None:
            version = self.cluster.version
        art = self._device_artifact_for(version, "asura")
        return addition_numbers_on_table_device(
            datum_ids,
            art.len32_dev,
            art.node_of_dev,
            top_level=art.top_level,
            n_replicas=n_replicas,
            params=self.params,
        )

    def sharded(self, mesh=None):
        """A ``ShardedSweep`` running this engine's bulk sweeps across a
        device mesh (DESIGN.md section 11): id streams partitioned over the
        data axis, table artifacts replicated, histograms / movement
        matrices / moved counts reduced with one ``psum`` -- bit-identical
        to the single-device ``*_device`` methods.

        ``mesh=None`` spans all visible devices; sweeps on the default mesh
        are cached so repeat calls share the compiled shard_map callables.
        """
        from repro.launch.placement_mesh import ShardedSweep

        if mesh is not None:
            return ShardedSweep(self, mesh)
        if self._default_sweep is None:
            self._default_sweep = ShardedSweep(self)
        return self._default_sweep

    def _device_kwargs(self) -> dict:
        kw = self._kernel_kwargs()
        # numpy backend device calls run on the jnp reference kernels.
        kw["use_pallas"] = self.backend == "pallas"
        return kw

    def _baseline_device_kwargs(self) -> dict:
        kw = self._baseline_kwargs()
        kw["use_pallas"] = self.backend == "pallas"
        return kw
