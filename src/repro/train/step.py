"""Train / serve step factories with microbatched gradient accumulation.

``make_train_step(cfg)`` -> step(params, opt_state, batch) -> (params,
opt_state, metrics); microbatching splits the per-step batch into
``n_microbatches`` slices scanned sequentially, accumulating fp32 (or bf16)
grads -- the activation peak scales with the slice, the accumulation buffer
with the model.  ``make_serve_step(cfg)`` -> one-token decode against a
cache.  Both are pure functions ready for jax.jit(in_shardings=...).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import decode_step, loss_fn, prefill
from repro.models.config import ModelConfig

from .optimizer import AdamWConfig, adamw_init, adamw_update


def _split_micro(batch: dict, n: int) -> dict:
    def r(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible into {n} microbatches"
        return x.reshape((n, b // n) + x.shape[1:])

    return jax.tree.map(r, batch)


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig = AdamWConfig(),
    *,
    n_microbatches: int = 1,
    grad_dtype=jnp.float32,
):
    def train_step(params, opt_state, batch):
        def loss(p, mb):
            return loss_fn(cfg, p, mb)

        if n_microbatches == 1:
            (val, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
                params, batch
            )
        else:
            micro = _split_micro(batch, n_microbatches)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (val, _), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(grad_dtype), g_acc, g
                )
                return (g_acc, l_acc + val), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, grad_dtype), params
            )
            (grads, vsum), _ = jax.lax.scan(acc_step, (g0, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            val = vsum / n_microbatches
            metrics = {}
        params, opt_state, opt_metrics = adamw_update(opt_cfg, grads, opt_state, params)
        out = {"loss": val, **opt_metrics}
        return params, opt_state, out

    return train_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, batch):
        return decode_step(cfg, params, cache, batch)

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return prefill(cfg, params, batch)

    return prefill_step


def init_train_state(cfg: ModelConfig, params):
    return adamw_init(params)
