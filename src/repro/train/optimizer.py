"""AdamW from scratch (no optax), sharding-transparent.

Moments are stored fp32 with the same tree structure (and hence the same
NamedShardings) as the parameters -- with FSDP'd params this is ZeRO-1 for
free.  ``grad_dtype`` lets the accumulation run in bf16 to cut the
grad-buffer footprint for the largest models (measured in section Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, count):
    warm = jnp.minimum(count.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        p_new = p - lr * (step + cfg.weight_decay * p)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
