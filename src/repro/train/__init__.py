from .optimizer import AdamWConfig, adamw_init, adamw_update, global_norm
from .step import (
    init_train_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "global_norm",
    "init_train_state",
    "make_prefill_step",
    "make_serve_step",
    "make_train_step",
]
