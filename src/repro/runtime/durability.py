"""Event-driven durability simulation: years of failures in virtual time.

Why this exists: the hierarchical placement mode (DESIGN.md section 14)
claims failure-domain awareness buys DURABILITY -- that spreading R
replicas over R distinct racks turns a correlated whole-rack outage from a
data-loss event into a degraded-redundancy event.  This module measures
that claim with the repo's own recovery machinery instead of a closed-form
approximation: node and whole-domain failures arrive as counter-based
exponential draws on a virtual clock, each victim is repaired IN PLACE by
re-replicating its held rows through the existing ``MigrationDriver`` +
``ThrottledMover`` stack (detection via ``HeartbeatTracker``, one repair
in flight at a time, ingress-budgeted rounds), and an object is LOST the
instant every one of its R copies is simultaneously unavailable --
including copies whose restoring row has not yet landed mid-repair, so the
serialized repair queue after a correlated domain failure is exactly the
vulnerability window it is in production systems.

The failure trace is a pure function of (topology, seed, rates): two
placement policies over the same node set -- flat R-way vs domain-aware --
replay IDENTICAL failure times, so every durability delta is attributable
to placement alone (``compare_policies``).

Everything is host-side NumPy: the owners matrices come out of the engines
once (device-placed if the backend allows), then the event loop is a few
vectorized masks per failure -- simulating a decade over dozens of nodes
is milliseconds, which is what lets the benchmark suite gate on it in CI.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import numpy as np

from repro.core.rng import GOLDEN, KMULT, fmix32_np

SECONDS_PER_YEAR = 365.25 * 86_400.0


# -- deterministic failure trace ---------------------------------------------


def _u01_stream(seed: int, stream_id: int, n: int) -> np.ndarray:
    """n uniform (0, 1) draws for one entity's counter-based stream.

    Same fmix32 construction as the placement draws (core.rng): draw k of
    stream ``stream_id`` is ``fmix32(fmix32(seed ^ stream_id * GOLDEN) ^
    (k * KMULT))`` -- reproducible, order-free, and independent of every
    other stream.  The +0.5 offset keeps draws strictly inside (0, 1) so
    ``log`` below never sees 0.
    """
    with np.errstate(over="ignore"):
        base = fmix32_np(
            np.uint32(seed & 0xFFFFFFFF)
            ^ (np.uint32(stream_id & 0xFFFFFFFF) * np.uint32(GOLDEN))
        )
        ctrs = (np.arange(n, dtype=np.uint32) * np.uint32(KMULT)) ^ base
        return (fmix32_np(ctrs).astype(np.float64) + 0.5) * 2.0**-32


def _arrivals(seed: int, stream_id: int, mttf_s: float, horizon_s: float) -> np.ndarray:
    """Poisson arrival times in (0, horizon) for one failure stream."""
    if mttf_s <= 0 or not math.isfinite(mttf_s):
        return np.zeros(0, dtype=np.float64)
    # Draw enough exponentials to cross the horizon with slack, extend in
    # the (astronomically unlikely) case the batch still falls short.
    n = max(8, int(horizon_s / mttf_s * 2) + 8)
    while True:
        gaps = -np.log(_u01_stream(seed, stream_id, n)) * mttf_s
        times = np.cumsum(gaps)
        if times[-1] >= horizon_s:
            return times[times < horizon_s]
        n *= 2


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    time: float  # seconds since simulation start
    kind: str  # "node" | "domain"
    target: int  # node id, or domain id (kills every member node)


def failure_trace(
    node_domain: dict[int, int],
    *,
    years: float,
    mttf_node_years: float,
    mttf_domain_years: float,
    seed: int = 0,
) -> list[FailureEvent]:
    """The deterministic failure schedule for a topology.

    Every node and every domain gets an independent counter-based
    exponential stream keyed by (seed, entity id), so the trace depends
    only on the TOPOLOGY -- two placement policies over the same nodes
    replay the same failures.  Domain events model correlated outages
    (shared switch / PDU): every member node fails at the same instant.
    """
    horizon = years * SECONDS_PER_YEAR
    events: list[FailureEvent] = []
    for nid in sorted(node_domain):
        for t in _arrivals(seed, 2 * nid + 1, mttf_node_years * SECONDS_PER_YEAR, horizon):
            events.append(FailureEvent(float(t), "node", int(nid)))
    for did in sorted(set(node_domain.values())):
        for t in _arrivals(seed ^ 0x5BD1E995, 2 * did, mttf_domain_years * SECONDS_PER_YEAR, horizon):
            events.append(FailureEvent(float(t), "domain", int(did)))
    events.sort(key=lambda e: (e.time, e.kind, e.target))
    return events


# -- the event loop ----------------------------------------------------------


@dataclasses.dataclass
class DurabilityReport:
    years: float
    n_objects: int
    n_replicas: int
    n_nodes: int
    node_failures: int  # node-scoped failure events applied
    domain_failures: int  # correlated whole-domain events applied
    loss_incidents: int  # failure events that destroyed >= 1 object
    objects_lost: int  # distinct objects with all R copies gone
    rows_repaired: int  # (object, slot) copies re-replicated
    bytes_repaired: int
    repairs_completed: int
    max_repair_queue: int  # worst-case victims awaiting their window

    @property
    def data_loss_probability(self) -> float:
        return self.objects_lost / self.n_objects if self.n_objects else 0.0


class DurabilitySimulator:
    """Replay a failure trace against one placement's static owners matrix.

    ``owners`` is (n_objects, R) int node ids -- each row an object's
    replica set under the policy being scored.  ``node_domain`` maps every
    node to its failure domain.  Copies become unavailable when their node
    fails and come back row by row as the victim's repair lands them; an
    object whose R copies are simultaneously unavailable is lost for good
    (its rows leave the repair universe -- there is nothing to source).
    """

    def __init__(
        self,
        owners: np.ndarray,
        node_domain: dict[int, int],
        *,
        repair_ingress_rows: int = 2_000,
        round_seconds: float = 60.0,
        detect_timeout: float = 30.0,
        bytes_per_row: int = 1 << 22,
        ledger=None,
    ):
        from .failures import HeartbeatTracker, MigrationDriver

        self.owners = np.asarray(owners, dtype=np.int64)
        if self.owners.ndim != 2:
            raise ValueError("owners must be (n_objects, n_replicas)")
        self.node_domain = dict(node_domain)
        self.n_objects, self.n_replicas = self.owners.shape
        self.repair_ingress_rows = int(repair_ingress_rows)
        self.round_seconds = float(round_seconds)
        self.detect_timeout = float(detect_timeout)
        self.bytes_per_row = int(bytes_per_row)
        self.ledger = ledger
        self.now = 0.0
        self.alive: set[int] = set(self.node_domain)
        # copy_ok[o, r]: object o's slot-r copy is live on its owner
        self.copy_ok = np.ones(self.owners.shape, dtype=bool)
        self.lost = np.zeros(self.n_objects, dtype=bool)
        self.loss_incidents = 0
        self.rows_repaired = 0
        self.repairs_completed = 0
        self.max_repair_queue = 0
        self.node_failures = 0
        self.domain_failures = 0
        self.tracker = HeartbeatTracker(timeout=self.detect_timeout, clock=lambda: self.now)
        self.driver = MigrationDriver(self.tracker, self._start_repair)
        self._victim_of: dict[int, int] = {}  # id(mover) -> node id
        for nid in self.alive:
            self.tracker.beat(nid)

    # -- repair wiring (the existing migrate/runtime stack) -------------------

    def _start_repair(self, victim: int):
        """Victim -> a ThrottledMover restoring every row it held.

        The plan's unit is the (object, slot) row, dst = the victim
        (repair-in-place), src = a surviving holder of the same object --
        the mover's ingress budget on the victim is the repair bandwidth,
        and its injected clock is the simulation clock, so repair DURATION
        is rows / bandwidth in virtual time.
        """
        from repro.migrate import MigrationPlan, ThrottledMover

        obj, slot = np.nonzero((self.owners == victim) & ~self.lost[:, None])
        # Source each row from a currently-live copy of the same object;
        # rows with no live source are exactly the lost objects (already
        # accounted) -- nothing to restore.
        ok = self.copy_ok[obj]
        ok[np.arange(obj.size), slot] = False  # not from the dead copy itself
        has_src = ok.any(axis=1)
        obj, slot, ok = obj[has_src], slot[has_src], ok[has_src]
        src_slot = np.argmax(ok, axis=1).astype(np.int32)
        plan = MigrationPlan(
            v_from=0,
            v_to=0,
            ids=obj.astype(np.uint32),
            src=self.owners[obj, src_slot],
            dst=np.full(obj.size, victim, dtype=np.int64),
            index=np.arange(obj.size, dtype=np.int64),
            n_scanned=self.n_objects,
            n_replicas=self.n_replicas,
            slot=slot.astype(np.int32),
            src_slot=src_slot,
        )
        from repro.migrate.mover import MigrationState

        mover = ThrottledMover(
            MigrationState(plan),
            ingress=self.repair_ingress_rows,
            clock=lambda: self.now,
            round_seconds=self.round_seconds,
            ledger=self.ledger,
            bytes_per_row=self.bytes_per_row,
        )
        self._victim_of[id(mover)] = victim
        return mover

    # -- availability bookkeeping ---------------------------------------------

    def _absorb(self, mover) -> None:
        state = mover.state
        landed = state.landed
        if landed.any():
            self.copy_ok[
                state.plan.ids[landed].astype(np.int64), state.plan.slot[landed]
            ] = True

    def _absorb_landed(self) -> None:
        """Fold repairs' landed rows back into copy_ok: the in-flight
        mover's partial progress AND any mover the driver retired inside
        its own pump (retirement precedes this hook)."""
        for mover in self.driver.active:
            self._absorb(mover)
        self._retire_completed()

    def _retire_completed(self) -> None:
        for mover in self.driver.completed:
            victim = self._victim_of.pop(id(mover), None)
            if victim is None:
                continue  # already processed on an earlier pass
            self._absorb(mover)
            self.repairs_completed += 1
            self.rows_repaired += int(mover.state.landed.sum())
            self.alive.add(victim)
            self.tracker.beat(victim)
            self.driver.notify_recovered(victim)  # re-arm its detection

    def _pump_to(self, t: float) -> None:
        """Advance virtual time to ``t``, draining due repair rounds.

        The queue is SERIALIZED, so time must step through it: each pass
        pumps the in-flight repair's due rounds (a finished one retires
        and the next queued victim's repair starts at that instant), then
        jumps the clock straight to the next round boundary -- no
        round-by-round polling across the (weeks-long) quiet gaps, but
        queued repairs still run back to back in virtual time instead of
        waiting for the next failure to be observed.
        """
        while True:
            self.driver.pump()
            self._absorb_landed()
            if self.driver.done:
                break
            active = self.driver.active
            if not active:
                continue  # a queued repair just started; pump it next pass
            next_due = active[0].next_round_at
            if next_due is None or next_due > t:
                break
            self.now = next_due
        self.now = t
        for nid in self.alive:
            self.tracker.beat(nid)

    def _fail_nodes(self, victims: Iterable[int]) -> None:
        newly = [v for v in victims if v in self.alive]
        if not newly:
            return
        for v in newly:
            self.alive.discard(v)  # stops beating -> tracker flags it
        mask = np.isin(self.owners, newly)
        self.copy_ok[mask] = False
        fresh = ~self.copy_ok.any(axis=1) & ~self.lost
        if fresh.any():
            self.loss_incidents += 1
            self.lost |= fresh
        # Detection: the victims miss ``detect_timeout`` of heartbeats,
        # then the driver queues their serialized repairs.  The survivors
        # kept beating through the detection window.
        self.now += self.detect_timeout * 1.001
        for nid in self.alive:
            self.tracker.beat(nid)
        self.driver.poll()
        self.max_repair_queue = max(
            self.max_repair_queue, len(self.driver.queued) + len(self.driver.active)
        )

    # -- entry point -----------------------------------------------------------

    def run(self, events: list[FailureEvent], *, years: float) -> DurabilityReport:
        for ev in events:
            self._pump_to(ev.time)
            if ev.kind == "node":
                self.node_failures += 1
                self._fail_nodes([ev.target])
            else:
                self.domain_failures += 1
                self._fail_nodes(
                    [n for n, d in self.node_domain.items() if d == ev.target]
                )
        # drain the tail: every queued repair completes after the last event
        self.now += self.round_seconds
        while not self.driver.done:
            self.driver.round()
            self._absorb_landed()
        return DurabilityReport(
            years=years,
            n_objects=self.n_objects,
            n_replicas=self.n_replicas,
            n_nodes=len(self.node_domain),
            node_failures=self.node_failures,
            domain_failures=self.domain_failures,
            loss_incidents=self.loss_incidents,
            objects_lost=int(self.lost.sum()),
            rows_repaired=self.rows_repaired,
            bytes_repaired=self.rows_repaired * self.bytes_per_row,
            repairs_completed=self.repairs_completed,
            max_repair_queue=self.max_repair_queue,
        )


# -- policy comparison (the benchmark's core) ---------------------------------


def _topology_clusters(topology: dict[int, dict[int, float]]):
    """(flat Cluster, HierarchicalCluster) over the same node ids."""
    from repro.core.cluster import Cluster
    from repro.core.hierarchy import HierarchicalCluster

    flat = Cluster()
    hier = HierarchicalCluster()
    for did, members in topology.items():
        for nid, cap in members.items():
            flat.add_node(nid, cap)
            hier.add_node(did, nid, cap)
    return flat, hier


def compare_policies(
    topology: dict[int, dict[int, float]],
    *,
    n_objects: int = 50_000,
    n_replicas: int = 3,
    years: float = 10.0,
    mttf_node_years: float = 4.0,
    mttf_domain_years: float = 25.0,
    seed: int = 0,
    repair_ingress_rows: int = 2_000,
    round_seconds: float = 60.0,
    detect_timeout: float = 30.0,
    bytes_per_row: int = 1 << 22,
) -> dict[str, DurabilityReport]:
    """Flat R-way vs domain-aware placement under IDENTICAL failure traces.

    ``topology`` is {domain: {node: capacity}}.  Both policies place the
    same ``n_objects`` ids over the same nodes; the flat policy ignores
    domains (so a correlated domain failure can take out all R copies of
    an object whose replicas happened to co-reside), the hierarchical
    policy pins the R copies to R distinct domains (at most one copy per
    domain event).  Returns ``{"flat": report, "hier": report}``.
    """
    flat, hier = _topology_clusters(topology)
    node_domain = hier.node_domains()
    ids = np.arange(n_objects, dtype=np.uint32)
    owners_flat = flat.place_replicas(ids, n_replicas)
    owners_hier = hier.place_replicas(ids, n_replicas)[:, :, 1]
    events = failure_trace(
        node_domain,
        years=years,
        mttf_node_years=mttf_node_years,
        mttf_domain_years=mttf_domain_years,
        seed=seed,
    )
    out: dict[str, DurabilityReport] = {}
    for name, owners in (("flat", owners_flat), ("hier", owners_hier)):
        sim = DurabilitySimulator(
            owners,
            node_domain,
            repair_ingress_rows=repair_ingress_rows,
            round_seconds=round_seconds,
            detect_timeout=detect_timeout,
            bytes_per_row=bytes_per_row,
        )
        out[name] = sim.run(events, years=years)
    return out


def movement_on_node_add(
    topology: dict[int, dict[int, float]],
    *,
    n_objects: int = 50_000,
    n_replicas: int = 3,
    add_domain: int | None = None,
    add_capacity: float = 1.0,
) -> dict[str, float]:
    """Fraction of replica rows moved by one node add, per policy.

    The "equal movement cost" half of the durability headline: domain
    awareness must not give back ASURA's minimal-movement property.  Both
    policies add the SAME node (same id, same capacity; the hierarchical
    one inside ``add_domain``, default: the first domain) and the moved
    fraction is rows-moved / total replica rows, via each engine's fused
    replica diff.
    """
    flat, hier = _topology_clusters(topology)
    if add_domain is None:
        add_domain = sorted(topology)[0]
    new_id = max(hier.node_domains()) + 1
    ids = np.arange(n_objects, dtype=np.uint32)
    out: dict[str, float] = {}

    flat.engine.artifact()
    v0 = flat.version
    flat.add_node(new_id, add_capacity)
    moved, _, _, _ = flat.engine.diff_replicas_at(ids, v0, flat.version, n_replicas)
    out["flat"] = float(np.asarray(moved).sum()) / (n_objects * n_replicas)

    hier.engine.hier_artifact()
    w0 = hier.version
    hier.add_node(add_domain, new_id, add_capacity)
    moved_h, _, _, _ = hier.engine.diff_replicas_at(ids, w0, hier.version, n_replicas)
    out["hier"] = float(np.asarray(moved_h).sum()) / (n_objects * n_replicas)
    return out


__all__ = [
    "DurabilityReport",
    "DurabilitySimulator",
    "FailureEvent",
    "compare_policies",
    "failure_trace",
    "movement_on_node_add",
]
