from .elastic import ElasticCoordinator, MovePlan
from .failures import FailureDetector, HeartbeatTracker, MigrationDriver
from .straggler import StragglerMitigator

__all__ = [
    "ElasticCoordinator",
    "FailureDetector",
    "HeartbeatTracker",
    "MigrationDriver",
    "MovePlan",
    "StragglerMitigator",
]
