from .durability import (
    DurabilityReport,
    DurabilitySimulator,
    FailureEvent,
    compare_policies,
    failure_trace,
    movement_on_node_add,
)
from .elastic import ElasticCoordinator, MovePlan
from .failures import FailureDetector, HeartbeatTracker, MigrationDriver
from .straggler import StragglerMitigator

__all__ = [
    "DurabilityReport",
    "DurabilitySimulator",
    "ElasticCoordinator",
    "FailureDetector",
    "FailureEvent",
    "HeartbeatTracker",
    "MigrationDriver",
    "MovePlan",
    "StragglerMitigator",
    "compare_policies",
    "failure_trace",
    "movement_on_node_add",
]
