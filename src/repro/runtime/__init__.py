from .elastic import ElasticCoordinator, MovePlan
from .failures import FailureDetector, HeartbeatTracker
from .straggler import StragglerMitigator

__all__ = [
    "ElasticCoordinator",
    "FailureDetector",
    "HeartbeatTracker",
    "MovePlan",
    "StragglerMitigator",
]
