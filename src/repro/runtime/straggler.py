"""Straggler mitigation: speculative backup tasks for slow shard work.

Because shard payloads are recomputable from shard ids (data/pipeline.py) and
placement is a pure function of the table, ANY host can execute a backup copy
of a slow host's shard task.  The mitigator tracks per-task progress and
dispatches a backup to the least-loaded healthy host once a task exceeds
``threshold`` x the running median duration (MapReduce-style speculation).
First completion wins; duplicates are idempotent by construction
(deterministic task outputs keyed by shard id).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass
class TaskState:
    shard_id: int
    host: int
    started: float
    done: bool = False
    backup_host: Optional[int] = None


class StragglerMitigator:
    def __init__(self, clock: Callable[[], float], threshold: float = 2.0):
        self.clock = clock
        self.threshold = threshold
        self.tasks: dict[int, TaskState] = {}
        self.durations: list[float] = []

    def start(self, shard_id: int, host: int) -> None:
        self.tasks[shard_id] = TaskState(shard_id, host, self.clock())

    def complete(self, shard_id: int) -> None:
        t = self.tasks[shard_id]
        if not t.done:
            t.done = True
            self.durations.append(self.clock() - t.started)

    def _median(self) -> float:
        if not self.durations:
            return float("inf")
        s = sorted(self.durations)
        return s[len(s) // 2]

    def stragglers(self) -> list[TaskState]:
        med = self._median()
        now = self.clock()
        return [
            t
            for t in self.tasks.values()
            if not t.done
            and t.backup_host is None
            and now - t.started > self.threshold * med
        ]

    def dispatch_backups(self, healthy_hosts: list[int], load: dict[int, int]) -> list[tuple[int, int]]:
        """Returns (shard_id, backup_host) pairs; updates state."""
        out = []
        for t in self.stragglers():
            candidates = [h for h in healthy_hosts if h != t.host]
            if not candidates:
                continue
            backup = min(candidates, key=lambda h: load.get(h, 0))
            t.backup_host = backup
            load[backup] = load.get(backup, 0) + 1
            out.append((t.shard_id, backup))
        return out
