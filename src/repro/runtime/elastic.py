"""Elastic scaling coordinator: minimal-movement membership changes.

The coordinator owns the authoritative ASURA ``Cluster`` table (the paper's
temporary-central-node role, section 2.D -- any host can take it over since
the table is tiny and serializable).  On membership events it produces a
``MovePlan``: exactly which datum ids (shards / cache entries / checkpoint
chunks) move where.  ASURA's optimality theorems guarantee the plan is
minimal; tests/test_runtime.py re-verifies against brute force.

Change detection uses the section 2.D metadata:
  * removals: a datum is affected iff one of its REMOVE NUMBERS names a
    segment of the removed node (exact, any capacity mix),
  * additions: candidates are data whose ADDITION NUMBER is <= the assigned
    segment number (the sound "<=" rule; the paper's "==" rule is exact only
    for full-length segment tables -- see DESIGN.md section 7 and
    tests/test_asura_properties.py::test_p5*), then verified by recompute.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import Cluster
from repro.core.asura import addition_numbers_batch, remove_numbers


@dataclasses.dataclass
class MovePlan:
    """datum id -> (src node, dst node) for every datum that must move."""

    moves: dict[int, tuple[int, int]]

    @property
    def n_moves(self) -> int:
        return len(self.moves)


class ElasticCoordinator:
    def __init__(self, cluster: Cluster, tracked_ids: np.ndarray):
        self.cluster = cluster
        self.engine = cluster.engine  # shared versioned table artifact
        self.tracked = np.asarray(tracked_ids, dtype=np.uint32)
        self._owners = self.engine.place_nodes(self.tracked)
        self._an: np.ndarray | None = None  # lazy ADDITION NUMBER cache

    # -- metadata ------------------------------------------------------------

    def _addition_numbers(self) -> np.ndarray:
        if self._an is None:
            # Vectorized 2.D metadata: one batched trace over every tracked
            # id (addition_numbers_batch), not a per-id Python loop.
            art = self.engine.artifact()
            self._an = addition_numbers_batch(
                self.tracked, self.cluster.seg_lengths(), art.node_of
            )
        return self._an

    # -- events ---------------------------------------------------------------

    def add_node(self, node_id: int, capacity: float) -> MovePlan:
        """Grow the cluster; move only data captured by the new segments.

        The AN <= f prefilter shrinks the recompute set; each candidate is
        then verified by recomputing its placement (cheap, O(1))."""
        an = self._addition_numbers()
        owners_before = self._owners
        new_segs = self.cluster.add_node(node_id, capacity)
        max_seg = max(new_segs)
        candidates = np.nonzero(an <= max_seg)[0]
        moves: dict[int, tuple[int, int]] = {}
        if candidates.size:
            new_owner = self.engine.place_nodes(self.tracked[candidates])
            for idx, owner in zip(candidates, new_owner):
                if owner != owners_before[idx]:
                    moves[int(self.tracked[idx])] = (int(owners_before[idx]), int(owner))
                    self._owners[idx] = owner
        self._an = None  # ANs shift once their segment is taken; recompute lazily
        return MovePlan(moves)

    def remove_node(self, node_id: int) -> MovePlan:
        """Shrink the cluster; move exactly the data the victim held."""
        owners_before = self._owners
        victim_rows = np.nonzero(owners_before == node_id)[0]
        self.cluster.remove_node(node_id)
        moves: dict[int, tuple[int, int]] = {}
        if victim_rows.size:
            new_owner = self.engine.place_nodes(self.tracked[victim_rows])
            for idx, owner in zip(victim_rows, new_owner):
                moves[int(self.tracked[idx])] = (node_id, int(owner))
                self._owners[idx] = owner
        self._an = None
        return MovePlan(moves)

    def remove_numbers_for(self, datum_id: int, n_replicas: int) -> list[int]:
        return remove_numbers(
            datum_id,
            self.cluster.seg_lengths(),
            self.cluster.seg_to_node(),
            n_replicas,
        )

    def owners(self) -> np.ndarray:
        return self._owners.copy()
