"""Elastic scaling coordinator: minimal-movement membership changes.

The coordinator owns the authoritative ASURA ``Cluster`` table (the paper's
temporary-central-node role, section 2.D -- any host can take it over since
the table is tiny and serializable).  On membership events it produces a
``MovePlan``: exactly which datum ids (shards / cache entries / checkpoint
chunks) move where.  ASURA's optimality theorems guarantee the plan is
minimal; tests/test_runtime.py re-verifies against brute force.

Change detection uses the section 2.D metadata:
  * removals: a datum is affected iff one of its REMOVE NUMBERS names a
    segment of the removed node (exact, any capacity mix),
  * additions: candidates are data whose ADDITION NUMBER is <= the assigned
    segment number (the sound "<=" rule; the paper's "==" rule is exact only
    for full-length segment tables -- see DESIGN.md section 7 and
    tests/test_asura_properties.py::test_p5*), then verified by recompute.

The recompute itself runs through the migration planner (DESIGN.md section
8): candidates are diffed against the v and v+1 table artifacts in one
vectorized sweep -- the ``MovePlan`` dict is built from the plan's moved
arrays, not a per-candidate Python loop.  ``add_node_live`` /
``remove_node_live`` return the same change as a ``LiveMigration``: a
throttled, dual-version-served drain instead of an instantaneous swap.

With ``n_replicas > 1`` the coordinator tracks full R-way replica SETS
(section 5.A) and every event plans through the per-slot replica planner
(DESIGN.md section 10): only replicas whose owner actually changed move,
live drains serve mixed-version replica sets via
``LiveMigration.route_replicas``, and a failed node repairs as a
throttled replica migration (exactly its replica mass) instead of full
re-replication.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import Cluster
from repro.core.asura import DEFAULT_PARAMS, addition_numbers_batch
from repro.migrate import LiveMigration, MigrationPlan, MigrationPlanner


@dataclasses.dataclass
class MovePlan:
    """datum id -> (src node, dst node) for every datum that must move."""

    moves: dict[int, tuple[int, int]]

    @property
    def n_moves(self) -> int:
        return len(self.moves)


class ElasticCoordinator:
    def __init__(
        self,
        cluster: Cluster,
        tracked_ids: np.ndarray,
        *,
        algorithm: str = "asura",
        n_replicas: int = 1,
    ):
        self.cluster = cluster
        self.engine = cluster.engine  # shared versioned table artifact
        self.algorithm = algorithm
        self.n_replicas = int(n_replicas)
        if self.n_replicas > 1 and algorithm != "asura":
            raise ValueError(
                "replica-set tracking rides on ASURA's section 5.A "
                f"replication; got algorithm={algorithm!r}"
            )
        self.planner = MigrationPlanner(self.engine)
        self.tracked = np.asarray(tracked_ids, dtype=np.uint32)
        if self.n_replicas > 1:
            # (n, R) replica-node sets, primary first
            self._owners = self.engine.place_replica_nodes(
                self.tracked, self.n_replicas
            )
        else:
            self._owners = self.engine.place_nodes(self.tracked, algorithm=algorithm)
        self._an: np.ndarray | None = None  # lazy ADDITION NUMBER cache
        self._live_migration: LiveMigration | None = None  # in-flight drain
        self._last_revert = None  # (rows, before-sets) of the last replica apply

    # -- metadata ------------------------------------------------------------

    def _addition_numbers(self) -> np.ndarray:
        if self._an is None:
            # Vectorized 2.D metadata: one batched trace over every tracked
            # id (addition_numbers_batch), not a per-id Python loop -- for
            # replica sets, the R-replica trace's AN.
            art = self.engine.artifact()
            self._an = addition_numbers_batch(
                self.tracked,
                self.cluster.seg_lengths(),
                art.node_of,
                self.n_replicas,
                params=getattr(self.cluster, "params", DEFAULT_PARAMS),
            )
        return self._an

    # -- events ---------------------------------------------------------------

    def _apply(self, plan: MigrationPlan, rows: np.ndarray) -> MovePlan:
        """Fold a planner diff over ``rows`` of the tracked set into the
        owner table and a ``MovePlan`` (vectorized dict build).

        Replica mode re-places the CHANGED ids' full sets rather than
        patching moved slots: common nodes can permute positions inside a
        set across versions, so only the fresh v+1 sets are positionally
        authoritative.  The pre-event sets are remembered for
        ``rollback_live``."""
        if self.n_replicas > 1:
            changed = (
                rows[np.unique(plan.index)]
                if plan.n_moves
                else np.zeros(0, dtype=np.int64)
            )
            self._last_revert = (changed, self._owners[changed].copy())
            if len(changed):
                self._owners[changed] = self.engine.place_replica_nodes(
                    self.tracked[changed], self.n_replicas
                )
        else:
            self._owners[rows[plan.index]] = plan.dst
        self._an = None  # ANs shift once their segment is taken; recompute lazily
        return MovePlan(plan.moves_dict())

    def _plan_candidates(self, rows: np.ndarray, v_from: int) -> MigrationPlan:
        """One planner sweep over candidate rows, with the cached owner
        table supplying the v side (one placement per candidate, not two)."""
        if self.n_replicas > 1:
            return self.planner.plan_replicas(
                self.tracked[rows],
                v_from,
                self.cluster.version,
                self.n_replicas,
                known_before=self._owners[rows],
            )
        return self.planner.plan(
            self.tracked[rows],
            v_from,
            self.cluster.version,
            known_src=self._owners[rows],
        )

    def _add_plan(self, node_id: int, capacity: float):
        """Mutate the cluster; diff the AN-candidate rows -> (plan, rows).

        The AN <= f prefilter shrinks the recompute set; the candidates
        are then diffed in one planner sweep."""
        an = self._addition_numbers()
        self.engine.artifact()  # pin the v table in the LRU before mutating
        v_from = self.cluster.version
        new_segs = self.cluster.add_node(node_id, capacity)
        rows = np.nonzero(an <= max(new_segs))[0]
        return self._plan_candidates(rows, v_from), rows

    def _remove_plan(self, node_id: int):
        """Mutate the cluster; diff the victim's rows -> (plan, rows).

        Replica mode: a datum is affected iff the victim is IN its replica
        set -- the vectorized REMOVE-NUMBER test (a remove number names a
        victim segment exactly when the victim owns a replica)."""
        self.engine.artifact()
        v_from = self.cluster.version
        if self.n_replicas > 1:
            rows = np.nonzero((self._owners == node_id).any(axis=1))[0]
        else:
            rows = np.nonzero(self._owners == node_id)[0]
        self.cluster.remove_node(node_id)
        return self._plan_candidates(rows, v_from), rows

    def _baseline_event(self, mutate) -> MovePlan:
        """Movement accounting for a baseline algorithm: pin the current
        artifact, apply the membership change, and diff the tracked set's
        owners across the two cached versions -- the same before/after
        accounting the paper's section 6.D comparison uses, vectorized
        through the engine's versioned ``(algorithm, version)`` LRU."""
        self.engine.artifact(self.algorithm)  # pin the v table in the LRU
        v_from = self.cluster.version
        mutate()
        before = self.engine.place_nodes_at(
            self.tracked, v_from, algorithm=self.algorithm
        )
        after = self.engine.place_nodes(self.tracked, algorithm=self.algorithm)
        rows = np.nonzero(before != after)[0]
        # vectorized dict build (the planner's moves_dict shape) -- no
        # per-row numpy scalar indexing.
        moved_ids = self.tracked[rows].tolist()
        moves = dict(
            zip(moved_ids, zip(before[rows].tolist(), after[rows].tolist()))
        )
        self._owners = after
        return MovePlan(moves)

    def add_node(self, node_id: int, capacity: float) -> MovePlan:
        """Grow the cluster; move only data captured by the new segments."""
        self._check_no_live()
        if self.algorithm != "asura":
            return self._baseline_event(
                lambda: self.cluster.add_node(node_id, capacity)
            )
        return self._apply(*self._add_plan(node_id, capacity))

    def remove_node(self, node_id: int) -> MovePlan:
        """Shrink the cluster; move exactly the data the victim held."""
        self._check_no_live()
        if self.algorithm != "asura":
            return self._baseline_event(lambda: self.cluster.remove_node(node_id))
        return self._apply(*self._remove_plan(node_id))

    # -- live (throttled, dual-version-served) events -------------------------

    def _require_asura_live(self) -> None:
        if self.algorithm != "asura":
            raise ValueError(
                "live (dual-version-served) migrations ride on ASURA's "
                f"table artifacts; this coordinator tracks {self.algorithm!r}"
                " -- use add_node/remove_node for the instantaneous plan"
            )

    def _check_no_live(self) -> None:
        """Dual-version read rules of OVERLAPPING migrations do not compose
        (a second plan's src comes from the eagerly-advanced owner table,
        not from where pending data physically sits) -- one drain at a
        time, like the checkpoint store."""
        live = self._live_migration
        if live is not None and not (live.done or live.aborted):
            raise RuntimeError(
                "a live migration is already in flight; drain or roll it "
                "back before the next membership event"
            )

    def _live(
        self, plan: MigrationPlan, rows: np.ndarray, egress, ingress, clock,
        round_seconds: float,
    ) -> LiveMigration:
        self._apply(plan, rows)  # owner table tracks the post-drain state
        migration = LiveMigration.from_plan(
            self.engine,
            plan,
            egress=egress,
            ingress=ingress,
            clock=clock,
            round_seconds=round_seconds,
        )
        # remembered so rollback_live can revert the owner table rows
        migration.tracked_rows = rows[plan.index]
        if self.n_replicas > 1:
            migration.replica_revert = self._last_revert
        self._live_migration = migration
        return migration

    def add_node_live(
        self,
        node_id: int,
        capacity: float,
        *,
        egress=None,
        ingress=None,
        clock=None,
        round_seconds: float = 1.0,
    ) -> LiveMigration:
        """Grow the cluster as a LIVE migration: the same minimal plan as
        ``add_node``, drained under bandwidth budgets while reads are
        served through the dual-version rule (route via the returned
        migration until it is ``done``)."""
        self._require_asura_live()
        self._check_no_live()
        plan, rows = self._add_plan(node_id, capacity)
        migration = self._live(plan, rows, egress, ingress, clock, round_seconds)
        migration.membership_event = ("add", node_id)
        return migration

    def remove_node_live(
        self,
        node_id: int,
        *,
        egress=None,
        ingress=None,
        clock=None,
        round_seconds: float = 1.0,
    ) -> LiveMigration:
        """Shrink the cluster as a live migration (planned drain / scale-in;
        for a crashed node the drain degenerates to repair traffic -- the
        source copies are gone, but the (src, dst) matrix still bounds the
        per-node repair ingress)."""
        self._require_asura_live()
        self._check_no_live()
        plan, rows = self._remove_plan(node_id)
        migration = self._live(plan, rows, egress, ingress, clock, round_seconds)
        migration.membership_event = ("remove", node_id)
        return migration

    def rollback_live(self, migration: LiveMigration) -> LiveMigration:
        """Roll back one of THIS coordinator's live ADD migrations.

        Beyond ``LiveMigration.rollback``: the owner-table rows the forward
        migration eagerly advanced to v+1 are reverted to their v owners
        (landed rows return via the reverse drain; unlanded rows never
        left), and the membership change itself is reverted NOW -- removing
        the just-added node frees exactly the segments it was assigned, so
        the current table places bit-identically to v and every
        non-migrating consumer immediately plans/routes against the truth.
        The reverse drain keeps routing through the v/v+1 artifacts in the
        LRU regardless.

        Rolling back a REMOVAL is not an inverse operation but a fresh
        scale-out (re-adding the node may be assigned different free
        segments): use ``add_node``/``add_node_live`` instead.
        """
        # Fail BEFORE mutating: stale references (an earlier, already-drained
        # migration) or foreign migrations must not touch cluster state.
        if migration is not self._live_migration or migration.done:
            raise ValueError(
                "can only roll back this coordinator's in-flight migration"
            )
        migration._check_live()
        event = getattr(migration, "membership_event", (None,))
        if event[0] != "add":
            raise ValueError(
                "only add-node migrations roll back exactly; undo a removal "
                "by re-adding the node (a regular add event)"
            )
        if self.n_replicas > 1:
            # whole pre-event sets were remembered (slot patches cannot
            # reconstruct them: common nodes may have permuted positions)
            revert_rows, before_sets = migration.replica_revert
            self._owners[revert_rows] = before_sets
        else:
            self._owners[migration.tracked_rows] = migration.state.plan.src
        self._an = None
        self.cluster.remove_node(event[1])
        migration._coordinator_rollback = True  # bare rollback() is refused
        reverse = migration.rollback()
        self._live_migration = reverse  # the drain in flight is now the reverse
        return reverse

    def remove_numbers_batch(self, datum_ids, n_replicas: int) -> np.ndarray:
        """Vectorized section 2.D REMOVE NUMBERS -> (batch, R) sorted segs.

        One replica-placement sweep on the engine path (cached artifact,
        device backends stay on device) instead of the historical per-id
        scalar trace."""
        return self.engine.remove_numbers_batch(datum_ids, n_replicas)

    def remove_numbers_for(self, datum_id: int, n_replicas: int) -> list[int]:
        return [int(x) for x in self.remove_numbers_batch([datum_id], n_replicas)[0]]

    def owners(self) -> np.ndarray:
        """The tracked owner table: (n,) node ids, or (n, R) replica sets
        when the coordinator tracks replicas."""
        return self._owners.copy()
