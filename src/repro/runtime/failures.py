"""Heartbeat-based failure detection (simulated clock).

A node that misses ``timeout`` of heartbeats is declared dead; the caller
(launcher / coordinator) then drives the recovery path:
ElasticCoordinator.remove_node -> checkpoint restore -> resume.  The clock is
injected so tests are deterministic.

``MigrationDriver`` is the live-migration wiring (DESIGN.md sections 8,
10): a detected failure starts a throttled repair ``LiveMigration``
instead of an instantaneous table swap, and the same injected clock that
declared the node dead paces the repair rounds -- repair bandwidth is the
scarce resource (arXiv:1701.00335), so recovery traffic is budgeted
exactly like planned scale events.  With a replica-tracking coordinator
(``ElasticCoordinator(n_replicas=R)``) the repair is a REPLICA repair:
exactly the victim's replica mass re-replicates, per slot, instead of
whole-datum re-replication -- the surviving R-1 copies keep serving
throughout.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.migrate import DrainDriver


@dataclasses.dataclass
class HeartbeatTracker:
    timeout: float
    clock: Callable[[], float]
    last_seen: dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, node_id: int) -> None:
        self.last_seen[node_id] = self.clock()

    def dead_nodes(self) -> list[int]:
        now = self.clock()
        return [n for n, t in self.last_seen.items() if now - t > self.timeout]


class FailureDetector:
    """Drives detection -> removal -> repair for a checkpoint store or an
    elastic coordinator."""

    def __init__(self, tracker: HeartbeatTracker, on_failure: Callable[[int], None]):
        self.tracker = tracker
        self.on_failure = on_failure
        self.handled: set[int] = set()

    def poll(self) -> list[int]:
        newly_dead = [n for n in self.tracker.dead_nodes() if n not in self.handled]
        for node in newly_dead:
            self.handled.add(node)
            self.on_failure(node)
        return newly_dead

    def clear(self, node_id: int) -> None:
        """Forget a handled node (it recovered / was repaired in place), so
        a LATER failure of the same node is detected and handled again."""
        self.handled.discard(node_id)


class MigrationDriver(DrainDriver):
    """Failure -> throttled repair migration (no instantaneous swap).

    ``start_repair(node_id)`` must produce a ``LiveMigration`` (typically
    ``ElasticCoordinator.remove_node_live`` with the same injected clock;
    on a replica-tracking coordinator that is a per-slot REPLICA repair --
    only the victim's replica mass moves).  ``poll()`` detects deaths and
    queues their repairs; ``pump()`` advances the in-flight repair by the
    rounds its clock says are due and retires it when drained, and
    ``round()``/``run()`` (the shared ``DrainDriver`` loop) drive the
    queue clocklessly -- ``run()`` drains every queued repair.  Repairs
    run ONE AT A TIME in death order -- the dual-version read rules of
    overlapping migrations do not compose (a second plan would source ids
    from mid-flight locations), and the coordinator enforces the same
    single-drain rule.  While a repair is in flight, readers route through
    its rule (``active`` exposes it).
    """

    def __init__(self, tracker: HeartbeatTracker, start_repair: Callable[[int], "object"]):
        self.start_repair = start_repair
        self.queued: list[int] = []  # victims awaiting their repair window
        self.active: list = []  # at most one in-flight repair
        self.completed: list = []
        self._detector = FailureDetector(tracker, self._on_failure)

    def _on_failure(self, node_id: int) -> None:
        self.queued.append(node_id)
        self._start_next()

    def _start_next(self) -> None:
        if not self.active and self.queued:
            self.active.append(self.start_repair(self.queued.pop(0)))

    def poll(self) -> list[int]:
        """Detect new deaths; queue one repair migration per victim."""
        return self._detector.poll()

    def notify_recovered(self, node_id: int) -> None:
        """A repaired-in-place node is healthy again: re-arm detection so
        its NEXT failure queues a fresh repair (long-lived simulations and
        real clusters both re-fail nodes)."""
        self._detector.clear(node_id)

    @property
    def done(self) -> bool:
        return not self.active and not self.queued

    def _pending_desc(self) -> str:
        return f"{len(self.active)} active + {len(self.queued)} queued repairs"

    def _retire(self) -> None:
        for migration in list(self.active):
            if migration.done:
                self.active.remove(migration)
                self.completed.append(migration)
        self._start_next()

    def _round(self) -> dict[tuple[int, int], int]:
        """One clockless round of the in-flight repair (starting the next
        queued one if needed); an idle driver's round is an empty matrix,
        like the mover's."""
        self._start_next()
        if not self.active:
            return {}
        matrix = self.active[0].round()
        self._retire()
        return matrix

    def _pump_rounds(self) -> list[dict[tuple[int, int], int]]:
        """Advance the in-flight repair; returns the rounds' matrices."""
        matrices: list[dict[tuple[int, int], int]] = []
        for migration in list(self.active):
            matrices.extend(migration.pump())
        self._retire()
        return matrices
