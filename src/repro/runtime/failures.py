"""Heartbeat-based failure detection (simulated clock).

A node that misses ``timeout`` of heartbeats is declared dead; the caller
(launcher / coordinator) then drives the recovery path:
ElasticCoordinator.remove_node -> checkpoint restore -> resume.  The clock is
injected so tests are deterministic.
"""

from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass
class HeartbeatTracker:
    timeout: float
    clock: Callable[[], float]
    last_seen: dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, node_id: int) -> None:
        self.last_seen[node_id] = self.clock()

    def dead_nodes(self) -> list[int]:
        now = self.clock()
        return [n for n, t in self.last_seen.items() if now - t > self.timeout]


class FailureDetector:
    """Drives detection -> removal -> repair for a checkpoint store or an
    elastic coordinator."""

    def __init__(self, tracker: HeartbeatTracker, on_failure: Callable[[int], None]):
        self.tracker = tracker
        self.on_failure = on_failure
        self.handled: set[int] = set()

    def poll(self) -> list[int]:
        newly_dead = [n for n in self.tracker.dead_nodes() if n not in self.handled]
        for node in newly_dead:
            self.handled.add(node)
            self.on_failure(node)
        return newly_dead
