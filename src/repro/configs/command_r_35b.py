"""command-r-35b [dense GQA, no-bias] — hf:CohereForAI/c4ai-command-r-v01."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="lm",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    head_dim=128,
    attn_kind="full",
    norm="layernorm",
    act="swiglu",
    rope_theta=8_000_000.0,
    tie_embeddings=True,
)


def get_config() -> ModelConfig:
    return CONFIG
