"""recurrentgemma-9b [hybrid RG-LRU + local attention, 2:1] — arXiv:2402.19427.

Block pattern (rec, rec, attn) repeating; 38 layers = 12 super-blocks + 2
trailing recurrent layers.  Local attention window 2048, MQA (kv=1).
Constant-size recurrent state + windowed cache -> long_500k runs.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="rglru",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    attn_kind="local",
    window=2048,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    norm="rmsnorm",
    act="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    subquadratic=True,
)


def get_config() -> ModelConfig:
    return CONFIG
