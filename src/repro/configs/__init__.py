"""Assigned architecture registry: ``get_config("<arch-id>")``."""

from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeSpec, shape_applicable

ARCHS: tuple[str, ...] = (
    "granite-3-2b",
    "command-r-35b",
    "deepseek-7b",
    "smollm-135m",
    "whisper-large-v3",
    "deepseek-v2-236b",
    "mixtral-8x22b",
    "internvl2-26b",
    "recurrentgemma-9b",
    "rwkv6-3b",
)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    module = importlib.import_module(
        f"repro.configs.{name.replace('-', '_').replace('.', '_')}"
    )
    return module.get_config()


def all_cells():
    """Every (arch, shape) pair with its applicability verdict."""
    for arch in ARCHS:
        cfg = get_config(arch)
        for spec in SHAPES.values():
            ok, reason = shape_applicable(cfg, spec)
            yield arch, spec, ok, reason


__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "get_config", "all_cells", "shape_applicable"]
