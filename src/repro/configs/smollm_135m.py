"""smollm-135m [dense, llama-arch small] — hf:HuggingFaceTB/SmolLM-135M."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="lm",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    head_dim=64,
    attn_kind="full",
    norm="rmsnorm",
    act="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def get_config() -> ModelConfig:
    return CONFIG
