"""whisper-large-v3 [audio enc-dec backbone] — arXiv:2212.04356.

The conv/audio frontend is a STUB: ``input_specs()`` supplies precomputed
1280-d frame embeddings (1500 frames) to the encoder (DESIGN.md section 4).
Assigned sequence shapes apply to the decoder.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,  # decoder layers
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,  # MHA
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    attn_kind="full",
    norm="layernorm",
    act="gelu",
    enc_seq=1500,
)


def get_config() -> ModelConfig:
    return CONFIG
