"""rwkv6-3b [Finch: attention-free, data-dependent decay] — arXiv:2404.05892.

Constant-size WKV matrix state -> long_500k runs.  Head dim 64 (40 heads).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="rwkv6",
    n_layers=32,
    d_model=2560,
    n_heads=40,  # d_model / rwkv_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    head_dim=64,
    rwkv_head_dim=64,
    norm="layernorm",
    act="swiglu",
    subquadratic=True,
)


def get_config() -> ModelConfig:
    return CONFIG
