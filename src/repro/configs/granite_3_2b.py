"""granite-3-2b [dense GQA] — hf:ibm-granite/granite-3.0-2b-base."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="lm",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=49155,
    head_dim=64,
    attn_kind="full",
    norm="rmsnorm",
    act="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def get_config() -> ModelConfig:
    return CONFIG
