"""deepseek-v2-236b [MLA + MoE 160e top-6 + 2 shared] — arXiv:2405.04434.

MLA: kv_lora=512, q_lora=1536, qk_nope=128, qk_rope=64, v_head=128.
Layer 0 is a dense FFN (d_ff=12288); layers 1..59 are MoE with expert
d_ff=1536, 2 shared experts, top-6 routing of 160 experts.
"""

from repro.models.config import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="lm",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,  # the dense layer's FFN
    vocab=102400,
    head_dim=192,  # qk_nope + qk_rope (for bookkeeping; MLA dims rule)
    attn_kind="full",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        d_ff_expert=1536,
        n_shared=2,
        d_ff_shared=1536,
        capacity_factor=1.25,
    ),
    n_dense_layers=1,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=10_000.0,
)


def get_config() -> ModelConfig:
    return CONFIG
