"""internvl2-26b [VLM: InternViT stub + InternLM2-20b backbone] — arXiv:2404.16821.

The vision tower is a STUB: ``input_specs()`` supplies 256 precomputed patch
embeddings (already projected to d_model) prepended to the text sequence
(DESIGN.md section 4).  Assigned sequence shapes apply to the text tokens.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="lm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    head_dim=128,
    attn_kind="full",
    vision_prefix=256,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1_000_000.0,
)


def get_config() -> ModelConfig:
    return CONFIG
