"""deepseek-7b [dense, llama-arch, MHA] — arXiv:2401.02954."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="lm",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,  # GQA kv=32 == MHA
    d_ff=11008,
    vocab=102400,
    head_dim=128,
    attn_kind="full",
    norm="rmsnorm",
    act="swiglu",
    rope_theta=10_000.0,
)


def get_config() -> ModelConfig:
    return CONFIG
