"""mixtral-8x22b [MoE 8e top-2, SWA] — arXiv:2401.04088.

Sliding-window attention (window 4096) bounds decode cache and attention
compute, so the long_500k cell runs with a window-clamped ring cache.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="lm",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    head_dim=128,
    attn_kind="swa",
    window=4096,
    moe=MoEConfig(
        n_experts=8,
        top_k=2,
        d_ff_expert=16384,
        capacity_factor=1.25,
    ),
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1_000_000.0,
    subquadratic=True,  # SWA: cache and compute bounded by the window
)


def get_config() -> ModelConfig:
    return CONFIG
