"""The shared drain-driver loop (DESIGN.md sections 8.2, 10).

Every layer that advances a migration exposes the same three verbs --
``round()`` (one primitive round -> its movement matrix), ``pump()`` (the
rounds an injected clock says are due) and ``run(max_rounds)`` (drain to
completion, raising if the budget can never finish).  The loop used to be
copy-pasted across ``ThrottledMover``, ``LiveMigration``, ``StoreMigration``
and ``runtime.failures.MigrationDriver``; ``DrainDriver`` hosts it once.

Subclasses implement:

  * ``done``            -- is the drain complete?
  * ``_round()``        -- one primitive round -> its (src, dst) matrix,
  * ``_pump_rounds()``  -- the clock-paced batch of rounds (the default is
                          clockless: one round when not done; the mover
                          overrides it with the injected-clock pacing, and
                          wrappers delegate to the wrapped object so clock
                          accounting lives in exactly one place),
  * ``_advance(fn)``    -- optional wrapper applied uniformly around every
                          public verb (liveness guards, blob landing,
                          detach-on-done) so a hook can never be skipped by
                          calling one verb instead of another.
"""

from __future__ import annotations


class DrainDriver:
    """Mixin: the round()/pump()/run() drain loop over one primitive."""

    @property
    def done(self) -> bool:
        raise NotImplementedError

    def _round(self) -> dict:
        raise NotImplementedError

    def _advance(self, fn):
        return fn()

    def _pump_rounds(self) -> list:
        return [] if self.done else [self._round()]

    def _pending_desc(self) -> str:
        return "work still pending"

    def round(self) -> dict:
        """One round; returns its per-(src, dst) movement matrix."""
        [matrix] = self._advance(lambda: [self._round()])
        return matrix

    def pump(self) -> list:
        """Run the rounds the injected clock says are due (0 if none)."""
        return self._advance(self._pump_rounds)

    def run(self, max_rounds: int = 100_000) -> list:
        """Drain to completion; returns the per-round matrices."""

        def drain():
            out = []
            for _ in range(max_rounds):
                if self.done:
                    break
                out.append(self._round())
            if not self.done:
                raise RuntimeError(
                    f"drain did not complete within {max_rounds} rounds "
                    f"({self._pending_desc()}) -- zero budget?"
                )
            return out

        return self._advance(drain)
