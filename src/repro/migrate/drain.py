"""The shared drain-driver loop (DESIGN.md sections 8.2, 10).

Every layer that advances a migration exposes the same three verbs --
``round()`` (one primitive round -> its movement matrix), ``pump()`` (the
rounds an injected clock says are due) and ``run(max_rounds)`` (drain to
completion, raising if the budget can never finish).  The loop used to be
copy-pasted across ``ThrottledMover``, ``LiveMigration``, ``StoreMigration``
and ``runtime.failures.MigrationDriver``; ``DrainDriver`` hosts it once.

Subclasses implement:

  * ``done``            -- is the drain complete?
  * ``_round()``        -- one primitive round -> its (src, dst) matrix,
  * ``_pump_rounds()``  -- the clock-paced batch of rounds (the default is
                          clockless: one round when not done; the mover
                          overrides it with the injected-clock pacing, and
                          wrappers delegate to the wrapped object so clock
                          accounting lives in exactly one place),
  * ``_advance(fn)``    -- optional wrapper applied uniformly around every
                          public verb (liveness guards, blob landing,
                          detach-on-done) so a hook can never be skipped by
                          calling one verb instead of another.

Observability rides the same chokepoint: a driver that carries a
``ledger`` attribute (an ``obs.TraceLedger``) gets one structured
``migrate.round`` event per completed round -- round index, per-(src,
dst) pair count, rows moved, and bytes when a ``bytes_per_row`` attribute
is set -- emitted from the public verbs only, so wrappers that delegate
``_pump_rounds`` to an inner driver never double-count.  The round dicts
the verbs RETURN are unchanged (field-compatible with every PR-3..5
consumer); the events replace nothing, they annotate.
"""

from __future__ import annotations


class DrainDriver:
    """Mixin: the round()/pump()/run() drain loop over one primitive."""

    @property
    def done(self) -> bool:
        raise NotImplementedError

    def _round(self) -> dict:
        raise NotImplementedError

    def _advance(self, fn):
        return fn()

    def _pump_rounds(self) -> list:
        return [] if self.done else [self._round()]

    def _pending_desc(self) -> str:
        return "work still pending"

    def _emit_rounds(self, matrices: list) -> list:
        """Ledger/metrics hook: one ``migrate.round`` event per matrix."""
        ledger = getattr(self, "ledger", None)
        if ledger is None or not matrices:
            return matrices
        bytes_per_row = int(getattr(self, "bytes_per_row", 0) or 0)
        metrics = getattr(self, "metrics", None)
        for matrix in matrices:
            moves = sum(matrix.values())
            fields = {
                "round": ledger.incr("migrate.rounds"),
                "moves": moves,
                "pairs": len(matrix),
            }
            ledger.incr("migrate.rows_moved", moves)
            if bytes_per_row:
                fields["bytes"] = moves * bytes_per_row
                ledger.incr("migrate.bytes_moved", moves * bytes_per_row)
                if metrics is not None:
                    metrics.inc_host(
                        "migrate.bytes_moved", moves * bytes_per_row
                    )
            ledger.event("migrate.round", type(self).__name__, **fields)
        return matrices

    def round(self) -> dict:
        """One round; returns its per-(src, dst) movement matrix."""
        [matrix] = self._emit_rounds(self._advance(lambda: [self._round()]))
        return matrix

    def pump(self) -> list:
        """Run the rounds the injected clock says are due (0 if none)."""
        return self._emit_rounds(self._advance(self._pump_rounds))

    def run(self, max_rounds: int = 100_000) -> list:
        """Drain to completion; returns the per-round matrices."""

        def drain():
            out = []
            for _ in range(max_rounds):
                if self.done:
                    break
                out.append(self._round())
            if not self.done:
                raise RuntimeError(
                    f"drain did not complete within {max_rounds} rounds "
                    f"({self._pending_desc()}) -- zero budget?"
                )
            return out

        return self._emit_rounds(self._advance(drain))
