"""Device-resident migration subsystem (DESIGN.md section 8).

Three layers over one membership change v -> v+1:

  1. ``MigrationPlanner``  -- streaming version-diff planner: places every
     id under both cached table versions in one device pass (fused
     dual-table kernel, ADDITION-NUMBER prefilter for add-node events) and
     emits the minimal ``MigrationPlan``.
  2. ``ThrottledMover``    -- drains the plan in rounds under per-node
     ingress/egress budgets (simulated clock), maintaining the landed
     bitmap in ``MigrationState`` and per-round movement matrices.
  3. ``LiveMigration``     -- dual-version serving: routes every read to
     the node that actually holds the datum mid-drain (v owner while the
     move is pending, v+1 owner after it lands), host and device paths,
     with free rollback of half-landed migrations.

The unit of work is a replica SLOT (DESIGN.md section 10): plan rows are
``(id, replica_slot, src, dst)``, the landed bitmap is per slot, and
``LiveMigration.route_replicas[_device]`` serves mixed-version replica
sets -- each slot independently v or v+1 by its own landed bit --
reproducing the paper's minimal data movement *even if data are
replicated* (characteristic 1).  Single-owner migration is the R=1 case.
The round/pump/run drain loop all four driver layers share lives in
``drain.DrainDriver``.

Consumers: ``runtime.elastic`` (live add/remove, R-way owner tracking),
``runtime.failures`` (failure -> throttled replica repair), ``serve.router``
(serve through a scale event, replica fan-out included),
``checkpoint.sharded`` (read-through per-slot blob migration and live
node repair).
"""

from .drain import DrainDriver
from .live import LiveMigration
from .mover import MigrationState, ThrottledMover
from .planner import DEFAULT_CHUNK, MigrationPlan, MigrationPlanner

__all__ = [
    "DEFAULT_CHUNK",
    "DrainDriver",
    "LiveMigration",
    "MigrationPlan",
    "MigrationPlanner",
    "MigrationState",
    "ThrottledMover",
]
