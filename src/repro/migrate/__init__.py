"""Device-resident migration subsystem (DESIGN.md section 8).

Three layers over one membership change v -> v+1:

  1. ``MigrationPlanner``  -- streaming version-diff planner: places every
     id under both cached table versions in one device pass (fused
     dual-table kernel, ADDITION-NUMBER prefilter for add-node events) and
     emits the minimal ``MigrationPlan``.
  2. ``ThrottledMover``    -- drains the plan in rounds under per-node
     ingress/egress budgets (simulated clock), maintaining the landed
     bitmap in ``MigrationState`` and per-round movement matrices.
  3. ``LiveMigration``     -- dual-version serving: routes every read to
     the node that actually holds the datum mid-drain (v owner while the
     move is pending, v+1 owner after it lands), host and device paths,
     with free rollback of half-landed migrations.

Consumers: ``runtime.elastic`` (live add/remove), ``runtime.failures``
(failure -> throttled repair), ``serve.router`` (serve through a scale
event), ``checkpoint.sharded`` (read-through blob migration).
"""

from .live import LiveMigration
from .mover import MigrationState, ThrottledMover
from .planner import DEFAULT_CHUNK, MigrationPlan, MigrationPlanner

__all__ = [
    "DEFAULT_CHUNK",
    "LiveMigration",
    "MigrationPlan",
    "MigrationPlanner",
    "MigrationState",
    "ThrottledMover",
]
