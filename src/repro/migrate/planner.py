"""Migration layer 1: the streaming version-diff planner.

A membership change turns cluster version v into v+1.  The planner answers
"which data must move, from where, to where" by placing every tracked id
under BOTH table versions (both artifacts coexist in the engine's LRU --
DESIGN.md section 6) and diffing the owners:

  * ``diff_device``   -- one chunk: (moved, src, dst) DEVICE arrays, zero
                         host syncs (the fused dual-table kernel,
                         ``kernels.ops.diff_nodes_on_tables_device``).
  * ``plan_stream``   -- the streaming sweep: iterate id chunks through
                         ``diff_device`` so tens of millions of ids are
                         diffed in fixed device memory.  Yields device
                          4-tuples and never touches the host (tested under
                         a transfer guard).
  * ``plan``          -- host-facing assembly into a ``MigrationPlan``
                         (the moved rows only).  For the common add-node
                         case, pass ``max_new_seg`` to enable the
                         device-side ADDITION-NUMBER prefilter (section
                         2.D): a cheap metadata sweep marks the candidate
                         set and only candidates pay the full dual diff.

The unit of work generalizes from a node to an R-way REPLICA SET
(DESIGN.md section 10): ``diff_replicas_device`` / ``plan_replicas_stream``
/ ``plan_replicas`` are the per-slot twins -- each id's full replica set is
placed under both versions in one pass and aligned slot by slot, so only
replicas whose owner actually changed produce a row (the paper's
section-5 minimal replica movement, even under replication).

ASURA's optimality theorems make the diff minimal by construction; the
oracle tests re-verify against brute force (tests/test_migrate.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

DEFAULT_CHUNK = 1 << 20  # ids per streaming chunk (fixed device memory)

_MASK_CACHE: dict = {}


def pad_pow2(chunk, multiple: int = 1):
    """(padded, n_valid): zero-pad a chunk into its pow2 bucket (and up to
    a device multiple for mesh sweeps), so ragged tails share one compile
    per bucket.  Full pow2 chunks pass through untouched (``padded is
    chunk`` -- the zero-sync fast path); device-array tails pad ON DEVICE
    (``kernels.ops._pad_ids``).  Shared by the streaming planner and the
    serving driver's external-batch path (DESIGN.md sections 11-12)."""
    n = int(chunk.shape[0])
    target = 1 << max(0, n - 1).bit_length()
    target += (-target) % max(1, multiple)
    if target == n:
        return chunk, n
    if isinstance(chunk, np.ndarray):
        return np.pad(chunk, (0, target - n)), n
    from repro.kernels.ops import _pad_ids

    return _pad_ids(chunk, target), n


def _mask_tail(moved, n_valid: int):
    """``moved`` with rows >= ``n_valid`` forced False, on device.

    ``n_valid`` is a TRACED argument, so every ragged tail that lands in
    the same pow2 bucket shares one compile -- the whole point of the
    bucketing (a static tail length would compile once per distinct
    raggedness, the bug this fixes)."""
    import jax
    import jax.numpy as jnp

    fn = _MASK_CACHE.get(moved.ndim)
    if fn is None:

        @jax.jit
        def fn(m, n):
            idx = jnp.arange(m.shape[0]).reshape((-1,) + (1,) * (m.ndim - 1))
            return m & (idx < n)

        _MASK_CACHE[moved.ndim] = fn
    return fn(moved, n_valid)


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    """The moved rows of a two-version placement diff.

    The unit of work is a REPLICA SLOT, not a node: row i says replica
    slot ``slot[i]`` of datum ``ids[i]`` must move from node ``src[i]``
    (where its bytes live under v) to node ``dst[i]`` (its v+1 owner);
    ``index[i]`` is the row's position in the scanned id array (so callers
    can update per-id side tables without a search).  Single-owner plans
    are the R=1 degenerate case (``slot``/``src_slot`` all zero; one row
    per moved id).  For replica plans, ``slot`` indexes the id's v+1
    replica set and ``src_slot`` the position of ``src`` in its v set --
    rollback swaps the two so the reverse plan's slots index the reverse
    destination set (DESIGN.md section 10).  Rows keep scan order (id
    major, slot minor).
    """

    v_from: int
    v_to: int
    ids: np.ndarray  # uint32, moved ids (one row per moved (id, slot))
    src: np.ndarray  # int64, vacated owner under v_from
    dst: np.ndarray  # int64, owner under v_to
    index: np.ndarray  # int64, positions in the scanned id array
    n_scanned: int
    n_replicas: int = 1
    slot: np.ndarray | None = None  # int32, position in the v_to replica set
    src_slot: np.ndarray | None = None  # int32, position of src in the v set

    def __post_init__(self):
        # Single-owner construction sites predate replica plans; normalize
        # so every consumer can rely on the per-slot arrays existing.
        if self.slot is None:
            object.__setattr__(
                self, "slot", np.zeros(len(self.ids), dtype=np.int32)
            )
        if self.src_slot is None:
            object.__setattr__(
                self, "src_slot", np.zeros(len(self.ids), dtype=np.int32)
            )

    @property
    def n_moves(self) -> int:
        return int(self.ids.shape[0])

    @property
    def moved_fraction(self) -> float:
        """Moved fraction of the scanned REPLICA mass (R * n_scanned)."""
        return self.n_moves / max(1, self.n_scanned * self.n_replicas)

    def moves_dict(self) -> dict[int, tuple[int, int]]:
        """datum id -> (src, dst), built from the vectorized arrays (no
        per-candidate Python compare loop).  For replica plans an id with
        several moved slots keeps its LAST row -- add/remove events move at
        most one slot per id, so the dict is total there; slot-accurate
        consumers read the arrays directly."""
        return dict(
            zip(
                self.ids.tolist(),
                zip(self.src.tolist(), self.dst.tolist()),
            )
        )


class MigrationPlanner:
    """Version-diff planner bound to one ``PlacementEngine``.

    Both versions' artifacts must be cached (place at v before mutating --
    every engine consumer already does) or ``engine.artifact_for`` raises.
    """

    def __init__(self, engine, *, ledger=None, metrics=None):
        self.engine = engine
        # observability (optional): spans around plan assembly plus the
        # ADDITION-NUMBER prefilter's scanned/kept counters (its hit rate
        # is the section-2.D fast path's effectiveness, DESIGN.md 13).
        self.ledger = ledger
        self.metrics = metrics
        # scan-fused multi-chunk diff jits, keyed (kind, statics[, R])
        self._fuse_fns: dict = {}

    def _note_prefilter(self, n_scanned: int, n_kept: int) -> None:
        if self.ledger is not None:
            self.ledger.incr("planner.prefilter_scanned", n_scanned)
            self.ledger.incr("planner.prefilter_kept", n_kept)
        if self.metrics is not None:
            self.metrics.inc_host("planner.prefilter_scanned", n_scanned)
            self.metrics.inc_host("planner.prefilter_kept", n_kept)

    def _note_plan(self, kind: str, plan, t0: float) -> None:
        if self.ledger is None:
            return
        import time

        self.ledger.event(
            "span", kind, dur_s=float(time.perf_counter() - t0),
            n_scanned=plan.n_scanned, n_moves=plan.n_moves,
            v_from=plan.v_from, v_to=plan.v_to,
        )

    def _sweep(self, mesh):
        """Resolve ``mesh=`` (a Mesh, a ``ShardedSweep``, or None) into a
        sweep bound to this planner's engine -- the multi-chip diff path
        (DESIGN.md section 11)."""
        if mesh is None:
            return None
        from repro.launch.placement_mesh import ShardedSweep

        if isinstance(mesh, ShardedSweep):
            return mesh
        return ShardedSweep(self.engine, mesh)

    # -- device streaming sweep ---------------------------------------------

    def diff_device(self, datum_ids, v_from: int, v_to: int):
        """One chunk -> (moved, src, dst) device arrays, zero host syncs."""
        return self.engine.diff_nodes_device(datum_ids, v_from, v_to)

    def diff_replicas_device(
        self, datum_ids, v_from: int, v_to: int, n_replicas: int
    ):
        """One chunk -> per-slot (moved, src, dst, src_slot) device arrays,
        each (chunk, R), zero host syncs (the fused dual-table replica
        kernel + on-device set alignment)."""
        return self.engine.diff_replicas_device(
            datum_ids, v_from, v_to, n_replicas
        )

    # -- scan-fused multi-chunk diff (DESIGN.md section 15) -------------------

    def _fuse_tables(self, v_from: int, v_to: int, replicas: bool):
        """(tables, statics) for the scan-fused diff body -- the same
        dual-version device artifacts ``diff_device`` resolves."""
        e = self.engine
        art_a = e._device_artifact_for(v_from, "asura")
        art_b = e._device_artifact_for(v_to, "asura")
        p = e.params
        statics = (art_a.top_level, art_b.top_level, p.s_log2, p.max_draws)
        if replicas:
            tables = (
                art_a.len32_dev, art_a.node_of_dev,
                art_b.len32_dev, art_b.node_of_dev,
            )
        else:
            tables = (
                art_a.len32_dev, art_a.cum_hi_dev, art_a.cum_lo_dev,
                art_a.node_of_dev,
                art_b.len32_dev, art_b.cum_hi_dev, art_b.cum_lo_dev,
                art_b.node_of_dev,
            )
        return tables, statics

    def _fuse_fn(self, statics: tuple, n_replicas: int | None):
        """Jitted ``lax.scan`` of the fused dual-table diff over a stacked
        (B, chunk) id block -- ONE dispatch per B chunks.  Cached per
        static routing configuration; block shape changes retrace inside
        jax's own cache (pow2 chunking bounds them at O(log chunk))."""
        key = ("rdiff", statics, n_replicas) if n_replicas else ("diff", statics)
        fn = self._fuse_fns.get(key)
        if fn is not None:
            return fn
        import jax
        import jax.numpy as jnp

        from repro.kernels.ops import _diff_fused_ref, _diff_replicas_fused_ref

        top_a, top_b, s_log2, max_draws = statics

        def body(tabs, ids):
            u = ids.astype(jnp.uint32)
            if n_replicas:
                out = _diff_replicas_fused_ref(
                    u, *tabs, top_a=top_a, top_b=top_b,
                    s_log2=s_log2, max_draws=max_draws, n_replicas=n_replicas,
                )
            else:
                out = _diff_fused_ref(
                    u, *tabs, top_a=top_a, top_b=top_b,
                    s_log2=s_log2, max_draws=max_draws,
                )
            return tabs, out

        @jax.jit
        def run(ids_blk, *tabs):
            _, outs = jax.lax.scan(body, tabs, ids_blk)
            return outs

        self._fuse_fns[key] = run
        return run

    def _fused_stream(
        self, id_chunks, v_from: int, v_to: int, fuse: int,
        n_replicas: int | None,
    ):
        """Shared fused-stream driver: group consecutive equal-pow2-length
        chunks into blocks of up to ``fuse``, diff each block in one
        scanned dispatch, and yield the SAME per-chunk tuples the
        unfused stream yields (pad lanes' ``moved`` masked False)."""
        import jax.numpy as jnp

        tables, statics = self._fuse_tables(v_from, v_to, bool(n_replicas))
        run = self._fuse_fn(statics, n_replicas)

        def flush(buf):
            if not buf:
                return
            stack = (
                np.stack([p for p, _, _ in buf])
                if all(isinstance(p, np.ndarray) for p, _, _ in buf)
                else jnp.stack([jnp.asarray(p) for p, _, _ in buf])
            )
            outs = run(stack, *tables)
            for i, (padded, n_valid, was_padded) in enumerate(buf):
                moved = outs[0][i]
                if was_padded:
                    moved = _mask_tail(moved, n_valid)
                yield (padded, moved, *(o[i] for o in outs[1:]))

        buf: list = []
        for chunk in id_chunks:
            padded, n_valid = self._pad_pow2(chunk, 1)
            if buf and (
                buf[0][0].shape[0] != padded.shape[0] or len(buf) >= fuse
            ):
                yield from flush(buf)
                buf = []
            buf.append((padded, n_valid, padded is not chunk))
        yield from flush(buf)

    def plan_stream(
        self, id_chunks, v_from: int, v_to: int, *, mesh=None, fuse: int = 1
    ):
        """Streaming sweep: yield ``(ids, moved, src, dst)`` per chunk.

        ``id_chunks`` is any iterable of id arrays (device arrays keep the
        whole sweep sync-free; NumPy chunks pay one upload each -- the
        host-feeding pattern).  Device memory is bounded by the largest
        chunk, not the id population.

        A ragged final chunk is padded into its pow2 bucket (the same
        buckets the prefilter path uses) so the jitted diff sees O(log
        chunk) distinct shapes instead of one extra compile per sweep; the
        yielded arrays are bucket-length with the pad lanes' ``moved``
        forced False on device, so counts and selections over the stream
        see no phantom moves.  Full chunks take the unpadded zero-sync path
        untouched.

        ``mesh=`` (a Mesh or a ``ShardedSweep``) runs each chunk's diff
        across the mesh's data axis instead of one device -- same yielded
        contract, bit-identical outputs, host-fed chunks (DESIGN.md
        section 11).

        ``fuse=`` > 1 groups consecutive equal-pow2-length chunks into
        blocks of up to ``fuse`` and diffs each block with ONE scanned
        dispatch (DESIGN.md section 15) -- same yielded per-chunk
        contract, bit-identical outputs, ~fuse-fold fewer dispatches.
        Single-device flat-ASURA only (mesh and hierarchical sweeps stay
        per-chunk).
        """
        sweep = self._sweep(mesh)
        if (
            int(fuse) > 1
            and sweep is None
            and not getattr(self.engine, "hierarchical", False)
        ):
            yield from self._fused_stream(
                id_chunks, v_from, v_to, int(fuse), None
            )
            return
        mult = 1 if sweep is None else sweep.n_devices
        for chunk in id_chunks:
            padded, n_valid = self._pad_pow2(chunk, mult)
            if sweep is None:
                moved, src, dst = self.diff_device(padded, v_from, v_to)
            else:
                moved, src, dst = sweep.diff_nodes_device(padded, v_from, v_to)
            if padded is not chunk:
                moved = _mask_tail(moved, n_valid)
            yield padded, moved, src, dst

    def plan_replicas_stream(
        self, id_chunks, v_from: int, v_to: int, n_replicas: int, *,
        mesh=None, fuse: int = 1,
    ):
        """Replica streaming sweep: yield ``(ids, moved, src, dst,
        src_slot)`` device tuples per chunk -- the R-way twin of
        ``plan_stream``, same fixed device memory, zero host syncs, pow2
        tail bucketing (pad rows' ``moved`` all False), optional ``mesh=``
        scale-out and optional ``fuse=`` scan-fused multi-chunk blocks."""
        sweep = self._sweep(mesh)
        if (
            int(fuse) > 1
            and sweep is None
            and not getattr(self.engine, "hierarchical", False)
        ):
            yield from self._fused_stream(
                id_chunks, v_from, v_to, int(fuse), int(n_replicas)
            )
            return
        mult = 1 if sweep is None else sweep.n_devices
        for chunk in id_chunks:
            padded, n_valid = self._pad_pow2(chunk, mult)
            if sweep is None:
                moved, src, dst, src_slot = self.diff_replicas_device(
                    padded, v_from, v_to, n_replicas
                )
            else:
                moved, src, dst, src_slot = sweep.diff_replicas_device(
                    padded, v_from, v_to, n_replicas
                )
            if padded is not chunk:
                moved = _mask_tail(moved, n_valid)
            yield padded, moved, src, dst, src_slot

    @staticmethod
    def chunked(ids: np.ndarray, chunk: int = DEFAULT_CHUNK):
        """Host-side chunking helper for ``plan_stream``."""
        for start in range(0, len(ids), chunk):
            yield ids[start : start + chunk]

    # kept as a staticmethod alias so planner call sites and tests read the
    # same way they always did; the shared implementation is module-level.
    _pad_pow2 = staticmethod(pad_pow2)

    # -- host-facing plan assembly ------------------------------------------

    def plan(
        self,
        datum_ids,
        v_from: int,
        v_to: int,
        *,
        chunk: int = DEFAULT_CHUNK,
        max_new_seg: int | None = None,
        known_src=None,
        mesh=None,
    ) -> MigrationPlan:
        """Assemble the full ``MigrationPlan`` for a tracked id set.

        ``max_new_seg`` (the largest segment number the v -> v+1 change
        assigned; add-node events know it) enables the ADDITION-NUMBER
        prefilter: a device metadata sweep computes each id's AN against
        the v table and only ids with AN <= max_new_seg (or AN unknown,
        the sound fallback) pay the full dual-version diff -- the paper's
        section 2.D fast path for the common scale-out event.

        ``known_src`` (aligned with ``datum_ids``) supplies the v owners a
        caller already maintains (``ElasticCoordinator``'s owner table), so
        the host path places each id once, not twice.

        On the numpy backend the diff runs on the vectorized host path
        (same bit-identical placements, no jit warm-up) -- the engine's
        usual backend contract.

        ``mesh=`` (a Mesh or ``ShardedSweep``) runs every chunk's dual
        diff across the mesh's data axis -- the assembled plan is
        bit-identical (DESIGN.md section 11); it forces the device path
        regardless of backend.
        """
        import time

        t0 = time.perf_counter()
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        sweep = self._sweep(mesh)
        host = self.engine.backend == "numpy" and sweep is None
        if known_src is not None:
            known_src = np.asarray(known_src, dtype=np.int64)
        out_ids: list[np.ndarray] = []
        out_src: list[np.ndarray] = []
        out_dst: list[np.ndarray] = []
        out_idx: list[np.ndarray] = []
        for start in range(0, len(ids), chunk):
            c = ids[start : start + chunk]
            base = np.arange(start, start + len(c), dtype=np.int64)
            if max_new_seg is not None:
                keep = self._candidates(c, v_from, max_new_seg, host)
                self._note_prefilter(len(keep), int(keep.sum()))
                c, base = c[keep], base[keep]
            if c.size == 0:
                continue
            if host:
                src = (
                    known_src[base]
                    if known_src is not None
                    else self.engine.place_nodes_at(c, v_from)
                )
                dst = self.engine.place_nodes_at(c, v_to)
                moved = src != dst
            else:
                # Pad ragged (prefiltered) chunks to the next power of two
                # so the jitted diff sees O(log chunk) distinct shapes, not
                # one compile per candidate count.
                n_c = len(c)
                cp, _ = self._pad_pow2(
                    c, 1 if sweep is None else sweep.n_devices
                )
                if sweep is None:
                    moved_d, src_d, dst_d = self.diff_device(cp, v_from, v_to)
                else:
                    moved_d, src_d, dst_d = sweep.diff_nodes_device(
                        cp, v_from, v_to
                    )
                moved = np.asarray(moved_d)[:n_c]
                src = np.asarray(src_d)[:n_c].astype(np.int64)
                dst = np.asarray(dst_d)[:n_c].astype(np.int64)
            out_ids.append(c[moved])
            out_src.append(src[moved])
            out_dst.append(dst[moved])
            out_idx.append(base[moved])
        cat = lambda parts, dtype: (  # noqa: E731
            np.concatenate(parts) if parts else np.zeros(0, dtype=dtype)
        )
        plan = MigrationPlan(
            v_from=v_from,
            v_to=v_to,
            ids=cat(out_ids, np.uint32),
            src=cat(out_src, np.int64),
            dst=cat(out_dst, np.int64),
            index=cat(out_idx, np.int64),
            n_scanned=len(ids),
        )
        self._note_plan("planner.plan", plan, t0)
        return plan

    def plan_replicas(
        self,
        datum_ids,
        v_from: int,
        v_to: int,
        n_replicas: int,
        *,
        chunk: int = DEFAULT_CHUNK,
        max_new_seg: int | None = None,
        known_before=None,
        mesh=None,
    ) -> MigrationPlan:
        """Assemble the per-slot REPLICA ``MigrationPlan`` for an id set.

        The R-way generalization of ``plan``: every id's full R-replica set
        is placed under both cached versions (the fused dual-table replica
        kernel on device backends; the vectorized host path on numpy) and
        the two sets are aligned per slot, so a row exists exactly for the
        replicas whose owner actually changed -- ``|after \\ before|`` rows
        per id, the paper's section-5 minimal replica mass; common nodes
        that merely changed position inside the set move nothing.

        ``max_new_seg`` enables the R-aware ADDITION-NUMBER prefilter (the
        replica trace's AN; sound, plan-preserving).  ``known_before``
        (aligned (len(ids), R) v replica sets a caller already maintains,
        e.g. the coordinator's owner table) saves the host path one of the
        two placement sweeps.  ``mesh=`` scales the dual replica diff over
        the mesh's data axis, bit-identically, as in ``plan``.
        """
        import time

        t0 = time.perf_counter()
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        sweep = self._sweep(mesh)
        hier = bool(getattr(self.engine, "hierarchical", False))
        if hier and max_new_seg is not None:
            raise ValueError(
                "the ADDITION-NUMBER prefilter is flat-table semantics; "
                "hierarchical plans scan the full id set (max_new_seg=None)"
            )
        # Hierarchical engines always diff through the fused two-level
        # kernel path (node-plane alignment, domains validated globally
        # unique) -- the host replica sweep returns (batch, R, 2) pairs.
        host = self.engine.backend == "numpy" and sweep is None and not hier
        if known_before is not None:
            known_before = np.asarray(known_before, dtype=np.int64)
        out: dict[str, list[np.ndarray]] = {
            k: [] for k in ("ids", "src", "dst", "idx", "slot", "src_slot")
        }
        for start in range(0, len(ids), chunk):
            c = ids[start : start + chunk]
            base = np.arange(start, start + len(c), dtype=np.int64)
            if max_new_seg is not None:
                keep = self._candidates(
                    c, v_from, max_new_seg, host, n_replicas=n_replicas
                )
                self._note_prefilter(len(keep), int(keep.sum()))
                c, base = c[keep], base[keep]
            if c.size == 0:
                continue
            if host:
                from repro.core.asura import align_replica_sets

                before = (
                    known_before[base]
                    if known_before is not None
                    else self.engine.place_replica_nodes_at(c, v_from, n_replicas)
                )
                dst = self.engine.place_replica_nodes_at(c, v_to, n_replicas)
                moved, src, src_slot = align_replica_sets(before, dst)
            else:
                # pow2-bucketed ragged chunks, as in ``plan``
                n_c = len(c)
                cp, _ = self._pad_pow2(
                    c, 1 if sweep is None else sweep.n_devices
                )
                if sweep is None:
                    moved_d, src_d, dst_d, slot_d = self.diff_replicas_device(
                        cp, v_from, v_to, n_replicas
                    )
                else:
                    moved_d, src_d, dst_d, slot_d = sweep.diff_replicas_device(
                        cp, v_from, v_to, n_replicas
                    )
                moved = np.asarray(moved_d)[:n_c]
                src = np.asarray(src_d)[:n_c].astype(np.int64)
                dst = np.asarray(dst_d)[:n_c].astype(np.int64)
                src_slot = np.asarray(slot_d)[:n_c]
            b_idx, r_idx = np.nonzero(moved)  # id-major, slot-minor
            out["ids"].append(c[b_idx])
            out["src"].append(src[b_idx, r_idx])
            out["dst"].append(dst[b_idx, r_idx])
            out["idx"].append(base[b_idx])
            out["slot"].append(r_idx.astype(np.int32))
            out["src_slot"].append(src_slot[b_idx, r_idx].astype(np.int32))
        cat = lambda parts, dtype: (  # noqa: E731
            np.concatenate(parts) if parts else np.zeros(0, dtype=dtype)
        )
        plan = MigrationPlan(
            v_from=v_from,
            v_to=v_to,
            ids=cat(out["ids"], np.uint32),
            src=cat(out["src"], np.int64),
            dst=cat(out["dst"], np.int64),
            index=cat(out["idx"], np.int64),
            n_scanned=len(ids),
            n_replicas=n_replicas,
            slot=cat(out["slot"], np.int32),
            src_slot=cat(out["src_slot"], np.int32),
        )
        self._note_plan("planner.plan_replicas", plan, t0)
        return plan

    def _candidates(
        self,
        chunk: np.ndarray,
        v_from: int,
        max_new_seg: int,
        host: bool,
        n_replicas: int = 1,
    ) -> np.ndarray:
        """AN <= max_new_seg prefilter mask (sound: unknown -> candidate);
        the ADDITION NUMBER is computed for the R-replica trace."""
        if host:
            from repro.core.asura import addition_numbers_batch

            art = self.engine.artifact_for(v_from)
            lengths = art.len32.astype(np.float64) / 2.0**32  # exact round-trip
            an = addition_numbers_batch(
                chunk,
                lengths,
                art.node_of,
                n_replicas,
                params=self.engine.params,
            )
            return an <= max_new_seg
        an = np.asarray(
            self.engine.addition_numbers_device(
                chunk, version=v_from, n_replicas=n_replicas
            )
        )
        return (an < 0) | (an <= max_new_seg)
