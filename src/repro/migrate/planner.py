"""Migration layer 1: the streaming version-diff planner.

A membership change turns cluster version v into v+1.  The planner answers
"which data must move, from where, to where" by placing every tracked id
under BOTH table versions (both artifacts coexist in the engine's LRU --
DESIGN.md section 6) and diffing the owners:

  * ``diff_device``   -- one chunk: (moved, src, dst) DEVICE arrays, zero
                         host syncs (the fused dual-table kernel,
                         ``kernels.ops.diff_nodes_on_tables_device``).
  * ``plan_stream``   -- the streaming sweep: iterate id chunks through
                         ``diff_device`` so tens of millions of ids are
                         diffed in fixed device memory.  Yields device
                          4-tuples and never touches the host (tested under
                         a transfer guard).
  * ``plan``          -- host-facing assembly into a ``MigrationPlan``
                         (the moved rows only).  For the common add-node
                         case, pass ``max_new_seg`` to enable the
                         device-side ADDITION-NUMBER prefilter (section
                         2.D): a cheap metadata sweep marks the candidate
                         set and only candidates pay the full dual diff.

ASURA's optimality theorems make the diff minimal by construction; the
oracle tests re-verify against brute force (tests/test_migrate.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

DEFAULT_CHUNK = 1 << 20  # ids per streaming chunk (fixed device memory)


@dataclasses.dataclass(frozen=True)
class MigrationPlan:
    """The moved rows of a two-version placement diff.

    ``ids[i]`` must move from node ``src[i]`` (its v owner) to node
    ``dst[i]`` (its v+1 owner); ``index[i]`` is the row's position in the
    scanned id array (so callers can update per-id side tables without a
    search).  Rows keep scan order.
    """

    v_from: int
    v_to: int
    ids: np.ndarray  # uint32, moved ids
    src: np.ndarray  # int64, owner under v_from
    dst: np.ndarray  # int64, owner under v_to
    index: np.ndarray  # int64, positions in the scanned id array
    n_scanned: int

    @property
    def n_moves(self) -> int:
        return int(self.ids.shape[0])

    @property
    def moved_fraction(self) -> float:
        return self.n_moves / max(1, self.n_scanned)

    def moves_dict(self) -> dict[int, tuple[int, int]]:
        """datum id -> (src, dst), built from the vectorized arrays (no
        per-candidate Python compare loop)."""
        return dict(
            zip(
                self.ids.tolist(),
                zip(self.src.tolist(), self.dst.tolist()),
            )
        )


class MigrationPlanner:
    """Version-diff planner bound to one ``PlacementEngine``.

    Both versions' artifacts must be cached (place at v before mutating --
    every engine consumer already does) or ``engine.artifact_for`` raises.
    """

    def __init__(self, engine):
        self.engine = engine

    # -- device streaming sweep ---------------------------------------------

    def diff_device(self, datum_ids, v_from: int, v_to: int):
        """One chunk -> (moved, src, dst) device arrays, zero host syncs."""
        return self.engine.diff_nodes_device(datum_ids, v_from, v_to)

    def plan_stream(self, id_chunks, v_from: int, v_to: int):
        """Streaming sweep: yield ``(ids, moved, src, dst)`` per chunk.

        ``id_chunks`` is any iterable of id arrays (device arrays keep the
        whole sweep sync-free; NumPy chunks pay one upload each -- the
        host-feeding pattern).  Device memory is bounded by the largest
        chunk, not the id population.
        """
        for chunk in id_chunks:
            moved, src, dst = self.diff_device(chunk, v_from, v_to)
            yield chunk, moved, src, dst

    @staticmethod
    def chunked(ids: np.ndarray, chunk: int = DEFAULT_CHUNK):
        """Host-side chunking helper for ``plan_stream``."""
        for start in range(0, len(ids), chunk):
            yield ids[start : start + chunk]

    # -- host-facing plan assembly ------------------------------------------

    def plan(
        self,
        datum_ids,
        v_from: int,
        v_to: int,
        *,
        chunk: int = DEFAULT_CHUNK,
        max_new_seg: int | None = None,
        known_src=None,
    ) -> MigrationPlan:
        """Assemble the full ``MigrationPlan`` for a tracked id set.

        ``max_new_seg`` (the largest segment number the v -> v+1 change
        assigned; add-node events know it) enables the ADDITION-NUMBER
        prefilter: a device metadata sweep computes each id's AN against
        the v table and only ids with AN <= max_new_seg (or AN unknown,
        the sound fallback) pay the full dual-version diff -- the paper's
        section 2.D fast path for the common scale-out event.

        ``known_src`` (aligned with ``datum_ids``) supplies the v owners a
        caller already maintains (``ElasticCoordinator``'s owner table), so
        the host path places each id once, not twice.

        On the numpy backend the diff runs on the vectorized host path
        (same bit-identical placements, no jit warm-up) -- the engine's
        usual backend contract.
        """
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        host = self.engine.backend == "numpy"
        if known_src is not None:
            known_src = np.asarray(known_src, dtype=np.int64)
        out_ids: list[np.ndarray] = []
        out_src: list[np.ndarray] = []
        out_dst: list[np.ndarray] = []
        out_idx: list[np.ndarray] = []
        for start in range(0, len(ids), chunk):
            c = ids[start : start + chunk]
            base = np.arange(start, start + len(c), dtype=np.int64)
            if max_new_seg is not None:
                keep = self._candidates(c, v_from, max_new_seg, host)
                c, base = c[keep], base[keep]
            if c.size == 0:
                continue
            if host:
                src = (
                    known_src[base]
                    if known_src is not None
                    else self.engine.place_nodes_at(c, v_from)
                )
                dst = self.engine.place_nodes_at(c, v_to)
                moved = src != dst
            else:
                # Pad ragged (prefiltered) chunks to the next power of two
                # so the jitted diff sees O(log chunk) distinct shapes, not
                # one compile per candidate count.
                n_c = len(c)
                target = 1 << max(0, n_c - 1).bit_length()
                cp = np.pad(c, (0, target - n_c)) if target != n_c else c
                moved_d, src_d, dst_d = self.diff_device(cp, v_from, v_to)
                moved = np.asarray(moved_d)[:n_c]
                src = np.asarray(src_d)[:n_c].astype(np.int64)
                dst = np.asarray(dst_d)[:n_c].astype(np.int64)
            out_ids.append(c[moved])
            out_src.append(src[moved])
            out_dst.append(dst[moved])
            out_idx.append(base[moved])
        cat = lambda parts, dtype: (  # noqa: E731
            np.concatenate(parts) if parts else np.zeros(0, dtype=dtype)
        )
        return MigrationPlan(
            v_from=v_from,
            v_to=v_to,
            ids=cat(out_ids, np.uint32),
            src=cat(out_src, np.int64),
            dst=cat(out_dst, np.int64),
            index=cat(out_idx, np.int64),
            n_scanned=len(ids),
        )

    def _candidates(
        self, chunk: np.ndarray, v_from: int, max_new_seg: int, host: bool
    ) -> np.ndarray:
        """AN <= max_new_seg prefilter mask (sound: unknown -> candidate)."""
        if host:
            from repro.core.asura import addition_numbers_batch

            art = self.engine.artifact_for(v_from)
            lengths = art.len32.astype(np.float64) / 2.0**32  # exact round-trip
            an = addition_numbers_batch(
                chunk, lengths, art.node_of, params=self.engine.params
            )
            return an <= max_new_seg
        an = np.asarray(self.engine.addition_numbers_device(chunk, version=v_from))
        return (an < 0) | (an <= max_new_seg)
