"""Migration layer 2: the bandwidth-throttled mover.

Draining a ``MigrationPlan`` all at once would saturate the cluster
network exactly when it is already degraded (the scenario Sequential
Checking, arXiv:1707.00904, and the mean-field repair analysis,
arXiv:1701.00335, treat as the scarce resource).  The mover drains the
plan in ROUNDS under per-node ingress/egress budgets:

  * ``MigrationState`` -- the plan plus a landed bitmap (which moves have
    physically completed) and a device view of the still-pending id set
    for the dual-version read rule (``live.py``),
  * ``ThrottledMover``  -- each round picks pending rows in plan order,
    admitting a row only while both its source's egress budget and its
    destination's ingress budget have headroom, and returns the round's
    per-(src, dst) movement matrix.  The clock is injected (simulated,
    like ``runtime/failures.py``) so ``pump()`` advances exactly the
    rounds the wall time allows and tests stay deterministic.

Budget admission is conservative: ranks are computed per src group and
per dst group up front (vectorized), and a row is admitted iff BOTH ranks
are within budget -- a row blocked on one side may leave a slot of the
other side unused for a round, but neither budget is ever exceeded.
"""

from __future__ import annotations

import math
from typing import Callable

import numpy as np

from .drain import DrainDriver
from .planner import MigrationPlan


def _group_ranks(keys: np.ndarray) -> np.ndarray:
    """Rank of each element within its value group, preserving order.

    ``keys = [7, 3, 7, 7, 2]`` -> ``[0, 0, 1, 2, 0]``: the cumcount the
    budget admission is defined on (see ``_GroupIndex`` for the per-round
    sort-free evaluation).
    """
    if keys.size == 0:
        return np.zeros(0, dtype=np.int64)
    return _GroupIndex(keys).ranks(np.ones(len(keys), dtype=bool))


class _GroupIndex:
    """Per-round group-rank evaluation without per-round sorting.

    The plan's row order never changes -- only the pending mask does -- so
    the stable sort by node and the group boundaries are computed ONCE;
    each round the rank of every pending row within its group's pending
    rows is a segmented cumsum over the precomputed order: O(n) arithmetic,
    no sort, and bit-identical to ranking the compacted pending set.
    """

    def __init__(self, keys: np.ndarray):
        self.order = np.argsort(keys, kind="stable")
        sorted_keys = keys[self.order]
        self.is_start = np.empty(len(keys), dtype=bool)
        if len(keys):
            self.is_start[0] = True
            np.not_equal(sorted_keys[1:], sorted_keys[:-1], out=self.is_start[1:])

    def ranks(self, flags: np.ndarray) -> np.ndarray:
        """Rank of each row among the FLAGGED rows of its group (row order);
        meaningful only where ``flags`` is True."""
        if flags.size == 0:
            return np.zeros(0, dtype=np.int64)
        f = flags[self.order].astype(np.int64)
        cum = np.cumsum(f)
        before = cum - f  # flagged rows anywhere before this position
        base = np.maximum.accumulate(np.where(self.is_start, before, 0))
        ranks = np.empty(len(f), dtype=np.int64)
        ranks[self.order] = before - base
        return ranks


def _budget_of(budget, nodes: np.ndarray) -> np.ndarray:
    """Per-row budget array from None (unlimited), a scalar, or a dict.

    The dict path pays one Python lookup per DISTINCT node, not per
    pending row -- rounds over multi-million-row plans stay NumPy-bound.
    """
    no_limit = np.iinfo(np.int64).max
    if budget is None:
        return np.full(len(nodes), no_limit, dtype=np.int64)
    if isinstance(budget, dict):
        uniq, inverse = np.unique(nodes, return_inverse=True)
        caps = np.array(
            [budget.get(int(n), no_limit) for n in uniq], dtype=np.int64
        )
        return caps[inverse]
    return np.full(len(nodes), int(budget), dtype=np.int64)


def _scan_rounds(landed, *, src, dst, valid, src_c, dst_c, n_bins, k):
    """k throttled admission rounds in ONE jit over the pow2-padded plan.

    Module-level so jax's jit cache (keyed on array shapes + the static
    ``(n_bins, k)``) is shared by every mover in the process.  Each round
    recomputes the per-group admission ranks with the ``_GroupIndex``
    recurrence -- ``lax.cummax`` standing in for ``np.maximum.accumulate``
    -- and scatter-adds the admitted rows into a dense (n_bins, n_bins)
    movement matrix.
    """
    return _get_scan_rounds_jit()(
        landed, *src, *dst, valid, src_c, dst_c, n_bins=n_bins, k=k
    )


def _scan_rounds_impl(
    landed,
    order_s, start_s, cap_s,
    order_d, start_d, cap_d,
    valid, src_c, dst_c,
    *, n_bins, k,
):
    import jax
    import jax.numpy as jnp

    P = landed.shape[0]

    def ranks(order, is_start, pend):
        f = pend[order].astype(jnp.int32)
        cum = jnp.cumsum(f)
        before = cum - f
        base = jax.lax.cummax(jnp.where(is_start, before, 0))
        return jnp.zeros((P,), jnp.int32).at[order].set(before - base)

    def one(landed, _):
        pend = valid & ~landed
        take = (
            pend
            & (ranks(order_s, start_s, pend) < cap_s)
            & (ranks(order_d, start_d, pend) < cap_d)
        )
        mat = jnp.zeros((n_bins, n_bins), jnp.int32).at[src_c, dst_c].add(
            take.astype(jnp.int32)
        )
        return landed | take, mat

    return jax.lax.scan(one, landed, None, length=k)


_scan_rounds_jit = None  # jitted lazily: keep jax imports off the host path


def _get_scan_rounds_jit():
    global _scan_rounds_jit
    if _scan_rounds_jit is None:
        import jax

        _scan_rounds_jit = jax.jit(
            _scan_rounds_impl, static_argnames=("n_bins", "k")
        )
    return _scan_rounds_jit


class MigrationState:
    """A plan plus its landed bitmap -- the single source of truth for the
    dual-version read rule.

    Rows are per (id, replica_slot) -- the PER-SLOT LANDED BITMAP of
    DESIGN.md section 10; single-owner plans are the R=1 case.
    ``landed[i]`` flips True when row i's replica has physically arrived at
    ``dst[i]`` (and left ``src[i]``); until then readers of that slot must
    be routed to its v-side source.  ``pending_device()`` exposes the
    still-pending id set as a sorted, sentinel-padded device array so the
    single-owner serving hot path tests membership with zero host syncs
    (padding to the next power of two bounds recompiles at O(log n)
    distinct shapes); ``pending_replicas_device()`` is the per-slot twin:
    one sorted (ids, src) pair per replica slot, stacked (R, P), so the
    replica read rule probes all R slots in one jitted vmap.
    """

    _SENTINEL = np.uint32(0xFFFFFFFF)

    def __init__(self, plan: MigrationPlan):
        self.plan = plan
        self.landed = np.zeros(plan.n_moves, dtype=bool)
        self._sorted_pending = None  # host cache for the serving hot path
        self._dev_view = None  # (padded sorted pending ids, count) device pair
        self._slot_host = None  # per-slot (sorted ids, src) host cache
        self._slot_dev = None  # per-slot device view (ids, src, counts)

    # -- host views ----------------------------------------------------------

    @property
    def n_pending(self) -> int:
        return int((~self.landed).sum())

    @property
    def done(self) -> bool:
        return self.n_pending == 0

    def pending_ids(self) -> np.ndarray:
        return self.plan.ids[~self.landed]

    def landed_ids(self) -> np.ndarray:
        return self.plan.ids[self.landed]

    def is_pending(self, datum_ids) -> np.ndarray:
        """Vectorized membership of ids in the still-pending move set.

        Probes a sorted pending array cached per round (invalidated by
        ``mark_landed``), so a serving read batch costs O(batch log
        pending), not a fresh sort of the pending set per call."""
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        if self._sorted_pending is None:
            self._sorted_pending = np.sort(self.pending_ids())
        pending = self._sorted_pending
        if pending.size == 0:
            return np.zeros(ids.shape, dtype=bool)
        pos = np.searchsorted(pending, ids)
        return (pos < pending.size) & (pending[np.minimum(pos, pending.size - 1)] == ids)

    def mark_landed(self, rows: np.ndarray) -> None:
        """Flip plan rows to landed (the mover calls this per round)."""
        self.landed[rows] = True
        self._sorted_pending = None  # host and device views are stale
        self._dev_view = None
        self._slot_host = None
        self._slot_dev = None

    # -- per-slot views (replica read rule) ------------------------------------

    def _slot_tables(self) -> list[tuple[np.ndarray, np.ndarray]]:
        """Per-slot sorted pending ``(ids, src)`` pairs, cached per round.

        Within one slot each id appears at most once (a plan row is a
        unique (id, slot)), so a sorted array per slot supports the same
        O(batch log pending) probe ``is_pending`` uses."""
        if self._slot_host is None:
            plan = self.plan
            tables = []
            for r in range(plan.n_replicas):
                mask = ~self.landed & (plan.slot == r)
                ids = plan.ids[mask]
                src = plan.src[mask]
                order = np.argsort(ids, kind="stable")
                tables.append((ids[order], src[order]))
            self._slot_host = tables
        return self._slot_host

    def pending_replicas(self, datum_ids) -> tuple[np.ndarray, np.ndarray]:
        """(batch, R) pending mask + aligned v-side sources (host path).

        ``pending[b, r]`` says slot r of id b still awaits its copy;
        ``src[b, r]`` is then the node that holds that replica's bytes
        right now (meaningful only where pending)."""
        ids = np.atleast_1d(np.asarray(datum_ids, dtype=np.uint32))
        R = self.plan.n_replicas
        pending = np.zeros((len(ids), R), dtype=bool)
        src = np.zeros((len(ids), R), dtype=np.int64)
        for r, (p_ids, p_src) in enumerate(self._slot_tables()):
            if p_ids.size == 0:
                continue
            pos = np.searchsorted(p_ids, ids)
            pos_c = np.minimum(pos, p_ids.size - 1)
            hit = (pos < p_ids.size) & (p_ids[pos_c] == ids)
            pending[:, r] = hit
            src[hit, r] = p_src[pos_c[hit]]
        return pending, src

    def pending_replicas_device(self):
        """Per-slot device view: ``(ids_pad, src_pad, counts)``.

        ``ids_pad`` (R, P) sorted sentinel-padded pending ids per slot,
        ``src_pad`` (R, P) their aligned v-side sources, ``counts`` (R,)
        live lengths.  P is the shared next power of two, so recompiles
        stay O(log n) and the replica read rule vmaps one probe over the
        static R slots.  Rebuilt lazily after ``mark_landed`` -- one upload
        per round on the control path; call outside any transfer guard.
        """
        if self._slot_dev is None:
            import jax.numpy as jnp

            tables = self._slot_tables()
            n_max = max((len(t[0]) for t in tables), default=0)
            padded_len = max(1, 1 << (n_max - 1).bit_length()) if n_max else 1
            R = self.plan.n_replicas
            ids_pad = np.full((R, padded_len), self._SENTINEL, dtype=np.uint32)
            src_pad = np.full((R, padded_len), -1, dtype=np.int32)
            counts = np.zeros(R, dtype=np.int32)
            for r, (p_ids, p_src) in enumerate(tables):
                ids_pad[r, : len(p_ids)] = p_ids
                src_pad[r, : len(p_ids)] = p_src
                counts[r] = len(p_ids)
            self._slot_dev = (
                jnp.asarray(ids_pad),
                jnp.asarray(src_pad),
                jnp.asarray(counts),
            )
        return self._slot_dev

    # -- device view ----------------------------------------------------------

    def pending_device(self):
        """(sorted_padded_ids, count) device pair for sync-free membership.

        Rebuilt lazily after ``mark_landed`` -- ONE upload per round on the
        control path, so the serving path (``live.route_device``) stays
        guarded-transfer clean.  Call this outside any transfer guard.
        """
        if self._dev_view is None:
            import jax.numpy as jnp

            pending = np.sort(self.pending_ids())
            n = len(pending)
            padded_len = max(1, 1 << (n - 1).bit_length()) if n else 1
            padded = np.full(padded_len, self._SENTINEL, dtype=np.uint32)
            padded[:n] = pending
            self._dev_view = (jnp.asarray(padded), jnp.asarray(np.int32(n)))
        return self._dev_view


class ThrottledMover(DrainDriver):
    """Drains a ``MigrationState`` in budgeted rounds.

    ``egress`` / ``ingress``: max rows (replica copies) a node may send /
    receive per round -- ``None`` (unlimited), a scalar applied to every
    node, or a ``{node_id: limit}`` dict (missing nodes unlimited).  Rows
    are per (id, replica_slot), so budgets and movement matrices account
    every replica copy individually.  ``clock`` is an injected time
    source; ``pump()`` runs however many whole ``round_seconds`` periods
    have elapsed since the last call, so a simulated clock drives
    deterministic tests and a real clock drives a real drain loop.  The
    round/pump/run verbs come from the shared ``DrainDriver`` loop.
    """

    def __init__(
        self,
        state: MigrationState,
        *,
        egress=None,
        ingress=None,
        clock: Callable[[], float] | None = None,
        round_seconds: float = 1.0,
        ledger=None,
        metrics=None,
        bytes_per_row: int = 0,
    ):
        self.state = state
        self.egress = egress
        self.ingress = ingress
        self.clock = clock
        self.round_seconds = float(round_seconds)
        # observability (optional): a TraceLedger gets one structured
        # event per round via the DrainDriver hook; ``bytes_per_row``
        # prices each (id, slot) row so the events/counters carry bytes.
        self.ledger = ledger
        self.metrics = metrics
        self.bytes_per_row = int(bytes_per_row)
        self.rounds_done = 0
        self._pumped = 0  # clock-paced rounds only (manual round()s excluded)
        self.history: list[dict[tuple[int, int], int]] = []
        self._t0 = clock() if clock is not None else 0.0
        # Row order and budgets never change; precompute so each round is
        # pure O(n) arithmetic (no sort, no Python per-row lookups).
        self._by_src = _GroupIndex(state.plan.src)
        self._by_dst = _GroupIndex(state.plan.dst)
        self._cap_src = _budget_of(egress, state.plan.src)
        self._cap_dst = _budget_of(ingress, state.plan.dst)
        # Device round engine (lazy): built on the first round_block().
        self._dev_rounds = None
        self._block_fns: dict[int, object] = {}

    @property
    def done(self) -> bool:
        return self.state.done

    @property
    def next_round_at(self) -> float | None:
        """Clock time the next paced round becomes due (None: no clock or
        already drained).  Event-driven callers (the durability simulator)
        use this to jump virtual time straight to the next thing that can
        happen instead of polling round by round."""
        if self.clock is None or self.done:
            return None
        return self._t0 + (self._pumped + 1) * self.round_seconds

    def _pending_desc(self) -> str:
        return f"{self.state.n_pending} rows pending"

    def _round(self) -> dict[tuple[int, int], int]:
        """One throttled round -> the per-(src, dst) movement matrix."""
        state = self.state
        pending = ~state.landed
        take = (
            pending
            & (self._by_src.ranks(pending) < self._cap_src)
            & (self._by_dst.ranks(pending) < self._cap_dst)
        )
        moved_rows = np.nonzero(take)[0]
        state.mark_landed(moved_rows)
        matrix: dict[tuple[int, int], int] = {}
        if moved_rows.size:
            pairs, counts = np.unique(
                np.stack([state.plan.src[take], state.plan.dst[take]], axis=1),
                axis=0,
                return_counts=True,
            )
            matrix = {
                (int(s), int(d)): int(c) for (s, d), c in zip(pairs, counts)
            }
        self.rounds_done += 1
        self.history.append(matrix)
        return matrix

    def _pump_rounds(self) -> list[dict[tuple[int, int], int]]:
        """The injected-clock pacing (0 rounds if none are due).

        Clock-paced rounds are accounted separately from manual ``round()``
        calls, so mixing an eager kick-off round with ``pump()`` never
        skips periods the clock has earned."""
        if self.clock is None:
            return [] if self.done else [self._round()]
        due = int(math.floor((self.clock() - self._t0) / self.round_seconds))
        out = []
        while self._pumped < due and not self.done:
            out.append(self._round())
            self._pumped += 1
        return out

    # -- device-resident round blocks (DESIGN.md section 15) ------------------

    def _device_rounds(self):
        """Lazy device round engine over the pow2-padded plan view.

        Everything the admission rule needs is plan-constant -- the stable
        group orders, group-start flags, per-row budget caps, scatter
        coordinates -- so it uploads ONCE per mover and each round becomes
        pure on-device arithmetic: a segmented cumsum per group axis (the
        ``_GroupIndex.ranks`` recurrence, with ``lax.cummax`` standing in
        for ``np.maximum.accumulate``) and one landed-bitmap OR.  Budget
        caps clamp to int32 max: ranks are < P <= 2^31, so the comparison
        is unchanged.  Returns None for an empty plan."""
        if self._dev_rounds is None:
            plan = self.state.plan
            n = plan.n_moves
            if n == 0:
                self._dev_rounds = False
            else:
                import jax.numpy as jnp

                P = 1 << max(0, n - 1).bit_length()
                no_key = np.iinfo(np.int64).max  # pads sort last
                i32max = np.iinfo(np.int32).max

                def axis(keys, caps):
                    kp = np.full(P, no_key, dtype=np.int64)
                    kp[:n] = keys
                    order = np.argsort(kp, kind="stable")
                    sk = kp[order]
                    is_start = np.empty(P, dtype=bool)
                    is_start[0] = True
                    np.not_equal(sk[1:], sk[:-1], out=is_start[1:])
                    cp = np.zeros(P, dtype=np.int64)
                    cp[:n] = np.minimum(caps, i32max)
                    return (
                        jnp.asarray(order.astype(np.int32)),
                        jnp.asarray(is_start),
                        jnp.asarray(cp.astype(np.int32)),
                    )

                n_bins = int(max(plan.src.max(), plan.dst.max())) + 1
                coord = np.zeros((2, P), dtype=np.int32)
                coord[0, :n] = plan.src
                coord[1, :n] = plan.dst
                self._dev_rounds = {
                    "src": axis(plan.src, self._cap_src),
                    "dst": axis(plan.dst, self._cap_dst),
                    "valid": jnp.asarray(np.arange(P) < n),
                    "src_c": jnp.asarray(coord[0]),
                    "dst_c": jnp.asarray(coord[1]),
                    "n_bins": n_bins,
                    "P": P,
                }
        return self._dev_rounds or None

    def _block_fn(self, k: int):
        """k-round scan, bound to this mover's plan-constant arrays.

        The jit itself is the MODULE-LEVEL ``_scan_rounds`` (static over
        (k, n_bins) and cached by jax on array shapes), so two movers with
        same-shape plans share one compile -- a fresh migration pays no
        retrace for its round blocks."""
        fn = self._block_fns.get(k)
        if fn is not None:
            return fn
        import functools

        dv = self._device_rounds()
        fn = functools.partial(
            _scan_rounds,
            src=dv["src"],
            dst=dv["dst"],
            valid=dv["valid"],
            src_c=dv["src_c"],
            dst_c=dv["dst_c"],
            n_bins=dv["n_bins"],
            k=k,
        )
        self._block_fns[k] = fn
        return fn

    def _round_block(self, k: int) -> list[dict[tuple[int, int], int]]:
        """k throttled rounds on device -- ONE dispatch, one sync back.

        Bit-identical to k sequential ``_round()`` calls: the scan carries
        the landed bitmap so each round's admission sees the previous
        round's landings, and the per-round matrices aggregate the same
        (src, dst) pair counts ``np.unique`` produces on the host path.
        Runs exactly k rounds even once drained (trailing rounds move
        nothing and record empty matrices, like the host loop)."""
        state = self.state
        if self._device_rounds() is None:  # empty plan: host loop is exact
            return [self._round() for _ in range(k)]
        import jax.numpy as jnp

        dv = self._device_rounds()
        P, n = dv["P"], state.plan.n_moves
        landed = state.landed if n == P else np.pad(state.landed, (0, P - n))
        landed_out, mats = self._block_fn(k)(jnp.asarray(landed))
        landed_np = np.asarray(landed_out)[:n]
        mats_np = np.asarray(mats)
        newly = landed_np & ~state.landed
        state.mark_landed(np.nonzero(newly)[0])
        matrices: list[dict[tuple[int, int], int]] = []
        for r in range(k):
            s_idx, d_idx = np.nonzero(mats_np[r])
            matrices.append(
                {
                    (int(s), int(d)): int(mats_np[r, s, d])
                    for s, d in zip(s_idx, d_idx)
                }
            )
        self.rounds_done += k
        self.history.extend(matrices)
        return matrices

    def round_block(self, k: int) -> list[dict[tuple[int, int], int]]:
        """Run k budgeted rounds in ONE device dispatch; returns the k
        per-round movement matrices (ledger-emitted like any other round).
        Counts as manual rounds: clock pacing (``pump``) is unaffected."""
        k = int(k)
        if k < 1:
            raise ValueError(f"round_block needs k >= 1, got {k}")
        return self._emit_rounds(self._advance(lambda: self._round_block(k)))

    def movement_matrix(self) -> dict[tuple[int, int], int]:
        """Accumulated (src, dst) -> rows moved so far, across all rounds."""
        total: dict[tuple[int, int], int] = {}
        for matrix in self.history:
            for pair, count in matrix.items():
                total[pair] = total.get(pair, 0) + count
        return total
